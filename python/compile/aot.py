"""AOT export: lower the L2 entry points to HLO *text* artifacts.

HLO text — not `.serialize()` protos — is the interchange format: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the rust
side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/load_hlo and its README.

Run once at build time (`make artifacts`); the rust binary is then fully
self-contained.  Python never executes on the simulation hot path.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


EXPORTS = {
    # name -> (fn, example args)
    "stage_oracle": (
        model.stage_oracle,
        lambda: (
            f32(model.R_MAX),
            f32(model.R_MAX),
            f32(model.R_MAX),
            f32(8),
            f32(12),
        ),
    ),
    "cosim_step": (
        model.cosim_step,
        lambda: (
            f32(model.T_COSIM),
            f32(model.T_COSIM),
            f32(model.T_COSIM),
            f32(8),
            f32(1),
        ),
    ),
    "bin_power": (
        model.bin_power,
        lambda: (f32(model.N_SAMPLES), f32(model.N_SAMPLES), f32(model.N_SAMPLES)),
    ),
}


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, args) in EXPORTS.items():
        lowered = jax.jit(fn).lower(*args())
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    manifest["shapes"] = {
        "R_MAX": model.R_MAX,
        "T_COSIM": model.T_COSIM,
        "N_SAMPLES": model.N_SAMPLES,
        "N_BINS": model.N_BINS,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export_all(args.out_dir)


if __name__ == "__main__":
    main()
