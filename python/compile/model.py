"""L2: the JAX compute graph the rust coordinator calls at runtime.

Three exported entry points (all lowered to HLO text by aot.py and loaded
by rust/src/runtime):

  stage_oracle(new_tokens[R], context[R], active[R], mp[8], gp[12])
      -> (t_stage, flops, mfu, power)          # the per-batch-stage oracle
  cosim_step(load[T], solar[T], ci[T], bp[8], soc0[1])
      -> (soc[T], grid[T], solar_used[T], batt[T], emissions[T])
  bin_power(power[N], dt[N], bin_idx[N])
      -> (energy[B], weight[B])                # Eq. 5 binning

Each calls its L1 Pallas kernel so everything lowers into a single fused
HLO module per entry point.  Static shapes (R=128, T=1440, N=4096, B=512)
are the AOT contract with the rust side — see rust/src/runtime/artifacts.rs.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.stage_cost import stage_cost
from .kernels.battery import microgrid
from .kernels.binning import bin_power as bin_power_kernel

# AOT static shapes — the rust runtime pads to these.
R_MAX = 128      # max requests per batch stage (paper's batch cap)
T_COSIM = 1440   # one day of 1-minute steps per cosim call
N_SAMPLES = 4096  # power samples per binning call
N_BINS = 512     # bins per binning call


def stage_oracle(new_tokens, context, active, mp, gp):
    """Latency / FLOPs / MFU (Eq. 2) / per-GPU power (Eq. 1) of one stage.

    Combines the L1 per-request cost kernel with the roofline latency
    model and the power law; mirrors ref.ref_stage_oracle exactly (tested
    in python/tests/test_model.py).
    """
    flops_r, kv_r = stage_cost(new_tokens, context, active, mp)
    tp = mp[ref.MP_TP]
    pp = mp[ref.MP_PP]

    flops_stage = jnp.sum(flops_r) / pp
    tokens = jnp.sum(new_tokens * active)
    layers_pp = mp[ref.MP_LAYERS] / pp
    h = mp[ref.MP_HIDDEN]

    wbytes = ref.ref_weight_bytes(mp) / (tp * pp)
    kv_bytes = jnp.sum(kv_r) / (tp * pp)

    t_comp = flops_stage / (tp * gp[ref.GP_PEAK_FLOPS] * gp[ref.GP_FLOPS_EFF])
    t_mem = (wbytes + kv_bytes) / (gp[ref.GP_HBM_BW] * gp[ref.GP_MEM_EFF])

    act_bytes = tokens * h * 2.0
    ring = 2.0 * (tp - 1.0) / jnp.maximum(tp, 1.0)
    t_tp = jnp.where(
        tp > 1.0,
        layers_pp
        * 2.0
        * (ring * act_bytes / gp[ref.GP_LINK_BW] + gp[ref.GP_LINK_LAT]),
        0.0,
    )
    t_pp = jnp.where(
        pp > 1.0, act_bytes / gp[ref.GP_LINK_BW] + gp[ref.GP_LINK_LAT], 0.0
    )

    t_stage = (
        jnp.maximum(t_comp, t_mem)
        + t_tp
        + t_pp
        + gp[ref.GP_T_OVERHEAD]
        + layers_pp * gp[ref.GP_LAYER_OVERHEAD]
    )

    mfu = flops_stage / (t_stage * tp * gp[ref.GP_PEAK_FLOPS])
    power = ref.ref_power(
        mfu,
        gp[ref.GP_P_IDLE],
        gp[ref.GP_P_MAX],
        gp[ref.GP_MFU_SAT],
        gp[ref.GP_GAMMA],
    )
    return t_stage, flops_stage, mfu, power


def cosim_step(load_w, solar_w, ci, bp, soc0):
    """One T-step microgrid window (L1 battery scan kernel)."""
    return tuple(microgrid(load_w, solar_w, ci, bp, soc0))


def bin_power(power, dt, bin_idx):
    """Eq. 5 duration-weighted binning (L1 binning kernel)."""
    return tuple(bin_power_kernel(power, dt, bin_idx, N_BINS))
