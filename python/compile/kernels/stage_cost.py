"""L1 Pallas kernel: per-request transformer stage cost (FLOPs + KV bytes).

This is the numerator of the paper's Eq. 2 evaluated for every request in
a batch stage — the innermost computation of the whole simulator, executed
once per simulated batch stage (hundreds of thousands of times per run).

TPU mapping (see DESIGN.md §6): the request axis is tiled into 128-wide
blocks (VPU-lane aligned); each tile's FLOP/byte computation is purely
elementwise so the whole block lives in VMEM with one HBM read per input
tile and one write per output tile.  The model-parameter vector is small
and replicated to every grid step.

VMEM footprint per grid step: 3 input tiles + 2 output tiles + params
= 5 * 128 * 4 B + 32 B ≈ 2.6 KiB — far under the ~16 MiB VMEM budget,
leaving room for the compiler to double-buffer the HBM streams.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO (see /opt/xla-example).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = 128


def _stage_cost_kernel(nt_ref, ctx_ref, act_ref, mp_ref, flops_ref, kv_ref):
    """One 128-request tile: elementwise FLOP / KV-byte arithmetic."""
    layers = mp_ref[ref.MP_LAYERS]
    h = mp_ref[ref.MP_HIDDEN]
    ffn = mp_ref[ref.MP_FFN]
    heads = mp_ref[ref.MP_HEADS]
    kvh = mp_ref[ref.MP_KV_HEADS]
    vocab = mp_ref[ref.MP_VOCAB]

    kv_dim = h * kvh / heads
    t = nt_ref[...] * act_ref[...]
    c = ctx_ref[...] * act_ref[...]

    proj = 2.0 * h * (2.0 * h + 2.0 * kv_dim)
    mlp = 6.0 * h * ffn
    attn = 4.0 * h * (c * t + t * (t + 1.0) * 0.5)
    head = 2.0 * h * vocab

    flops_ref[...] = layers * (t * (proj + mlp) + attn) + t * head
    kv_ref[...] = 2.0 * layers * kv_dim * (c + t) * 2.0


def stage_cost(new_tokens, context, active, mp):
    """Pallas-tiled per-request stage cost; matches ref.ref_stage_cost.

    Arguments are float32 arrays of identical length R (R % 128 == 0; the
    caller pads with active=0) plus the mp[8] model-parameter vector.
    """
    (r,) = new_tokens.shape
    assert r % TILE == 0, f"request axis {r} must be a multiple of {TILE}"
    grid = (r // TILE,)
    row = pl.BlockSpec((TILE,), lambda i: (i,))
    rep = pl.BlockSpec((mp.shape[0],), lambda i: (0,))
    return pl.pallas_call(
        _stage_cost_kernel,
        grid=grid,
        in_specs=[row, row, row, rep],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.float32),
        ],
        interpret=True,
    )(new_tokens, context, active, mp)
