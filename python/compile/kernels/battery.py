"""L1 Pallas kernel: Vessim-style microgrid / battery scan.

The co-simulation inner loop — per-minute power balance between the LLM
load, solar generation, a rate/SoC-limited battery, and the grid — is a
strictly sequential recurrence over the state of charge.  It is exported
as one kernel over a T-step horizon; the rust co-simulator chains chunks
by feeding the final SoC of one call into the next.

TPU mapping: the whole T-step window (default 1440 = one day of minutes,
5 input + 5 output arrays ≈ 57 KiB) is VMEM-resident; the recurrence runs
as a fori_loop with scalar carry, reading/writing VMEM directly — the
classic "small sequential scan on-chip" pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _microgrid_kernel(
    load_ref, solar_ref, ci_ref, bp_ref, soc0_ref,
    soc_ref, grid_ref, used_ref, batt_ref, em_ref,
):
    cap_wh = bp_ref[ref.BP_CAP_WH]
    soc_min = bp_ref[ref.BP_SOC_MIN]
    soc_max = bp_ref[ref.BP_SOC_MAX]
    max_chg = bp_ref[ref.BP_MAX_CHARGE_W]
    max_dis = bp_ref[ref.BP_MAX_DISCHARGE_W]
    eff_c = bp_ref[ref.BP_EFF_CHARGE]
    eff_d = bp_ref[ref.BP_EFF_DISCHARGE]
    dt_h = bp_ref[ref.BP_DT_S] / 3600.0

    t_steps = load_ref.shape[0]

    def step(i, soc):
        load = load_ref[i]
        solar = solar_ref[i]
        carbon = ci_ref[i]

        solar_used = jnp.minimum(solar, load)
        excess = solar - solar_used
        deficit = load - solar_used

        room_wh = (soc_max - soc) * cap_wh
        chg_w = jnp.minimum(excess, max_chg)
        chg_w = jnp.minimum(chg_w, room_wh / (dt_h * eff_c))
        chg_w = jnp.maximum(chg_w, 0.0)
        export_w = excess - chg_w

        avail_wh = (soc - soc_min) * cap_wh
        dis_w = jnp.minimum(deficit, max_dis)
        dis_w = jnp.minimum(dis_w, avail_wh * eff_d / dt_h)
        dis_w = jnp.maximum(dis_w, 0.0)
        import_w = deficit - dis_w

        soc_next = soc + (chg_w * eff_c - dis_w / eff_d) * dt_h / cap_wh
        soc_next = jnp.clip(soc_next, 0.0, 1.0)

        soc_ref[i] = soc_next
        grid_ref[i] = import_w - export_w
        used_ref[i] = solar_used
        batt_ref[i] = dis_w - chg_w
        em_ref[i] = import_w * dt_h / 1000.0 * carbon
        return soc_next

    jax.lax.fori_loop(0, t_steps, step, soc0_ref[0])


def microgrid(load_w, solar_w, ci, bp, soc0):
    """Pallas microgrid scan; matches ref.ref_microgrid.

    load_w, solar_w, ci: float32[T]; bp: float32[8]; soc0: float32[1].
    Returns (soc, grid_w, solar_used_w, batt_w, emissions_g), each [T].
    """
    (t,) = load_w.shape
    full = pl.BlockSpec((t,), lambda: (0,))
    prm = pl.BlockSpec((bp.shape[0],), lambda: (0,))
    scl = pl.BlockSpec((1,), lambda: (0,))
    out = jax.ShapeDtypeStruct((t,), jnp.float32)
    return pl.pallas_call(
        _microgrid_kernel,
        grid=(),
        in_specs=[full, full, full, prm, scl],
        out_specs=[full] * 5,
        out_shape=[out] * 5,
        interpret=True,
    )(load_w, solar_w, ci, bp, soc0)
