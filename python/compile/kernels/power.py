"""L1 Pallas kernel: the paper's Eq. 1 MFU -> power law, vectorized.

Used by the Vidur->Vessim pipeline to convert binned MFU traces into
instantaneous power, and by the stage oracle for single values.

TPU mapping: elementwise over 128-wide tiles; `pow` with a scalar
exponent lowers to exp/log on the VPU.  VMEM per step: 2 tiles + 4
params ≈ 1 KiB.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _power_kernel(mfu_ref, pp_ref, out_ref):
    p_idle = pp_ref[0]
    p_max = pp_ref[1]
    sat = pp_ref[2]
    gamma = pp_ref[3]
    x = jnp.clip(mfu_ref[...] / sat, 0.0, 1.0)
    out_ref[...] = p_idle + (p_max - p_idle) * jnp.power(x, gamma)


def power_law(mfu, power_params):
    """Eq. 1 over an arbitrary (128-multiple) MFU vector.

    power_params = [p_idle, p_max, mfu_sat, gamma] (float32[4]).
    """
    (n,) = mfu.shape
    assert n % TILE == 0, f"length {n} must be a multiple of {TILE}"
    row = pl.BlockSpec((TILE,), lambda i: (i,))
    rep = pl.BlockSpec((4,), lambda i: (0,))
    return pl.pallas_call(
        _power_kernel,
        grid=(n // TILE,),
        in_specs=[row, rep],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(mfu, power_params)
