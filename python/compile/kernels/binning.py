"""L1 Pallas kernel: Eq. 5 duration-weighted binning.

Variable-duration batch-stage power samples are folded into fixed-width
time bins:  energy[b] = sum_i P_i * dt_i [idx_i == b],
            weight[b] = sum_i dt_i       [idx_i == b].
The Eq. 5 weighted mean is energy/weight, computed by the caller so the
kernel output stays exactly mergeable across chunks.

TPU mapping: the grid walks (bin-tile, sample-tile); each step compares a
128-wide bin-id block against a 128-wide sample block (outer broadcast,
128x128 in VMEM — MXU-shaped though executed on the VPU) and accumulates
into a bins-resident output block.  The output block stays in VMEM across
the whole inner sample loop (revisiting grid dimension), so HBM sees each
bin tile exactly once.

VMEM per step: one 128 sample tile x3 + one 128x128 mask + 2 output tiles
≈ 67 KiB — comfortably double-bufferable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _binning_kernel(idx_ref, p_ref, dt_ref, e_ref, w_ref):
    j = pl.program_id(1)

    # Zero the accumulators on the first visit of this bin tile.
    @pl.when(j == 0)
    def _():
        e_ref[...] = jnp.zeros_like(e_ref)
        w_ref[...] = jnp.zeros_like(w_ref)

    i = pl.program_id(0)
    bins = i * TILE + jax.lax.iota(jnp.float32, TILE)  # bin ids of this tile
    idx = idx_ref[...]
    mask = bins[:, None] == idx[None, :]  # [bins, samples]
    e_ref[...] += jnp.sum(jnp.where(mask, (p_ref[...] * dt_ref[...])[None, :], 0.0), axis=1)
    w_ref[...] += jnp.sum(jnp.where(mask, dt_ref[...][None, :], 0.0), axis=1)


def bin_power(power, dt, bin_idx, n_bins):
    """Pallas-tiled Eq. 5 binning; matches ref.ref_bin_power.

    power, dt, bin_idx: float32[N] (N % 128 == 0; pad with dt=0).
    bin_idx holds float bin indices (exact small integers).
    n_bins must be a multiple of 128.
    """
    (n,) = power.shape
    assert n % TILE == 0 and n_bins % TILE == 0
    grid = (n_bins // TILE, n // TILE)
    sample = pl.BlockSpec((TILE,), lambda i, j: (j,))
    binrow = pl.BlockSpec((TILE,), lambda i, j: (i,))
    return pl.pallas_call(
        _binning_kernel,
        grid=grid,
        in_specs=[sample, sample, sample],
        out_specs=[binrow, binrow],
        out_shape=[
            jax.ShapeDtypeStruct((n_bins,), jnp.float32),
            jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        ],
        interpret=True,
    )(bin_idx, power, dt)
