"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each Pallas kernel in this package
must match its `ref_*` counterpart to float32 tolerance (see
python/tests/).  They are deliberately written in the most direct,
un-optimized style so a reviewer can check them against the paper's
equations:

  * Eq. 2   — per-stage FLOPs (MLP + attention) and MFU,
  * Eq. 1   — sublinear MFU -> power law,
  * Eq. 5   — duration-weighted power binning,
  * Sec 3.2 — the Vessim-style battery / microgrid step.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


# --------------------------------------------------------------------------
# Parameter-vector layouts (shared with the rust side; keep in sync with
# rust/src/runtime/artifacts.rs and python/compile/model.py)
# --------------------------------------------------------------------------

# model_params mp[8]:
MP_LAYERS, MP_HIDDEN, MP_FFN, MP_HEADS, MP_KV_HEADS, MP_VOCAB, MP_TP, MP_PP = range(8)

# gpu_params gp[12]:
(
    GP_PEAK_FLOPS,    # peak BF16 FLOPs/s of one GPU
    GP_HBM_BW,        # HBM bytes/s
    GP_P_IDLE,        # idle watts
    GP_P_MAX,         # max instantaneous watts
    GP_MFU_SAT,       # MFU saturation threshold (Eq. 1)
    GP_GAMMA,         # power-law exponent (Eq. 1)
    GP_FLOPS_EFF,     # achievable fraction of peak FLOPs (kernel efficiency)
    GP_MEM_EFF,       # achievable fraction of HBM bandwidth
    GP_T_OVERHEAD,    # fixed per-stage overhead, seconds (scheduler/launch)
    GP_LAYER_OVERHEAD,  # per-layer kernel-launch overhead, seconds
    GP_LINK_BW,       # interconnect bytes/s (NVLink pairwise / PCIe)
    GP_LINK_LAT,      # interconnect latency per collective, seconds
) = range(12)

# battery_params bp[8]:
(
    BP_CAP_WH,        # usable capacity, Wh
    BP_SOC_MIN,       # minimum state of charge, fraction
    BP_SOC_MAX,       # maximum state of charge, fraction
    BP_MAX_CHARGE_W,  # charge power limit, W
    BP_MAX_DISCHARGE_W,  # discharge power limit, W
    BP_EFF_CHARGE,    # charge efficiency, fraction
    BP_EFF_DISCHARGE,  # discharge efficiency, fraction
    BP_DT_S,          # step duration, seconds
) = range(8)


# --------------------------------------------------------------------------
# Per-request transformer stage cost (numerator of Eq. 2)
# --------------------------------------------------------------------------

def ref_stage_cost(new_tokens, context, active, mp):
    """Per-request forward FLOPs and KV-cache bytes for one batch stage.

    new_tokens[r] : tokens processed this iteration (prefill chunk, or 1
                    for a decode step).
    context[r]    : tokens already resident in the KV cache.
    active[r]     : 1.0 if slot r holds a live request.
    mp            : model-parameter vector (see layout above).

    Returns (flops[r], kv_bytes[r]) where flops is the *whole model*
    forward cost for this request's tokens and kv_bytes the KV-cache
    traffic (read of context + write of new tokens, both K and V).
    """
    layers = mp[MP_LAYERS]
    h = mp[MP_HIDDEN]
    ffn = mp[MP_FFN]
    heads = mp[MP_HEADS]
    kvh = mp[MP_KV_HEADS]
    vocab = mp[MP_VOCAB]

    kv_dim = h * kvh / heads
    t = new_tokens * active
    c = context * active

    # Projections per token per layer: Q (2h^2), O (2h^2), K, V (2*h*kv_dim each).
    proj = 2.0 * h * (2.0 * h + 2.0 * kv_dim)
    # SwiGLU MLP: three h x ffn matmuls.
    mlp = 6.0 * h * ffn
    # Causal attention over the running context: QK^T + AV, per layer.
    # Token j of the chunk attends to (c + j) positions: sum over the chunk
    # gives c*t + t*(t+1)/2.
    attn_positions = c * t + t * (t + 1.0) / 2.0
    attn = 4.0 * h * attn_positions
    # LM head + embedding, once per token (model-level, not per layer).
    head = 2.0 * h * vocab

    flops = layers * (t * (proj + mlp) + attn) + t * head

    # KV cache bytes: K and V, bf16 (2 bytes), all layers.
    kv_bytes = 2.0 * layers * kv_dim * (c + t) * 2.0
    return flops, kv_bytes


# --------------------------------------------------------------------------
# Stage oracle: roofline time, MFU (Eq. 2), power (Eq. 1)
# --------------------------------------------------------------------------

def ref_weight_bytes(mp):
    """Approximate bf16 parameter bytes of the whole model."""
    layers = mp[MP_LAYERS]
    h = mp[MP_HIDDEN]
    ffn = mp[MP_FFN]
    heads = mp[MP_HEADS]
    kvh = mp[MP_KV_HEADS]
    vocab = mp[MP_VOCAB]
    kv_dim = h * kvh / heads
    per_layer = h * (2.0 * h + 2.0 * kv_dim) + 3.0 * h * ffn
    embed = 2.0 * h * vocab  # embedding + lm head
    return 2.0 * (layers * per_layer + embed)


def ref_power(mfu, p_idle, p_max, mfu_sat, gamma):
    """Eq. 1: sublinear power law, clamped at the saturation threshold."""
    x = jnp.clip(mfu / mfu_sat, 0.0, 1.0)
    return p_idle + (p_max - p_idle) * jnp.power(x, gamma)


def ref_stage_oracle(new_tokens, context, active, mp, gp):
    """One pipeline-stage iteration: latency, FLOPs, MFU, per-GPU power.

    The returned FLOPs/latency describe ONE pipeline-parallel stage
    (layers/pp of the model) executed across its TP group, matching
    Vidur's "replica stage" granularity that the paper logs at.
    """
    flops_r, kv_r = ref_stage_cost(new_tokens, context, active, mp)
    tp = mp[MP_TP]
    pp = mp[MP_PP]

    flops_stage = jnp.sum(flops_r) / pp
    tokens = jnp.sum(new_tokens * active)
    layers_pp = mp[MP_LAYERS] / pp
    h = mp[MP_HIDDEN]

    # Per-GPU bytes moved: weight read (sharded over tp*pp) + KV traffic.
    wbytes = ref_weight_bytes(mp) / (tp * pp)
    kv_bytes = jnp.sum(kv_r) / (tp * pp)

    t_comp = flops_stage / (tp * gp[GP_PEAK_FLOPS] * gp[GP_FLOPS_EFF])
    t_mem = (wbytes + kv_bytes) / (gp[GP_HBM_BW] * gp[GP_MEM_EFF])

    # TP: two all-reduces per layer over the activations (ring cost).
    act_bytes = tokens * h * 2.0
    ring = 2.0 * (tp - 1.0) / jnp.maximum(tp, 1.0)
    t_tp = jnp.where(
        tp > 1.0,
        layers_pp * 2.0 * (ring * act_bytes / gp[GP_LINK_BW] + gp[GP_LINK_LAT]),
        0.0,
    )
    # PP: one activation send per stage boundary.
    t_pp = jnp.where(
        pp > 1.0, act_bytes / gp[GP_LINK_BW] + gp[GP_LINK_LAT], 0.0
    )

    t_stage = (
        jnp.maximum(t_comp, t_mem)
        + t_tp
        + t_pp
        + gp[GP_T_OVERHEAD]
        + layers_pp * gp[GP_LAYER_OVERHEAD]
    )

    # Eq. 2: achieved FLOPs over the stage group's peak.
    mfu = flops_stage / (t_stage * tp * gp[GP_PEAK_FLOPS])
    power = ref_power(
        mfu, gp[GP_P_IDLE], gp[GP_P_MAX], gp[GP_MFU_SAT], gp[GP_GAMMA]
    )
    return t_stage, flops_stage, mfu, power


# --------------------------------------------------------------------------
# Eq. 5: duration-weighted binning of a variable-duration power trace
# --------------------------------------------------------------------------

def ref_bin_power(power, dt, bin_idx, n_bins):
    """Weighted sums per bin:  sum(P_i * dt_i)  and  sum(dt_i)  per bin.

    The caller divides to get the Eq. 5 weighted average; returning the
    two sums keeps the result exact when bins are later merged.
    """
    energy = jnp.zeros((n_bins,), dtype=jnp.float32)
    weight = jnp.zeros((n_bins,), dtype=jnp.float32)
    idx = bin_idx.astype(jnp.int32)
    energy = energy.at[idx].add(power * dt)
    weight = weight.at[idx].add(dt)
    return energy, weight


# --------------------------------------------------------------------------
# Vessim-style battery / microgrid step (Sec. 3.2)
# --------------------------------------------------------------------------

def ref_microgrid(load_w, solar_w, ci, bp, soc0):
    """Sequential microgrid simulation over T fixed-width steps.

    Power-balance policy per step (matches rust/src/cosim/microgrid.rs):
      1. solar serves the load first;
      2. excess solar charges the battery (rate & SoC limited), the
         remainder is exported to the grid;
      3. residual load discharges the battery (rate & SoC limited), the
         remainder is imported from the grid;
      4. emissions = imported energy x carbon intensity.

    Returns (soc[T], grid_w[T], solar_used_w[T], batt_w[T], emissions_g[T]).
    grid_w > 0 is import, < 0 export; batt_w > 0 discharge, < 0 charge.
    """
    cap_wh = bp[BP_CAP_WH]
    dt_h = bp[BP_DT_S] / 3600.0

    def step(soc, inp):
        load, solar, carbon = inp
        solar_used = jnp.minimum(solar, load)
        excess = solar - solar_used
        deficit = load - solar_used

        # Charge with excess solar.
        room_wh = (bp[BP_SOC_MAX] - soc) * cap_wh
        chg_w = jnp.minimum(excess, bp[BP_MAX_CHARGE_W])
        chg_w = jnp.minimum(chg_w, room_wh / (dt_h * bp[BP_EFF_CHARGE]))
        chg_w = jnp.maximum(chg_w, 0.0)
        export_w = excess - chg_w

        # Discharge into the residual load.
        avail_wh = (soc - bp[BP_SOC_MIN]) * cap_wh
        dis_w = jnp.minimum(deficit, bp[BP_MAX_DISCHARGE_W])
        dis_w = jnp.minimum(dis_w, avail_wh * bp[BP_EFF_DISCHARGE] / dt_h)
        dis_w = jnp.maximum(dis_w, 0.0)
        import_w = deficit - dis_w

        soc_next = soc + (
            chg_w * bp[BP_EFF_CHARGE] - dis_w / bp[BP_EFF_DISCHARGE]
        ) * dt_h / cap_wh
        soc_next = jnp.clip(soc_next, 0.0, 1.0)

        grid_w = import_w - export_w
        batt_w = dis_w - chg_w
        emissions = import_w * dt_h / 1000.0 * carbon  # kWh * g/kWh
        return soc_next, (soc_next, grid_w, solar_used, batt_w, emissions)

    _, out = jax.lax.scan(step, soc0, (load_w, solar_w, ci))
    return out
