"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes/values; every kernel must match its ref_*
counterpart to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.stage_cost import stage_cost
from compile.kernels.power import power_law
from compile.kernels.binning import bin_power
from compile.kernels.battery import microgrid

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- helpers

def mk_mp(layers=32, h=4096, ffn=14336, heads=32, kvh=8, vocab=128256, tp=1, pp=1):
    return jnp.array([layers, h, ffn, heads, kvh, vocab, tp, pp], dtype=jnp.float32)


def mk_gp(
    peak=312e12, bw=2.039e12, p_idle=100.0, p_max=400.0, sat=0.45, gamma=0.7,
    flops_eff=0.46, mem_eff=0.8, t_overhead=5e-4, layer_overhead=2.5e-5,
    link_bw=250e9, link_lat=5e-6,
):
    return jnp.array(
        [peak, bw, p_idle, p_max, sat, gamma, flops_eff, mem_eff,
         t_overhead, layer_overhead, link_bw, link_lat],
        dtype=jnp.float32,
    )


def mk_bp(cap=100.0, soc_min=0.2, soc_max=0.8, chg=50.0, dis=50.0,
          eff_c=0.95, eff_d=0.95, dt=60.0):
    return jnp.array([cap, soc_min, soc_max, chg, dis, eff_c, eff_d, dt],
                     dtype=jnp.float32)


# ------------------------------------------------------------- stage cost

class TestStageCost:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(0)
        nt = jnp.array(rng.integers(0, 2048, 128), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 4096, 128), dtype=jnp.float32)
        act = jnp.array(rng.integers(0, 2, 128), dtype=jnp.float32)
        mp = mk_mp()
        got_f, got_kv = stage_cost(nt, ctx, act, mp)
        want_f, want_kv = ref.ref_stage_cost(nt, ctx, act, mp)
        np.testing.assert_allclose(got_f, want_f, rtol=1e-6)
        np.testing.assert_allclose(got_kv, want_kv, rtol=1e-6)

    def test_inactive_rows_are_zero(self):
        nt = jnp.full((128,), 64.0)
        ctx = jnp.full((128,), 512.0)
        act = jnp.zeros((128,))
        f, kv = stage_cost(nt, ctx, act, mk_mp())
        assert float(jnp.abs(f).max()) == 0.0
        assert float(jnp.abs(kv).max()) == 0.0

    def test_multi_tile(self):
        rng = np.random.default_rng(1)
        n = 512
        nt = jnp.array(rng.integers(1, 512, n), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 1024, n), dtype=jnp.float32)
        act = jnp.ones((n,))
        mp = mk_mp(layers=48, h=8192, ffn=22016, heads=64)
        got_f, got_kv = stage_cost(nt, ctx, act, mp)
        want_f, want_kv = ref.ref_stage_cost(nt, ctx, act, mp)
        np.testing.assert_allclose(got_f, want_f, rtol=1e-6)
        np.testing.assert_allclose(got_kv, want_kv, rtol=1e-6)

    def test_decode_token_flops_scale_with_context(self):
        """A decode step's attention FLOPs must grow linearly in context."""
        mp = mk_mp()
        one = jnp.ones((128,))
        f1, _ = ref.ref_stage_cost(one, 100.0 * one, one, mp)
        f2, _ = ref.ref_stage_cost(one, 200.0 * one, one, mp)
        d = float((f2 - f1)[0])
        # 4*h*delta_c per layer
        assert d == pytest.approx(32 * 4 * 4096 * 100, rel=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tiles=st.integers(1, 4),
        layers=st.integers(2, 96),
        h=st.sampled_from([1024, 2560, 4096, 8192]),
        kv_frac=st.sampled_from([1, 4, 8]),
    )
    def test_matches_ref_hypothesis(self, seed, tiles, layers, h, kv_frac):
        rng = np.random.default_rng(seed)
        n = 128 * tiles
        nt = jnp.array(rng.integers(0, 4096, n), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 8192, n), dtype=jnp.float32)
        act = jnp.array(rng.integers(0, 2, n), dtype=jnp.float32)
        heads = h // 128
        mp = mk_mp(layers=layers, h=h, ffn=4 * h, heads=heads,
                   kvh=max(1, heads // kv_frac))
        got_f, got_kv = stage_cost(nt, ctx, act, mp)
        want_f, want_kv = ref.ref_stage_cost(nt, ctx, act, mp)
        np.testing.assert_allclose(got_f, want_f, rtol=1e-5)
        np.testing.assert_allclose(got_kv, want_kv, rtol=1e-5)


# -------------------------------------------------------------- power law

class TestPowerLaw:
    def test_matches_ref(self):
        mfu = jnp.linspace(0.0, 1.0, 1280)
        got = power_law(mfu, jnp.array([100.0, 400.0, 0.45, 0.7]))
        want = ref.ref_power(mfu, 100.0, 400.0, 0.45, 0.7)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_idle_at_zero_mfu(self):
        p = power_law(jnp.zeros(128), jnp.array([100.0, 400.0, 0.45, 0.7]))
        np.testing.assert_allclose(p, 100.0)

    def test_clamps_at_saturation(self):
        """Above mfu_sat the curve must flatten at P_max (Eq. 1 clamp)."""
        pp = jnp.array([60.0, 700.0, 0.45, 0.7])
        hi = power_law(jnp.full((128,), 0.9), pp)
        at = power_law(jnp.full((128,), 0.45), pp)
        np.testing.assert_allclose(hi, 700.0, rtol=1e-6)
        np.testing.assert_allclose(at, 700.0, rtol=1e-6)

    def test_monotone_below_saturation(self):
        mfu = jnp.linspace(0.0, 0.45, 128)
        p = np.asarray(power_law(mfu, jnp.array([30.0, 300.0, 0.45, 0.7])))
        assert (np.diff(p) >= -1e-4).all()

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        gamma=st.floats(0.3, 1.0),
        sat=st.floats(0.2, 0.9),
    )
    def test_matches_ref_hypothesis(self, seed, gamma, sat):
        rng = np.random.default_rng(seed)
        mfu = jnp.array(rng.uniform(0, 1.2, 256), dtype=jnp.float32)
        pp = jnp.array([100.0, 400.0, sat, gamma], dtype=jnp.float32)
        got = power_law(mfu, pp)
        want = ref.ref_power(mfu, 100.0, 400.0, sat, gamma)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


# ---------------------------------------------------------------- binning

class TestBinning:
    def test_matches_ref(self):
        rng = np.random.default_rng(2)
        n, b = 1024, 256
        p = jnp.array(rng.uniform(100, 400, n), dtype=jnp.float32)
        dt = jnp.array(rng.uniform(0.001, 0.5, n), dtype=jnp.float32)
        idx = jnp.array(rng.integers(0, b, n), dtype=jnp.float32)
        got_e, got_w = bin_power(p, dt, idx, b)
        want_e, want_w = ref.ref_bin_power(p, dt, idx, b)
        np.testing.assert_allclose(got_e, want_e, rtol=1e-4)
        np.testing.assert_allclose(got_w, want_w, rtol=1e-4)

    def test_energy_conserved(self):
        """Total P*dt must be preserved by binning (no sample dropped)."""
        rng = np.random.default_rng(3)
        n, b = 512, 128
        p = jnp.array(rng.uniform(0, 500, n), dtype=jnp.float32)
        dt = jnp.array(rng.uniform(0.01, 1.0, n), dtype=jnp.float32)
        idx = jnp.array(rng.integers(0, b, n), dtype=jnp.float32)
        e, w = bin_power(p, dt, idx, b)
        assert float(jnp.sum(e)) == pytest.approx(float(jnp.sum(p * dt)), rel=1e-4)
        assert float(jnp.sum(w)) == pytest.approx(float(jnp.sum(dt)), rel=1e-4)

    def test_single_bin(self):
        n, b = 128, 128
        p = jnp.full((n,), 200.0)
        dt = jnp.full((n,), 0.1)
        idx = jnp.zeros((n,))
        e, w = bin_power(p, dt, idx, b)
        assert float(e[0]) == pytest.approx(200.0 * 0.1 * n, rel=1e-5)
        assert float(jnp.sum(e[1:])) == 0.0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 4))
    def test_matches_ref_hypothesis(self, seed, tiles):
        rng = np.random.default_rng(seed)
        n, b = 128 * tiles, 256
        p = jnp.array(rng.uniform(0, 700, n), dtype=jnp.float32)
        dt = jnp.array(rng.uniform(0, 2, n), dtype=jnp.float32)
        idx = jnp.array(rng.integers(0, b, n), dtype=jnp.float32)
        got_e, got_w = bin_power(p, dt, idx, b)
        want_e, want_w = ref.ref_bin_power(p, dt, idx, b)
        np.testing.assert_allclose(got_e, want_e, rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(got_w, want_w, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- battery

class TestMicrogrid:
    def _run_pair(self, seed, t=256, **bp_kw):
        rng = np.random.default_rng(seed)
        load = jnp.array(rng.uniform(0, 400, t), dtype=jnp.float32)
        solar = jnp.array(rng.uniform(0, 600, t), dtype=jnp.float32)
        ci = jnp.array(rng.uniform(50, 500, t), dtype=jnp.float32)
        bp = mk_bp(**bp_kw)
        soc0 = jnp.array([0.5], dtype=jnp.float32)
        got = microgrid(load, solar, ci, bp, soc0)
        want = ref.ref_microgrid(load, solar, ci, bp, jnp.float32(0.5))
        return got, want, (load, solar, ci, bp)

    def test_matches_ref(self):
        got, want, _ = self._run_pair(4)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-3)

    def test_soc_bounds_respected(self):
        got, _, (_, _, _, bp) = self._run_pair(5, cap=50.0)
        soc = np.asarray(got[0])
        assert (soc >= float(bp[ref.BP_SOC_MIN]) - 1e-3).all()
        assert (soc <= float(bp[ref.BP_SOC_MAX]) + 1e-3).all()

    def test_power_balance_each_step(self):
        """load = solar_used + battery_discharge + grid_import each step."""
        got, _, (load, solar, _, _) = self._run_pair(6)
        _, grid, used, batt, _ = (np.asarray(x) for x in got)
        imp = np.maximum(grid, 0.0)
        exp = np.maximum(-grid, 0.0)
        dis = np.maximum(batt, 0.0)
        chg = np.maximum(-batt, 0.0)
        np.testing.assert_allclose(np.asarray(load), used + dis + imp, rtol=1e-4, atol=1e-2)
        # and solar = used + charge + export
        np.testing.assert_allclose(np.asarray(solar), used + chg + exp, rtol=1e-4, atol=1e-2)

    def test_no_solar_all_grid(self):
        t = 128
        load = jnp.full((t,), 300.0)
        solar = jnp.zeros((t,))
        ci = jnp.full((t,), 400.0)
        # battery starts at min soc -> nothing to discharge
        bp = mk_bp(soc_min=0.5)
        got = microgrid(load, solar, ci, bp, jnp.array([0.5], dtype=jnp.float32))
        np.testing.assert_allclose(got[1], 300.0, rtol=1e-5)  # all import
        # emissions = 300W * 1min in kWh * 400 g/kWh
        np.testing.assert_allclose(got[4], 300.0 / 60 / 1000 * 400, rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cap=st.floats(10.0, 1000.0),
        eff=st.floats(0.7, 1.0),
    )
    def test_matches_ref_hypothesis(self, seed, cap, eff):
        got, want, _ = self._run_pair(seed, cap=cap, eff_c=eff, eff_d=eff)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-3, atol=5e-3)
