"""L2 stage oracle: shape contract, ref equivalence, physical sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .test_kernels import mk_mp, mk_gp

jax.config.update("jax_platform_name", "cpu")


def run_oracle(nt, ctx, act, mp=None, gp=None):
    mp = mp if mp is not None else mk_mp()
    gp = gp if gp is not None else mk_gp()
    return [float(x) for x in model.stage_oracle(nt, ctx, act, mp, gp)]


def pad(v, n=model.R_MAX):
    out = np.zeros(n, dtype=np.float32)
    out[: len(v)] = v
    return jnp.array(out)


class TestStageOracle:
    def test_matches_ref(self):
        rng = np.random.default_rng(7)
        nt = jnp.array(rng.integers(0, 512, 128), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 2048, 128), dtype=jnp.float32)
        act = jnp.array(rng.integers(0, 2, 128), dtype=jnp.float32)
        got = model.stage_oracle(nt, ctx, act, mk_mp(), mk_gp())
        want = ref.ref_stage_oracle(nt, ctx, act, mk_mp(), mk_gp())
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5)

    def test_empty_batch_is_overhead_only(self):
        z = jnp.zeros((128,))
        t, flops, mfu, power = run_oracle(z, z, z)
        gp = mk_gp()
        assert flops == 0.0 and mfu == 0.0
        assert power == pytest.approx(100.0)  # idle
        # weight read still occurs; time >= overhead
        assert t > float(gp[ref.GP_T_OVERHEAD])

    def test_decode_is_memory_bound(self):
        """Small decode batch: latency ~ weight-read time, low MFU."""
        nt = pad([1.0] * 8)
        ctx = pad([1024.0] * 8)
        act = pad([1.0] * 8)
        t, _, mfu, power = run_oracle(nt, ctx, act)
        gp = mk_gp()
        mp = mk_mp()
        _, kv_r = ref.ref_stage_cost(nt, ctx, act, mp)
        bytes_moved = float(ref.ref_weight_bytes(mp)) + float(jnp.sum(kv_r))
        mem_t = bytes_moved / (
            float(gp[ref.GP_HBM_BW]) * float(gp[ref.GP_MEM_EFF])
        )
        assert t == pytest.approx(
            mem_t + float(gp[ref.GP_T_OVERHEAD])
            + 32 * float(gp[ref.GP_LAYER_OVERHEAD]),
            rel=0.02,
        )
        assert mfu < 0.05
        assert power < 250.0

    def test_prefill_is_compute_bound_high_mfu(self):
        """A big prefill chunk saturates the MFU ceiling (~flops_eff)."""
        nt = pad([4096.0])
        ctx = pad([0.0])
        act = pad([1.0])
        _, _, mfu, power = run_oracle(nt, ctx, act)
        assert mfu > 0.35
        assert power > 350.0

    def test_tp_reduces_stage_time(self):
        nt, ctx, act = pad([2048.0]), pad([0.0]), pad([1.0])
        t1 = run_oracle(nt, ctx, act, mk_mp(tp=1))[0]
        t2 = run_oracle(nt, ctx, act, mk_mp(tp=2))[0]
        assert t2 < t1

    def test_pp_splits_flops(self):
        nt, ctx, act = pad([2048.0]), pad([0.0]), pad([1.0])
        f1 = run_oracle(nt, ctx, act, mk_mp(pp=1))[1]
        f2 = run_oracle(nt, ctx, act, mk_mp(pp=2))[1]
        assert f2 == pytest.approx(f1 / 2, rel=1e-5)

    def test_bigger_model_more_flops(self):
        nt, ctx, act = pad([256.0] * 4), pad([512.0] * 4), pad([1.0] * 4)
        small = run_oracle(nt, ctx, act, mk_mp())[1]
        big = run_oracle(
            nt, ctx, act, mk_mp(layers=80, h=8192, ffn=28672, heads=64)
        )[1]
        assert big > 5 * small

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tp=st.sampled_from([1, 2, 4]),
        pp=st.sampled_from([1, 2, 4]),
    )
    def test_ref_equivalence_hypothesis(self, seed, tp, pp):
        rng = np.random.default_rng(seed)
        nt = jnp.array(rng.integers(0, 1024, 128), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 4096, 128), dtype=jnp.float32)
        act = jnp.array(rng.integers(0, 2, 128), dtype=jnp.float32)
        mp = mk_mp(tp=tp, pp=pp)
        got = model.stage_oracle(nt, ctx, act, mp, mk_gp())
        want = ref.ref_stage_oracle(nt, ctx, act, mp, mk_gp())
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_physical_invariants(self, seed):
        """time > 0, 0 <= mfu <= 1, idle <= power <= max."""
        rng = np.random.default_rng(seed)
        nt = jnp.array(rng.integers(0, 4096, 128), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 8192, 128), dtype=jnp.float32)
        act = jnp.array(rng.integers(0, 2, 128), dtype=jnp.float32)
        t, flops, mfu, power = run_oracle(nt, ctx, act)
        assert t > 0
        assert flops >= 0
        assert 0.0 <= mfu <= 1.0
        assert 100.0 - 1e-3 <= power <= 400.0 + 1e-3
