"""AOT export contract tests: the HLO-text artifacts must exist-ably
lower, carry the advertised static shapes, and the exported functions
must equal their eager counterparts on concrete inputs."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestExport:
    def test_export_all_writes_artifacts(self, tmp_path):
        out = str(tmp_path / "artifacts")
        manifest = aot.export_all(out)
        for name in ["stage_oracle", "cosim_step", "bin_power"]:
            path = os.path.join(out, f"{name}.hlo.txt")
            assert os.path.exists(path)
            text = open(path).read()
            # HLO text format sanity: module header + ENTRY computation.
            assert text.startswith("HloModule"), text[:80]
            assert "ENTRY" in text
            assert manifest[name]["bytes"] == len(text)
        shapes = manifest["shapes"]
        assert shapes["R_MAX"] == model.R_MAX
        assert shapes["T_COSIM"] == model.T_COSIM

    def test_manifest_json_parseable(self, tmp_path):
        out = str(tmp_path / "a")
        aot.export_all(out)
        m = json.load(open(os.path.join(out, "manifest.json")))
        assert set(m["shapes"]) == {"R_MAX", "T_COSIM", "N_SAMPLES", "N_BINS"}

    def test_lowered_stage_oracle_matches_eager(self):
        rng = np.random.default_rng(0)
        nt = jnp.array(rng.integers(0, 512, model.R_MAX), dtype=jnp.float32)
        ctx = jnp.array(rng.integers(0, 2048, model.R_MAX), dtype=jnp.float32)
        act = jnp.array(rng.integers(0, 2, model.R_MAX), dtype=jnp.float32)
        mp = jnp.array([32, 4096, 14336, 32, 8, 128256, 1, 1], dtype=jnp.float32)
        gp = jnp.array(
            [312e12, 2.039e12, 100, 400, 0.45, 0.7, 0.46, 0.8, 5e-4, 2.5e-5,
             250e9, 5e-6],
            dtype=jnp.float32,
        )
        eager = model.stage_oracle(nt, ctx, act, mp, gp)
        compiled = jax.jit(model.stage_oracle)(nt, ctx, act, mp, gp)
        for e, c in zip(eager, compiled):
            np.testing.assert_allclose(e, c, rtol=1e-6)
        # And against the pure-jnp reference oracle.
        want = ref.ref_stage_oracle(nt, ctx, act, mp, gp)
        for e, w in zip(eager, want):
            np.testing.assert_allclose(e, w, rtol=1e-5)

    def test_cosim_chunk_chaining_equals_single_run(self):
        """Chaining two T-step calls via final SoC == one 2T-step scan
        (the contract the rust runtime relies on)."""
        t = 128
        rng = np.random.default_rng(1)
        load = jnp.array(rng.uniform(0, 500, 2 * t), dtype=jnp.float32)
        solar = jnp.array(rng.uniform(0, 600, 2 * t), dtype=jnp.float32)
        ci = jnp.array(rng.uniform(50, 500, 2 * t), dtype=jnp.float32)
        bp = jnp.array([100.0, 0.2, 0.8, 100.0, 100.0, 0.95, 0.95, 60.0],
                       dtype=jnp.float32)

        full = ref.ref_microgrid(load, solar, ci, bp, jnp.float32(0.5))
        a = ref.ref_microgrid(load[:t], solar[:t], ci[:t], bp, jnp.float32(0.5))
        soc_mid = a[0][-1]
        b = ref.ref_microgrid(load[t:], solar[t:], ci[t:], bp, soc_mid)
        for fa, (pa, pb) in zip(full, zip(a, b)):
            np.testing.assert_allclose(
                fa, jnp.concatenate([pa, pb]), rtol=1e-5, atol=1e-4
            )
