//! Carbon-aware autoscaling: grow and shrink the replica fleet against
//! load and grid signals, and compare the scaling policies on energy,
//! emissions, SLO attainment, and fleet size (DESIGN.md §6).
//!
//! Run:  cargo run --release --example autoscale
//! (compressed evening-window scenario by default; pass `-- --full`
//! for the whole-day sweep the experiment regenerator runs.)

use vidur_energy::experiments::exp_autoscale::{diurnal_trace, run_policy, scenario, POLICIES};

fn main() -> anyhow::Result<()> {
    let fast = !std::env::args().any(|a| a == "--full");
    let (cfg, scale, cosim, horizon_s, qps_peak) = scenario(fast);
    let trace = diurnal_trace(&cfg, cosim.start_hour, horizon_s, qps_peak, cfg.seed);
    println!(
        "{} requests over {:.1} h starting {:02.0}:00 (fleet {}..{}, cold start {:.0}s)\n",
        trace.len(),
        horizon_s / 3600.0,
        cosim.start_hour,
        scale.min_replicas,
        scale.max_replicas,
        scale.cold_start_s
    );

    println!(
        "{:<16} {:>10} {:>12} {:>9} {:>10} {:>9}",
        "policy", "energy_kWh", "net_gCO2", "slo_%", "mean_fleet", "drains"
    );
    for &policy in POLICIES {
        let r = run_policy(&cfg, &scale, &cosim, policy, horizon_s, trace.clone())?;
        let (_, drains) = r.out.timeline.scale_event_counts();
        println!(
            "{:<16} {:>10.4} {:>12.1} {:>9.2} {:>10.3} {:>9}",
            r.policy,
            r.energy_kwh,
            r.net_footprint_g,
            r.out.sim.metrics.slo_attained * 100.0,
            r.out.timeline.mean_fleet(),
            drains
        );
    }
    println!(
        "\nthe carbon-aware policy sheds replicas in dirty-grid hours (SLO-guarded),\n\
         so its net emissions undercut the static fleet at matched attainment"
    );
    Ok(())
}
