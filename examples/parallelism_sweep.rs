//! Parallelism sweep (the paper's Experiment 5 as a library scenario):
//! CodeLlama-34B across the TP×PP grid, reporting the energy/latency
//! trade-off and the most energy-efficient configuration.
//!
//! Run:  cargo run --release --example parallelism_sweep [-- --fast]

use vidur_energy::config::simconfig::{CostModelKind, SimConfig};
use vidur_energy::energy::EnergyAccountant;
use vidur_energy::runtime::ArtifactStore;
use vidur_energy::sim;
use vidur_energy::workload::{Trace, WorkloadGenerator};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut base = SimConfig::default();
    base.model = "codellama-34b".into();
    base.num_requests = if fast { 128 } else { 512 };
    if ArtifactStore::discover().is_err() {
        base.cost_model = CostModelKind::Native;
    }

    // Hold the workload fixed across configurations.
    let mut gen = WorkloadGenerator::from_config(&base);
    let trace = Trace::new(gen.generate(base.num_requests));

    println!(
        "{:<10} {:>6} {:>12} {:>12} {:>12} {:>10}",
        "tp x pp", "gpus", "makespan_s", "avg_W/gpu", "energy_kWh", "p99_s"
    );
    let mut best: Option<(String, f64)> = None;
    for (tp, pp) in [(1u32, 1u32), (1, 2), (1, 4), (2, 1), (2, 2), (4, 1), (4, 4)] {
        let mut cfg = base.clone();
        cfg.tp = tp;
        cfg.pp = pp;
        let out = sim::run_with_trace(&cfg, trace.clone())?;
        let acc = EnergyAccountant::paper_default(&cfg)?;
        let e = acc.account(&cfg, &out.stagelog, out.metrics.makespan_s);
        println!(
            "{:<10} {:>6} {:>12.1} {:>12.1} {:>12.4} {:>10.2}",
            format!("{tp}x{pp}"),
            tp * pp,
            out.metrics.makespan_s,
            e.avg_power_w,
            e.energy_kwh,
            out.metrics.e2e_p99_s,
        );
        if best.as_ref().map(|(_, b)| e.energy_kwh < *b).unwrap_or(true) {
            best = Some((format!("TP{tp}/PP{pp}"), e.energy_kwh));
        }
    }
    let (name, kwh) = best.unwrap();
    println!("\nmost energy-efficient: {name} at {kwh:.4} kWh");
    println!("(paper: TP2/PP1 and TP1/PP2 balance runtime and power best)");
    Ok(())
}
