//! Carbon-aware co-simulation: the full Vidur→Vessim pipeline with and
//! without the carbon-aware load-shifting controller — the deployment
//! question the paper's §5 poses ("renewable availability alone is
//! insufficient; real-time grid-aware adaptation matters").
//!
//! Run:  cargo run --release --example carbon_aware_cosim [-- --fast]

use vidur_energy::config::simconfig::{CosimConfig, CostModelKind, SimConfig};
use vidur_energy::cosim::{CarbonAwareController, Environment};
use vidur_energy::energy::EnergyAccountant;
use vidur_energy::grid::{CarbonIntensityTrace, SolarModel};
use vidur_energy::pipeline::{bin_stages, BinningBackend, LoadProfile};
use vidur_energy::runtime::ArtifactStore;
use vidur_energy::sim;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");

    // 1. Inference side: a llama2-7b serving day.
    let mut cfg = SimConfig::default();
    cfg.model = "llama2-7b".into();
    cfg.num_requests = if fast { 500 } else { 5_000 };
    cfg.prefill_decode_ratio = Some(20.0);
    if ArtifactStore::discover().is_err() {
        cfg.cost_model = CostModelKind::Native;
    }
    println!("simulating inference workload ({} requests)...", cfg.num_requests);
    let out = sim::run(&cfg)?;
    let acc = EnergyAccountant::paper_default(&cfg)?;
    let e = acc.account(&cfg, &out.stagelog, out.metrics.makespan_s);
    println!(
        "  makespan {:.0} s, avg power {:.0} W, energy {:.3} kWh",
        out.metrics.makespan_s, e.avg_power_w, e.energy_kwh
    );

    // 2. Eq. 5 pipeline into 1-minute bins.
    let cosim = CosimConfig::default();
    let binned = bin_stages(
        &cfg,
        &out.stagelog,
        out.metrics.makespan_s,
        cosim.interval_s,
        BinningBackend::Native,
    )?;
    let profile = LoadProfile::from_binned(&binned);

    // 3. Environment signals starting at 06:00.
    let n = profile.len();
    let start = cosim.start_hour * 3600.0;
    let solar_w = SolarModel::default().trace(start, n).sample_grid(start, n, 60.0);
    let ci = CarbonIntensityTrace::default().trace(start, n).sample_grid(start, n, 60.0);

    // 4. Co-simulate twice.
    let mut base_env = Environment::new(cosim.clone());
    let base = base_env.run_native(&profile.power_w, &solar_w, &ci)?;
    let mut aware_env = Environment::new(cosim.clone())
        .with_controller(CarbonAwareController::new(cosim.ci_low, cosim.ci_high, 0.5));
    let aware = aware_env.run_native(&profile.power_w, &solar_w, &ci)?;

    println!("\n{:<28} {:>12} {:>12}", "metric", "baseline", "carbon-aware");
    let row = |m: &str, b: f64, a: f64| println!("{m:<28} {b:>12.2} {a:>12.2}");
    row("total energy (kWh)", base.total_energy_kwh, aware.total_energy_kwh);
    row("renewable share (%)", base.renewable_share * 100.0, aware.renewable_share * 100.0);
    row("net footprint (gCO2)", base.net_footprint_g, aware.net_footprint_g);
    row("carbon offset (%)", base.carbon_offset_frac * 100.0, aware.carbon_offset_frac * 100.0);
    row("battery cycles", base.battery_full_cycles, aware.battery_full_cycles);
    row("avg SoC (%)", base.avg_soc * 100.0, aware.avg_soc * 100.0);
    println!(
        "\ncarbon-aware shifting cut net emissions by {:.1}%",
        (1.0 - aware.net_footprint_g / base.net_footprint_g) * 100.0
    );
    Ok(())
}
