//! Multi-region carbon-aware routing (§5 "future directions",
//! implemented): serve one inference load profile against a fleet of
//! regions with phase-shifted grid conditions and compare static
//! placement with greedy lowest-CI routing under a transfer penalty.
//!
//! Run:  cargo run --release --example multi_region [-- --fast]

use vidur_energy::config::simconfig::{CosimConfig, CostModelKind, SimConfig};
use vidur_energy::coordinator::multiregion::{default_regions, simulate};
use vidur_energy::pipeline::{bin_stages, BinningBackend, LoadProfile};
use vidur_energy::runtime::ArtifactStore;
use vidur_energy::sim;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut cfg = SimConfig::default();
    cfg.num_requests = if fast { 300 } else { 2_000 };
    if ArtifactStore::discover().is_err() {
        cfg.cost_model = CostModelKind::Native;
    }
    println!("simulating home-region workload ({} requests)...", cfg.num_requests);
    let out = sim::run(&cfg)?;
    let cosim = CosimConfig::default();
    let binned = bin_stages(
        &cfg,
        &out.stagelog,
        out.metrics.makespan_s,
        cosim.interval_s,
        BinningBackend::Native,
    )?;
    let load = LoadProfile::from_binned(&binned);

    let regions = default_regions();
    println!("\nfleet:");
    for r in &regions {
        println!(
            "  {:<14} mean CI {:>5.0} g/kWh, tz {:+.0} h, solar {:>4.0} W",
            r.name, r.ci_mean, r.tz_offset_h, r.solar_w
        );
    }
    let res = simulate(&load, &regions, cosim.interval_s, cfg.seed)?;
    println!("\n{}", res.table.to_markdown());
    println!("\n{}", res.summary.to_markdown());
    println!(
        "greedy lowest-CI routing: {:.0} g vs static {:.0} g ({:+.1}%)",
        res.greedy_g,
        res.static_g,
        (res.greedy_g / res.static_g - 1.0) * 100.0
    );
    Ok(())
}
