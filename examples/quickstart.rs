//! Quickstart: simulate the paper's default configuration (Table 1a —
//! Meta-Llama-3-8B on one A100, vLLM scheduler, Zipf lengths, QPS 6.45)
//! and report latency, MFU, power, energy, and carbon.
//!
//! Run:  cargo run --release --example quickstart
//! (requires `make artifacts` once; falls back to the native oracle
//! if artifacts are missing.)

use vidur_energy::config::simconfig::{CostModelKind, SimConfig};
use vidur_energy::energy::EnergyAccountant;
use vidur_energy::runtime::ArtifactStore;
use vidur_energy::sim;

fn main() -> anyhow::Result<()> {
    let mut cfg = SimConfig::default();
    cfg.num_requests = 512;
    if ArtifactStore::discover().is_err() {
        eprintln!("artifacts/ not found — using the native cost oracle");
        cfg.cost_model = CostModelKind::Native;
    }

    println!("simulating {} requests of {} on {} ...", cfg.num_requests, cfg.model, cfg.gpu);
    let out = sim::run(&cfg)?;
    let m = &out.metrics;
    println!("\n-- latency/throughput --");
    println!("makespan            {:>10.1} s", m.makespan_s);
    println!("achieved QPS        {:>10.2}", m.achieved_qps);
    println!("token throughput    {:>10.0} tok/s", m.token_throughput);
    println!("TTFT p50/p99        {:>7.3} / {:.3} s", m.ttft_p50_s, m.ttft_p99_s);
    println!("E2E  p50/p99        {:>7.3} / {:.3} s", m.e2e_p50_s, m.e2e_p99_s);
    println!("mean batch size     {:>10.1}", m.mean_batch_size);
    println!("weighted MFU        {:>10.3}", m.weighted_mfu);

    let acc = EnergyAccountant::paper_default(&cfg)?;
    let e = acc.account(&cfg, &out.stagelog, m.makespan_s);
    println!("\n-- energy/carbon (Eq. 1-4) --");
    println!("avg GPU power       {:>10.1} W", e.avg_power_w);
    println!("peak GPU power      {:>10.1} W", e.peak_power_w);
    println!("energy (PUE {:.1})   {:>10.4} kWh", cfg.pue, e.energy_kwh);
    println!("operational carbon  {:>10.1} g  (CI {:.1} g/kWh)", e.operational_g, 418.2);
    println!("embodied carbon     {:>10.1} g", e.embodied_g);
    println!("busy fraction       {:>10.2}", e.busy_fraction);
    Ok(())
}
