//! Bench: regenerate Fig. 1 (MFU vs QPS saturation) and time the
//! underlying per-point simulation.

use vidur_energy::experiments::fig1;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig1_qps_saturation");
    let dir = std::env::temp_dir().join("vidur_bench_fig1");
    b.once(
        "fig1 full sweep (fast grid)",
        || fig1::run(&dir, true).unwrap(),
        |t| {
            let mfu = t.f64_col("weighted_mfu").unwrap();
            format!(
                "mfu[0]={:.3} mfu[max]={:.3} (paper: plateau ≈0.45)",
                mfu[0],
                mfu.last().unwrap()
            )
        },
    );
    b.run();
}
