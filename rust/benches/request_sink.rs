//! Bench: streaming request telemetry (DESIGN.md §8) — the lazy
//! arrival + sketch-sink path vs the materialized request vector, on
//! one workload: wall clock, and the memory story (peak live requests
//! + sketch tuples vs one `Request` per submitted request). Emits
//! `BENCH_reqsink.json` (path overridable via `REPRO_BENCH_OUT`) so CI
//! accumulates a perf trajectory across PRs.

use std::time::Instant;
use vidur_energy::config::simconfig::{Arrival, CostModelKind, LengthDist, SimConfig};
use vidur_energy::exec::build_cost_model;
use vidur_energy::sim;
use vidur_energy::telemetry::{StreamingRequestSink, StreamingSink};
use vidur_energy::util::bench::fmt_time;
use vidur_energy::util::json::Value;
use vidur_energy::workload::WorkloadGenerator;

fn cfg(n: u64) -> SimConfig {
    let mut c = SimConfig::default();
    c.cost_model = CostModelKind::Native;
    c.num_requests = n;
    c.arrival = Arrival::Poisson { qps: 6.45 };
    c.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 64,
        max: 512,
    };
    c.seed = 0xBE5E;
    c
}

fn main() {
    let fast = std::env::var("REPRO_BENCH_FAST").is_ok();
    let n: u64 = if fast { 20_000 } else { 200_000 };
    let c = cfg(n);
    eprintln!("request sink bench: {n} requests (fast={fast})");

    // Materialized: request vector + stage log resident.
    let t0 = Instant::now();
    let mat = sim::run(&c).unwrap();
    let mat_s = t0.elapsed().as_secs_f64();
    eprintln!("  materialized: {}", fmt_time(mat_s));

    // Streaming: lazy arrivals, request sketches, stage bins.
    let t0 = Instant::now();
    let mut source = WorkloadGenerator::from_config(&c).take(n);
    let mut stage_sink = StreamingSink::new(&c, 60.0).unwrap();
    let mut req_sink = StreamingRequestSink::new(&c);
    let cost = build_cost_model(&c).unwrap();
    let run = sim::run_with_sinks(&c, &mut source, cost, &mut stage_sink, &mut req_sink)
        .unwrap();
    let stream_s = t0.elapsed().as_secs_f64();
    eprintln!("  streaming:    {}", fmt_time(stream_s));

    // Determinism smoke: the two paths ran the same simulation.
    assert_eq!(mat.metrics.makespan_s, run.metrics.makespan_s);
    assert_eq!(mat.metrics.stage_count, run.metrics.stage_count);
    assert_eq!(run.request_stats.finished, n);

    // The p99 the sketch reports vs the exact p99, as a drift metric.
    let p99_exact = mat.metrics.e2e_p99_s;
    let p99_sketch = run.metrics.e2e_p99_s;
    let drift = (p99_sketch - p99_exact).abs() / p99_exact.max(1e-9);

    let resident_stream = run.peak_live_requests + req_sink.resident_tuples();
    println!("\n## bench: request_sink\n");
    println!("| case | wall | resident request state | metric |");
    println!("|---|---|---|---|");
    println!(
        "| materialized | {} | {n} requests | e2e p99 {p99_exact:.3}s |",
        fmt_time(mat_s)
    );
    println!(
        "| streaming | {} | {} live + {} sketch tuples | e2e p99 {p99_sketch:.3}s ({:+.3}% drift) |",
        fmt_time(stream_s),
        run.peak_live_requests,
        req_sink.resident_tuples(),
        drift * 100.0
    );

    let mut v = Value::obj();
    v.set("bench", "request_sink")
        .set("fast", fast)
        .set("requests", n)
        .set("materialized_s", mat_s)
        .set("streaming_s", stream_s)
        .set("peak_live_requests", run.peak_live_requests as u64)
        .set("sketch_tuples", req_sink.resident_tuples() as u64)
        .set("resident_stream_total", resident_stream as u64)
        .set("peak_resident_bins", stage_sink.peak_resident_bins() as u64)
        .set("e2e_p99_exact_s", p99_exact)
        .set("e2e_p99_sketch_s", p99_sketch)
        .set("e2e_p99_rel_drift", drift);
    let out = std::env::var("REPRO_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_reqsink.json".to_string());
    std::fs::write(&out, v.pretty()).unwrap();
    eprintln!("wrote {out}");
}
