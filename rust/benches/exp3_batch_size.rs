//! Bench: regenerate Experiment 3 / Fig. 4 (batch-size cap vs actual
//! batch, power, energy).

use vidur_energy::experiments::exp3;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("exp3_batch_size");
    let dir = std::env::temp_dir().join("vidur_bench_exp3");
    b.once(
        "exp3 sweep (fast caps)",
        || exp3::run(&dir, true).unwrap(),
        |t| {
            let e = t.f64_col("energy_kwh").unwrap();
            format!(
                "energy cap=1 {:.4} -> cap=128 {:.4} kWh (paper: falls, diminishing past 16)",
                e[0],
                e.last().unwrap()
            )
        },
    );
    b.run();
}
