//! Bench: regenerate Experiment 4 / Fig. 5 (QPS vs power & energy).

use vidur_energy::experiments::exp4;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("exp4_qps");
    let dir = std::env::temp_dir().join("vidur_bench_exp4");
    b.once(
        "exp4 sweep (fast grid)",
        || exp4::run(&dir, true).unwrap(),
        |t| {
            let p = t.f64_col("avg_power_w").unwrap();
            let e = t.f64_col("energy_kwh").unwrap();
            format!(
                "power {:.0}->{:.0} W, energy {:.3}->{:.3} kWh (paper: saturate ~360 W, converge ~0.5 kWh)",
                p[0], p.last().unwrap(), e[0], e.last().unwrap()
            )
        },
    );
    b.run();
}
