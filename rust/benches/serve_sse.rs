//! Bench: the serve plane's broadcast hot path (DESIGN.md §11) — hub
//! publish/drain throughput under 0/1/4/8 concurrent SSE subscribers,
//! plus snapshot→SSE frame serialization. Emits `BENCH_serve.json`
//! (path overridable via `REPRO_BENCH_OUT`) so CI accumulates a perf
//! trajectory across PRs.
//!
//! The numbers bound how much a live dashboard can cost a sweep: every
//! watched case emission goes through `SnapshotHub::publish` once the
//! server is up, so publish must stay far below the cost of the batch
//! stages whose telemetry it carries.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use vidur_energy::serve::sse::{sse_frame, Next, SnapshotHub, DEFAULT_HUB_CAPACITY};
use vidur_energy::telemetry::window::Snapshot;
use vidur_energy::util::bench::fmt_time;
use vidur_energy::util::json::Value;

fn snap(seq: u64) -> Snapshot {
    Snapshot {
        experiment: "bench".into(),
        shard: None,
        case_index: seq % 9,
        seq,
        t_s: seq as f64 * 0.05,
        done: false,
        cases_done: 0,
        cases_owned: 9,
        cases_total: 9,
        finished: seq,
        stages: seq * 3,
        qps: 12.0,
        ttft_p50_s: 0.08,
        ttft_p99_s: 0.31,
        e2e_p50_s: 1.4,
        e2e_p99_s: 4.2,
        norm_latency_p50_s_per_tok: 0.011,
        power_w: 412.0,
        mfu: 0.47,
        energy_kwh: seq as f64 * 1e-6,
        gco2_g: seq as f64 * 4e-4,
    }
}

/// Publish `n` snapshots through a hub with `subs` draining
/// subscribers; returns (publisher wall seconds, events delivered
/// across all subscribers).
fn run_scenario(n: u64, subs: usize) -> (f64, u64) {
    let hub = Arc::new(SnapshotHub::new(DEFAULT_HUB_CAPACITY));
    let delivered = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for _ in 0..subs {
        let (hub, delivered) = (hub.clone(), delivered.clone());
        handles.push(std::thread::spawn(move || {
            let mut cursor = hub.cursor_oldest();
            let mut last_seq = 0u64;
            loop {
                match hub.next(cursor, Duration::from_millis(50)) {
                    Next::Event(arrival, s) => {
                        cursor = arrival + 1;
                        last_seq = s.seq;
                        delivered.fetch_add(1, Ordering::Relaxed);
                    }
                    Next::Lagged(resume_at) => cursor = resume_at,
                    Next::Timeout => {}
                    Next::Closed => return last_seq,
                }
            }
        }));
    }
    let t0 = Instant::now();
    for seq in 1..=n {
        hub.publish(snap(seq));
    }
    let wall = t0.elapsed().as_secs_f64();
    hub.close();
    for h in handles {
        // Every subscriber drains to the final snapshot before Closed:
        // close() only flips a flag, retained events still deliver.
        assert_eq!(h.join().unwrap(), n, "subscriber fell short of seq {n}");
    }
    (wall, delivered.load(Ordering::Relaxed))
}

fn main() {
    let fast = std::env::var("REPRO_BENCH_FAST").is_ok();
    let n: u64 = if fast { 5_000 } else { 50_000 };
    eprintln!("serve sse bench: {n} snapshots (fast={fast})");

    let mut v = Value::obj();
    v.set("bench", "serve_sse").set("fast", fast).set("snapshots", n);

    println!("\n## bench: serve_sse\n");
    println!("| subscribers | publish wall | ns/publish | events delivered |");
    println!("|---|---|---|---|");
    let mut scenarios = Value::obj();
    for subs in [0usize, 1, 4, 8] {
        let (wall, delivered) = run_scenario(n, subs);
        let ns = wall * 1e9 / n as f64;
        println!(
            "| {subs} | {} | {ns:.0} | {delivered} |",
            fmt_time(wall)
        );
        let mut s = Value::obj();
        s.set("publish_s", wall).set("ns_per_publish", ns).set(
            "events_delivered",
            delivered,
        );
        scenarios.set(&format!("subs_{subs}"), s);
    }
    v.set("scenarios", scenarios);

    // Frame serialization: snapshot -> JSON -> SSE frame, the per-event
    // cost each subscriber connection pays.
    let t0 = Instant::now();
    let mut bytes = 0usize;
    for seq in 1..=n {
        let s = snap(seq);
        let frame = sse_frame(Some("snapshot"), Some(s.seq), &s.to_json().to_string());
        bytes += frame.len();
    }
    let ser_wall = t0.elapsed().as_secs_f64();
    let ser_ns = ser_wall * 1e9 / n as f64;
    println!(
        "| serialize-only | {} | {ser_ns:.0} | {bytes} bytes |",
        fmt_time(ser_wall)
    );
    v.set("serialize_s", ser_wall)
        .set("serialize_ns_per_frame", ser_ns)
        .set("frame_bytes_total", bytes as u64);

    let out =
        std::env::var("REPRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&out, v.pretty()).unwrap();
    eprintln!("wrote {out}");
}
