//! Hot-path microbenchmarks — the §Perf instrumentation: stage-oracle
//! latency (native vs HLO vs precomputed surface), Eq. 5 binning
//! backends, the event engine on both schedulers (calendar queue vs
//! reference heap), and workload generation. Emits
//! `BENCH_hotpath.json` (path overridable via
//! `REPRO_BENCH_HOTPATH_OUT`) so CI can compare against the committed
//! baseline and flag >2× regressions on the tracked cases.

use vidur_energy::config::simconfig::{Arrival, CostModelKind, ExecParams, LengthDist, SimConfig};
use vidur_energy::config::{gpus, models};
use vidur_energy::exec::batch::BatchDesc;
use vidur_energy::exec::hlo::HloCost;
use vidur_energy::exec::native::NativeCost;
use vidur_energy::exec::surface::{SurfaceCost, SurfaceInner};
use vidur_energy::exec::{build_cost_model, StageCostModel};
use vidur_energy::pipeline::{bin_stages, BinningBackend};
use vidur_energy::sim;
use vidur_energy::sim::{run_with_sinks, run_with_sinks_heap};
use vidur_energy::telemetry::{RequestLog, StageLog};
use vidur_energy::util::bench::{black_box, Bench};
use vidur_energy::util::json::Value;
use vidur_energy::util::rng::Rng;
use vidur_energy::workload::{Trace, WorkloadGenerator};

fn decode_batch(n: usize, ctx: u32) -> BatchDesc {
    let mut b = BatchDesc::new(
        models::model("llama3-8b").unwrap(),
        gpus::gpu("a100-80g").unwrap(),
        1,
        1,
        ExecParams::default(),
    );
    for i in 0..n {
        b.push(1, ctx + i as u32);
    }
    b
}

fn main() {
    let mut bench = Bench::new("hotpath");
    let artifacts = vidur_energy::runtime::ArtifactStore::discover().is_ok();

    // --- L3: native stage oracle ---
    let batch = decode_batch(64, 1024);
    bench.case("native stage_cost (64-req decode)", || {
        black_box(NativeCost::compute(&batch))
    });

    // --- Precomputed surface oracle (warm table) ---
    let mut surface = SurfaceCost::with_inner(SurfaceInner::Native);
    surface.stage_cost(&batch); // build the table outside the timed loop
    bench.case("surface stage_cost (64-req decode)", || {
        black_box(surface.stage_cost(&batch))
    });

    if artifacts {
        // --- L1/L2 through PJRT: uncached vs memo-cached ---
        let mut hlo_exact = HloCost::new().unwrap().exact();
        let mut rng = Rng::new(1);
        bench.case("hlo stage oracle, cache-miss path", || {
            // Vary the context so every call misses the cache.
            let b = decode_batch(64, 1024 + (rng.next_u64() % 8192) as u32);
            black_box(hlo_exact.stage_cost(&b))
        });
        let mut hlo_quant = HloCost::new().unwrap();
        let warm = decode_batch(64, 1024);
        hlo_quant.stage_cost(&warm);
        bench.case_with_metric(
            "hlo stage oracle, memo-cached",
            || black_box(hlo_quant.stage_cost(&warm)),
            |_| String::new(),
        );
    }

    // --- Event engine throughput (native oracle) ---
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.num_requests = 2_000;
    cfg.arrival = Arrival::Poisson { qps: 50.0 };
    cfg.lengths = LengthDist::Zipf { theta: 0.6, min: 64, max: 512 };
    bench.case_with_metric(
        "event engine, 2k requests (native)",
        || sim::run(&cfg).unwrap().stagelog.len(),
        |n| format!("{n} stages"),
    );
    let mut cfg_surface = cfg.clone();
    cfg_surface.cost_model = CostModelKind::Surface;
    bench.case_with_metric(
        "event engine, 2k requests (surface)",
        || sim::run(&cfg_surface).unwrap().stagelog.len(),
        |n| format!("{n} stages"),
    );
    if artifacts {
        let mut cfg_hlo = cfg.clone();
        cfg_hlo.cost_model = CostModelKind::Hlo;
        bench.case_with_metric(
            "event engine, 2k requests (hlo+cache)",
            || sim::run(&cfg_hlo).unwrap().stagelog.len(),
            |n| format!("{n} stages"),
        );
    }

    // --- Scheduler differential: calendar queue vs reference heap on
    // the identical trace (both paths include sink overhead, so the
    // delta isolates the event scheduler itself) ---
    let trace = {
        let mut gen = WorkloadGenerator::from_config(&cfg);
        Trace::new(gen.generate(cfg.num_requests))
    };
    bench.case_with_metric(
        "engine scheduler: calendar queue",
        || {
            let mut stages = StageLog::new();
            let mut reqs = RequestLog::new(&cfg);
            let mut src = trace.clone().into_source();
            let run = run_with_sinks(
                &cfg,
                &mut src,
                build_cost_model(&cfg).unwrap(),
                &mut stages,
                &mut reqs,
            )
            .unwrap();
            run.metrics.stage_count
        },
        |n| format!("{n} stages"),
    );
    bench.case_with_metric(
        "engine scheduler: binary heap",
        || {
            let mut stages = StageLog::new();
            let mut reqs = RequestLog::new(&cfg);
            let mut src = trace.clone().into_source();
            let run = run_with_sinks_heap(
                &cfg,
                &mut src,
                build_cost_model(&cfg).unwrap(),
                &mut stages,
                &mut reqs,
            )
            .unwrap();
            run.metrics.stage_count
        },
        |n| format!("{n} stages"),
    );

    // --- Eq. 5 binning backends over a real stage log ---
    let out = sim::run(&cfg).unwrap();
    let makespan = out.metrics.makespan_s;
    bench.case_with_metric(
        "binning native",
        || {
            bin_stages(&cfg, &out.stagelog, makespan, 60.0, BinningBackend::Native)
                .unwrap()
                .len()
        },
        |n| format!("{n} bins"),
    );
    if artifacts {
        bench.case_with_metric(
            "binning hlo kernel",
            || {
                bin_stages(&cfg, &out.stagelog, makespan, 60.0, BinningBackend::Hlo)
                    .unwrap()
                    .len()
            },
            |n| format!("{n} bins"),
        );
    }

    // --- Workload generation ---
    bench.case("workload gen, 10k zipf requests", || {
        let mut g = WorkloadGenerator::from_config(&SimConfig::default());
        black_box(g.generate(10_000).len())
    });

    let results = bench.run();

    // Persist the trajectory point for CI's regression gate.
    let mut cases = Vec::new();
    for r in &results {
        let mut c = Value::obj();
        c.set("name", r.name.as_str())
            .set("iters", r.iters)
            .set("mean_s", r.mean_s)
            .set("p50_s", r.p50_s)
            .set("p99_s", r.p99_s)
            .set("std_s", r.std_s)
            .set("metric", r.metric.as_str());
        cases.push(c);
    }
    let mut v = Value::obj();
    v.set("bench", "hotpath")
        .set("fast", std::env::var("REPRO_BENCH_FAST").is_ok())
        .set("artifacts", artifacts)
        .set("cases", Value::Arr(cases));
    let out = std::env::var("REPRO_BENCH_HOTPATH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&out, v.pretty()).unwrap();
    eprintln!("wrote {out}");
}
