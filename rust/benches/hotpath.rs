//! Hot-path microbenchmarks — the §Perf instrumentation: stage-oracle
//! latency (native vs HLO uncached vs HLO memo-cached), Eq. 5 binning
//! backends, the event engine's stage throughput, and workload
//! generation.

use vidur_energy::config::simconfig::{Arrival, CostModelKind, ExecParams, LengthDist, SimConfig};
use vidur_energy::config::{gpus, models};
use vidur_energy::exec::batch::BatchDesc;
use vidur_energy::exec::hlo::HloCost;
use vidur_energy::exec::native::NativeCost;
use vidur_energy::exec::StageCostModel;
use vidur_energy::pipeline::{bin_stages, BinningBackend};
use vidur_energy::sim;
use vidur_energy::util::bench::{black_box, Bench};
use vidur_energy::util::rng::Rng;
use vidur_energy::workload::WorkloadGenerator;

fn decode_batch(n: usize, ctx: u32) -> BatchDesc {
    let mut b = BatchDesc::new(
        models::model("llama3-8b").unwrap(),
        gpus::gpu("a100-80g").unwrap(),
        1,
        1,
        ExecParams::default(),
    );
    for i in 0..n {
        b.push(1, ctx + i as u32);
    }
    b
}

fn main() {
    let mut bench = Bench::new("hotpath");
    let artifacts = vidur_energy::runtime::ArtifactStore::discover().is_ok();

    // --- L3: native stage oracle ---
    let batch = decode_batch(64, 1024);
    bench.case("native stage_cost (64-req decode)", || {
        black_box(NativeCost::compute(&batch))
    });

    if artifacts {
        // --- L1/L2 through PJRT: uncached vs memo-cached ---
        let mut hlo_exact = HloCost::new().unwrap().exact();
        let mut rng = Rng::new(1);
        bench.case("hlo stage oracle, cache-miss path", || {
            // Vary the context so every call misses the cache.
            let b = decode_batch(64, 1024 + (rng.next_u64() % 8192) as u32);
            black_box(hlo_exact.stage_cost(&b))
        });
        let mut hlo_quant = HloCost::new().unwrap();
        let warm = decode_batch(64, 1024);
        hlo_quant.stage_cost(&warm);
        bench.case_with_metric(
            "hlo stage oracle, memo-cached",
            || black_box(hlo_quant.stage_cost(&warm)),
            |_| String::new(),
        );
    }

    // --- Event engine throughput (native oracle) ---
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.num_requests = 2_000;
    cfg.arrival = Arrival::Poisson { qps: 50.0 };
    cfg.lengths = LengthDist::Zipf { theta: 0.6, min: 64, max: 512 };
    bench.case_with_metric(
        "event engine, 2k requests (native)",
        || sim::run(&cfg).unwrap().stagelog.len(),
        |n| format!("{n} stages"),
    );
    if artifacts {
        let mut cfg_hlo = cfg.clone();
        cfg_hlo.cost_model = CostModelKind::Hlo;
        bench.case_with_metric(
            "event engine, 2k requests (hlo+cache)",
            || sim::run(&cfg_hlo).unwrap().stagelog.len(),
            |n| format!("{n} stages"),
        );
    }

    // --- Eq. 5 binning backends over a real stage log ---
    let out = sim::run(&cfg).unwrap();
    let makespan = out.metrics.makespan_s;
    bench.case_with_metric(
        "binning native",
        || {
            bin_stages(&cfg, &out.stagelog, makespan, 60.0, BinningBackend::Native)
                .unwrap()
                .len()
        },
        |n| format!("{n} bins"),
    );
    if artifacts {
        bench.case_with_metric(
            "binning hlo kernel",
            || {
                bin_stages(&cfg, &out.stagelog, makespan, 60.0, BinningBackend::Hlo)
                    .unwrap()
                    .len()
            },
            |n| format!("{n} bins"),
        );
    }

    // --- Workload generation ---
    bench.case("workload gen, 10k zipf requests", || {
        let mut g = WorkloadGenerator::from_config(&SimConfig::default());
        black_box(g.generate(10_000).len())
    });

    bench.run();
}
