//! Bench: regenerate Experiment 5 (TP×PP grid for CodeLlama-34B).

use vidur_energy::experiments::exp5;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("exp5_parallelism");
    let dir = std::env::temp_dir().join("vidur_bench_exp5");
    b.once(
        "exp5 TPxPP grid (fast subset)",
        || exp5::run(&dir, true).unwrap(),
        |t| {
            let e = t.f64_col("energy_kwh").unwrap();
            let idx = e
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            format!(
                "best config row {}: tp={} pp={} ({:.4} kWh) (paper: TP2/PP1 & TP1/PP2 best)",
                idx, t.rows[idx][0], t.rows[idx][1], e[idx]
            )
        },
    );
    b.run();
}
