//! Bench: regenerate Experiment 1 / Fig. 2 (request volume vs power &
//! energy across model sizes).

use vidur_energy::experiments::exp1;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("exp1_request_scaling");
    let dir = std::env::temp_dir().join("vidur_bench_exp1");
    b.once(
        "exp1 sweep (fast: 6 models x 2^8..2^11)",
        || exp1::run(&dir, true).unwrap(),
        |t| {
            let p = t.f64_col("avg_power_w").unwrap();
            let e = t.f64_col("energy_kwh").unwrap();
            format!(
                "power range {:.0}-{:.0} W, max energy {:.3} kWh (paper: stable power, linear energy)",
                p.iter().cloned().fold(f64::INFINITY, f64::min),
                p.iter().cloned().fold(0.0, f64::max),
                e.iter().cloned().fold(0.0, f64::max)
            )
        },
    );
    b.run();
}
