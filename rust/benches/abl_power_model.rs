//! Bench: the power-model ablation (γ / mfu_sat sensitivity, Eq. 3 vs
//! physical accounting, NVML-proxy and static-TDP baselines).

use vidur_energy::experiments::ablation;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("abl_power_model");
    let dir = std::env::temp_dir().join("vidur_bench_abl");
    b.once(
        "ablation table (fast)",
        || ablation::run(&dir, true).unwrap(),
        |t| {
            let nvml = t
                .rows
                .iter()
                .find(|r| r[0].contains("nvml"))
                .map(|r| r[3].clone())
                .unwrap_or_default();
            format!("nvml-proxy energy delta {nvml}% vs MFU law (paper §2: proxies overestimate)")
        },
    );
    b.run();
}
