//! Bench: the parallel sweep executor on the Exp. 1 grid — serial vs
//! 4-worker wall clock (acceptance: ≥2× at 4 workers on a 4-core
//! machine), the surface-oracle single-core speedup on the same grid
//! (the perf_opt contract, DESIGN.md §12), plus the telemetry memory
//! story (peak resident stage records, materialized vs streaming).
//! Emits `BENCH_sweep.json` (path overridable via `REPRO_BENCH_OUT`)
//! so CI accumulates a perf trajectory across PRs.

use std::time::Instant;
use vidur_energy::config::simconfig::{CostModelKind, SimConfig};
use vidur_energy::experiments::common::{run_cases_on, CaseResult};
use vidur_energy::experiments::exp1::MODELS;
use vidur_energy::runtime::ArtifactStore;
use vidur_energy::sim;
use vidur_energy::sweep::SweepExecutor;
use vidur_energy::util::bench::fmt_time;
use vidur_energy::util::json::Value;
use vidur_energy::util::rng::case_seed;

/// The Exp. 1 grid at bench scale (falls back to the native oracle
/// when the compiled artifacts are absent).
fn grid(fast: bool) -> Vec<SimConfig> {
    let exps: &[u32] = if fast { &[7, 8] } else { &[8, 9, 10] };
    let native = ArtifactStore::discover().is_err();
    let mut cfgs = Vec::new();
    for &(model, tp, pp) in MODELS {
        for &e in exps {
            let mut cfg = SimConfig::default();
            cfg.model = model.into();
            cfg.tp = tp;
            cfg.pp = pp;
            cfg.num_requests = 1u64 << e;
            if native {
                cfg.cost_model = CostModelKind::Native;
            }
            cfg.seed = case_seed(0xBE, cfgs.len() as u64);
            cfgs.push(cfg);
        }
    }
    cfgs
}

fn total_energy(results: &[CaseResult]) -> f64 {
    results.iter().map(|r| r.energy_kwh()).sum()
}

fn main() {
    let fast = std::env::var("REPRO_BENCH_FAST").is_ok();
    let cfgs = grid(fast);
    let n = cfgs.len();
    eprintln!("sweep bench: {n} cases (exp1 grid, fast={fast})");

    let t0 = Instant::now();
    let serial = run_cases_on(&SweepExecutor::new(1), cfgs.clone()).unwrap();
    let serial_s = t0.elapsed().as_secs_f64();
    eprintln!("  serial  ({n} cases): {}", fmt_time(serial_s));

    // Same grid, single core, surface oracle: the hot path answers
    // stage costs from the precomputed surface instead of re-deriving
    // them per stage. Energy is recorded as a relative delta (the
    // surface is an approximation of its inner oracle, not bit-equal).
    let surface_cfgs: Vec<SimConfig> = cfgs
        .iter()
        .map(|c| {
            let mut c = c.clone();
            c.cost_model = CostModelKind::Surface;
            c
        })
        .collect();
    let t0 = Instant::now();
    let surface = run_cases_on(&SweepExecutor::new(1), surface_cfgs).unwrap();
    let serial_surface_s = t0.elapsed().as_secs_f64();
    eprintln!("  surface ({n} cases): {}", fmt_time(serial_surface_s));

    const JOBS: usize = 4;
    let t0 = Instant::now();
    let parallel = run_cases_on(&SweepExecutor::new(JOBS), cfgs).unwrap();
    let parallel_s = t0.elapsed().as_secs_f64();
    eprintln!("  {JOBS} workers ({n} cases): {}", fmt_time(parallel_s));

    // Determinism smoke: the two sweeps are the same experiment.
    assert_eq!(total_energy(&serial), total_energy(&parallel));

    // Memory story: re-run the largest case materialized and compare
    // its resident stage-record count against the streaming sink's
    // resident bins.
    let biggest = serial
        .iter()
        .max_by_key(|r| r.out.metrics.stage_count)
        .unwrap();
    let materialized = sim::run(&biggest.out.config).unwrap();
    let peak_records = materialized.stagelog.len() as u64;
    let peak_bins = serial
        .iter()
        .map(|r| r.peak_resident_bins)
        .max()
        .unwrap() as u64;

    let speedup = serial_s / parallel_s.max(1e-9);
    let speedup_surface = serial_s / serial_surface_s.max(1e-9);
    let surface_energy_rel =
        (total_energy(&surface) - total_energy(&serial)).abs() / total_energy(&serial).max(1e-12);
    println!("\n## bench: sweep_executor\n");
    println!("| case | wall | cases/s | metric |");
    println!("|---|---|---|---|");
    println!(
        "| serial | {} | {:.2} | {} cases |",
        fmt_time(serial_s),
        n as f64 / serial_s,
        n
    );
    println!(
        "| surface oracle | {} | {:.2} | speedup {speedup_surface:.2}x, energy Δ {surface_energy_rel:.2e} |",
        fmt_time(serial_surface_s),
        n as f64 / serial_surface_s
    );
    println!(
        "| {JOBS} workers | {} | {:.2} | speedup {speedup:.2}x |",
        fmt_time(parallel_s),
        n as f64 / parallel_s
    );
    println!(
        "| telemetry | - | - | {peak_records} resident records (materialized) vs {peak_bins} bins (streaming) |"
    );

    let mut v = Value::obj();
    v.set("bench", "sweep_executor")
        .set("fast", fast)
        .set("grid_cases", n as u64)
        .set("jobs", JOBS as u64)
        .set("serial_s", serial_s)
        .set("serial_surface_s", serial_surface_s)
        .set("parallel_s", parallel_s)
        .set("speedup", speedup)
        .set("speedup_surface", speedup_surface)
        .set("surface_energy_rel_delta", surface_energy_rel)
        .set("cases_per_sec_serial", n as f64 / serial_s)
        .set("cases_per_sec_parallel", n as f64 / parallel_s)
        .set("peak_stage_records_materialized", peak_records)
        .set("peak_resident_bins_streaming", peak_bins);
    let out = std::env::var("REPRO_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_sweep.json".to_string());
    std::fs::write(&out, v.pretty()).unwrap();
    eprintln!("wrote {out}");
}
