//! Bench: regenerate Experiment 2 / Fig. 3 (prefill:decode ratio vs
//! power & energy across request lengths).

use vidur_energy::experiments::exp2;
use vidur_energy::util::bench::Bench;

fn main() {
    let mut b = Bench::new("exp2_pd_ratio");
    let dir = std::env::temp_dir().join("vidur_bench_exp2");
    b.once(
        "exp2 sweep (fast grid)",
        || exp2::run(&dir, true).unwrap(),
        |t| {
            let e = t.f64_col("energy_kwh").unwrap();
            format!(
                "energy span {:.4}..{:.4} kWh (paper: rises with length & decode share)",
                e.iter().cloned().fold(f64::INFINITY, f64::min),
                e.iter().cloned().fold(0.0, f64::max)
            )
        },
    );
    b.run();
}
