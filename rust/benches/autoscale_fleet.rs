//! Autoscaling hot-path benchmarks: the event engine with a dynamic
//! fleet (lifecycle events, requeue-on-drain, scale ticks) against the
//! fixed-fleet engine on the same workload, plus the fleet-aware Eq. 5
//! binning over a dynamic timeline.

use vidur_energy::autoscale::GridEnv;
use vidur_energy::config::simconfig::{
    Arrival, AutoscaleConfig, CostModelKind, LengthDist, ScalingPolicyKind, SimConfig,
};
use vidur_energy::pipeline::{bin_stages_fleet, BinningBackend};
use vidur_energy::sim;
use vidur_energy::util::bench::Bench;
use vidur_energy::workload::{Trace, WorkloadGenerator};

fn main() {
    let mut bench = Bench::new("autoscale_fleet");

    // Bursty workload that forces real scale-ups and drains.
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.num_requests = 2_000;
    cfg.arrival = Arrival::Gamma { qps: 40.0, cv: 2.5 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 64,
        max: 512,
    };
    cfg.seed = 0xBE7C;
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));

    let mut static_cfg = cfg.clone();
    static_cfg.replicas = 4;
    bench.case_with_metric(
        "engine, fixed fleet of 4 (2k requests)",
        || {
            sim::run_with_trace(&static_cfg, trace.clone())
                .unwrap()
                .stagelog
                .len()
        },
        |n| format!("{n} stages"),
    );

    let mut scale = AutoscaleConfig::default();
    scale.min_replicas = 1;
    scale.max_replicas = 4;
    scale.decision_interval_s = 5.0;
    scale.cold_start_s = 2.0;
    scale.queue_high = 4.0;

    for policy in [ScalingPolicyKind::Reactive, ScalingPolicyKind::CarbonAware] {
        let mut s = scale.clone();
        s.policy = policy;
        let label = format!("engine, autoscaled {} 1..4 (2k requests)", policy.as_str());
        let c = cfg.clone();
        let t = trace.clone();
        bench.case_with_metric(
            &label,
            move || {
                let grid = GridEnv::constant(250.0, 300.0);
                let out = sim::run_autoscaled(&c, &s, &grid, t.clone()).unwrap();
                (out.sim.stagelog.len(), out.timeline.mean_fleet())
            },
            |(n, mf)| format!("{n} stages, mean fleet {mf:.2}"),
        );
    }

    // Fleet-aware binning over a real dynamic timeline.
    let mut s = scale.clone();
    s.policy = ScalingPolicyKind::Reactive;
    let grid = GridEnv::constant(250.0, 300.0);
    let out = sim::run_autoscaled(&cfg, &s, &grid, trace).unwrap();
    bench.case_with_metric(
        "fleet-aware Eq.5 binning (60 s bins)",
        || {
            bin_stages_fleet(
                &cfg,
                &out.sim.stagelog,
                &out.timeline,
                60.0,
                BinningBackend::Native,
            )
            .unwrap()
            .len()
        },
        |n| format!("{n} bins"),
    );

    bench.run();
}
