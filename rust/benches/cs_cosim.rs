//! Bench: regenerate the Table 2 / Fig. 6 / Fig. 7 case study
//! (Vidur→Vessim integration) at reduced scale and time both cosim
//! backends over a multi-day horizon.

use vidur_energy::config::simconfig::CosimConfig;
use vidur_energy::cosim::Environment;
use vidur_energy::experiments::casestudy;
use vidur_energy::util::bench::Bench;
use vidur_energy::util::rng::Rng;

fn main() {
    let mut b = Bench::new("cs_cosim");
    let dir = std::env::temp_dir().join("vidur_bench_cs");
    b.once(
        "casestudy end-to-end (fast)",
        || casestudy::run(&dir, true).unwrap(),
        |t| {
            let find = |name: &str| {
                t.rows
                    .iter()
                    .find(|r| r[0] == name)
                    .map(|r| r[1].clone())
                    .unwrap_or_default()
            };
            format!(
                "renewable {}% offset {}% (paper: 70.3% / 69.2%)",
                find("renewable_share_pct"),
                find("carbon_offset_pct")
            )
        },
    );

    // Cosim stepping throughput: native vs HLO kernel over 2 days.
    let n = 2880;
    let mut rng = Rng::new(9);
    let load: Vec<f64> = (0..n).map(|_| rng.uniform(50.0, 500.0)).collect();
    let solar: Vec<f64> = (0..n)
        .map(|i| ((i % 1440) as f64 / 1440.0 * 3.14).sin().max(0.0) * 500.0)
        .collect();
    let ci: Vec<f64> = (0..n).map(|_| rng.uniform(80.0, 550.0)).collect();
    b.case_with_metric(
        "cosim native loop (2880 steps)",
        || {
            let mut env = Environment::new(CosimConfig::default());
            env.run_native(&load, &solar, &ci).unwrap().net_footprint_g
        },
        |g| format!("net={g:.0} g"),
    );
    if vidur_energy::runtime::ArtifactStore::discover().is_ok() {
        b.case_with_metric(
            "cosim HLO kernel (2880 steps)",
            || {
                let mut env = Environment::new(CosimConfig::default());
                env.run_hlo(&load, &solar, &ci).unwrap().net_footprint_g
            },
            |g| format!("net={g:.0} g"),
        );
    }
    b.run();
}
