//! End-to-end integration: full simulate → account → bin → co-simulate
//! pipelines through the public API, exercising the HLO artifacts on
//! the hot path exactly as the examples and the paper's case study do.

use vidur_energy::config::simconfig::{
    Arrival, CosimConfig, CostModelKind, LengthDist, SchedulerKind, SimConfig,
};
use vidur_energy::cosim::Environment;
use vidur_energy::energy::{AccountingMode, EnergyAccountant};
use vidur_energy::grid::{CarbonIntensityTrace, SolarModel};
use vidur_energy::pipeline::{bin_stages, BinningBackend, LoadProfile};
use vidur_energy::sim;
use vidur_energy::workload::{Trace, WorkloadGenerator};

fn artifacts_present() -> bool {
    vidur_energy::runtime::ArtifactStore::discover().is_ok()
}

fn small_cfg(cost: CostModelKind) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.cost_model = cost;
    cfg.num_requests = 150;
    cfg.arrival = Arrival::Poisson { qps: 8.0 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 64,
        max: 1024,
    };
    cfg.seed = 0xE2E;
    cfg
}

#[test]
fn full_pipeline_hlo_oracle() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let cfg = small_cfg(CostModelKind::Hlo);
    let out = sim::run(&cfg).unwrap();
    assert!(out.requests.iter().all(|r| r.is_finished()));

    let acc = EnergyAccountant::paper_default(&cfg).unwrap();
    let rep = acc.account(&cfg, &out.stagelog, out.metrics.makespan_s);
    assert!(rep.energy_kwh > 0.0);
    assert!(rep.avg_power_w >= 100.0 && rep.avg_power_w <= 400.0);

    // Pipeline into minute bins (HLO binning kernel) and co-simulate.
    let binned = bin_stages(
        &cfg,
        &out.stagelog,
        out.metrics.makespan_s,
        60.0,
        BinningBackend::Hlo,
    )
    .unwrap();
    let profile = LoadProfile::from_binned(&binned);
    // Binned energy equals accounted GPU energy (before PUE) within 1%.
    assert!(
        (profile.total_energy_kwh() - rep.gpu_energy_kwh).abs() / rep.gpu_energy_kwh
            < 0.01,
        "binned {} vs accounted {}",
        profile.total_energy_kwh(),
        rep.gpu_energy_kwh
    );

    let n = profile.len();
    let cosim = CosimConfig::default();
    let solar = SolarModel::default().trace(0.0, n);
    let ci = CarbonIntensityTrace::default().trace(0.0, n);
    let solar_w = solar.sample_grid(0.0, n, 60.0);
    let ci_w = ci.sample_grid(0.0, n, 60.0);
    let mut env = Environment::new(cosim);
    let res = env.run_hlo(&profile.power_w, &solar_w, &ci_w).unwrap();
    // Identity: total emissions = offset + net.
    let total = res.total_emissions_kg * 1000.0;
    assert!(
        (total - (res.offset_by_solar_kg * 1000.0 + res.net_footprint_g)).abs() < 1e-6
    );
    assert!((res.total_energy_kwh - profile.total_energy_kwh()).abs() < 1e-6);
}

#[test]
fn hlo_binning_matches_native_binning() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = small_cfg(CostModelKind::Native);
    let out = sim::run(&cfg).unwrap();
    let native = bin_stages(
        &cfg,
        &out.stagelog,
        out.metrics.makespan_s,
        60.0,
        BinningBackend::Native,
    )
    .unwrap();
    let hlo = bin_stages(
        &cfg,
        &out.stagelog,
        out.metrics.makespan_s,
        60.0,
        BinningBackend::Hlo,
    )
    .unwrap();
    assert_eq!(native.len(), hlo.len());
    for (a, b) in native.power_w.iter().zip(&hlo.power_w) {
        assert!((a - b).abs() / a.max(1.0) < 1e-3, "bin {a} vs {b}");
    }
}

#[test]
fn schedulers_all_complete_same_workload() {
    let mut cfg = small_cfg(CostModelKind::Native);
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));
    let mut energies = Vec::new();
    for sched in [SchedulerKind::Vllm, SchedulerKind::Sarathi, SchedulerKind::Orca] {
        cfg.scheduler = sched;
        let out = sim::run_with_trace(&cfg, trace.clone()).unwrap();
        assert!(
            out.requests.iter().all(|r| r.is_finished()),
            "{sched:?} left requests unfinished"
        );
        let acc = EnergyAccountant::paper_default(&cfg).unwrap();
        energies.push(
            acc.account(&cfg, &out.stagelog, out.metrics.makespan_s)
                .energy_kwh,
        );
    }
    // All in a sane band of each other (same work, different policies).
    let emin = energies.iter().cloned().fold(f64::INFINITY, f64::min);
    let emax = energies.iter().cloned().fold(0.0, f64::max);
    assert!(emax / emin < 2.0, "scheduler energies diverge: {energies:?}");
}

#[test]
fn noise_layer_perturbs_but_preserves_totals() {
    let mut cfg = small_cfg(CostModelKind::Native);
    let base = sim::run(&cfg).unwrap();
    cfg.exec.rf_noise_std = 0.08;
    let noisy = sim::run(&cfg).unwrap();
    assert!(noisy.requests.iter().all(|r| r.is_finished()));
    // Same stage structure, slightly different makespan.
    let rel = (noisy.metrics.makespan_s - base.metrics.makespan_s).abs()
        / base.metrics.makespan_s;
    assert!(rel < 0.2, "noise shifted makespan too much: {rel}");
    assert!(noisy.metrics.makespan_s != base.metrics.makespan_s);
}

#[test]
fn paper_eq3_vs_physical_accounting_ordering() {
    let cfg = small_cfg(CostModelKind::Native);
    let out = sim::run(&cfg).unwrap();
    let phys = EnergyAccountant::paper_default(&cfg)
        .unwrap()
        .account(&cfg, &out.stagelog, out.metrics.makespan_s);
    let eq3 = EnergyAccountant::paper_default(&cfg)
        .unwrap()
        .with_mode(AccountingMode::PaperEq3)
        .account(&cfg, &out.stagelog, out.metrics.makespan_s);
    // With TP=PP=1 and a mostly-busy replica the two agree closely;
    // Eq. 3 just skips idle gaps.
    assert!(eq3.energy_kwh <= phys.energy_kwh + 1e-9);
    assert!(eq3.energy_kwh > 0.5 * phys.energy_kwh);
}
