//! Cross-machine sharding parity (DESIGN.md §9): running a sweep grid
//! under `--shard k/N` on N (simulated) hosts and recombining with
//! `repro merge` must reproduce the unsharded run — CSVs
//! byte-identical, exact telemetry counters equal, merged latency
//! sketches within the documented combined rank bound.
//!
//! Everything lives in ONE test function run sequentially: the shard
//! setting is process-global (like `--jobs`), so parallel test threads
//! must not interleave `set_shard` calls.
//!
//! Fixtures (grid, renderer, tempdir runner, readers) come from the
//! shared harness in `tests/common`; this file keeps its historical
//! seed base.

mod common;

use common::{load_json, read_bytes, run_and_save_grid, TempDir};
use std::path::{Path, PathBuf};
use vidur_energy::experiments::common::GridRun;
use vidur_energy::sweep::{self, merge_shard_dirs, ShardSpec};
use vidur_energy::telemetry::ShardTelemetry;
use vidur_energy::util::json::Value;

const ID: &str = "gridtest";
const SEED_BASE: u64 = 0x5A4D;

fn run_and_save(out: &Path) -> GridRun {
    run_and_save_grid(out, ID, SEED_BASE)
}

#[test]
fn sharded_runs_merge_back_to_the_unsharded_outputs() {
    let base = TempDir::new("vidur_energy_shard_merge");

    // Ground truth: the unsharded run.
    sweep::set_shard(None);
    let unsharded_dir = base.join("unsharded");
    let unsharded_run = run_and_save(&unsharded_dir);
    assert_eq!(unsharded_run.results.len(), 9);
    let want_csv = read_bytes(unsharded_dir.join(ID).join(format!("{ID}.csv")));
    let want_tel = ShardTelemetry::load(&unsharded_dir.join(ID)).unwrap().unwrap();
    assert!(want_tel.is_complete());
    assert_eq!(want_tel.shard, None);

    for shards in [2u32, 4] {
        // "N machines": one sharded run per k, each into its own dir.
        let mut shard_dirs = Vec::new();
        for k in 0..shards {
            sweep::set_shard(Some(ShardSpec::new(k, shards).unwrap()));
            let dir = base.join(format!("{shards}way-{k}"));
            let run = run_and_save(&dir);
            assert_eq!(
                run.results.len(),
                ShardSpec::new(k, shards).unwrap().count_owned(9)
            );
            let tel = ShardTelemetry::load(&dir.join(ID)).unwrap().unwrap();
            assert_eq!(tel.shard, Some(ShardSpec::new(k, shards).unwrap()));
            assert!(!tel.is_complete());
            shard_dirs.push(dir);
        }
        sweep::set_shard(None);

        // Merge — in scrambled order, to prove order independence.
        shard_dirs.reverse();
        let merged_dir = base.join(format!("{shards}way-merged"));
        let merged = merge_shard_dirs(&shard_dirs, &merged_dir).unwrap();
        assert_eq!(merged.len(), 1);
        assert_eq!(merged[0].id, ID);
        assert_eq!(merged[0].shards, shards as usize);
        assert_eq!(merged[0].rows, 9);
        assert!(merged[0].complete);

        // 1. The headline guarantee: byte-identical CSV.
        let got_csv = read_bytes(merged_dir.join(ID).join(format!("{ID}.csv")));
        assert_eq!(
            got_csv, want_csv,
            "{shards}-way merged CSV differs from the unsharded run"
        );

        // 2. Exact accumulators equal.
        let got = ShardTelemetry::load(&merged_dir.join(ID)).unwrap().unwrap();
        assert!(got.is_complete());
        assert_eq!(got.shard, None);
        assert_eq!(got.requests.submitted, want_tel.requests.submitted);
        assert_eq!(got.requests.finished, want_tel.requests.finished);
        assert_eq!(
            got.requests.prefill_tokens_done,
            want_tel.requests.prefill_tokens_done
        );
        assert_eq!(
            got.requests.decode_tokens_done,
            want_tel.requests.decode_tokens_done
        );
        assert_eq!(got.requests.slo_ttft_ok, want_tel.requests.slo_ttft_ok);
        assert_eq!(got.requests.slo_e2e_ok, want_tel.requests.slo_e2e_ok);
        assert_eq!(got.requests.slo_both_ok, want_tel.requests.slo_both_ok);
        assert_eq!(got.requests.norm_latency_n, want_tel.requests.norm_latency_n);
        assert_eq!(got.stages.stages, want_tel.stages.stages);
        assert_eq!(got.oracle, want_tel.oracle);
        assert_eq!(got.peak_resident_bins, want_tel.peak_resident_bins);
        assert_eq!(got.peak_live_requests, want_tel.peak_live_requests);
        assert!(
            (got.requests.norm_latency_mean_s_per_tok
                - want_tel.requests.norm_latency_mean_s_per_tok)
                .abs()
                < 1e-12
        );
        assert!((got.stages.busy_gpu_s - want_tel.stages.busy_gpu_s).abs() < 1e-9);
        assert!((got.stages.weighted_mfu - want_tel.stages.weighted_mfu).abs() < 1e-9);

        // 3. Merged sketches: same sample counts, quantiles within the
        //    documented combined rank bound. ε = 1e-3, n < 1000 ⇒ the
        //    rank bound ⌈εn⌉ = 1 on both sides: answers may differ by
        //    at most a couple of neighbouring order statistics.
        assert_eq!(got.sketches.e2e.count(), want_tel.sketches.e2e.count());
        assert_eq!(got.sketches.ttft.count(), want_tel.sketches.ttft.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            let a = got.sketches.e2e.quantile(q).unwrap();
            let b = want_tel.sketches.e2e.quantile(q).unwrap();
            assert!(
                (a - b).abs() <= 0.1 * b.abs().max(1.0),
                "{shards}-way e2e q{q}: merged {a} vs unsharded {b}"
            );
        }
        // Exact extremes survive every merge.
        assert_eq!(got.sketches.e2e.quantile(0.0), want_tel.sketches.e2e.quantile(0.0));
        assert_eq!(got.sketches.e2e.quantile(1.0), want_tel.sketches.e2e.quantile(1.0));

        // 4. Merged meta.json: sum/max semantics reassemble the
        //    unsharded sweep stats (the satellite bugfix).
        let load_meta = |dir: &PathBuf| load_json(dir.join(ID).join("meta.json"));
        let got_meta = load_meta(&merged_dir);
        let want_meta = load_meta(&unsharded_dir);
        for key in ["cases", "total_stages", "peak_resident_bins", "peak_live_requests"] {
            assert_eq!(
                got_meta.at(&["sweep", key]).and_then(|v| v.as_u64()),
                want_meta.at(&["sweep", key]).and_then(|v| v.as_u64()),
                "sweep.{key} diverged after merge"
            );
        }
        let oracle_calls =
            |m: &Value| m.at(&["sweep", "oracle_cache", "calls"]).and_then(|v| v.as_u64());
        assert_eq!(oracle_calls(&got_meta), oracle_calls(&want_meta));
        // The per-shard label must not leak into merged output.
        assert!(got_meta.at(&["sweep", "shard"]).is_none());
    }

    // Sidecar-less single-case directories (the casestudy/ablation
    // shape: only shard 0 runs them, and they carry no telemetry.json
    // — their CSVs are summary tables, not case grids) are copied
    // through wholesale when exactly one shard produced them, and are
    // an error when more than one did. This pins the documented merge
    // contract the PR-4 log overstated ("written by sharded AND
    // unsharded runs" is true of *grid* experiments only).
    {
        let single = base.join("2way-single");
        std::fs::create_dir_all(single.join("soloexp")).unwrap();
        std::fs::write(single.join("soloexp/soloexp.csv"), "metric,value\nx,1\n").unwrap();
        std::fs::write(single.join("soloexp/meta.json"), "{\"table\": \"t9\"}").unwrap();
        let other = base.join("2way-empty");
        std::fs::create_dir_all(&other).unwrap();
        let out = base.join("single-merged");
        let merged = merge_shard_dirs(&[single.clone(), other.clone()], &out).unwrap();
        let solo = merged.iter().find(|m| m.id == "soloexp").unwrap();
        assert_eq!(solo.shards, 1);
        assert!(solo.complete);
        assert_eq!(
            read_bytes(out.join("soloexp/soloexp.csv")),
            read_bytes(single.join("soloexp/soloexp.csv"))
        );
        assert!(!out.join("soloexp").join("telemetry.json").exists());
        // The same sidecar-less id in TWO shard dirs cannot be merged.
        std::fs::create_dir_all(other.join("soloexp")).unwrap();
        std::fs::write(other.join("soloexp/soloexp.csv"), "metric,value\nx,2\n").unwrap();
        let err = merge_shard_dirs(&[single, other], &base.join("single-err")).unwrap_err();
        assert!(
            err.to_string().contains("no telemetry sidecar"),
            "expected sidecar-less multi-shard error, got: {err}"
        );
    }

    // Protocol errors: the same shard twice must be rejected, never
    // silently double-counted.
    sweep::set_shard(Some(ShardSpec::new(0, 2).unwrap()));
    let dup_a = base.join("dup-a");
    let dup_b = base.join("dup-b");
    run_and_save(&dup_a);
    run_and_save(&dup_b);
    sweep::set_shard(None);
    let err = merge_shard_dirs(&[dup_a, dup_b], &base.join("dup-merged")).unwrap_err();
    assert!(
        err.to_string().contains("overlap"),
        "expected overlap error, got: {err}"
    );
}
