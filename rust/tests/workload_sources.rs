//! RequestSource conformance suite (DESIGN.md §14): every arrival
//! stream the engine can be driven by — the synthetic generator,
//! materialized traces, round-robin splits, streamed trace replay, the
//! scenario library, and weighted mixes — must honor one contract:
//!
//!  * arrivals are nondecreasing and finite,
//!  * ids are dense 0..n in emission order,
//!  * every request has ≥1 prefill and ≥1 decode token, and rate-based
//!    generators keep prefill+decode ≤ `max_tokens`,
//!  * an exhausted source keeps returning `None` (the engine polls
//!    freely after drain),
//!  * equal seeds/inputs reproduce bit-identical streams.
//!
//! The engine, router, and autoscaler all assume these invariants
//! without checking them, so this suite is where a new source earns
//! the right to be wired into `source_from_config`.

mod common;

use common::{stream_cfg, trace_for, TempDir};
use vidur_energy::config::simconfig::{SimConfig, WorkloadKind};
use vidur_energy::workload::{self, split_round_robin, Request, RequestSource};

/// Drain up to `limit` requests (a hard fail-safe for a source that
/// refuses to exhaust; every finite source here ends well below it).
fn drain(src: &mut dyn RequestSource, limit: usize) -> Vec<Request> {
    let mut out = Vec::new();
    while let Some(r) = src.next_request() {
        out.push(r);
        assert!(out.len() <= limit, "source exceeded {limit} requests");
    }
    // Exhaustion is stable: the engine may poll again after None.
    for _ in 0..3 {
        assert!(src.next_request().is_none(), "source revived after None");
    }
    out
}

/// The shared contract. `token_cap` is `Some(max_tokens)` for
/// rate-based generators; replayed traces carry whatever the file
/// says, so they only promise positive token counts.
fn assert_conformant(what: &str, reqs: &[Request], expect_n: usize, token_cap: Option<u64>) {
    assert_eq!(reqs.len(), expect_n, "{what}: wrong request count");
    let mut last = f64::NEG_INFINITY;
    for (i, r) in reqs.iter().enumerate() {
        assert_eq!(r.id, i as u64, "{what}: ids not dense at {i}");
        assert!(r.arrival_s.is_finite(), "{what}: non-finite arrival at {i}");
        assert!(
            r.arrival_s >= last,
            "{what}: arrivals decreased at {i}: {} < {last}",
            r.arrival_s
        );
        last = r.arrival_s;
        assert!(r.prefill_tokens >= 1, "{what}: zero prefill at {i}");
        assert!(r.decode_tokens >= 1, "{what}: zero decode at {i}");
        if let Some(cap) = token_cap {
            assert!(
                r.prefill_tokens + r.decode_tokens <= cap,
                "{what}: request {i} exceeds max_tokens {cap}: {} + {}",
                r.prefill_tokens,
                r.decode_tokens
            );
        }
    }
}

/// Config for the workload-kind sources: native oracle, 300 requests,
/// 12 QPS — small enough that the whole suite is fast.
fn cfg_for(kind: WorkloadKind) -> SimConfig {
    let mut cfg = stream_cfg(0x50C); // historical seed for this suite
    cfg.num_requests = 300;
    cfg.workload = kind;
    cfg
}

fn kind_sources() -> Vec<(String, SimConfig)> {
    [
        WorkloadKind::Synthetic,
        WorkloadKind::Chat,
        WorkloadKind::Rag,
        WorkloadKind::Agentic,
        WorkloadKind::Tenants,
        WorkloadKind::parse("mix:chat=2,rag=1,agentic=0.5,tenants=1,synthetic=1").unwrap(),
    ]
    .into_iter()
    .map(|k| (k.spec(), cfg_for(k)))
    .collect()
}

#[test]
fn every_workload_kind_is_conformant() {
    for (spec, cfg) in kind_sources() {
        let mut src = workload::source_from_config(&cfg).unwrap();
        let reqs = drain(&mut *src, 10_000);
        assert_conformant(&spec, &reqs, 300, Some(cfg.max_tokens));
    }
}

#[test]
fn equal_seeds_reproduce_bit_identical_streams() {
    for (spec, cfg) in kind_sources() {
        let mut a = workload::source_from_config(&cfg).unwrap();
        let mut b = workload::source_from_config(&cfg).unwrap();
        let ra = drain(&mut *a, 10_000);
        let rb = drain(&mut *b, 10_000);
        assert_eq!(ra.len(), rb.len(), "{spec}: stream lengths differ");
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id, "{spec}: ids diverge");
            assert_eq!(
                x.arrival_s.to_bits(),
                y.arrival_s.to_bits(),
                "{spec}: arrivals diverge at id {}",
                x.id
            );
            assert_eq!(x.prefill_tokens, y.prefill_tokens, "{spec}: prefill diverges");
            assert_eq!(x.decode_tokens, y.decode_tokens, "{spec}: decode diverges");
        }
    }
}

#[test]
fn trace_source_and_split_partitions_are_conformant_and_conserving() {
    let cfg = stream_cfg(0x5117);
    let trace = trace_for(&cfg);
    let n = trace.requests.len();
    let total_tokens: u64 = trace
        .requests
        .iter()
        .map(|r| r.prefill_tokens + r.decode_tokens)
        .sum();

    let mut src = trace.clone().into_source();
    let reqs = drain(&mut src, n + 1);
    assert_conformant("trace", &reqs, n, Some(cfg.max_tokens));

    // Round-robin split: each partition is itself conformant, and the
    // re-union conserves request count and token totals exactly.
    let mut split_n = 0usize;
    let mut split_tokens = 0u64;
    for (i, mut part) in split_round_robin(&trace, 3).into_iter().enumerate() {
        let preqs = drain(&mut part, n + 1);
        assert_conformant(&format!("split[{i}]"), &preqs, preqs.len(), Some(cfg.max_tokens));
        split_n += preqs.len();
        split_tokens += preqs
            .iter()
            .map(|r| r.prefill_tokens + r.decode_tokens)
            .sum::<u64>();
    }
    assert_eq!(split_n, n, "split lost or duplicated requests");
    assert_eq!(split_tokens, total_tokens, "split changed token totals");
}

#[test]
fn replay_source_is_conformant_and_matches_the_saved_trace() {
    let tmp = TempDir::new("vidur_energy_workload_sources");
    let cfg = stream_cfg(0x3E91A);
    let trace = trace_for(&cfg);
    let path = tmp.join("trace.csv");
    trace.save(&path).unwrap();

    let mut src = workload::ReplaySource::open(&path, 1.0, 1).unwrap();
    let reqs = drain(&mut src, trace.requests.len() + 1);
    assert_conformant("replay", &reqs, trace.requests.len(), None);
    for (a, b) in trace.requests.iter().zip(&reqs) {
        assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
        assert_eq!(a.prefill_tokens, b.prefill_tokens);
        assert_eq!(a.decode_tokens, b.decode_tokens);
    }

    // Looped replay stays conformant across the pass seam.
    let mut cfg2 = cfg.clone();
    cfg2.workload = WorkloadKind::Trace {
        path: path.to_string_lossy().into_owned(),
        time_scale: 0.5,
        repeat: 3,
    };
    cfg2.num_requests = 3 * trace.requests.len() as u64;
    let mut looped = workload::source_from_config(&cfg2).unwrap();
    let lreqs = drain(&mut *looped, 3 * trace.requests.len() + 1);
    assert_conformant("replay-looped", &lreqs, 3 * trace.requests.len(), None);
}

#[test]
fn lazy_workload_matches_materialized_generate() {
    let cfg = stream_cfg(0x1A2);
    let materialized = trace_for(&cfg).requests;
    let mut lazy =
        vidur_energy::workload::WorkloadGenerator::from_config(&cfg).take(cfg.num_requests);
    let streamed = drain(&mut lazy, materialized.len() + 1);
    assert_eq!(streamed.len(), materialized.len());
    for (a, b) in materialized.iter().zip(&streamed) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
        assert_eq!(a.prefill_tokens, b.prefill_tokens);
        assert_eq!(a.decode_tokens, b.decode_tokens);
    }
}
