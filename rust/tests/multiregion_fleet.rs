//! Integration tests for the regional-fleet routing subsystem
//! (DESIGN.md §13), pinning the three contracts the refactor claims:
//!
//! 1. **Degenerate-case oracle** — with zero RTT, zero cold-start, one
//!    always-on replica per region, a zero-idle power model, no solar,
//!    and an inert battery, the request-level greedy-ci router books
//!    the same emissions as the legacy closed-form
//!    `multiregion::simulate_with_overhead` greedy placement.
//! 2. **Energy conservation** — per region, fleet-aware accounted GPU
//!    energy == integrated Eq. 5 binned demand == the microgrid
//!    co-simulation's load energy (at zero transfer overhead).
//! 3. **Single-region byte-neutrality** — one region under the
//!    `static-home` router is not just "close to" the plain engine, it
//!    writes byte-identical stage and request CSVs.
//!
//! Fixtures come from the shared harness in `tests/common`.

mod common;

use common::{read_bytes, stream_cfg, trace_for, TempDir};
use std::path::Path;
use vidur_energy::autoscale::GridEnv;
use vidur_energy::battery::Battery;
use vidur_energy::config::simconfig::CosimConfig;
use vidur_energy::coordinator::fleet::{
    run_global, FleetRegion, GlobalFleetSpec, RoutePolicyKind,
};
use vidur_energy::coordinator::multiregion::{simulate_with_overhead, Region};
use vidur_energy::cosim::Microgrid;
use vidur_energy::exec::build_cost_model;
use vidur_energy::pipeline::LoadProfile;
use vidur_energy::power::{PowerModel, PowerParams};
use vidur_energy::sim::{self, RegionSim};
use vidur_energy::telemetry::{RequestLog, StageLog, StreamingSink};
use vidur_energy::workload::Request;

/// Idle-free power model: the closed-form oracle only ever sees busy
/// demand, so the router side must not book idle watts for its
/// always-on replicas.
fn zero_idle_model() -> PowerModel {
    PowerModel::MfuPowerLaw(PowerParams {
        p_idle: 0.0,
        p_max: 700.0,
        mfu_sat: 0.6,
        gamma: 1.0,
    })
}

/// A degenerate region: no solar, battery pinned at its floor (it can
/// never charge without solar excess, hence never discharge), so the
/// microgrid reduces to "import everything from the grid".
fn degenerate_region(name: &str, ci_mean: f64) -> FleetRegion {
    let mut cosim = CosimConfig::default();
    cosim.soc_init = cosim.soc_min;
    cosim.solar_capacity_w = 0.0;
    FleetRegion {
        region: Region {
            name: name.into(),
            ci_mean,
            tz_offset_h: 0.0,
            solar_w: 0.0,
        },
        replicas: 1,
        scale: None,
        rtt_s: 0.0,
        cosim,
    }
}

/// Contract 1: the request-granularity router, collapsed to the legacy
/// model's assumptions, reproduces the closed-form greedy emissions.
/// The CI means are far enough apart that the cheap region wins at
/// every instant, so both deciders make identical placements and any
/// residual difference is bin-edge quantization.
#[test]
fn zero_rtt_degenerate_greedy_matches_closed_form_oracle() {
    let mut cfg = stream_cfg(0x6E0D);
    cfg.replicas = 1;
    cfg.num_requests = 200;
    let trace = trace_for(&cfg);
    let model = zero_idle_model();
    let interval_s = CosimConfig::default().interval_s;

    // Reference demand profile: the same workload on one always-on
    // replica (identical schedule to whichever region serves it all).
    let mut sink = StreamingSink::with_model(&cfg, interval_s, model).unwrap();
    let cost = build_cost_model(&cfg).unwrap();
    let run = sim::run_with_sink(&cfg, trace.clone(), cost, &mut sink).unwrap();
    let prof = sink.binned_span(&cfg, run.metrics.makespan_s).unwrap();
    let load = LoadProfile {
        interval_s,
        power_w: prof.power_w.clone(),
    };

    let fleet = vec![
        degenerate_region("home-dirty", 450.0),
        degenerate_region("coal", 700.0),
        degenerate_region("hydro", 60.0),
    ];
    let rlist: Vec<Region> = fleet.iter().map(|fr| fr.region.clone()).collect();
    let overhead = CosimConfig::default().transfer_overhead;
    let legacy = simulate_with_overhead(&load, &rlist, interval_s, cfg.seed, overhead).unwrap();

    let spec = GlobalFleetSpec {
        regions: fleet,
        policy: RoutePolicyKind::GreedyCi,
        power_model: Some(model),
    };
    let mut source = trace.into_source();
    let res = run_global(&cfg, &spec, &mut source, None).unwrap();

    // Hydro is cheapest at every instant even with the transfer
    // overhead, so the router must move the whole workload there.
    assert_eq!(res.moved_requests, cfg.num_requests, "router kept work home");
    assert_eq!(res.regions[2].routed, cfg.num_requests);

    assert!(legacy.greedy_g > 0.0);
    let rel = (res.fleet_emissions_g - legacy.greedy_g).abs() / legacy.greedy_g;
    assert!(
        rel < 0.05,
        "router emissions {} vs closed-form greedy {} ({}% off)",
        res.fleet_emissions_g,
        legacy.greedy_g,
        rel * 100.0
    );
    // And both agree the move beat staying home.
    assert!(res.fleet_emissions_g < legacy.static_g);
}

/// Contract 2: the three energy views agree per region — accounted
/// fleet energy, integrated binned demand, and the co-simulated load
/// energy (transfer overhead zeroed so the cosim sees the raw demand).
#[test]
fn per_region_accounting_conserves_energy() {
    let mut cfg = stream_cfg(0xC0A5);
    cfg.replicas = 1;
    cfg.num_requests = 150;
    let trace = trace_for(&cfg);

    let mut fleet = vec![
        degenerate_region("home", 450.0),
        degenerate_region("hydro", 60.0),
    ];
    for fr in &mut fleet {
        fr.cosim.transfer_overhead = 0.0;
    }
    let spec = GlobalFleetSpec {
        regions: fleet,
        policy: RoutePolicyKind::GreedyCi,
        power_model: None,
    };
    let mut source = trace.into_source();
    let res = run_global(&cfg, &spec, &mut source, None).unwrap();

    let mut fleet_sum = 0.0;
    for r in &res.regions {
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-12);
        assert!(
            rel(r.gpu_energy_kwh, r.binned_energy_kwh) < 1e-6,
            "{}: accounted {} kWh != binned {} kWh",
            r.name,
            r.gpu_energy_kwh,
            r.binned_energy_kwh
        );
        assert!(
            rel(r.binned_energy_kwh, r.cosim.total_energy_kwh) < 1e-6,
            "{}: binned {} kWh != cosim load {} kWh",
            r.name,
            r.binned_energy_kwh,
            r.cosim.total_energy_kwh
        );
        fleet_sum += r.gpu_energy_kwh;
    }
    assert!((fleet_sum - res.fleet_gpu_energy_kwh).abs() < 1e-9);
    assert!(res.fleet_gpu_energy_kwh > 0.0);
}

fn write_request_csv(path: &Path, requests: &[Request]) {
    let mut out = String::from("id,arrival_s,prefill_tokens,decode_tokens,ttft_s,e2e_s\n");
    for r in requests {
        out.push_str(&format!(
            "{},{:.6},{},{},{:.6},{:.6}\n",
            r.id,
            r.arrival_s,
            r.prefill_tokens,
            r.decode_tokens,
            r.ttft().unwrap_or(f64::NAN),
            r.e2e_latency().unwrap_or(f64::NAN),
        ));
    }
    std::fs::write(path, out).unwrap();
}

/// Contract 3 (satellite): one region + `static-home` + fixed fleet is
/// the plain engine, bit for bit — same stage CSV, same request CSV.
#[test]
fn single_region_static_home_is_byte_identical_to_plain_engine() {
    let mut cfg = stream_cfg(0xB17E);
    cfg.replicas = 2;
    cfg.num_requests = 200;
    let trace = trace_for(&cfg);
    let dir = TempDir::new("vidur-mr-byte-neutral");

    let mut plain_stages = StageLog::new();
    let mut plain_reqs = RequestLog::new(&cfg);
    let mut src = trace.clone().into_source();
    sim::run_with_sinks(
        &cfg,
        &mut src,
        build_cost_model(&cfg).unwrap(),
        &mut plain_stages,
        &mut plain_reqs,
    )
    .unwrap();

    let mut fleet_stages = StageLog::new();
    let mut fleet_reqs = RequestLog::new(&cfg);
    let mut src = trace.into_source();
    let mut policy = RoutePolicyKind::StaticHome.build(cfg.slo_ttft_s);
    let region = RegionSim {
        replicas: cfg.replicas,
        scale: None,
        grid: GridEnv::constant(418.2, 0.0),
        rtt_s: 0.0,
        power_est_w: 300.0,
        microgrid: Microgrid::new(Battery::from_config(&CosimConfig::default())),
        interval_s: 60.0,
        transfer_overhead: 0.0,
        sink: &mut fleet_stages,
        requests: &mut fleet_reqs,
    };
    sim::run_multifleet(
        &cfg,
        &mut src,
        build_cost_model(&cfg).unwrap(),
        policy.as_mut(),
        vec![region],
    )
    .unwrap();

    plain_stages.save_csv(dir.join("plain_stages.csv")).unwrap();
    fleet_stages.save_csv(dir.join("fleet_stages.csv")).unwrap();
    assert_eq!(
        read_bytes(dir.join("plain_stages.csv")),
        read_bytes(dir.join("fleet_stages.csv")),
        "stage CSVs diverged"
    );

    write_request_csv(&dir.join("plain_requests.csv"), &plain_reqs.into_requests());
    write_request_csv(&dir.join("fleet_requests.csv"), &fleet_reqs.into_requests());
    assert_eq!(
        read_bytes(dir.join("plain_requests.csv")),
        read_bytes(dir.join("fleet_requests.csv")),
        "request CSVs diverged"
    );
}
