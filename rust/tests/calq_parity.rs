//! Calendar-queue vs binary-heap engine parity (DESIGN.md §12).
//!
//! The calendar queue replaces the engines' `BinaryHeap` on the hot
//! path; correctness rests on both schedulers popping events in
//! exactly the same order (ascending time, push-order tie-break).
//! `sim::calq`'s in-module differential tests pin that at the queue
//! level; these tests pin it end to end: the same trace through
//! [`vidur_energy::sim::run_with_sinks`] (calendar) and
//! [`vidur_energy::sim::run_with_sinks_heap`] (heap) must produce
//! byte-identical stage CSVs, bit-equal metrics, and identical
//! request lifecycles — fixed fleet and autoscaled alike.

mod common;

use common::{read_bytes, stream_cfg, trace_for, TempDir};
use vidur_energy::autoscale::GridEnv;
use vidur_energy::config::simconfig::{AutoscaleConfig, ScalingPolicyKind};
use vidur_energy::exec::build_cost_model;
use vidur_energy::sim::{
    run_autoscaled_with_sinks, run_autoscaled_with_sinks_heap, run_with_sinks,
    run_with_sinks_heap,
};
use vidur_energy::telemetry::{RequestLog, StageLog};

#[test]
fn fixed_fleet_stage_csvs_are_byte_identical() {
    let mut cfg = stream_cfg(0xCA1);
    cfg.replicas = 2;
    let trace = trace_for(&cfg);
    let tmp = TempDir::new("calq_parity_fixed");

    let mut cal_stages = StageLog::new();
    let mut cal_reqs = RequestLog::new(&cfg);
    let mut src = trace.clone().into_source();
    let cal = run_with_sinks(
        &cfg,
        &mut src,
        build_cost_model(&cfg).unwrap(),
        &mut cal_stages,
        &mut cal_reqs,
    )
    .unwrap();

    let mut heap_stages = StageLog::new();
    let mut heap_reqs = RequestLog::new(&cfg);
    let mut src = trace.into_source();
    let heap = run_with_sinks_heap(
        &cfg,
        &mut src,
        build_cost_model(&cfg).unwrap(),
        &mut heap_stages,
        &mut heap_reqs,
    )
    .unwrap();

    // Bit-equal summary metrics (no tolerance).
    assert_eq!(cal.metrics.makespan_s, heap.metrics.makespan_s);
    assert_eq!(cal.metrics.stage_count, heap.metrics.stage_count);
    assert_eq!(cal.metrics.achieved_qps, heap.metrics.achieved_qps);
    assert_eq!(cal.metrics.token_throughput, heap.metrics.token_throughput);
    assert_eq!(cal.oracle.calls, heap.oracle.calls);

    // Identical request lifecycles, in order.
    let cal_r = cal_reqs.into_requests();
    let heap_r = heap_reqs.into_requests();
    assert_eq!(cal_r.len(), heap_r.len());
    for (a, b) in cal_r.iter().zip(&heap_r) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.scheduled_s, b.scheduled_s);
        assert_eq!(a.first_token_s, b.first_token_s);
        assert_eq!(a.finished_s, b.finished_s);
    }

    // The satellite contract: byte-identical CSV exports.
    let cal_csv = tmp.join("cal.csv");
    let heap_csv = tmp.join("heap.csv");
    cal_stages.save_csv(&cal_csv).unwrap();
    heap_stages.save_csv(&heap_csv).unwrap();
    assert_eq!(
        read_bytes(&cal_csv),
        read_bytes(&heap_csv),
        "stage CSVs diverge between calendar and heap engines"
    );
}

#[test]
fn autoscaled_stage_csvs_are_byte_identical() {
    let mut cfg = stream_cfg(0xCA2);
    cfg.num_requests = 300;
    cfg.batch_cap = 8;
    let trace = trace_for(&cfg);
    let mut scale = AutoscaleConfig::default();
    scale.policy = ScalingPolicyKind::Reactive;
    scale.decision_interval_s = 2.0;
    scale.cold_start_s = 1.0;
    scale.queue_high = 4.0;
    let grid = GridEnv::constant(150.0, 0.0);
    let tmp = TempDir::new("calq_parity_auto");

    let mut cal_stages = StageLog::new();
    let mut cal_reqs = RequestLog::new(&cfg);
    let mut src = trace.clone().into_source();
    let cal = run_autoscaled_with_sinks(
        &cfg,
        &scale,
        &grid,
        &mut src,
        build_cost_model(&cfg).unwrap(),
        &mut cal_stages,
        &mut cal_reqs,
    )
    .unwrap();

    let mut heap_stages = StageLog::new();
    let mut heap_reqs = RequestLog::new(&cfg);
    let mut src = trace.into_source();
    let heap = run_autoscaled_with_sinks_heap(
        &cfg,
        &scale,
        &grid,
        &mut src,
        build_cost_model(&cfg).unwrap(),
        &mut heap_stages,
        &mut heap_reqs,
    )
    .unwrap();

    assert_eq!(cal.sim.metrics.makespan_s, heap.sim.metrics.makespan_s);
    assert_eq!(cal.sim.metrics.stage_count, heap.sim.metrics.stage_count);
    assert_eq!(cal.decisions.len(), heap.decisions.len());
    assert_eq!(cal.timeline.events.len(), heap.timeline.events.len());
    assert_eq!(cal.timeline.max_fleet(), heap.timeline.max_fleet());

    let cal_csv = tmp.join("cal.csv");
    let heap_csv = tmp.join("heap.csv");
    cal_stages.save_csv(&cal_csv).unwrap();
    heap_stages.save_csv(&heap_csv).unwrap();
    assert_eq!(
        read_bytes(&cal_csv),
        read_bytes(&heap_csv),
        "autoscaled stage CSVs diverge between calendar and heap engines"
    );
}
