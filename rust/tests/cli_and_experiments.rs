//! Integration tests over the CLI dispatch layer and the experiment
//! regenerators (fast variants), verifying the repository's operational
//! surface: every experiment writes its CSV + metadata and reports the
//! paper-shaped columns.

use vidur_energy::experiments;
use vidur_energy::report;
use vidur_energy::util::csv::Table;

fn artifacts_present() -> bool {
    vidur_energy::runtime::ArtifactStore::discover().is_ok()
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("vidur_energy_it_{name}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn fig1_fast_produces_saturating_mfu() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = tmp_dir("fig1");
    let t = experiments::fig1::run(&dir, true).unwrap();
    assert!(dir.join("fig1/fig1.csv").exists());
    assert!(dir.join("fig1/meta.json").exists());
    let mfu = t.f64_col("weighted_mfu").unwrap();
    // Monotone-ish growth toward saturation: last > first.
    assert!(mfu.last().unwrap() > &(mfu[0] * 1.2), "{mfu:?}");
    // Never exceeds the efficiency ceiling.
    assert!(mfu.iter().all(|&m| m <= 0.47));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn exp3_fast_shows_batching_energy_savings() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = tmp_dir("exp3");
    let t = experiments::exp3::run(&dir, true).unwrap();
    let energy = t.f64_col("energy_kwh").unwrap();
    // cap=1 (first row) must cost more than cap=128 (last row).
    assert!(
        energy[0] > *energy.last().unwrap(),
        "batching should save energy: {energy:?}"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn exp5_fast_covers_parallelism_grid() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = tmp_dir("exp5");
    let t = experiments::exp5::run(&dir, true).unwrap();
    assert_eq!(t.rows.len(), 4); // fast grid
    let power = t.f64_col("avg_power_w").unwrap();
    assert!(power.iter().all(|&p| (100.0..=400.0).contains(&p)));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn report_assembles_multiple_experiments() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = tmp_dir("report");
    experiments::fig1::run(&dir, true).unwrap();
    experiments::ablation::run(&dir, true).unwrap();
    let md = report::assemble(&dir).unwrap();
    assert!(md.contains("## fig1"));
    assert!(md.contains("## ablation"));
    assert!(md.contains("paper:"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn casestudy_fast_end_to_end_writes_all_figures() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = tmp_dir("cs");
    let t = experiments::casestudy::run(&dir, true).unwrap();
    // Table-2 metric rows present with paper reference column.
    let metrics: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
    for want in [
        "total_energy_kwh",
        "renewable_share_pct",
        "carbon_offset_pct",
        "battery_full_cycles",
    ] {
        assert!(metrics.contains(&want), "missing metric {want}");
    }
    for f in [
        "casestudy/casestudy.csv",
        "casestudy/fig6_power_flows.csv",
        "casestudy/fig7_battery_emissions.csv",
        "casestudy/load_profile.csv",
        "casestudy/meta.json",
    ] {
        assert!(dir.join(f).exists(), "missing {f}");
    }
    // Offset identity holds in the baseline column.
    let by = |name: &str| {
        t.rows
            .iter()
            .find(|r| r[0] == name)
            .map(|r| r[1].parse::<f64>().unwrap())
            .unwrap()
    };
    let total = by("total_emissions_kg") * 1000.0;
    let offset = by("offset_by_solar_kg") * 1000.0;
    let net = by("net_footprint_g");
    assert!((total - (offset + net)).abs() < 20.0, "identity violated");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn load_profile_fig6_consistent_with_summary() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = tmp_dir("cs2");
    experiments::casestudy::run(&dir, true).unwrap();
    let fig6 = Table::load(dir.join("casestudy/fig6_power_flows.csv")).unwrap();
    let load = fig6.f64_col("load_w").unwrap();
    let solar = fig6.f64_col("solar_w").unwrap();
    let grid = fig6.f64_col("grid_w").unwrap();
    let batt = fig6.f64_col("battery_w").unwrap();
    // Instantaneous power balance in every minute of Fig. 6.
    for i in 0..load.len() {
        let supply = solar[i].min(load[i]) + grid[i].max(0.0) + batt[i].max(0.0);
        assert!(
            (supply - load[i]).abs() < 0.5,
            "imbalance at row {i}: load {} supply {supply}",
            load[i]
        );
    }
    std::fs::remove_dir_all(dir).ok();
}
