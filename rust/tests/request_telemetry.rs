//! Streaming request telemetry: parity, determinism, and the memory
//! claim (DESIGN.md §8).
//!
//! * Parity: the streaming request path (lazy arrivals + sketch sink)
//!   must match the materialized path *exactly* on everything that is
//!   a count or a sum — finished/submitted, token totals, SLO
//!   fractions, throughput — because both run the same fold in the
//!   same completion order. Latency quantiles are approximate, but
//!   only within the sketch's documented rank-error bound ε.
//! * Determinism: `--jobs 1` and `--jobs 8` sweeps produce identical
//!   request metrics (the sinks are per-case state).
//! * Memory: a 1M-request run holds O(outstanding + bins) resident
//!   state — live map, sketch tuples, and bins all ≪ the request count.
//!
//! Fixtures (config, flat-cost oracle, rank-bound assertion) come from
//! the shared harness in `tests/common`.

mod common;

use common::{assert_rank_bounded, stream_cfg, trace_for, FlatCost};
use vidur_energy::config::simconfig::{Arrival, CostModelKind, LengthDist, SimConfig};
use vidur_energy::experiments::common::run_cases_on;
use vidur_energy::sim;
use vidur_energy::sweep::SweepExecutor;
use vidur_energy::telemetry::{StreamingRequestSink, StreamingSink};
use vidur_energy::util::rng::case_seed;
use vidur_energy::workload::WorkloadGenerator;

fn base_cfg() -> SimConfig {
    stream_cfg(0x9E57)
}

#[test]
fn streaming_request_metrics_match_materialized() {
    let mut cfg = base_cfg();
    cfg.replicas = 2;
    let trace = trace_for(&cfg);

    // Materialized: full request vector, exact percentiles.
    let mat = sim::run_with_trace(&cfg, trace.clone()).unwrap();

    // Streaming: lazy arrivals, sketch-based request sink.
    let mut stage_sink = StreamingSink::new(&cfg, 10.0).unwrap();
    let cost = vidur_energy::exec::build_cost_model(&cfg).unwrap();
    let run = sim::run_with_sink(&cfg, trace, cost, &mut stage_sink).unwrap();

    // Identical simulation schedule.
    assert_eq!(mat.metrics.makespan_s, run.metrics.makespan_s);
    assert_eq!(mat.metrics.stage_count, run.metrics.stage_count);

    // Exact request-side parity: counts, throughput, token totals,
    // SLO fractions, normalized-latency mean.
    assert_eq!(run.request_stats.submitted, cfg.num_requests);
    assert_eq!(run.request_stats.finished, cfg.num_requests);
    assert_eq!(mat.metrics.achieved_qps, run.metrics.achieved_qps);
    assert_eq!(mat.metrics.token_throughput, run.metrics.token_throughput);
    assert_eq!(mat.metrics.slo_ttft_attained, run.metrics.slo_ttft_attained);
    assert_eq!(mat.metrics.slo_e2e_attained, run.metrics.slo_e2e_attained);
    assert_eq!(mat.metrics.slo_attained, run.metrics.slo_attained);
    assert_eq!(
        mat.metrics.norm_latency_s_per_tok,
        run.metrics.norm_latency_s_per_tok
    );

    // Quantile parity within the sketch's rank-error bound, checked
    // against the materialized samples.
    let eps = StreamingRequestSink::DEFAULT_EPS;
    let mut ttft: Vec<f64> = mat.requests.iter().filter_map(|r| r.ttft()).collect();
    let mut e2e: Vec<f64> = mat
        .requests
        .iter()
        .filter_map(|r| r.e2e_latency())
        .collect();
    let mut qdel: Vec<f64> = mat
        .requests
        .iter()
        .filter_map(|r| r.scheduled_s.map(|s| s - r.arrival_s))
        .collect();
    ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qdel.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_rank_bounded(&ttft, run.metrics.ttft_p50_s, 0.50, eps, "ttft p50");
    assert_rank_bounded(&ttft, run.metrics.ttft_p99_s, 0.99, eps, "ttft p99");
    assert_rank_bounded(&e2e, run.metrics.e2e_p50_s, 0.50, eps, "e2e p50");
    assert_rank_bounded(&e2e, run.metrics.e2e_p99_s, 0.99, eps, "e2e p99");
    assert_rank_bounded(
        &qdel,
        run.metrics.queue_delay_p50_s,
        0.50,
        eps,
        "queue delay p50",
    );
}

/// The same parity on an autoscaled run: the dynamic-fleet core feeds
/// the identical completion stream to the request sink.
#[test]
fn streaming_request_metrics_match_materialized_autoscaled() {
    use vidur_energy::autoscale::GridEnv;
    use vidur_energy::config::simconfig::{AutoscaleConfig, ScalingPolicyKind};

    let mut cfg = base_cfg();
    cfg.replicas = 2;
    cfg.batch_cap = 16;
    let trace = trace_for(&cfg);
    let mut scale = AutoscaleConfig::default();
    scale.policy = ScalingPolicyKind::Reactive;
    scale.min_replicas = 1;
    scale.max_replicas = 4;
    scale.decision_interval_s = 10.0;
    scale.cold_start_s = 5.0;
    scale.queue_high = 4.0;

    let grid = GridEnv::constant(150.0, 0.0);
    let mat = sim::run_autoscaled(&cfg, &scale, &grid, trace.clone()).unwrap();
    let mut stage_sink = StreamingSink::new(&cfg, 10.0).unwrap();
    let run = sim::run_autoscaled_streaming(
        &cfg,
        &scale,
        &GridEnv::constant(150.0, 0.0),
        trace,
        &mut stage_sink,
    )
    .unwrap();

    assert_eq!(mat.sim.metrics.makespan_s, run.sim.metrics.makespan_s);
    assert_eq!(run.sim.request_stats.finished, cfg.num_requests);
    assert_eq!(mat.sim.metrics.achieved_qps, run.sim.metrics.achieved_qps);
    assert_eq!(
        mat.sim.metrics.token_throughput,
        run.sim.metrics.token_throughput
    );
    assert_eq!(mat.sim.metrics.slo_attained, run.sim.metrics.slo_attained);
    assert_eq!(mat.timeline.events.len(), run.timeline.events.len());
    assert_eq!(mat.decisions.len(), run.decisions.len());
}

/// Request metrics are byte-identical across sweep worker counts —
/// each case owns its sinks, so parallelism can't perturb them.
#[test]
fn request_metrics_identical_across_jobs() {
    let grid: Vec<SimConfig> = (0..6)
        .map(|i| {
            let mut cfg = base_cfg();
            cfg.num_requests = 96;
            cfg.arrival = Arrival::Poisson {
                qps: 2.0 + 3.0 * (i % 3) as f64,
            };
            cfg.seed = case_seed(0x9E, i as u64);
            cfg
        })
        .collect();
    let serial = run_cases_on(&SweepExecutor::new(1), grid.clone()).unwrap();
    let par = run_cases_on(&SweepExecutor::new(8), grid).unwrap();
    for (a, b) in serial.iter().zip(&par) {
        assert_eq!(a.out.request_stats, b.out.request_stats);
        assert_eq!(a.out.peak_live_requests, b.out.peak_live_requests);
        assert_eq!(a.out.metrics.ttft_p99_s, b.out.metrics.ttft_p99_s);
        assert_eq!(a.out.metrics.e2e_p50_s, b.out.metrics.e2e_p50_s);
        assert_eq!(
            a.out.metrics.queue_delay_p50_s,
            b.out.metrics.queue_delay_p50_s
        );
    }
}

/// The acceptance criterion: a 1M+-request run completes with
/// O(outstanding + bins) resident state — the live map, the latency
/// sketches, and the stage bins all stay orders of magnitude below the
/// request count. The constant-time oracle is the harness's `FlatCost`
/// (this test is about memory, not physics).
#[test]
fn million_request_run_is_o_outstanding_plus_bins() {
    const N: u64 = 1_000_000;
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native; // engine never builds it: FlatCost injected
    cfg.num_requests = N;
    cfg.arrival = Arrival::Poisson { qps: 5000.0 };
    cfg.lengths = LengthDist::Fixed { total: 8 };
    cfg.seed = 0x1A96E;

    let mut source = WorkloadGenerator::from_config(&cfg).take(N);
    let mut stage_sink = StreamingSink::new(&cfg, 60.0).unwrap();
    let mut req_sink = StreamingRequestSink::new(&cfg);
    let run = sim::run_with_sinks(
        &cfg,
        &mut source,
        Box::new(FlatCost),
        &mut stage_sink,
        &mut req_sink,
    )
    .unwrap();

    assert_eq!(run.request_stats.submitted, N);
    assert_eq!(run.request_stats.finished, N);
    assert_eq!(run.request_stats.tokens_done(), N * 8);

    // O(outstanding): the live map never approached the request count.
    assert!(
        run.peak_live_requests < 50_000,
        "live map peaked at {} of {N} requests",
        run.peak_live_requests
    );
    // O(sketch): four sketches, each ≪ n tuples.
    assert!(
        req_sink.resident_tuples() < 200_000,
        "sketches hold {} tuples for {N} requests",
        req_sink.resident_tuples()
    );
    // O(bins): the stage sink folded everything into the horizon bins.
    let horizon_bins = (run.metrics.makespan_s / 60.0) as usize + 2;
    assert!(
        stage_sink.peak_resident_bins() <= horizon_bins,
        "bins {} > horizon {horizon_bins}",
        stage_sink.peak_resident_bins()
    );

    // The latency distribution is still readable off the sketches.
    assert!(run.metrics.ttft_p50_s > 0.0);
    assert!(run.metrics.e2e_p99_s >= run.metrics.e2e_p50_s);
}
