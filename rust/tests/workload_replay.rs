//! Trace replay byte-neutrality (DESIGN.md §14): exporting a workload
//! to CSV and replaying it through the streamed `ReplaySource` must be
//! invisible to every downstream consumer. Three layers of the claim:
//!
//!  1. the file format round-trips — save → load → re-save is
//!     byte-identical (arrivals print in shortest-roundtrip form, so
//!     no precision is shed on the way through),
//!  2. a simulation driven by the replayed file produces a stage log,
//!     request vector, and metrics bit-identical to one driven by the
//!     in-memory generator (the replay analogue of
//!     `stream_parity.rs`),
//!  3. malformed trace files fail loudly with `path:line:` context
//!     instead of panicking or silently truncating.
//!
//! Fixtures come from the shared harness in `tests/common`.

mod common;

use common::{read_bytes, stream_cfg, trace_for, TempDir};
use vidur_energy::config::simconfig::WorkloadKind;
use vidur_energy::sim;
use vidur_energy::workload::{self, Trace};

#[test]
fn save_load_resave_is_byte_identical() {
    let tmp = TempDir::new("vidur_energy_replay_roundtrip");
    let cfg = stream_cfg(0x9017D);
    let trace = trace_for(&cfg);

    let first = tmp.join("first.csv");
    let second = tmp.join("second.csv");
    trace.save(&first).unwrap();
    Trace::load(&first).unwrap().save(&second).unwrap();
    assert_eq!(
        read_bytes(&first),
        read_bytes(&second),
        "save → load → re-save shed precision or reordered rows"
    );
}

#[test]
fn replayed_trace_simulates_bit_identically_to_generator() {
    let tmp = TempDir::new("vidur_energy_replay_parity");
    let cfg = stream_cfg(0x2EA1);
    let trace = trace_for(&cfg);
    let path = tmp.join("trace.csv");
    trace.save(&path).unwrap();

    // Generator-driven run (the pre-replay pipeline).
    let mat = sim::run_with_trace(&cfg, trace).unwrap();

    // File-driven run through the WorkloadKind::Trace → ReplaySource
    // path. Everything but the workload source is held constant.
    let mut replay_cfg = cfg.clone();
    replay_cfg.workload = WorkloadKind::Trace {
        path: path.to_string_lossy().into_owned(),
        time_scale: 1.0,
        repeat: 1,
    };
    let rep = sim::run(&replay_cfg).unwrap();

    // Identical per-request outcomes...
    assert_eq!(mat.requests.len(), rep.requests.len());
    for (a, b) in mat.requests.iter().zip(&rep.requests) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
        assert_eq!(a.prefill_tokens, b.prefill_tokens);
        assert_eq!(a.decode_tokens, b.decode_tokens);
        assert_eq!(a.first_token_s.map(f64::to_bits), b.first_token_s.map(f64::to_bits));
        assert_eq!(a.finished_s.map(f64::to_bits), b.finished_s.map(f64::to_bits));
    }
    // ...identical metrics...
    assert_eq!(mat.metrics.makespan_s, rep.metrics.makespan_s);
    assert_eq!(mat.metrics.stage_count, rep.metrics.stage_count);
    assert_eq!(mat.metrics.weighted_mfu, rep.metrics.weighted_mfu);
    // ...and a byte-identical stage log on disk.
    let mat_csv = tmp.join("mat_stages.csv");
    let rep_csv = tmp.join("rep_stages.csv");
    mat.stagelog.save_csv(&mat_csv).unwrap();
    rep.stagelog.save_csv(&rep_csv).unwrap();
    assert_eq!(
        read_bytes(&mat_csv),
        read_bytes(&rep_csv),
        "stage CSVs diverge between generator and replay"
    );
}

#[test]
fn time_scale_and_repeat_reshape_the_stream_predictably() {
    let tmp = TempDir::new("vidur_energy_replay_knobs");
    let cfg = stream_cfg(0xD0C);
    let trace = trace_for(&cfg);
    let path = tmp.join("trace.csv");
    trace.save(&path).unwrap();
    let n = trace.requests.len();
    let span = trace.requests[n - 1].arrival_s - trace.requests[0].arrival_s;

    // Half-speed clock: the replayed span is exactly scale × original.
    let mut fast = workload::ReplaySource::open(&path, 0.25, 1).unwrap();
    let mut reqs = Vec::new();
    while let Some(r) = fast.next_request() {
        reqs.push(r);
    }
    assert_eq!(reqs.len(), n);
    let fast_span = reqs[n - 1].arrival_s - reqs[0].arrival_s;
    assert!(
        (fast_span - 0.25 * span).abs() < 1e-9 * span.max(1.0),
        "time_scale 0.25: span {fast_span} vs expected {}",
        0.25 * span
    );

    // Looping: 2 passes emit 2n requests, monotone across the seam.
    let mut looped = workload::ReplaySource::open(&path, 1.0, 2).unwrap();
    let mut lreqs = Vec::new();
    while let Some(r) = looped.next_request() {
        lreqs.push(r);
    }
    assert_eq!(lreqs.len(), 2 * n);
    for w in lreqs.windows(2) {
        assert!(w[1].arrival_s >= w[0].arrival_s, "loop seam broke monotonicity");
    }
}

#[test]
fn malformed_traces_fail_loudly_with_line_numbers() {
    let tmp = TempDir::new("vidur_energy_replay_malformed");

    // NaN arrival on data row 2 (file line 3).
    let nan = tmp.join("nan.csv");
    std::fs::write(
        &nan,
        "id,arrival_s,prefill_tokens,decode_tokens\n0,0.0,10,5\n1,NaN,10,5\n",
    )
    .unwrap();
    let err = format!("{:#}", Trace::load(&nan).unwrap_err());
    assert!(err.contains(":3:"), "no line number in: {err}");
    assert!(err.contains("non-finite"), "wrong cause in: {err}");

    // The streamed replay path reports the same class of error; driving
    // it through the engine must propagate, not truncate.
    let mut cfg = stream_cfg(0xBAD);
    cfg.workload = WorkloadKind::Trace {
        path: nan.to_string_lossy().into_owned(),
        time_scale: 1.0,
        repeat: 1,
    };
    let err = format!("{:#}", sim::run(&cfg).unwrap_err());
    assert!(err.contains(":3:"), "engine swallowed the row context: {err}");

    // Zero-token row.
    let zero = tmp.join("zero.csv");
    std::fs::write(
        &zero,
        "id,arrival_s,prefill_tokens,decode_tokens\n0,0.0,0,5\n",
    )
    .unwrap();
    let err = format!("{:#}", Trace::load(&zero).unwrap_err());
    assert!(err.contains(":2:"), "no line number in: {err}");
    assert!(err.contains("prefill_tokens"), "wrong column in: {err}");
}
