//! `repro fleet` end to end over loopback (DESIGN.md §15), with fault
//! injection.
//!
//! Three in-process `Server` instances play the fleet's hosts — same
//! TCP, same serve plane, no child processes — so the launcher code
//! path under test is exactly the one a real multi-machine launch
//! uses. The contract legs:
//!
//! 1. **Healthy fleet** — a 2-host launch auto-merges to the same
//!    bytes as an unsharded run of the same grid.
//! 2. **Fault injection** — in a 3-host launch, one host dies
//!    mid-sweep *after* writing a poisoned partial output; its shard
//!    is re-partitioned across the survivors, the launch completes,
//!    and the merged CSV is still byte-identical to the unsharded
//!    run (the partial output never leaks into the merge).
//! 3. **Dead-host detection** — an endpoint nobody listens on is
//!    health-gated out up front and only warned about.
//!
//! Everything lives in ONE test function run sequentially: the shard
//! and jobs settings are process-global (same constraint as
//! `serve_http.rs`), and the stub runners serialize on one lock.

mod common;

use common::{read_bytes, run_and_save_grid, TempDir, GRID_CASES};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;
use vidur_energy::fleet::{run_fleet, FleetConfig, Manifest};
use vidur_energy::serve::state::{SweepRequest, SweepRunner};
use vidur_energy::serve::{ServeConfig, Server};
use vidur_energy::sweep::{self, ShardSpec};
use vidur_energy::telemetry::ShardTelemetry;

/// Experiment id the stub runners produce. Dispatch itself carries a
/// real experiment id (the serve plane validates it); the runner runs
/// the deterministic test grid instead, like `serve_http.rs`.
const ID: &str = "fleetgrid";
const SEED_BASE: u64 = 0xF1EE7;

/// Serializes the stub runners across the three servers' worker
/// threads — the shard/jobs settings they configure are process-global.
static RUN_LOCK: Mutex<()> = Mutex::new(());

/// A sweep runner that honors the request's shard against the test
/// grid. With `die_once` set, the first job panics mid-sweep after
/// leaving a poisoned partial output behind — the "kill -9 between
/// two cases" a real fleet must survive.
fn shard_runner(die_once: Option<Arc<AtomicBool>>) -> SweepRunner {
    Arc::new(move |req: &SweepRequest| {
        let _g = RUN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::fs::create_dir_all(&req.out)?;
        if let Some(flag) = &die_once {
            if flag.swap(false, Ordering::SeqCst) {
                let d = req.out.join(ID);
                std::fs::create_dir_all(&d)?;
                std::fs::write(d.join(format!("{ID}.csv")), b"partial,garbage\n")?;
                panic!("host killed mid-sweep");
            }
        }
        let shard = match &req.shard {
            Some(s) => Some(ShardSpec::parse(s)?),
            None => None,
        };
        sweep::set_shard(shard);
        run_and_save_grid(&req.out, ID, SEED_BASE);
        sweep::set_shard(None);
        Ok(())
    })
}

/// Start one in-process "fleet host".
fn start_host(out: &Path, runner: SweepRunner) -> Server {
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.out = out.to_path_buf();
    cfg.runner = runner;
    cfg.poll_interval = Duration::from_millis(50);
    Server::start(cfg).unwrap()
}

/// A `FleetConfig` tuned for loopback: tight polls, short backoff.
fn fleet_cfg(endpoints: Vec<String>, out: PathBuf, merged_out: PathBuf) -> FleetConfig {
    let manifest = Manifest::from_entries(&endpoints).unwrap();
    let mut cfg = FleetConfig::new("exp1", manifest, &out);
    cfg.merged_out = merged_out;
    cfg.poll = Duration::from_millis(50);
    cfg.http_timeout = Duration::from_secs(10);
    cfg.max_attempts = 3;
    cfg.backoff_base = Duration::from_millis(20);
    cfg
}

#[test]
fn fleet_launcher_survives_host_death_with_byte_identical_merge() {
    let base = TempDir::new("vidur_fleet_launcher");
    sweep::set_shard(None);
    sweep::set_default_jobs(2);

    // --- Unsharded baseline: the bytes every launch must reproduce --
    let baseline = base.join("baseline");
    run_and_save_grid(&baseline, ID, SEED_BASE);
    let want_csv = read_bytes(baseline.join(ID).join(format!("{ID}.csv")));
    let want_tel = ShardTelemetry::load(&baseline.join(ID)).unwrap().unwrap();

    // --- Leg 1: healthy 2-host fleet merges byte-identically --------
    {
        let a = start_host(&base.join("h2-a"), shard_runner(None));
        let b = start_host(&base.join("h2-b"), shard_runner(None));
        let cfg = fleet_cfg(
            vec![a.addr().to_string(), b.addr().to_string()],
            base.join("fleet2"),
            base.join("merged2"),
        );
        let report = run_fleet(&cfg).unwrap();
        assert_eq!(report.hosts, 2);
        assert!(report.dead.is_empty(), "healthy fleet: {:?}", report.dead);
        assert_eq!(report.dispatched, 2);
        assert_eq!(report.resharded, 0);
        assert_eq!(report.merged.len(), 1);
        assert_eq!(report.merged[0].id, ID);
        assert_eq!(report.merged[0].shards, 2);
        assert_eq!(report.merged[0].rows, GRID_CASES);
        assert!(report.merged[0].complete);
        let got = read_bytes(base.join("merged2").join(ID).join(format!("{ID}.csv")));
        assert_eq!(
            got, want_csv,
            "2-host fleet merge must be byte-identical to the unsharded run"
        );
        a.shutdown();
        b.shutdown();
    }

    // --- Legs 2+3: one dead endpoint, one mid-sweep death -----------
    {
        let a = start_host(&base.join("h3-a"), shard_runner(None));
        let b = start_host(&base.join("h3-b"), shard_runner(None));
        let die = Arc::new(AtomicBool::new(true));
        let c = start_host(&base.join("h3-c"), shard_runner(Some(Arc::clone(&die))));
        // Nobody listens on port 1: the health gate must exclude it
        // up front instead of sinking a shard into it.
        let unreachable = "127.0.0.1:1".to_string();
        let cfg = fleet_cfg(
            vec![
                a.addr().to_string(),
                b.addr().to_string(),
                c.addr().to_string(),
                unreachable.clone(),
            ],
            base.join("fleet3"),
            base.join("merged3"),
        );
        let report = run_fleet(&cfg).unwrap();

        // The unreachable endpoint never joined; C died mid-sweep.
        assert_eq!(report.hosts, 3, "three hosts pass the health gate");
        assert_eq!(report.dead.len(), 2, "dead: {:?}", report.dead);
        assert!(report.dead.contains(&unreachable));
        assert!(report.dead.contains(&c.addr().to_string()));
        assert!(!die.load(Ordering::SeqCst), "C's runner ran");

        // C's one shard (of 3) was re-partitioned across 2 survivors:
        // 3 initial dispatches + 2 sub-shard re-dispatches.
        assert_eq!(report.resharded, 1);
        assert_eq!(report.dispatched, 5);

        // The merge covers the full grid exactly once — the two
        // sub-shards have a different denominator (k/6) than the
        // survivors' originals (k/3), and C's poisoned partial CSV
        // is excluded because its job never reported done.
        assert_eq!(report.merged.len(), 1);
        assert_eq!(report.merged[0].shards, 4, "2 originals + 2 sub-shards");
        assert_eq!(report.merged[0].rows, GRID_CASES);
        assert!(report.merged[0].complete);
        let got = read_bytes(base.join("merged3").join(ID).join(format!("{ID}.csv")));
        assert_eq!(
            got, want_csv,
            "post-death fleet merge must be byte-identical to the unsharded run"
        );
        // Exact-counter telemetry agreement, like shard_merge.rs.
        let tel = ShardTelemetry::load(&base.join("merged3").join(ID))
            .unwrap()
            .unwrap();
        assert_eq!(tel.shard, None);
        assert_eq!(tel.requests.submitted, want_tel.requests.submitted);
        assert_eq!(tel.requests.finished, want_tel.requests.finished);
        assert_eq!(tel.stages.stages, want_tel.stages.stages);
        a.shutdown();
        b.shutdown();
        c.shutdown();
    }

    // --- No survivors: the launcher fails loudly, not silently ------
    {
        let die = Arc::new(AtomicBool::new(true));
        let only = start_host(&base.join("h1-solo"), shard_runner(Some(die)));
        let cfg = fleet_cfg(
            vec![only.addr().to_string()],
            base.join("fleet1"),
            base.join("merged1"),
        );
        let err = run_fleet(&cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("no survivors") || msg.contains("no surviving"),
            "lost-everything launch must say so: {msg}"
        );
        only.shutdown();
    }
}

/// Manifest errors reach the user with file + line, and a launch with
/// an empty manifest refuses to start.
#[test]
fn fleet_manifest_errors_are_loud() {
    let base = TempDir::new("vidur_fleet_manifest");
    let path = base.join("hosts.txt");
    std::fs::write(&path, "127.0.0.1:7878\nlocal:oops\n").unwrap();
    let err = Manifest::load(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("hosts.txt:2") && msg.contains("local"),
        "manifest error must cite path:line: {msg}"
    );

    let empty = Manifest::default();
    let cfg = FleetConfig::new("exp1", empty, &base.join("out"));
    let err = run_fleet(&cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("names no hosts"),
        "{err:#}"
    );
}
