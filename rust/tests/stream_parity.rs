//! Streaming-sink parity: the O(bins) `StreamingSink` must reproduce
//! the materialized `StageLog` path *exactly* — same Eq. 5 binned
//! profile, same weighted MFU / busy GPU-seconds, same accounted
//! energy — on both fixed-fleet and autoscaled runs. Exactness (not
//! tolerance) is the contract: both paths run the same accumulation
//! code in the same record order, so any drift is a real divergence.
//!
//! Plus the memory claim behind the refactor: the sink's peak resident
//! state is O(bins), not O(stages).
//!
//! Fixtures come from the shared harness in `tests/common`.

mod common;

use common::{assert_energy_reports_identical, stream_cfg, trace_for};
use vidur_energy::autoscale::GridEnv;
use vidur_energy::config::simconfig::{AutoscaleConfig, ScalingPolicyKind, SimConfig};
use vidur_energy::energy::EnergyAccountant;
use vidur_energy::exec::build_cost_model;
use vidur_energy::pipeline::{bin_stages, bin_stages_fleet, BinningBackend};
use vidur_energy::sim;
use vidur_energy::telemetry::StreamingSink;

const INTERVAL_S: f64 = 10.0;

fn base_cfg() -> SimConfig {
    stream_cfg(0x57E4)
}

#[test]
fn streaming_matches_materialized_on_fixed_fleet() {
    let mut cfg = base_cfg();
    cfg.replicas = 2;
    let trace = trace_for(&cfg);

    let mat = sim::run_with_trace(&cfg, trace.clone()).unwrap();

    let acc = EnergyAccountant::paper_default(&cfg).unwrap();
    let mut sink = StreamingSink::with_model(&cfg, INTERVAL_S, acc.power_model).unwrap();
    let cost = build_cost_model(&cfg).unwrap();
    let run = sim::run_with_sink(&cfg, trace, cost, &mut sink).unwrap();

    // Identical simulation.
    assert_eq!(mat.metrics.makespan_s, run.metrics.makespan_s);
    assert_eq!(mat.metrics.stage_count, run.metrics.stage_count);
    assert!(mat.metrics.stage_count > 0);

    // Identical stage aggregates.
    assert_eq!(mat.metrics.weighted_mfu, run.metrics.weighted_mfu);
    assert_eq!(mat.metrics.mean_batch_size, run.metrics.mean_batch_size);
    assert_eq!(mat.stagelog.busy_gpu_seconds(), run.stage_stats.busy_gpu_s);
    assert_eq!(mat.stagelog.span(), run.stage_stats.span);

    // Identical Eq. 5 binned profile.
    let mat_prof = bin_stages(
        &cfg,
        &mat.stagelog,
        mat.metrics.makespan_s,
        INTERVAL_S,
        BinningBackend::Native,
    )
    .unwrap();
    let str_prof = sink.binned_span(&cfg, run.metrics.makespan_s).unwrap();
    assert_eq!(mat_prof.power_w, str_prof.power_w);
    assert_eq!(mat_prof.covered_s, str_prof.covered_s);

    // Identical accounted energy.
    let mat_rep = acc.account(&cfg, &mat.stagelog, mat.metrics.makespan_s);
    let str_rep = acc.report(&cfg, sink.aggregates(), run.metrics.makespan_s);
    assert_energy_reports_identical(&mat_rep, &str_rep);

    // The memory claim: resident bins ≪ resident stage records.
    let bins = sink.peak_resident_bins() as u64;
    assert!(
        bins <= (run.metrics.makespan_s / INTERVAL_S) as u64 + 1,
        "sink grew past the horizon: {bins} bins"
    );
    assert!(
        bins * 10 < mat.metrics.stage_count,
        "O(bins) claim violated: {bins} bins vs {} stages",
        mat.metrics.stage_count
    );
}

#[test]
fn streaming_matches_materialized_on_autoscaled_run() {
    let mut cfg = base_cfg();
    cfg.replicas = 2;
    cfg.batch_cap = 16; // force queues so the fleet really moves
    let trace = trace_for(&cfg);

    let mut scale = AutoscaleConfig::default();
    scale.policy = ScalingPolicyKind::Reactive;
    scale.min_replicas = 1;
    scale.max_replicas = 4;
    scale.decision_interval_s = 10.0;
    scale.cold_start_s = 5.0;
    scale.queue_high = 4.0;

    let mat = sim::run_autoscaled(&cfg, &scale, &GridEnv::constant(150.0, 0.0), trace.clone())
        .unwrap();

    let acc = EnergyAccountant::paper_default(&cfg).unwrap();
    let mut sink = StreamingSink::with_model(&cfg, INTERVAL_S, acc.power_model).unwrap();
    let run = sim::run_autoscaled_streaming(
        &cfg,
        &scale,
        &GridEnv::constant(150.0, 0.0),
        trace,
        &mut sink,
    )
    .unwrap();

    assert_eq!(mat.sim.metrics.makespan_s, run.sim.metrics.makespan_s);
    assert_eq!(mat.sim.metrics.stage_count, run.sim.metrics.stage_count);
    assert_eq!(mat.timeline.events.len(), run.timeline.events.len());
    assert_eq!(mat.timeline.horizon_s, run.timeline.horizon_s);
    assert_eq!(mat.decisions.len(), run.decisions.len());

    // Fleet-aware accounting parity.
    let mat_rep = acc.account_fleet(&cfg, &mat.sim.stagelog, &mat.timeline);
    let str_rep = acc.report_fleet(&cfg, sink.aggregates(), &run.timeline);
    assert_energy_reports_identical(&mat_rep, &str_rep);

    // Fleet-aware Eq. 5 parity.
    let mat_prof = bin_stages_fleet(
        &cfg,
        &mat.sim.stagelog,
        &mat.timeline,
        INTERVAL_S,
        BinningBackend::Native,
    )
    .unwrap();
    let str_prof = sink.binned(&cfg, &run.timeline).unwrap();
    assert_eq!(mat_prof.power_w, str_prof.power_w);
    assert_eq!(mat_prof.covered_s, str_prof.covered_s);
}
