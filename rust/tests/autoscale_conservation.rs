//! Integration tests for the autoscaling subsystem's energy contract:
//! under a dynamic fleet, per-stage energy plus idle energy of *live*
//! replicas must equal the binned (Eq. 5) demand signal the
//! co-simulation consumes — and that signal's energy must survive the
//! microgrid unchanged. Plus the end-to-end policy property the
//! experiment claims: carbon-aware scaling emits less than the static
//! fleet at equal-or-better SLO attainment.

use vidur_energy::autoscale::GridEnv;
use vidur_energy::config::simconfig::{
    Arrival, AutoscaleConfig, CosimConfig, CostModelKind, LengthDist, ScalingPolicyKind,
    SimConfig,
};
use vidur_energy::cosim::Environment;
use vidur_energy::energy::EnergyAccountant;
use vidur_energy::pipeline::{bin_stages_fleet, BinningBackend, LoadProfile};
use vidur_energy::sim;
use vidur_energy::workload::{Trace, WorkloadGenerator};

fn bursty_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.num_requests = 800;
    cfg.arrival = Arrival::Gamma { qps: 25.0, cv: 3.0 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 64,
        max: 768,
    };
    cfg.batch_cap = 16; // force queues so the fleet really moves
    cfg.seed = 0xC0;
    cfg
}

fn dynamic_scale() -> AutoscaleConfig {
    let mut s = AutoscaleConfig::default();
    s.min_replicas = 1;
    s.max_replicas = 4;
    s.decision_interval_s = 10.0;
    s.cold_start_s = 5.0;
    s.queue_high = 4.0;
    s
}

#[test]
fn energy_conservation_under_dynamic_fleet() {
    let cfg = bursty_cfg();
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));
    let mut s = dynamic_scale();
    s.policy = ScalingPolicyKind::Reactive;
    // Alternating grid so the fleet both grows and sheds.
    let grid = GridEnv::from_fns(
        100.0,
        200.0,
        600.0,
        0.0,
        |t| if (t / 30.0) as u64 % 2 == 0 { 250.0 } else { 80.0 },
        |_| 0.0,
    );
    let out = sim::run_autoscaled(&cfg, &s, &grid, trace).unwrap();
    assert!(out.sim.requests.iter().all(|r| r.is_finished()));
    assert!(
        out.timeline.max_fleet() > 1,
        "scenario must actually scale: {:?}",
        out.decisions
    );

    // 1. Fleet-aware accounting == fleet-aware Eq. 5 binning (the
    //    per-stage + live-idle energy identity), within the binning
    //    boundary tolerance.
    let acc = EnergyAccountant::paper_default(&cfg).unwrap();
    let energy = acc.account_fleet(&cfg, &out.sim.stagelog, &out.timeline);
    let binned = bin_stages_fleet(
        &cfg,
        &out.sim.stagelog,
        &out.timeline,
        10.0,
        BinningBackend::Native,
    )
    .unwrap();
    let profile = LoadProfile::from_binned(&binned);
    let rel = (profile.total_energy_kwh() - energy.gpu_energy_kwh).abs()
        / energy.gpu_energy_kwh;
    assert!(
        rel < 0.02,
        "binned {} kWh vs accounted {} kWh (rel {rel})",
        profile.total_energy_kwh(),
        energy.gpu_energy_kwh
    );

    // 2. The cosim side consumes exactly that demand signal: total
    //    microgrid load energy == profile energy.
    let n = profile.len();
    let mut env = Environment::new(CosimConfig {
        interval_s: 10.0,
        ..CosimConfig::default()
    });
    let res = env
        .run_native(&profile.power_w, &vec![0.0; n], &vec![418.2; n])
        .unwrap();
    let rel2 = (res.total_energy_kwh - profile.total_energy_kwh()).abs()
        / profile.total_energy_kwh();
    assert!(
        rel2 < 1e-9,
        "cosim demand {} kWh vs profile {} kWh",
        res.total_energy_kwh,
        profile.total_energy_kwh()
    );

    // 3. Sanity: a static fleet of max size must cost at least as much
    //    GPU-time as the dynamic one.
    assert!(
        energy.gpu_hours
            <= s.max_replicas as f64 * out.timeline.horizon_s / 3600.0 + 1e-9
    );
}

#[test]
fn consolidation_saves_idle_energy_vs_static_fleet() {
    // Light steady load on a 3-replica fleet: the reactive policy
    // consolidates to one replica and the saved idle power must show
    // up in the fleet-aware accounting.
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.replicas = 3;
    cfg.num_requests = 600;
    cfg.arrival = Arrival::Poisson { qps: 2.0 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 128,
        max: 512,
    };
    cfg.seed = 0x1D1E;
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));

    let mut s = dynamic_scale();
    s.policy = ScalingPolicyKind::Reactive;
    let grid = GridEnv::constant(150.0, 0.0);
    let out = sim::run_autoscaled(&cfg, &s, &grid, trace.clone()).unwrap();
    assert!(out.sim.requests.iter().all(|r| r.is_finished()));
    assert!(
        out.timeline.mean_fleet() < 2.0,
        "light load should consolidate, mean fleet {}",
        out.timeline.mean_fleet()
    );
    let acc = EnergyAccountant::paper_default(&cfg).unwrap();
    let dynamic_kwh = acc
        .account_fleet(&cfg, &out.sim.stagelog, &out.timeline)
        .energy_kwh;

    let st = sim::run_with_trace(&cfg, trace).unwrap();
    let static_kwh = acc
        .account(&cfg, &st.stagelog, st.metrics.makespan_s)
        .energy_kwh;
    assert!(
        dynamic_kwh < 0.8 * static_kwh,
        "dynamic {dynamic_kwh} kWh !<< static-3 {static_kwh} kWh"
    );
}

#[test]
fn carbon_aware_cuts_emissions_at_equal_or_better_slo() {
    // The experiment's acceptance property on a controlled scenario:
    // modest steady load, 3-replica static baseline, dirty-then-clean
    // grid. Carbon-aware must emit less at equal-or-better attainment.
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.replicas = 3;
    cfg.num_requests = 1_200;
    cfg.arrival = Arrival::Poisson { qps: 2.0 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 128,
        max: 512,
    };
    cfg.seed = 0x51;
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));
    let span = trace.arrival_span_s();
    let switch = span * 0.6;
    let ci_at = move |t: f64| if t < switch { 480.0 } else { 70.0 };

    let run_policy = |policy: ScalingPolicyKind| {
        let mut s = AutoscaleConfig::default();
        s.policy = policy;
        s.decision_interval_s = 60.0;
        s.cold_start_s = 30.0;
        let grid = GridEnv::from_fns(100.0, 200.0, 600.0, 0.0, ci_at, |_| 0.0);
        let out = sim::run_autoscaled(&cfg, &s, &grid, trace.clone()).unwrap();
        assert!(out.sim.requests.iter().all(|r| r.is_finished()));
        let binned = bin_stages_fleet(
            &cfg,
            &out.sim.stagelog,
            &out.timeline,
            60.0,
            BinningBackend::Native,
        )
        .unwrap();
        let profile = LoadProfile::from_binned(&binned);
        let n = profile.len();
        let ci: Vec<f64> = (0..n).map(|i| ci_at(i as f64 * 60.0)).collect();
        let mut env = Environment::new(CosimConfig::default());
        let res = env
            .run_native(&profile.power_w, &vec![0.0; n], &ci)
            .unwrap();
        (
            res.net_footprint_g,
            out.sim.metrics.slo_attained,
            out.timeline.mean_fleet(),
        )
    };

    let (static_g, static_slo, static_fleet) = run_policy(ScalingPolicyKind::Static);
    let (carbon_g, carbon_slo, carbon_fleet) =
        run_policy(ScalingPolicyKind::CarbonAware);

    assert!((static_fleet - 3.0).abs() < 1e-9);
    assert!(carbon_fleet < static_fleet, "carbon never shed");
    assert!(
        carbon_g < 0.95 * static_g,
        "carbon {carbon_g} g !< static {static_g} g"
    );
    assert!(
        carbon_slo >= static_slo - 0.05,
        "SLO regressed: {carbon_slo} vs {static_slo}"
    );
}

#[test]
fn drained_work_is_conserved_under_aggressive_scaling() {
    // Thrash the fleet (tiny interval, dirty/clean flip every 20 s,
    // carbon policy oscillating between min and the 3-replica
    // baseline): every request must still finish exactly once.
    let mut cfg = bursty_cfg();
    cfg.replicas = 3;
    cfg.num_requests = 500;
    cfg.arrival = Arrival::Poisson { qps: 5.0 };
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));
    let mut s = dynamic_scale();
    s.policy = ScalingPolicyKind::CarbonAware;
    s.decision_interval_s = 5.0;
    s.cold_start_s = 1.0;
    let grid = GridEnv::from_fns(
        100.0,
        200.0,
        600.0,
        0.0,
        |t| if (t / 20.0) as u64 % 2 == 0 { 500.0 } else { 50.0 },
        |_| 0.0,
    );
    let out = sim::run_autoscaled(&cfg, &s, &grid, trace).unwrap();
    assert_eq!(out.sim.requests.len(), 500);
    assert!(out.sim.requests.iter().all(|r| r.is_finished()));
    let (ups, downs) = out.timeline.scale_event_counts();
    assert!(ups > 0 && downs > 0, "scenario must thrash: {ups} ups {downs} downs");
    // Lifecycle order per span: up <= online <= drain <= down.
    for sp in &out.timeline.spans {
        if let Some(on) = sp.online_s {
            assert!(on >= sp.up_s);
        }
        if let (Some(d), Some(down)) = (sp.drain_s, sp.down_s) {
            assert!(down >= d);
        }
    }
}
