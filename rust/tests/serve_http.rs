//! `repro serve` end to end over a loopback socket (DESIGN.md §11).
//!
//! The serve plane's contract has three legs, all asserted here with
//! nothing but `std::net::TcpStream` (no curl, no client crate):
//!
//! 1. **Observation only** — a sweep hosted through `POST /v1/sweeps`
//!    persists byte-identical artifacts (`<id>.csv`, `meta.json`,
//!    `telemetry.json`) to the same grid run without any server.
//! 2. **Totals agree** — `/v1/fleet` over a followed watch log reports
//!    the same finished/stages totals as the `telemetry.json` sidecar
//!    the watched run persisted (i.e. the same aggregation `repro
//!    watch` performs), and the final SSE snapshots sum to the same.
//! 3. **Hostile input is survivable** — garbage bytes, bogus paths,
//!    wrong methods and malformed bodies get well-formed 4xx answers
//!    and the server keeps serving.
//!
//! Everything lives in ONE test function run sequentially: the watch,
//! shard, and jobs settings are process-global (same constraint as
//! `watch_observer.rs`).

mod common;

use common::{read_bytes, run_and_save_grid, TempDir, GRID_CASES};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};
use vidur_energy::report::live::{self, WatchConfig, WatchTarget};
use vidur_energy::serve::state::{SweepRequest, SweepRunner};
use vidur_energy::serve::{ServeConfig, Server};
use vidur_energy::sweep;
use vidur_energy::telemetry::window::Snapshot;
use vidur_energy::telemetry::ShardTelemetry;
use vidur_energy::util::json::{parse, Value};

/// Followed (pre-recorded) watch-log experiment.
const ID: &str = "servegrid";
/// Experiment id the injected sweep runner produces.
const HOSTED_ID: &str = "servehosted";
const SEED_BASE: u64 = 0x5E12;

fn watch_json(path: &Path) -> Option<WatchConfig> {
    Some(WatchConfig {
        target: WatchTarget::Json(path.to_path_buf()),
        cadence_s: 20.0, // several intermediate snapshots per case
        window_s: 100.0,
    })
}

/// The three persisted outputs of one grid run.
fn output_bytes(dir: &Path, id: &str) -> (Vec<u8>, Vec<u8>, Vec<u8>) {
    (
        read_bytes(dir.join(id).join(format!("{id}.csv"))),
        read_bytes(dir.join(id).join("meta.json")),
        read_bytes(dir.join(id).join("telemetry.json")),
    )
}

/// One HTTP/1.1 exchange over a fresh connection. Returns
/// (status, head text, body text).
fn http_request(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: test\r\n");
    if let Some(b) = body {
        req.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    stream.write_all(req.as_bytes()).unwrap();
    read_response(&mut stream)
}

/// Read one Content-Length-framed response off the stream.
fn read_response(stream: &mut TcpStream) -> (u16, String, String) {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&buf[..pos]).to_string();
            let cl: usize = head
                .lines()
                .find_map(|l| {
                    l.to_ascii_lowercase()
                        .strip_prefix("content-length:")
                        .map(|v| v.trim().parse().unwrap())
                })
                .unwrap_or(0);
            let body_start = pos + 4;
            while buf.len() < body_start + cl {
                let n = stream.read(&mut chunk).expect("reading response body");
                assert!(n > 0, "connection closed mid-body");
                buf.extend_from_slice(&chunk[..n]);
            }
            let status: u16 = head
                .split_whitespace()
                .nth(1)
                .unwrap_or_else(|| panic!("bad status line in {head:?}"))
                .parse()
                .unwrap();
            let body = String::from_utf8_lossy(&buf[body_start..body_start + cl]).to_string();
            return (status, head, body);
        }
        let n = stream.read(&mut chunk).expect("reading response head");
        assert!(n > 0, "connection closed before response head");
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// GET returning parsed JSON.
fn get_json(addr: &str, path: &str) -> (u16, Value) {
    let (status, _, body) = http_request(addr, "GET", path, None);
    let v = parse(&body).unwrap_or_else(|e| panic!("GET {path}: bad json body {body:?}: {e}"));
    (status, v)
}

/// Poll `f` until it returns Some or the deadline passes.
fn poll_until<T>(what: &str, timeout: Duration, mut f: impl FnMut() -> Option<T>) -> T {
    let start = Instant::now();
    loop {
        if let Some(v) = f() {
            return v;
        }
        assert!(start.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Find one experiment's aggregate in a `/v1/fleet` body.
fn fleet_exp(v: &Value, id: &str) -> Option<Value> {
    v.get("experiments")?
        .as_arr()?
        .iter()
        .find(|e| e.req_str("experiment").ok() == Some(id))
        .cloned()
}

#[test]
fn serve_is_observation_only_and_mirrors_the_telemetry_plane() {
    let base = TempDir::new("vidur_energy_serve_http");
    sweep::set_shard(None);
    live::set_watch(None);
    // Pin the worker count: meta.json records it, so the plain and
    // served runs must agree for byte parity.
    sweep::set_default_jobs(2);

    // --- Baselines: plain runs of both grids, no watch, no server --
    let plain_dir = base.join("plain");
    run_and_save_grid(&plain_dir, ID, SEED_BASE);
    let plain_hosted_dir = base.join("plain_hosted");
    run_and_save_grid(&plain_hosted_dir, HOSTED_ID, SEED_BASE);

    // --- A watched run producing the log the server will follow ----
    let watched_dir = base.join("watched");
    let log = watched_dir.join("watch.jsonl");
    live::set_watch(watch_json(&log));
    run_and_save_grid(&watched_dir, ID, SEED_BASE);
    live::set_watch(None);
    // Watching is byte-neutral (the §10 contract the serve plane
    // builds on).
    assert_eq!(output_bytes(&plain_dir, ID), output_bytes(&watched_dir, ID));
    let sidecar = ShardTelemetry::load(&watched_dir.join(ID)).unwrap().unwrap();

    // --- Start the server: follow the watched dir, host sweeps -----
    let serve_out = base.join("serve-out");
    let runner: SweepRunner = Arc::new(move |req: &SweepRequest| {
        // The default runner shape (state::default_runner) against the
        // test grid instead of a real experiment: configure the
        // process-global jobs + watch, run, restore.
        std::fs::create_dir_all(&req.out)?;
        sweep::set_default_jobs(req.jobs);
        let mut watch = WatchConfig::stderr();
        watch.target = WatchTarget::Json(req.out.join("watch.jsonl"));
        watch.cadence_s = 20.0;
        watch.window_s = 100.0;
        live::set_watch(Some(watch));
        run_and_save_grid(&req.out, HOSTED_ID, SEED_BASE);
        live::set_watch(None);
        sweep::set_default_jobs(2);
        Ok(())
    });
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.follow = vec![watched_dir.clone()];
    cfg.out = serve_out.clone();
    cfg.runner = runner;
    cfg.poll_interval = Duration::from_millis(50);
    cfg.keepalive = Duration::from_millis(500);
    let server = Server::start(cfg).unwrap();
    let addr = server.addr().to_string();

    // --- /healthz: build identity ----------------------------------
    let (status, health) = get_json(&addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(health.req_str("format").unwrap(), "vidur-energy/serve/v1");
    assert_eq!(health.req_str("status").unwrap(), "ok");
    assert_eq!(
        health.req_str("version").unwrap(),
        vidur_energy::util::version::CRATE_VERSION
    );
    assert!(health
        .req_str("version_string")
        .unwrap()
        .starts_with(vidur_energy::util::version::CRATE_VERSION));

    // --- /v1/fleet converges on the followed log's totals ----------
    let fleet = poll_until("fleet to ingest the watch log", Duration::from_secs(30), || {
        let (status, v) = get_json(&addr, "/v1/fleet");
        assert_eq!(status, 200);
        let exp = fleet_exp(&v, ID)?;
        (exp.req_u64("cases_done").ok()? == GRID_CASES as u64).then_some(exp)
    });
    assert_eq!(fleet.req_u64("cases_total").unwrap(), GRID_CASES as u64);
    assert_eq!(fleet.req_u64("finished").unwrap(), sidecar.requests.finished);
    assert_eq!(fleet.req_u64("stages").unwrap(), sidecar.stages.stages);
    // Same numbers `repro watch` computes from the same log.
    let watch_aggs = live::aggregate(&live::read_snapshots(&log).unwrap());
    assert_eq!(watch_aggs.len(), 1);
    assert_eq!(watch_aggs[0].finished, sidecar.requests.finished);
    assert_eq!(watch_aggs[0].stages, sidecar.stages.stages);

    // --- SSE stream: full replay sums to the sidecar too ------------
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .unwrap();
        stream
            .write_all(b"GET /v1/snapshots?last_event_id=0 HTTP/1.1\r\nHost: t\r\n\r\n")
            .unwrap();
        let mut text = String::new();
        let mut chunk = [0u8; 4096];
        let mut done_cases: BTreeMap<u64, Snapshot> = BTreeMap::new();
        let start = Instant::now();
        while done_cases.len() < GRID_CASES {
            assert!(
                start.elapsed() < Duration::from_secs(30),
                "SSE replay incomplete: {} of {GRID_CASES} done cases",
                done_cases.len()
            );
            let n = stream.read(&mut chunk).expect("reading SSE stream");
            assert!(n > 0, "SSE stream closed early");
            text.push_str(&String::from_utf8_lossy(&chunk[..n]));
            // Parse complete frames (terminated by a blank line) off
            // the front; keep the torn tail for the next read.
            while let Some(end) = text.find("\n\n") {
                let frame: String = text[..end].to_string();
                text.drain(..end + 2);
                let data: String = frame
                    .lines()
                    .filter_map(|l| l.strip_prefix("data: "))
                    .collect::<Vec<_>>()
                    .join("\n");
                if data.is_empty() {
                    continue; // keep-alive comment
                }
                let s = Snapshot::from_json(&parse(&data).unwrap()).unwrap();
                if s.experiment == ID && s.done {
                    done_cases.insert(s.case_index, s);
                }
            }
        }
        let finished: u64 = done_cases.values().map(|s| s.finished).sum();
        let stages: u64 = done_cases.values().map(|s| s.stages).sum();
        assert_eq!(finished, sidecar.requests.finished, "SSE totals vs sidecar");
        assert_eq!(stages, sidecar.stages.stages, "SSE totals vs sidecar");
    }

    // --- Hostile input: 4xx answers, server stays up ----------------
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .unwrap();
        stream.write_all(b"COMPLETE GARBAGE\r\n\r\n").unwrap();
        let (status, _, body) = read_response(&mut stream);
        assert_eq!(status, 400, "{body}");
        assert!(parse(&body).unwrap().get("error").is_some());
    }
    let (status, _, _) = http_request(&addr, "GET", "/no/such/endpoint", None);
    assert_eq!(status, 404);
    let (status, head, _) = http_request(&addr, "DELETE", "/healthz", None);
    assert_eq!(status, 405);
    assert!(head.contains("Allow: GET"), "{head}");
    let (status, _, _) = http_request(&addr, "POST", "/v1/sweeps", Some("not json"));
    assert_eq!(status, 400);
    let (status, _, body) =
        http_request(&addr, "POST", "/v1/sweeps", Some(r#"{"experiment": "nope"}"#));
    assert_eq!(status, 400);
    assert!(body.contains("unknown experiment"), "{body}");
    let (status, _, _) = http_request(&addr, "GET", "/v1/sweeps/999", None);
    assert_eq!(status, 404);
    // Still alive after all of that.
    assert_eq!(get_json(&addr, "/healthz").0, 200);

    // --- Hosted sweep: submit, await, byte-compare ------------------
    let (status, _, body) = http_request(
        &addr,
        "POST",
        "/v1/sweeps",
        Some(r#"{"experiment": "exp1", "jobs": 2}"#),
    );
    assert_eq!(status, 202, "{body}");
    let job = parse(&body).unwrap();
    let job_id = job.req_u64("id").unwrap();
    assert_eq!(job.req_str("status").unwrap(), "queued");
    let job_out = std::path::PathBuf::from(job.req_str("out").unwrap());
    assert_eq!(job_out, serve_out.join(format!("sweep-{job_id}")));

    let final_status = poll_until("hosted sweep to finish", Duration::from_secs(120), || {
        let (status, v) = get_json(&addr, &format!("/v1/sweeps/{job_id}"));
        assert_eq!(status, 200);
        let s = v.req_str("status").unwrap().to_string();
        (s == "done" || s == "failed").then_some(s)
    });
    assert_eq!(final_status, "done");
    // The hosted run's artifacts are byte-identical to the plain run's:
    // serving (and the live broadcast it implies) changed nothing.
    assert_eq!(
        output_bytes(&plain_hosted_dir, HOSTED_ID),
        output_bytes(&job_out, HOSTED_ID),
        "hosted sweep artifacts differ from the unserved run"
    );
    // Its snapshots were broadcast in process: the fleet now reports
    // the hosted experiment complete, with totals matching *its*
    // sidecar.
    let hosted_sidecar = ShardTelemetry::load(&job_out.join(HOSTED_ID)).unwrap().unwrap();
    let (status, fleet_now) = get_json(&addr, "/v1/fleet");
    assert_eq!(status, 200);
    let hosted = fleet_exp(&fleet_now, HOSTED_ID).expect("hosted experiment in fleet");
    assert_eq!(hosted.req_u64("cases_done").unwrap(), GRID_CASES as u64);
    assert_eq!(
        hosted.req_u64("finished").unwrap(),
        hosted_sidecar.requests.finished
    );
    assert_eq!(hosted.req_u64("stages").unwrap(), hosted_sidecar.stages.stages);
    // The sweep list knows the job too.
    let (_, sweeps) = get_json(&addr, "/v1/sweeps");
    assert_eq!(
        sweeps.get("sweeps").and_then(|s| s.as_arr()).unwrap().len(),
        1
    );

    server.shutdown();
    sweep::set_default_jobs(0);
    live::set_watch(None);
}
