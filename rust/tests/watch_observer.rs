//! Live-watch observer parity and aggregation (DESIGN.md §10).
//!
//! The whole point of the fan-out design is that *watching a sweep
//! cannot change it*: with `--watch` enabled every case streams
//! rolling-window snapshots to the live view, while the primary sinks
//! — and therefore every persisted output — remain byte-identical to
//! an unobserved run. This file asserts that end to end, for both
//! `--jobs 1` and `--jobs 8`, then checks the snapshot log itself
//! (well-formed, monotone per case, totals equal to the
//! `telemetry.json` sidecar) and the `repro watch` aggregation across
//! two sharded watch logs.
//!
//! Everything lives in ONE test function run sequentially: the watch,
//! shard, and jobs settings are process-global.

mod common;

use common::{read_bytes, run_and_save_grid, TempDir, GRID_CASES};
use std::collections::BTreeMap;
use std::path::Path;
use vidur_energy::report::live::{
    self, aggregate, discover_watch_files, read_snapshots, render_watch, WatchConfig,
    WatchTarget,
};
use vidur_energy::sweep::{self, ShardSpec};
use vidur_energy::telemetry::window::Snapshot;
use vidur_energy::telemetry::ShardTelemetry;

const ID: &str = "watchgrid";
const SEED_BASE: u64 = 0x3A7C;

fn watch_json(path: &Path) -> Option<WatchConfig> {
    Some(WatchConfig {
        target: WatchTarget::Json(path.to_path_buf()),
        cadence_s: 20.0, // several intermediate snapshots per case
        window_s: 100.0,
    })
}

/// The three persisted outputs of one grid run.
fn output_bytes(dir: &Path) -> (Vec<u8>, Vec<u8>, Vec<u8>) {
    (
        read_bytes(dir.join(ID).join(format!("{ID}.csv"))),
        read_bytes(dir.join(ID).join("meta.json")),
        read_bytes(dir.join(ID).join("telemetry.json")),
    )
}

#[test]
fn watching_never_changes_outputs_and_snapshots_aggregate_correctly() {
    let base = TempDir::new("vidur_energy_watch_observer");
    sweep::set_shard(None);
    live::set_watch(None);

    // --- Observer parity, --jobs 1 and --jobs 8 -------------------
    let mut watched_outputs = Vec::new();
    for jobs in [1usize, 8] {
        sweep::set_default_jobs(jobs);
        live::set_watch(None);
        let plain_dir = base.join(format!("plain{jobs}"));
        run_and_save_grid(&plain_dir, ID, SEED_BASE);

        let watched_dir = base.join(format!("watched{jobs}"));
        let log = watched_dir.join("watch.jsonl");
        live::set_watch(watch_json(&log));
        run_and_save_grid(&watched_dir, ID, SEED_BASE);
        live::set_watch(None);

        let plain = output_bytes(&plain_dir);
        let watched = output_bytes(&watched_dir);
        assert_eq!(plain.0, watched.0, "jobs={jobs}: CSV changed under --watch");
        assert_eq!(
            plain.1, watched.1,
            "jobs={jobs}: meta.json changed under --watch"
        );
        assert_eq!(
            plain.2, watched.2,
            "jobs={jobs}: telemetry.json changed under --watch"
        );
        assert!(log.is_file(), "watched run produced no snapshot log");
        watched_outputs.push((watched_dir, log));
    }

    // --- The snapshot log itself ----------------------------------
    let (watched_dir, log) = &watched_outputs[1]; // the jobs=8 run
    let snaps = read_snapshots(log).unwrap();
    assert!(
        snaps.len() >= GRID_CASES,
        "expected at least one snapshot per case, got {}",
        snaps.len()
    );
    // seq is strictly increasing in write order (the view stamps it
    // under one lock, whatever the worker interleaving).
    for w in snaps.windows(2) {
        assert!(w[1].seq > w[0].seq, "seq not strictly increasing");
    }
    // Per-case sim time is monotone, each case ends with exactly one
    // `done` snapshot, and cases_done reaches the full grid.
    let mut by_case: BTreeMap<u64, Vec<&Snapshot>> = BTreeMap::new();
    for s in &snaps {
        assert_eq!(s.experiment, ID);
        assert_eq!(s.cases_total, GRID_CASES as u64);
        assert_eq!(s.cases_owned, GRID_CASES as u64, "unsharded: owned == total");
        assert_eq!(s.shard, None);
        by_case.entry(s.case_index).or_default().push(s);
    }
    assert_eq!(by_case.len(), GRID_CASES, "every case must emit");
    for (case, ss) in &by_case {
        for w in ss.windows(2) {
            assert!(
                w[1].t_s >= w[0].t_s,
                "case {case}: t_s not monotone ({} then {})",
                w[0].t_s,
                w[1].t_s
            );
        }
        assert!(
            ss.last().unwrap().done,
            "case {case}: last snapshot not final"
        );
        assert_eq!(
            ss.iter().filter(|s| s.done).count(),
            1,
            "case {case}: exactly one final snapshot expected"
        );
        // Cumulative fields never decrease.
        for w in ss.windows(2) {
            assert!(w[1].finished >= w[0].finished);
            assert!(w[1].stages >= w[0].stages);
            assert!(w[1].energy_kwh >= w[0].energy_kwh);
        }
    }
    assert_eq!(snaps.last().unwrap().cases_done, GRID_CASES as u64);

    // Final snapshots carry the case totals: summed, they equal the
    // telemetry sidecar the same run persisted.
    let tel = ShardTelemetry::load(&watched_dir.join(ID)).unwrap().unwrap();
    let finished: u64 = by_case.values().map(|ss| ss.last().unwrap().finished).sum();
    let stages: u64 = by_case.values().map(|ss| ss.last().unwrap().stages).sum();
    assert_eq!(finished, tel.requests.finished);
    assert_eq!(stages, tel.stages.stages);

    // --- `repro watch` across two shard dirs ----------------------
    let mut shard_dirs = Vec::new();
    for k in 0..2u32 {
        let dir = base.join(format!("shard{k}"));
        sweep::set_shard(Some(ShardSpec::new(k, 2).unwrap()));
        live::set_watch(watch_json(&dir.join("watch.jsonl")));
        run_and_save_grid(&dir, ID, SEED_BASE);
        live::set_watch(None);
        shard_dirs.push(dir);
    }
    sweep::set_shard(None);
    sweep::set_default_jobs(0);

    let files = discover_watch_files(&shard_dirs).unwrap();
    assert_eq!(files.len(), 2, "one watch.jsonl per shard dir");
    let mut all = Vec::new();
    for f in &files {
        all.extend(read_snapshots(f).unwrap());
    }
    // Sharded snapshots pair a shard-local denominator with the global
    // grid size (2-way over 9 cases: shards own 5 and 4).
    for s in &all {
        assert!(s.cases_owned == 4 || s.cases_owned == 5, "{s:?}");
        assert_eq!(s.cases_total, GRID_CASES as u64);
        assert!(s.cases_done <= s.cases_owned);
    }
    let aggs = aggregate(&all);
    assert_eq!(aggs.len(), 1);
    let a = &aggs[0];
    assert_eq!(a.experiment, ID);
    assert_eq!(a.cases_total, GRID_CASES as u64);
    assert_eq!(a.cases_done, GRID_CASES as u64, "both shards finished");
    assert_eq!(
        a.shards.iter().cloned().collect::<Vec<_>>(),
        vec!["0/2".to_string(), "1/2".to_string()]
    );
    // The aggregate of the two shards' final snapshots equals the
    // unsharded totals (same grid, same seeds — the §9 determinism
    // carried into the live view).
    assert_eq!(a.finished, tel.requests.finished);
    assert_eq!(a.stages, tel.stages.stages);
    // All cases done ⇒ no live rates left.
    assert_eq!(a.qps, 0.0);
    assert_eq!(a.power_w, 0.0);
    // And the renderer produces a dashboard naming the experiment.
    let text = render_watch(&aggs, files.len());
    assert!(text.contains(ID), "{text}");
    assert!(text.contains("cases 9/9"), "{text}");
}
