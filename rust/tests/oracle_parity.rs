//! Cross-layer parity: the AOT HLO stage oracle (JAX/Pallas, compiled
//! through PJRT) must agree with the native rust roofline model — the
//! two implementations of the same math (Eq. 1 + Eq. 2 + roofline) in
//! different layers of the stack.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use vidur_energy::config::simconfig::ExecParams;
use vidur_energy::config::{gpus, models};
use vidur_energy::exec::batch::BatchDesc;
use vidur_energy::exec::hlo::HloCost;
use vidur_energy::exec::native::NativeCost;
use vidur_energy::exec::StageCostModel;
use vidur_energy::util::rng::Rng;

fn artifacts_present() -> bool {
    vidur_energy::runtime::ArtifactStore::discover().is_ok()
}

fn batch_for(model: &str, gpu: &str, tp: u32, pp: u32) -> BatchDesc {
    BatchDesc::new(
        models::model(model).unwrap(),
        gpus::gpu(gpu).unwrap(),
        tp,
        pp,
        ExecParams::default(),
    )
}

/// f32 through the HLO path vs f64 native: tolerances account for the
/// precision gap (flops values reach 1e15).
fn assert_close(native: f64, hlo: f64, rel: f64, what: &str) {
    let denom = native.abs().max(1e-12);
    assert!(
        (native - hlo).abs() / denom < rel,
        "{what}: native {native} vs hlo {hlo}"
    );
}

#[test]
fn hlo_matches_native_across_batches() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut hlo = HloCost::new().unwrap().exact();
    let mut rng = Rng::new(0xBEEF);
    let cases = [
        ("llama3-8b", 1u32, 1u32),
        ("llama2-7b", 1, 1),
        ("codellama-34b", 2, 1),
        ("llama3-70b", 2, 2),
        ("qwen-72b", 4, 1),
        ("phi-2", 1, 2),
    ];
    for (model, tp, pp) in cases {
        for _ in 0..8 {
            let mut b = batch_for(model, "a100-80g", tp, pp);
            let n = rng.int_range(1, 128);
            for _ in 0..n {
                if rng.f64() < 0.25 {
                    b.push(rng.int_range(2, 4096) as u32, rng.int_range(0, 512) as u32);
                } else {
                    b.push(1, rng.int_range(1, 4096) as u32);
                }
            }
            let nat = NativeCost::compute(&b);
            let oracle = hlo.stage_cost(&b);
            assert_close(nat.t_stage_s, oracle.t_stage_s, 2e-3, "t_stage");
            assert_close(nat.flops, oracle.flops, 2e-3, "flops");
            assert_close(nat.mfu, oracle.mfu, 2e-3, "mfu");
            assert_close(nat.power_w, oracle.power_w, 2e-3, "power");
        }
    }
}

#[test]
fn hlo_empty_batch_is_idle() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut hlo = HloCost::new().unwrap().exact();
    let b = batch_for("llama3-8b", "a100-80g", 1, 1);
    let c = hlo.stage_cost(&b);
    assert!((c.power_w - 100.0).abs() < 0.1, "power {}", c.power_w);
    assert!(c.flops.abs() < 1.0);
}

#[test]
fn hlo_gpu_variants_change_power_envelope() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut hlo = HloCost::new().unwrap().exact();
    // Saturating prefill on each GPU: power must approach its p_max.
    for (gpu, pmax) in [("a100-80g", 400.0), ("h100", 700.0), ("a40", 300.0)] {
        let mut b = batch_for("llama2-7b", gpu, 1, 1);
        b.push(4096, 0);
        let c = hlo.stage_cost(&b);
        assert!(
            c.power_w > 0.85 * pmax,
            "{gpu}: power {} vs pmax {pmax}",
            c.power_w
        );
    }
}

#[test]
fn quantized_cache_hits_and_stays_close() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut hlo = HloCost::new().unwrap(); // quantized (default)
    let mut rng = Rng::new(7);
    // Many decode batches with slightly-varying contexts: quantization
    // must produce cache hits while keeping results close to native.
    for _ in 0..200 {
        let mut b = batch_for("llama3-8b", "a100-80g", 1, 1);
        let n = 32;
        for _ in 0..n {
            b.push(1, 1000 + rng.int_range(0, 40) as u32);
        }
        let nat = NativeCost::compute(&b);
        let got = hlo.stage_cost(&b);
        assert_close(nat.t_stage_s, got.t_stage_s, 0.05, "quantized t_stage");
        assert_close(nat.power_w, got.power_w, 0.05, "quantized power");
    }
    assert!(
        hlo.hits > 150,
        "expected heavy cache reuse, got {}/{} hits",
        hlo.hits,
        hlo.calls
    );
}
