//! Sweep determinism: `--jobs 1` and `--jobs 8` must produce
//! byte-identical experiment CSVs. Each case's RNG seed is derived
//! from its case index (`util::rng::case_seed`) and results are
//! returned in case order, so the worker count can only change
//! wall-clock time, never output bytes.
//!
//! The mini grid and the fixed-format renderer live in the shared
//! harness (`tests/common`); this file keeps its historical seed base.

mod common;

use common::{grid_cfgs, render_cases};
use vidur_energy::config::simconfig::SimConfig;
use vidur_energy::experiments;
use vidur_energy::experiments::common::run_cases_on;
use vidur_energy::sweep::{self, SweepExecutor};

/// A small exp-shaped grid (QPS × batch cap) on the native oracle, so
/// the test runs without compiled artifacts.
fn grid() -> Vec<SimConfig> {
    grid_cfgs(0xD7)
}

#[test]
fn jobs_1_and_8_produce_byte_identical_results() {
    let serial = run_cases_on(&SweepExecutor::new(1), grid()).unwrap();
    let par = run_cases_on(&SweepExecutor::new(8), grid()).unwrap();
    assert_eq!(
        render_cases(serial.iter().enumerate()).to_csv(),
        render_cases(par.iter().enumerate()).to_csv()
    );
    // Oracle/telemetry metadata is deterministic too (per-case models).
    for (a, b) in serial.iter().zip(&par) {
        assert_eq!(a.out.oracle, b.out.oracle);
        assert_eq!(a.peak_resident_bins, b.peak_resident_bins);
        assert_eq!(a.out.metrics.stage_count, b.out.metrics.stage_count);
    }
}

/// The scenario library goes through the same contract: a workload
/// axis (chat / rag / agentic / tenants / mix) × QPS grid renders
/// byte-identically under any worker count. Scenario generators carry
/// more internal RNG state than the synthetic generator (per-session
/// forks, tenant pickers), so this pins that none of it leaks across
/// cases or depends on scheduling order.
#[test]
fn scenario_grid_is_byte_identical_across_jobs() {
    use vidur_energy::config::simconfig::{Arrival, CostModelKind, WorkloadKind};
    use vidur_energy::util::rng::case_seed;

    let grid = || -> Vec<SimConfig> {
        let mut cfgs = Vec::new();
        for kind in ["chat", "rag", "agentic", "tenants", "mix:chat=2,tenants=1"] {
            for &qps in &[2.0, 8.0] {
                let mut cfg = SimConfig::default();
                cfg.cost_model = CostModelKind::Native;
                cfg.workload = WorkloadKind::parse(kind).unwrap();
                cfg.arrival = Arrival::Poisson { qps };
                cfg.num_requests = 96;
                cfg.seed = case_seed(0x5CE, cfgs.len() as u64);
                cfgs.push(cfg);
            }
        }
        cfgs
    };
    let serial = run_cases_on(&SweepExecutor::new(1), grid()).unwrap();
    let par = run_cases_on(&SweepExecutor::new(8), grid()).unwrap();
    assert_eq!(
        render_cases(serial.iter().enumerate()).to_csv(),
        render_cases(par.iter().enumerate()).to_csv()
    );
    for (a, b) in serial.iter().zip(&par) {
        assert_eq!(a.out.request_stats.prefill_tokens_done, b.out.request_stats.prefill_tokens_done);
        assert_eq!(a.out.request_stats.decode_tokens_done, b.out.request_stats.decode_tokens_done);
        assert_eq!(a.out.metrics.stage_count, b.out.metrics.stage_count);
    }
}

/// Experiment-level check through the real regenerator + CSV writer
/// (needs the compiled HLO artifacts; skipped without them). Runs both
/// worker counts sequentially in one test so the process-global
/// `--jobs` setting never races another test.
#[test]
fn fig1_csv_identical_across_jobs() {
    if vidur_energy::runtime::ArtifactStore::discover().is_err() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let base = std::env::temp_dir().join("vidur_energy_sweep_det");
    std::fs::remove_dir_all(&base).ok();
    let d1 = base.join("jobs1");
    let d8 = base.join("jobs8");

    sweep::set_default_jobs(1);
    experiments::fig1::run(&d1, true).unwrap();
    sweep::set_default_jobs(8);
    experiments::fig1::run(&d8, true).unwrap();
    sweep::set_default_jobs(0);

    let a = std::fs::read(d1.join("fig1/fig1.csv")).unwrap();
    let b = std::fs::read(d8.join("fig1/fig1.csv")).unwrap();
    assert_eq!(a, b, "fig1.csv differs between --jobs 1 and --jobs 8");
    std::fs::remove_dir_all(&base).ok();
}
