//! Property tests for [`ReplicaScheduler`] invariants, driven by the
//! crate's own proptest harness (`util::proptest`):
//!
//! 1. admission never over-allocates the paged KV cache;
//! 2. preemption always evicts the youngest running request(s) —
//!    survivors of an eviction form a prefix of the admission order;
//! 3. drained replicas admit nothing, ever.

use vidur_energy::cluster::kvcache::KvCache;
use vidur_energy::config::simconfig::SchedulerKind;
use vidur_energy::scheduler::replica::ReplicaScheduler;
use vidur_energy::util::proptest::{check, gens};
use vidur_energy::util::rng::Rng;
use vidur_energy::workload::Request;

fn random_requests(rng: &mut Rng, n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::new(
                i as u64,
                0.0,
                rng.int_range(1, 200),
                rng.int_range(1, 120),
            )
        })
        .collect()
}

fn random_sched(rng: &mut Rng) -> ReplicaScheduler {
    let kind = *rng.choose(&[
        SchedulerKind::Vllm,
        SchedulerKind::Sarathi,
        SchedulerKind::Orca,
    ]);
    let batch_cap = rng.int_range(1, 16) as usize;
    // Deliberately tight cache so preemption paths fire.
    let blocks = rng.int_range(16, 96);
    ReplicaScheduler::with_kv(0, kind, batch_cap, 64, KvCache::with_blocks(16, blocks))
}

#[test]
fn property_admission_never_overallocates_kv() {
    check(40, gens::u64_in(0, u64::MAX / 2), |&seed| {
        let mut rng = Rng::new(seed);
        let mut reqs = random_requests(&mut rng, 30);
        let mut s = random_sched(&mut rng);
        let mut next_arrival = 0usize;
        let mut now = 0.0;
        for _ in 0..2_000 {
            // Interleave arrivals with scheduling.
            if next_arrival < reqs.len() && rng.f64() < 0.3 {
                s.enqueue(next_arrival as u64);
                next_arrival += 1;
            }
            let Some(plan) = s.next_stage(&mut reqs, now) else {
                if next_arrival >= reqs.len() {
                    break;
                }
                s.enqueue(next_arrival as u64);
                next_arrival += 1;
                continue;
            };
            now += 0.01;
            s.complete_stage(&mut reqs, &plan, now);
            // The invariant proper: held + free == total, i.e. no
            // over-allocation and no leaks, after every step.
            s.kv().check_invariants()?;
            if s.kv().free_blocks() > s.kv().total_blocks() {
                return Err("free exceeds total".into());
            }
        }
        if reqs.iter().any(|r| !r.is_finished()) && !s.has_work() && next_arrival >= reqs.len()
        {
            return Err("work lost: unfinished requests but scheduler idle".into());
        }
        Ok(())
    });
}

#[test]
fn property_preemption_evicts_youngest_first() {
    check(40, gens::u64_in(0, u64::MAX / 2), |&seed| {
        let mut rng = Rng::new(seed);
        // Long decodes against a tiny cache force repeated preemption.
        let mut reqs: Vec<Request> = (0..12)
            .map(|i| {
                Request::new(i as u64, 0.0, rng.int_range(16, 64), rng.int_range(64, 256))
            })
            .collect();
        let mut s = ReplicaScheduler::with_kv(
            0,
            SchedulerKind::Vllm,
            8,
            64,
            KvCache::with_blocks(16, rng.int_range(8, 20)),
        );
        for i in 0..reqs.len() as u64 {
            s.enqueue(i);
        }
        let mut now = 0.0;
        for _ in 0..5_000 {
            let before = s.running_ids();
            let Some(plan) = s.next_stage(&mut reqs, now) else { break };
            let after = s.running_ids();
            // Survivors of `before` must be a *prefix* of `before`:
            // preemption pops from the tail (the youngest) only.
            let survivors: Vec<u64> = before
                .iter()
                .copied()
                .filter(|id| after.contains(id))
                .collect();
            if survivors.as_slice() != &before[..survivors.len()] {
                return Err(format!(
                    "eviction skipped the youngest: before {before:?}, after {after:?}"
                ));
            }
            now += 0.01;
            s.complete_stage(&mut reqs, &plan, now);
            s.kv().check_invariants()?;
        }
        if s.preemptions == 0 {
            return Err("scenario produced no preemption; tighten it".into());
        }
        Ok(())
    });
}

#[test]
fn property_drained_replicas_admit_nothing() {
    check(40, gens::u64_in(0, u64::MAX / 2), |&seed| {
        let mut rng = Rng::new(seed);
        let mut reqs = random_requests(&mut rng, 24);
        let mut s = random_sched(&mut rng);
        // Warm up with some work, then drain mid-flight.
        for i in 0..12u64 {
            s.enqueue(i);
        }
        let mut now = 0.0;
        let warm_steps = rng.int_range(0, 20);
        for _ in 0..warm_steps {
            let Some(plan) = s.next_stage(&mut reqs, now) else { break };
            now += 0.01;
            s.complete_stage(&mut reqs, &plan, now);
        }
        s.begin_drain();
        if !s.is_draining() {
            return Err("begin_drain did not latch".into());
        }
        let frozen = s.running_ids();
        for i in 12..24u64 {
            s.enqueue(i); // queued after drain: must never run here
        }
        for _ in 0..5_000 {
            let Some(plan) = s.next_stage(&mut reqs, now) else { break };
            // No new admissions: every planned id was running at drain
            // time (preemption may shrink the running set, never grow it).
            for &(id, _) in &plan.entries {
                if !frozen.contains(&id) {
                    return Err(format!(
                        "drained replica ran request {id} admitted after drain"
                    ));
                }
            }
            now += 0.01;
            s.complete_stage(&mut reqs, &plan, now);
        }
        // Running set fully drained; late arrivals still queued (or
        // preempted back to the queue), ready for re-routing.
        if s.running_len() != 0 {
            return Err(format!("drain left {} running", s.running_len()));
        }
        let moved = s.drain_queue();
        for id in 12..24u64 {
            if !moved.contains(&id) {
                return Err(format!("late request {id} vanished from the queue"));
            }
        }
        s.kv().check_invariants()?;
        Ok(())
    });
}
