//! Shared deterministic test harness for the integration suite.
//!
//! Four fast-moving PRs each re-implemented the same fixtures — a
//! small native-oracle `SimConfig`, a 3×3 case grid with
//! `case_seed`-derived seeds, a flat-cost oracle, tempdir sweep
//! runners, and CSV/JSON readers. They live here once now;
//! `stream_parity.rs`, `request_telemetry.rs`, `sweep_determinism.rs`,
//! `shard_merge.rs`, and `watch_observer.rs` all build on this module.
//!
//! Everything is deterministic by construction: configs take explicit
//! seed bases (each test keeps the constant it always used, so
//! behaviour is unchanged by the consolidation), grids derive per-case
//! seeds from **global** case indices via `util::rng::case_seed` —
//! exactly like the real experiment regenerators, which is the
//! property the sharding/determinism tests rely on.

// Each integration-test binary compiles its own copy of this module
// and uses a different slice of it.
#![allow(dead_code)]

use std::path::{Path, PathBuf};
use vidur_energy::config::simconfig::{Arrival, CostModelKind, LengthDist, SimConfig};
use vidur_energy::energy::EnergyReport;
use vidur_energy::exec::batch::{BatchDesc, StageCost};
use vidur_energy::exec::StageCostModel;
use vidur_energy::experiments::common::{run_grid, save_grid, CaseResult, GridRun};
use vidur_energy::util::csv::Table;
use vidur_energy::util::json::Value;
use vidur_energy::util::rng::case_seed;
use vidur_energy::workload::{Trace, WorkloadGenerator};

/// Rows of the standard 3×3 test grid ([`grid_cfgs`]).
pub const GRID_CASES: usize = 9;

/// The standard single-run workload: native oracle (no compiled
/// artifacts needed), 500 Poisson arrivals at 12 QPS, Zipf lengths.
/// `seed` keeps each test's historical constant.
pub fn stream_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.cost_model = CostModelKind::Native;
    cfg.num_requests = 500;
    cfg.arrival = Arrival::Poisson { qps: 12.0 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 64,
        max: 768,
    };
    cfg.seed = seed;
    cfg
}

/// Materialize `cfg`'s workload as a fixed trace (held constant across
/// the runs a parity test compares).
pub fn trace_for(cfg: &SimConfig) -> Trace {
    let mut gen = WorkloadGenerator::from_config(cfg);
    Trace::new(gen.generate(cfg.num_requests))
}

/// The standard exp-shaped mini grid (QPS × batch cap, 3×3, 96
/// requests per case) on the native oracle. Seeds derive from the
/// **global** case index under `seed_base`, exactly like the real
/// experiment regenerators.
pub fn grid_cfgs(seed_base: u64) -> Vec<SimConfig> {
    let mut cfgs = Vec::new();
    for &qps in &[1.0, 4.0, 10.0] {
        for &cap in &[4usize, 16, 128] {
            let mut cfg = SimConfig::default();
            cfg.cost_model = CostModelKind::Native;
            cfg.arrival = Arrival::Poisson { qps };
            cfg.batch_cap = cap;
            cfg.num_requests = 96;
            cfg.seed = case_seed(seed_base, cfgs.len() as u64);
            cfgs.push(cfg);
        }
    }
    cfgs
}

/// Constant-time cost oracle for tests about memory/scheduling rather
/// than physics: every stage takes 10 ms at fixed power/MFU.
pub struct FlatCost;

impl StageCostModel for FlatCost {
    fn stage_cost(&mut self, b: &BatchDesc) -> StageCost {
        StageCost {
            t_stage_s: 0.01,
            flops: b.total_new_tokens() as f64 * 1e9,
            mfu: 0.2,
            power_w: 250.0,
        }
    }
    fn name(&self) -> &'static str {
        "flat"
    }
}

/// Render grid results the way the experiment regenerators do — fixed
/// formatting, one row per case, rows labelled by **global** case
/// index. Byte-comparing two of these tables is the determinism/
/// sharding contract.
pub fn render_cases<'a>(rows: impl Iterator<Item = (usize, &'a CaseResult)>) -> Table {
    let mut t = Table::new(&["case", "avg_power_w", "energy_kwh", "makespan_s", "mfu"]);
    for (i, r) in rows {
        t.push_row(vec![
            i.to_string(),
            format!("{:.3}", r.avg_power_w()),
            format!("{:.6}", r.energy_kwh()),
            format!("{:.6}", r.out.metrics.makespan_s),
            format!("{:.6}", r.mfu()),
        ]);
    }
    t
}

/// Run the (possibly shard-filtered, possibly watched) standard grid
/// and persist it in the `save_grid` layout (`<id>.csv`, `meta.json`,
/// `telemetry.json`) under `out/<id>` — the tempdir sweep runner the
/// shard-merge and watch tests share.
pub fn run_and_save_grid(out: &Path, id: &str, seed_base: u64) -> GridRun {
    let run = run_grid(id, grid_cfgs(seed_base)).unwrap();
    let table = render_cases(run.iter());
    let mut meta = Value::obj();
    meta.set("experiment", id).set("sweep", run.sweep_meta());
    save_grid(out, id, &table, meta, &run).unwrap();
    run
}

/// Exact-equality comparison of two energy reports (the streaming-vs-
/// materialized and watched-vs-unwatched contracts are bit-exact, not
/// tolerance-based).
pub fn assert_energy_reports_identical(a: &EnergyReport, b: &EnergyReport) {
    assert_eq!(a.energy_kwh, b.energy_kwh);
    assert_eq!(a.gpu_energy_kwh, b.gpu_energy_kwh);
    assert_eq!(a.avg_power_w, b.avg_power_w);
    assert_eq!(a.peak_power_w, b.peak_power_w);
    assert_eq!(a.gpu_hours, b.gpu_hours);
    assert_eq!(a.operational_g, b.operational_g);
    assert_eq!(a.embodied_g, b.embodied_g);
    assert_eq!(a.busy_fraction, b.busy_fraction);
}

/// Assert `v`'s true rank in `sorted` lies within ⌈εn⌉ (+1 slack for
/// the materialized side's order-statistic interpolation) of `q·n` —
/// the sketch-quantile parity check.
pub fn assert_rank_bounded(sorted: &[f64], v: f64, q: f64, eps: f64, what: &str) {
    let n = sorted.len() as f64;
    let rank_lo = sorted.partition_point(|&x| x < v) as f64;
    let rank_hi = sorted.partition_point(|&x| x <= v) as f64;
    let target = q * n;
    let slack = (eps * n).ceil() + 1.0;
    assert!(
        rank_hi >= target - slack && rank_lo <= target + slack,
        "{what}: sketch value {v} has rank [{rank_lo}, {rank_hi}], \
         target {target} ± {slack} (n={n})"
    );
}

/// A scratch directory under the system tempdir. Pre-cleaned on
/// creation; removed on drop **unless the test is panicking**, so
/// failing runs leave their artifacts behind for inspection.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(name: &str) -> TempDir {
        let path = std::env::temp_dir().join(name);
        std::fs::remove_dir_all(&path).ok();
        std::fs::create_dir_all(&path).unwrap();
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, rel: impl AsRef<Path>) -> PathBuf {
        self.path.join(rel)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            std::fs::remove_dir_all(&self.path).ok();
        }
    }
}

/// Read a file's raw bytes (byte-identity assertions), with a useful
/// panic message on absence.
pub fn read_bytes(path: impl AsRef<Path>) -> Vec<u8> {
    let path = path.as_ref();
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {path:?}: {e}"))
}

/// Parse a JSON result file (`meta.json`, sidecars, snapshot lines).
pub fn load_json(path: impl AsRef<Path>) -> Value {
    let path = path.as_ref();
    let text = String::from_utf8(read_bytes(path)).unwrap();
    vidur_energy::util::json::parse(&text)
        .unwrap_or_else(|e| panic!("parsing {path:?}: {e}"))
}
