//! Surface-oracle parity (DESIGN.md §12).
//!
//! The surface oracle answers `stage_cost` from the analytical
//! closed form plus a bilinearly-interpolated residual grid sampled
//! from its inner oracle. With the native inner, the residual is
//! identically zero (the closed form *is* the native model), so the
//! surface must reproduce `NativeCost::compute` to floating-point
//! noise — the property test pins a 1e-6 relative bound across random
//! mixed batches. End to end, an exp1-shaped run under the surface
//! oracle must match the native run's summary metrics within a loose
//! tolerance (ulp-level stage-time differences may flip event ties
//! and perturb the schedule slightly).

mod common;

use common::stream_cfg;
use vidur_energy::config::simconfig::{CostModelKind, ExecParams};
use vidur_energy::config::{gpus, models};
use vidur_energy::exec::batch::BatchDesc;
use vidur_energy::exec::native::NativeCost;
use vidur_energy::exec::surface::{SurfaceCost, SurfaceInner};
use vidur_energy::exec::StageCostModel;
use vidur_energy::sim;
use vidur_energy::util::proptest::{check, gens};
use vidur_energy::util::rng::Rng;

/// Documented interpolation bound for the native-inner surface: the
/// correction term carries the whole closed form, so only rounding
/// differences (different accumulation order) remain.
const REL_BOUND: f64 = 1e-6;

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(1e-12)
}

#[test]
fn surface_matches_native_on_random_batches() {
    check(120, gens::u64_in(0, u64::MAX / 2), |&seed| {
        let mut rng = Rng::new(seed);
        let model = *rng.choose(&["llama3-8b", "llama2-7b", "codellama-34b", "phi-2"]);
        let gpu = *rng.choose(&["a100-80g", "h100", "a40"]);
        let tp = *rng.choose(&[1u32, 2]);
        let pp = *rng.choose(&[1u32, 2]);
        let mut surf = SurfaceCost::with_inner(SurfaceInner::Native);
        let mut b = BatchDesc::new(
            models::model(model).unwrap(),
            gpus::gpu(gpu).unwrap(),
            tp,
            pp,
            ExecParams::default(),
        );
        let n = rng.int_range(0, 128);
        for _ in 0..n {
            if rng.f64() < 0.25 {
                b.push(rng.int_range(2, 4096) as u32, rng.int_range(0, 512) as u32);
            } else {
                b.push(1, rng.int_range(0, 8192) as u32);
            }
        }
        let nat = NativeCost::compute(&b);
        let got = surf.stage_cost(&b);
        for (what, a, g) in [
            ("t_stage", nat.t_stage_s, got.t_stage_s),
            ("flops", nat.flops, got.flops),
            ("mfu", nat.mfu, got.mfu),
            ("power", nat.power_w, got.power_w),
        ] {
            if rel(a, g) > REL_BOUND {
                return Err(format!(
                    "{model}/{gpu} tp{tp} pp{pp} n={n}: {what} native {a} vs surface {g}"
                ));
            }
        }
        Ok(())
    });
}

/// One surface answers every batch shape for its configuration: the
/// table is built exactly once per (model, gpu, tp, pp, exec) key and
/// shared process-wide.
#[test]
fn surface_builds_once_per_config() {
    let mut surf = SurfaceCost::with_inner(SurfaceInner::Native);
    let mut b = BatchDesc::new(
        models::model("llama2-7b").unwrap(),
        gpus::gpu("a40").unwrap(),
        1,
        1,
        ExecParams::default(),
    );
    for ctx in [64u32, 512, 4096] {
        b.clear();
        for _ in 0..16 {
            b.push(1, ctx);
        }
        surf.stage_cost(&b);
    }
    assert!(surf.builds() <= 1, "rebuilt per batch: {}", surf.builds());
    let stats = surf.stats();
    assert_eq!(stats.calls, 3);
    // Calls 2 and 3 must resolve warm against the instance-local table
    // (call 1 either builds or finds the process-global entry).
    assert_eq!(stats.hits, 2);
}

/// End-to-end exp1-shaped parity: the same workload simulated under
/// `--oracle surface` matches the native run's summary metrics.
#[test]
fn e2e_summary_metrics_match_native() {
    let native_cfg = stream_cfg(0x5F);
    let mut surface_cfg = native_cfg.clone();
    surface_cfg.cost_model = CostModelKind::Surface;

    let nat = sim::run(&native_cfg).unwrap();
    let surf = sim::run(&surface_cfg).unwrap();

    assert!(surf.oracle.surface_builds >= 1, "surface never built");
    assert!(
        surf.oracle.calls > 0 && nat.oracle.calls > 0,
        "oracle stats not plumbed"
    );

    // With compiled artifacts present, `build_cost_model` samples the
    // surface from the HLO inner — a different physics than the
    // native baseline, so tight parity is only meaningful without
    // them (the CI tier-1 environment).
    if vidur_energy::runtime::ArtifactStore::discover().is_ok() {
        assert!(surf.metrics.makespan_s.is_finite() && surf.metrics.makespan_s > 0.0);
        return;
    }

    assert!(
        rel(nat.metrics.makespan_s, surf.metrics.makespan_s) < 1e-3,
        "makespan: native {} vs surface {}",
        nat.metrics.makespan_s,
        surf.metrics.makespan_s
    );
    assert!(
        rel(nat.metrics.token_throughput, surf.metrics.token_throughput) < 1e-3,
        "throughput: native {} vs surface {}",
        nat.metrics.token_throughput,
        surf.metrics.token_throughput
    );
    let sc_rel = rel(nat.metrics.stage_count as f64, surf.metrics.stage_count as f64);
    assert!(
        sc_rel < 0.01,
        "stage counts diverge: native {} vs surface {}",
        nat.metrics.stage_count,
        surf.metrics.stage_count
    );
}
