//! The AOT cosim kernel (battery/microgrid scan, JAX/Pallas) must
//! reproduce the native rust microgrid loop step-for-step, including
//! SoC chaining across 1440-step chunk boundaries.

use vidur_energy::config::simconfig::CosimConfig;
use vidur_energy::cosim::Environment;
use vidur_energy::util::rng::Rng;

fn artifacts_present() -> bool {
    vidur_energy::runtime::ArtifactStore::discover().is_ok()
}

fn signals(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let load: Vec<f64> = (0..n).map(|_| rng.uniform(50.0, 500.0)).collect();
    let solar: Vec<f64> = (0..n)
        .map(|i| {
            let h = (i as f64 / 60.0).rem_euclid(24.0);
            if (6.0..20.0).contains(&h) {
                550.0 * (std::f64::consts::PI * (h - 6.0) / 14.0).sin()
            } else {
                0.0
            }
        })
        .collect();
    let ci: Vec<f64> = (0..n).map(|_| rng.uniform(80.0, 550.0)).collect();
    (load, solar, ci)
}

#[test]
fn hlo_cosim_matches_native_over_three_days() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let n = 3 * 1440 + 77; // cross chunk boundaries incl. a ragged tail
    let (load, solar, ci) = signals(n, 0xC051);

    let mut env_native = Environment::new(CosimConfig::default());
    let native = env_native.run_native(&load, &solar, &ci).unwrap();
    let mut env_hlo = Environment::new(CosimConfig::default());
    let hlo = env_hlo.run_hlo(&load, &solar, &ci).unwrap();

    assert_eq!(native.records.len(), hlo.records.len());
    for (a, b) in native.records.iter().zip(&hlo.records) {
        assert!((a.soc - b.soc).abs() < 2e-4, "soc {} vs {} at {}", a.soc, b.soc, a.t_s);
        assert!(
            (a.grid_w - b.grid_w).abs() < 0.2,
            "grid {} vs {} at {}",
            a.grid_w,
            b.grid_w,
            a.t_s
        );
        assert!((a.battery_w - b.battery_w).abs() < 0.2);
        assert!((a.emissions_g - b.emissions_g).abs() < 0.05);
    }
    // Summary metrics agree.
    assert!((native.total_energy_kwh - hlo.total_energy_kwh).abs() < 1e-3);
    assert!((native.net_footprint_g - hlo.net_footprint_g).abs() < 2.0);
    assert!((native.renewable_share - hlo.renewable_share).abs() < 1e-3);
    assert!((native.battery_full_cycles - hlo.battery_full_cycles).abs() < 0.02);
}

#[test]
fn hlo_cosim_rejects_controller() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use vidur_energy::cosim::CarbonAwareController;
    let mut env = Environment::new(CosimConfig::default())
        .with_controller(CarbonAwareController::new(100.0, 200.0, 0.5));
    let r = env.run_hlo(&[100.0], &[0.0], &[300.0]);
    assert!(r.is_err(), "controller feedback must force the native path");
}
