//! Thin wrapper over the `xla` crate's PJRT CPU client: compile HLO
//! text once, execute many times with f32 buffers.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): jax >= 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

/// Shared PJRT CPU client (one per process).
pub struct PjrtRuntime {
    client: Arc<xla::PjRtClient>,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime {
            client: Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file into a reusable executable.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe })
    }
}

/// A compiled computation; `call_f32` feeds f32 vectors and returns the
/// flattened tuple outputs as f32 vectors.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 1-D inputs of the given sizes; returns each
    /// tuple element as a f32 vector (scalars become length-1).
    pub fn call_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|x| xla::Literal::vec1(x)).collect();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // AOT export uses return_tuple=True: the root is always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

thread_local! {
    /// Per-thread compiled-executable cache (PJRT objects are
    /// thread-affine, so the cache is thread-local rather than global).
    /// Avoids re-parsing + re-compiling an artifact on every
    /// `HloCost::new` / `bin_stages` / `run_hlo` call — compile once,
    /// execute millions of times (§Perf iteration 1: the hotpath bench
    /// showed artifact compilation dominating short runs at ~100 ms
    /// per call site).
    static EXE_CACHE: RefCell<HashMap<String, Rc<Executable>>> =
        RefCell::new(HashMap::new());
}

/// Fetch (or compile and cache) the named artifact's executable for
/// this thread.
pub fn cached_executable(name: &str) -> Result<Rc<Executable>> {
    EXE_CACHE.with(|cache| {
        if let Some(exe) = cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let store = crate::runtime::ArtifactStore::discover()?;
        let rt = PjrtRuntime::cpu()?;
        let exe = Rc::new(rt.load_hlo_text(store.path(name))?);
        cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    })
}

#[cfg(test)]
mod tests {
    // Execution-level tests live in rust/tests/ (they need built
    // artifacts); here we only check client creation.
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }
}
