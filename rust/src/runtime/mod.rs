//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the simulation hot
//! path. Python never runs at request time — the compiled executables
//! are the only bridge between layers.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::ArtifactStore;
pub use pjrt::{Executable, PjrtRuntime};
