//! Artifact discovery + the static-shape contract with the AOT export
//! (python/compile/model.py):
//!
//! | artifact          | inputs                                   | outputs |
//! |-------------------|------------------------------------------|---------|
//! | stage_oracle      | nt[128], ctx[128], act[128], mp[8], gp[12] | (t, flops, mfu, power) scalars |
//! | cosim_step        | load[1440], solar[1440], ci[1440], bp[8], soc0[1] | 5 × [1440] |
//! | bin_power         | p[4096], dt[4096], idx[4096]             | (energy[512], weight[512]) |

use crate::util::json;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Static shapes shared with python/compile/model.py.
pub const R_MAX: usize = 128;
pub const T_COSIM: usize = 1440;
pub const N_SAMPLES: usize = 4096;
pub const N_BINS: usize = 512;

/// Locates artifacts and validates the manifest's shape contract.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
}

impl ArtifactStore {
    /// Open the artifact directory (env `REPRO_ARTIFACTS` overrides;
    /// default `artifacts/` relative to the workspace root, walking up
    /// from the current dir so tests/benches work from target/).
    pub fn discover() -> Result<ArtifactStore> {
        if let Ok(dir) = std::env::var("REPRO_ARTIFACTS") {
            return Self::open(dir);
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Self::open(cand);
            }
            if !cur.pop() {
                bail!(
                    "artifacts/ not found (run `make artifacts`); searched up from the current directory"
                );
            }
        }
    }

    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let dir = dir.into();
        let store = ArtifactStore { dir };
        store.validate_manifest()?;
        Ok(store)
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    fn validate_manifest(&self) -> Result<()> {
        let text = std::fs::read_to_string(self.dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {:?}", self.dir))?;
        let m = json::parse(&text).context("parsing artifact manifest")?;
        let shapes = m.get("shapes").context("manifest missing 'shapes'")?;
        let check = |key: &str, want: usize| -> Result<()> {
            let got = shapes
                .get(key)
                .and_then(|v| v.as_u64())
                .with_context(|| format!("manifest missing shapes.{key}"))? as usize;
            if got != want {
                bail!(
                    "artifact shape mismatch: {key}={got} but this binary expects {want}; \
                     re-run `make artifacts` after syncing python/compile/model.py"
                );
            }
            Ok(())
        };
        check("R_MAX", R_MAX)?;
        check("T_COSIM", T_COSIM)?;
        check("N_SAMPLES", N_SAMPLES)?;
        check("N_BINS", N_BINS)?;
        for name in ["stage_oracle", "cosim_step", "bin_power"] {
            if !self.path(name).exists() {
                bail!("missing artifact {:?}", self.path(name));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discover_finds_workspace_artifacts() {
        // Only meaningful after `make artifacts`; skip quietly otherwise.
        if std::env::var("REPRO_ARTIFACTS").is_err()
            && !std::path::Path::new("artifacts/manifest.json").exists()
        {
            return;
        }
        let store = ArtifactStore::discover().unwrap();
        assert!(store.path("stage_oracle").exists());
    }

    #[test]
    fn open_missing_dir_fails() {
        assert!(ArtifactStore::open("/nonexistent/path").is_err());
    }
}
