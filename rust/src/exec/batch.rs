//! Batch-stage description and cost output — the data contract between
//! the scheduler (which composes batches) and the cost oracle (which
//! prices them).

use crate::config::gpus::GpuSpec;
use crate::config::models::ModelSpec;
use crate::config::simconfig::ExecParams;

/// Max requests per stage — must equal `R_MAX` in python/compile/model.py
/// (the AOT padding width).
pub const R_MAX: usize = 128;

/// One batch stage to be priced: parallel arrays over the requests in
/// the running batch.
#[derive(Debug, Clone)]
pub struct BatchDesc {
    /// New tokens processed per request this iteration (prefill chunk
    /// size, or 1 for a decode step).
    pub new_tokens: Vec<u32>,
    /// KV context already resident per request.
    pub context: Vec<u32>,
    /// Model / parallelism / GPU parameters.
    pub model: &'static ModelSpec,
    pub gpu: &'static GpuSpec,
    pub tp: u32,
    pub pp: u32,
    pub exec: ExecParams,
}

impl BatchDesc {
    pub fn new(
        model: &'static ModelSpec,
        gpu: &'static GpuSpec,
        tp: u32,
        pp: u32,
        exec: ExecParams,
    ) -> Self {
        BatchDesc {
            new_tokens: Vec::with_capacity(R_MAX),
            context: Vec::with_capacity(R_MAX),
            model,
            gpu,
            tp,
            pp,
            exec,
        }
    }

    #[inline]
    pub fn clear(&mut self) {
        self.new_tokens.clear();
        self.context.clear();
    }

    #[inline]
    pub fn push(&mut self, new_tokens: u32, context: u32) {
        assert!(self.new_tokens.len() < R_MAX, "batch exceeds R_MAX");
        self.new_tokens.push(new_tokens);
        self.context.push(context);
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.new_tokens.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.new_tokens.is_empty()
    }

    #[inline]
    pub fn total_new_tokens(&self) -> u64 {
        self.new_tokens.iter().map(|&t| t as u64).sum()
    }

    /// Count of requests doing prefill (chunk > 1) vs decode (1 token).
    #[inline]
    pub fn prefill_count(&self) -> usize {
        self.new_tokens.iter().filter(|&&t| t > 1).count()
    }

    /// The gp[12] vector for the AOT oracle (layout:
    /// python/compile/kernels/ref.py).
    pub fn gpu_param_vec(&self) -> [f32; 12] {
        let link = self.gpu.interconnect;
        [
            self.gpu.peak_flops as f32,
            self.gpu.hbm_bw as f32,
            self.gpu.p_idle as f32,
            self.gpu.p_max_inst as f32,
            self.gpu.mfu_sat as f32,
            self.gpu.gamma as f32,
            self.exec.flops_eff as f32,
            self.exec.mem_eff as f32,
            self.exec.t_overhead as f32,
            self.exec.layer_overhead as f32,
            link.bandwidth() as f32,
            link.latency() as f32,
        ]
    }

    /// Eq. 1 power at a given MFU (used by the noise wrapper to keep
    /// power consistent after perturbing latency).
    #[inline]
    pub fn gpu_power(&self, mfu: f64) -> f64 {
        self.gpu.power(mfu)
    }
}

/// Cost of one pipeline-parallel stage of a batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageCost {
    /// Wall-clock of one pp stage, seconds.
    pub t_stage_s: f64,
    /// Useful FLOPs executed by this pp stage (whole TP group).
    pub flops: f64,
    /// Eq. 2 MFU of the stage's TP group.
    pub mfu: f64,
    /// Eq. 1 per-GPU power of the stage's active GPUs, W.
    pub power_w: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpus, models};

    #[test]
    fn push_and_counts() {
        let mut b = BatchDesc::new(
            models::model("llama3-8b").unwrap(),
            gpus::gpu("a100-80g").unwrap(),
            1,
            1,
            ExecParams::default(),
        );
        b.push(512, 0); // prefill
        b.push(1, 100); // decode
        b.push(1, 200); // decode
        assert_eq!(b.len(), 3);
        assert_eq!(b.total_new_tokens(), 514);
        assert_eq!(b.prefill_count(), 1);
        b.clear();
        assert!(b.is_empty());
    }

    #[test]
    fn gpu_param_vec_layout() {
        let b = BatchDesc::new(
            models::model("llama3-8b").unwrap(),
            gpus::gpu("a100-80g").unwrap(),
            1,
            1,
            ExecParams::default(),
        );
        let gp = b.gpu_param_vec();
        assert_eq!(gp[0], 312e12 as f32);
        assert_eq!(gp[2], 100.0);
        assert_eq!(gp[3], 400.0);
        assert_eq!(gp[4], 0.45);
        assert_eq!(gp[5], 0.7);
    }

    #[test]
    #[should_panic(expected = "R_MAX")]
    fn overflow_rejected() {
        let mut b = BatchDesc::new(
            models::model("llama3-8b").unwrap(),
            gpus::gpu("a100-80g").unwrap(),
            1,
            1,
            ExecParams::default(),
        );
        for _ in 0..(R_MAX + 1) {
            b.push(1, 10);
        }
    }
}
