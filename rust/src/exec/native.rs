//! Pure-rust analytical stage-cost model — the exact math of
//! `ref_stage_oracle` in python/compile/kernels/ref.py, kept in
//! lockstep (cross-checked against the HLO oracle in
//! rust/tests/oracle_parity.rs).
//!
//! Roofline: stage latency = max(compute, memory) + TP/PP communication
//! + fixed and per-layer overheads; MFU per Eq. 2; power per Eq. 1.

use super::batch::{BatchDesc, StageCost};
use super::StageCostModel;

/// Analytical roofline cost model.
#[derive(Debug, Default, Clone)]
pub struct NativeCost;

impl NativeCost {
    pub fn new() -> Self {
        NativeCost
    }

    /// Per-request (flops, kv_bytes) — mirrors `ref_stage_cost`.
    pub fn request_cost(batch: &BatchDesc, i: usize) -> (f64, f64) {
        let m = batch.model;
        let h = m.hidden as f64;
        let layers = m.num_layers as f64;
        let kv_dim = m.kv_dim();
        let t = batch.new_tokens[i] as f64;
        let c = batch.context[i] as f64;

        let proj = 2.0 * h * (2.0 * h + 2.0 * kv_dim);
        let mlp = 6.0 * h * m.ffn_eff();
        let attn = 4.0 * h * (c * t + t * (t + 1.0) * 0.5);
        let head = 2.0 * h * m.vocab as f64;

        let flops = layers * (t * (proj + mlp) + attn) + t * head;
        let kv_bytes = 2.0 * layers * kv_dim * (c + t) * 2.0;
        (flops, kv_bytes)
    }

    /// Full-stage cost — mirrors `ref_stage_oracle`.
    pub fn compute(batch: &BatchDesc) -> StageCost {
        let g = batch.gpu;
        let e = &batch.exec;
        let tp = batch.tp as f64;
        let pp = batch.pp as f64;
        let m = batch.model;

        let mut flops_total = 0.0;
        let mut kv_total = 0.0;
        for i in 0..batch.len() {
            let (f, kv) = Self::request_cost(batch, i);
            flops_total += f;
            kv_total += kv;
        }
        let flops_stage = flops_total / pp;
        let tokens = batch.total_new_tokens() as f64;
        let layers_pp = m.num_layers as f64 / pp;
        let h = m.hidden as f64;

        let wbytes = m.weight_bytes() / (tp * pp);
        let kv_bytes = kv_total / (tp * pp);

        let t_comp = flops_stage / (tp * g.peak_flops * e.flops_eff);
        let t_mem = (wbytes + kv_bytes) / (g.hbm_bw * e.mem_eff);

        let link_bw = g.interconnect.bandwidth();
        let link_lat = g.interconnect.latency();
        let act_bytes = tokens * h * 2.0;
        let ring = 2.0 * (tp - 1.0) / tp.max(1.0);
        let t_tp = if batch.tp > 1 {
            layers_pp * 2.0 * (ring * act_bytes / link_bw + link_lat)
        } else {
            0.0
        };
        let t_pp = if batch.pp > 1 {
            act_bytes / link_bw + link_lat
        } else {
            0.0
        };

        let t_stage = t_comp.max(t_mem)
            + t_tp
            + t_pp
            + e.t_overhead
            + layers_pp * e.layer_overhead;

        let mfu = flops_stage / (t_stage * tp * g.peak_flops);
        let power_w = g.power(mfu);

        StageCost {
            t_stage_s: t_stage,
            flops: flops_stage,
            mfu,
            power_w,
        }
    }
}

impl StageCostModel for NativeCost {
    fn stage_cost(&mut self, batch: &BatchDesc) -> StageCost {
        Self::compute(batch)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::ExecParams;
    use crate::config::{gpus, models};
    use crate::util::proptest::{check, gens};

    fn batch(tp: u32, pp: u32) -> BatchDesc {
        BatchDesc::new(
            models::model("llama3-8b").unwrap(),
            gpus::gpu("a100-80g").unwrap(),
            tp,
            pp,
            ExecParams::default(),
        )
    }

    #[test]
    fn empty_batch_costs_weight_read_plus_overhead() {
        let b = batch(1, 1);
        let c = NativeCost::compute(&b);
        let g = b.gpu;
        let expect = b.model.weight_bytes() / (g.hbm_bw * b.exec.mem_eff)
            + b.exec.t_overhead
            + 32.0 * b.exec.layer_overhead;
        assert!((c.t_stage_s - expect).abs() / expect < 1e-9);
        assert_eq!(c.flops, 0.0);
        assert_eq!(c.mfu, 0.0);
        assert_eq!(c.power_w, 100.0);
    }

    #[test]
    fn decode_memory_bound_low_mfu() {
        let mut b = batch(1, 1);
        for _ in 0..8 {
            b.push(1, 1024);
        }
        let c = NativeCost::compute(&b);
        assert!(c.mfu < 0.05, "mfu {}", c.mfu);
        assert!(c.power_w < 250.0);
        // Memory-bound: latency ≈ weight-read time.
        let wread = b.model.weight_bytes() / (b.gpu.hbm_bw * b.exec.mem_eff);
        assert!(c.t_stage_s > wread);
    }

    #[test]
    fn big_prefill_compute_bound_high_mfu() {
        let mut b = batch(1, 1);
        b.push(4096, 0);
        let c = NativeCost::compute(&b);
        assert!(c.mfu > 0.35, "mfu {}", c.mfu);
        assert!(c.power_w > 350.0);
    }

    #[test]
    fn mfu_never_exceeds_flops_eff() {
        // The efficiency ceiling is the Trainy plateau (DESIGN.md §5).
        for toks in [64u32, 256, 1024, 4096] {
            let mut b = batch(1, 1);
            b.push(toks, 0);
            let c = NativeCost::compute(&b);
            assert!(c.mfu <= b.exec.flops_eff + 1e-9);
        }
    }

    #[test]
    fn tp_halves_compute_time_roughly() {
        let mut b1 = batch(1, 1);
        b1.push(4096, 0);
        let mut b2 = batch(2, 1);
        b2.push(4096, 0);
        let c1 = NativeCost::compute(&b1);
        let c2 = NativeCost::compute(&b2);
        assert!(c2.t_stage_s < 0.7 * c1.t_stage_s);
        assert!(c2.t_stage_s > 0.4 * c1.t_stage_s); // comm overhead > 0
    }

    #[test]
    fn pp_stage_flops_split() {
        let mut b1 = batch(1, 1);
        b1.push(1024, 0);
        let mut b2 = batch(1, 2);
        b2.push(1024, 0);
        let c1 = NativeCost::compute(&b1);
        let c2 = NativeCost::compute(&b2);
        assert!((c2.flops - c1.flops / 2.0).abs() / c1.flops < 1e-9);
    }

    #[test]
    fn pcie_comm_slower_than_nvlink() {
        let mk = |gpu: &str| {
            let mut b = BatchDesc::new(
                models::model("llama2-7b").unwrap(),
                gpus::gpu(gpu).unwrap(),
                2,
                1,
                ExecParams::default(),
            );
            b.push(2048, 0);
            NativeCost::compute(&b)
        };
        // A40 is PCIe: same batch with TP=2 pays much more comm time
        // relative to its compute (can't directly compare absolute
        // times across GPUs, so compare comm fraction via the gap to
        // an ideal no-comm run).
        let a40 = mk("a40");
        let a100 = mk("a100-80g");
        assert!(a40.t_stage_s > a100.t_stage_s);
    }

    #[test]
    fn property_physical_invariants() {
        check(200, gens::u64_in(0, u64::MAX / 2), |&seed| {
            let mut rng = crate::util::rng::Rng::new(seed);
            let tp = *rng.choose(&[1u32, 2, 4]);
            let pp = *rng.choose(&[1u32, 2, 4]);
            let mut b = batch(tp, pp);
            let n = rng.int_range(0, 128);
            for _ in 0..n {
                if rng.f64() < 0.3 {
                    b.push(rng.int_range(2, 4096) as u32, 0);
                } else {
                    b.push(1, rng.int_range(0, 8192) as u32);
                }
            }
            let c = NativeCost::compute(&b);
            if !(c.t_stage_s > 0.0) {
                return Err(format!("nonpositive time {c:?}"));
            }
            if !(0.0..=1.0).contains(&c.mfu) {
                return Err(format!("mfu out of range {c:?}"));
            }
            if c.power_w < 100.0 - 1e-9 || c.power_w > 400.0 + 1e-9 {
                return Err(format!("power out of range {c:?}"));
            }
            Ok(())
        });
    }
}
