//! Interpolated cost-surface oracle — the "prediction model over
//! (batch, seq-len, config) signatures" framing of LLMCO2, specialized
//! to our closed-form roofline.
//!
//! Per (model, gpu, tp, pp, ExecParams) configuration, [`SurfaceCost`]
//! builds one [`SurfaceTable`]: the analytically-hoisted constants of
//! the stage-cost decomposition plus a (batch-size × mean-context)
//! grid of *residuals* sampled from an inner oracle ([`NativeCost`] or
//! [`super::hlo::HloCost`]). Every term of the native roofline is a
//! function of four batch aggregates —
//!
//! ```text
//! T  = Σ tᵢ        (new tokens)       CT = Σ cᵢ·tᵢ
//! T2 = Σ tᵢ²                          S  = Σ (cᵢ + tᵢ)
//! F  = kf_t·T + kf_ct·CT + kf_t2·T2   (total stage FLOPs)
//! t  = max(F·a_comp, m0 + m1·S) + c0 + c1·T + d
//! ```
//!
//! — so a query is one O(n) pass over the batch plus O(1) arithmetic:
//! the closed form *is* the exact additive correction for the
//! per-request token-sum terms, and the bilinear interpolation only
//! carries the inner oracle's deviation from it (identically zero for
//! the native inner, small f32/XLA rounding for the HLO inner). The
//! documented accuracy bound vs [`NativeCost::compute`] is 1e-6
//! relative (`rust/tests/surface_oracle.rs` pins it property-style
//! across random mixed batches; single-batch agreement is ~1e-8).
//!
//! Tables are plain `f64` arrays — `Send + Sync` — shared through a
//! process-global cache, so parallel sweep workers
//! ([`crate::sweep::SweepExecutor`]) reuse one build instead of
//! constructing a PJRT-bound oracle per worker. Each distinct
//! configuration is built exactly once per process;
//! [`super::OracleStats::surface_builds`] counts the builds an oracle
//! instance performed (later instances of the same config report 0).

use super::batch::{BatchDesc, StageCost, R_MAX};
use super::native::NativeCost;
use super::{OracleStats, StageCostModel};
use crate::config::gpus::GpuSpec;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Batch-size grid axis (decode batch sizes sampled for the residual
/// surface). Spans the full `R_MAX` admission range.
const B_AXIS: &[u32] = &[1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128];
/// Mean-context grid axis (tokens), geometric over the KV range the
/// schedulers produce.
const S_AXIS: &[u32] = &[0, 128, 256, 512, 1024, 2048, 4096, 8192, 16384];

/// Which oracle the surface is sampled from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SurfaceInner {
    Native,
    Hlo,
}

/// Identity of one precomputed surface: everything the stage cost
/// depends on besides the batch composition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SurfaceKey {
    model: &'static str,
    gpu: &'static str,
    tp: u32,
    pp: u32,
    flops_eff: u64,
    mem_eff: u64,
    t_overhead: u64,
    layer_overhead: u64,
    inner: SurfaceInner,
}

impl SurfaceKey {
    fn of(batch: &BatchDesc, inner: SurfaceInner) -> SurfaceKey {
        SurfaceKey {
            model: batch.model.name,
            gpu: batch.gpu.name,
            tp: batch.tp,
            pp: batch.pp,
            flops_eff: batch.exec.flops_eff.to_bits(),
            mem_eff: batch.exec.mem_eff.to_bits(),
            t_overhead: batch.exec.t_overhead.to_bits(),
            layer_overhead: batch.exec.layer_overhead.to_bits(),
            inner,
        }
    }
}

/// One config's precomputed surface: hoisted roofline constants plus
/// the inner-oracle residual grid. Plain data, `Send + Sync`.
pub struct SurfaceTable {
    /// t_comp = F · a_comp.
    a_comp: f64,
    /// t_mem = m0 + m1 · S.
    m0: f64,
    m1: f64,
    /// Communication: c0 + c1 · T (TP ring + PP boundary).
    c0: f64,
    c1: f64,
    /// Fixed + per-layer overheads.
    d: f64,
    /// F = kf_t·T + kf_ct·CT + kf_t2·T2.
    kf_t: f64,
    kf_ct: f64,
    kf_t2: f64,
    /// mfu = (F/pp) · inv_peak_tp / t.
    inv_peak_tp: f64,
    pp: f64,
    gpu: &'static GpuSpec,
    /// Residual grid, row-major `[b_idx][s_idx]`:
    /// t_inner − t_analytic at canonical decode batches.
    bs: Vec<f64>,
    ss: Vec<f64>,
    residual: Vec<f64>,
}

enum InnerOracle {
    Native,
    Hlo(super::hlo::HloCost),
}

impl InnerOracle {
    fn sample(&mut self, batch: &BatchDesc) -> StageCost {
        match self {
            InnerOracle::Native => NativeCost::compute(batch),
            InnerOracle::Hlo(h) => h.stage_cost(batch),
        }
    }
}

impl SurfaceTable {
    fn build(batch: &BatchDesc, inner_kind: SurfaceInner) -> SurfaceTable {
        let m = batch.model;
        let g = batch.gpu;
        let e = &batch.exec;
        let tp = batch.tp as f64;
        let pp = batch.pp as f64;
        let h = m.hidden as f64;
        let layers = m.num_layers as f64;
        let layers_pp = layers / pp;
        let kv_dim = m.kv_dim();

        let proj = 2.0 * h * (2.0 * h + 2.0 * kv_dim);
        let mlp = 6.0 * h * m.ffn_eff();
        let head = 2.0 * h * m.vocab as f64;
        let kf_t = layers * (proj + mlp) + head + layers * 2.0 * h;
        let kf_ct = layers * 4.0 * h;
        let kf_t2 = layers * 2.0 * h;

        let mem_den = tp * pp * g.hbm_bw * e.mem_eff;
        let m0 = m.weight_bytes() / mem_den;
        let m1 = 4.0 * layers * kv_dim / mem_den;
        let a_comp = 1.0 / (pp * tp * g.peak_flops * e.flops_eff);

        let link_bw = g.interconnect.bandwidth();
        let link_lat = g.interconnect.latency();
        let ring = 2.0 * (tp - 1.0) / tp.max(1.0);
        let (mut c0, mut c1) = (0.0, 0.0);
        if batch.tp > 1 {
            c0 += layers_pp * 2.0 * link_lat;
            c1 += layers_pp * 2.0 * ring * 2.0 * h / link_bw;
        }
        if batch.pp > 1 {
            c0 += link_lat;
            c1 += 2.0 * h / link_bw;
        }
        let d = e.t_overhead + layers_pp * e.layer_overhead;

        let mut table = SurfaceTable {
            a_comp,
            m0,
            m1,
            c0,
            c1,
            d,
            kf_t,
            kf_ct,
            kf_t2,
            inv_peak_tp: 1.0 / (tp * g.peak_flops),
            pp,
            gpu: g,
            bs: B_AXIS.iter().map(|&b| b as f64).collect(),
            ss: S_AXIS.iter().map(|&s| s as f64).collect(),
            residual: vec![0.0; B_AXIS.len() * S_AXIS.len()],
        };

        // Sample the inner oracle on canonical decode batches and store
        // its deviation from the closed form. The HLO inner is sampled
        // in exact mode — quantization would alias grid points. If the
        // HLO artifact store is unavailable despite being requested,
        // fall back to the native inner (residuals identically zero).
        let mut inner = match inner_kind {
            SurfaceInner::Native => InnerOracle::Native,
            SurfaceInner::Hlo => match super::hlo::HloCost::new() {
                Ok(h) => InnerOracle::Hlo(h.exact()),
                Err(_) => InnerOracle::Native,
            },
        };
        let mut probe = BatchDesc::new(batch.model, batch.gpu, batch.tp, batch.pp, e.clone());
        for (bi, &b) in B_AXIS.iter().enumerate() {
            for (si, &s) in S_AXIS.iter().enumerate() {
                probe.clear();
                for _ in 0..b {
                    probe.push(1, s);
                }
                let sampled = inner.sample(&probe).t_stage_s;
                // Aggregates of b decodes at context s.
                let t_sum = b as f64;
                let f = table.flops(t_sum, b as f64 * s as f64, t_sum);
                let analytic = table.analytic_t(f, t_sum * (s as f64 + 1.0), t_sum);
                table.residual[bi * S_AXIS.len() + si] = sampled - analytic;
            }
        }
        table
    }

    #[inline]
    fn flops(&self, t: f64, ct: f64, t2: f64) -> f64 {
        self.kf_t * t + self.kf_ct * ct + self.kf_t2 * t2
    }

    #[inline]
    fn analytic_t(&self, f: f64, s: f64, t: f64) -> f64 {
        (f * self.a_comp).max(self.m0 + self.m1 * s) + self.c0 + self.c1 * t + self.d
    }

    /// Locate `x` on `axis`: bracketing indices and the interpolation
    /// weight toward the upper one (clamped at the edges).
    #[inline]
    fn locate(axis: &[f64], x: f64) -> (usize, usize, f64) {
        if x <= axis[0] {
            return (0, 0, 0.0);
        }
        let last = axis.len() - 1;
        if x >= axis[last] {
            return (last, last, 0.0);
        }
        let hi = axis.partition_point(|&v| v <= x);
        let lo = hi - 1;
        (lo, hi, (x - axis[lo]) / (axis[hi] - axis[lo]))
    }

    /// Bilinear residual at (batch size, mean context).
    #[inline]
    fn residual_at(&self, n: f64, ctx_mean: f64) -> f64 {
        let (b0, b1, wb) = Self::locate(&self.bs, n);
        let (s0, s1, ws) = Self::locate(&self.ss, ctx_mean);
        let w = self.ss.len();
        let r00 = self.residual[b0 * w + s0];
        let r01 = self.residual[b0 * w + s1];
        let r10 = self.residual[b1 * w + s0];
        let r11 = self.residual[b1 * w + s1];
        let lo = r00 + (r01 - r00) * ws;
        let hi = r10 + (r11 - r10) * ws;
        lo + (hi - lo) * wb
    }

    /// Price one pipeline stage of `batch`: one pass of aggregate
    /// accumulation, the closed form, plus the interpolated residual.
    pub fn eval(&self, batch: &BatchDesc) -> StageCost {
        let n = batch.len();
        let (mut t_sum, mut ct, mut t2, mut s_sum) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for i in 0..n {
            let t = batch.new_tokens[i] as f64;
            let c = batch.context[i] as f64;
            t_sum += t;
            ct += c * t;
            t2 += t * t;
            s_sum += c + t;
        }
        let f = self.flops(t_sum, ct, t2);
        let mut t = self.analytic_t(f, s_sum, t_sum);
        if n > 0 {
            let ctx_mean = (s_sum - t_sum) / n as f64;
            t += self.residual_at(n as f64, ctx_mean);
        }
        let flops_stage = f / self.pp;
        let mfu = if f > 0.0 && t > 0.0 {
            flops_stage * self.inv_peak_tp / t
        } else {
            0.0
        };
        StageCost {
            t_stage_s: t,
            flops: flops_stage,
            mfu,
            power_w: self.gpu.power(mfu),
        }
    }
}

/// Process-global surface cache: each distinct [`SurfaceKey`] is built
/// exactly once per process, whichever thread asks first, and shared
/// as a plain `Arc`.
fn surfaces() -> &'static Mutex<HashMap<SurfaceKey, Arc<SurfaceTable>>> {
    static SURFACES: OnceLock<Mutex<HashMap<SurfaceKey, Arc<SurfaceTable>>>> = OnceLock::new();
    SURFACES.get_or_init(|| Mutex::new(HashMap::new()))
}

/// The surface-interpolation stage oracle. `Send`-compatible state
/// only — sweep workers each hold an instance, all pointing at the
/// shared per-config tables.
pub struct SurfaceCost {
    inner: SurfaceInner,
    key: Option<SurfaceKey>,
    table: Option<Arc<SurfaceTable>>,
    calls: u64,
    hits: u64,
    builds: u64,
}

impl SurfaceCost {
    /// Sample from the HLO oracle when the artifact store is present,
    /// else from the native roofline — the same availability fallback
    /// the benches use.
    pub fn new() -> Self {
        let inner = if crate::runtime::ArtifactStore::discover().is_ok() {
            SurfaceInner::Hlo
        } else {
            SurfaceInner::Native
        };
        Self::with_inner(inner)
    }

    pub fn with_inner(inner: SurfaceInner) -> Self {
        SurfaceCost {
            inner,
            key: None,
            table: None,
            calls: 0,
            hits: 0,
            builds: 0,
        }
    }

    /// Surfaces built by this instance (0 when every config this
    /// oracle touched was already in the process-global cache).
    pub fn builds(&self) -> u64 {
        self.builds
    }

    fn resolve(&mut self, batch: &BatchDesc) -> Arc<SurfaceTable> {
        let key = SurfaceKey::of(batch, self.inner);
        if self.key == Some(key) {
            if let Some(t) = &self.table {
                self.hits += 1;
                return Arc::clone(t);
            }
        }
        let mut map = surfaces().lock().expect("surface cache poisoned");
        let table = match map.get(&key) {
            Some(t) => Arc::clone(t),
            None => {
                let t = Arc::new(SurfaceTable::build(batch, self.inner));
                map.insert(key, Arc::clone(&t));
                self.builds += 1;
                t
            }
        };
        drop(map);
        self.key = Some(key);
        self.table = Some(Arc::clone(&table));
        table
    }
}

impl Default for SurfaceCost {
    fn default() -> Self {
        Self::new()
    }
}

impl StageCostModel for SurfaceCost {
    fn stage_cost(&mut self, batch: &BatchDesc) -> StageCost {
        debug_assert!(batch.len() <= R_MAX);
        self.calls += 1;
        let table = self.resolve(batch);
        table.eval(batch)
    }

    fn name(&self) -> &'static str {
        "surface"
    }

    fn stats(&self) -> OracleStats {
        OracleStats {
            calls: self.calls,
            hits: self.hits,
            resets: 0,
            surface_builds: self.builds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::ExecParams;
    use crate::config::{gpus, models};

    fn batch(tp: u32, pp: u32, flops_eff: f64) -> BatchDesc {
        let exec = ExecParams {
            flops_eff,
            ..ExecParams::default()
        };
        BatchDesc::new(
            models::model("llama3-8b").unwrap(),
            gpus::gpu("a100-80g").unwrap(),
            tp,
            pp,
            exec,
        )
    }

    #[test]
    fn matches_native_closed_form() {
        // Mixed batches across parallelism configs: the native-inner
        // surface must agree with NativeCost to float precision.
        for (tp, pp) in [(1u32, 1u32), (2, 1), (1, 2), (2, 2)] {
            let mut oracle = SurfaceCost::with_inner(SurfaceInner::Native);
            let mut b = batch(tp, pp, 0.46);
            b.push(512, 0);
            b.push(1, 777);
            b.push(1, 3000);
            b.push(96, 1024);
            let got = oracle.stage_cost(&b);
            let want = NativeCost::compute(&b);
            let rel = (got.t_stage_s - want.t_stage_s).abs() / want.t_stage_s;
            assert!(rel < 1e-8, "tp={tp} pp={pp}: rel err {rel}");
            assert!((got.mfu - want.mfu).abs() < 1e-8);
            assert!((got.power_w - want.power_w).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_batch_matches_native() {
        let mut oracle = SurfaceCost::with_inner(SurfaceInner::Native);
        let b = batch(1, 1, 0.46);
        let got = oracle.stage_cost(&b);
        let want = NativeCost::compute(&b);
        let rel = (got.t_stage_s - want.t_stage_s).abs() / want.t_stage_s;
        assert!(rel < 1e-9, "rel err {rel}");
        assert_eq!(got.mfu, 0.0);
    }

    #[test]
    fn tables_shared_across_instances() {
        // A unique flops_eff keys a fresh surface: the first instance
        // builds it, the second finds it in the process-global cache.
        let mut b = batch(1, 1, 0.460_731);
        b.push(1, 512);
        let mut first = SurfaceCost::with_inner(SurfaceInner::Native);
        first.stage_cost(&b);
        assert_eq!(first.builds(), 1);
        let mut second = SurfaceCost::with_inner(SurfaceInner::Native);
        second.stage_cost(&b);
        second.stage_cost(&b);
        assert_eq!(second.builds(), 0);
        let st = second.stats();
        assert_eq!(st.calls, 2);
        assert_eq!(st.hits, 1); // first call resolved, second was warm
        assert_eq!(st.surface_builds, 0);
        assert_eq!(second.name(), "surface");
    }
}
