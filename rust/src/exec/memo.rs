//! Segmented (second-chance) memo cache for the stage oracles.
//!
//! The previous eviction policy was `HashMap::clear()` on overflow:
//! one cold signature past `CACHE_CAP` discarded every hot entry and
//! forced the oracle to re-execute the steady-state working set —
//! visible in telemetry as reset thrash. `SegmentedMemo` keeps two
//! generations instead: inserts land in `cur`; when `cur` fills, it
//! becomes `prev` and only the *old* `prev` (entries not touched for a
//! full generation) is dropped. A hit in `prev` promotes the entry
//! back into `cur`, so anything accessed at least once per generation
//! survives forever.
//!
//! Invariant (pinned by `working_set_within_cap_never_resets`): a
//! working set of at most `cap` distinct keys never loses an entry and
//! never increments `resets`. Worst-case resident size is `2 * cap`
//! (both segments full), so callers size `cap` at half their old
//! hard limit to keep the same memory ceiling.

use std::collections::HashMap;

/// Two-generation memo map with second-chance eviction.
#[derive(Debug)]
pub struct SegmentedMemo<V> {
    cur: HashMap<u64, V>,
    prev: HashMap<u64, V>,
    cap: usize,
    /// Rotations that actually dropped entries (a non-empty old
    /// generation was discarded). Rotations of an empty `prev` are
    /// free and not counted.
    pub resets: u64,
}

impl<V: Copy> SegmentedMemo<V> {
    /// `cap` is the per-generation capacity; resident size is bounded
    /// by `2 * cap`.
    pub fn new(cap: usize) -> Self {
        SegmentedMemo {
            cur: HashMap::new(),
            prev: HashMap::new(),
            cap: cap.max(1),
            resets: 0,
        }
    }

    /// Look `key` up in either generation; a `prev` hit promotes the
    /// entry into `cur` so it survives the next rotation.
    #[inline]
    pub fn get(&mut self, key: u64) -> Option<V> {
        if let Some(&v) = self.cur.get(&key) {
            return Some(v);
        }
        if let Some(v) = self.prev.remove(&key) {
            self.insert(key, v);
            return Some(v);
        }
        None
    }

    /// Insert `key`, rotating generations when `cur` is full.
    #[inline]
    pub fn insert(&mut self, key: u64, value: V) {
        if self.cur.len() >= self.cap && !self.cur.contains_key(&key) {
            let dropped = std::mem::take(&mut self.prev);
            if !dropped.is_empty() {
                self.resets += 1;
            }
            self.prev = std::mem::take(&mut self.cur);
        }
        self.cur.insert(key, value);
    }

    /// Total resident entries across both generations.
    pub fn len(&self) -> usize {
        self.cur.len() + self.prev.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cur.is_empty() && self.prev.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn working_set_within_cap_never_resets() {
        // The satellite invariant: a working set <= cap cycles forever
        // without losing a single entry or counting a reset.
        let cap = 8;
        let mut memo: SegmentedMemo<u64> = SegmentedMemo::new(cap);
        for round in 0..50 {
            for k in 0..cap as u64 {
                match memo.get(k) {
                    Some(v) => assert_eq!(v, k * 10),
                    None => {
                        assert_eq!(round, 0, "entry {k} lost after round {round}");
                        memo.insert(k, k * 10);
                    }
                }
            }
        }
        assert_eq!(memo.resets, 0);
        assert_eq!(memo.len(), cap);
    }

    #[test]
    fn overflow_keeps_recent_generation() {
        let mut memo: SegmentedMemo<u64> = SegmentedMemo::new(4);
        // Fill two full generations (8 distinct cold keys).
        for k in 0..8 {
            memo.insert(k, k);
        }
        // No entries dropped yet: first rotation retired an empty prev.
        assert_eq!(memo.resets, 0);
        assert_eq!(memo.len(), 8);
        // A third generation drops the oldest four, keeps 4..8.
        for k in 8..12 {
            memo.insert(k, k);
        }
        assert_eq!(memo.resets, 1);
        for k in 4..12 {
            assert_eq!(memo.get(k), Some(k), "recent key {k} evicted");
        }
        for k in 0..4 {
            assert_eq!(memo.get(k), None, "cold key {k} survived");
        }
    }

    #[test]
    fn prev_hit_promotes() {
        let mut memo: SegmentedMemo<u64> = SegmentedMemo::new(2);
        memo.insert(1, 11);
        memo.insert(2, 22);
        memo.insert(3, 33); // rotates: prev = {1, 2}
        assert_eq!(memo.get(1), Some(11)); // promoted into cur
        memo.insert(4, 44); // rotates: prev = {1, 3}; {2} dropped
        memo.insert(5, 55);
        assert_eq!(memo.get(1), Some(11), "promoted entry lost");
        assert_eq!(memo.get(2), None);
    }

    #[test]
    fn resident_bounded_by_two_cap() {
        let cap = 16;
        let mut memo: SegmentedMemo<u64> = SegmentedMemo::new(cap);
        for k in 0..10_000 {
            memo.insert(k, k);
            assert!(memo.len() <= 2 * cap);
        }
        assert!(memo.resets > 0);
    }
}
