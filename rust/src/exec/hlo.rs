//! The AOT stage oracle: batch-stage cost evaluated by the compiled
//! JAX/Pallas artifact (`artifacts/stage_oracle.hlo.txt`) through PJRT.
//!
//! This is the default request-path backend of the three-layer
//! architecture. A quantized-signature memo cache keeps the PJRT call
//! count sublinear in simulated stages: batch compositions are rounded
//! to token buckets (context to 256, prefill chunks to 128 — both far
//! below the weight-read term they perturb), sorted, hashed, and looked
//! up before falling back to execution. The cache is a two-generation
//! [`SegmentedMemo`] (second-chance eviction), so overflow drops only
//! the cold half instead of resetting the hot working set.
//!
//! Hot-path allocation: zero. The canonical-pairs scratch is a reused
//! field, and a last-call fast path skips the quantize/sort/hash
//! rebuild entirely when the raw batch composition and config repeat —
//! the common steady-decode case, where consecutive stages price the
//! identical batch.

use super::batch::{BatchDesc, StageCost, R_MAX};
use super::memo::SegmentedMemo;
use super::{OracleStats, StageCostModel};
use crate::runtime::pjrt::cached_executable;
use crate::runtime::Executable;
use anyhow::Result;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::rc::Rc;

/// Context-length quantization bucket (tokens).
const CTX_BUCKET: u32 = 256;
/// Prefill-chunk quantization bucket (tokens).
const PREFILL_BUCKET: u32 = 128;
/// Memo resident-entry ceiling. Split across the two generations of
/// the segmented cache (per-generation capacity `CACHE_CAP / 2`), so
/// the memory bound matches the old clear-on-overflow limit.
const CACHE_CAP: usize = 1 << 20;

pub struct HloCost {
    exe: Rc<Executable>,
    cache: SegmentedMemo<StageCost>,
    /// Reused padded input buffers (zero-allocation hot path).
    nt_buf: Vec<f32>,
    ctx_buf: Vec<f32>,
    act_buf: Vec<f32>,
    /// Quantization on/off (exact signatures when off).
    quantize: bool,
    /// Reused canonical-pairs scratch; always holds the pairs of the
    /// most recent signature (`last_sig`), so a fast-path hit can still
    /// execute on a memo miss.
    pairs: Vec<(u32, u32)>,
    /// Raw composition + config of the previous call: when they repeat
    /// exactly, `last_sig` is reused without rebuilding the pairs.
    last_nt: Vec<u32>,
    last_ctx: Vec<u32>,
    last_tp: u32,
    last_pp: u32,
    last_model: &'static str,
    last_gpu: &'static str,
    last_flops_eff: u64,
    last_t_overhead: u64,
    last_sig: u64,
    has_last: bool,
    pub calls: u64,
    pub hits: u64,
}

impl HloCost {
    pub fn new() -> Result<Self> {
        let exe = cached_executable("stage_oracle")?;
        Ok(HloCost {
            exe,
            cache: SegmentedMemo::new(CACHE_CAP / 2),
            nt_buf: vec![0.0; R_MAX],
            ctx_buf: vec![0.0; R_MAX],
            act_buf: vec![0.0; R_MAX],
            quantize: true,
            pairs: Vec::with_capacity(R_MAX),
            last_nt: Vec::with_capacity(R_MAX),
            last_ctx: Vec::with_capacity(R_MAX),
            last_tp: 0,
            last_pp: 0,
            last_model: "",
            last_gpu: "",
            last_flops_eff: 0,
            last_t_overhead: 0,
            last_sig: 0,
            has_last: false,
            calls: 0,
            hits: 0,
        })
    }

    /// Disable signature quantization (exact evaluation; used by the
    /// native/HLO parity tests).
    pub fn exact(mut self) -> Self {
        self.quantize = false;
        self
    }

    /// Times the memo overflowed and dropped its cold generation.
    pub fn resets(&self) -> u64 {
        self.cache.resets
    }

    /// Build the canonical (quantized) batch representation used both
    /// as the cache key and as the oracle's evaluation input.
    ///
    /// Decode entries (1 new token each) are *aggregated*: per-request
    /// FLOPs and KV bytes are linear in the context length, so a batch
    /// of n decodes with contexts summing to S prices identically to n
    /// decodes at the mean context S/n — the aggregation is exact up
    /// to the sum bucket (512 tokens of KV ≈ 0.4% of one weight read).
    /// Prefill entries keep per-request identity (the t² causal term
    /// is nonlinear) with chunk/context bucketing.
    fn signature(quantize: bool, batch: &BatchDesc, pairs: &mut Vec<(u32, u32)>) -> u64 {
        pairs.clear();
        if !quantize {
            for i in 0..batch.len() {
                pairs.push((batch.new_tokens[i], batch.context[i]));
            }
        } else {
            let q = |x: u32, b: u32| (x + b / 2) / b * b;
            let mut n_decode = 0u32;
            let mut ctx_sum = 0u64;
            for i in 0..batch.len() {
                let nt = batch.new_tokens[i];
                if nt <= 1 {
                    n_decode += 1;
                    ctx_sum += batch.context[i] as u64;
                } else {
                    pairs.push((
                        q(nt, PREFILL_BUCKET).max(2),
                        q(batch.context[i], CTX_BUCKET),
                    ));
                }
            }
            if n_decode > 0 {
                let sum_bucketed = (ctx_sum + 256) / 512 * 512;
                let mean_ctx = (sum_bucketed / n_decode as u64) as u32;
                for _ in 0..n_decode {
                    pairs.push((1, mean_ctx));
                }
            }
        }
        pairs.sort_unstable();
        let mut h = DefaultHasher::new();
        batch.model.name.hash(&mut h);
        batch.gpu.name.hash(&mut h);
        (batch.tp, batch.pp).hash(&mut h);
        batch.exec.flops_eff.to_bits().hash(&mut h);
        batch.exec.t_overhead.to_bits().hash(&mut h);
        pairs.hash(&mut h);
        h.finish()
    }

    /// True when `batch` is byte-for-byte the previous call's input —
    /// the signature is guaranteed unchanged and need not be rebuilt.
    #[inline]
    fn same_as_last(&self, batch: &BatchDesc) -> bool {
        self.has_last
            && self.last_tp == batch.tp
            && self.last_pp == batch.pp
            && self.last_model == batch.model.name
            && self.last_gpu == batch.gpu.name
            && self.last_flops_eff == batch.exec.flops_eff.to_bits()
            && self.last_t_overhead == batch.exec.t_overhead.to_bits()
            && self.last_nt == batch.new_tokens
            && self.last_ctx == batch.context
    }

    #[inline]
    fn remember(&mut self, batch: &BatchDesc, sig: u64) {
        self.last_nt.clear();
        self.last_nt.extend_from_slice(&batch.new_tokens);
        self.last_ctx.clear();
        self.last_ctx.extend_from_slice(&batch.context);
        self.last_tp = batch.tp;
        self.last_pp = batch.pp;
        self.last_model = batch.model.name;
        self.last_gpu = batch.gpu.name;
        self.last_flops_eff = batch.exec.flops_eff.to_bits();
        self.last_t_overhead = batch.exec.t_overhead.to_bits();
        self.last_sig = sig;
        self.has_last = true;
    }

    fn execute(&mut self, batch: &BatchDesc) -> Result<StageCost> {
        self.nt_buf.iter_mut().for_each(|x| *x = 0.0);
        self.ctx_buf.iter_mut().for_each(|x| *x = 0.0);
        self.act_buf.iter_mut().for_each(|x| *x = 0.0);
        for (i, &(nt, ctx)) in self.pairs.iter().enumerate() {
            self.nt_buf[i] = nt as f32;
            self.ctx_buf[i] = ctx as f32;
            self.act_buf[i] = 1.0;
        }
        let mp = batch.model.param_vec(batch.tp, batch.pp);
        let gp = batch.gpu_param_vec();
        let out = self.exe.call_f32(&[
            &self.nt_buf,
            &self.ctx_buf,
            &self.act_buf,
            &mp,
            &gp,
        ])?;
        anyhow::ensure!(out.len() == 4, "stage oracle returned {} outputs", out.len());
        Ok(StageCost {
            t_stage_s: out[0][0] as f64,
            flops: out[1][0] as f64,
            mfu: out[2][0] as f64,
            power_w: out[3][0] as f64,
        })
    }
}

impl StageCostModel for HloCost {
    fn stage_cost(&mut self, batch: &BatchDesc) -> StageCost {
        debug_assert!(batch.len() <= R_MAX);
        self.calls += 1;
        let sig = if self.same_as_last(batch) {
            self.last_sig
        } else {
            let mut pairs = std::mem::take(&mut self.pairs);
            let sig = Self::signature(self.quantize, batch, &mut pairs);
            self.pairs = pairs;
            self.remember(batch, sig);
            sig
        };
        if let Some(c) = self.cache.get(sig) {
            self.hits += 1;
            return c;
        }
        let cost = self
            .execute(batch)
            .expect("stage oracle execution failed");
        self.cache.insert(sig, cost);
        cost
    }

    fn name(&self) -> &'static str {
        "hlo"
    }

    fn stats(&self) -> OracleStats {
        OracleStats {
            calls: self.calls,
            hits: self.hits,
            resets: self.cache.resets,
            ..Default::default()
        }
    }
}
