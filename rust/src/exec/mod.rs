//! Execution-time / MFU / power oracle for batch stages.
//!
//! Three interchangeable backends behind [`StageCostModel`]:
//! * [`native::NativeCost`] — pure-rust analytical roofline (mirrors
//!   python/compile/kernels/ref.py exactly; used for cross-checking and
//!   fast sweeps);
//! * [`hlo::HloCost`] — the AOT-compiled JAX/Pallas stage oracle
//!   executed via PJRT (the three-layer architecture's default hot
//!   path), with a quantized-signature memo cache;
//! * [`surface::SurfaceCost`] — the interpolated cost surface
//!   (DESIGN.md §12): per-config tables sampled once from an inner
//!   oracle and shared process-wide, reducing each stage query to an
//!   O(batch) aggregate pass + bilinear interpolation.
//!
//! All substitute Vidur's random-forest runtime predictor (see
//! DESIGN.md §5); an optional log-normal noise layer emulates the
//! learned predictor's spread.

pub mod batch;
pub mod memo;
pub mod native;
pub mod hlo;
pub mod surface;

pub use batch::{BatchDesc, StageCost};

use crate::config::simconfig::{CostModelKind, SimConfig};
use crate::util::json::Value;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU8, Ordering};

/// Memo-cache statistics of a cost oracle: every `stage_cost` call,
/// how many were served from the cache, and how often the cache was
/// reset after overflowing its capacity. Surfaced in the metrics JSON
/// and each experiment's `meta.json` so sweep-performance regressions
/// (a collapsing hit rate, reset thrash) are observable per run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OracleStats {
    pub calls: u64,
    pub hits: u64,
    pub resets: u64,
    /// Cost-surface tables built ([`surface::SurfaceCost`]); zero for
    /// the other backends. Summed across a sweep's cases, this is the
    /// number of distinct configurations priced (each built once
    /// process-wide, regardless of `--jobs`).
    pub surface_builds: u64,
}

impl OracleStats {
    pub fn hit_rate(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.hits as f64 / self.calls as f64
        }
    }

    /// Sum component-wise (aggregating a sweep's cases).
    pub fn merge(&mut self, other: &OracleStats) {
        self.calls += other.calls;
        self.hits += other.hits;
        self.resets += other.resets;
        self.surface_builds += other.surface_builds;
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("calls", self.calls)
            .set("hits", self.hits)
            .set("resets", self.resets)
            .set("surface_builds", self.surface_builds)
            .set("hit_rate", self.hit_rate());
        v
    }

    /// Reload stats serialized by [`OracleStats::to_json`] (the shard
    /// telemetry sidecar / merged `meta.json`). `hit_rate` is derived,
    /// not stored; `surface_builds` is optional so sidecars written
    /// before the surface oracle existed still parse.
    pub fn from_json(v: &Value) -> crate::Result<OracleStats> {
        Ok(OracleStats {
            calls: v.req_u64("calls")?,
            hits: v.req_u64("hits")?,
            resets: v.req_u64("resets")?,
            surface_builds: v
                .get("surface_builds")
                .and_then(|x| x.as_u64())
                .unwrap_or(0),
        })
    }
}

/// The oracle interface the simulator hot path calls once per batch
/// stage. Not `Send`: the PJRT client is thread-affine — parallel
/// sweeps ([`crate::sweep`]) build one model per worker thread instead
/// (the compiled executable itself is shared per-thread through the
/// `runtime::pjrt` thread-local cache, so each worker compiles once).
pub trait StageCostModel {
    /// Cost of executing `batch` for ONE pipeline-parallel stage
    /// (layers/pp of the model on a TP group).
    fn stage_cost(&mut self, batch: &BatchDesc) -> StageCost;

    /// Backend name for logs/reports.
    fn name(&self) -> &'static str;

    /// Memo-cache statistics — all zero for backends without a cache.
    fn stats(&self) -> OracleStats {
        OracleStats::default()
    }
}

/// Multiplicative log-normal noise wrapper emulating Vidur's learned
/// (random-forest, k=10) predictor spread around the analytical model.
pub struct NoisyCost<M: StageCostModel> {
    inner: M,
    rng: Rng,
    sigma: f64,
}

impl<M: StageCostModel> NoisyCost<M> {
    pub fn new(inner: M, sigma: f64, seed: u64) -> Self {
        NoisyCost {
            inner,
            rng: Rng::new(seed ^ 0x5EED_CAFE),
            sigma,
        }
    }
}

impl<M: StageCostModel> StageCostModel for NoisyCost<M> {
    fn stage_cost(&mut self, batch: &BatchDesc) -> StageCost {
        let mut c = self.inner.stage_cost(batch);
        if self.sigma > 0.0 {
            let f = self.rng.lognormal(0.0, self.sigma);
            c.t_stage_s *= f;
            // MFU moves inversely with time (same flops, new latency);
            // recompute power consistently through the same power law.
            c.mfu /= f;
            c.power_w = batch.gpu_power(c.mfu);
        }
        c
    }
    fn name(&self) -> &'static str {
        "noisy"
    }
}

/// Process-wide oracle override (`--oracle` on the CLI): when set, it
/// wins over every `SimConfig::cost_model` — the lever that lets one
/// flag retarget experiment suites whose grids build their own
/// configs. Same process-global pattern as `sweep::set_default_jobs`.
static ORACLE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

pub fn set_oracle_override(kind: Option<CostModelKind>) {
    let v = match kind {
        None => 0,
        Some(CostModelKind::Native) => 1,
        Some(CostModelKind::Hlo) => 2,
        Some(CostModelKind::Surface) => 3,
    };
    ORACLE_OVERRIDE.store(v, Ordering::Relaxed);
}

pub fn oracle_override() -> Option<CostModelKind> {
    match ORACLE_OVERRIDE.load(Ordering::Relaxed) {
        1 => Some(CostModelKind::Native),
        2 => Some(CostModelKind::Hlo),
        3 => Some(CostModelKind::Surface),
        _ => None,
    }
}

/// Build the configured cost model (native, HLO-oracle, or surface),
/// wrapped in noise when `exec.rf_noise_std > 0`. A process-wide
/// [`set_oracle_override`] takes precedence over the config.
pub fn build_cost_model(cfg: &SimConfig) -> crate::Result<Box<dyn StageCostModel>> {
    let kind = oracle_override().unwrap_or(cfg.cost_model);
    let base: Box<dyn StageCostModel> = match kind {
        CostModelKind::Native => Box::new(native::NativeCost::new()),
        CostModelKind::Hlo => Box::new(hlo::HloCost::new()?),
        CostModelKind::Surface => Box::new(surface::SurfaceCost::new()),
    };
    if cfg.exec.rf_noise_std > 0.0 {
        Ok(Box::new(NoisyBox {
            inner: base,
            rng: Rng::new(cfg.seed ^ 0x5EED_CAFE),
            sigma: cfg.exec.rf_noise_std,
        }))
    } else {
        Ok(base)
    }
}

/// Object-safe noise wrapper (for boxed models).
struct NoisyBox {
    inner: Box<dyn StageCostModel>,
    rng: Rng,
    sigma: f64,
}

impl StageCostModel for NoisyBox {
    fn stage_cost(&mut self, batch: &BatchDesc) -> StageCost {
        let mut c = self.inner.stage_cost(batch);
        let f = self.rng.lognormal(0.0, self.sigma);
        c.t_stage_s *= f;
        c.mfu /= f;
        c.power_w = batch.gpu_power(c.mfu);
        c
    }
    fn name(&self) -> &'static str {
        "noisy"
    }
    fn stats(&self) -> OracleStats {
        self.inner.stats()
    }
}
