//! Host manifests for `repro fleet` (DESIGN.md §15): the list of
//! `repro serve` agents a fleet launch fans its shards across.
//!
//! The format is deliberately tiny — one entry per line:
//!
//! ```text
//! # comment lines and blanks are skipped
//! 10.0.0.7:7878         # a remote `repro serve` endpoint
//! sim-host-2:7878
//! local:2               # spawn 2 local `repro serve` child processes
//! ```
//!
//! The same entries can come from repeated `--host` flags instead of a
//! file. Parse errors are loud and positional (`path:line: message`) —
//! a fleet launch that silently dropped a host would quietly shrink
//! the sweep's shard count.

use anyhow::{bail, Result};
use std::path::Path;

/// A parsed host manifest: remote serve endpoints plus a count of
/// local agent processes to spawn.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Manifest {
    /// `host:port` serve endpoints, in manifest order.
    pub endpoints: Vec<String>,
    /// Local `repro serve` children to spawn (the sum of `local:N`
    /// entries).
    pub local: usize,
}

impl Manifest {
    /// Total hosts this manifest names.
    pub fn host_count(&self) -> usize {
        self.endpoints.len() + self.local
    }

    /// Parse manifest text. `origin` names the source in errors — the
    /// file path, or a stand-in like `--host` for flag-provided
    /// entries.
    pub fn parse(text: &str, origin: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            // Strip trailing comments, then whitespace.
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(n) = line.strip_prefix("local:") {
                let n: usize = n.trim().parse().map_err(|_| {
                    anyhow::anyhow!(
                        "{origin}:{lineno}: bad local worker count '{n}' \
                         (expected local:N with N >= 1)"
                    )
                })?;
                if n == 0 {
                    bail!("{origin}:{lineno}: local:0 names no hosts (expected N >= 1)");
                }
                m.local += n;
                continue;
            }
            match validate_endpoint(line) {
                Ok(ep) => m.endpoints.push(ep),
                Err(e) => bail!("{origin}:{lineno}: {e:#}"),
            }
        }
        Ok(m)
    }

    /// Load and parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: cannot read manifest: {e}", path.display()))?;
        Manifest::parse(&text, &path.display().to_string())
    }

    /// Build a manifest from flag-provided entries (each one line of
    /// the file format). Errors cite `--host:<n>` as the position.
    pub fn from_entries(entries: &[String]) -> Result<Manifest> {
        Manifest::parse(&entries.join("\n"), "--host")
    }
}

/// Validate one `host:port` endpoint. Ports must parse (a typo'd
/// `host:78788` would otherwise surface much later as a connect
/// failure with a worse message).
fn validate_endpoint(s: &str) -> Result<String> {
    let Some((host, port)) = s.rsplit_once(':') else {
        bail!("'{s}' is not host:port or local:N");
    };
    if host.is_empty() {
        bail!("'{s}' has an empty host");
    }
    if port.parse::<u16>().is_err() {
        bail!("'{s}' has a bad port '{port}' (expected 1..65535)");
    }
    Ok(s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_endpoints_locals_comments_and_blanks() {
        let m = Manifest::parse(
            "# fleet for the paper grid\n\
             10.0.0.7:7878\n\
             \n\
             sim-host-2:7878  # trailing comment\n\
             local:2\n\
             local:1\n",
            "hosts.txt",
        )
        .unwrap();
        assert_eq!(m.endpoints, vec!["10.0.0.7:7878", "sim-host-2:7878"]);
        assert_eq!(m.local, 3);
        assert_eq!(m.host_count(), 5);
        // IPv6-ish / multi-colon endpoints split on the *last* colon.
        let m = Manifest::parse("::1:7878\n", "hosts.txt").unwrap();
        assert_eq!(m.endpoints, vec!["::1:7878"]);
    }

    #[test]
    fn errors_are_loud_with_path_and_line() {
        let cases = [
            ("ok:7878\nnot-an-endpoint\n", "hosts.txt:2"),
            ("local:zero\n", "hosts.txt:1"),
            ("\n\nlocal:0\n", "hosts.txt:3"),
            ("host:99999\n", "hosts.txt:1"),
            (":7878\n", "hosts.txt:1"),
        ];
        for (text, want) in cases {
            let e = Manifest::parse(text, "hosts.txt").unwrap_err();
            assert!(
                format!("{e:#}").contains(want),
                "error for {text:?} must cite {want}: {e:#}"
            );
        }
    }

    #[test]
    fn flag_entries_cite_the_flag() {
        let m =
            Manifest::from_entries(&["127.0.0.1:7878".into(), "local:2".into()]).unwrap();
        assert_eq!(m.host_count(), 3);
        let e = Manifest::from_entries(&["bogus".into()]).unwrap_err();
        assert!(format!("{e:#}").contains("--host:1"), "{e:#}");
    }

    #[test]
    fn load_names_the_missing_file() {
        let e = Manifest::load(Path::new("/nonexistent/hosts.txt")).unwrap_err();
        assert!(format!("{e:#}").contains("/nonexistent/hosts.txt"), "{e:#}");
    }
}
