//! The fleet supervisor (DESIGN.md §15): shard a sweep across a set
//! of `repro serve` hosts, survive host deaths by re-sharding the lost
//! work over the survivors, and auto-merge the shard outputs when the
//! last assignment lands.
//!
//! The one invariant everything here defends: **the merged output is
//! byte-identical to an unsharded run**, whatever subset of hosts
//! survived. It holds because
//!
//! 1. a host's output only enters the merge once its job reports
//!    `done` — a dead host's partial directory is never read, and
//! 2. [`reshard`] splits a lost shard `k/M` into sub-shards
//!    `(k + u·M) / (s·M)` for `u in 0..s`, whose ownership classes
//!    `i ≡ k + u·M (mod s·M)` partition exactly `i ≡ k (mod M)` —
//!    the lost cases, each exactly once, and
//! 3. `merge_shard_dirs` orders rows by *global case index*, so mixed
//!    shard denominators from re-sharding cannot perturb the output.

use crate::fleet::client::{get_json, health_ok, post_json, SseSubscription};
use crate::fleet::manifest::Manifest;
use crate::report::live::{aggregate, render_watch, snapshot_supersedes};
use crate::sweep::{merge_shard_dirs, MergedExperiment, ShardSpec};
use crate::telemetry::window::Snapshot;
use crate::util::json::{parse, Value};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One fleet launch: what to run, where, and how patient to be.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Experiment id (`exp1`, `scenarios`, `all`, …) — validated by
    /// each host's `SweepRequest` parser on dispatch.
    pub experiment: String,
    /// Forwarded as the sweep's `fast` flag.
    pub fast: bool,
    /// Forwarded as the sweep's `--jobs`; `None` leaves each host's
    /// default.
    pub jobs: Option<u64>,
    /// The hosts to fan out across.
    pub manifest: Manifest,
    /// Fleet scratch root: local agents' output trees and logs live
    /// in `out/host-<i>/`.
    pub out: PathBuf,
    /// Where the auto-merged, byte-identical-to-unsharded tree lands.
    pub merged_out: PathBuf,
    /// Job-status poll cadence.
    pub poll: Duration,
    /// Per-request HTTP deadline.
    pub http_timeout: Duration,
    /// Bounded-retry budget for health checks, dispatches, and status
    /// polls before a host is declared dead.
    pub max_attempts: u32,
    /// First retry delay; doubles per attempt.
    pub backoff_base: Duration,
    /// Render a merged live dashboard (from every host's SSE stream)
    /// to stderr.
    pub dashboard: bool,
    /// Binary to spawn for `local:N` agents; defaults to the current
    /// executable.
    pub serve_bin: Option<PathBuf>,
}

impl FleetConfig {
    /// Defaults tuned for a loopback fleet; real deployments mostly
    /// raise `http_timeout`.
    pub fn new(experiment: &str, manifest: Manifest, out: &Path) -> FleetConfig {
        FleetConfig {
            experiment: experiment.to_string(),
            fast: false,
            jobs: None,
            manifest,
            out: out.to_path_buf(),
            merged_out: out.join("merged"),
            poll: Duration::from_millis(200),
            http_timeout: Duration::from_secs(10),
            max_attempts: 5,
            backoff_base: Duration::from_millis(100),
            dashboard: false,
            serve_bin: None,
        }
    }
}

/// What a fleet launch did, for the CLI summary and the tests.
#[derive(Debug)]
pub struct FleetReport {
    /// Hosts that passed the health gate and got work.
    pub hosts: usize,
    /// Hosts declared dead (never healthy, or failed mid-sweep).
    pub dead: Vec<String>,
    /// Shard dispatches, counting re-dispatches.
    pub dispatched: usize,
    /// Lost shards that were re-partitioned across survivors.
    pub resharded: usize,
    /// The auto-merged experiments.
    pub merged: Vec<MergedExperiment>,
}

/// Split a lost shard across `survivors` hosts: sub-shard `u` is
/// `(index + u·total) / (survivors·total)`. The sub-shards' ownership
/// classes partition the lost shard's exactly (see module docs), so
/// re-dispatching them covers every lost case once. Works recursively:
/// a lost *sub*-shard re-splits the same way.
pub fn reshard(failed: ShardSpec, survivors: usize) -> Result<Vec<ShardSpec>> {
    ensure!(survivors >= 1, "cannot re-shard {failed} across 0 survivors");
    let s = u32::try_from(survivors).context("survivor count overflows u32")?;
    let total = failed
        .total
        .checked_mul(s)
        .with_context(|| format!("re-shard denominator {}x{s} overflows u32", failed.total))?;
    (0..s)
        .map(|u| ShardSpec::new(failed.index + u * failed.total, total))
        .collect()
}

/// Exponential backoff for attempt `n` (0-based): `base · 2^n`, capped
/// at 10 s so a long retry budget stays responsive.
fn backoff_delay(base: Duration, attempt: u32) -> Duration {
    let factor = 1u32 << attempt.min(16);
    (base * factor).min(Duration::from_secs(10))
}

// ---- local agents -------------------------------------------------

struct LocalAgent {
    addr: String,
    child: Child,
}

/// Locally spawned `repro serve` children (`local:N` manifest
/// entries). Killed on drop so an aborted launch never leaks servers.
pub struct LocalAgents {
    agents: Vec<LocalAgent>,
}

impl LocalAgents {
    /// Spawn `n` serve children under `out/host-<i>/`, each on a
    /// freshly reserved loopback port, logging to `serve.log` in its
    /// host directory.
    pub fn spawn(n: usize, out: &Path, serve_bin: Option<&Path>) -> Result<LocalAgents> {
        let bin = match serve_bin {
            Some(p) => p.to_path_buf(),
            None => std::env::current_exe().context("locating the repro binary")?,
        };
        let mut agents = Vec::new();
        for i in 0..n {
            let dir = out.join(format!("host-{i}"));
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating {}", dir.display()))?;
            // Reserve a free port by binding then releasing it. A
            // tiny window exists before the child re-binds; the
            // health gate's bounded retries absorb a lost race.
            let probe = std::net::TcpListener::bind("127.0.0.1:0")
                .context("reserving a local agent port")?;
            let addr = format!("127.0.0.1:{}", probe.local_addr()?.port());
            drop(probe);
            let log = std::fs::File::create(dir.join("serve.log"))
                .with_context(|| format!("creating {}/serve.log", dir.display()))?;
            let child = Command::new(&bin)
                .arg("serve")
                .arg("--addr")
                .arg(&addr)
                .arg("--out")
                .arg(&dir)
                .stdin(Stdio::null())
                .stdout(log.try_clone()?)
                .stderr(log)
                .spawn()
                .with_context(|| format!("spawning local agent {}", bin.display()))?;
            eprintln!("fleet: local agent {i} on {addr} (pid {})", child.id());
            agents.push(LocalAgent { addr, child });
        }
        Ok(LocalAgents { agents })
    }

    /// The spawned agents' `host:port` addresses, in spawn order.
    pub fn addrs(&self) -> Vec<String> {
        self.agents.iter().map(|a| a.addr.clone()).collect()
    }
}

impl Drop for LocalAgents {
    fn drop(&mut self) {
        for a in &mut self.agents {
            a.child.kill().ok();
            a.child.wait().ok();
        }
    }
}

// ---- supervisor ---------------------------------------------------

struct HostJob {
    shard: ShardSpec,
    id: u64,
    out: PathBuf,
    done: bool,
}

struct HostState {
    addr: String,
    alive: bool,
    fail_streak: u32,
    jobs: Vec<HostJob>,
}

/// The merged live view: latest snapshot per (experiment, shard,
/// case), folded from every host's SSE stream under the
/// `snapshot_supersedes` rule.
type SnapMap = BTreeMap<(String, String, u64), Snapshot>;

/// Run one fleet launch end to end: health-gate, dispatch, monitor,
/// re-shard around deaths, auto-merge.
pub fn run_fleet(cfg: &FleetConfig) -> Result<FleetReport> {
    std::fs::create_dir_all(&cfg.out)
        .with_context(|| format!("creating {}", cfg.out.display()))?;
    let locals = LocalAgents::spawn(cfg.manifest.local, &cfg.out, cfg.serve_bin.as_deref())?;
    let mut candidates = cfg.manifest.endpoints.clone();
    candidates.extend(locals.addrs());
    ensure!(
        !candidates.is_empty(),
        "fleet manifest names no hosts (add host:port lines or local:N)"
    );

    // Health gate: a host that never answers /healthz is warned dead
    // up front rather than sinking a shard.
    let mut hosts: Vec<HostState> = Vec::new();
    let mut dead: Vec<String> = Vec::new();
    for addr in candidates {
        if wait_healthy(&addr, cfg) {
            eprintln!("fleet: host {addr} healthy");
            hosts.push(HostState {
                addr,
                alive: true,
                fail_streak: 0,
                jobs: Vec::new(),
            });
        } else {
            eprintln!(
                "fleet: WARNING host {addr} failed /healthz after {} attempts — excluded",
                cfg.max_attempts
            );
            dead.push(addr);
        }
    }
    ensure!(
        !hosts.is_empty(),
        "no fleet host passed /healthz ({} candidate(s) dead)",
        dead.len()
    );
    let gated = hosts.len();

    // Merged dashboard: one SSE follower thread per gated host.
    let stop = Arc::new(AtomicBool::new(false));
    let snaps: Arc<Mutex<SnapMap>> = Arc::new(Mutex::new(BTreeMap::new()));
    let followers: Vec<_> = hosts
        .iter()
        .map(|h| {
            let addr = h.addr.clone();
            let stop = Arc::clone(&stop);
            let snaps = Arc::clone(&snaps);
            let timeout = cfg.http_timeout;
            let base = cfg.backoff_base;
            std::thread::spawn(move || follow_host(&addr, timeout, base, &stop, &snaps))
        })
        .collect();

    // Initial partition: one shard per gated host.
    let initial =
        u32::try_from(hosts.len()).context("host count overflows the shard denominator")?;
    let mut pending: Vec<ShardSpec> = (0..initial)
        .map(|k| ShardSpec::new(k, initial))
        .collect::<Result<_>>()?;
    let mut dispatched = 0usize;
    let mut resharded = 0usize;

    let mut supervise = || -> Result<()> {
        loop {
            // Dispatch every pending shard to the least-loaded
            // survivor. A host whose dispatch exhausts its retries is
            // declared dead on the spot.
            while let Some(shard) = pending.pop() {
                let Some(hi) = pick_host(&hosts) else {
                    bail!(
                        "no surviving fleet host to run shard {shard} \
                         ({} declared dead)",
                        dead.len()
                    );
                };
                match dispatch_shard(cfg, &mut hosts[hi], shard) {
                    Ok(()) => dispatched += 1,
                    Err(e) => {
                        pending.push(shard);
                        declare_dead(
                            &mut hosts,
                            hi,
                            &format!("dispatch failed: {e:#}"),
                            &mut pending,
                            &mut dead,
                            &mut resharded,
                            &snaps,
                        )?;
                    }
                }
            }

            // Poll every in-flight job; collect at most one death per
            // pass (survivor count must be current when re-sharding).
            let mut death: Option<(usize, String)> = None;
            'hosts: for (hi, h) in hosts.iter_mut().enumerate() {
                if !h.alive {
                    continue;
                }
                for j in h.jobs.iter_mut().filter(|j| !j.done) {
                    match poll_job(&h.addr, j.id, cfg.http_timeout) {
                        Ok(("done", _)) => {
                            h.fail_streak = 0;
                            j.done = true;
                            eprintln!("fleet: host {} finished shard {}", h.addr, j.shard);
                        }
                        Ok(("failed", err)) => {
                            let err = err.unwrap_or_else(|| "unknown error".to_string());
                            death = Some((hi, format!("sweep failed: {err}")));
                            break 'hosts;
                        }
                        Ok(_) => h.fail_streak = 0,
                        Err(e) => {
                            h.fail_streak += 1;
                            if h.fail_streak >= cfg.max_attempts {
                                death = Some((hi, format!("unreachable: {e:#}")));
                                break 'hosts;
                            }
                            let wait = backoff_delay(cfg.backoff_base, h.fail_streak - 1);
                            std::thread::sleep(wait);
                        }
                    }
                }
            }
            if let Some((hi, why)) = death {
                declare_dead(
                    &mut hosts, hi, &why, &mut pending, &mut dead, &mut resharded, &snaps,
                )?;
                continue; // dispatch the re-shards immediately
            }

            if cfg.dashboard {
                render_dashboard(&snaps, hosts.iter().filter(|h| h.alive).count());
            }

            let all_done = hosts
                .iter()
                .filter(|h| h.alive)
                .all(|h| h.jobs.iter().all(|j| j.done));
            if pending.is_empty() && all_done {
                return Ok(());
            }
            std::thread::sleep(cfg.poll);
        }
    };
    let outcome = supervise();
    stop.store(true, Ordering::Relaxed);
    for f in followers {
        f.join().ok();
    }
    outcome?;

    // Merge only `done` outputs: a dead host's partial directory never
    // enters, and the re-shards cover its cases exactly once.
    let mut shard_dirs: Vec<PathBuf> = hosts
        .iter()
        .flat_map(|h| h.jobs.iter())
        .filter(|j| j.done)
        .map(|j| j.out.clone())
        .collect();
    shard_dirs.sort();
    ensure!(
        !shard_dirs.is_empty(),
        "fleet finished with no completed shard outputs"
    );
    let merged =
        merge_shard_dirs(&shard_dirs, &cfg.merged_out).context("auto-merging fleet outputs")?;

    drop(locals);
    Ok(FleetReport {
        hosts: gated,
        dead,
        dispatched,
        resharded,
        merged,
    })
}

/// Bounded-retry health probe.
fn wait_healthy(addr: &str, cfg: &FleetConfig) -> bool {
    for attempt in 0..cfg.max_attempts {
        if attempt > 0 {
            std::thread::sleep(backoff_delay(cfg.backoff_base, attempt - 1));
        }
        if health_ok(addr, cfg.http_timeout).is_ok() {
            return true;
        }
    }
    false
}

/// Least-loaded live host, by undone job count.
fn pick_host(hosts: &[HostState]) -> Option<usize> {
    hosts
        .iter()
        .enumerate()
        .filter(|(_, h)| h.alive)
        .min_by_key(|(_, h)| h.jobs.iter().filter(|j| !j.done).count())
        .map(|(i, _)| i)
}

/// POST one shard to one host with bounded retries. A non-202 answer
/// fails immediately (the request is malformed or the host refuses —
/// retrying cannot help); transport errors retry with backoff.
fn dispatch_shard(cfg: &FleetConfig, host: &mut HostState, shard: ShardSpec) -> Result<()> {
    let mut body = Value::obj();
    body.set("experiment", cfg.experiment.as_str())
        .set("fast", cfg.fast)
        .set("shard", shard.label());
    if let Some(j) = cfg.jobs {
        body.set("jobs", j);
    }
    let mut last_err = None;
    for attempt in 0..cfg.max_attempts {
        if attempt > 0 {
            std::thread::sleep(backoff_delay(cfg.backoff_base, attempt - 1));
        }
        match post_json(&host.addr, "/v1/sweeps", &body, cfg.http_timeout) {
            Ok((202, v)) => {
                let id = v.req_u64("id")?;
                let out = PathBuf::from(v.req_str("out")?);
                eprintln!(
                    "fleet: dispatched {} shard {} -> {} (job {id})",
                    cfg.experiment, shard, host.addr
                );
                host.jobs.push(HostJob {
                    shard,
                    id,
                    out,
                    done: false,
                });
                return Ok(());
            }
            Ok((status, v)) => bail!(
                "{} rejected shard {shard}: HTTP {status} {}",
                host.addr,
                v.to_string()
            ),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no attempts made")))
        .with_context(|| format!("dispatching shard {shard} to {}", host.addr))
}

/// GET one job's status: returns (status string, error message).
fn poll_job(addr: &str, id: u64, timeout: Duration) -> Result<(&'static str, Option<String>)> {
    let (status, v) = get_json(addr, &format!("/v1/sweeps/{id}"), timeout)?;
    ensure!(status == 200, "{addr}/v1/sweeps/{id} answered {status}");
    let st = match v.req_str("status")? {
        "queued" => "queued",
        "running" => "running",
        "done" => "done",
        "failed" => "failed",
        other => bail!("{addr} reports unknown job status '{other}'"),
    };
    let err = v.get("error").and_then(|e| e.as_str()).map(|s| s.to_string());
    Ok((st, err))
}

/// Remove a host from the pool and re-shard its unfinished work
/// across the survivors. Its `done` outputs are kept — they are
/// complete, disjoint shard directories. Its stale live snapshots are
/// dropped so the dashboard doesn't double-count re-run cases.
fn declare_dead(
    hosts: &mut [HostState],
    hi: usize,
    why: &str,
    pending: &mut Vec<ShardSpec>,
    dead: &mut Vec<String>,
    resharded: &mut usize,
    snaps: &Mutex<SnapMap>,
) -> Result<()> {
    hosts[hi].alive = false;
    let addr = hosts[hi].addr.clone();
    dead.push(addr.clone());
    let survivors = hosts.iter().filter(|h| h.alive).count();
    let lost: Vec<ShardSpec> = hosts[hi]
        .jobs
        .iter()
        .filter(|j| !j.done)
        .map(|j| j.shard)
        .collect();
    eprintln!(
        "fleet: host {addr} dead ({why}) — re-sharding {} lost shard(s) \
         across {survivors} survivor(s)",
        lost.len()
    );
    ensure!(
        survivors > 0 || lost.is_empty(),
        "host {addr} died ({why}) with no survivors to absorb its shards"
    );
    let mut g = snaps.lock().unwrap_or_else(|e| e.into_inner());
    for shard in lost {
        let label = shard.label();
        g.retain(|(_, s, _), _| *s != label);
        pending.extend(reshard(shard, survivors)?);
        *resharded += 1;
    }
    Ok(())
}

/// One host's SSE follower: subscribe, fold snapshots into the merged
/// map, resume from the last seen `id` across reconnects.
fn follow_host(
    addr: &str,
    timeout: Duration,
    backoff_base: Duration,
    stop: &AtomicBool,
    snaps: &Mutex<SnapMap>,
) {
    let mut last_seq: Option<u64> = None;
    let mut attempt = 0u32;
    while !stop.load(Ordering::Relaxed) {
        match SseSubscription::open(addr, last_seq, timeout) {
            Ok(mut sub) => {
                attempt = 0;
                while !stop.load(Ordering::Relaxed) {
                    match sub.poll() {
                        Ok(events) => {
                            for ev in events {
                                if let Some(id) = ev.id {
                                    last_seq = Some(id);
                                }
                                let Ok(v) = parse(&ev.data) else { continue };
                                let Ok(s) = Snapshot::from_json(&v) else { continue };
                                let key = (
                                    s.experiment.clone(),
                                    s.shard.clone().unwrap_or_default(),
                                    s.case_index,
                                );
                                let mut g = snaps.lock().unwrap_or_else(|e| e.into_inner());
                                match g.get(&key) {
                                    Some(old) if !snapshot_supersedes(&s, old) => {}
                                    _ => {
                                        g.insert(key, s);
                                    }
                                }
                            }
                        }
                        Err(_) => break, // reconnect with Last-Event-ID
                    }
                }
            }
            Err(_) => {
                std::thread::sleep(backoff_delay(backoff_base, attempt).min(timeout));
                attempt = attempt.saturating_add(1);
            }
        }
    }
}

/// Render the merged live view to stderr, `repro watch`-style.
fn render_dashboard(snaps: &Mutex<SnapMap>, hosts_alive: usize) {
    let g = snaps.lock().unwrap_or_else(|e| e.into_inner());
    if g.is_empty() {
        return;
    }
    let aggs = aggregate(g.values());
    eprintln!("{}", render_watch(&aggs, hosts_alive));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshard_partitions_the_lost_shard_exactly() {
        for total in 1u32..=6 {
            for index in 0..total {
                let failed = ShardSpec::new(index, total).unwrap();
                for survivors in 1usize..=5 {
                    let subs = reshard(failed, survivors).unwrap();
                    assert_eq!(subs.len(), survivors);
                    for i in 0..200usize {
                        let owners = subs.iter().filter(|s| s.owns(i)).count();
                        let want = usize::from(failed.owns(i));
                        assert_eq!(
                            owners, want,
                            "case {i}: lost {failed}, {survivors} survivors, subs {subs:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn reshard_is_safe_recursively() {
        // A re-shard of a re-shard still covers exactly the original
        // cases — the death-of-a-survivor path.
        let failed = ShardSpec::new(1, 3).unwrap();
        let first = reshard(failed, 2).unwrap();
        // The host running first[0] dies too; 2 survivors absorb it.
        let second = reshard(first[0], 2).unwrap();
        let cover: Vec<&ShardSpec> = second.iter().chain(&first[1..]).collect();
        for i in 0..300usize {
            let owners = cover.iter().filter(|s| s.owns(i)).count();
            assert_eq!(owners, usize::from(failed.owns(i)), "case {i}");
        }
    }

    #[test]
    fn reshard_rejects_degenerate_inputs() {
        let s = ShardSpec::new(0, 2).unwrap();
        assert!(reshard(s, 0).is_err());
        // One survivor re-dispatches the shard unchanged.
        let same = reshard(s, 1).unwrap();
        assert_eq!(same, vec![s]);
        // Denominator overflow is loud, not wrapped.
        let wide = ShardSpec::new(0, u32::MAX / 2).unwrap();
        assert!(reshard(wide, 3).is_err());
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let base = Duration::from_millis(100);
        assert_eq!(backoff_delay(base, 0), base);
        assert_eq!(backoff_delay(base, 1), base * 2);
        assert_eq!(backoff_delay(base, 3), base * 8);
        assert_eq!(backoff_delay(base, 30), Duration::from_secs(10));
    }
}
