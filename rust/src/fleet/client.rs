//! std-only HTTP/1.1 + SSE *client* for the fleet launcher
//! (DESIGN.md §15) — the counterpart of the serve plane's server-side
//! [`crate::serve::http`]. Same philosophy: every byte-level decision
//! is a pure function (`parse_response_head`, [`SseParser`]) so torn
//! reads and hostile bytes are unit-testable without a socket, and the
//! thin socket wrappers ([`exchange`], [`SseSubscription`]) only move
//! bytes and deadlines.
//!
//! Scope mirrors what `repro serve` speaks: fixed `Content-Length`
//! JSON bodies and one never-ending `text/event-stream`. Anything
//! outside that (chunked encoding, duplicate `Content-Length`) is
//! rejected loudly — a launcher that guessed at message framing would
//! corrupt its view of the fleet in ways that surface as phantom
//! dead hosts.

use crate::util::json::{parse, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Cap on a buffered response (head + body). Fleet bodies are job
/// status JSON — tiny; beyond this the peer is not a `repro serve`.
const MAX_RESPONSE_BYTES: usize = 4 * 1024 * 1024;

/// One complete HTTP response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u16,
    /// Headers with lowercased names, values trimmed.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Response {
    /// Header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Value> {
        let text = std::str::from_utf8(&self.body).context("response body is not UTF-8")?;
        parse(text).map_err(|e| anyhow::anyhow!("response body is not JSON: {e}"))
    }
}

/// Find the head terminator (`\r\n\r\n` or bare `\n\n`), returning
/// (head length, bytes consumed through the terminator).
fn find_head_end(buf: &[u8]) -> Option<(usize, usize)> {
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n");
    let lf = buf.windows(2).position(|w| w == b"\n\n");
    match (crlf, lf) {
        (Some(c), Some(l)) if l < c => Some((l, l + 2)),
        (Some(c), _) => Some((c, c + 4)),
        (None, Some(l)) => Some((l, l + 2)),
        (None, None) => None,
    }
}

/// Try to parse a response head from the front of `buf`. `Ok(None)` =
/// incomplete, read more. Returns (status, headers, consumed bytes).
pub fn parse_response_head(
    buf: &[u8],
) -> Result<Option<(u16, BTreeMap<String, String>, usize)>> {
    let Some((head_len, consumed)) = find_head_end(buf) else {
        if buf.len() > MAX_RESPONSE_BYTES {
            bail!("response head exceeds {MAX_RESPONSE_BYTES} bytes without terminating");
        }
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_len]).context("response head is not UTF-8")?;
    let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let (version, status) = match (parts.next(), parts.next()) {
        (Some(v), Some(s)) => (v, s),
        _ => bail!("malformed status line '{status_line}'"),
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version '{version}'");
    }
    let status: u16 = status
        .parse()
        .map_err(|_| anyhow::anyhow!("bad status code in '{status_line}'"))?;
    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            bail!("malformed response header line '{line}'");
        };
        let lname = name.trim().to_ascii_lowercase();
        let prev = headers.insert(lname.clone(), value.trim().to_string());
        if prev.is_some() && lname == "content-length" {
            // Same smuggling-shape rejection as the server side.
            bail!("duplicate content-length header in response");
        }
    }
    Ok(Some((status, headers, consumed)))
}

/// Try to parse one complete fixed-length response from the front of
/// `buf`. `Ok(None)` = incomplete. Returns the response plus the total
/// bytes it consumed.
pub fn parse_response(buf: &[u8]) -> Result<Option<(Response, usize)>> {
    let Some((status, headers, consumed)) = parse_response_head(buf)? else {
        return Ok(None);
    };
    let len: usize = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad content-length '{v}' in response"))?,
    };
    if len > MAX_RESPONSE_BYTES {
        bail!("response body of {len} bytes exceeds the {MAX_RESPONSE_BYTES}-byte cap");
    }
    if buf.len() < consumed + len {
        return Ok(None);
    }
    Ok(Some((
        Response {
            status,
            headers,
            body: buf[consumed..consumed + len].to_vec(),
        },
        consumed + len,
    )))
}

/// Connect with a deadline, resolving the address first.
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let sockaddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("{addr} resolved to no addresses"))?;
    let stream = TcpStream::connect_timeout(&sockaddr, timeout)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    // Short read timeouts keep deadline checks responsive.
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(stream)
}

/// Frame one client request.
fn request_bytes(method: &str, path: &str, host: &str, headers: &[String], body: &[u8]) -> Vec<u8> {
    let mut out = format!("{method} {path} HTTP/1.1\r\nHost: {host}\r\n");
    for h in headers {
        out.push_str(h);
        out.push_str("\r\n");
    }
    if !body.is_empty() {
        out.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            body.len()
        ));
    }
    out.push_str("\r\n");
    let mut bytes = out.into_bytes();
    bytes.extend_from_slice(body);
    bytes
}

/// One request/response exchange against `addr`, bounded by `timeout`
/// end to end (connect + write + read).
pub fn exchange(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    timeout: Duration,
) -> Result<Response> {
    let deadline = Instant::now() + timeout;
    let mut stream = connect(addr, timeout)?;
    stream
        .write_all(&request_bytes(method, path, addr, &[], body))
        .with_context(|| format!("writing {method} {path} to {addr}"))?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 8192];
    loop {
        if let Some((resp, _)) = parse_response(&buf)
            .with_context(|| format!("parsing {method} {path} response from {addr}"))?
        {
            return Ok(resp);
        }
        if Instant::now() >= deadline {
            bail!("{method} {path} to {addr} timed out after {timeout:?}");
        }
        match stream.read(&mut chunk) {
            Ok(0) => bail!("{addr} closed the connection mid-response"),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e).with_context(|| format!("reading from {addr}")),
        }
    }
}

/// GET a JSON endpoint: returns (status, parsed body).
pub fn get_json(addr: &str, path: &str, timeout: Duration) -> Result<(u16, Value)> {
    let resp = exchange(addr, "GET", path, &[], timeout)?;
    let v = resp.json()?;
    Ok((resp.status, v))
}

/// POST a JSON body: returns (status, parsed response body).
pub fn post_json(addr: &str, path: &str, body: &Value, timeout: Duration) -> Result<(u16, Value)> {
    let resp = exchange(addr, "POST", path, body.to_string().as_bytes(), timeout)?;
    let v = resp.json()?;
    Ok((resp.status, v))
}

/// Probe `/healthz`; `Ok` only on a 200 with `"status": "ok"`.
pub fn health_ok(addr: &str, timeout: Duration) -> Result<()> {
    let (status, v) = get_json(addr, "/healthz", timeout)?;
    if status != 200 || v.get("status").and_then(|s| s.as_str()) != Some("ok") {
        bail!("{addr}/healthz answered {status}");
    }
    Ok(())
}

// ---- SSE ----------------------------------------------------------

/// One parsed SSE event (or the fields present on it).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SseEvent {
    /// `event:` field, if any.
    pub event: Option<String>,
    /// `id:` field parsed as the snapshot `seq` it carries.
    pub id: Option<u64>,
    /// `data:` lines joined with `\n`.
    pub data: String,
}

/// Incremental SSE frame parser: feed raw bytes, get complete events.
/// Comment-only frames (keep-alives, lag notes) parse to no event.
#[derive(Debug, Default)]
pub struct SseParser {
    buf: Vec<u8>,
}

/// Find an SSE frame terminator — a blank line, in either bare-`\n`
/// (what `serve::sse` emits) or `\r\n` framing — returning (frame
/// length, bytes consumed through the terminator).
fn find_frame_end(buf: &[u8]) -> Option<(usize, usize)> {
    // `\r\n\r\n` contains no `\n\n` window, so both must be searched.
    let lf = buf.windows(2).position(|w| w == b"\n\n");
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n");
    match (lf, crlf) {
        (Some(l), Some(c)) if l <= c => Some((l, l + 2)),
        (_, Some(c)) => Some((c, c + 4)),
        (Some(l), None) => Some((l, l + 2)),
        (None, None) => None,
    }
}

impl SseParser {
    /// Feed bytes; return every event completed by them.
    pub fn push(&mut self, bytes: &[u8]) -> Vec<SseEvent> {
        self.buf.extend_from_slice(bytes);
        let mut events = Vec::new();
        while let Some((frame_len, consumed)) = find_frame_end(&self.buf) {
            let mut frame: Vec<u8> = self.buf.drain(..consumed).collect();
            frame.truncate(frame_len);
            let text = String::from_utf8_lossy(&frame);
            let mut ev = SseEvent::default();
            let mut has_data = false;
            // `str::lines` strips a trailing `\r`, so CRLF input needs
            // no per-line handling here.
            for line in text.lines() {
                if let Some(rest) = line.strip_prefix("data:") {
                    if has_data {
                        ev.data.push('\n');
                    }
                    ev.data.push_str(rest.strip_prefix(' ').unwrap_or(rest));
                    has_data = true;
                } else if let Some(rest) = line.strip_prefix("event:") {
                    ev.event = Some(rest.trim().to_string());
                } else if let Some(rest) = line.strip_prefix("id:") {
                    ev.id = rest.trim().parse().ok();
                }
                // ":" comments and unknown fields are ignored per spec.
            }
            if has_data || ev.event.is_some() {
                events.push(ev);
            }
        }
        events
    }
}

/// An open `/v1/snapshots` SSE stream.
pub struct SseSubscription {
    stream: TcpStream,
    parser: SseParser,
}

impl SseSubscription {
    /// Connect and subscribe. `last_seq` resumes delivery just past
    /// that snapshot sequence (the serve plane's `Last-Event-ID`
    /// contract); `None` replays the retained history.
    pub fn open(addr: &str, last_seq: Option<u64>, timeout: Duration) -> Result<SseSubscription> {
        let deadline = Instant::now() + timeout;
        let mut stream = connect(addr, timeout)?;
        let mut headers = vec!["Accept: text/event-stream".to_string()];
        if let Some(seq) = last_seq {
            headers.push(format!("Last-Event-ID: {seq}"));
        }
        stream.write_all(&request_bytes("GET", "/v1/snapshots", addr, &headers, &[]))?;
        // Read just the response head; everything after it is stream.
        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 8192];
        loop {
            if let Some((status, headers, consumed)) = parse_response_head(&buf)? {
                if status != 200 {
                    bail!("{addr}/v1/snapshots answered {status}");
                }
                let ct = headers.get("content-type").map(|s| s.as_str()).unwrap_or("");
                if !ct.starts_with("text/event-stream") {
                    bail!("{addr}/v1/snapshots is not an event stream (content-type '{ct}')");
                }
                // Bytes past the head already belong to the stream;
                // seed them unparsed so the first poll delivers them.
                let parser = SseParser {
                    buf: buf[consumed..].to_vec(),
                };
                return Ok(SseSubscription { stream, parser });
            }
            if Instant::now() >= deadline {
                bail!("subscribing to {addr}/v1/snapshots timed out after {timeout:?}");
            }
            match stream.read(&mut chunk) {
                Ok(0) => bail!("{addr} closed the connection during SSE subscribe"),
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(e) => return Err(e).with_context(|| format!("reading from {addr}")),
            }
        }
    }

    /// Read whatever arrived and return the completed events. `Ok` with
    /// an empty vec on a quiet interval; `Err` when the stream is gone
    /// (reconnect with the last seen `id` to resume).
    pub fn poll(&mut self) -> Result<Vec<SseEvent>> {
        let mut chunk = [0u8; 8192];
        match self.stream.read(&mut chunk) {
            Ok(0) => bail!("SSE stream closed"),
            Ok(n) => Ok(self.parser.push(&chunk[..n])),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Still drain frames the subscribe read already buffered.
                Ok(self.parser.push(&[]))
            }
            Err(e) => Err(e).context("reading SSE stream"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_parses_incrementally_and_exactly() {
        let raw = b"HTTP/1.1 202 Accepted\r\nContent-Type: application/json\r\nContent-Length: 11\r\n\r\n{\"id\": 111}NEXT";
        // Every prefix short of head+body is incomplete.
        let full = raw.len() - 4; // "NEXT" is not part of the response
        for cut in 0..full {
            assert!(
                parse_response(&raw[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes must be incomplete"
            );
        }
        let (resp, consumed) = parse_response(raw).unwrap().unwrap();
        assert_eq!(consumed, full, "must not consume the next response's bytes");
        assert_eq!(resp.status, 202);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.body, b"{\"id\": 111}");
        assert_eq!(resp.json().unwrap().req_u64("id").unwrap(), 111);
        // No content-length = empty body (our endpoints always send it).
        let (resp, _) = parse_response(b"HTTP/1.1 200 OK\r\n\r\n").unwrap().unwrap();
        assert!(resp.body.is_empty());
    }

    #[test]
    fn hostile_responses_error_cleanly() {
        assert!(parse_response(b"NOT HTTP\r\n\r\n").is_err());
        assert!(parse_response(b"HTTP/2 200 OK\r\n\r\n").is_err());
        assert!(parse_response(b"HTTP/1.1 abc OK\r\n\r\n").is_err());
        assert!(parse_response(b"HTTP/1.1 200 OK\r\nContent-Length: x\r\n\r\n").is_err());
        // The smuggling shape is rejected on responses too.
        assert!(parse_response(
            b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\nabc"
        )
        .is_err());
        // A reason phrase with spaces parses fine.
        let (resp, _) = parse_response(b"HTTP/1.1 405 Method Not Allowed\r\nAllow: GET\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("allow"), Some("GET"));
    }

    #[test]
    fn sse_parser_reassembles_torn_frames() {
        let mut p = SseParser::default();
        // A frame split at every possible boundary still yields exactly
        // one event.
        let frame = b"event: snapshot\nid: 42\ndata: {\"a\":1}\n\n";
        for cut in 0..frame.len() {
            let mut p = SseParser::default();
            let mut got = p.push(&frame[..cut]);
            got.extend(p.push(&frame[cut..]));
            assert_eq!(got.len(), 1, "split at {cut}");
            assert_eq!(got[0].event.as_deref(), Some("snapshot"));
            assert_eq!(got[0].id, Some(42));
            assert_eq!(got[0].data, "{\"a\":1}");
        }
        // Comments (keep-alives, lag notes) produce no events; data
        // spanning multiple lines re-joins with \n.
        let got = p.push(b": keep-alive\n\ndata: l1\ndata: l2\n\n: lagged\n\n");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].data, "l1\nl2");
        assert_eq!(got[0].id, None);
        // CRLF line endings are tolerated.
        let got = p.push(b"id: 7\r\ndata: x\r\n\r\n\r\n");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, Some(7));
        assert_eq!(got[0].data, "x");
    }
}
