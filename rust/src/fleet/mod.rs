//! Fault-tolerant multi-host sweep launcher (`repro fleet`,
//! DESIGN.md §15).
//!
//! The serve plane (§11) made one process remotely drivable: `repro
//! serve` accepts sweeps over `POST /v1/sweeps` and streams live
//! snapshots over `/v1/snapshots`. This module is the other half — a
//! *launcher* that fans one sweep out across a whole fleet of those
//! servers:
//!
//! - [`manifest`]: the host list (`host:port` lines, `local:N` spawn
//!   counts, or repeated `--host` flags), with loud `path:line:`
//!   parse errors.
//! - [`client`]: a std-only HTTP/1.1 + SSE client speaking exactly
//!   the serve plane's dialect, with pure byte-level parsers.
//! - [`supervisor`]: health-gates the hosts, dispatches one shard per
//!   survivor, follows every host's snapshot stream into one merged
//!   dashboard, re-shards a dead host's unfinished work across the
//!   survivors, and auto-merges the completed shard directories into
//!   a tree byte-identical to an unsharded run.
//!
//! Everything is std + `anyhow`, like the rest of the crate: the
//! "fleet" is plain TCP between plain processes, so the loopback
//! fault-injection tests exercise the same code paths as a real
//! multi-machine launch.

pub mod client;
pub mod manifest;
pub mod supervisor;

pub use client::{SseEvent, SseParser, SseSubscription};
pub use manifest::Manifest;
pub use supervisor::{reshard, run_fleet, FleetConfig, FleetReport, LocalAgents};
