//! Scaling policies: map (load telemetry, grid signals) → desired
//! fleet size. Policies are deliberately incremental — they move the
//! fleet by at most a step or two per decision interval, which damps
//! oscillation against the cold-start delay — and every non-static
//! policy shares the same SLO guard so "green" never silently means
//! "slow".

use crate::autoscale::controller::{GridSignals, LoadSignals};
use crate::config::simconfig::{AutoscaleConfig, ScalingPolicyKind};

/// A fleet-sizing policy. `desired_replicas` returns the target total
/// fleet (online + cold-starting); the [`super::FleetController`]
/// clamps it into the configured bounds.
pub trait ScalingPolicy {
    fn name(&self) -> &'static str;
    fn desired_replicas(&mut self, load: &LoadSignals, grid: &GridSignals) -> u32;
}

/// Is the fleet under latency/backlog pressure? Recent p99s above
/// `slo * margin` or a deep per-replica queue veto any shedding.
/// Queue depth is measured against replicas that can actually serve
/// (cold-starting ones don't drain queues yet). NaN percentiles (no
/// recent completions) never count as pressure.
fn slo_pressure(load: &LoadSignals, queue_high: f64, margin: f64) -> bool {
    let serving = load.active_replicas.max(1) as f64;
    let queue_per_replica = load.queued as f64 / serving;
    queue_per_replica > queue_high
        || load.recent_ttft_p99_s > load.slo_ttft_s * margin
        || load.recent_e2e_p99_s > load.slo_e2e_s * margin
}

/// Fixed fleet — the paper's original setting and the sweep baseline.
#[derive(Debug, Clone)]
pub struct StaticPolicy {
    pub replicas: u32,
}

impl ScalingPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }
    fn desired_replicas(&mut self, _load: &LoadSignals, _grid: &GridSignals) -> u32 {
        self.replicas
    }
}

/// Reactive queue-based scaling: grow when the per-replica backlog is
/// deep, consolidate when both the queue and the running set are thin.
#[derive(Debug, Clone)]
pub struct ReactivePolicy {
    pub queue_high: f64,
    pub queue_low: f64,
    /// Running requests per replica below which consolidation is safe.
    pub run_low: f64,
}

impl ReactivePolicy {
    pub fn from_config(cfg: &AutoscaleConfig) -> Self {
        ReactivePolicy {
            queue_high: cfg.queue_high,
            queue_low: cfg.queue_low,
            run_low: cfg.run_low,
        }
    }
}

impl ScalingPolicy for ReactivePolicy {
    fn name(&self) -> &'static str {
        "reactive"
    }
    fn desired_replicas(&mut self, load: &LoadSignals, _grid: &GridSignals) -> u32 {
        let fleet = load.fleet().max(1);
        let queue_per = load.queued as f64 / fleet as f64;
        let run_per = load.running as f64 / fleet as f64;
        if queue_per > self.queue_high {
            fleet + 1
        } else if queue_per < self.queue_low && run_per < self.run_low {
            fleet.saturating_sub(1)
        } else {
            fleet
        }
    }
}

/// SLO-guarded carbon-aware scaling: when the grid is dirty
/// (CI > ci_high) shed one replica per interval; when it is clean
/// (CI < ci_low) restore the baseline fleet; in between drift back
/// toward the baseline. Latency pressure overrides shedding.
#[derive(Debug, Clone)]
pub struct CarbonAwarePolicy {
    /// Fleet size to hold when the grid is clean or moderate (the
    /// static comparator's size).
    pub baseline: u32,
    pub queue_high: f64,
    pub slo_margin: f64,
}

impl CarbonAwarePolicy {
    pub fn from_config(cfg: &AutoscaleConfig, baseline: u32) -> Self {
        CarbonAwarePolicy {
            baseline,
            queue_high: cfg.queue_high,
            slo_margin: cfg.slo_margin,
        }
    }
}

impl ScalingPolicy for CarbonAwarePolicy {
    fn name(&self) -> &'static str {
        "carbon_aware"
    }
    fn desired_replicas(&mut self, load: &LoadSignals, grid: &GridSignals) -> u32 {
        let fleet = load.fleet().max(1);
        if slo_pressure(load, self.queue_high, self.slo_margin) {
            // SLO guard beats carbon: add capacity regardless of CI.
            return fleet + 1;
        }
        if grid.ci > grid.ci_high {
            // Dirty grid: shed one replica per decision interval.
            return fleet.saturating_sub(1);
        }
        if grid.ci < grid.ci_low {
            // Clean grid: restore the baseline fleet in one jump when
            // below it; capacity above baseline persists only while
            // the SLO guard keeps demanding it, otherwise it drains
            // off one replica per interval.
            return if fleet < self.baseline {
                self.baseline
            } else if fleet > self.baseline {
                fleet - 1
            } else {
                fleet
            };
        }
        // Moderate grid: drift toward the baseline one step at a time.
        match fleet.cmp(&self.baseline) {
            std::cmp::Ordering::Less => fleet + 1,
            std::cmp::Ordering::Greater => fleet - 1,
            std::cmp::Ordering::Equal => fleet,
        }
    }
}

/// Solar-following: the fleet tracks instantaneous solar availability
/// between the configured bounds ("ride the solar peak with extra
/// capacity"), with the same SLO guard as the carbon policy.
#[derive(Debug, Clone)]
pub struct SolarFollowingPolicy {
    pub min_replicas: u32,
    pub max_replicas: u32,
    pub queue_high: f64,
    pub slo_margin: f64,
}

impl SolarFollowingPolicy {
    pub fn from_config(cfg: &AutoscaleConfig) -> Self {
        SolarFollowingPolicy {
            min_replicas: cfg.min_replicas,
            max_replicas: cfg.max_replicas,
            queue_high: cfg.queue_high,
            slo_margin: cfg.slo_margin,
        }
    }
}

impl ScalingPolicy for SolarFollowingPolicy {
    fn name(&self) -> &'static str {
        "solar_following"
    }
    fn desired_replicas(&mut self, load: &LoadSignals, grid: &GridSignals) -> u32 {
        let fleet = load.fleet().max(1);
        if slo_pressure(load, self.queue_high, self.slo_margin) {
            return fleet + 1;
        }
        let frac = if grid.solar_capacity_w > 0.0 {
            (grid.solar_w / grid.solar_capacity_w).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let span = self.max_replicas.saturating_sub(self.min_replicas) as f64;
        let target = self.min_replicas + (span * frac).round() as u32;
        // Move at most one step per interval toward the solar target.
        match fleet.cmp(&target) {
            std::cmp::Ordering::Less => fleet + 1,
            std::cmp::Ordering::Greater => fleet - 1,
            std::cmp::Ordering::Equal => fleet,
        }
    }
}

/// Build the configured policy. `baseline_replicas` is the fleet size
/// the run starts with (`SimConfig::replicas`) — the static policy
/// holds it, the carbon-aware policy restores to it on a clean grid.
pub fn build_policy(cfg: &AutoscaleConfig, baseline_replicas: u32) -> Box<dyn ScalingPolicy> {
    match cfg.policy {
        ScalingPolicyKind::Static => Box::new(StaticPolicy {
            replicas: baseline_replicas,
        }),
        ScalingPolicyKind::Reactive => Box::new(ReactivePolicy::from_config(cfg)),
        ScalingPolicyKind::CarbonAware => {
            Box::new(CarbonAwarePolicy::from_config(cfg, baseline_replicas))
        }
        ScalingPolicyKind::SolarFollowing => Box::new(SolarFollowingPolicy::from_config(cfg)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(queued: u64, running: u64, fleet: u32) -> LoadSignals {
        LoadSignals {
            t_s: 0.0,
            queued,
            running,
            active_replicas: fleet,
            pending_replicas: 0,
            recent_qps: 1.0,
            recent_ttft_p99_s: f64::NAN,
            recent_e2e_p99_s: f64::NAN,
            slo_ttft_s: 10.0,
            slo_e2e_s: 60.0,
        }
    }

    fn grid(ci: f64, solar_w: f64) -> GridSignals {
        GridSignals {
            ci,
            ci_low: 100.0,
            ci_high: 200.0,
            solar_w,
            solar_capacity_w: 600.0,
        }
    }

    #[test]
    fn reactive_scales_with_queue() {
        let mut p = ReactivePolicy {
            queue_high: 8.0,
            queue_low: 2.0,
            run_low: 8.0,
        };
        // Deep backlog: scale up.
        assert_eq!(p.desired_replicas(&load(40, 10, 2), &grid(150.0, 0.0)), 3);
        // Thin queue and thin batch: consolidate.
        assert_eq!(p.desired_replicas(&load(0, 4, 3), &grid(150.0, 0.0)), 2);
        // Busy but not backlogged: hold.
        assert_eq!(p.desired_replicas(&load(4, 60, 2), &grid(150.0, 0.0)), 2);
    }

    #[test]
    fn carbon_sheds_when_dirty_restores_when_clean() {
        let mut p = CarbonAwarePolicy {
            baseline: 3,
            queue_high: 8.0,
            slo_margin: 0.8,
        };
        assert_eq!(p.desired_replicas(&load(0, 2, 3), &grid(400.0, 0.0)), 2);
        assert_eq!(p.desired_replicas(&load(0, 2, 2), &grid(400.0, 0.0)), 1);
        assert_eq!(p.desired_replicas(&load(0, 2, 1), &grid(60.0, 0.0)), 3);
        // Moderate CI drifts toward baseline one step at a time.
        assert_eq!(p.desired_replicas(&load(0, 2, 1), &grid(150.0, 0.0)), 2);
    }

    #[test]
    fn carbon_slo_guard_overrides_shedding() {
        let mut p = CarbonAwarePolicy {
            baseline: 3,
            queue_high: 8.0,
            slo_margin: 0.8,
        };
        let mut l = load(40, 10, 1); // queue 40/replica >> queue_high
        assert_eq!(p.desired_replicas(&l, &grid(500.0, 0.0)), 2);
        // Latency pressure alone (queue fine, p99 near SLO) also guards.
        l = load(0, 10, 1);
        l.recent_ttft_p99_s = 9.5; // > 10.0 * 0.8
        assert_eq!(p.desired_replicas(&l, &grid(500.0, 0.0)), 2);
    }

    #[test]
    fn solar_following_tracks_irradiance() {
        let mut p = SolarFollowingPolicy {
            min_replicas: 1,
            max_replicas: 4,
            queue_high: 8.0,
            slo_margin: 0.8,
        };
        // Night: step down toward the floor.
        assert_eq!(p.desired_replicas(&load(0, 2, 3), &grid(300.0, 0.0)), 2);
        // Full sun: step up toward the ceiling.
        assert_eq!(p.desired_replicas(&load(0, 2, 2), &grid(300.0, 600.0)), 3);
        // At the solar-implied target: hold.
        assert_eq!(p.desired_replicas(&load(0, 2, 4), &grid(300.0, 600.0)), 4);
    }

    #[test]
    fn nan_percentiles_never_trigger_pressure() {
        let l = load(0, 0, 1);
        assert!(!slo_pressure(&l, 8.0, 0.8));
    }

    #[test]
    fn pressure_counts_only_serving_replicas() {
        // 1 active + 1 cold-starting, 14 queued, threshold 8: the
        // provisioning replica cannot drain the queue, so this IS
        // pressure (14/1 > 8), not 14/2 < 8.
        let mut l = load(14, 4, 1);
        l.pending_replicas = 1;
        assert!(slo_pressure(&l, 8.0, 0.8));
    }

    #[test]
    fn carbon_clean_grid_drains_over_baseline_capacity() {
        // An SLO-guard upscale above baseline must not persist forever
        // on a clean grid once the pressure is gone.
        let mut p = CarbonAwarePolicy {
            baseline: 3,
            queue_high: 8.0,
            slo_margin: 0.8,
        };
        assert_eq!(p.desired_replicas(&load(0, 2, 4), &grid(60.0, 0.0)), 3);
        assert_eq!(p.desired_replicas(&load(0, 2, 3), &grid(60.0, 0.0)), 3);
    }

    #[test]
    fn build_policy_covers_all_kinds() {
        let cfg = AutoscaleConfig::default();
        for kind in [
            ScalingPolicyKind::Static,
            ScalingPolicyKind::Reactive,
            ScalingPolicyKind::CarbonAware,
            ScalingPolicyKind::SolarFollowing,
        ] {
            let mut c = cfg.clone();
            c.policy = kind;
            let p = build_policy(&c, 3);
            assert_eq!(p.name(), kind.as_str());
        }
    }
}
