//! The fleet controller, its input signals, and the fleet timeline.
//!
//! The controller is deliberately thin: policies ([`super::policy`])
//! decide a desired fleet size, the controller clamps it into the
//! configured bounds and records the decision; the simulation engine
//! ([`crate::sim::engine::run_autoscaled`]) owns the mechanics
//! (provisioning with cold-start, graceful drains, re-queueing).
//!
//! The [`FleetTimeline`] is the contract with the energy layers: it
//! records, per replica, the interval during which that replica
//! physically exists (provision → offline) so idle power is charged
//! only for live replicas ([`crate::energy`]) and the Eq. 5 binning
//! produces a time-varying demand signal ([`crate::pipeline`]).

use crate::config::simconfig::{AutoscaleConfig, CosimConfig};
use crate::grid::HistoricalSignal;

use super::policy::ScalingPolicy;

/// Load telemetry snapshot at a scaling decision.
#[derive(Debug, Clone, Copy)]
pub struct LoadSignals {
    pub t_s: f64,
    /// Requests queued (routed but not admitted) across the fleet.
    pub queued: u64,
    /// Requests currently running across the fleet.
    pub running: u64,
    /// Online, non-draining replicas.
    pub active_replicas: u32,
    /// Provisioning (cold-starting) replicas.
    pub pending_replicas: u32,
    /// Completions per second over the recent window.
    pub recent_qps: f64,
    /// Recent-window TTFT p99, seconds (NaN when nothing finished).
    pub recent_ttft_p99_s: f64,
    /// Recent-window e2e p99, seconds (NaN when nothing finished).
    pub recent_e2e_p99_s: f64,
    pub slo_ttft_s: f64,
    pub slo_e2e_s: f64,
}

impl LoadSignals {
    /// Capacity the fleet will have once cold starts complete.
    pub fn fleet(&self) -> u32 {
        self.active_replicas + self.pending_replicas
    }
}

/// Grid-condition snapshot at a scaling decision.
#[derive(Debug, Clone, Copy)]
pub struct GridSignals {
    /// Carbon intensity, gCO₂/kWh.
    pub ci: f64,
    /// Below this CI the grid counts as clean (Table 1b: 100).
    pub ci_low: f64,
    /// Above this CI the grid counts as dirty (Table 1b: 200).
    pub ci_high: f64,
    /// Solar generation, W.
    pub solar_w: f64,
    /// Installed solar capacity, W.
    pub solar_capacity_w: f64,
}

/// Time-varying grid environment the engine queries at each decision.
/// Wraps arbitrary CI/solar functions of *absolute* time; simulation
/// time t is offset by `start_s` (the hour of day the run begins).
pub struct GridEnv {
    pub ci_low: f64,
    pub ci_high: f64,
    pub solar_capacity_w: f64,
    /// Wall-clock offset of simulation t=0, seconds (e.g. 6 h × 3600).
    pub start_s: f64,
    ci: Box<dyn Fn(f64) -> f64>,
    solar: Box<dyn Fn(f64) -> f64>,
}

impl GridEnv {
    /// Arbitrary signal functions of absolute time.
    pub fn from_fns(
        ci_low: f64,
        ci_high: f64,
        solar_capacity_w: f64,
        start_s: f64,
        ci: impl Fn(f64) -> f64 + 'static,
        solar: impl Fn(f64) -> f64 + 'static,
    ) -> Self {
        GridEnv {
            ci_low,
            ci_high,
            solar_capacity_w,
            start_s,
            ci: Box::new(ci),
            solar: Box::new(solar),
        }
    }

    /// Constant conditions (tests, ablations). Thresholds are the
    /// paper's 100/200 gCO₂/kWh.
    pub fn constant(ci: f64, solar_w: f64) -> Self {
        Self::from_fns(100.0, 200.0, 600.0, 0.0, move |_| ci, move |_| solar_w)
    }

    /// Sampled historical/synthetic signals with the co-simulation
    /// thresholds; starts at the configured hour of day.
    pub fn from_signals(
        cosim: &CosimConfig,
        ci: HistoricalSignal,
        solar: HistoricalSignal,
    ) -> Self {
        let cap = cosim.solar_capacity_w;
        Self::from_fns(
            cosim.ci_low,
            cosim.ci_high,
            cap,
            cosim.start_hour * 3600.0,
            move |t| ci.at(t),
            move |t| solar.at(t),
        )
    }

    /// Grid snapshot at simulation time `t_s`.
    pub fn at(&self, t_s: f64) -> GridSignals {
        let t = self.start_s + t_s;
        GridSignals {
            ci: (self.ci)(t),
            ci_low: self.ci_low,
            ci_high: self.ci_high,
            solar_w: (self.solar)(t),
            solar_capacity_w: self.solar_capacity_w,
        }
    }
}

/// One recorded scaling decision.
#[derive(Debug, Clone, Copy)]
pub struct ScaleDecision {
    pub t_s: f64,
    /// Fleet (active + pending) when the decision was taken.
    pub fleet_before: u32,
    /// Clamped policy output.
    pub desired: u32,
    pub ci: f64,
    pub solar_w: f64,
}

/// Clamps policy outputs into the configured bounds and keeps the
/// decision log.
pub struct FleetController {
    pub cfg: AutoscaleConfig,
    policy: Box<dyn ScalingPolicy>,
    pub decisions: Vec<ScaleDecision>,
}

impl FleetController {
    pub fn new(cfg: AutoscaleConfig, policy: Box<dyn ScalingPolicy>) -> Self {
        FleetController {
            cfg,
            policy,
            decisions: Vec::new(),
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Desired fleet size for this interval, clamped into bounds.
    pub fn desired(&mut self, load: &LoadSignals, grid: &GridSignals) -> u32 {
        let raw = self.policy.desired_replicas(load, grid);
        let desired = raw.clamp(self.cfg.min_replicas, self.cfg.max_replicas);
        self.decisions.push(ScaleDecision {
            t_s: load.t_s,
            fleet_before: load.fleet(),
            desired,
            ci: grid.ci,
            solar_w: grid.solar_w,
        });
        desired
    }
}

/// Replica lifecycle event kinds, in causal order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEventKind {
    /// Instance requested; cold start begins (idle power draw starts).
    Provision,
    /// Cold start finished; replica serves traffic.
    Online,
    /// Graceful drain begins: admission closed, queue re-routed.
    DrainStart,
    /// Replica gone (power draw ends).
    Offline,
}

/// One replica lifecycle event.
#[derive(Debug, Clone, Copy)]
pub struct FleetEvent {
    pub t_s: f64,
    pub replica: u32,
    pub kind: FleetEventKind,
}

/// One replica's existence interval. `down_s == None` means the
/// replica was still live at the end of the run.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSpan {
    pub replica: u32,
    /// Provisioned (starts drawing idle power: boot + weight load).
    pub up_s: f64,
    /// Began serving traffic (None: never finished cold start).
    pub online_s: Option<f64>,
    pub drain_s: Option<f64>,
    pub down_s: Option<f64>,
}

/// The full fleet lifecycle of a run: per-replica spans, the event
/// log, and the horizon (makespan) that closes still-live spans.
#[derive(Debug, Clone, Default)]
pub struct FleetTimeline {
    /// Indexed by replica id (ids are assigned densely in provision
    /// order and never reused).
    pub spans: Vec<ReplicaSpan>,
    pub events: Vec<FleetEvent>,
    pub horizon_s: f64,
}

impl FleetTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// A fixed fleet of `n` replicas live over the whole horizon —
    /// makes the static case a degenerate timeline so the fleet-aware
    /// accounting and binning subsume the original fixed-fleet paths.
    pub fn static_fleet(n: u32, horizon_s: f64) -> Self {
        let mut t = Self::new();
        for i in 0..n {
            t.provision(i, 0.0);
            t.online(i, 0.0);
        }
        t.close(horizon_s);
        t
    }

    pub fn provision(&mut self, replica: u32, t_s: f64) {
        assert_eq!(
            replica as usize,
            self.spans.len(),
            "replica ids must be dense and provisioned in order"
        );
        self.spans.push(ReplicaSpan {
            replica,
            up_s: t_s,
            online_s: None,
            drain_s: None,
            down_s: None,
        });
        self.events.push(FleetEvent {
            t_s,
            replica,
            kind: FleetEventKind::Provision,
        });
    }

    pub fn online(&mut self, replica: u32, t_s: f64) {
        self.spans[replica as usize].online_s = Some(t_s);
        self.events.push(FleetEvent {
            t_s,
            replica,
            kind: FleetEventKind::Online,
        });
    }

    pub fn drain_start(&mut self, replica: u32, t_s: f64) {
        self.spans[replica as usize].drain_s = Some(t_s);
        self.events.push(FleetEvent {
            t_s,
            replica,
            kind: FleetEventKind::DrainStart,
        });
    }

    pub fn offline(&mut self, replica: u32, t_s: f64) {
        self.spans[replica as usize].down_s = Some(t_s);
        self.events.push(FleetEvent {
            t_s,
            replica,
            kind: FleetEventKind::Offline,
        });
    }

    /// Fix the horizon (run makespan). Spans with no explicit offline
    /// time are treated as live through the horizon.
    pub fn close(&mut self, horizon_s: f64) {
        let latest = self
            .events
            .iter()
            .map(|e| e.t_s)
            .fold(0.0f64, f64::max);
        self.horizon_s = horizon_s.max(latest);
    }

    fn span_end(&self, s: &ReplicaSpan) -> f64 {
        s.down_s.unwrap_or(self.horizon_s)
    }

    /// Replica-seconds of existence overlapping [lo, hi).
    pub fn live_seconds_in(&self, lo: f64, hi: f64) -> f64 {
        self.spans
            .iter()
            .map(|s| (self.span_end(s).min(hi) - s.up_s.max(lo)).max(0.0))
            .sum()
    }

    /// Total GPU-seconds of existence over the whole run.
    pub fn live_gpu_seconds(&self, gpus_per_replica: u32) -> f64 {
        self.live_seconds_in(0.0, self.horizon_s) * gpus_per_replica as f64
    }

    /// Replicas existing at instant `t_s`.
    pub fn live_count_at(&self, t_s: f64) -> u32 {
        self.spans
            .iter()
            .filter(|s| s.up_s <= t_s && t_s < self.span_end(s))
            .count() as u32
    }

    /// Time-averaged fleet size over the horizon.
    pub fn mean_fleet(&self) -> f64 {
        if self.horizon_s <= 0.0 {
            return 0.0;
        }
        self.live_seconds_in(0.0, self.horizon_s) / self.horizon_s
    }

    /// Peak concurrent fleet size (evaluated at event boundaries).
    pub fn max_fleet(&self) -> u32 {
        self.spans
            .iter()
            .map(|s| self.live_count_at(s.up_s))
            .max()
            .unwrap_or(0)
    }

    /// Scale-up / scale-down event counts (provisions beyond the
    /// initial fleet, and drains).
    pub fn scale_event_counts(&self) -> (u32, u32) {
        let ups = self
            .events
            .iter()
            .filter(|e| e.kind == FleetEventKind::Provision && e.t_s > 0.0)
            .count() as u32;
        let downs = self
            .events
            .iter()
            .filter(|e| e.kind == FleetEventKind::DrainStart)
            .count() as u32;
        (ups, downs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::policy::StaticPolicy;

    #[test]
    fn controller_clamps_to_bounds() {
        let mut cfg = AutoscaleConfig::default();
        cfg.min_replicas = 2;
        cfg.max_replicas = 3;
        let mut c = FleetController::new(cfg, Box::new(StaticPolicy { replicas: 10 }));
        let load = LoadSignals {
            t_s: 0.0,
            queued: 0,
            running: 0,
            active_replicas: 2,
            pending_replicas: 0,
            recent_qps: 0.0,
            recent_ttft_p99_s: f64::NAN,
            recent_e2e_p99_s: f64::NAN,
            slo_ttft_s: 10.0,
            slo_e2e_s: 60.0,
        };
        let grid = GridEnv::constant(150.0, 0.0).at(0.0);
        assert_eq!(c.desired(&load, &grid), 3);
        assert_eq!(c.decisions.len(), 1);
        assert_eq!(c.decisions[0].desired, 3);
    }

    #[test]
    fn grid_env_applies_start_offset() {
        let env = GridEnv::from_fns(100.0, 200.0, 600.0, 3600.0, |t| t, |_| 0.0);
        // Simulation t=60 queries absolute t=3660.
        assert_eq!(env.at(60.0).ci, 3660.0);
        assert_eq!(env.at(0.0).ci_high, 200.0);
    }

    #[test]
    fn timeline_live_accounting() {
        let mut t = FleetTimeline::new();
        t.provision(0, 0.0);
        t.online(0, 0.0);
        t.provision(1, 100.0);
        t.online(1, 160.0);
        t.drain_start(1, 400.0);
        t.offline(1, 500.0);
        t.close(1000.0);

        // Replica 0 lives 0..1000, replica 1 lives 100..500.
        assert_eq!(t.live_seconds_in(0.0, 1000.0), 1000.0 + 400.0);
        assert_eq!(t.live_seconds_in(0.0, 100.0), 100.0);
        assert_eq!(t.live_seconds_in(450.0, 600.0), 150.0 + 50.0);
        assert_eq!(t.live_count_at(50.0), 1);
        assert_eq!(t.live_count_at(300.0), 2);
        assert_eq!(t.live_count_at(600.0), 1);
        assert_eq!(t.max_fleet(), 2);
        assert!((t.mean_fleet() - 1.4).abs() < 1e-12);
        assert_eq!(t.live_gpu_seconds(2), 2.0 * 1400.0);
        let (ups, downs) = t.scale_event_counts();
        assert_eq!((ups, downs), (1, 1));
    }

    #[test]
    fn static_fleet_timeline_is_flat() {
        let t = FleetTimeline::static_fleet(3, 600.0);
        assert_eq!(t.live_count_at(0.0), 3);
        assert_eq!(t.live_count_at(599.0), 3);
        assert_eq!(t.mean_fleet(), 3.0);
        assert_eq!(t.live_gpu_seconds(1), 1800.0);
        assert_eq!(t.scale_event_counts(), (0, 0));
    }

    #[test]
    fn close_extends_to_latest_event() {
        let mut t = FleetTimeline::new();
        t.provision(0, 0.0);
        t.online(0, 0.0);
        t.offline(0, 750.0);
        t.close(600.0);
        assert!(t.horizon_s >= 750.0);
    }
}
