//! Carbon-aware autoscaling (DESIGN.md §6): an in-simulation fleet
//! controller that, on a configurable decision interval, observes load
//! telemetry (queue depth, achieved QPS, recent TTFT/e2e percentiles
//! against the SLO targets) and grid signals (carbon intensity, solar
//! availability) and issues scale-up / scale-down / drain decisions
//! for replicas.
//!
//! The subsystem splits into:
//! * [`policy`] — the [`ScalingPolicy`] trait and the three shipped
//!   policies (reactive queue-based, SLO-guarded carbon-aware,
//!   solar-following) plus the static baseline;
//! * [`controller`] — the [`FleetController`] that clamps and records
//!   decisions, the [`GridEnv`] signal source, and the
//!   [`FleetTimeline`] of replica lifecycle events that the energy
//!   accounting ([`crate::energy`]) and Eq. 5 binning
//!   ([`crate::pipeline`]) consume so idle power is charged only for
//!   replicas that exist at each instant.
//!
//! The engine side ([`crate::sim::engine::run_autoscaled`]) threads the
//! lifecycle through the event loop: provision (with cold-start delay,
//! drawing idle power while booting), online, graceful drain (stops
//! admitting, finishes running requests, re-queues queued ones via the
//! [`crate::scheduler::router::Router`]), and offline.

pub mod controller;
pub mod policy;
pub mod window;

pub use controller::{
    FleetController, FleetEvent, FleetEventKind, FleetTimeline, GridEnv, GridSignals,
    LoadSignals, ReplicaSpan, ScaleDecision,
};
pub use window::CompletionWindow;
pub use policy::{
    build_policy, CarbonAwarePolicy, ReactivePolicy, ScalingPolicy, SolarFollowingPolicy,
    StaticPolicy,
};
