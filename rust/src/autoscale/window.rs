//! The autoscaler's recent-completion window, as a request-telemetry
//! sink client (DESIGN.md §8).
//!
//! The engine feeds every completed request to this window alongside
//! the caller's [`RequestSink`]; on each scaling tick the controller
//! reads windowed completion rate and TTFT/e2e p99s from it. Keeping
//! it behind the same trait as the metrics sinks means the scaling
//! telemetry taps the identical completion stream — no second
//! bookkeeping path inside the engine loop.
//!
//! Since DESIGN.md §10 the ring-buffer mechanics live in the shared
//! [`TimeWindow`] (`util::stats`) — the same substrate the live-watch
//! windows (`telemetry::window`) run on. The eviction convention is
//! the shared one, audited when the window was lifted: an entry whose
//! finish time lands **exactly** on `now − window` is retained (the
//! window is the inclusive trailing interval `[now − window, now]`);
//! only strictly older entries fall out. A regression test below pins
//! that boundary.

use crate::telemetry::{RequestSink, RequestStats};
use crate::util::stats::{percentile, TimeWindow};
use crate::workload::Request;

/// Sliding window over recent completions, keyed by finish time and
/// carrying (TTFT, e2e) samples. Memory is O(completions inside the
/// window), bounded by the window length × completion rate — the
/// engine prunes it every tick.
#[derive(Debug)]
pub struct CompletionWindow {
    window: TimeWindow<(f64, f64)>,
}

impl CompletionWindow {
    pub fn new(window_s: f64) -> Self {
        CompletionWindow {
            window: TimeWindow::new(window_s),
        }
    }

    /// The configured window length, seconds.
    pub fn window_s(&self) -> f64 {
        self.window.window_s()
    }

    /// Drop completions strictly older than `now - window`.
    pub fn prune(&mut self, now: f64) {
        self.window.prune(now);
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Completions per second over the (elapsed part of the) window.
    pub fn qps(&self, now: f64) -> f64 {
        self.window.rate(now)
    }

    /// Windowed TTFT p99 (NaN when nothing completed recently).
    pub fn ttft_p99(&self) -> f64 {
        self.p99(|&(ttft, _)| ttft)
    }

    /// Windowed e2e p99 (NaN when nothing completed recently).
    pub fn e2e_p99(&self) -> f64 {
        self.p99(|&(_, e2e)| e2e)
    }

    fn p99(&self, f: impl Fn(&(f64, f64)) -> f64) -> f64 {
        if self.window.is_empty() {
            return f64::NAN;
        }
        let v: Vec<f64> = self.window.iter().map(|(_, s)| f(s)).collect();
        percentile(&v, 99.0)
    }
}

impl RequestSink for CompletionWindow {
    fn record(&mut self, r: &Request) {
        // Completions arrive in finish order; an unfinished request
        // (never produced by the engines) is ignored.
        if let Some(fin) = r.finished_s {
            self.window
                .push(fin, (r.ttft().unwrap_or(0.0), r.e2e_latency().unwrap_or(0.0)));
        }
    }

    /// Windowed view of the standard request aggregates — enough for a
    /// dashboard tap; the engine's SLO metrics come from the primary
    /// sink, not from here.
    fn stats(&self) -> RequestStats {
        let ttft: Vec<f64> = self.window.iter().map(|(_, s)| s.0).collect();
        let e2e: Vec<f64> = self.window.iter().map(|(_, s)| s.1).collect();
        let pc = |v: &[f64], p: f64| if v.is_empty() { 0.0 } else { percentile(v, p) };
        RequestStats {
            submitted: self.window.len() as u64,
            finished: self.window.len() as u64,
            ttft_p50_s: pc(&ttft, 50.0),
            ttft_p99_s: pc(&ttft, 99.0),
            e2e_p50_s: pc(&e2e, 50.0),
            e2e_p99_s: pc(&e2e, 99.0),
            ..RequestStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done(id: u64, fin: f64, ttft: f64, e2e: f64) -> Request {
        let mut r = Request::new(id, fin - e2e, 10, 5);
        r.prefill_done = 10;
        r.decode_done = 5;
        r.scheduled_s = Some(fin - e2e);
        r.first_token_s = Some(fin - e2e + ttft);
        r.finished_s = Some(fin);
        r
    }

    #[test]
    fn window_prunes_and_reports() {
        let mut w = CompletionWindow::new(100.0);
        for i in 0..10u64 {
            w.record(&done(i, i as f64 * 20.0, 0.5, 2.0));
        }
        assert_eq!(w.len(), 10);
        // At t=200 the cutoff is 100: completions at 0, 20, 40, 60, 80
        // fall out.
        w.prune(200.0);
        assert_eq!(w.len(), 5);
        assert!((w.qps(200.0) - 5.0 / 100.0).abs() < 1e-12);
        assert!((w.ttft_p99() - 0.5).abs() < 1e-12);
        assert!((w.e2e_p99() - 2.0).abs() < 1e-12);
        let st = w.stats();
        assert_eq!(st.finished, 5);
        assert_eq!(st.ttft_p50_s, 0.5);
    }

    #[test]
    fn empty_window_is_nan_percentiles() {
        let mut w = CompletionWindow::new(60.0);
        assert!(w.ttft_p99().is_nan());
        assert!(w.e2e_p99().is_nan());
        assert_eq!(w.qps(30.0), 0.0);
        w.prune(1000.0); // no-op on empty
        assert!(w.is_empty());
    }

    #[test]
    fn early_window_uses_elapsed_time() {
        let mut w = CompletionWindow::new(300.0);
        w.record(&done(0, 10.0, 0.1, 1.0));
        // Only 20 s elapsed: rate is 1/20, not 1/300.
        assert!((w.qps(20.0) - 0.05).abs() < 1e-12);
    }

    /// Satellite regression (boundary audit): the convention kept when
    /// the window was rebased onto the shared `TimeWindow` is the
    /// *inclusive* cutoff — a completion landing exactly at
    /// `now − window` survives the prune; anything strictly older
    /// falls out. The pre-rebase code (`e.0 < cutoff`) behaved the
    /// same; this pins it so neither side drifts.
    #[test]
    fn prune_boundary_is_inclusive() {
        let mut w = CompletionWindow::new(100.0);
        w.record(&done(0, 50.0, 0.5, 2.0));
        w.record(&done(1, 99.0, 0.5, 2.0));
        // cutoff = 50.0: the t = 50.0 entry is exactly on it — kept.
        w.prune(150.0);
        assert_eq!(w.len(), 2, "entry at the cutoff must be retained");
        // One epsilon later it is strictly older — evicted.
        w.prune(150.0 + 1e-9);
        assert_eq!(w.len(), 1);
    }
}
