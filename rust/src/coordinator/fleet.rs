//! Regional-fleet coordinator (DESIGN.md §13): request-granularity
//! carbon-aware global routing.
//!
//! Where [`crate::coordinator::multiregion`] compares policies by
//! arithmetic over a pre-binned load profile, this layer actually
//! *runs* the fleet: every [`FleetRegion`] owns a simulated cluster
//! (engine replicas + optional per-region
//! [`crate::autoscale::FleetController`] + a
//! [`crate::cosim::Microgrid`] with battery and solar + a
//! phase-shifted [`crate::grid::CarbonIntensityTrace`]), all advanced
//! on one shared clock by [`crate::sim::run_multifleet`]. A
//! [`RoutePolicy`] assigns each request at admission time from live
//! signals — grid CI, battery state of charge, queue depth, and the
//! inter-region RTT measured against the TTFT SLO.
//!
//! Accounting is two-tier (the same split the autoscale experiment
//! uses): inside the engine the microgrid is stepped with an
//! *advisory* demand estimate so the battery SoC the router sees moves
//! with fleet activity; after the run, each region's streamed stage
//! records are binned against its replica timeline and co-simulated
//! ([`crate::cosim::Environment`]) against the exact same CI/solar
//! series the closed-form oracle samples
//! ([`crate::coordinator::multiregion::region_series`]) — which is
//! what makes the degenerate-case equivalence test meaningful.

use crate::autoscale::GridEnv;
use crate::battery::Battery;
use crate::config::simconfig::{AutoscaleConfig, CosimConfig, SimConfig};
use crate::coordinator::multiregion::{region_series, Region};
use crate::cosim::{CosimResult, Environment, Microgrid};
use crate::energy::{EnergyAccountant, EnergyReport};
use crate::exec::build_cost_model;
use crate::grid::{CarbonIntensityTrace, SolarModel};
use crate::power::PowerModel;
use crate::report::live;
use crate::sim::{self, MultiFleetRun, RegionSim};
use crate::telemetry::{StreamingRequestSink, StreamingSink};
use crate::workload::RequestSource;
use anyhow::{ensure, Result};

/// Live per-region state a [`RoutePolicy`] decides from. One snapshot
/// per region, taken at the arrival instant on the shared clock.
#[derive(Debug, Clone, Copy)]
pub struct RegionSignals {
    /// Grid carbon intensity right now, gCO₂/kWh.
    pub ci_g_per_kwh: f64,
    /// Solar generation right now, W.
    pub solar_w: f64,
    /// Advisory fleet demand estimate (active replicas × est. W).
    pub est_demand_w: f64,
    /// Battery state of charge, fraction of capacity.
    pub battery_soc: f64,
    /// Battery SoC floor (discharge stops here).
    pub soc_min: f64,
    /// Battery SoC ceiling (charge stops here).
    pub soc_max: f64,
    /// Outstanding (queued + running) requests in the region.
    pub queue_depth: u64,
    /// Replicas currently serving traffic.
    pub active_replicas: u32,
    /// One-way RTT from the router to this region, seconds (0 at home).
    pub rtt_s: f64,
    /// Fractional energy overhead of moving a request here (0 at home).
    pub transfer_overhead: f64,
}

/// Object-safe admission-time routing policy: pick the region index
/// for one request. Called once per arrival with one snapshot per
/// region; index 0 is the home region.
pub trait RoutePolicy {
    fn route(&mut self, arrival_s: f64, signals: &[RegionSignals]) -> usize;
    fn name(&self) -> &'static str;
}

/// Effective grams-per-kWh cost of serving in a region right now:
/// transfer overhead inflates remote energy, and solar covering the
/// estimated demand discounts it. With zero solar this collapses to
/// `(1 + overhead) × ci` — exactly the closed-form oracle's greedy
/// scan — which is what the degenerate-case equivalence relies on.
fn effective_cost(s: &RegionSignals) -> f64 {
    let headroom = if s.est_demand_w > 0.0 {
        (s.solar_w / s.est_demand_w).min(1.0)
    } else {
        0.0
    };
    (1.0 + s.transfer_overhead) * s.ci_g_per_kwh * (1.0 - headroom)
}

/// First index minimizing `cost` (strict `<` scan, so the home region
/// wins ties — the same tie-break as `multiregion::simulate`).
fn argmin_by(signals: &[RegionSignals], mut cost: impl FnMut(&RegionSignals) -> f64) -> usize {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for (i, s) in signals.iter().enumerate() {
        let c = cost(s);
        if c < best_cost {
            best_cost = c;
            best = i;
        }
    }
    best
}

/// Everything stays in the home region — the byte-neutrality baseline.
struct StaticHomePolicy;
impl RoutePolicy for StaticHomePolicy {
    fn route(&mut self, _arrival_s: f64, _signals: &[RegionSignals]) -> usize {
        0
    }
    fn name(&self) -> &'static str {
        "static-home"
    }
}

/// Route to the lowest effective-CI region, ignoring latency.
struct GreedyCiPolicy;
impl RoutePolicy for GreedyCiPolicy {
    fn route(&mut self, _arrival_s: f64, signals: &[RegionSignals]) -> usize {
        argmin_by(signals, effective_cost)
    }
    fn name(&self) -> &'static str {
        "greedy-ci"
    }
}

/// Lowest effective CI among regions whose RTT fits inside the TTFT
/// SLO budget (a remote hop may spend at most a quarter of it); falls
/// back to home when nothing remote is feasible.
struct SloCarbonPolicy {
    slo_ttft_s: f64,
}
impl RoutePolicy for SloCarbonPolicy {
    fn route(&mut self, _arrival_s: f64, signals: &[RegionSignals]) -> usize {
        let budget = 0.25 * self.slo_ttft_s;
        argmin_by(signals, |s| {
            if s.rtt_s <= budget {
                effective_cost(s)
            } else {
                f64::INFINITY
            }
        })
    }
    fn name(&self) -> &'static str {
        "latency-slo-carbon"
    }
}

/// Follow the renewables: effective CI discounted by how full the
/// region's battery is — stored clean energy makes a region cheaper.
struct SocAwarePolicy;
impl RoutePolicy for SocAwarePolicy {
    fn route(&mut self, _arrival_s: f64, signals: &[RegionSignals]) -> usize {
        argmin_by(signals, |s| {
            let span = (s.soc_max - s.soc_min).max(1e-9);
            let frac = ((s.battery_soc - s.soc_min) / span).clamp(0.0, 1.0);
            effective_cost(s) * (1.0 - 0.5 * frac)
        })
    }
    fn name(&self) -> &'static str {
        "battery-soc-aware"
    }
}

/// The built-in routing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicyKind {
    StaticHome,
    GreedyCi,
    LatencySloCarbon,
    BatterySocAware,
}

impl RoutePolicyKind {
    pub fn all() -> [RoutePolicyKind; 4] {
        [
            RoutePolicyKind::StaticHome,
            RoutePolicyKind::GreedyCi,
            RoutePolicyKind::LatencySloCarbon,
            RoutePolicyKind::BatterySocAware,
        ]
    }

    pub fn as_str(self) -> &'static str {
        match self {
            RoutePolicyKind::StaticHome => "static-home",
            RoutePolicyKind::GreedyCi => "greedy-ci",
            RoutePolicyKind::LatencySloCarbon => "latency-slo-carbon",
            RoutePolicyKind::BatterySocAware => "battery-soc-aware",
        }
    }

    pub fn parse(s: &str) -> Option<RoutePolicyKind> {
        match s.trim().replace('_', "-").as_str() {
            "static-home" | "static" => Some(RoutePolicyKind::StaticHome),
            "greedy-ci" | "greedy" => Some(RoutePolicyKind::GreedyCi),
            "latency-slo-carbon" | "slo-carbon" => Some(RoutePolicyKind::LatencySloCarbon),
            "battery-soc-aware" | "soc-aware" | "battery" => Some(RoutePolicyKind::BatterySocAware),
            _ => None,
        }
    }

    /// Instantiate the policy. `slo_ttft_s` parameterizes the
    /// latency-aware policy's RTT budget.
    pub fn build(self, slo_ttft_s: f64) -> Box<dyn RoutePolicy> {
        match self {
            RoutePolicyKind::StaticHome => Box::new(StaticHomePolicy),
            RoutePolicyKind::GreedyCi => Box::new(GreedyCiPolicy),
            RoutePolicyKind::LatencySloCarbon => Box::new(SloCarbonPolicy { slo_ttft_s }),
            RoutePolicyKind::BatterySocAware => Box::new(SocAwarePolicy),
        }
    }
}

/// One region of the global fleet: its grid environment plus the
/// simulated cluster and microgrid it owns.
#[derive(Debug, Clone)]
pub struct FleetRegion {
    pub region: Region,
    /// Initial (and, without `scale`, fixed) replica count.
    pub replicas: u32,
    /// Per-region autoscaler; `None` keeps the fleet fixed.
    pub scale: Option<AutoscaleConfig>,
    /// One-way RTT from the router (home region) to here, seconds.
    pub rtt_s: f64,
    /// Microgrid parameters: battery, interval, transfer overhead.
    pub cosim: CosimConfig,
}

impl FleetRegion {
    /// A region with the default microgrid, no autoscaler, no RTT.
    pub fn new(region: Region, replicas: u32) -> Self {
        FleetRegion {
            region,
            replicas,
            scale: None,
            rtt_s: 0.0,
            cosim: CosimConfig::default(),
        }
    }
}

/// The whole global fleet: regions (index 0 = home, where requests
/// arrive), the routing policy, and an optional power-model override.
#[derive(Debug, Clone)]
pub struct GlobalFleetSpec {
    pub regions: Vec<FleetRegion>,
    pub policy: RoutePolicyKind,
    /// Override the accounting power model (e.g. a zero-idle model for
    /// the degenerate-case oracle test, where always-on remote
    /// replicas must not book idle watts the closed-form path never
    /// sees). `None` uses the paper-default model.
    pub power_model: Option<PowerModel>,
}

/// Per-region outcome: routing, fleet shape, and the two energy views
/// (fleet-aware accounting and microgrid co-simulation).
pub struct RegionReport {
    pub name: String,
    /// Requests the policy routed here.
    pub routed: u64,
    pub mean_fleet: f64,
    pub max_fleet: u32,
    /// GPU-side accounted energy (stages + idle fill), kWh.
    pub gpu_energy_kwh: f64,
    /// Eq. 5 binned demand integrated over the run, kWh (equals
    /// `gpu_energy_kwh` — the conservation test pins this).
    pub binned_energy_kwh: f64,
    /// Full fleet-aware accounting report (PUE, embodied, peak).
    pub energy: EnergyReport,
    /// Microgrid co-simulation of the region's (overhead-inflated)
    /// demand against its CI/solar series.
    pub cosim: CosimResult,
    /// Battery SoC at the end of the in-engine advisory stepping.
    pub final_soc: f64,
}

/// A complete global-routing run: the engine output plus per-region
/// accounting and the fleet-level rollups.
pub struct GlobalRunResult {
    pub run: MultiFleetRun,
    pub regions: Vec<RegionReport>,
    /// Σ per-region GPU-side energy, kWh.
    pub fleet_gpu_energy_kwh: f64,
    /// Σ per-region net grid-import emissions, gCO₂.
    pub fleet_emissions_g: f64,
    /// Requests served outside the home region.
    pub moved_requests: u64,
    /// Largest per-region streaming-sink bin residency (memory bound).
    pub peak_resident_bins: usize,
}

/// Build a region's live grid environment: the same
/// [`CarbonIntensityTrace`]/[`SolarModel`] sampling as
/// [`region_series`], wrapped as closures with the time-zone phase
/// baked in, so the router's live signals and the post-hoc accounting
/// draw from one source of truth.
fn region_grid(r: &Region, seed: u64) -> GridEnv {
    let trace = CarbonIntensityTrace {
        mean: r.ci_mean,
        seed,
        ..CarbonIntensityTrace::default()
    };
    let ci_low = (trace.mean - trace.diurnal_amplitude).max(40.0);
    let ci_high = trace.mean + trace.diurnal_amplitude;
    let solar = SolarModel {
        capacity_w: r.solar_w,
        ..SolarModel::default()
    };
    let off = r.tz_offset_h * 3600.0;
    GridEnv::from_fns(
        ci_low,
        ci_high,
        r.solar_w,
        0.0,
        move |t| trace.base_at(t + off),
        move |t| solar.clear_sky_w(t + off),
    )
}

/// Run the global fleet: route every request of `source` across
/// `spec.regions` under `spec.policy`, then account each region's
/// energy and emissions. `tap` (when watching) observes the home
/// region's telemetry live.
pub fn run_global(
    cfg: &SimConfig,
    spec: &GlobalFleetSpec,
    source: &mut dyn RequestSource,
    tap: Option<live::CaseTap>,
) -> Result<GlobalRunResult> {
    ensure!(
        !spec.regions.is_empty(),
        "global fleet needs at least one region"
    );
    let n = spec.regions.len();
    let acc = EnergyAccountant::paper_default(cfg)?;
    let model = spec.power_model.unwrap_or(acc.power_model);
    let interval_s = spec.regions[0].cosim.interval_s;

    let mut sinks = Vec::with_capacity(n);
    let mut reqsinks = Vec::with_capacity(n);
    let mut grids = Vec::with_capacity(n);
    let mut microgrids = Vec::with_capacity(n);
    for (i, fr) in spec.regions.iter().enumerate() {
        sinks.push(StreamingSink::with_model(cfg, fr.cosim.interval_s, model)?);
        reqsinks.push(StreamingRequestSink::new(cfg));
        grids.push(region_grid(&fr.region, cfg.seed ^ (i as u64)));
        microgrids.push(Microgrid::new(Battery::from_config(&fr.cosim)));
    }
    // Advisory per-replica wattage for the in-engine microgrid/router
    // signals (authoritative energy comes from the post-hoc binning).
    let power_est_w = model.power(0.3, true) * cfg.gpus_per_replica() as f64;

    let cost = build_cost_model(cfg)?;
    let mut policy = spec.policy.build(cfg.slo_ttft_s);
    let grid_ci = acc.grid_ci;

    let (home_sinks, rest_sinks) = sinks.split_at_mut(1);
    let (home_reqs, rest_reqs) = reqsinks.split_at_mut(1);
    let run = live::run_observed(
        tap,
        cfg,
        grid_ci,
        &mut home_sinks[0],
        &mut home_reqs[0],
        |s, r| {
            let mut grids_it = grids.into_iter();
            let mut micro_it = microgrids.into_iter();
            let mut specs: Vec<RegionSim<'_>> = Vec::with_capacity(n);
            let fr0 = &spec.regions[0];
            specs.push(RegionSim {
                replicas: fr0.replicas,
                scale: fr0.scale.clone(),
                grid: grids_it.next().unwrap(),
                rtt_s: 0.0,
                power_est_w,
                microgrid: micro_it.next().unwrap(),
                interval_s: fr0.cosim.interval_s,
                transfer_overhead: 0.0,
                sink: s,
                requests: r,
            });
            for ((fr, sk), rq) in spec.regions[1..]
                .iter()
                .zip(rest_sinks.iter_mut())
                .zip(rest_reqs.iter_mut())
            {
                specs.push(RegionSim {
                    replicas: fr.replicas,
                    scale: fr.scale.clone(),
                    grid: grids_it.next().unwrap(),
                    rtt_s: fr.rtt_s.max(0.0),
                    power_est_w,
                    microgrid: micro_it.next().unwrap(),
                    interval_s: fr.cosim.interval_s,
                    transfer_overhead: fr.cosim.transfer_overhead,
                    sink: sk,
                    requests: rq,
                });
            }
            sim::run_multifleet(cfg, source, cost, policy.as_mut(), specs)
        },
    )?;

    // Post-hoc authoritative accounting: bin each region's streamed
    // stages against its own timeline, then co-simulate the
    // (overhead-inflated) demand against the oracle's CI/solar series.
    let rlist: Vec<Region> = spec.regions.iter().map(|fr| fr.region.clone()).collect();
    let mut binned = Vec::with_capacity(n);
    for (i, sk) in sinks.iter().enumerate() {
        binned.push(sk.binned(cfg, &run.per_region[i].timeline)?);
    }
    let n_bins = binned.iter().map(|b| b.len()).max().unwrap_or(0);
    let (ci, solar) = region_series(&rlist, n_bins, interval_s, cfg.seed);

    let racc = EnergyAccountant {
        power_model: model,
        ..acc
    };
    let mut regions_out = Vec::with_capacity(n);
    let mut fleet_gpu_energy_kwh = 0.0;
    let mut fleet_emissions_g = 0.0;
    for (i, fr) in spec.regions.iter().enumerate() {
        let rr = &run.per_region[i];
        let energy = racc.report_fleet(cfg, sinks[i].aggregates(), &rr.timeline);
        let b = &binned[i];
        let len = b.len();
        let overhead = if i == 0 {
            1.0
        } else {
            1.0 + fr.cosim.transfer_overhead
        };
        let load: Vec<f64> = b.power_w.iter().map(|w| w * overhead).collect();
        let mut env = Environment::new(fr.cosim.clone());
        let cosim = env.run_native(&load, &solar[i][..len], &ci[i][..len])?;
        fleet_gpu_energy_kwh += energy.gpu_energy_kwh;
        fleet_emissions_g += cosim.net_footprint_g;
        regions_out.push(RegionReport {
            name: fr.region.name.clone(),
            routed: rr.routed,
            mean_fleet: rr.timeline.mean_fleet(),
            max_fleet: rr.timeline.max_fleet(),
            gpu_energy_kwh: energy.gpu_energy_kwh,
            binned_energy_kwh: b.total_energy_kwh(),
            energy,
            cosim,
            final_soc: rr.final_soc,
        });
    }
    let moved_requests = run.per_region.iter().skip(1).map(|r| r.routed).sum();
    let peak_resident_bins = sinks
        .iter()
        .map(|s| s.peak_resident_bins())
        .max()
        .unwrap_or(0);
    Ok(GlobalRunResult {
        run,
        regions: regions_out,
        fleet_gpu_energy_kwh,
        fleet_emissions_g,
        moved_requests,
        peak_resident_bins,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(ci: f64, overhead: f64, rtt_s: f64) -> RegionSignals {
        RegionSignals {
            ci_g_per_kwh: ci,
            solar_w: 0.0,
            est_demand_w: 300.0,
            battery_soc: 0.5,
            soc_min: 0.2,
            soc_max: 0.8,
            queue_depth: 0,
            active_replicas: 1,
            rtt_s,
            transfer_overhead: overhead,
        }
    }

    #[test]
    fn parse_roundtrip_and_aliases() {
        for k in RoutePolicyKind::all() {
            assert_eq!(RoutePolicyKind::parse(k.as_str()), Some(k));
            assert_eq!(k.build(0.5).name(), k.as_str());
        }
        assert_eq!(
            RoutePolicyKind::parse("greedy_ci"),
            Some(RoutePolicyKind::GreedyCi)
        );
        assert_eq!(
            RoutePolicyKind::parse("static"),
            Some(RoutePolicyKind::StaticHome)
        );
        assert_eq!(RoutePolicyKind::parse("nope"), None);
    }

    #[test]
    fn static_home_always_routes_home() {
        let mut p = RoutePolicyKind::StaticHome.build(0.5);
        let s = [sig(900.0, 0.0, 0.0), sig(10.0, 0.05, 0.05)];
        assert_eq!(p.route(0.0, &s), 0);
    }

    #[test]
    fn greedy_ci_picks_cheapest_effective_and_breaks_ties_home() {
        let mut p = RoutePolicyKind::GreedyCi.build(0.5);
        // Remote is cheaper even after the 5% transfer overhead.
        let s = [sig(400.0, 0.0, 0.0), sig(120.0, 0.05, 0.05)];
        assert_eq!(p.route(0.0, &s), 1);
        // Equal effective cost: the strict-< scan keeps traffic home.
        let s = [sig(105.0, 0.0, 0.0), sig(100.0, 0.05, 0.05)];
        assert_eq!(p.route(0.0, &s), 0);
        // Solar headroom discounts a region's effective CI.
        let mut covered = sig(400.0, 0.0, 0.0);
        covered.solar_w = 300.0; // covers the whole est_demand_w
        let s = [sig(120.0, 0.0, 0.0), covered];
        assert_eq!(p.route(0.0, &s), 1);
    }

    #[test]
    fn slo_policy_excludes_regions_beyond_the_rtt_budget() {
        // TTFT SLO 0.4 s → RTT budget 0.1 s.
        let mut p = RoutePolicyKind::LatencySloCarbon.build(0.4);
        let far = sig(10.0, 0.05, 0.2); // cheapest, but too far
        let near = sig(120.0, 0.05, 0.05);
        assert_eq!(p.route(0.0, &[sig(400.0, 0.0, 0.0), far, near]), 2);
        // Nothing feasible but home → home.
        assert_eq!(p.route(0.0, &[sig(400.0, 0.0, 0.0), far]), 0);
    }

    #[test]
    fn soc_aware_prefers_the_fuller_battery_at_equal_ci() {
        let mut p = RoutePolicyKind::BatterySocAware.build(0.5);
        let mut full = sig(200.0, 0.0, 0.0);
        full.battery_soc = 0.8;
        let mut empty = sig(200.0, 0.0, 0.0);
        empty.battery_soc = 0.2;
        assert_eq!(p.route(0.0, &[empty, full]), 1);
    }
}
