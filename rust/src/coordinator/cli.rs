//! Command-line interface of the `repro` binary.
//!
//! Subcommands:
//!   simulate    — one inference-simulation run (prints metrics JSON)
//!   cosim       — full Vidur→Vessim case-study pipeline
//!   autoscale   — sweep fleet-scaling policies over a day of grid signals
//!   experiment  — regenerate a paper table/figure (or `all`)
//!   merge       — recombine sharded sweep outputs (DESIGN.md §9)
//!   multiregion — carbon-aware multi-region routing exploration
//!   policy      — model-size vs grid-condition policy exploration
//!   config      — show the default (Table 1) configuration
//!   report      — assemble results/ into one markdown report
//!   trace       — generate and save a workload trace CSV
//!
//! The full flag-by-flag reference lives in `docs/CLI.md`.

use crate::config::simconfig::{Arrival, CosimConfig, CostModelKind, LengthDist, SimConfig};
use crate::coordinator::{multiregion, policy};
use crate::energy::EnergyAccountant;
use crate::experiments;
use crate::report;
use crate::sim;
use crate::sweep;
use crate::telemetry::StreamingSink;
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::Value;
use crate::workload::{Trace, WorkloadGenerator};
use anyhow::{bail, Result};
use std::path::PathBuf;

const TOP_USAGE: &str = "repro — rust+JAX+Pallas reproduction of 'Quantifying the Energy \
Consumption and Carbon Emissions of LLM Inference via Simulations'

subcommands:
  simulate     run one inference simulation
  cosim        run the Vidur→Vessim integration case study
  autoscale    sweep fleet-scaling policies (static/reactive/carbon/solar) over a day of grid signals
  experiment   regenerate paper tables/figures: fig1 exp1..exp5 casestudy ablation autoscale all
               (--jobs N sweeps cases in parallel; --shard k/N splits the grid across machines)
  merge        recombine sharded sweep outputs: repro merge <shard-dir>... --out results
  multiregion  carbon-aware multi-region routing exploration
  policy       model-size policy exploration (small in dirty grid vs large in clean)
  config       print the default Table-1 configuration
  report       assemble results/ into a markdown report
  trace        generate a workload trace CSV

see docs/CLI.md for every flag of every subcommand
";

/// Entry point used by main.rs.
pub fn run(argv: Vec<String>) -> Result<()> {
    let mut it = argv.into_iter();
    let _bin = it.next();
    let Some(cmd) = it.next() else {
        print!("{TOP_USAGE}");
        return Ok(());
    };
    let rest: Vec<String> = it.collect();
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "cosim" => cmd_cosim(&args),
        "autoscale" => cmd_autoscale(&args),
        "experiment" => cmd_experiment(&args),
        "merge" => cmd_merge(&args),
        "multiregion" => multiregion::cmd(&args),
        "policy" => policy::cmd(&args),
        "config" => cmd_config(),
        "report" => cmd_report(&args),
        "trace" => cmd_trace(&args),
        "help" | "--help" | "-h" => {
            print!("{TOP_USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{TOP_USAGE}"),
    }
}

/// Apply the common simulation overrides shared by several commands.
pub fn apply_sim_overrides(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(g) = args.get("gpu") {
        cfg.gpu = g.to_string();
    }
    cfg.tp = args.u64_or("tp", cfg.tp as u64)? as u32;
    cfg.pp = args.u64_or("pp", cfg.pp as u64)? as u32;
    cfg.replicas = args.u64_or("replicas", cfg.replicas as u64)? as u32;
    cfg.num_requests = args.u64_or("requests", cfg.num_requests)?;
    cfg.batch_cap = args.usize_or("batch-cap", cfg.batch_cap)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    let qps = args.f64_or("qps", cfg.arrival.qps())?;
    cfg.arrival = Arrival::Poisson { qps };
    if let Some(total) = args.get("fixed-len") {
        cfg.lengths = LengthDist::Fixed {
            total: total.parse()?,
        };
    }
    if args.get("pd-ratio").is_some() {
        cfg.prefill_decode_ratio = Some(args.f64_or("pd-ratio", 4.0)?);
    }
    cfg.cost_model = match args.str_or("cost-model", "hlo").as_str() {
        "native" => CostModelKind::Native,
        "hlo" => CostModelKind::Hlo,
        other => bail!("unknown --cost-model '{other}' (native|hlo)"),
    };
    cfg.exec.rf_noise_std = args.f64_or("rf-noise", cfg.exec.rf_noise_std)?;
    cfg.validate()
}

fn sim_opts() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "model", help: "model key (llama3-8b, ...)", default: Some("llama3-8b") },
        OptSpec { name: "gpu", help: "gpu key (a100-80g, h100, a40)", default: Some("a100-80g") },
        OptSpec { name: "tp", help: "tensor parallelism", default: Some("1") },
        OptSpec { name: "pp", help: "pipeline parallelism", default: Some("1") },
        OptSpec { name: "replicas", help: "replica count", default: Some("1") },
        OptSpec { name: "requests", help: "request count (supports 2^16, 400k, 2M)", default: Some("1024") },
        OptSpec { name: "qps", help: "Poisson arrival rate", default: Some("6.45") },
        OptSpec { name: "batch-cap", help: "max batch size", default: Some("128") },
        OptSpec { name: "fixed-len", help: "fixed total tokens per request", default: None },
        OptSpec { name: "pd-ratio", help: "prefill:decode ratio", default: None },
        OptSpec { name: "cost-model", help: "stage oracle: hlo|native", default: Some("hlo") },
        OptSpec { name: "rf-noise", help: "lognormal latency noise sigma", default: Some("0") },
        OptSpec { name: "seed", help: "rng seed", default: None },
        OptSpec { name: "stagelog", help: "write per-stage CSV here (materializes the run)", default: None },
        OptSpec { name: "config", help: "load SimConfig JSON file", default: None },
    ]
}

fn cmd_simulate(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{}", usage("repro simulate", "one inference run", &sim_opts()));
        return Ok(());
    }
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::load(path)?,
        None => SimConfig::default(),
    };
    apply_sim_overrides(&mut cfg, args)?;
    let mut v = Value::obj();
    v.set("config", cfg.to_json());
    if let Some(path) = args.get("stagelog") {
        // Per-stage CSV export needs every record: materialized run.
        let out = sim::run(&cfg)?;
        let acc = EnergyAccountant::paper_default(&cfg)?;
        let energy = acc.account(&cfg, &out.stagelog, out.metrics.makespan_s);
        v.set("metrics", out.metrics.to_json())
            .set("energy", energy.to_json());
        if out.oracle.calls > 0 {
            v.set("oracle_cache", out.oracle.to_json());
        }
        println!("{}", v.pretty());
        out.stagelog.save_csv(path)?;
        eprintln!("stage log -> {path}");
    } else {
        // Default: fully streaming run — arrivals are generated
        // lazily, requests fold into latency sketches, stages into
        // one-minute bins, so `--requests 2M` holds O(outstanding +
        // bins) state (the CI smoke asserts exactly that from the
        // telemetry object below).
        let acc = EnergyAccountant::paper_default(&cfg)?;
        let mut sink = StreamingSink::with_model(&cfg, 60.0, acc.power_model)?;
        let run = sim::run_streaming(&cfg, &mut sink)?;
        let energy = acc.report(&cfg, sink.aggregates(), run.metrics.makespan_s);
        let mut telemetry = Value::obj();
        telemetry
            .set("submitted", run.request_stats.submitted)
            .set("finished", run.request_stats.finished)
            .set("peak_live_requests", run.peak_live_requests as u64)
            .set("peak_resident_bins", sink.peak_resident_bins() as u64);
        v.set("metrics", run.metrics.to_json())
            .set("energy", energy.to_json())
            .set("telemetry", telemetry);
        if run.oracle.calls > 0 {
            v.set("oracle_cache", run.oracle.to_json());
        }
        println!("{}", v.pretty());
    }
    Ok(())
}

fn cmd_cosim(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let fast = args.has("fast");
    let cs = experiments::casestudy::run_full(&out_dir, fast)?;
    let mut v = Value::obj();
    v.set("baseline", cs.baseline_json).set("carbon_aware", cs.aware_json);
    println!("{}", v.pretty());
    Ok(())
}

fn cmd_autoscale(args: &Args) -> Result<()> {
    if args.has("help") {
        println!(
            "repro autoscale — sweep fleet-scaling policies over a day of grid signals\n\n\
             options:\n  --out <dir>   results directory (default: results)\n  \
             --jobs <n>    sweep worker threads (default: all cores)\n  \
             --shard <k/N> run only policies k, k+N, … of the sweep (merge with `repro merge`)\n  \
             --fast        compressed evening-window scenario"
        );
        return Ok(());
    }
    apply_jobs(args)?;
    apply_shard(args)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let table = experiments::exp_autoscale::run(&out_dir, args.has("fast"))?;
    // The save() call already printed the markdown table; surface the
    // headline comparison on top.
    let by = |policy: &str, col: &str| -> Option<f64> {
        let c = table.col_index(col).ok()?;
        table
            .rows
            .iter()
            .find(|r| r[0] == policy)
            .and_then(|r| r[c].parse().ok())
    };
    if let (Some(sg), Some(cg)) = (
        by("static", "net_footprint_g"),
        by("carbon_aware", "net_footprint_g"),
    ) {
        if sg > 0.0 {
            println!(
                "carbon-aware vs static: {:+.1}% net emissions",
                (cg / sg - 1.0) * 100.0
            );
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!(
            "usage: repro experiment <fig1|exp1..exp5|casestudy|ablation|sched|gpu|autoscale|all> \
             [--out results] [--fast] [--jobs N] [--shard k/N]"
        );
    };
    apply_jobs(args)?;
    apply_shard(args)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    experiments::run_by_id(id, &out_dir, args.has("fast"))
}

/// Recombine sharded sweep outputs (DESIGN.md §9): interleave shard
/// CSV rows back into case order (byte-identical to an unsharded run),
/// merge telemetry sidecars (exact counters summed, latency sketches
/// GK-merged) and `meta.json` sweep stats (sum/max per field).
fn cmd_merge(args: &Args) -> Result<()> {
    if args.has("help") || args.positional.is_empty() {
        println!(
            "repro merge — recombine sharded sweep outputs into one results tree\n\n\
             usage: repro merge <shard-dir>... [--out <dir>]\n\n\
             options:\n  --out <dir>   merged results directory (default: results)\n\n\
             each <shard-dir> is the --out directory of one `repro experiment\n\
             ... --shard k/N` (or `repro autoscale --shard k/N`) run; pass all\n\
             N of them to reassemble the full grid"
        );
        return Ok(());
    }
    let shard_dirs: Vec<PathBuf> = args.positional.iter().map(PathBuf::from).collect();
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let merged = sweep::merge_shard_dirs(&shard_dirs, &out_dir)?;
    for m in &merged {
        println!(
            "merged {:<12} {} shard(s), {} rows{} -> {}",
            m.id,
            m.shards,
            m.rows,
            if m.complete { "" } else { " [INCOMPLETE]" },
            out_dir.join(&m.id).display()
        );
    }
    if merged.iter().any(|m| !m.complete) {
        eprintln!(
            "warning: some experiments are missing shards — \
             re-run `repro merge` with all shard directories"
        );
    }
    Ok(())
}

/// Apply the sweep worker count: `--jobs N` (0 or absent = all cores).
fn apply_jobs(args: &Args) -> Result<()> {
    let jobs = args.u64_or("jobs", 0)? as usize;
    sweep::set_default_jobs(jobs);
    Ok(())
}

/// Apply the cross-machine shard: `--shard k/N` (absent = whole grid).
fn apply_shard(args: &Args) -> Result<()> {
    match args.get("shard") {
        Some(spec) => sweep::set_shard(Some(sweep::ShardSpec::parse(spec)?)),
        None => sweep::set_shard(None),
    }
    Ok(())
}

fn cmd_config() -> Result<()> {
    let mut v = Value::obj();
    v.set("sim (Table 1a)", SimConfig::default().to_json())
        .set("cosim (Table 1b)", CosimConfig::default().to_json());
    println!("{}", v.pretty());
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("out", "results"));
    let md = report::assemble(&dir)?;
    let path = dir.join("REPORT.md");
    std::fs::write(&path, &md)?;
    println!("{md}");
    eprintln!("report -> {path:?}");
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let mut cfg = SimConfig::default();
    apply_sim_overrides(&mut cfg, args).ok(); // cost model irrelevant here
    let mut gen = WorkloadGenerator::from_config(&cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));
    let path = args.str_or("out", "results/trace.csv");
    trace.save(&path)?;
    println!(
        "wrote {} requests spanning {:.1}s ({} tokens) to {path}",
        trace.len(),
        trace.arrival_span_s(),
        trace.total_tokens()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn overrides_applied() {
        let mut cfg = SimConfig::default();
        apply_sim_overrides(
            &mut cfg,
            &args(&[
                "--model", "llama2-7b", "--tp", "2", "--requests", "2^10",
                "--qps", "3.5", "--cost-model", "native",
            ]),
        )
        .unwrap();
        assert_eq!(cfg.model, "llama2-7b");
        assert_eq!(cfg.tp, 2);
        assert_eq!(cfg.num_requests, 1024);
        assert_eq!(cfg.arrival.qps(), 3.5);
        assert_eq!(cfg.cost_model, CostModelKind::Native);
    }

    #[test]
    fn bad_model_rejected() {
        let mut cfg = SimConfig::default();
        assert!(apply_sim_overrides(&mut cfg, &args(&["--model", "gpt9"])).is_err());
    }

    #[test]
    fn unknown_subcommand_fails() {
        let r = run(vec!["repro".into(), "frobnicate".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn help_is_ok() {
        run(vec!["repro".into()]).unwrap();
        run(vec!["repro".into(), "help".into()]).unwrap();
    }

    #[test]
    fn merge_without_dirs_prints_usage() {
        run(vec!["repro".into(), "merge".into()]).unwrap();
    }

    #[test]
    fn merge_of_missing_dir_fails() {
        let r = run(vec![
            "repro".into(),
            "merge".into(),
            "/nonexistent/shard-0".into(),
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_shard_spec_rejected_before_running() {
        let r = run(vec![
            "repro".into(),
            "experiment".into(),
            "exp1".into(),
            "--shard".into(),
            "9/4".into(),
        ]);
        assert!(r.unwrap_err().to_string().contains("shard index"));
    }
}
