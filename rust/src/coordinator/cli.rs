//! Command-line interface of the `repro` binary.
//!
//! Subcommands:
//!   simulate    — one inference-simulation run (prints metrics JSON)
//!   cosim       — full Vidur→Vessim case-study pipeline
//!   autoscale   — sweep fleet-scaling policies over a day of grid signals
//!   experiment  — regenerate a paper table/figure (or `all`)
//!   merge       — recombine sharded sweep outputs (DESIGN.md §9)
//!   watch       — tail/aggregate live sweep snapshots (DESIGN.md §10)
//!   serve       — HTTP/SSE telemetry + control surface (DESIGN.md §11)
//!   fleet       — fault-tolerant multi-host sweep launcher (DESIGN.md §15)
//!   multiregion — carbon-aware global routing sweep over simulated regional fleets
//!   policy      — model-size vs grid-condition policy exploration
//!   config      — show the default (Table 1) configuration
//!   report      — assemble results/ into one markdown report
//!   trace       — generate and save a workload trace CSV
//!
//! The full flag-by-flag reference lives in `docs/CLI.md`.

use crate::config::simconfig::{
    Arrival, CosimConfig, CostModelKind, LengthDist, SimConfig, WorkloadKind,
};
use crate::coordinator::fleet::RoutePolicyKind;
use crate::coordinator::policy;
use crate::energy::EnergyAccountant;
use crate::exec;
use crate::experiments;
use crate::report;
use crate::sim;
use crate::sweep;
use crate::telemetry::StreamingSink;
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::Value;
use crate::workload;
use anyhow::{bail, Result};
use std::path::PathBuf;

const TOP_USAGE: &str = "repro — rust+JAX+Pallas reproduction of 'Quantifying the Energy \
Consumption and Carbon Emissions of LLM Inference via Simulations'

subcommands:
  simulate     run one inference simulation
  cosim        run the Vidur→Vessim integration case study
  autoscale    sweep fleet-scaling policies (static/reactive/carbon/solar) over a day of grid signals
  experiment   regenerate paper tables/figures: fig1 exp1..exp5 casestudy ablation autoscale multiregion scenarios all
               (--jobs N sweeps cases in parallel; --shard k/N splits the grid across machines;
                --watch[=stderr|json:PATH] live dashboard / snapshot log)
  merge        recombine sharded sweep outputs: repro merge <shard-dir>... --out results
  watch        tail/aggregate live sweep snapshots: repro watch <dir-or-jsonl>... [--follow]
  serve        HTTP/SSE telemetry + control surface: repro serve [<dir-or-jsonl>...] [--addr H:P]
  fleet        fan one sweep across many serve hosts, re-shard around dead ones, auto-merge
  multiregion  carbon-aware global routing sweep: route policies x regions x battery sizes
  scenarios    production-shaped workload sweep: scenario (chat/rag/agentic/tenants) x QPS
  policy       model-size policy exploration (small in dirty grid vs large in clean)
  config       print the default Table-1 configuration
  report       assemble results/ into a markdown report
  trace        generate a workload trace CSV

see docs/CLI.md for every flag of every subcommand
";

/// Entry point used by main.rs.
pub fn run(argv: Vec<String>) -> Result<()> {
    let mut it = argv.into_iter();
    let _bin = it.next();
    let Some(cmd) = it.next() else {
        print!("{TOP_USAGE}");
        return Ok(());
    };
    let rest: Vec<String> = it.collect();
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "cosim" => cmd_cosim(&args),
        "autoscale" => cmd_autoscale(&args),
        "experiment" => cmd_experiment(&args),
        "merge" => cmd_merge(&args),
        "watch" => cmd_watch(&args),
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "multiregion" => cmd_multiregion(&args),
        "scenarios" => cmd_scenarios(&args),
        "policy" => policy::cmd(&args),
        "config" => cmd_config(),
        "report" => cmd_report(&args),
        "trace" => cmd_trace(&args),
        "version" | "--version" | "-V" => {
            println!("repro {}", crate::util::version::version_string());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{TOP_USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{TOP_USAGE}"),
    }
}

/// Apply the common simulation overrides shared by several commands.
pub fn apply_sim_overrides(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(g) = args.get("gpu") {
        cfg.gpu = g.to_string();
    }
    cfg.tp = args.u64_or("tp", cfg.tp as u64)? as u32;
    cfg.pp = args.u64_or("pp", cfg.pp as u64)? as u32;
    cfg.replicas = args.u64_or("replicas", cfg.replicas as u64)? as u32;
    cfg.num_requests = args.u64_or("requests", cfg.num_requests)?;
    cfg.batch_cap = args.usize_or("batch-cap", cfg.batch_cap)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    let qps = args.f64_or("qps", cfg.arrival.qps())?;
    cfg.arrival = Arrival::Poisson { qps };
    if let Some(total) = args.get("fixed-len") {
        cfg.lengths = LengthDist::Fixed {
            total: total.parse()?,
        };
    }
    if args.get("pd-ratio").is_some() {
        cfg.prefill_decode_ratio = Some(args.f64_or("pd-ratio", 4.0)?);
    }
    cfg.cost_model = parse_oracle_kind(&args.str_or("cost-model", "hlo"), "--cost-model")?;
    cfg.exec.rf_noise_std = args.f64_or("rf-noise", cfg.exec.rf_noise_std)?;
    if let Some(kind) = parse_workload_flags(args)? {
        cfg.workload = kind;
    }
    cfg.validate()
}

/// Parse `--workload SPEC` plus its trace companions `--trace-scale`
/// / `--trace-repeat` into a [`WorkloadKind`] (DESIGN.md §14). The
/// companions only mean something on a `trace:` workload; anywhere
/// else they are an error, not a silent no-op (the `--watch-cadence`
/// standard). `Ok(None)` = no flag given.
fn parse_workload_flags(args: &Args) -> Result<Option<WorkloadKind>> {
    anyhow::ensure!(
        !args.has("workload"),
        "--workload needs a value (e.g. --workload chat, --workload trace:PATH)"
    );
    let trace_knobs =
        args.get("trace-scale").is_some() || args.get("trace-repeat").is_some();
    anyhow::ensure!(
        !args.has("trace-scale") && !args.has("trace-repeat"),
        "--trace-scale/--trace-repeat need a value"
    );
    let Some(spec) = args.get("workload") else {
        anyhow::ensure!(
            !trace_knobs,
            "--trace-scale/--trace-repeat have no effect without --workload trace:PATH"
        );
        return Ok(None);
    };
    let mut kind = WorkloadKind::parse(spec)?;
    if let WorkloadKind::Trace { time_scale, repeat, .. } = &mut kind {
        *time_scale = args.f64_or("trace-scale", *time_scale)?;
        *repeat = args.u64_or("trace-repeat", *repeat as u64)? as u32;
    } else {
        anyhow::ensure!(
            !trace_knobs,
            "--trace-scale/--trace-repeat only apply to --workload trace:PATH, \
             not --workload {spec}"
        );
    }
    kind.validate()?;
    Ok(Some(kind))
}

/// Apply the process-wide workload override for sweep commands whose
/// per-case configs the per-run `--workload` on `apply_sim_overrides`
/// cannot reach (the `--oracle` pattern). Absent = no override.
fn apply_workload(args: &Args) -> Result<()> {
    workload::set_workload_override(parse_workload_flags(args)?);
    Ok(())
}

fn parse_oracle_kind(s: &str, flag: &str) -> Result<CostModelKind> {
    Ok(match s {
        "native" => CostModelKind::Native,
        "hlo" => CostModelKind::Hlo,
        "surface" => CostModelKind::Surface,
        other => bail!("unknown {flag} '{other}' (native|hlo|surface)"),
    })
}

/// Apply the process-wide stage-oracle override: `--oracle
/// <native|hlo|surface>` wins over every config's `cost_model` —
/// including the per-case configs that experiment grids build
/// internally, which `--cost-model` cannot reach. Absent = no
/// override.
fn apply_oracle(args: &Args) -> Result<()> {
    match args.get("oracle") {
        Some(s) => exec::set_oracle_override(Some(parse_oracle_kind(s, "--oracle")?)),
        None => exec::set_oracle_override(None),
    }
    Ok(())
}

fn sim_opts() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "model", help: "model key (llama3-8b, ...)", default: Some("llama3-8b") },
        OptSpec { name: "gpu", help: "gpu key (a100-80g, h100, a40)", default: Some("a100-80g") },
        OptSpec { name: "tp", help: "tensor parallelism", default: Some("1") },
        OptSpec { name: "pp", help: "pipeline parallelism", default: Some("1") },
        OptSpec { name: "replicas", help: "replica count", default: Some("1") },
        OptSpec { name: "requests", help: "request count (supports 2^16, 400k, 2M)", default: Some("1024") },
        OptSpec { name: "qps", help: "Poisson arrival rate", default: Some("6.45") },
        OptSpec { name: "batch-cap", help: "max batch size", default: Some("128") },
        OptSpec { name: "fixed-len", help: "fixed total tokens per request", default: None },
        OptSpec { name: "pd-ratio", help: "prefill:decode ratio", default: None },
        OptSpec { name: "workload", help: "request source: synthetic|chat|rag|agentic|tenants|trace:PATH|mix:NAME=W,...", default: Some("synthetic") },
        OptSpec { name: "trace-scale", help: "multiply trace arrival times (0.5 = 2x rate; trace: only)", default: Some("1") },
        OptSpec { name: "trace-repeat", help: "loop the trace N times end to end (trace: only)", default: Some("1") },
        OptSpec { name: "cost-model", help: "stage oracle: hlo|native|surface", default: Some("hlo") },
        OptSpec { name: "oracle", help: "process-wide oracle override (native|hlo|surface)", default: None },
        OptSpec { name: "rf-noise", help: "lognormal latency noise sigma", default: Some("0") },
        OptSpec { name: "seed", help: "rng seed", default: None },
        OptSpec { name: "stagelog", help: "write per-stage CSV here (materializes the run)", default: None },
        OptSpec { name: "config", help: "load SimConfig JSON file", default: None },
    ]
}

fn cmd_simulate(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{}", usage("repro simulate", "one inference run", &sim_opts()));
        return Ok(());
    }
    apply_oracle(args)?;
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::load(path)?,
        None => SimConfig::default(),
    };
    apply_sim_overrides(&mut cfg, args)?;
    let mut v = Value::obj();
    v.set("config", cfg.to_json());
    if let Some(path) = args.get("stagelog") {
        // Per-stage CSV export needs every record: materialized run.
        let out = sim::run(&cfg)?;
        let acc = EnergyAccountant::paper_default(&cfg)?;
        let energy = acc.account(&cfg, &out.stagelog, out.metrics.makespan_s);
        v.set("metrics", out.metrics.to_json())
            .set("energy", energy.to_json());
        if out.oracle.calls > 0 {
            v.set("oracle_cache", out.oracle.to_json());
        }
        println!("{}", v.pretty());
        out.stagelog.save_csv(path)?;
        eprintln!("stage log -> {path}");
    } else {
        // Default: fully streaming run — arrivals are generated
        // lazily, requests fold into latency sketches, stages into
        // one-minute bins, so `--requests 2M` holds O(outstanding +
        // bins) state (the CI smoke asserts exactly that from the
        // telemetry object below).
        let acc = EnergyAccountant::paper_default(&cfg)?;
        let mut sink = StreamingSink::with_model(&cfg, 60.0, acc.power_model)?;
        let run = sim::run_streaming(&cfg, &mut sink)?;
        let energy = acc.report(&cfg, sink.aggregates(), run.metrics.makespan_s);
        let mut telemetry = Value::obj();
        telemetry
            .set("submitted", run.request_stats.submitted)
            .set("finished", run.request_stats.finished)
            .set("prefill_tokens_done", run.request_stats.prefill_tokens_done)
            .set("decode_tokens_done", run.request_stats.decode_tokens_done)
            .set("peak_live_requests", run.peak_live_requests as u64)
            .set("peak_resident_bins", sink.peak_resident_bins() as u64);
        v.set("metrics", run.metrics.to_json())
            .set("energy", energy.to_json())
            .set("telemetry", telemetry);
        if run.oracle.calls > 0 {
            v.set("oracle_cache", run.oracle.to_json());
        }
        println!("{}", v.pretty());
    }
    Ok(())
}

fn cmd_cosim(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let fast = args.has("fast");
    let cs = experiments::casestudy::run_full(&out_dir, fast)?;
    let mut v = Value::obj();
    v.set("baseline", cs.baseline_json).set("carbon_aware", cs.aware_json);
    println!("{}", v.pretty());
    Ok(())
}

fn cmd_autoscale(args: &Args) -> Result<()> {
    if args.has("help") {
        println!(
            "repro autoscale — sweep fleet-scaling policies over a day of grid signals\n\n\
             options:\n  --out <dir>   results directory (default: results)\n  \
             --jobs <n>    sweep worker threads (default: all cores)\n  \
             --shard <k/N> run only policies k, k+N, … of the sweep (merge with `repro merge`)\n  \
             --watch[=stderr|json:PATH]  live dashboard / JSONL snapshot log (DESIGN.md §10)\n  \
             --watch-cadence <s>         sim-time seconds between snapshots (default 60)\n  \
             --oracle <native|hlo|surface>  override every case's stage oracle\n  \
             --workload <spec>  replace the diurnal demand curve: trace:PATH (with\n                     \
             --trace-scale/--trace-repeat), chat, rag, agentic, tenants, mix:...\n  \
             --fast        compressed evening-window scenario"
        );
        return Ok(());
    }
    apply_jobs(args)?;
    apply_shard(args)?;
    apply_watch(args)?;
    apply_oracle(args)?;
    apply_workload(args)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let table = experiments::exp_autoscale::run(&out_dir, args.has("fast"))?;
    // The save() call already printed the markdown table; surface the
    // headline comparison on top.
    let by = |policy: &str, col: &str| -> Option<f64> {
        let c = table.col_index(col).ok()?;
        table
            .rows
            .iter()
            .find(|r| r[0] == policy)
            .and_then(|r| r[c].parse().ok())
    };
    if let (Some(sg), Some(cg)) = (
        by("static", "net_footprint_g"),
        by("carbon_aware", "net_footprint_g"),
    ) {
        if sg > 0.0 {
            println!(
                "carbon-aware vs static: {:+.1}% net emissions",
                (cg / sg - 1.0) * 100.0
            );
        }
    }
    Ok(())
}

fn cmd_multiregion(args: &Args) -> Result<()> {
    if args.has("help") {
        println!(
            "repro multiregion — carbon-aware global routing sweep over simulated regional \
             fleets (DESIGN.md §13)\n\n\
             options:\n  --out <dir>            results directory (default: results)\n  \
             --route-policy <list>  comma-separated policies to sweep (default: all four:\n                         \
             static-home,greedy-ci,latency-slo-carbon,battery-soc-aware)\n  \
             --regions <n>          fix the region-count axis to one value (default: 1,3; fast: 3)\n  \
             --rtt-ms <ms>          one-way RTT from the router to every remote region (default: 50)\n  \
             --transfer-overhead <f>  cross-region transfer energy overhead fraction\n                           \
             (default: CosimConfig.transfer_overhead = 0.05)\n  \
             --jobs <n>    sweep worker threads (default: all cores)\n  \
             --shard <k/N> run only cases k, k+N, … of the grid (merge with `repro merge`)\n  \
             --watch[=stderr|json:PATH]  live dashboard / JSONL snapshot log (DESIGN.md §10)\n  \
             --watch-cadence <s>         sim-time seconds between snapshots (default 60)\n  \
             --oracle <native|hlo|surface>  override every case's stage oracle\n  \
             --workload <spec>  request source for every case: trace:PATH, chat, rag,\n                     \
             agentic, tenants, mix:NAME=W,... (default: synthetic)\n  \
             --fast        reduced grid: 3 regions, one battery size, fewer requests"
        );
        return Ok(());
    }
    apply_jobs(args)?;
    apply_shard(args)?;
    apply_watch(args)?;
    apply_oracle(args)?;
    apply_workload(args)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let fast = args.has("fast");
    let mut opts = experiments::exp_multiregion::MultiRegionOpts::defaults(fast);
    if let Some(spec) = args.get("route-policy") {
        let mut policies = Vec::new();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let Some(p) = RoutePolicyKind::parse(part) else {
                bail!(
                    "unknown route policy '{part}' (known: static-home, greedy-ci, \
                     latency-slo-carbon, battery-soc-aware)"
                );
            };
            policies.push(p);
        }
        anyhow::ensure!(!policies.is_empty(), "--route-policy needs at least one policy");
        opts.policies = policies;
    }
    if args.get("regions").is_some() {
        let n = args.u64_or("regions", 3)? as usize;
        anyhow::ensure!(n >= 1, "--regions must be >= 1");
        opts.region_counts = vec![n];
    }
    opts.rtt_s = args.f64_or("rtt-ms", opts.rtt_s * 1_000.0)? / 1_000.0;
    anyhow::ensure!(opts.rtt_s >= 0.0, "--rtt-ms must be >= 0");
    if args.get("transfer-overhead").is_some() {
        let t = args.f64_or("transfer-overhead", 0.0)?;
        anyhow::ensure!(t >= 0.0, "--transfer-overhead must be >= 0");
        opts.transfer_overhead = Some(t);
    }
    let table = experiments::exp_multiregion::run_with(&out_dir, fast, &opts)?;
    // The save() call already printed the markdown table; surface the
    // headline comparison (first row of each policy) on top.
    let by = |policy: &str, col: &str| -> Option<f64> {
        let c = table.col_index(col).ok()?;
        table
            .rows
            .iter()
            .find(|r| r[0] == policy)
            .and_then(|r| r[c].parse().ok())
    };
    if let (Some(sg), Some(gg)) = (
        by("static-home", "net_footprint_g"),
        by("greedy-ci", "net_footprint_g"),
    ) {
        if sg > 0.0 {
            println!(
                "greedy-ci vs static-home: {:+.1}% net emissions",
                (gg / sg - 1.0) * 100.0
            );
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!(
            "usage: repro experiment <fig1|exp1..exp5|casestudy|ablation|sched|gpu|autoscale|multiregion|scenarios|all> \
             [--out results] [--fast] [--jobs N] [--shard k/N] \
             [--watch[=stderr|json:PATH]] [--watch-cadence s] [--oracle native|hlo|surface] \
             [--workload spec]"
        );
    };
    apply_jobs(args)?;
    apply_shard(args)?;
    apply_watch(args)?;
    apply_oracle(args)?;
    apply_workload(args)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    experiments::run_by_id(id, &out_dir, args.has("fast"))
}

/// The production-shaped workload sweep (DESIGN.md §14): scenario ×
/// QPS grid through the standard sweep machinery.
fn cmd_scenarios(args: &Args) -> Result<()> {
    if args.has("help") {
        println!(
            "repro scenarios — production-shaped workload sweep: scenario \
             (chat/rag/agentic/tenants) x QPS (DESIGN.md §14)\n\n\
             options:\n  --out <dir>   results directory (default: results)\n  \
             --jobs <n>    sweep worker threads (default: all cores)\n  \
             --shard <k/N> run only cases k, k+N, … of the grid (merge with `repro merge`)\n  \
             --watch[=stderr|json:PATH]  live dashboard / JSONL snapshot log (DESIGN.md §10)\n  \
             --watch-cadence <s>         sim-time seconds between snapshots (default 60)\n  \
             --oracle <native|hlo|surface>  override every case's stage oracle\n  \
             --fast        reduced grid (fewer QPS points and requests)\n\n\
             the scenario axis IS the grid, so this command takes no --workload; use\n\
             `repro simulate --workload ...` for a single scenario run"
        );
        return Ok(());
    }
    // The grid sweeps the workload axis itself; a process-wide
    // override would collapse every case onto one scenario.
    anyhow::ensure!(
        args.get("workload").is_none() && !args.has("workload"),
        "--workload would collapse the scenario axis of this sweep; \
         use `repro simulate --workload ...` for one scenario"
    );
    apply_jobs(args)?;
    apply_shard(args)?;
    apply_watch(args)?;
    apply_oracle(args)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    experiments::exp_scenarios::run(&out_dir, args.has("fast"))?;
    Ok(())
}

/// Recombine sharded sweep outputs (DESIGN.md §9): interleave shard
/// CSV rows back into case order (byte-identical to an unsharded run),
/// merge telemetry sidecars (exact counters summed, latency sketches
/// GK-merged) and `meta.json` sweep stats (sum/max per field).
fn cmd_merge(args: &Args) -> Result<()> {
    if args.has("help") || args.positional.is_empty() {
        println!(
            "repro merge — recombine sharded sweep outputs into one results tree\n\n\
             usage: repro merge <shard-dir>... [--out <dir>]\n\n\
             options:\n  --out <dir>   merged results directory (default: results)\n\n\
             each <shard-dir> is the --out directory of one `repro experiment\n\
             ... --shard k/N` (or `repro autoscale --shard k/N`) run; pass all\n\
             N of them to reassemble the full grid"
        );
        return Ok(());
    }
    let shard_dirs: Vec<PathBuf> = args.positional.iter().map(PathBuf::from).collect();
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let merged = sweep::merge_shard_dirs(&shard_dirs, &out_dir)?;
    for m in &merged {
        println!(
            "merged {:<12} {} shard(s), {} rows{} -> {}",
            m.id,
            m.shards,
            m.rows,
            if m.complete { "" } else { " [INCOMPLETE]" },
            out_dir.join(&m.id).display()
        );
    }
    if merged.iter().any(|m| !m.complete) {
        eprintln!(
            "warning: some experiments are missing shards — \
             re-run `repro merge` with all shard directories"
        );
    }
    Ok(())
}

/// Apply the sweep worker count: `--jobs N` (0 or absent = all cores).
fn apply_jobs(args: &Args) -> Result<()> {
    let jobs = args.u64_or("jobs", 0)? as usize;
    sweep::set_default_jobs(jobs);
    Ok(())
}

/// Apply the cross-machine shard: `--shard k/N` (absent = whole grid).
fn apply_shard(args: &Args) -> Result<()> {
    match args.get("shard") {
        Some(spec) => sweep::set_shard(Some(sweep::ShardSpec::parse(spec)?)),
        None => sweep::set_shard(None),
    }
    Ok(())
}

/// Apply the live-watch configuration (DESIGN.md §10): bare `--watch`
/// = in-place stderr dashboard, `--watch=json:PATH` = JSONL snapshot
/// log for `repro watch`; `--watch-cadence <s>` sets the sim-time
/// snapshot period. Absent = watching off (the zero-overhead default).
fn apply_watch(args: &Args) -> Result<()> {
    let mut cfg = if let Some(spec) = args.get("watch") {
        Some(report::live::WatchConfig::parse(spec)?)
    } else if args.has("watch") {
        Some(report::live::WatchConfig::stderr())
    } else {
        None
    };
    anyhow::ensure!(
        !args.has("watch-cadence"),
        "--watch-cadence needs a value (e.g. --watch-cadence 30)"
    );
    anyhow::ensure!(
        cfg.is_some() || args.get("watch-cadence").is_none(),
        "--watch-cadence has no effect without --watch"
    );
    if let Some(c) = cfg.as_mut() {
        c.cadence_s = args.f64_or("watch-cadence", c.cadence_s)?;
        anyhow::ensure!(c.cadence_s > 0.0, "--watch-cadence must be positive");
        c.window_s = c.window_s.max(c.cadence_s);
    }
    report::live::set_watch(cfg);
    Ok(())
}

/// Tail/aggregate live sweep snapshots (DESIGN.md §10): read the
/// `watch.jsonl` files under the given directories (one per shard of a
/// cross-machine sweep, or the file paths directly) and render one
/// aggregate dashboard; `--follow` re-reads on a wall-clock interval.
fn cmd_watch(args: &Args) -> Result<()> {
    if args.has("help") || args.positional.is_empty() {
        println!(
            "repro watch — tail/aggregate live sweep snapshots\n\n\
             usage: repro watch <dir-or-jsonl>... [--follow] [--interval <s>]\n\n\
             each path is a watch.jsonl written by `repro experiment/autoscale\n\
             --watch=json:PATH`, or a directory searched for watch.jsonl (itself\n\
             and one level of subdirectories — the shape of sharded --out trees)\n\n\
             options:\n  --follow        keep re-reading and re-rendering\n  \
             --interval <s>  wall-clock refresh period with --follow (default 5)"
        );
        return Ok(());
    }
    let paths: Vec<PathBuf> = args.positional.iter().map(PathBuf::from).collect();
    // `--follow results/` would bind the path as the switch's *value*
    // under the tiny parser's rules, silently un-following and
    // dropping the path — reject it loudly instead.
    anyhow::ensure!(
        args.get("follow").is_none(),
        "--follow takes no value; put it after the paths \
         (repro watch <dir-or-jsonl>... --follow)"
    );
    let follow = args.has("follow");
    // The same loud-validation standard as --watch-cadence: a flag
    // that would silently do nothing (or something else) is an error.
    anyhow::ensure!(
        !args.has("interval"),
        "--interval needs a value (e.g. --interval 10)"
    );
    anyhow::ensure!(
        follow || args.get("interval").is_none(),
        "--interval has no effect without --follow"
    );
    let interval = args.f64_or("interval", 5.0)?;
    anyhow::ensure!(
        interval >= 0.5,
        "--interval must be at least 0.5 seconds, got {interval}"
    );
    let mut first = true;
    // Per-file incremental tail state for --follow: logs are
    // append-only, so each tick parses only the appended suffix —
    // O(new bytes), never a full re-read of a day-long log.
    let mut cache: std::collections::BTreeMap<PathBuf, report::live::TailState> =
        std::collections::BTreeMap::new();
    loop {
        // In follow mode a path may simply not exist *yet* (a shard
        // host that hasn't created its --out tree) and a file may be
        // caught mid-rewrite: wait for the stragglers, per path, while
        // the shards that are already streaming keep rendering.
        // Single-shot keeps the loud errors.
        let files = if follow {
            let mut files = Vec::new();
            for p in &paths {
                match report::live::discover_watch_files(std::slice::from_ref(p)) {
                    Ok(mut f) => files.append(&mut f),
                    Err(e) => eprintln!("waiting: {e:#}"),
                }
            }
            files.sort();
            files.dedup();
            files
        } else {
            report::live::discover_watch_files(&paths)?
        };
        let mut changed = first;
        // A log that vanished from discovery (deleted/renamed shard
        // dir) must stop contributing to the aggregate.
        let before = cache.len();
        cache.retain(|k, _| files.contains(k));
        changed |= cache.len() != before;
        for f in &files {
            let state = cache.entry(f.clone()).or_default();
            match report::live::tail_snapshots(f, state) {
                Ok(grew) => {
                    changed |= grew;
                    // A follower picks the torn tail up next tick; a
                    // single shot won't, so it says so.
                    if !follow {
                        report::live::warn_if_torn_tail(f, state);
                    }
                }
                Err(e) if follow => {
                    // Parse errors already self-reset; reset here too
                    // for I/O errors (an NFS flap), so an unreadable
                    // shard's stale snapshots — including live
                    // qps/watts — drop out of the render until the
                    // file is readable again and reparses in full.
                    *state = report::live::TailState::default();
                    changed = true;
                    eprintln!("waiting: {e:#}");
                }
                Err(e) => return Err(e),
            }
        }
        let total: usize = cache.values().map(|s| s.snapshots.len()).sum();
        if total == 0 {
            if !follow {
                bail!(
                    "no watch snapshots found under {paths:?} — pass the \
                     watch.jsonl files (or their directories) of a \
                     `--watch=json:` run"
                );
            }
            eprintln!("no snapshots yet under {paths:?} — waiting…");
        } else if changed {
            // Only changed ticks pay for aggregation (over borrows —
            // nothing is cloned); quiet ticks keep the last render.
            let aggs = report::live::aggregate(
                cache.values().flat_map(|s| s.snapshots.iter()),
            );
            if follow && !first {
                // Redraw in place.
                print!("\x1b[2J\x1b[H");
            }
            println!("{}", report::live::render_watch(&aggs, files.len()));
        }
        if !follow {
            return Ok(());
        }
        first = false;
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
}

/// Serve the live telemetry plane over HTTP/SSE (DESIGN.md §11):
/// follow watch JSONL files/directories like `repro watch` and expose
/// them as `/v1/fleet` + `/v1/snapshots`, plus host sweeps submitted
/// to `POST /v1/sweeps` (their snapshots broadcast in process, their
/// artifacts land under `--out`, byte-identical to an unserved run).
fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("help") {
        println!(
            "repro serve — zero-dep HTTP/SSE telemetry and control surface\n\n\
             usage: repro serve [<dir-or-jsonl>...] [--addr HOST:PORT] [--out <dir>]\n\n\
             each positional path is followed like `repro watch --follow` (a\n\
             watch.jsonl, or a directory searched for them); hosted sweeps are\n\
             submitted over HTTP and need no paths at all\n\n\
             options:\n  --addr <host:port>  bind address (default 127.0.0.1:7878; :0 picks a port)\n  \
             --out <dir>         hosted sweep outputs root (default serve-results)\n  \
             --interval <s>      follower poll period (default 0.25)\n\n\
             endpoints (format {}):\n  \
             GET  /healthz        build identity + liveness\n  \
             GET  /v1/fleet       aggregated fleet state as JSON\n  \
             GET  /v1/snapshots   SSE snapshot stream (Last-Event-ID resume)\n  \
             POST /v1/sweeps      submit {{\"experiment\": ..., \"jobs\": N, \"shard\": \"k/N\", \"fast\": bool}}\n  \
             GET  /v1/sweeps[/id] submitted sweep status",
            crate::serve::state::SERVE_FORMAT
        );
        return Ok(());
    }
    // The loud-validation standard of cmd_watch: a switch the parser
    // would silently misread is an error, not a surprise.
    anyhow::ensure!(
        !args.has("addr"),
        "--addr needs a value (e.g. --addr 0.0.0.0:7878)"
    );
    anyhow::ensure!(!args.has("out"), "--out needs a value (e.g. --out serve-results)");
    anyhow::ensure!(
        !args.has("interval"),
        "--interval needs a value (e.g. --interval 1)"
    );
    let interval = args.f64_or("interval", 0.25)?;
    anyhow::ensure!(
        interval >= 0.05,
        "--interval must be at least 0.05 seconds, got {interval}"
    );
    let mut cfg = crate::serve::ServeConfig::new(&args.str_or("addr", "127.0.0.1:7878"));
    cfg.follow = args.positional.iter().map(PathBuf::from).collect();
    cfg.out = PathBuf::from(args.str_or("out", "serve-results"));
    cfg.poll_interval = std::time::Duration::from_secs_f64(interval);
    let server = crate::serve::Server::start(cfg)?;
    eprintln!(
        "repro serve {} listening on http://{}",
        crate::util::version::version_string(),
        server.addr()
    );
    server.run();
    Ok(())
}

/// Fan one sweep across a fleet of `repro serve` hosts (DESIGN.md
/// §15): one shard per healthy host, re-shard around deaths,
/// auto-merge into a tree byte-identical to an unsharded run.
fn cmd_fleet(args: &Args) -> Result<()> {
    if args.has("help") || (args.positional.is_empty() && args.options.is_empty()) {
        println!(
            "repro fleet — fault-tolerant multi-host sweep launcher\n\n\
             usage: repro fleet <experiment> (--hosts <file> | --host <e>[,<e>...] | --local <n>)\n\n\
             splits the sweep one shard per healthy host; a host that dies\n\
             mid-sweep has its unfinished shards re-partitioned across the\n\
             survivors, and the completed shard outputs are auto-merged into\n\
             a tree byte-identical to an unsharded run\n\n\
             options:\n  \
             --hosts <file>       host manifest: one host:port or local:N per line, # comments\n  \
             --host <e>[,<e>...]  inline manifest entries (host:port or local:N)\n  \
             --local <n>          shorthand for --host local:N (spawn n serve children)\n  \
             --out <dir>          fleet scratch root: agent trees + logs (default fleet-results)\n  \
             --merged-out <dir>   auto-merged results tree (default <out>/merged)\n  \
             --jobs <n>           per-host sweep worker count (default: each host's cores)\n  \
             --fast               forwarded: reduced request counts for smoke runs\n  \
             --watch              merged live dashboard (every host's SSE stream) on stderr\n  \
             --retries <n>        attempts before a host is declared dead (default 5)\n  \
             --timeout <s>        per-request HTTP deadline, seconds (default 10)\n  \
             --poll <s>           job-status poll period, seconds (default 0.2)"
        );
        return Ok(());
    }
    anyhow::ensure!(
        args.positional.len() == 1,
        "repro fleet expects exactly one experiment id, got {:?} (try `repro fleet --help`)",
        args.positional
    );
    let experiment = args.positional[0].clone();
    // The loud-validation standard: a flag the parser would silently
    // misread is an error, not a surprise.
    for (flag, hint) in [
        ("hosts", "--hosts fleet-hosts.txt"),
        ("host", "--host 10.0.0.7:7878,local:2"),
        ("local", "--local 2"),
        ("out", "--out fleet-results"),
        ("merged-out", "--merged-out results"),
        ("jobs", "--jobs 4"),
        ("retries", "--retries 5"),
        ("timeout", "--timeout 10"),
        ("poll", "--poll 0.2"),
    ] {
        anyhow::ensure!(!args.has(flag), "--{flag} needs a value (e.g. {hint})");
    }
    for switch in ["fast", "watch"] {
        anyhow::ensure!(
            args.get(switch).is_none(),
            "--{switch} takes no value (put it after the experiment id)"
        );
    }

    let mut manifest = match args.get("hosts") {
        Some(path) => crate::fleet::Manifest::load(&PathBuf::from(path))?,
        None => crate::fleet::Manifest::default(),
    };
    if let Some(entries) = args.get("host") {
        let parts: Vec<String> = entries.split(',').map(|s| s.trim().to_string()).collect();
        let inline = crate::fleet::Manifest::from_entries(&parts)?;
        manifest.endpoints.extend(inline.endpoints);
        manifest.local += inline.local;
    }
    manifest.local += args.usize_or("local", 0)?;
    anyhow::ensure!(
        manifest.host_count() > 0,
        "no fleet hosts named — pass --hosts <file>, --host <host:port|local:N>, or --local <n>"
    );

    let out = PathBuf::from(args.str_or("out", "fleet-results"));
    let mut cfg = crate::fleet::FleetConfig::new(&experiment, manifest, &out);
    cfg.fast = args.has("fast");
    cfg.dashboard = args.has("watch");
    if let Some(dir) = args.get("merged-out") {
        cfg.merged_out = PathBuf::from(dir);
    }
    if args.get("jobs").is_some() {
        let jobs = args.u64_or("jobs", 0)?;
        anyhow::ensure!(jobs >= 1, "--jobs must be at least 1, got {jobs}");
        cfg.jobs = Some(jobs);
    }
    let retries = args.u64_or("retries", cfg.max_attempts as u64)?;
    anyhow::ensure!(retries >= 1, "--retries must be at least 1, got {retries}");
    cfg.max_attempts = retries as u32;
    let timeout = args.f64_or("timeout", cfg.http_timeout.as_secs_f64())?;
    anyhow::ensure!(timeout > 0.0, "--timeout must be positive, got {timeout}");
    cfg.http_timeout = std::time::Duration::from_secs_f64(timeout);
    let poll = args.f64_or("poll", cfg.poll.as_secs_f64())?;
    anyhow::ensure!(poll >= 0.05, "--poll must be at least 0.05 seconds, got {poll}");
    cfg.poll = std::time::Duration::from_secs_f64(poll);

    let report = crate::fleet::run_fleet(&cfg)?;
    for m in &report.merged {
        println!(
            "merged {:<12} {} shard(s), {} rows{} -> {}",
            m.id,
            m.shards,
            m.rows,
            if m.complete { "" } else { " [INCOMPLETE]" },
            cfg.merged_out.join(&m.id).display()
        );
    }
    println!(
        "fleet: {} host(s), {} dispatch(es), {} re-shard(s), {} dead",
        report.hosts,
        report.dispatched,
        report.resharded,
        report.dead.len()
    );
    if !report.dead.is_empty() {
        eprintln!("fleet: dead host(s): {}", report.dead.join(", "));
    }
    anyhow::ensure!(
        report.merged.iter().all(|m| m.complete),
        "fleet merge is missing shards — completed outputs did not cover the grid"
    );
    Ok(())
}

fn cmd_config() -> Result<()> {
    let mut v = Value::obj();
    v.set("sim (Table 1a)", SimConfig::default().to_json())
        .set("cosim (Table 1b)", CosimConfig::default().to_json());
    println!("{}", v.pretty());
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("out", "results"));
    let md = report::assemble(&dir)?;
    let path = dir.join("REPORT.md");
    std::fs::write(&path, &md)?;
    println!("{md}");
    eprintln!("report -> {path:?}");
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let mut cfg = SimConfig::default();
    apply_sim_overrides(&mut cfg, args).ok(); // cost model irrelevant here
    // Workload flags must not fail silently under the `.ok()` above:
    // a scenario trace export is exactly this command's job.
    if let Some(kind) = parse_workload_flags(args)? {
        cfg.workload = kind;
    }
    let trace = workload::trace_from_config(&cfg)?;
    let path = args.str_or("out", "results/trace.csv");
    trace.save(&path)?;
    println!(
        "wrote {} requests spanning {:.1}s ({} tokens) to {path}",
        trace.len(),
        trace.arrival_span_s(),
        trace.total_tokens()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn overrides_applied() {
        let mut cfg = SimConfig::default();
        apply_sim_overrides(
            &mut cfg,
            &args(&[
                "--model", "llama2-7b", "--tp", "2", "--requests", "2^10",
                "--qps", "3.5", "--cost-model", "native",
            ]),
        )
        .unwrap();
        assert_eq!(cfg.model, "llama2-7b");
        assert_eq!(cfg.tp, 2);
        assert_eq!(cfg.num_requests, 1024);
        assert_eq!(cfg.arrival.qps(), 3.5);
        assert_eq!(cfg.cost_model, CostModelKind::Native);
    }

    #[test]
    fn bad_model_rejected() {
        let mut cfg = SimConfig::default();
        assert!(apply_sim_overrides(&mut cfg, &args(&["--model", "gpt9"])).is_err());
    }

    /// `--cost-model surface` parses; `--oracle` values parse or fail
    /// loudly. The override global itself stays None here — setting it
    /// would race with concurrently running engine tests that build
    /// cost models.
    #[test]
    fn oracle_flags_parse() {
        let mut cfg = SimConfig::default();
        apply_sim_overrides(&mut cfg, &args(&["--cost-model", "surface"])).unwrap();
        assert_eq!(cfg.cost_model, CostModelKind::Surface);
        assert!(apply_sim_overrides(&mut cfg, &args(&["--cost-model", "rf"])).is_err());

        assert_eq!(
            parse_oracle_kind("native", "--oracle").unwrap(),
            CostModelKind::Native
        );
        assert_eq!(
            parse_oracle_kind("surface", "--oracle").unwrap(),
            CostModelKind::Surface
        );
        assert!(parse_oracle_kind("rf", "--oracle").is_err());
        // A bad --oracle value fails before touching the global.
        assert!(apply_oracle(&args(&["--oracle", "rf"])).is_err());
        // Absent flag clears the override (the default state).
        apply_oracle(&args(&[])).unwrap();
        assert_eq!(exec::oracle_override(), None);
    }

    /// `--workload` forms parse into the right [`WorkloadKind`]; the
    /// process-global override stays None here (setting it would race
    /// with concurrently running engine tests — the oracle-test rule).
    #[test]
    fn workload_flags_parse() {
        assert_eq!(parse_workload_flags(&args(&[])).unwrap(), None);
        assert_eq!(
            parse_workload_flags(&args(&["--workload", "chat"])).unwrap(),
            Some(WorkloadKind::Chat)
        );
        assert_eq!(
            parse_workload_flags(&args(&[
                "--workload", "trace:t.csv", "--trace-scale", "0.5", "--trace-repeat", "4",
            ]))
            .unwrap(),
            Some(WorkloadKind::Trace {
                path: "t.csv".into(),
                time_scale: 0.5,
                repeat: 4,
            })
        );
        assert_eq!(
            parse_workload_flags(&args(&["--workload", "mix:chat=2,rag=1"])).unwrap(),
            Some(WorkloadKind::Mix(vec![("chat".into(), 2.0), ("rag".into(), 1.0)]))
        );
        // Loud failures: bad spec, bare flag, trace knobs off a trace.
        assert!(parse_workload_flags(&args(&["--workload", "bogus"])).is_err());
        assert!(parse_workload_flags(&args(&["--workload"])).is_err());
        assert!(parse_workload_flags(&args(&["--trace-scale", "2"])).is_err());
        assert!(
            parse_workload_flags(&args(&["--workload", "chat", "--trace-repeat", "2"])).is_err()
        );
        assert!(parse_workload_flags(&args(&[
            "--workload", "trace:t.csv", "--trace-scale", "0",
        ]))
        .is_err());

        // The per-config path lands on cfg.workload.
        let mut cfg = SimConfig::default();
        apply_sim_overrides(&mut cfg, &args(&["--workload", "rag", "--cost-model", "native"]))
            .unwrap();
        assert_eq!(cfg.workload, WorkloadKind::Rag);
        // Absent flag clears the process override (the default state).
        apply_workload(&args(&[])).unwrap();
        assert_eq!(workload::workload_override(), None);
    }

    #[test]
    fn scenarios_rejects_workload_override() {
        let r = run(vec![
            "repro".into(),
            "scenarios".into(),
            "--workload".into(),
            "chat".into(),
        ]);
        assert!(r.unwrap_err().to_string().contains("scenario axis"));
        run(vec!["repro".into(), "scenarios".into(), "--help".into()]).unwrap();
    }

    #[test]
    fn unknown_subcommand_fails() {
        let r = run(vec!["repro".into(), "frobnicate".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn help_is_ok() {
        run(vec!["repro".into()]).unwrap();
        run(vec!["repro".into(), "help".into()]).unwrap();
    }

    #[test]
    fn merge_without_dirs_prints_usage() {
        run(vec!["repro".into(), "merge".into()]).unwrap();
    }

    #[test]
    fn merge_of_missing_dir_fails() {
        let r = run(vec![
            "repro".into(),
            "merge".into(),
            "/nonexistent/shard-0".into(),
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn watch_without_paths_prints_usage() {
        run(vec!["repro".into(), "watch".into()]).unwrap();
    }

    #[test]
    fn watch_of_missing_path_fails() {
        let r = run(vec![
            "repro".into(),
            "watch".into(),
            "/nonexistent/watch.jsonl".into(),
        ]);
        assert!(r.is_err());
    }

    /// `--watch` forms parse into the right process-global config (and
    /// a bad spec is rejected before any sweep starts).
    #[test]
    fn apply_watch_sets_and_clears_the_global() {
        use crate::report::live::{self, WatchTarget};
        let _guard = live::WATCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        apply_watch(&args(&["--watch=json:w.jsonl", "--watch-cadence", "30"])).unwrap();
        let cfg = live::active_watch().unwrap();
        assert_eq!(cfg.target, WatchTarget::Json("w.jsonl".into()));
        assert_eq!(cfg.cadence_s, 30.0);
        // Bare switch = stderr dashboard.
        apply_watch(&args(&["--watch"])).unwrap();
        assert_eq!(live::active_watch().unwrap().target, WatchTarget::Stderr);
        // Absent = off.
        apply_watch(&args(&[])).unwrap();
        assert_eq!(live::active_watch(), None);
        assert!(apply_watch(&args(&["--watch=tcp:99"])).is_err());
        assert!(apply_watch(&args(&["--watch", "--watch-cadence", "0"])).is_err());
        // A cadence without --watch is a mistake, not a silent no-op.
        assert!(apply_watch(&args(&["--watch-cadence", "9"])).is_err());
        live::set_watch(None);
    }

    #[test]
    fn version_and_serve_help_are_ok() {
        run(vec!["repro".into(), "--version".into()]).unwrap();
        run(vec!["repro".into(), "version".into()]).unwrap();
        run(vec!["repro".into(), "serve".into(), "--help".into()]).unwrap();
    }

    #[test]
    fn serve_flag_mistakes_are_loud() {
        // --addr swallowing the next flag / given bare.
        let r = run(vec!["repro".into(), "serve".into(), "--addr".into()]);
        assert!(r.unwrap_err().to_string().contains("--addr needs a value"));
        let r = run(vec![
            "repro".into(),
            "serve".into(),
            "--interval".into(),
            "0.001".into(),
        ]);
        assert!(r.unwrap_err().to_string().contains("--interval"));
    }

    #[test]
    fn bad_shard_spec_rejected_before_running() {
        let r = run(vec![
            "repro".into(),
            "experiment".into(),
            "exp1".into(),
            "--shard".into(),
            "9/4".into(),
        ]);
        assert!(r.unwrap_err().to_string().contains("shard index"));
    }
}
