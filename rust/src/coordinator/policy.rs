//! Model-size policy exploration (§5: "deploying smaller models in
//! high-CI regions versus larger ones during renewable peaks").
//!
//! Compares three serving policies over a day of grid conditions:
//! * `large-always` — serve every request with the large model;
//! * `small-always` — always the small model;
//! * `ci-adaptive`  — large model when CI < low threshold (clean),
//!   small model when CI > high threshold, large otherwise.
//!
//! Reports energy, emissions, and a quality proxy (fraction of tokens
//! served by the large model).

use crate::config::simconfig::{CosimConfig, SimConfig};
use crate::experiments::common::run_case;
use crate::grid::CarbonIntensityTrace;
use crate::util::cli::Args;
use crate::util::csv::Table;
use anyhow::Result;

pub struct PolicyCase {
    pub name: String,
    pub energy_kwh: f64,
    pub emissions_g: f64,
    pub large_frac: f64,
}

/// Evaluate the three policies for a given per-request energy cost of
/// the small and large models (measured by two short sims), a CI
/// trace, and a uniform request stream.
pub fn evaluate(
    e_small_wh: f64,
    e_large_wh: f64,
    ci: &[f64],
    ci_low: f64,
    ci_high: f64,
    requests_per_step: f64,
) -> Vec<PolicyCase> {
    let mut out = Vec::new();
    for name in ["large-always", "small-always", "ci-adaptive"] {
        let mut energy_wh = 0.0;
        let mut emissions = 0.0;
        let mut large_steps = 0usize;
        for &c in ci {
            let use_large = match name {
                "large-always" => true,
                "small-always" => false,
                _ => c <= ci_high, // adaptive: downshift only in dirty hours
            };
            let e = requests_per_step * if use_large { e_large_wh } else { e_small_wh };
            energy_wh += e;
            emissions += e / 1000.0 * c;
            if use_large {
                large_steps += 1;
            }
            let _ = ci_low;
        }
        out.push(PolicyCase {
            name: name.into(),
            energy_kwh: energy_wh / 1000.0,
            emissions_g: emissions,
            large_frac: large_steps as f64 / ci.len().max(1) as f64,
        });
    }
    out
}

/// Measure per-request energy of a model via a short calibration sim.
pub fn per_request_energy_wh(model: &str, args: &Args, fast: bool) -> Result<f64> {
    let mut cfg = SimConfig::default();
    super::cli::apply_sim_overrides(&mut cfg, args)?;
    cfg.model = model.to_string();
    cfg.num_requests = if fast { 128 } else { 512 };
    let r = run_case(&cfg)?;
    Ok(r.energy_kwh() * 1000.0 / cfg.num_requests as f64)
}

/// `repro policy` command.
pub fn cmd(args: &Args) -> Result<()> {
    let fast = args.has("fast");
    let small = args.str_or("small-model", "llama2-7b");
    let large = args.str_or("large-model", "codellama-34b");
    let e_small = per_request_energy_wh(&small, args, fast)?;
    let e_large = per_request_energy_wh(&large, args, fast)?;
    let cosim = CosimConfig::default();
    let trace = CarbonIntensityTrace::default();
    let ci: Vec<f64> = (0..2880).map(|k| trace.base_at(k as f64 * 60.0)).collect();
    let cases = evaluate(e_small, e_large, &ci, cosim.ci_low, cosim.ci_high, 1.0);

    let mut t = Table::new(&["policy", "energy_kwh", "emissions_g", "large_model_frac"]);
    for c in &cases {
        t.push_row(vec![
            c.name.clone(),
            format!("{:.3}", c.energy_kwh),
            format!("{:.0}", c.emissions_g),
            format!("{:.2}", c.large_frac),
        ]);
    }
    println!(
        "per-request energy: {small} {e_small:.3} Wh, {large} {e_large:.3} Wh\n\n{}",
        t.to_markdown()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_sits_between_extremes() {
        let ci: Vec<f64> = (0..1440)
            .map(|k| if k % 480 < 240 { 450.0 } else { 90.0 })
            .collect();
        let cases = evaluate(1.0, 4.0, &ci, 100.0, 200.0, 1.0);
        let by = |n: &str| cases.iter().find(|c| c.name == n).unwrap();
        let large = by("large-always");
        let small = by("small-always");
        let adaptive = by("ci-adaptive");
        assert!(adaptive.emissions_g < large.emissions_g);
        assert!(adaptive.emissions_g > small.emissions_g);
        assert!(adaptive.large_frac > 0.3 && adaptive.large_frac < 0.9);
    }

    #[test]
    fn adaptive_serves_large_in_clean_hours() {
        let ci = vec![50.0; 100]; // always clean
        let cases = evaluate(1.0, 4.0, &ci, 100.0, 200.0, 1.0);
        let adaptive = cases.iter().find(|c| c.name == "ci-adaptive").unwrap();
        assert_eq!(adaptive.large_frac, 1.0);
    }
}
