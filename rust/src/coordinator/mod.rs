//! The L3 coordinator binary's guts: CLI dispatch plus the
//! carbon-aware extensions (§5 "future directions", implemented):
//! multi-region routing and the model-size policy explorer.

pub mod cli;
pub mod multiregion;
pub mod policy;
