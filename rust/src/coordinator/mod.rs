//! The L3 coordinator binary's guts: CLI dispatch plus the
//! carbon-aware extensions (§5 "future directions", implemented):
//! multi-region routing — closed-form ([`multiregion`]) and
//! request-granularity ([`fleet`]) — and the model-size policy
//! explorer.

pub mod cli;
pub mod fleet;
pub mod multiregion;
pub mod policy;
