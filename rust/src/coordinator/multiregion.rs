//! Multi-region carbon-aware routing (§5 "our framework also extends
//! naturally to multi-region routing") — implemented.
//!
//! A fleet of regions, each with its own CI trace phase (time-zone
//! offset) and optional solar array, serves a shared inference load
//! profile. Policies:
//! * `static` — all load stays in the home region;
//! * `greedy-ci` — each step routes to the currently cleanest region,
//!   paying a transfer-energy penalty per shifted watt (modeled
//!   interconnect cost).
//!
//! Reports per-region energy and total emissions for both policies.

use crate::config::simconfig::{CosimConfig, SimConfig};
use crate::grid::{CarbonIntensityTrace, SolarModel};
use crate::pipeline::LoadProfile;
use crate::sim;
use crate::telemetry::StreamingSink;
use crate::util::cli::Args;
use crate::util::csv::Table;
use anyhow::Result;

/// One region's environment.
#[derive(Debug, Clone)]
pub struct Region {
    pub name: String,
    /// Mean grid CI, g/kWh.
    pub ci_mean: f64,
    /// Diurnal phase offset, hours (time zone).
    pub tz_offset_h: f64,
    /// Installed solar, W.
    pub solar_w: f64,
}

/// Default three-region fleet: CAISO-North (home), a dirty region, a
/// clean region — phases 0 / +3 / +9 hours.
pub fn default_regions() -> Vec<Region> {
    vec![
        Region { name: "caiso-north".into(), ci_mean: 418.2, tz_offset_h: 0.0, solar_w: 600.0 },
        Region { name: "midwest-coal".into(), ci_mean: 650.0, tz_offset_h: 3.0, solar_w: 0.0 },
        Region { name: "hydro-north".into(), ci_mean: 120.0, tz_offset_h: 9.0, solar_w: 0.0 },
    ]
}

pub struct MultiRegionResult {
    pub table: Table,
    pub static_g: f64,
    pub greedy_g: f64,
}

/// Per-watt-hour transfer overhead for moving load across regions
/// (network + marshalling), as a fraction of the moved energy.
const TRANSFER_OVERHEAD: f64 = 0.05;

pub fn simulate(
    load: &LoadProfile,
    regions: &[Region],
    interval_s: f64,
    seed: u64,
) -> Result<MultiRegionResult> {
    let n = load.len();
    // Per-region CI series (phase-shifted) and solar.
    let ci: Vec<Vec<f64>> = regions
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let t = CarbonIntensityTrace {
                mean: r.ci_mean,
                seed: seed ^ (i as u64),
                ..CarbonIntensityTrace::default()
            };
            (0..n)
                .map(|k| t.base_at(k as f64 * interval_s + r.tz_offset_h * 3600.0))
                .collect()
        })
        .collect();
    let solar: Vec<Vec<f64>> = regions
        .iter()
        .map(|r| {
            let m = SolarModel {
                capacity_w: r.solar_w,
                ..SolarModel::default()
            };
            (0..n)
                .map(|k| m.clear_sky_w(k as f64 * interval_s + r.tz_offset_h * 3600.0))
                .collect()
        })
        .collect();

    let dt_h = interval_s / 3600.0;
    let mut static_g = 0.0;
    let mut greedy_g = 0.0;
    let mut region_energy_kwh = vec![0.0f64; regions.len()];
    let mut moved_kwh = 0.0;

    for k in 0..n {
        let load_w = load.power_w[k];
        // Static: home region (0), net of its solar.
        let home_net = (load_w - solar[0][k]).max(0.0);
        static_g += home_net * dt_h / 1000.0 * ci[0][k];

        // Greedy: pick the region with the lowest *effective* CI
        // (transfer overhead inflates remote energy).
        let mut best = 0usize;
        let mut best_cost = f64::INFINITY;
        for (i, _) in regions.iter().enumerate() {
            let overhead = if i == 0 { 1.0 } else { 1.0 + TRANSFER_OVERHEAD };
            let net = (load_w * overhead - solar[i][k]).max(0.0);
            let cost = net * ci[i][k];
            if cost < best_cost {
                best_cost = cost;
                best = i;
            }
        }
        let overhead = if best == 0 { 1.0 } else { 1.0 + TRANSFER_OVERHEAD };
        let e_kwh = load_w * overhead * dt_h / 1000.0;
        region_energy_kwh[best] += e_kwh;
        if best != 0 {
            moved_kwh += e_kwh;
        }
        greedy_g += best_cost * dt_h / 1000.0;
    }

    let mut table = Table::new(&["region", "ci_mean", "greedy_energy_kwh"]);
    for (i, r) in regions.iter().enumerate() {
        table.push_row(vec![
            r.name.clone(),
            format!("{:.0}", r.ci_mean),
            format!("{:.3}", region_energy_kwh[i]),
        ]);
    }
    table.push_row(vec![
        "TOTAL (static → greedy gCO₂)".into(),
        format!("{static_g:.0}"),
        format!("{greedy_g:.0}"),
    ]);
    table.push_row(vec![
        "moved_kwh".into(),
        String::new(),
        format!("{moved_kwh:.3}"),
    ]);
    Ok(MultiRegionResult {
        table,
        static_g,
        greedy_g,
    })
}

/// `repro multiregion` command.
pub fn cmd(args: &Args) -> Result<()> {
    let fast = args.has("fast");
    let mut cfg = SimConfig::default();
    super::cli::apply_sim_overrides(&mut cfg, args)?;
    if fast {
        cfg.num_requests = cfg.num_requests.min(512);
    }
    let cosim = CosimConfig::default();
    let mut sink = StreamingSink::new(&cfg, cosim.interval_s)?;
    let r = sim::run_streaming(&cfg, &mut sink)?;
    let binned = sink.binned_span(&cfg, r.metrics.makespan_s)?;
    let load = LoadProfile::from_binned(&binned);
    let res = simulate(&load, &default_regions(), cosim.interval_s, cfg.seed)?;
    println!("{}", res.table.to_markdown());
    println!(
        "net emissions: static {:.0} g -> greedy-ci {:.0} g ({:+.1}%)",
        res.static_g,
        res.greedy_g,
        (res.greedy_g / res.static_g - 1.0) * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_beats_static_with_a_clean_region() {
        let load = LoadProfile {
            interval_s: 60.0,
            power_w: vec![400.0; 1440],
        };
        let res = simulate(&load, &default_regions(), 60.0, 1).unwrap();
        assert!(
            res.greedy_g < res.static_g,
            "greedy {} !< static {}",
            res.greedy_g,
            res.static_g
        );
    }

    #[test]
    fn single_region_greedy_equals_static() {
        let load = LoadProfile {
            interval_s: 60.0,
            power_w: vec![300.0; 720],
        };
        let only_home = vec![default_regions()[0].clone()];
        let res = simulate(&load, &only_home, 60.0, 2).unwrap();
        assert!((res.greedy_g - res.static_g).abs() < 1e-6);
    }
}
