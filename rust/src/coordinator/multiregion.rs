//! Multi-region carbon-aware routing (§5 "our framework also extends
//! naturally to multi-region routing") — the closed-form load-profile
//! comparison.
//!
//! A fleet of regions, each with its own CI trace phase (time-zone
//! offset) and optional solar array, serves a shared inference load
//! profile. Policies:
//! * `static` — all load stays in the home region;
//! * `greedy-ci` — each step routes to the currently cleanest region,
//!   paying a transfer-energy penalty per shifted watt (modeled
//!   interconnect cost).
//!
//! Both policies account energy **net of local solar** — the grid
//! energy a region actually draws — so the per-region columns and the
//! emissions totals are consistent with each other.
//!
//! This module is the *degenerate-case oracle* for the request-level
//! router in [`crate::coordinator::fleet`] (DESIGN.md §13): with zero
//! RTT, no cold-start, and one always-on replica per region, the
//! router's greedy-ci emissions reproduce `simulate` within tolerance
//! (`rust/tests/multiregion_fleet.rs`).

use crate::config::simconfig::CosimConfig;
use crate::grid::{CarbonIntensityTrace, SolarModel};
use crate::pipeline::LoadProfile;
use crate::util::csv::Table;
use anyhow::Result;

/// One region's environment.
#[derive(Debug, Clone)]
pub struct Region {
    pub name: String,
    /// Mean grid CI, g/kWh.
    pub ci_mean: f64,
    /// Diurnal phase offset, hours (time zone).
    pub tz_offset_h: f64,
    /// Installed solar, W.
    pub solar_w: f64,
}

/// Default three-region fleet: CAISO-North (home), a dirty region, a
/// clean region — phases 0 / +3 / +9 hours.
pub fn default_regions() -> Vec<Region> {
    vec![
        Region { name: "caiso-north".into(), ci_mean: 418.2, tz_offset_h: 0.0, solar_w: 600.0 },
        Region { name: "midwest-coal".into(), ci_mean: 650.0, tz_offset_h: 3.0, solar_w: 0.0 },
        Region { name: "hydro-north".into(), ci_mean: 120.0, tz_offset_h: 9.0, solar_w: 0.0 },
    ]
}

pub struct MultiRegionResult {
    /// Per-region breakdown: one row per region, net-of-solar energy
    /// under each policy. No totals are smuggled into these columns —
    /// they live in `summary` and the scalar fields below.
    pub table: Table,
    /// Policy totals: one row per policy (net kWh, emissions, moved kWh).
    pub summary: Table,
    /// Static-placement net emissions, gCO₂.
    pub static_g: f64,
    /// Greedy-ci net emissions, gCO₂.
    pub greedy_g: f64,
    /// Total net grid energy under static placement, kWh.
    pub static_net_kwh: f64,
    /// Total net grid energy under greedy routing, kWh.
    pub greedy_net_kwh: f64,
    /// Net energy greedy served outside the home region, kWh.
    pub moved_kwh: f64,
}

/// Phase-shifted per-region CI and solar series for `n` intervals —
/// the exact sampling the closed-form comparison and the request-level
/// router's accounting both use (keeping them identical is what makes
/// the degenerate-case equivalence test meaningful).
pub fn region_series(
    regions: &[Region],
    n: usize,
    interval_s: f64,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let ci: Vec<Vec<f64>> = regions
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let t = CarbonIntensityTrace {
                mean: r.ci_mean,
                seed: seed ^ (i as u64),
                ..CarbonIntensityTrace::default()
            };
            (0..n)
                .map(|k| t.base_at(k as f64 * interval_s + r.tz_offset_h * 3600.0))
                .collect()
        })
        .collect();
    let solar: Vec<Vec<f64>> = regions
        .iter()
        .map(|r| {
            let m = SolarModel {
                capacity_w: r.solar_w,
                ..SolarModel::default()
            };
            (0..n)
                .map(|k| m.clear_sky_w(k as f64 * interval_s + r.tz_offset_h * 3600.0))
                .collect()
        })
        .collect();
    (ci, solar)
}

/// Closed-form comparison at the paper-default transfer overhead.
pub fn simulate(
    load: &LoadProfile,
    regions: &[Region],
    interval_s: f64,
    seed: u64,
) -> Result<MultiRegionResult> {
    simulate_with_overhead(
        load,
        regions,
        interval_s,
        seed,
        CosimConfig::default().transfer_overhead,
    )
}

/// Closed-form comparison with an explicit per-watt transfer overhead
/// (fraction of moved energy; `CosimConfig::transfer_overhead`).
pub fn simulate_with_overhead(
    load: &LoadProfile,
    regions: &[Region],
    interval_s: f64,
    seed: u64,
    transfer_overhead: f64,
) -> Result<MultiRegionResult> {
    let n = load.len();
    let (ci, solar) = region_series(regions, n, interval_s, seed);

    let dt_h = interval_s / 3600.0;
    let mut static_g = 0.0;
    let mut greedy_g = 0.0;
    let mut static_kwh = vec![0.0f64; regions.len()];
    let mut greedy_kwh = vec![0.0f64; regions.len()];
    let mut moved_kwh = 0.0;

    for k in 0..n {
        let load_w = load.power_w[k];
        // Static: home region (0), net of its solar.
        let home_net = (load_w - solar[0][k]).max(0.0);
        static_kwh[0] += home_net * dt_h / 1000.0;
        static_g += home_net * dt_h / 1000.0 * ci[0][k];

        // Greedy: pick the region with the lowest *effective* cost
        // (transfer overhead inflates remote energy, solar nets out).
        let mut best = 0usize;
        let mut best_net = home_net;
        let mut best_cost = f64::INFINITY;
        for (i, _) in regions.iter().enumerate() {
            let overhead = if i == 0 { 1.0 } else { 1.0 + transfer_overhead };
            let net = (load_w * overhead - solar[i][k]).max(0.0);
            let cost = net * ci[i][k];
            if cost < best_cost {
                best_cost = cost;
                best_net = net;
                best = i;
            }
        }
        // Book what the winning region actually draws from its grid —
        // net of solar, the same quantity the emissions integrate.
        let e_kwh = best_net * dt_h / 1000.0;
        greedy_kwh[best] += e_kwh;
        if best != 0 {
            moved_kwh += e_kwh;
        }
        greedy_g += best_cost * dt_h / 1000.0;
    }

    let mut table = Table::new(&["region", "ci_mean", "static_net_kwh", "greedy_net_kwh"]);
    for (i, r) in regions.iter().enumerate() {
        table.push_row(vec![
            r.name.clone(),
            format!("{:.0}", r.ci_mean),
            format!("{:.3}", static_kwh[i]),
            format!("{:.3}", greedy_kwh[i]),
        ]);
    }
    let static_net_kwh: f64 = static_kwh.iter().sum();
    let greedy_net_kwh: f64 = greedy_kwh.iter().sum();
    let mut summary = Table::new(&["policy", "net_kwh", "emissions_g", "moved_kwh"]);
    summary.push_row(vec![
        "static".into(),
        format!("{static_net_kwh:.3}"),
        format!("{static_g:.0}"),
        "0.000".into(),
    ]);
    summary.push_row(vec![
        "greedy-ci".into(),
        format!("{greedy_net_kwh:.3}"),
        format!("{greedy_g:.0}"),
        format!("{moved_kwh:.3}"),
    ]);
    Ok(MultiRegionResult {
        table,
        summary,
        static_g,
        greedy_g,
        static_net_kwh,
        greedy_net_kwh,
        moved_kwh,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_beats_static_with_a_clean_region() {
        let load = LoadProfile {
            interval_s: 60.0,
            power_w: vec![400.0; 1440],
        };
        let res = simulate(&load, &default_regions(), 60.0, 1).unwrap();
        assert!(
            res.greedy_g < res.static_g,
            "greedy {} !< static {}",
            res.greedy_g,
            res.static_g
        );
    }

    #[test]
    fn single_region_greedy_equals_static() {
        let load = LoadProfile {
            interval_s: 60.0,
            power_w: vec![300.0; 720],
        };
        let only_home = vec![default_regions()[0].clone()];
        let res = simulate(&load, &only_home, 60.0, 2).unwrap();
        assert!((res.greedy_g - res.static_g).abs() < 1e-6);
        assert!((res.greedy_net_kwh - res.static_net_kwh).abs() < 1e-9);
        assert!(res.moved_kwh.abs() < 1e-12);
    }

    #[test]
    fn per_region_energy_sums_to_policy_total_net_of_solar() {
        let load = LoadProfile {
            interval_s: 60.0,
            power_w: vec![500.0; 1440],
        };
        let res = simulate(&load, &default_regions(), 60.0, 7).unwrap();
        // The table's per-region columns must reconcile with the
        // summary totals exactly (they are the same accumulators).
        let sc = res.table.col_index("static_net_kwh").unwrap();
        let gc = res.table.col_index("greedy_net_kwh").unwrap();
        let ssum: f64 = res.table.rows.iter().map(|r| r[sc].parse::<f64>().unwrap()).sum();
        let gsum: f64 = res.table.rows.iter().map(|r| r[gc].parse::<f64>().unwrap()).sum();
        assert!((ssum - res.static_net_kwh).abs() < 1e-2, "{ssum} vs {}", res.static_net_kwh);
        assert!((gsum - res.greedy_net_kwh).abs() < 1e-2, "{gsum} vs {}", res.greedy_net_kwh);
        // Net accounting: greedy can never book more energy in a
        // region than gross load + overhead would imply, and with the
        // home region's 600 W solar the static net is below gross.
        let gross_kwh = 500.0 * 1440.0 * 60.0 / 3.6e6;
        assert!(res.static_net_kwh < gross_kwh);
        assert!(res.greedy_net_kwh <= gross_kwh * (1.0 + 0.05) + 1e-9);
    }

    #[test]
    fn transfer_overhead_monotone_discourages_moving() {
        let load = LoadProfile {
            interval_s: 60.0,
            power_w: vec![400.0; 1440],
        };
        let cheap = simulate_with_overhead(&load, &default_regions(), 60.0, 3, 0.0).unwrap();
        let dear = simulate_with_overhead(&load, &default_regions(), 60.0, 3, 10.0).unwrap();
        // A prohibitive transfer overhead pins everything home.
        assert!(dear.moved_kwh < 1e-9, "moved {}", dear.moved_kwh);
        assert!((dear.greedy_g - dear.static_g).abs() < 1e-6);
        // Free transfers move at least as much as the 5% default.
        let base = simulate(&load, &default_regions(), 60.0, 3).unwrap();
        assert!(cheap.moved_kwh >= base.moved_kwh - 1e-9);
    }
}
