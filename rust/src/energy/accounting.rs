//! Operational energy (Eq. 3) and carbon (Eq. 4) from a stage log.
//!
//! Two accounting modes:
//! * `Physical` (default): active GPUs draw P(MFU_i), the replica's
//!   other (pp−1)·tp GPUs draw P_idle during the stage, and all GPUs
//!   draw P_idle over gaps between stages. Energy-conserving and
//!   power-balanced at every instant.
//! * `PaperEq3`: the literal Eq. 3 — every one of the G = R·TP·PP GPUs
//!   is charged at P(MFU_i) for H_i = Δt·G/3600 GPU-hours, and idle
//!   gaps are not charged. Provided for fidelity comparison (ablation
//!   bench `abl_power_model`).

use crate::autoscale::FleetTimeline;
use crate::config::simconfig::SimConfig;
use crate::power::PowerModel;
use crate::telemetry::{StageLog, StageRecord};
use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccountingMode {
    Physical,
    PaperEq3,
}

/// Energy/carbon totals for one simulation run.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// Operational energy at the wall (kWh), PUE included.
    pub energy_kwh: f64,
    /// GPU-side energy before PUE (kWh).
    pub gpu_energy_kwh: f64,
    /// Time-averaged per-GPU power over the makespan (W) — the Fig. 2/4/5
    /// y-axis.
    pub avg_power_w: f64,
    /// Peak instantaneous per-GPU power across stages (W).
    pub peak_power_w: f64,
    /// GPU-hours (all GPUs × makespan).
    pub gpu_hours: f64,
    /// Operational carbon at a static grid intensity (g).
    pub operational_g: f64,
    /// Embodied carbon share (g, Eq. 4's H·φ_manuf term).
    pub embodied_g: f64,
    /// Busy fraction of GPU time.
    pub busy_fraction: f64,
}

impl EnergyReport {
    pub fn total_g(&self) -> f64 {
        self.operational_g + self.embodied_g
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("energy_kwh", self.energy_kwh)
            .set("gpu_energy_kwh", self.gpu_energy_kwh)
            .set("avg_power_w", self.avg_power_w)
            .set("peak_power_w", self.peak_power_w)
            .set("gpu_hours", self.gpu_hours)
            .set("operational_g", self.operational_g)
            .set("embodied_g", self.embodied_g)
            .set("total_g", self.total_g())
            .set("busy_fraction", self.busy_fraction);
        v
    }
}

/// Online physical-mode accumulators over stage records: everything
/// the Eq. 3/4 report needs that is linear in the stages. Both the
/// materialized paths ([`EnergyAccountant::account`] /
/// [`EnergyAccountant::account_fleet`]) and the streaming
/// [`crate::telemetry::StreamingSink`] fold records through
/// [`StageAggregates::add`] in production order, so the two paths
/// produce identical floating-point sums.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageAggregates {
    /// GPU-side stage energy (active GPUs at P(MFU), replica-idle GPUs
    /// at P_idle), J — before the idle-gap fill.
    pub joules: f64,
    /// Active-GPU busy time, GPU-seconds.
    pub busy_gpu_s: f64,
    /// GPU-time covered by stage records (active + replica-idle).
    pub covered_gpu_s: f64,
    /// Peak active per-GPU power seen, W (0 until the first record;
    /// the report floors it at P_idle).
    pub peak_w: f64,
}

impl StageAggregates {
    /// Fold one stage record under `model`'s power law.
    pub fn add(&mut self, r: &StageRecord, model: &PowerModel, p_idle: f64) {
        let p_active = model.power(r.mfu, true);
        self.joules +=
            (p_active * r.active_gpus as f64 + p_idle * r.idle_gpus as f64) * r.dt_s;
        self.busy_gpu_s += r.dt_s * r.active_gpus as f64;
        self.covered_gpu_s += r.dt_s * (r.active_gpus + r.idle_gpus) as f64;
        self.peak_w = self.peak_w.max(p_active);
    }
}

/// Computes Eq. 2–4 over a stage log.
pub struct EnergyAccountant {
    pub mode: AccountingMode,
    pub power_model: PowerModel,
    /// Static grid carbon intensity, gCO₂/kWh (time-varying CI is
    /// handled by the co-simulation pipeline instead).
    pub grid_ci: f64,
}

impl EnergyAccountant {
    pub fn paper_default(cfg: &SimConfig) -> crate::Result<Self> {
        Ok(EnergyAccountant {
            mode: AccountingMode::Physical,
            power_model: PowerModel::paper_default(cfg.gpu_spec()?),
            grid_ci: 418.2, // the case study's average CI
        })
    }

    pub fn with_mode(mut self, mode: AccountingMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_ci(mut self, ci: f64) -> Self {
        self.grid_ci = ci;
        self
    }

    /// Fold a materialized log into the physical-mode aggregates (the
    /// streaming sink accumulates the same sums online).
    pub fn aggregate(&self, log: &StageLog) -> StageAggregates {
        let p_idle = self.power_model.power(0.0, false);
        let mut agg = StageAggregates::default();
        for r in &log.records {
            agg.add(r, &self.power_model, p_idle);
        }
        agg
    }

    /// Account a finished run. `makespan_s` bounds the idle-gap term.
    pub fn account(&self, cfg: &SimConfig, log: &StageLog, makespan_s: f64) -> EnergyReport {
        match self.mode {
            AccountingMode::Physical => {
                let agg = self.aggregate(log);
                self.report(cfg, &agg, makespan_s)
            }
            AccountingMode::PaperEq3 => {
                // E_op = Σ P(MFU_i) · H_i · PUE with H_i = Δt·G/3600;
                // idle gaps are not charged (fidelity-comparison mode,
                // materialized path only).
                let g_total = cfg.total_gpus() as f64;
                let mut agg = StageAggregates::default();
                for r in &log.records {
                    let p = self.power_model.power(r.mfu, true);
                    agg.joules += p * g_total * r.dt_s;
                    agg.busy_gpu_s += r.dt_s * r.active_gpus as f64;
                    agg.peak_w = agg.peak_w.max(p);
                }
                self.finish(cfg, &agg, makespan_s)
            }
        }
    }

    /// Physical fixed-fleet report from pre-folded aggregates: charge
    /// the idle gaps (every GPU-second of `R·TP·PP × makespan` not
    /// covered by a stage record draws idle power) and finish Eq. 3/4.
    ///
    /// Physical mode only: `PaperEq3` charges all GPUs at stage power
    /// and skips idle gaps, which the streaming aggregates don't
    /// carry — use [`Self::account`] on a materialized log for it.
    pub fn report(
        &self,
        cfg: &SimConfig,
        agg: &StageAggregates,
        makespan_s: f64,
    ) -> EnergyReport {
        assert!(
            self.mode == AccountingMode::Physical,
            "streaming aggregates carry physical-mode sums; PaperEq3 needs the \
             materialized log (EnergyAccountant::account)"
        );
        let g_total = cfg.total_gpus() as f64;
        let p_idle = self.power_model.power(0.0, false);
        let total_gpu_s = g_total * makespan_s;
        let idle_gpu_s = (total_gpu_s - agg.covered_gpu_s).max(0.0);
        let mut agg = *agg;
        agg.joules += idle_gpu_s * p_idle;
        self.finish(cfg, &agg, makespan_s)
    }

    /// Shared Eq. 3/4 tail over final (joules, busy, peak) totals.
    fn finish(&self, cfg: &SimConfig, agg: &StageAggregates, makespan_s: f64) -> EnergyReport {
        let g_total = cfg.total_gpus() as f64;
        let gpu = cfg.gpu_spec().expect("validated config");
        let p_idle = self.power_model.power(0.0, false);
        let gpu_energy_kwh = agg.joules / 3.6e6;
        let energy_kwh = gpu_energy_kwh * cfg.pue;
        let gpu_hours = g_total * makespan_s / 3600.0;
        let avg_power_w = if makespan_s > 0.0 {
            agg.joules / makespan_s / g_total
        } else {
            0.0
        };

        EnergyReport {
            energy_kwh,
            gpu_energy_kwh,
            avg_power_w,
            peak_power_w: agg.peak_w.max(p_idle),
            gpu_hours,
            operational_g: energy_kwh * self.grid_ci,
            embodied_g: gpu_hours * gpu.phi_manuf,
            busy_fraction: if makespan_s > 0.0 {
                (agg.busy_gpu_s / (g_total * makespan_s)).min(1.0)
            } else {
                0.0
            },
        }
    }

    /// Physical accounting over a **dynamic fleet** (DESIGN.md §6):
    /// stage energy as in [`Self::account`], but idle power is charged
    /// only for GPU-time of replicas that exist at each instant
    /// (provision → offline, cold starts included), and GPU-hours /
    /// embodied carbon follow the timeline instead of `R·TP·PP ×
    /// makespan`. `avg_power_w` is per *live* GPU. With
    /// [`FleetTimeline::static_fleet`] this reduces to the fixed-fleet
    /// physical accounting.
    pub fn account_fleet(
        &self,
        cfg: &SimConfig,
        log: &StageLog,
        fleet: &FleetTimeline,
    ) -> EnergyReport {
        let agg = self.aggregate(log);
        self.report_fleet(cfg, &agg, fleet)
    }

    /// Fleet-aware physical report from pre-folded aggregates: idle
    /// gaps are charged only for live GPU-time (dead replicas draw
    /// nothing), and GPU-hours / embodied carbon follow the timeline.
    ///
    /// Physical mode only — see [`Self::report`].
    pub fn report_fleet(
        &self,
        cfg: &SimConfig,
        agg: &StageAggregates,
        fleet: &FleetTimeline,
    ) -> EnergyReport {
        assert!(
            self.mode == AccountingMode::Physical,
            "streaming aggregates carry physical-mode sums; PaperEq3 needs the \
             materialized log (EnergyAccountant::account)"
        );
        let gpu = cfg.gpu_spec().expect("validated config");
        let p_idle = self.power_model.power(0.0, false);
        let gpus_per_replica = cfg.gpus_per_replica() as f64;
        let live_gpu_s = fleet.live_gpu_seconds(cfg.gpus_per_replica());

        // Idle gaps: live GPU-time not covered by a stage record draws
        // idle power. Dead replicas draw nothing.
        let idle_gpu_s = (live_gpu_s - agg.covered_gpu_s).max(0.0);
        let joules = agg.joules + idle_gpu_s * p_idle;
        debug_assert!(
            agg.covered_gpu_s <= live_gpu_s * (1.0 + 1e-9) + gpus_per_replica,
            "stages cover more GPU-time than the fleet has"
        );

        let gpu_energy_kwh = joules / 3.6e6;
        let gpu_hours = live_gpu_s / 3600.0;
        EnergyReport {
            energy_kwh: gpu_energy_kwh * cfg.pue,
            gpu_energy_kwh,
            avg_power_w: if live_gpu_s > 0.0 {
                joules / live_gpu_s
            } else {
                0.0
            },
            peak_power_w: agg.peak_w.max(p_idle),
            gpu_hours,
            operational_g: gpu_energy_kwh * cfg.pue * self.grid_ci,
            embodied_g: gpu_hours * gpu.phi_manuf,
            busy_fraction: if live_gpu_s > 0.0 {
                (agg.busy_gpu_s / live_gpu_s).min(1.0)
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::replica::StageKind;
    use crate::telemetry::StageRecord;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    fn rec(start: f64, dt: f64, mfu: f64) -> StageRecord {
        StageRecord {
            replica: 0,
            pp_stage: 0,
            start_s: start,
            dt_s: dt,
            batch_size: 1,
            new_tokens: 1,
            mfu,
            power_w: 0.0, // accountant recomputes from its own model
            active_gpus: 1,
            idle_gpus: 0,
            flops: 1e12,
            kind: StageKind::Decode,
        }
    }

    #[test]
    fn fully_idle_run_draws_idle_power() {
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let log = StageLog::new();
        let rep = acc.account(&cfg(), &log, 3600.0);
        // 1 GPU at 100 W for 1 h, PUE 1.2 -> 0.12 kWh.
        assert!((rep.energy_kwh - 0.12).abs() < 1e-9, "{}", rep.energy_kwh);
        assert!((rep.avg_power_w - 100.0).abs() < 1e-9);
        assert_eq!(rep.busy_fraction, 0.0);
    }

    #[test]
    fn saturated_stage_draws_pmax() {
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let mut log = StageLog::new();
        log.push(rec(0.0, 3600.0, 0.45));
        let rep = acc.account(&cfg(), &log, 3600.0);
        // 400 W for 1 h * PUE -> 0.48 kWh.
        assert!((rep.energy_kwh - 0.48).abs() < 1e-6);
        assert_eq!(rep.peak_power_w, 400.0);
        assert!((rep.busy_fraction - 1.0).abs() < 1e-9);
    }

    #[test]
    fn half_busy_blends_with_idle() {
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let mut log = StageLog::new();
        log.push(rec(0.0, 1800.0, 0.45)); // 400 W for half the time
        let rep = acc.account(&cfg(), &log, 3600.0);
        let expect_avg = (400.0 * 1800.0 + 100.0 * 1800.0) / 3600.0;
        assert!((rep.avg_power_w - expect_avg).abs() < 1e-9);
    }

    #[test]
    fn paper_eq3_charges_all_gpus_at_stage_power() {
        let mut c = cfg();
        c.tp = 2;
        c.pp = 2; // G = 4
        let acc = EnergyAccountant::paper_default(&c)
            .unwrap()
            .with_mode(AccountingMode::PaperEq3);
        let mut log = StageLog::new();
        let mut r = rec(0.0, 3600.0, 0.45);
        r.active_gpus = 2;
        r.idle_gpus = 2;
        log.push(r);
        let rep = acc.account(&c, &log, 3600.0);
        // Eq. 3: 400 W × 4 GPUs × 1 h × PUE 1.2 = 1.92 kWh.
        assert!((rep.energy_kwh - 1.92).abs() < 1e-6, "{}", rep.energy_kwh);
        // Physical mode would charge 2 GPUs at 400 + 2 at 100 (+PUE).
        let phys = EnergyAccountant::paper_default(&c)
            .unwrap()
            .account(&c, &log, 3600.0);
        assert!(phys.energy_kwh < rep.energy_kwh);
    }

    #[test]
    fn embodied_carbon_scales_with_gpu_hours() {
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let log = StageLog::new();
        let rep = acc.account(&cfg(), &log, 7200.0);
        assert!((rep.gpu_hours - 2.0).abs() < 1e-9);
        assert!((rep.embodied_g - 2.0 * 3.42).abs() < 1e-9);
        assert!(rep.total_g() > rep.operational_g);
    }

    #[test]
    fn fleet_accounting_reduces_to_static() {
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let mut log = StageLog::new();
        log.push(rec(0.0, 1800.0, 0.45));
        let fixed = acc.account(&cfg(), &log, 3600.0);
        let fleet = acc.account_fleet(
            &cfg(),
            &log,
            &FleetTimeline::static_fleet(1, 3600.0),
        );
        assert!((fixed.energy_kwh - fleet.energy_kwh).abs() < 1e-9);
        assert!((fixed.avg_power_w - fleet.avg_power_w).abs() < 1e-9);
        assert!((fixed.gpu_hours - fleet.gpu_hours).abs() < 1e-12);
        assert!((fixed.busy_fraction - fleet.busy_fraction).abs() < 1e-12);
    }

    #[test]
    fn dead_replicas_draw_nothing() {
        // Two replicas for the first half of the run, one afterwards:
        // idle energy must reflect 1.5 replica-hours, not 2.
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let log = StageLog::new();
        let mut t = FleetTimeline::new();
        t.provision(0, 0.0);
        t.online(0, 0.0);
        t.provision(1, 0.0);
        t.online(1, 0.0);
        t.drain_start(1, 1800.0);
        t.offline(1, 1800.0);
        t.close(3600.0);
        let rep = acc.account_fleet(&cfg(), &log, &t);
        // 1.5 GPU-hours at 100 W idle, PUE 1.2 -> 0.18 kWh.
        assert!((rep.energy_kwh - 0.18).abs() < 1e-9, "{}", rep.energy_kwh);
        assert!((rep.gpu_hours - 1.5).abs() < 1e-12);
        // Static 2-replica accounting would charge 0.24 kWh.
        let static2 = acc.account_fleet(
            &cfg(),
            &log,
            &FleetTimeline::static_fleet(2, 3600.0),
        );
        assert!(rep.energy_kwh < static2.energy_kwh);
    }

    #[test]
    fn cold_start_charged_as_idle() {
        // One replica provisioned at t=0 but online only at t=1800:
        // the boot period still draws idle power.
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let log = StageLog::new();
        let mut t = FleetTimeline::new();
        t.provision(0, 0.0);
        t.online(0, 1800.0);
        t.close(3600.0);
        let rep = acc.account_fleet(&cfg(), &log, &t);
        assert!((rep.gpu_hours - 1.0).abs() < 1e-12);
        assert!((rep.energy_kwh - 0.12).abs() < 1e-9);
    }

    #[test]
    fn energy_monotone_in_mfu() {
        let acc = EnergyAccountant::paper_default(&cfg()).unwrap();
        let mut prev = 0.0;
        for mfu in [0.0, 0.1, 0.2, 0.3, 0.45] {
            let mut log = StageLog::new();
            log.push(rec(0.0, 100.0, mfu));
            let rep = acc.account(&cfg(), &log, 100.0);
            assert!(rep.energy_kwh >= prev);
            prev = rep.energy_kwh;
        }
    }
}
