//! Energy and carbon accounting — the paper's Eq. 2–4 applied to the
//! stage log.

pub mod accounting;

pub use accounting::{AccountingMode, EnergyAccountant, EnergyReport, StageAggregates};
