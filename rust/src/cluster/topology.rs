//! Cluster topology: replica → GPU-group layout and interconnect
//! characteristics (the paper's Table 1b "NVLink (pairwise)" testbed,
//! Exp. 5's 4×A100 TP×PP grid).

use crate::config::gpus::GpuSpec;
use crate::config::models::ModelSpec;
use crate::config::simconfig::SimConfig;
use anyhow::Result;

/// Immutable description of the simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterTopology {
    pub model: &'static ModelSpec,
    pub gpu: &'static GpuSpec,
    pub replicas: u32,
    pub tp: u32,
    pub pp: u32,
}

impl ClusterTopology {
    pub fn from_config(cfg: &SimConfig) -> Result<Self> {
        Ok(ClusterTopology {
            model: cfg.model_spec()?,
            gpu: cfg.gpu_spec()?,
            replicas: cfg.replicas,
            tp: cfg.tp,
            pp: cfg.pp,
        })
    }

    /// GPUs per replica (one TP group per PP stage).
    pub fn gpus_per_replica(&self) -> u32 {
        self.tp * self.pp
    }

    /// Total GPUs G = R·TP·PP (Eq. 2).
    pub fn total_gpus(&self) -> u32 {
        self.replicas * self.gpus_per_replica()
    }

    /// Peak FLOPs of one replica's full GPU group.
    pub fn replica_peak_flops(&self) -> f64 {
        self.gpus_per_replica() as f64 * self.gpu.peak_flops
    }

    /// Whether a replica's weights physically fit in its GPUs' VRAM
    /// (the simulator proceeds regardless, but reports this).
    pub fn weights_fit(&self) -> bool {
        self.model.weight_bytes() <= self.gpu.vram_bytes * self.gpus_per_replica() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::SimConfig;

    #[test]
    fn counts() {
        let mut cfg = SimConfig::default();
        cfg.tp = 2;
        cfg.pp = 2;
        cfg.replicas = 2;
        let t = ClusterTopology::from_config(&cfg).unwrap();
        assert_eq!(t.gpus_per_replica(), 4);
        assert_eq!(t.total_gpus(), 8);
        assert_eq!(t.replica_peak_flops(), 4.0 * 312e12);
    }

    #[test]
    fn fit_check() {
        let mut cfg = SimConfig::default();
        cfg.model = "llama3-70b".into(); // ~141 GB bf16
        cfg.tp = 1;
        cfg.pp = 1;
        let t = ClusterTopology::from_config(&cfg).unwrap();
        assert!(!t.weights_fit());
        cfg.tp = 2;
        cfg.pp = 2; // 4 × 80 GB
        let t = ClusterTopology::from_config(&cfg).unwrap();
        assert!(t.weights_fit());
    }
}
