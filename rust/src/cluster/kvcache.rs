//! Paged KV-cache block manager (vLLM-style): fixed-size token blocks,
//! admission checks, per-request allocation, preemption support.
//!
//! Capacity is derived from GPU VRAM minus sharded weights. When the
//! model does not physically fit (the paper simulates CodeLlama-34B on
//! a single A100 regardless), a floor capacity keeps the simulation
//! well-defined — matching Vidur's behaviour of simulating the
//! schedule even for configurations a real deployment would reject.

use crate::config::gpus::GpuSpec;
use crate::config::models::ModelSpec;
use std::collections::HashMap;

/// Fraction of free VRAM given to KV blocks (vLLM's
/// gpu_memory_utilization semantics, applied post-weights).
const KV_MEM_FRACTION: f64 = 0.9;

#[derive(Debug)]
pub struct KvCache {
    block_tokens: u64,
    total_blocks: u64,
    free_blocks: u64,
    per_request: HashMap<u64, u64>,
}

impl KvCache {
    /// Size the cache for one replica (model sharded over tp×pp GPUs).
    pub fn for_replica(
        model: &ModelSpec,
        gpu: &GpuSpec,
        tp: u32,
        pp: u32,
        block_tokens: u64,
        max_request_tokens: u64,
    ) -> Self {
        let gpus = (tp * pp) as f64;
        let free = (gpu.vram_bytes * gpus - model.weight_bytes()).max(0.0) * KV_MEM_FRACTION;
        let bytes_per_block = model.kv_bytes_per_token() * block_tokens as f64;
        let mut total_blocks = (free / bytes_per_block) as u64;
        // Floor: always admit at least one maximal request, so
        // "doesn't physically fit" configs still simulate (Vidur-like).
        let floor = max_request_tokens.div_ceil(block_tokens) * 2;
        if total_blocks < floor {
            total_blocks = floor;
        }
        KvCache {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
            per_request: HashMap::new(),
        }
    }

    /// Fixed-size cache for tests.
    pub fn with_blocks(block_tokens: u64, total_blocks: u64) -> Self {
        KvCache {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
            per_request: HashMap::new(),
        }
    }

    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }
    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }
    pub fn used_blocks(&self) -> u64 {
        self.total_blocks - self.free_blocks
    }
    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    fn blocks_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a request with `tokens` total KV demand be admitted now?
    pub fn can_admit(&self, tokens: u64) -> bool {
        self.blocks_for(tokens.max(1)) <= self.free_blocks
    }

    /// Reserve blocks for `tokens` of KV for request `id` (admission).
    /// Returns false (no change) if insufficient.
    pub fn admit(&mut self, id: u64, tokens: u64) -> bool {
        let need = self.blocks_for(tokens.max(1));
        if need > self.free_blocks {
            return false;
        }
        self.free_blocks -= need;
        *self.per_request.entry(id).or_insert(0) += need;
        true
    }

    /// Grow request `id` to hold `new_total` tokens (decode progress).
    /// Returns false if the growth cannot be satisfied (caller must
    /// preempt someone).
    pub fn grow(&mut self, id: u64, new_total: u64) -> bool {
        let have = *self.per_request.get(&id).unwrap_or(&0);
        let need = self.blocks_for(new_total.max(1));
        if need <= have {
            return true;
        }
        let extra = need - have;
        if extra > self.free_blocks {
            return false;
        }
        self.free_blocks -= extra;
        self.per_request.insert(id, need);
        true
    }

    /// Release all blocks of request `id` (finish or preemption).
    pub fn release(&mut self, id: u64) {
        if let Some(n) = self.per_request.remove(&id) {
            self.free_blocks += n;
        }
    }

    /// Invariant check for property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let held: u64 = self.per_request.values().sum();
        if held + self.free_blocks != self.total_blocks {
            return Err(format!(
                "block leak: held {held} + free {} != total {}",
                self.free_blocks, self.total_blocks
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpus, models};
    use crate::util::proptest::{check, gens};
    use crate::util::rng::Rng;

    #[test]
    fn sizing_8b_on_a100() {
        let kv = KvCache::for_replica(
            models::model("llama3-8b").unwrap(),
            gpus::gpu("a100-80g").unwrap(),
            1,
            1,
            16,
            4096,
        );
        // ~(80-16)GB * 0.9 / (131072 B/token * 16 tokens) ≈ 27k blocks.
        assert!(kv.total_blocks() > 20_000, "{}", kv.total_blocks());
        assert!(kv.total_blocks() < 40_000);
    }

    #[test]
    fn oversized_model_gets_floor_capacity() {
        // CodeLlama-34B weights (~68 GB) + KV barely fit in 80 GB:
        // with TP=1 the floor keeps simulation possible.
        let kv = KvCache::for_replica(
            models::model("qwen-72b").unwrap(), // 144 GB weights >> 80
            gpus::gpu("a100-80g").unwrap(),
            1,
            1,
            16,
            4096,
        );
        assert_eq!(kv.total_blocks(), 4096 / 16 * 2);
    }

    #[test]
    fn admit_grow_release_cycle() {
        let mut kv = KvCache::with_blocks(16, 10);
        assert!(kv.admit(1, 100)); // 7 blocks
        assert_eq!(kv.free_blocks(), 3);
        assert!(kv.grow(1, 112)); // still 7 blocks
        assert_eq!(kv.free_blocks(), 3);
        assert!(kv.grow(1, 128)); // 8 blocks
        assert_eq!(kv.free_blocks(), 2);
        assert!(!kv.admit(2, 100)); // needs 7, only 2 free
        assert!(kv.admit(2, 30)); // 2 blocks
        assert!(!kv.grow(1, 160)); // would need 2 more, 0 free
        kv.release(1);
        assert_eq!(kv.free_blocks(), 8);
        kv.release(2);
        assert_eq!(kv.free_blocks(), 10);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut kv = KvCache::with_blocks(16, 4);
        kv.release(99);
        assert_eq!(kv.free_blocks(), 4);
    }

    #[test]
    fn property_no_block_leaks() {
        check(50, gens::u64_in(0, u64::MAX / 2), |&seed| {
            let mut rng = Rng::new(seed);
            let mut kv = KvCache::with_blocks(16, 64);
            let mut live: Vec<u64> = Vec::new();
            for op in 0..500 {
                match rng.int_range(0, 2) {
                    0 => {
                        let id = op as u64;
                        if kv.admit(id, rng.int_range(1, 512)) {
                            live.push(id);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.int_range(0, live.len() as u64 - 1) as usize;
                        kv.grow(live[i], rng.int_range(1, 1024));
                    }
                    _ if !live.is_empty() => {
                        let i = rng.int_range(0, live.len() as u64 - 1) as usize;
                        kv.release(live.swap_remove(i));
                    }
                    _ => {}
                }
                kv.check_invariants()?;
            }
            Ok(())
        });
    }
}
