//! Cluster model: paged KV-cache management (vLLM-style) and the
//! replica/topology bookkeeping for TP×PP groups.

pub mod kvcache;
pub mod topology;

pub use kvcache::KvCache;
pub use topology::ClusterTopology;
