//! `repro merge` — recombine sharded sweep outputs (DESIGN.md §9).
//!
//! Each host of an N-way sharded sweep produced a results directory
//! whose experiment subdirectories hold the shard's CSV rows, its
//! `meta.json`, and the mergeable telemetry sidecar
//! ([`crate::telemetry::ShardTelemetry`]). [`merge_shard_dirs`] folds
//! those directories back into one results tree:
//!
//! * **CSV** — rows are re-interleaved by global case index (the
//!   sidecar records each row's case) and written through the same
//!   [`Table::save`] writer the experiments use, so the merged file is
//!   **byte-identical** to what an unsharded run would have written:
//!   every row was formatted by the same code from the same
//!   case-seeded simulation, sharding only moved it between files.
//! * **`telemetry.json`** — sidecars merge via
//!   [`ShardTelemetry::merge`]: exact counters sum, peaks take maxima,
//!   GK sketches combine within the documented rank bound, quantile
//!   point-estimates are re-derived from the merged sketches.
//! * **`meta.json`** — merged with per-field semantics for the `sweep`
//!   object (see [`merge_sweep_values`]); other keys union with
//!   first-shard-wins on conflicts.
//! * **Everything else** (`fleet_*.csv`, case-study figures…) is
//!   copied through; shards own disjoint cases, so name collisions
//!   with differing content are protocol errors, not merges.
//!
//! Experiment directories *without* a sidecar (single-case experiments
//! like `casestudy`/`ablation`, which only shard 0 runs) are copied
//! wholesale when exactly one shard produced them.

use crate::telemetry::{shard as sidecar, ShardTelemetry};
use crate::util::csv::Table;
use crate::util::json::{parse, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Summary of one merged experiment directory.
#[derive(Debug)]
pub struct MergedExperiment {
    pub id: String,
    /// Shard directories that contributed.
    pub shards: usize,
    /// Rows in the merged CSV (0 for sidecar-less copy-through dirs).
    pub rows: usize,
    /// Whether the merged telemetry covers the full case grid.
    pub complete: bool,
}

/// Merge the experiment outputs under `shard_dirs` into `out_dir`.
/// Every subdirectory name found in any shard dir is treated as one
/// experiment id and merged independently; the result layout matches
/// an unsharded `repro experiment` run.
pub fn merge_shard_dirs(shard_dirs: &[PathBuf], out_dir: &Path) -> Result<Vec<MergedExperiment>> {
    if shard_dirs.is_empty() {
        bail!("nothing to merge: no shard directories given");
    }
    for d in shard_dirs {
        if !d.is_dir() {
            bail!("shard directory {d:?} does not exist");
        }
    }
    // Group: experiment id -> the shard dirs containing it.
    let mut by_id: BTreeMap<String, Vec<PathBuf>> = BTreeMap::new();
    for dir in shard_dirs {
        for entry in std::fs::read_dir(dir).with_context(|| format!("listing {dir:?}"))? {
            let path = entry?.path();
            if path.is_dir() {
                let id = path
                    .file_name()
                    .and_then(|n| n.to_str())
                    .ok_or_else(|| anyhow::anyhow!("unreadable directory name in {dir:?}"))?
                    .to_string();
                by_id.entry(id).or_default().push(path.clone());
            }
        }
    }
    if by_id.is_empty() {
        bail!(
            "no experiment subdirectories found under {shard_dirs:?} — \
             pass the --out directories the sharded runs wrote"
        );
    }

    let mut merged = Vec::new();
    for (id, dirs) in by_id {
        merged.push(merge_experiment(&id, &dirs, &out_dir.join(&id))?);
    }
    Ok(merged)
}

/// Merge one experiment id's shard directories into `out`.
fn merge_experiment(id: &str, dirs: &[PathBuf], out: &Path) -> Result<MergedExperiment> {
    // Load sidecars; order shards deterministically by shard index
    // (input order as a tiebreak for shard-less sidecars).
    let mut parts: Vec<(PathBuf, Option<ShardTelemetry>)> = Vec::new();
    for d in dirs {
        parts.push((d.clone(), ShardTelemetry::load(d)?));
    }
    let with_sidecar = parts.iter().filter(|(_, t)| t.is_some()).count();
    if with_sidecar == 0 {
        // Single-case experiments (casestudy, ablation): only one
        // shard ran them; copy through untouched.
        if parts.len() > 1 {
            bail!(
                "experiment '{id}' has no telemetry sidecar but appears in \
                 {} shard directories — cannot merge without the sidecar's \
                 case map (was it produced by a pre-sharding build?)",
                parts.len()
            );
        }
        copy_dir(&parts[0].0, out)?;
        return Ok(MergedExperiment {
            id: id.to_string(),
            shards: 1,
            rows: 0,
            complete: true,
        });
    }
    if with_sidecar != parts.len() {
        bail!(
            "experiment '{id}': some shard directories have a telemetry \
             sidecar and some do not — mixed sharded/unsharded outputs \
             cannot be merged"
        );
    }
    parts.sort_by_key(|(_, t)| {
        t.as_ref()
            .and_then(|t| t.shard)
            .map(|s| s.index)
            .unwrap_or(u32::MAX)
    });

    // Fold telemetry + collect (case, row) pairs.
    let mut telemetry: Option<ShardTelemetry> = None;
    let mut rows: Vec<(u64, Vec<String>)> = Vec::new();
    let mut header: Option<Vec<String>> = None;
    let mut metas: Vec<Value> = Vec::new();
    for (dir, part) in &parts {
        let part = part.as_ref().expect("checked above");
        let csv_path = dir.join(format!("{id}.csv"));
        let table = Table::load(&csv_path)?;
        if table.rows.len() != part.cases.len() {
            bail!(
                "{csv_path:?} has {} rows but its sidecar covers {} cases — \
                 shard output is inconsistent",
                table.rows.len(),
                part.cases.len()
            );
        }
        match &header {
            None => header = Some(table.header.clone()),
            Some(h) if *h != table.header => bail!(
                "experiment '{id}': shard CSV headers disagree \
                 ({h:?} vs {:?}) — shards must come from the same build",
                table.header
            ),
            Some(_) => {}
        }
        for (case, row) in part.cases.iter().zip(table.rows) {
            rows.push((*case, row));
        }
        if let Some(t) = telemetry.as_mut() {
            t.merge(part).with_context(|| format!("merging {dir:?}"))?;
        } else {
            telemetry = Some(part.clone());
        }
        let meta_path = dir.join("meta.json");
        if meta_path.exists() {
            let text = std::fs::read_to_string(&meta_path)?;
            metas.push(
                parse(&text).map_err(|e| anyhow::anyhow!("parsing {meta_path:?}: {e}"))?,
            );
        }
    }
    let telemetry = telemetry.expect("at least one sidecar");
    rows.sort_by_key(|(case, _)| *case);

    // Write the merged tree.
    std::fs::create_dir_all(out)?;
    let table = Table {
        header: header.expect("at least one shard CSV"),
        rows: rows.into_iter().map(|(_, row)| row).collect(),
    };
    let n_rows = table.rows.len();
    table.save(out.join(format!("{id}.csv")))?;
    if !metas.is_empty() {
        let merged_meta = merge_metas(&metas)?;
        std::fs::write(out.join("meta.json"), merged_meta.pretty())?;
    }
    telemetry.save(out)?;

    // Copy per-case extras (fleet timelines, figures) from every shard.
    for (dir, _) in &parts {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or_default()
                .to_string();
            if name == format!("{id}.csv")
                || name == "meta.json"
                || name == sidecar::FILENAME
            {
                continue;
            }
            copy_checked(&path, &out.join(&name), id)?;
        }
    }

    let complete = telemetry.is_complete();
    if !complete {
        eprintln!(
            "warning: experiment '{id}' merged from {}/{} cases — \
             some shards are missing; the CSV is a partial grid",
            telemetry.cases.len(),
            telemetry.total_cases
        );
    }
    Ok(MergedExperiment {
        id: id.to_string(),
        shards: parts.len(),
        rows: n_rows,
        complete,
    })
}

/// Merge shard `meta.json` documents: the `sweep` object merges with
/// per-field semantics ([`merge_sweep_values`]); every other key
/// unions, first (lowest-index) shard wins on conflicting values —
/// experiment-constant keys (`figure`, `paper_claim`, configs) agree
/// anyway, and per-shard keys (autoscale's `decisions_<policy>`) are
/// disjoint. Everything flowing through here came out of parsed (i.e.
/// arbitrarily shaped) shard files, so mutation goes through the
/// non-panicking `try_set`.
fn merge_metas(metas: &[Value]) -> Result<Value> {
    let mut out = Value::obj();
    // First-wins union of plain keys.
    for meta in metas {
        if let Value::Obj(m) = meta {
            for (k, v) in m {
                if k == "sweep" {
                    continue;
                }
                if out.get(k).is_none() {
                    out.try_set(k, v.clone())?;
                }
            }
        }
    }
    let sweeps: Vec<&Value> = metas.iter().filter_map(|m| m.get("sweep")).collect();
    if !sweeps.is_empty() {
        out.try_set("sweep", merge_sweep_values(&sweeps)?)?;
    }
    Ok(out)
}

/// Merge `meta.json`'s `sweep` objects with the correct per-field
/// semantics — **sum** for work counters (`cases`, `total_stages`, the
/// `oracle_cache` counters, with `hit_rate` recomputed), **max** for
/// per-process peaks (`peak_resident_bins`, `peak_live_requests`,
/// `jobs`), **or** for flags (`materialized`). Anything else would be
/// wrong in a way that is easy to miss: naively taking the last
/// shard's object silently reports one machine's peaks and one
/// machine's oracle counters as if they covered the whole sweep.
/// The per-shard `shard` label is dropped — the merged object speaks
/// for the union.
pub fn merge_sweep_values(sweeps: &[&Value]) -> Result<Value> {
    let mut out = Value::obj();
    let sum_u64 = |key: &str, objs: &[&Value]| -> Option<u64> {
        let vals: Vec<u64> = objs.iter().filter_map(|v| v.get(key)?.as_u64()).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum())
        }
    };
    let max_u64 = |key: &str, objs: &[&Value]| -> Option<u64> {
        let vals: Vec<u64> = objs.iter().filter_map(|v| v.get(key)?.as_u64()).collect();
        if vals.is_empty() {
            None
        } else {
            vals.iter().max().copied()
        }
    };
    for (key, val) in [
        ("cases", sum_u64("cases", sweeps)),
        ("total_stages", sum_u64("total_stages", sweeps)),
        ("jobs", max_u64("jobs", sweeps)),
        ("peak_resident_bins", max_u64("peak_resident_bins", sweeps)),
        ("peak_live_requests", max_u64("peak_live_requests", sweeps)),
    ] {
        if let Some(v) = val {
            out.try_set(key, v)?;
        }
    }
    if sweeps
        .iter()
        .any(|s| s.get("materialized").and_then(|v| v.as_bool()).unwrap_or(false))
    {
        out.try_set("materialized", true)?;
    }
    let oracles: Vec<&Value> = sweeps.iter().filter_map(|s| s.get("oracle_cache")).collect();
    if !oracles.is_empty() {
        let mut oc = Value::obj();
        let calls = sum_u64("calls", &oracles).unwrap_or(0);
        let hits = sum_u64("hits", &oracles).unwrap_or(0);
        oc.try_set("calls", calls)?
            .try_set("hits", hits)?
            .try_set("resets", sum_u64("resets", &oracles).unwrap_or(0))?
            .try_set(
                "surface_builds",
                sum_u64("surface_builds", &oracles).unwrap_or(0),
            )?
            .try_set(
                "hit_rate",
                if calls == 0 { 0.0 } else { hits as f64 / calls as f64 },
            )?;
        out.try_set("oracle_cache", oc)?;
    }
    Ok(out)
}

/// Recursive copy of a per-case extra (file or directory) with the
/// disjointness guard: shards own disjoint cases, so a same-named
/// file with *different* content coming from two shards is a protocol
/// error, never a silent overwrite. Identical content is idempotent.
fn copy_checked(src: &Path, dst: &Path, id: &str) -> Result<()> {
    if src.is_dir() {
        std::fs::create_dir_all(dst)?;
        for entry in std::fs::read_dir(src).with_context(|| format!("listing {src:?}"))? {
            let path = entry?.path();
            let to = dst.join(path.file_name().expect("read_dir yields named entries"));
            copy_checked(&path, &to, id)?;
        }
        return Ok(());
    }
    let content = std::fs::read(src)?;
    if dst.exists() && std::fs::read(dst)? != content {
        bail!(
            "experiment '{id}': shards disagree on extra file {dst:?} — \
             shard case sets were not disjoint?"
        );
    }
    std::fs::write(dst, content).with_context(|| format!("copying {src:?} -> {dst:?}"))
}

/// Recursive directory copy (used for sidecar-less experiment dirs,
/// which by construction have exactly one source shard).
fn copy_dir(src: &Path, dst: &Path) -> Result<()> {
    std::fs::create_dir_all(dst)?;
    for entry in std::fs::read_dir(src).with_context(|| format!("listing {src:?}"))? {
        let path = entry?.path();
        let to = dst.join(path.file_name().expect("read_dir yields named entries"));
        if path.is_dir() {
            copy_dir(&path, &to)?;
        } else {
            std::fs::copy(&path, &to)
                .with_context(|| format!("copying {path:?} -> {to:?}"))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep_obj(
        cases: u64,
        stages: u64,
        jobs: u64,
        peak_bins: u64,
        calls: u64,
        hits: u64,
    ) -> Value {
        let mut oc = Value::obj();
        oc.set("calls", calls)
            .set("hits", hits)
            .set("resets", 1u64)
            .set("hit_rate", 0.0);
        let mut v = Value::obj();
        v.set("cases", cases)
            .set("total_stages", stages)
            .set("jobs", jobs)
            .set("peak_resident_bins", peak_bins)
            .set("peak_live_requests", peak_bins * 2)
            .set("oracle_cache", oc)
            .set("shard", "0/2");
        v
    }

    /// The satellite bugfix pinned down: merged sweep stats must use
    /// sum semantics for work counters and max semantics for
    /// per-process peaks — not last-shard-wins for either.
    #[test]
    fn sweep_meta_merges_with_max_vs_sum_semantics() {
        let a = sweep_obj(5, 1000, 8, 40, 600, 500);
        let b = sweep_obj(4, 800, 4, 70, 400, 100);
        let m = merge_sweep_values(&[&a, &b]).unwrap();
        assert_eq!(m.get("cases").unwrap().as_u64(), Some(9)); // sum
        assert_eq!(m.get("total_stages").unwrap().as_u64(), Some(1800)); // sum
        assert_eq!(m.get("jobs").unwrap().as_u64(), Some(8)); // max
        assert_eq!(m.get("peak_resident_bins").unwrap().as_u64(), Some(70)); // max
        assert_eq!(m.get("peak_live_requests").unwrap().as_u64(), Some(140)); // max
        let oc = m.get("oracle_cache").unwrap();
        assert_eq!(oc.get("calls").unwrap().as_u64(), Some(1000)); // sum
        assert_eq!(oc.get("hits").unwrap().as_u64(), Some(600)); // sum
        assert_eq!(oc.get("resets").unwrap().as_u64(), Some(2)); // sum
        // hit_rate recomputed from the merged counters, not averaged.
        assert!((oc.get("hit_rate").unwrap().as_f64().unwrap() - 0.6).abs() < 1e-12);
        // The per-shard label does not survive the merge.
        assert!(m.get("shard").is_none());
        assert!(m.get("materialized").is_none());
    }

    #[test]
    fn metas_union_first_wins_and_sweep_is_special() {
        let mut a = Value::obj();
        a.set("figure", "fig2")
            .set("decisions_static", 10u64)
            .set("sweep", sweep_obj(2, 10, 2, 5, 10, 5));
        let mut b = Value::obj();
        b.set("figure", "fig2")
            .set("decisions_reactive", 12u64)
            .set("sweep", sweep_obj(2, 12, 3, 9, 10, 5));
        let m = merge_metas(&[a, b]).unwrap();
        assert_eq!(m.get("figure").unwrap().as_str(), Some("fig2"));
        // Disjoint per-shard keys union.
        assert_eq!(m.get("decisions_static").unwrap().as_u64(), Some(10));
        assert_eq!(m.get("decisions_reactive").unwrap().as_u64(), Some(12));
        assert_eq!(m.at(&["sweep", "cases"]).unwrap().as_u64(), Some(4));
        assert_eq!(m.at(&["sweep", "jobs"]).unwrap().as_u64(), Some(3));
    }

    #[test]
    fn missing_and_empty_dirs_error_clearly() {
        let tmp = std::env::temp_dir().join("vidur_energy_merge_err");
        std::fs::remove_dir_all(&tmp).ok();
        std::fs::create_dir_all(tmp.join("empty")).unwrap();
        assert!(merge_shard_dirs(&[], &tmp.join("out")).is_err());
        assert!(merge_shard_dirs(&[tmp.join("nope")], &tmp.join("out")).is_err());
        assert!(merge_shard_dirs(&[tmp.join("empty")], &tmp.join("out")).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
