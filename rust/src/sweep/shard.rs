//! Cross-machine sweep sharding (DESIGN.md §9): deterministic
//! partitioning of an experiment's case grid across hosts.
//!
//! A [`ShardSpec`] `k/N` owns every case whose **global** case index
//! `i` satisfies `i % N == k`. Ownership is a pure function of the
//! index — and each case's RNG seed already is too
//! ([`crate::util::rng::case_seed`]) — so running a grid sharded
//! changes *which process* runs a case, never the case's results.
//! That is the whole determinism argument behind `repro merge`
//! reproducing byte-identical CSVs: shard outputs are the same rows
//! the unsharded run would have written, just distributed.
//!
//! The active shard is process-global (set once from the CLI's
//! `--shard k/N`, like the `--jobs` worker count), so experiment
//! regenerators pick it up without signature churn.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// One shard of an `N`-way partition: this process runs the cases with
/// `index % total == index_of_this_shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Zero-based shard index, `< total`.
    pub index: u32,
    /// Total number of shards, ≥ 1.
    pub total: u32,
}

impl ShardSpec {
    pub fn new(index: u32, total: u32) -> Result<ShardSpec> {
        if total == 0 {
            bail!("shard total must be ≥ 1");
        }
        if index >= total {
            bail!("shard index {index} out of range for {total} shards (indices are 0-based)");
        }
        Ok(ShardSpec { index, total })
    }

    /// Parse the CLI form `k/N` (zero-based `k`, e.g. `0/4` … `3/4`).
    pub fn parse(s: &str) -> Result<ShardSpec> {
        let Some((k, n)) = s.split_once('/') else {
            bail!("--shard expects k/N (e.g. 0/4), got '{s}'");
        };
        let index: u32 = k
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad shard index '{k}' in '{s}'"))?;
        let total: u32 = n
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad shard count '{n}' in '{s}'"))?;
        ShardSpec::new(index, total)
    }

    /// Does this shard own global case index `i`?
    pub fn owns(&self, case_index: usize) -> bool {
        case_index % self.total as usize == self.index as usize
    }

    /// How many of `total_cases` this shard owns.
    pub fn count_owned(&self, total_cases: usize) -> usize {
        (0..total_cases).filter(|&i| self.owns(i)).count()
    }

    /// The CLI / sidecar form `k/N`.
    pub fn label(&self) -> String {
        format!("{}/{}", self.index, self.total)
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.total)
    }
}

/// Process-wide active shard, packed into one atomic: 0 = unsharded,
/// else `(total << 32) | (index + 1)` (total ≥ 1 makes the high word
/// nonzero). Mirrors the `DEFAULT_JOBS` pattern next door.
static ACTIVE_SHARD: AtomicU64 = AtomicU64::new(0);

/// Set (or clear, with `None`) the process-wide shard — the CLI's
/// `--shard k/N`.
pub fn set_shard(shard: Option<ShardSpec>) {
    let packed = match shard {
        None => 0,
        Some(s) => ((s.total as u64) << 32) | (s.index as u64 + 1),
    };
    ACTIVE_SHARD.store(packed, Ordering::Relaxed);
}

/// The process-wide active shard, if any.
pub fn active_shard() -> Option<ShardSpec> {
    match ACTIVE_SHARD.load(Ordering::Relaxed) {
        0 => None,
        packed => Some(ShardSpec {
            index: (packed & 0xFFFF_FFFF) as u32 - 1,
            total: (packed >> 32) as u32,
        }),
    }
}

/// Partition a case list by the process-wide active shard: returns the
/// shard (if any) and the `(global index, case)` pairs this process
/// owns, in ascending index order — the shared front half of every
/// shardable sweep (`experiments::common::run_grid`, the autoscale
/// policy sweep). With no active shard, every case is owned.
pub fn shard_owned<T>(cases: Vec<T>) -> (Option<ShardSpec>, Vec<(usize, T)>) {
    let shard = active_shard();
    let total = cases.len();
    let owned: Vec<(usize, T)> = cases
        .into_iter()
        .enumerate()
        .filter(|(i, _)| shard.map(|s| s.owns(*i)).unwrap_or(true))
        .collect();
    if let Some(s) = shard {
        eprintln!("shard {s}: running {} of {total} cases", owned.len());
    }
    (shard, owned)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_ownership_partition_the_grid() {
        let shards: Vec<ShardSpec> =
            (0..4).map(|k| ShardSpec::parse(&format!("{k}/4")).unwrap()).collect();
        for i in 0..100usize {
            let owners: Vec<u32> = shards
                .iter()
                .filter(|s| s.owns(i))
                .map(|s| s.index)
                .collect();
            assert_eq!(owners.len(), 1, "case {i} owned by {owners:?}");
            assert_eq!(owners[0] as usize, i % 4);
        }
        assert_eq!(shards[1].count_owned(10), 3); // 1, 5, 9
        assert_eq!(shards[1].label(), "1/4");
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(ShardSpec::parse("4/4").is_err()); // 0-based
        assert!(ShardSpec::parse("0/0").is_err());
        assert!(ShardSpec::parse("1").is_err());
        assert!(ShardSpec::parse("a/4").is_err());
        assert!(ShardSpec::parse("1/b").is_err());
        assert!(ShardSpec::parse("2/4").is_ok());
    }

    #[test]
    fn single_shard_owns_everything() {
        let s = ShardSpec::parse("0/1").unwrap();
        assert!((0..50).all(|i| s.owns(i)));
        assert_eq!(s.count_owned(50), 50);
    }

    #[test]
    fn shard_global_roundtrips() {
        // Sequential set/get in one test: the static is process-global.
        assert_eq!(active_shard(), None);
        set_shard(Some(ShardSpec::new(2, 5).unwrap()));
        assert_eq!(active_shard(), Some(ShardSpec { index: 2, total: 5 }));
        set_shard(None);
        assert_eq!(active_shard(), None);
    }
}
