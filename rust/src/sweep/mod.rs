//! Parallel sweep execution (DESIGN.md §7).
//!
//! Every paper experiment is a grid of independent simulation cases;
//! [`SweepExecutor`] runs such a case list across `N` worker threads
//! with a lock-free work queue over [`std::thread::scope`] — no
//! external dependencies, no thread pool kept alive between sweeps.
//!
//! Design constraints, and how they are met:
//! * **`!Send` cost oracles.** PJRT clients are thread-affine
//!   ([`crate::exec::StageCostModel`] is deliberately not `Send`), so
//!   cases never share an oracle across threads: each case builds its
//!   model on the worker that claimed it, and the expensive compiled
//!   artifact is reused per worker through the `runtime::pjrt`
//!   thread-local executable cache (one compile per worker, not one
//!   per case). Keeping the memo cache per *case* rather than per
//!   worker makes the reported oracle statistics deterministic —
//!   independent of which worker ran which case.
//! * **Determinism.** Results are returned in case order regardless of
//!   completion order, each case derives its RNG seed from its index
//!   ([`crate::util::rng::case_seed`]) rather than shared sequential
//!   state, and errors surface lowest-case-index first — so `--jobs 1`
//!   and `--jobs 8` produce byte-identical experiment CSVs (asserted
//!   in `tests/sweep_determinism.rs`).
//! * **Panic safety.** A panicking case propagates out of
//!   [`std::thread::scope`] and fails the sweep, never silently drops
//!   a case.
//!
//! Beyond one machine (DESIGN.md §9): the [`shard`] module partitions
//! a case grid across hosts (`repro experiment --shard k/N` owns the
//! cases with `index % N == k`), and the [`merge`] module recombines
//! the per-shard output directories — CSVs byte-identical to an
//! unsharded run, exact counters summed, latency sketches merged
//! within the documented rank bound. The same case-index seeding that
//! makes `--jobs` determinism hold makes shard assignment
//! result-invariant, so adding hosts is purely a wall-clock decision.

pub mod merge;
pub mod shard;

pub use merge::{merge_shard_dirs, MergedExperiment};
pub use shard::{active_shard, set_shard, ShardSpec};

use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide default worker count: 0 = auto (`available_parallelism`).
/// Set once from the CLI's `--jobs` flag; experiment regenerators pick
/// it up through [`SweepExecutor::with_default_jobs`] so their public
/// signatures stay stable.
static DEFAULT_JOBS: AtomicUsize = AtomicUsize::new(0);

/// Configure the process-default worker count (the CLI's `--jobs N`).
/// 0 restores auto-detection.
pub fn set_default_jobs(jobs: usize) {
    DEFAULT_JOBS.store(jobs, Ordering::Relaxed);
}

/// The effective default worker count: the configured `--jobs`, or the
/// machine's available parallelism.
pub fn default_jobs() -> usize {
    match DEFAULT_JOBS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
}

/// A work-queue executor for embarrassingly parallel sweep cases.
#[derive(Debug, Clone, Copy)]
pub struct SweepExecutor {
    jobs: usize,
}

impl SweepExecutor {
    /// Executor with an explicit worker count (floored at 1).
    pub fn new(jobs: usize) -> Self {
        SweepExecutor {
            jobs: jobs.max(1),
        }
    }

    /// Executor honouring the process default (`--jobs`, else
    /// `available_parallelism`).
    pub fn with_default_jobs() -> Self {
        SweepExecutor::new(default_jobs())
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run `f` over every case, fanning out across the worker threads,
    /// and return the results **in case order** (independent of
    /// completion order). `f` receives the case index and the case;
    /// with one worker (or one case) everything runs inline on the
    /// calling thread — no spawn, identical to the serial code path.
    ///
    /// If any case fails, workers stop claiming new cases (cases
    /// already in flight finish), and the error of the lowest-index
    /// failing case is returned — the same error the serial path stops
    /// at, deterministic regardless of scheduling.
    ///
    /// ```
    /// use vidur_energy::sweep::SweepExecutor;
    ///
    /// // A toy grid: squares of 0..8, computed on 4 workers.
    /// let out = SweepExecutor::new(4)
    ///     .run((0u64..8).collect(), |i, &c| {
    ///         assert_eq!(i as u64, c); // f sees the case index
    ///         Ok(c * c)
    ///     })
    ///     .unwrap();
    /// // Results come back in case order, whatever order workers
    /// // finished in.
    /// assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    /// ```
    pub fn run<T, R, F>(&self, cases: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: Sync + Send,
        R: Send,
        F: Fn(usize, &T) -> Result<R> + Sync,
    {
        let n = cases.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let jobs = self.jobs.min(n);
        if jobs == 1 {
            return cases
                .iter()
                .enumerate()
                .map(|(i, case)| f(i, case))
                .collect();
        }

        let next = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let collected: Mutex<Vec<(usize, Result<R>)>> =
            Mutex::new(Vec::with_capacity(n));

        /// Raises the shared abort flag if its worker unwinds, so a
        /// panicking case (like an Err one) stops the other workers
        /// from claiming further cases while the panic propagates out
        /// of the scope.
        struct AbortOnPanic<'a>(&'a AtomicBool);
        impl Drop for AbortOnPanic<'_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.store(true, Ordering::Relaxed);
                }
            }
        }

        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| {
                    let _abort_guard = AbortOnPanic(&failed);
                    // Buffer worker-locally; one lock per worker, not
                    // one per case.
                    let mut local: Vec<(usize, Result<R>)> = Vec::new();
                    loop {
                        // After any failure, stop claiming new cases
                        // (in-flight cases finish) — matching the
                        // serial path's stop-at-first-error.
                        if failed.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = f(i, &cases[i]);
                        if r.is_err() {
                            failed.store(true, Ordering::Relaxed);
                        }
                        local.push((i, r));
                    }
                    collected.lock().unwrap().extend(local);
                });
            }
        });

        let mut slots: Vec<Option<Result<R>>> = (0..n).map(|_| None).collect();
        for (i, r) in collected.into_inner().unwrap() {
            slots[i] = Some(r);
        }
        // Claims are monotone in case index and every claimed case ran,
        // so unclaimed slots form a suffix strictly above the lowest
        // failing index — walking in order surfaces that error (the
        // same one the serial path would stop at) before any gap.
        let mut out = Vec::with_capacity(n);
        for slot in slots {
            match slot {
                Some(r) => out.push(r?),
                None => unreachable!("unclaimed sweep case without a prior error"),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_case_order_regardless_of_jobs() {
        let cases: Vec<u64> = (0..64).collect();
        for jobs in [1, 2, 8] {
            let out = SweepExecutor::new(jobs)
                .run(cases.clone(), |i, &c| {
                    // Uneven work so completion order differs from
                    // case order.
                    let spin = (c % 7) * 1000;
                    let mut acc = 0u64;
                    for k in 0..spin {
                        acc = acc.wrapping_add(k);
                    }
                    std::hint::black_box(acc);
                    Ok(i as u64 * 10 + c)
                })
                .unwrap();
            let want: Vec<u64> = (0..64).map(|c| c * 11).collect();
            assert_eq!(out, want, "jobs={jobs}");
        }
    }

    #[test]
    fn lowest_index_error_wins() {
        let cases: Vec<u64> = (0..32).collect();
        let err = SweepExecutor::new(4)
            .run(cases, |i, _| {
                if i == 5 || i == 20 {
                    anyhow::bail!("case {i} failed")
                }
                Ok(i)
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "case 5 failed");
    }

    #[test]
    fn failure_stops_claiming_new_cases() {
        let ran = AtomicUsize::new(0);
        let cases: Vec<u64> = (0..1000).collect();
        let err = SweepExecutor::new(2)
            .run(cases, |i, _| {
                ran.fetch_add(1, Ordering::Relaxed);
                if i == 0 {
                    anyhow::bail!("boom")
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
                Ok(i)
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "boom");
        assert!(
            ran.load(Ordering::Relaxed) < 1000,
            "workers kept claiming cases after the failure"
        );
    }

    #[test]
    fn empty_and_single_case() {
        let ex = SweepExecutor::new(8);
        let out: Vec<u64> = ex.run(Vec::<u64>::new(), |_, &c| Ok(c)).unwrap();
        assert!(out.is_empty());
        let out = ex.run(vec![7u64], |i, &c| Ok(c + i as u64)).unwrap();
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn jobs_floor_and_default() {
        assert_eq!(SweepExecutor::new(0).jobs(), 1);
        set_default_jobs(3);
        assert_eq!(SweepExecutor::with_default_jobs().jobs(), 3);
        set_default_jobs(0);
        assert!(default_jobs() >= 1);
    }
}
