//! # vidur-energy
//!
//! A Rust + JAX + Pallas reproduction of *"Quantifying the Energy
//! Consumption and Carbon Emissions of LLM Inference via Simulations"*
//! (Özcan et al., CS.DC 2025).
//!
//! The crate implements, from scratch, both systems the paper couples:
//!
//! * a **Vidur-like high-fidelity LLM inference simulator** — request
//!   workloads, vLLM-style continuous batching, KV-cache management,
//!   TP/PP cluster topologies, and a roofline execution model whose
//!   per-batch-stage hot path is evaluated through an AOT-compiled
//!   JAX/Pallas oracle loaded via PJRT ([`runtime`]);
//! * a **Vessim-like grid co-simulator** — solar/carbon-intensity
//!   signals, a rate- and SoC-limited battery, microgrid power balance,
//!   and carbon-aware controllers ([`cosim`]);
//!
//! plus the paper's contribution proper: the MFU→power GPU model
//! ([`power`]), stage-level energy/carbon accounting ([`energy`]), and
//! the Eq. 5 signal pipeline bridging the two simulators ([`pipeline`]);
//! and, on top of both, a carbon-aware autoscaling subsystem
//! ([`autoscale`]) that grows and shrinks the replica fleet against
//! load telemetry and grid signals (DESIGN.md §6).
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! [`experiments`] for regenerators of every table and figure in the
//! paper's evaluation.

pub mod util;
pub mod config;
pub mod workload;
pub mod cluster;
pub mod scheduler;
pub mod autoscale;
pub mod exec;
pub mod power;
pub mod energy;
pub mod telemetry;
pub mod sim;
pub mod sweep;
pub mod grid;
pub mod battery;
pub mod cosim;
pub mod pipeline;
pub mod report;
pub mod experiments;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod fleet;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
