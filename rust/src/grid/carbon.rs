//! Synthetic marginal carbon-intensity trace (WattTime CAISO-North
//! substitute, DESIGN.md §5).
//!
//! CAISO's marginal operating emissions rate follows a "duck curve":
//! low midday (solar pushes gas off the margin), high evening ramp,
//! moderate overnight. The model is a mean level plus two harmonics
//! and noise, calibrated so a multi-day average lands near the paper's
//! observed 418.2 gCO₂/kWh with excursions straddling the 100/200
//! g thresholds used by the carbon-aware controllers.

use crate::grid::signal::HistoricalSignal;
use crate::util::rng::Rng;
use crate::util::timeseries::{Interp, TimeSeries};

#[derive(Debug, Clone)]
pub struct CarbonIntensityTrace {
    /// Long-run mean, gCO₂/kWh (paper's run averaged 418.2).
    pub mean: f64,
    /// Amplitude of the diurnal swing, g.
    pub diurnal_amplitude: f64,
    /// Evening-ramp bump amplitude, g.
    pub ramp_amplitude: f64,
    /// Gaussian noise std, g.
    pub noise_std: f64,
    pub seed: u64,
}

impl Default for CarbonIntensityTrace {
    fn default() -> Self {
        CarbonIntensityTrace {
            mean: 418.2,
            diurnal_amplitude: 180.0,
            ramp_amplitude: 90.0,
            noise_std: 18.0,
            seed: 0xC02,
        }
    }
}

impl CarbonIntensityTrace {
    /// Deterministic duck-curve component at absolute sim time. A
    /// constant correction (the analytic 24-h mean of the shape terms)
    /// keeps the long-run average at `self.mean`.
    pub fn base_at(&self, t_s: f64) -> f64 {
        let h = (t_s / 3600.0).rem_euclid(24.0);
        // Midday dip centred at 13:00 (σ = 3.2 h).
        let dip = -self.diurnal_amplitude
            * (-((h - 13.0) * (h - 13.0)) / (2.0 * 3.2 * 3.2)).exp();
        // Evening ramp centred at 19:30 (σ = 2 h).
        let ramp = self.ramp_amplitude
            * (-((h - 19.5) * (h - 19.5)) / (2.0 * 2.0 * 2.0)).exp();
        // Mild overnight elevation.
        let night = 30.0 * ((std::f64::consts::PI * (h - 3.0) / 12.0).cos()).max(0.0);
        // Analytic means: gaussian integrals σ√(2π)/24, cosine half-wave.
        let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
        let correction = self.diurnal_amplitude * 3.2 * sqrt_2pi / 24.0
            - self.ramp_amplitude * 2.0 * sqrt_2pi / 24.0
            - 30.0 * 12.0 * (2.0 / std::f64::consts::PI) / 24.0;
        (self.mean + correction + dip + ramp + night).max(40.0)
    }

    /// Generate a 1-minute trace with noise.
    pub fn trace(&self, start_s: f64, n_minutes: usize) -> HistoricalSignal {
        let mut rng = Rng::new(self.seed);
        let mut t = Vec::with_capacity(n_minutes);
        let mut v = Vec::with_capacity(n_minutes);
        let mut walk = 0.0f64;
        for i in 0..n_minutes {
            let ts = start_s + i as f64 * 60.0;
            walk = (walk + rng.normal(0.0, self.noise_std / 8.0)).clamp(-60.0, 60.0);
            let ci = (self.base_at(ts) + walk + rng.normal(0.0, self.noise_std * 0.3))
                .max(40.0);
            t.push(ts);
            v.push(ci);
        }
        HistoricalSignal::new("carbon_intensity", TimeSeries::new(t, v), Interp::Cubic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_day_mean_near_target() {
        let c = CarbonIntensityTrace::default();
        let tr = c.trace(0.0, 2880);
        let mean: f64 =
            tr.series().values().iter().sum::<f64>() / tr.series().values().len() as f64;
        assert!(
            (mean - 418.2).abs() < 40.0,
            "mean {mean} too far from the paper's 418.2"
        );
    }

    #[test]
    fn duck_curve_shape() {
        let c = CarbonIntensityTrace::default();
        let midday = c.base_at(13.0 * 3600.0);
        let evening = c.base_at(19.5 * 3600.0);
        let night = c.base_at(3.0 * 3600.0);
        assert!(midday < night, "midday {midday} !< night {night}");
        assert!(evening > night, "evening {evening} !> night {night}");
    }

    #[test]
    fn always_positive() {
        let c = CarbonIntensityTrace::default();
        let tr = c.trace(0.0, 1440);
        assert!(tr.series().values().iter().all(|&v| v >= 40.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let c = CarbonIntensityTrace::default();
        let a = c.trace(0.0, 100);
        let b = c.trace(0.0, 100);
        assert_eq!(a.series().values(), b.series().values());
    }
}
