//! Synthetic solar irradiance (Solcast substitute, DESIGN.md §5).
//!
//! Clear-sky diurnal model: power follows the sine of solar elevation
//! between sunrise and sunset, scaled by installed capacity, with
//! day-level weather attenuation and minute-level cloud noise — enough
//! structure to reproduce the paper's midday-peaking generation that
//! partially offsets the workload (Fig. 6).

use crate::grid::signal::HistoricalSignal;
use crate::util::rng::Rng;
use crate::util::timeseries::{Interp, TimeSeries};

/// Parameterized diurnal solar generator.
#[derive(Debug, Clone)]
pub struct SolarModel {
    /// Installed capacity, W (paper Table 1b: 600 W).
    pub capacity_w: f64,
    /// Sunrise hour (local sim time).
    pub sunrise_h: f64,
    /// Sunset hour.
    pub sunset_h: f64,
    /// Day-level clear-sky fraction in [0,1] (weather).
    pub clearness: f64,
    /// Std-dev of minute-level multiplicative cloud noise.
    pub noise_std: f64,
    pub seed: u64,
}

impl Default for SolarModel {
    fn default() -> Self {
        SolarModel {
            capacity_w: 600.0,
            sunrise_h: 6.0,
            sunset_h: 20.0, // CAISO summer (the paper applies Jun–Jul traces)
            clearness: 0.85,
            noise_std: 0.08,
            seed: 0x501AB,
        }
    }
}

impl SolarModel {
    /// Deterministic clear-sky power at an absolute sim time (seconds).
    pub fn clear_sky_w(&self, t_s: f64) -> f64 {
        let hour = (t_s / 3600.0).rem_euclid(24.0);
        if hour < self.sunrise_h || hour > self.sunset_h {
            return 0.0;
        }
        let daylight = self.sunset_h - self.sunrise_h;
        let x = (hour - self.sunrise_h) / daylight; // 0..1
        let elevation = (std::f64::consts::PI * x).sin();
        self.capacity_w * self.clearness * elevation
    }

    /// Generate a 1-minute-resolution trace of `n_minutes` starting at
    /// `start_s`, with stochastic cloud noise.
    pub fn trace(&self, start_s: f64, n_minutes: usize) -> HistoricalSignal {
        let mut rng = Rng::new(self.seed);
        let mut t = Vec::with_capacity(n_minutes);
        let mut v = Vec::with_capacity(n_minutes);
        // Slow cloud bank factor (random walk) + fast noise.
        let mut cloud = 1.0f64;
        for i in 0..n_minutes {
            let ts = start_s + i as f64 * 60.0;
            cloud = (cloud + rng.normal(0.0, 0.02)).clamp(0.55, 1.0);
            let fast = (1.0 + rng.normal(0.0, self.noise_std)).clamp(0.0, 1.3);
            let p = (self.clear_sky_w(ts) * cloud * fast).max(0.0);
            t.push(ts);
            v.push(p.min(self.capacity_w));
        }
        HistoricalSignal::new("solar", TimeSeries::new(t, v), Interp::Cubic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn night_is_dark() {
        let m = SolarModel::default();
        assert_eq!(m.clear_sky_w(0.0), 0.0); // midnight
        assert_eq!(m.clear_sky_w(3.0 * 3600.0), 0.0);
        assert_eq!(m.clear_sky_w(22.0 * 3600.0), 0.0);
    }

    #[test]
    fn midday_peaks_near_capacity() {
        let m = SolarModel::default();
        let noon = m.clear_sky_w(13.0 * 3600.0);
        assert!(noon > 0.8 * m.capacity_w * m.clearness, "noon {noon}");
        // Peak of the day is the maximum.
        let mut max = 0.0f64;
        for h in 0..24 {
            max = max.max(m.clear_sky_w(h as f64 * 3600.0));
        }
        assert!(noon >= 0.95 * max);
    }

    #[test]
    fn second_day_repeats_diurnally() {
        let m = SolarModel::default();
        let a = m.clear_sky_w(10.0 * 3600.0);
        let b = m.clear_sky_w((24.0 + 10.0) * 3600.0);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn trace_is_bounded_and_deterministic() {
        let m = SolarModel::default();
        let tr1 = m.trace(0.0, 2880); // two days
        let tr2 = m.trace(0.0, 2880);
        for (i, t) in tr1.series().times().iter().enumerate() {
            let v1 = tr1.series().values()[i];
            let v2 = tr2.series().values()[i];
            assert_eq!(v1, v2, "nondeterministic at {t}");
            assert!((0.0..=600.0).contains(&v1));
        }
        // Daily energy is positive and plausible (several kWh-minutes).
        let day_wh: f64 = tr1.series().values()[..1440].iter().sum::<f64>() / 60.0;
        assert!(day_wh > 2000.0 && day_wh < 6000.0, "day {day_wh} Wh");
    }
}
