//! Vessim-style `HistoricalSignal`: a time-stamped trace with
//! configurable interpolation, loadable from CSV (for real Solcast /
//! WattTime data) or built from synthetic models.

use crate::util::csv::Table;
use crate::util::timeseries::{Interp, TimeSeries};
use anyhow::{Context, Result};
use std::path::Path;

/// A named signal over simulation time.
#[derive(Debug, Clone)]
pub struct HistoricalSignal {
    pub name: String,
    series: TimeSeries,
    interp: Interp,
}

impl HistoricalSignal {
    pub fn new(name: &str, series: TimeSeries, interp: Interp) -> Self {
        HistoricalSignal {
            name: name.to_string(),
            series,
            interp,
        }
    }

    /// Load from a 2-column CSV (`t_s,value`). The paper resamples
    /// environmental datasets with cubic interpolation; pass
    /// `Interp::Cubic` to mirror that.
    pub fn from_csv(name: &str, path: impl AsRef<Path>, interp: Interp) -> Result<Self> {
        let t = Table::load(&path)?;
        let ts = t.f64_col("t_s").context("signal csv needs 't_s'")?;
        let vs = t.f64_col("value").context("signal csv needs 'value'")?;
        Ok(Self::new(name, TimeSeries::new(ts, vs), interp))
    }

    pub fn at(&self, t_s: f64) -> f64 {
        self.series.at(t_s, self.interp)
    }

    /// Sample onto a fixed grid (the co-simulation step).
    pub fn sample_grid(&self, start_s: f64, n: usize, dt_s: f64) -> Vec<f64> {
        (0..n).map(|i| self.at(start_s + i as f64 * dt_s)).collect()
    }

    pub fn series(&self) -> &TimeSeries {
        &self.series
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["t_s", "value"]);
        for i in 0..10 {
            t.push(&[(i * 60) as f64, (i as f64) * 1.5]);
        }
        let dir = std::env::temp_dir().join("vidur_energy_signal");
        let p = dir.join("sig.csv");
        t.save(&p).unwrap();
        let s = HistoricalSignal::from_csv("test", &p, Interp::Linear).unwrap();
        assert_eq!(s.at(60.0), 1.5);
        assert!((s.at(90.0) - 2.25).abs() < 1e-12);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn grid_sampling() {
        let ts = TimeSeries::new(vec![0.0, 100.0], vec![0.0, 100.0]);
        let s = HistoricalSignal::new("ramp", ts, Interp::Linear);
        let g = s.sample_grid(0.0, 5, 25.0);
        assert_eq!(g, vec![0.0, 25.0, 50.0, 75.0, 100.0]);
    }
}
