//! Grid signals: historical/synthetic solar irradiance and carbon
//! intensity (the paper's Solcast + WattTime substitutes).

pub mod signal;
pub mod solar;
pub mod carbon;
pub mod datasets;

pub use carbon::CarbonIntensityTrace;
pub use signal::HistoricalSignal;
pub use solar::SolarModel;
