//! Importers for the real environmental datasets the paper uses, so
//! synthetic substitutes can be swapped out when the data is available:
//!
//! * **WattTime** marginal-operating-emissions-rate CSV
//!   (`timestamp,MOER` — lbs CO₂/MWh, converted to gCO₂/kWh);
//! * **Solcast** irradiance CSV (`period_end,ghi` — W/m², scaled by a
//!   panel area × efficiency factor to installed watts).
//!
//! Timestamps are ISO-8601; they are re-based to seconds from the
//! first sample (the co-simulator runs on relative time).

use crate::grid::signal::HistoricalSignal;
use crate::util::csv::Table;
use crate::util::timeseries::{Interp, TimeSeries};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// lbs/MWh → g/kWh.
const LBS_PER_MWH_TO_G_PER_KWH: f64 = 453.592 / 1000.0;

/// Parse an ISO-8601 `YYYY-MM-DDTHH:MM:SS[Z]` timestamp into epoch-ish
/// seconds (no leap-second handling; differences only).
pub fn parse_iso8601_s(s: &str) -> Result<f64> {
    let s = s.trim().trim_end_matches('Z');
    let (date, time) = s
        .split_once('T')
        .or_else(|| s.split_once(' '))
        .with_context(|| format!("bad timestamp '{s}'"))?;
    let d: Vec<u32> = date
        .split('-')
        .map(|p| p.parse().context("bad date"))
        .collect::<Result<_>>()?;
    let t: Vec<f64> = time
        .split(':')
        .map(|p| p.parse().context("bad time"))
        .collect::<Result<_>>()?;
    if d.len() != 3 || t.len() < 2 {
        bail!("bad timestamp '{s}'");
    }
    // Days since a fixed epoch (civil-from-days, Howard Hinnant's algo).
    let (y, m, day) = (d[0] as i64, d[1] as i64, d[2] as i64);
    let y_adj = if m <= 2 { y - 1 } else { y };
    let era = y_adj.div_euclid(400);
    let yoe = y_adj - era * 400;
    let doy = (153 * (if m > 2 { m - 3 } else { m + 9 }) + 2) / 5 + day - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    let days = era * 146097 + doe - 719468;
    let secs = t[0] * 3600.0 + t[1] * 60.0 + t.get(2).copied().unwrap_or(0.0);
    Ok(days as f64 * 86400.0 + secs)
}

/// Load a WattTime-style MOER CSV into a carbon-intensity signal
/// (gCO₂/kWh, cubic interpolation as the paper resamples).
pub fn load_watttime(path: impl AsRef<Path>) -> Result<HistoricalSignal> {
    let t = Table::load(&path)?;
    let ts_col = t
        .col_index("timestamp")
        .or_else(|_| t.col_index("point_time"))?;
    let moer_col = t.col_index("MOER").or_else(|_| t.col_index("moer"))?;
    let mut times = Vec::with_capacity(t.rows.len());
    let mut vals = Vec::with_capacity(t.rows.len());
    for r in &t.rows {
        times.push(parse_iso8601_s(&r[ts_col])?);
        vals.push(r[moer_col].parse::<f64>()? * LBS_PER_MWH_TO_G_PER_KWH);
    }
    rebase(&mut times)?;
    Ok(HistoricalSignal::new(
        "watttime_ci",
        TimeSeries::new(times, vals),
        Interp::Cubic,
    ))
}

/// Load a Solcast GHI CSV into a solar-power signal. `system_factor`
/// converts W/m² to installed watts (panel area × efficiency ×
/// performance ratio); e.g. a 600 W array ≈ factor 0.6 at
/// 1000 W/m² standard irradiance.
pub fn load_solcast(path: impl AsRef<Path>, system_factor: f64) -> Result<HistoricalSignal> {
    let t = Table::load(&path)?;
    let ts_col = t
        .col_index("period_end")
        .or_else(|_| t.col_index("timestamp"))?;
    let ghi_col = t.col_index("ghi").or_else(|_| t.col_index("GHI"))?;
    let mut times = Vec::with_capacity(t.rows.len());
    let mut vals = Vec::with_capacity(t.rows.len());
    for r in &t.rows {
        times.push(parse_iso8601_s(&r[ts_col])?);
        vals.push((r[ghi_col].parse::<f64>()? * system_factor).max(0.0));
    }
    rebase(&mut times)?;
    Ok(HistoricalSignal::new(
        "solcast_solar",
        TimeSeries::new(times, vals),
        Interp::Cubic,
    ))
}

fn rebase(times: &mut [f64]) -> Result<()> {
    if times.is_empty() {
        bail!("empty dataset");
    }
    let t0 = times[0];
    for t in times.iter_mut() {
        *t -= t0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iso8601_differences() {
        let a = parse_iso8601_s("2023-06-01T00:00:00Z").unwrap();
        let b = parse_iso8601_s("2023-06-01T01:30:00Z").unwrap();
        assert_eq!(b - a, 5400.0);
        let c = parse_iso8601_s("2023-06-02T00:00:00").unwrap();
        assert_eq!(c - a, 86400.0);
        // Month boundary.
        let d = parse_iso8601_s("2023-07-01T00:00:00").unwrap();
        assert_eq!(d - a, 30.0 * 86400.0);
    }

    #[test]
    fn watttime_roundtrip() {
        let dir = std::env::temp_dir().join("vidur_energy_wt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("moer.csv");
        std::fs::write(
            &p,
            "timestamp,MOER\n2023-06-01T00:00:00Z,900\n2023-06-01T00:05:00Z,1100\n",
        )
        .unwrap();
        let sig = load_watttime(&p).unwrap();
        // 900 lbs/MWh ≈ 408.2 g/kWh.
        assert!((sig.at(0.0) - 408.23).abs() < 0.1, "{}", sig.at(0.0));
        assert!(sig.at(300.0) > sig.at(0.0));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn solcast_scaling_and_clamp() {
        let dir = std::env::temp_dir().join("vidur_energy_sc");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ghi.csv");
        std::fs::write(
            &p,
            "period_end,ghi\n2023-06-01T10:00:00Z,800\n2023-06-01T10:30:00Z,1000\n",
        )
        .unwrap();
        let sig = load_solcast(&p, 0.6).unwrap();
        assert_eq!(sig.at(0.0), 480.0);
        assert_eq!(sig.at(1800.0), 600.0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_columns_error() {
        let dir = std::env::temp_dir().join("vidur_energy_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "a,b\n1,2\n").unwrap();
        assert!(load_watttime(&p).is_err());
        assert!(load_solcast(&p, 1.0).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
