//! `repro` — the L3 coordinator binary. All logic lives in the
//! library; this is only the process entry point.

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    if let Err(e) = vidur_energy::coordinator::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
