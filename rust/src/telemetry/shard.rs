//! The shard telemetry sidecar (DESIGN.md §9).
//!
//! A sharded experiment run (`repro experiment <id> --shard k/N`)
//! cannot put everything it knows into its CSV rows: the per-request
//! latency *distributions*, the exact counter accumulators, and the
//! sweep-level oracle/memory statistics all need to survive the trip
//! to the merge host in mergeable form. [`ShardTelemetry`] is that
//! container — one `telemetry.json` per experiment directory holding:
//!
//! * the global case indices this process ran (row ↔ case mapping for
//!   the CSV merge);
//! * the summed [`RequestStats`] counters and merged [`StageStats`];
//! * Greenwald–Khanna sketch snapshots ([`LatencySketches`]) for
//!   TTFT / e2e / queue-delay / normalized latency;
//! * [`OracleStats`] and the peak-memory telemetry that feeds
//!   `meta.json`'s `sweep` object.
//!
//! Unsharded runs write the same sidecar (with `shard: null`), so a
//! merged N-shard run and an unsharded run produce structurally
//! identical outputs — the parity that `tests/shard_merge.rs` pins
//! down. [`ShardTelemetry::merge`] enforces the protocol: same
//! experiment, same grid size, disjoint case sets; counters add
//! exactly, peaks take maxima, sketches merge within the combined
//! rank-error bound, and the quantile point-estimates are re-derived
//! from the merged sketches.

use crate::exec::OracleStats;
use crate::sweep::ShardSpec;
use crate::telemetry::{LatencySketches, RequestStats, StageStats, StreamingRequestSink};
use crate::util::json::Value;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Format tag written into every sidecar; bumped on breaking changes.
pub const FORMAT: &str = "vidur-energy/shard-telemetry/v1";

/// The sidecar's file name inside an experiment directory.
pub const FILENAME: &str = "telemetry.json";

/// Mergeable telemetry of one shard (or of a whole unsharded run) of
/// one experiment.
#[derive(Debug, Clone)]
pub struct ShardTelemetry {
    /// Experiment id (`exp1`, `autoscale`, …).
    pub experiment: String,
    /// Which shard produced this; `None` for unsharded/merged output.
    pub shard: Option<ShardSpec>,
    /// Size of the full case grid (all shards together).
    pub total_cases: u64,
    /// Global case indices this telemetry covers, ascending — also the
    /// row order of the accompanying CSV.
    pub cases: Vec<u64>,
    /// Worker threads used (`--jobs`); merged: max across shards.
    pub jobs: u64,
    /// Summed exact request counters across the covered cases.
    pub requests: RequestStats,
    /// Merged stage aggregates across the covered cases.
    pub stages: StageStats,
    /// Summed oracle memo-cache statistics.
    pub oracle: OracleStats,
    /// Latency sketch snapshots, merged across the covered cases.
    pub sketches: LatencySketches,
    /// Peak resident Eq. 5 bins of any covered case (max semantics).
    pub peak_resident_bins: u64,
    /// Peak live requests of any covered case (max semantics).
    pub peak_live_requests: u64,
}

impl ShardTelemetry {
    /// An empty accumulator for `experiment` over a `total_cases` grid.
    pub fn new(experiment: &str, shard: Option<ShardSpec>, total_cases: u64) -> Self {
        ShardTelemetry {
            experiment: experiment.to_string(),
            shard,
            total_cases,
            cases: Vec::new(),
            jobs: crate::sweep::default_jobs() as u64,
            requests: RequestStats::default(),
            stages: StageStats::default(),
            oracle: OracleStats::default(),
            sketches: LatencySketches::new(StreamingRequestSink::DEFAULT_EPS),
            peak_resident_bins: 0,
            peak_live_requests: 0,
        }
    }

    /// Fold one case's telemetry in (cases may arrive in any order;
    /// the list is kept sorted).
    pub fn add_case(
        &mut self,
        case_index: u64,
        requests: &RequestStats,
        stages: &StageStats,
        oracle: &OracleStats,
        sketches: &LatencySketches,
        peak_resident_bins: u64,
        peak_live_requests: u64,
    ) {
        let pos = self.cases.partition_point(|&c| c < case_index);
        self.cases.insert(pos, case_index);
        self.requests.merge(requests);
        self.stages.merge(stages);
        self.oracle.merge(oracle);
        self.sketches.merge(sketches);
        self.peak_resident_bins = self.peak_resident_bins.max(peak_resident_bins);
        self.peak_live_requests = self.peak_live_requests.max(peak_live_requests);
        // The quantile point-estimates in `requests` stay stale (zero)
        // during accumulation; `to_json` re-derives them from the
        // sketches once at serialization time.
    }

    /// Does this telemetry cover the entire grid (`0..total_cases`)?
    pub fn is_complete(&self) -> bool {
        self.cases.len() as u64 == self.total_cases
            && self.cases.iter().enumerate().all(|(i, &c)| c == i as u64)
    }

    /// Merge another shard's telemetry into this one (the `repro
    /// merge` core). Fails on protocol violations: different
    /// experiments, different grid sizes, or overlapping case sets.
    /// The result drops the shard identity (`shard: None`) — it now
    /// speaks for the union.
    pub fn merge(&mut self, other: &ShardTelemetry) -> Result<()> {
        if self.experiment != other.experiment {
            bail!(
                "cannot merge telemetry of '{}' into '{}'",
                other.experiment,
                self.experiment
            );
        }
        if self.total_cases != other.total_cases {
            bail!(
                "shard grids disagree: {} vs {} total cases — \
                 shards must come from the same experiment invocation \
                 (same --fast setting, same grid)",
                self.total_cases,
                other.total_cases
            );
        }
        if let Some(dup) = other.cases.iter().find(|c| self.cases.binary_search(c).is_ok()) {
            bail!(
                "shards overlap: case {dup} appears in both — \
                 each shard k/N must have run with a distinct k"
            );
        }
        let mut cases = Vec::with_capacity(self.cases.len() + other.cases.len());
        let (mut a, mut b) = (self.cases.iter().peekable(), other.cases.iter().peekable());
        loop {
            match (a.peek(), b.peek()) {
                (None, None) => break,
                (Some(_), None) => cases.push(*a.next().unwrap()),
                (None, Some(_)) => cases.push(*b.next().unwrap()),
                (Some(&&x), Some(&&y)) => {
                    if x <= y {
                        cases.push(*a.next().unwrap());
                    } else {
                        cases.push(*b.next().unwrap());
                    }
                }
            }
        }
        self.cases = cases;
        self.shard = None;
        self.jobs = self.jobs.max(other.jobs);
        self.requests.merge(&other.requests);
        self.stages.merge(&other.stages);
        self.oracle.merge(&other.oracle);
        self.sketches.merge(&other.sketches);
        self.peak_resident_bins = self.peak_resident_bins.max(other.peak_resident_bins);
        self.peak_live_requests = self.peak_live_requests.max(other.peak_live_requests);
        self.sketches.apply_quantiles(&mut self.requests);
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        // One quantile derivation per serialization, however many
        // cases were folded in.
        let mut requests = self.requests;
        self.sketches.apply_quantiles(&mut requests);
        let mut v = Value::obj();
        v.set("format", FORMAT)
            .set("experiment", self.experiment.as_str())
            .set(
                "shard",
                match self.shard {
                    Some(s) => Value::Str(s.label()),
                    None => Value::Null,
                },
            )
            .set("total_cases", self.total_cases)
            .set("cases", Value::Arr(self.cases.iter().map(|&c| Value::Num(c as f64)).collect()))
            .set("jobs", self.jobs)
            .set("requests", requests.to_json())
            .set("stages", self.stages.to_json())
            .set("oracle_cache", self.oracle.to_json())
            .set("sketches", self.sketches.to_json())
            .set("peak_resident_bins", self.peak_resident_bins)
            .set("peak_live_requests", self.peak_live_requests);
        v
    }

    pub fn from_json(v: &Value) -> Result<ShardTelemetry> {
        let format = v.req_str("format")?;
        if format != FORMAT {
            bail!("unknown telemetry sidecar format '{format}' (expected '{FORMAT}')");
        }
        let shard = match v.get("shard") {
            Some(Value::Str(s)) => Some(ShardSpec::parse(s)?),
            Some(Value::Null) | None => None,
            Some(other) => bail!("bad 'shard' field: {}", other.to_string()),
        };
        let mut cases = Vec::new();
        for (i, c) in v
            .get("cases")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| anyhow::anyhow!("telemetry missing 'cases' array"))?
            .iter()
            .enumerate()
        {
            let c = c
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("bad case index at position {i}"))?;
            if let Some(&last) = cases.last() {
                anyhow::ensure!(c > last, "case indices must be strictly ascending");
            }
            cases.push(c);
        }
        Ok(ShardTelemetry {
            experiment: v.req_str("experiment")?.to_string(),
            shard,
            total_cases: v.req_u64("total_cases")?,
            cases,
            jobs: v.req_u64("jobs")?,
            requests: RequestStats::from_json(
                v.get("requests")
                    .ok_or_else(|| anyhow::anyhow!("telemetry missing 'requests'"))?,
            )?,
            stages: StageStats::from_json(
                v.get("stages")
                    .ok_or_else(|| anyhow::anyhow!("telemetry missing 'stages'"))?,
            )?,
            oracle: OracleStats::from_json(
                v.get("oracle_cache")
                    .ok_or_else(|| anyhow::anyhow!("telemetry missing 'oracle_cache'"))?,
            )?,
            sketches: LatencySketches::from_json(
                v.get("sketches")
                    .ok_or_else(|| anyhow::anyhow!("telemetry missing 'sketches'"))?,
            )?,
            peak_resident_bins: v.req_u64("peak_resident_bins")?,
            peak_live_requests: v.req_u64("peak_live_requests")?,
        })
    }

    /// Write the sidecar into `dir` as [`FILENAME`].
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(FILENAME);
        std::fs::write(&path, self.to_json().pretty())
            .with_context(|| format!("writing {path:?}"))
    }

    /// Load the sidecar from `dir`, or `Ok(None)` if there is none
    /// (pre-sharding results, single-case experiments).
    pub fn load(dir: &Path) -> Result<Option<ShardTelemetry>> {
        let path = dir.join(FILENAME);
        if !path.exists() {
            return Ok(None);
        }
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let v = crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        Ok(Some(Self::from_json(&v).with_context(|| format!("{path:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::SimConfig;
    use crate::telemetry::RequestSink;
    use crate::workload::Request;

    fn sample_sink(ids: std::ops::Range<u64>) -> StreamingRequestSink {
        let cfg = SimConfig::default();
        let mut s = StreamingRequestSink::new(&cfg);
        for i in ids {
            let mut r = Request::new(i, i as f64, 64, 16);
            r.prefill_done = 64;
            r.decode_done = 16;
            r.scheduled_s = Some(i as f64 + 0.1);
            r.first_token_s = Some(i as f64 + 0.3 + (i % 11) as f64 * 0.05);
            r.finished_s = Some(i as f64 + 2.0 + (i % 17) as f64 * 0.2);
            s.record(&r);
        }
        s
    }

    fn shard_tel(k: u32, n: u32, cases: &[u64]) -> ShardTelemetry {
        let mut t = ShardTelemetry::new("expX", Some(ShardSpec::new(k, n).unwrap()), 8);
        for &c in cases {
            let sink = sample_sink(c * 100..c * 100 + 50);
            let mut st = sink.stats();
            st.submitted = 50;
            let stages = StageStats {
                stages: 10 + c,
                weighted_mfu: 0.3,
                dt_sum: 5.0,
                mean_batch: 4.0,
                batch_std: 1.0,
                busy_gpu_s: 5.0,
                span: (c as f64, c as f64 + 9.0),
            };
            let oracle = OracleStats {
                calls: 100,
                hits: 90,
                resets: c,
                surface_builds: 1,
            };
            t.add_case(c, &st, &stages, &oracle, sink.sketches(), 3 + c, 20 + c);
        }
        t
    }

    #[test]
    fn merge_enforces_protocol_and_combines_with_documented_semantics() {
        let mut a = shard_tel(0, 2, &[0, 2, 4, 6]);
        let b = shard_tel(1, 2, &[1, 3, 5, 7]);
        let finished_a = a.requests.finished;
        a.merge(&b).unwrap();
        assert_eq!(a.shard, None);
        assert_eq!(a.cases, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(a.is_complete());
        // Sum semantics.
        assert_eq!(a.requests.finished, finished_a + b.requests.finished);
        assert_eq!(a.oracle.calls, 800);
        assert_eq!(a.oracle.resets, (0..8).sum::<u64>());
        assert_eq!(a.stages.stages, (0..8).map(|c| 10 + c).sum::<u64>());
        // Max semantics (the meta.json bugfix: peaks must not be
        // last-shard-wins or summed).
        assert_eq!(a.peak_resident_bins, 3 + 7);
        assert_eq!(a.peak_live_requests, 20 + 7);
        // Quantiles re-derived from the merged sketches, not zeroed.
        assert!(a.requests.ttft_p50_s > 0.0);
        assert_eq!(a.sketches.e2e.count(), a.requests.finished);

        // Protocol violations.
        let mut c = shard_tel(0, 2, &[0, 2]);
        assert!(c.merge(&shard_tel(0, 2, &[0])).is_err(), "overlap");
        let mut d = ShardTelemetry::new("other", None, 8);
        assert!(d.merge(&b).is_err(), "experiment mismatch");
        let mut e = ShardTelemetry::new("expX", None, 9);
        assert!(e.merge(&b).is_err(), "grid size mismatch");
    }

    #[test]
    fn sidecar_roundtrips_through_disk() {
        let t = shard_tel(1, 4, &[1, 5]);
        let dir = std::env::temp_dir().join("vidur_energy_shard_tel_test");
        std::fs::remove_dir_all(&dir).ok();
        t.save(&dir).unwrap();
        let back = ShardTelemetry::load(&dir).unwrap().unwrap();
        assert_eq!(back.experiment, t.experiment);
        assert_eq!(back.shard, t.shard);
        assert_eq!(back.cases, t.cases);
        assert_eq!(back.total_cases, t.total_cases);
        // Serialization applies the sketch-derived quantiles; the
        // in-memory accumulator keeps them stale until then.
        let mut want_requests = t.requests;
        t.sketches.apply_quantiles(&mut want_requests);
        assert_eq!(back.requests, want_requests);
        assert!(back.requests.ttft_p50_s > 0.0);
        assert_eq!(back.stages.stages, t.stages.stages);
        assert_eq!(back.stages.weighted_mfu, t.stages.weighted_mfu);
        assert_eq!(back.oracle, t.oracle);
        assert_eq!(back.peak_resident_bins, t.peak_resident_bins);
        assert_eq!(
            back.sketches.ttft.quantile(0.99),
            t.sketches.ttft.quantile(0.99)
        );
        // Absent sidecar is None, not an error.
        let empty = std::env::temp_dir().join("vidur_energy_shard_tel_none");
        std::fs::remove_dir_all(&empty).ok();
        std::fs::create_dir_all(&empty).unwrap();
        assert!(ShardTelemetry::load(&empty).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&empty).ok();
    }
}
