//! Telemetry: per-batch-stage records — the paper's §3.2 modification
//! of Vidur ("log MFU at the batch stage level instead of replica-wide
//! averages"), which feeds both the energy accounting (Eq. 2–3) and the
//! Vessim-side pipeline (Eq. 5).
//!
//! Two consumers behind one [`StageSink`] trait (DESIGN.md §7): the
//! materialized [`StageLog`] (full record vector; per-stage CSV export)
//! and the O(bins) [`StreamingSink`] (online Eq. 5 / Eq. 3 folding for
//! sweeps and long traces).

pub mod sink;
pub mod stagelog;

pub use sink::{StageSink, StageStats, StreamingSink};
pub use stagelog::{StageLog, StageRecord};
