//! Telemetry: per-batch-stage records — the paper's §3.2 modification
//! of Vidur ("log MFU at the batch stage level instead of replica-wide
//! averages"), which feeds both the energy accounting (Eq. 2–3) and the
//! Vessim-side pipeline (Eq. 5) — plus per-request completion records
//! feeding the latency/SLO metrics.
//!
//! Each stream has two consumers behind one object-safe trait:
//!
//! * stages ([`StageSink`], DESIGN.md §7): the materialized
//!   [`StageLog`] (full record vector; per-stage CSV export) and the
//!   O(bins) [`StreamingSink`] (online Eq. 5 / Eq. 3 folding);
//! * requests ([`RequestSink`], DESIGN.md §8): the materialized
//!   [`RequestLog`] (full request vector; exact percentiles) and the
//!   [`StreamingRequestSink`] (online SLO counters, token totals, and
//!   Greenwald–Khanna latency quantile sketches).
//!
//! Every streaming accumulator is **mergeable** (DESIGN.md §9):
//! [`RequestStats::merge`] / [`StageStats::merge`] sum exact counters
//! and recombine weighted means, [`LatencySketches::merge`] combines
//! the GK sketches within a documented rank-error bound, and
//! [`ShardTelemetry`] packages all of it — plus the case-index map —
//! into the `telemetry.json` sidecar that `repro experiment --shard
//! k/N` writes and `repro merge` recombines. That sidecar is what
//! makes a sweep sharded across machines equivalent to one big local
//! run: CSVs merge byte-identically, counters exactly, quantiles
//! within ε.

//!
//! Because both consumers sit behind object-safe traits, a stream can
//! also be **fanned out** (DESIGN.md §10): [`FanoutStageSink`] /
//! [`FanoutRequestSink`] broadcast each record to N sinks — the normal
//! accumulator *plus* an observer such as the rolling-window live view
//! in [`window`] — without the engine knowing anyone is watching.

pub mod fanout;
pub mod reqsink;
pub mod shard;
pub mod sink;
pub mod stagelog;
pub mod window;

pub use fanout::{FanoutRequestSink, FanoutStageSink};
pub use reqsink::{
    LatencySketches, RequestLog, RequestSink, RequestStats, StreamingRequestSink,
};
pub use shard::ShardTelemetry;
pub use sink::{StageSink, StageStats, StreamingSink};
pub use stagelog::{StageLog, StageRecord};
pub use window::{CaseWatch, Snapshot, SnapshotEmitter, WindowedRequests, WindowedStages};
