//! Telemetry: per-batch-stage records — the paper's §3.2 modification
//! of Vidur ("log MFU at the batch stage level instead of replica-wide
//! averages"), which feeds both the energy accounting (Eq. 2–3) and the
//! Vessim-side pipeline (Eq. 5).

pub mod stagelog;

pub use stagelog::{StageLog, StageRecord};
