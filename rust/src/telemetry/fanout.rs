//! Fan-out telemetry sinks (DESIGN.md §10).
//!
//! A run's telemetry stream has exactly one producer (the engine) but
//! may want several consumers: the normal accumulator that feeds
//! metrics and sidecars, *plus* an observer — a live dashboard window,
//! a debug tap, a secondary log. [`FanoutStageSink`] /
//! [`FanoutRequestSink`] broadcast every record to N sinks behind the
//! same object-safe traits the engine already takes, so attaching an
//! observer requires **zero engine changes** — the sink seam is the
//! whole integration surface.
//!
//! The first sink is the **primary**: `stats()` answers from it alone,
//! so a fanned-out run returns byte-identical [`StageStats`] /
//! [`RequestStats`] to an un-fanned run over the same primary — the
//! observer-parity guarantee `tests/watch_observer.rs` asserts end to
//! end (CSVs, `meta.json`, `telemetry.json` all unchanged by watching).
//!
//! Sinks are borrowed mutably (not boxed) so the caller keeps
//! ownership of its accumulators and can read them after the run:
//!
//! ```
//! use vidur_energy::telemetry::{FanoutStageSink, StageLog, StageSink};
//!
//! let mut primary = StageLog::new();
//! let mut observer = StageLog::new();
//! {
//!     let mut fan = FanoutStageSink::new(vec![&mut primary, &mut observer]);
//!     // (the engine would call fan.record(..) for every stage)
//!     assert_eq!(fan.stats().stages, 0);
//! }
//! assert_eq!(primary.len(), observer.len()); // both saw every record
//! ```

use crate::telemetry::{RequestSink, RequestStats, StageRecord, StageSink, StageStats};
use crate::workload::Request;

/// Broadcasts each stage record to every attached sink; `stats()` is
/// the first (primary) sink's.
pub struct FanoutStageSink<'a> {
    sinks: Vec<&'a mut dyn StageSink>,
}

impl<'a> FanoutStageSink<'a> {
    /// Fan out over `sinks`; the first is the primary (must exist).
    pub fn new(sinks: Vec<&'a mut dyn StageSink>) -> Self {
        assert!(!sinks.is_empty(), "fan-out needs a primary sink");
        FanoutStageSink { sinks }
    }

    /// Number of attached sinks (primary included).
    pub fn len(&self) -> usize {
        self.sinks.len()
    }
}

impl StageSink for FanoutStageSink<'_> {
    fn record(&mut self, r: StageRecord) {
        for s in self.sinks.iter_mut() {
            s.record(r);
        }
    }

    fn stats(&self) -> StageStats {
        self.sinks[0].stats()
    }
}

/// Broadcasts each completed request to every attached sink; `stats()`
/// is the first (primary) sink's.
pub struct FanoutRequestSink<'a> {
    sinks: Vec<&'a mut dyn RequestSink>,
}

impl<'a> FanoutRequestSink<'a> {
    /// Fan out over `sinks`; the first is the primary (must exist).
    pub fn new(sinks: Vec<&'a mut dyn RequestSink>) -> Self {
        assert!(!sinks.is_empty(), "fan-out needs a primary sink");
        FanoutRequestSink { sinks }
    }

    /// Number of attached sinks (primary included).
    pub fn len(&self) -> usize {
        self.sinks.len()
    }
}

impl RequestSink for FanoutRequestSink<'_> {
    fn record(&mut self, r: &Request) {
        for s in self.sinks.iter_mut() {
            s.record(r);
        }
    }

    fn stats(&self) -> RequestStats {
        self.sinks[0].stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::SimConfig;
    use crate::scheduler::replica::StageKind;
    use crate::telemetry::{RequestLog, StageLog, StreamingRequestSink, StreamingSink};

    fn rec(start: f64, mfu: f64, batch: u32) -> StageRecord {
        StageRecord {
            replica: 0,
            pp_stage: 0,
            start_s: start,
            dt_s: 0.4,
            batch_size: batch,
            new_tokens: batch,
            mfu,
            power_w: 250.0,
            active_gpus: 1,
            idle_gpus: 0,
            flops: 1e12,
            kind: StageKind::Decode,
        }
    }

    fn req(id: u64) -> Request {
        let mut r = Request::new(id, id as f64, 64, 16);
        r.prefill_done = 64;
        r.decode_done = 16;
        r.scheduled_s = Some(id as f64 + 0.1);
        r.first_token_s = Some(id as f64 + 0.5);
        r.finished_s = Some(id as f64 + 3.0);
        r
    }

    /// The parity contract: a fanned-out run's primary stats equal an
    /// un-fanned run's, and every observer saw every record.
    #[test]
    fn fanout_is_transparent_to_the_primary() {
        let cfg = SimConfig::default();
        // Reference: primary alone.
        let mut alone = StreamingSink::new(&cfg, 10.0).unwrap();
        // Fanned: identical primary + a materialized observer.
        let mut primary = StreamingSink::new(&cfg, 10.0).unwrap();
        let mut observer = StageLog::new();
        {
            let mut fan = FanoutStageSink::new(vec![&mut primary, &mut observer]);
            assert_eq!(fan.len(), 2);
            for i in 0..120 {
                let r = rec(i as f64 * 0.5, 0.1 + (i % 7) as f64 * 0.05, 1 + i % 6);
                alone.record(r);
                fan.record(r);
            }
            let fan_stats = fan.stats();
            assert_eq!(fan_stats.stages, alone.stats().stages);
            assert_eq!(fan_stats.weighted_mfu, alone.stats().weighted_mfu);
        }
        assert_eq!(observer.len(), 120);
        assert_eq!(primary.stats().stages, 120);
        assert_eq!(primary.stats().busy_gpu_s, alone.stats().busy_gpu_s);
    }

    #[test]
    fn request_fanout_broadcasts_and_answers_from_primary() {
        let cfg = SimConfig::default();
        let mut alone = StreamingRequestSink::new(&cfg);
        let mut primary = StreamingRequestSink::new(&cfg);
        let mut observer = RequestLog::new(&cfg);
        {
            let mut fan = FanoutRequestSink::new(vec![&mut primary, &mut observer]);
            for i in 0..80u64 {
                let r = req(i);
                alone.record(&r);
                fan.record(&r);
            }
            let a = fan.stats();
            let b = alone.stats();
            assert_eq!(a.finished, b.finished);
            assert_eq!(a.slo_both_ok, b.slo_both_ok);
            assert_eq!(a.ttft_p50_s, b.ttft_p50_s);
        }
        assert_eq!(observer.len(), 80);
        assert_eq!(primary.stats().finished, 80);
    }

    #[test]
    #[should_panic(expected = "primary")]
    fn empty_fanout_is_rejected() {
        FanoutStageSink::new(Vec::new());
    }
}
