//! Streaming request telemetry (DESIGN.md §8), mirroring the stage
//! side's [`crate::telemetry::StageSink`].
//!
//! The engine hands every *completed* request to a [`RequestSink`] and
//! then drops it from its live map — so what the sink keeps is the
//! run's whole per-request memory. Two implementations:
//!
//! * [`RequestLog`] — materialized: retains every request (the
//!   `SimOutput.requests` vector) and computes exact latency
//!   percentiles at `stats()` time;
//! * [`StreamingRequestSink`] — O(sketch): folds each completion into
//!   SLO counters, token totals, a normalized-latency mean, and
//!   Greenwald–Khanna [`QuantileSketch`]es for TTFT / e2e /
//!   queue-delay / normalized latency.
//!
//! Parity contract (asserted in `tests/request_telemetry.rs`): counts,
//! SLO fractions, and token totals are *exact* across sinks — they run
//! the same folds on the same completion order. Quantiles from the
//! streaming sink are approximate within the sketch's documented rank
//! error ε ([`StreamingRequestSink::DEFAULT_EPS`]).
//!
//! Both the counter accumulator ([`RequestStats::merge`]) and the
//! sketch bundle ([`LatencySketches::merge`]) are mergeable across
//! disjoint completion streams, which is what lets a cross-machine
//! sweep recombine per-shard request telemetry (`repro merge`,
//! DESIGN.md §9) without re-running: counters stay exact under any
//! grouping, quantiles stay within the combined rank-error bound.

use crate::config::simconfig::SimConfig;
use crate::util::json::Value;
use crate::util::stats::{percentile, QuantileSketch};
use crate::workload::Request;
use anyhow::Result;

/// Aggregates the metrics layer consumes, regardless of sink kind.
/// `submitted` is stamped by the engine (sinks only observe
/// completions; requests still in flight at the end of a run count as
/// SLO misses against it).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RequestStats {
    /// Requests offered to the engine.
    pub submitted: u64,
    /// Requests that completed.
    pub finished: u64,
    /// Prompt tokens actually prefilled by completed requests.
    pub prefill_tokens_done: u64,
    /// Output tokens actually decoded by completed requests.
    pub decode_tokens_done: u64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    /// Median queueing delay (arrival → first scheduled).
    pub queue_delay_p50_s: f64,
    /// Mean normalized latency (s per output token) — vLLM's metric.
    pub norm_latency_mean_s_per_tok: f64,
    /// Completions contributing to the normalized-latency mean — the
    /// mean's weight, carried so two `RequestStats` merge exactly.
    pub norm_latency_n: u64,
    /// Completions whose TTFT met the configured SLO.
    pub slo_ttft_ok: u64,
    /// Completions whose e2e latency met the configured SLO.
    pub slo_e2e_ok: u64,
    /// Completions meeting both SLOs.
    pub slo_both_ok: u64,
}

impl RequestStats {
    /// Tokens actually processed (prefill + decode) by completions.
    pub fn tokens_done(&self) -> u64 {
        self.prefill_tokens_done + self.decode_tokens_done
    }

    /// Fold another (disjoint) completion stream's accumulator into
    /// this one (DESIGN.md §9). Every counter sums exactly; the
    /// normalized-latency mean recombines weighted by
    /// `norm_latency_n`.
    ///
    /// The five quantile point-estimates (`ttft_p50_s` …
    /// `queue_delay_p50_s`) are **not** mergeable from point values and
    /// are reset to 0.0 — re-derive them from merged
    /// [`LatencySketches`] via [`LatencySketches::apply_quantiles`]
    /// (the shard telemetry merge does exactly that).
    pub fn merge(&mut self, other: &RequestStats) {
        let n = self.norm_latency_n + other.norm_latency_n;
        self.norm_latency_mean_s_per_tok = if n == 0 {
            0.0
        } else {
            (self.norm_latency_mean_s_per_tok * self.norm_latency_n as f64
                + other.norm_latency_mean_s_per_tok * other.norm_latency_n as f64)
                / n as f64
        };
        self.norm_latency_n = n;
        self.submitted += other.submitted;
        self.finished += other.finished;
        self.prefill_tokens_done += other.prefill_tokens_done;
        self.decode_tokens_done += other.decode_tokens_done;
        self.slo_ttft_ok += other.slo_ttft_ok;
        self.slo_e2e_ok += other.slo_e2e_ok;
        self.slo_both_ok += other.slo_both_ok;
        self.ttft_p50_s = 0.0;
        self.ttft_p99_s = 0.0;
        self.e2e_p50_s = 0.0;
        self.e2e_p99_s = 0.0;
        self.queue_delay_p50_s = 0.0;
    }

    /// Serialize for the shard telemetry sidecar. The quantile fields
    /// ride along for human readers; the merge path recomputes them
    /// from the sketches.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("submitted", self.submitted)
            .set("finished", self.finished)
            .set("prefill_tokens_done", self.prefill_tokens_done)
            .set("decode_tokens_done", self.decode_tokens_done)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("e2e_p50_s", self.e2e_p50_s)
            .set("e2e_p99_s", self.e2e_p99_s)
            .set("queue_delay_p50_s", self.queue_delay_p50_s)
            .set("norm_latency_mean_s_per_tok", self.norm_latency_mean_s_per_tok)
            .set("norm_latency_n", self.norm_latency_n)
            .set("slo_ttft_ok", self.slo_ttft_ok)
            .set("slo_e2e_ok", self.slo_e2e_ok)
            .set("slo_both_ok", self.slo_both_ok);
        v
    }

    /// Reload stats serialized by [`RequestStats::to_json`].
    pub fn from_json(v: &Value) -> Result<RequestStats> {
        Ok(RequestStats {
            submitted: v.req_u64("submitted")?,
            finished: v.req_u64("finished")?,
            prefill_tokens_done: v.req_u64("prefill_tokens_done")?,
            decode_tokens_done: v.req_u64("decode_tokens_done")?,
            ttft_p50_s: v.req_f64("ttft_p50_s")?,
            ttft_p99_s: v.req_f64("ttft_p99_s")?,
            e2e_p50_s: v.req_f64("e2e_p50_s")?,
            e2e_p99_s: v.req_f64("e2e_p99_s")?,
            queue_delay_p50_s: v.req_f64("queue_delay_p50_s")?,
            norm_latency_mean_s_per_tok: v.req_f64("norm_latency_mean_s_per_tok")?,
            norm_latency_n: v.req_u64("norm_latency_n")?,
            slo_ttft_ok: v.req_u64("slo_ttft_ok")?,
            slo_e2e_ok: v.req_u64("slo_e2e_ok")?,
            slo_both_ok: v.req_u64("slo_both_ok")?,
        })
    }
}

/// The four latency-distribution sketches the streaming request sink
/// maintains — TTFT, end-to-end, queue delay, normalized latency —
/// bundled so they can travel together: out of a finished sink
/// ([`StreamingRequestSink::into_sketches`]), into the shard telemetry
/// sidecar (`to_json`/`from_json`), and across shards
/// ([`LatencySketches::merge`], DESIGN.md §9).
#[derive(Debug, Clone)]
pub struct LatencySketches {
    pub ttft: QuantileSketch,
    pub e2e: QuantileSketch,
    pub queue_delay: QuantileSketch,
    pub norm_latency: QuantileSketch,
}

impl LatencySketches {
    /// Four empty sketches at rank error `eps`.
    pub fn new(eps: f64) -> Self {
        LatencySketches {
            ttft: QuantileSketch::new(eps),
            e2e: QuantileSketch::new(eps),
            queue_delay: QuantileSketch::new(eps),
            norm_latency: QuantileSketch::new(eps),
        }
    }

    /// Merge another shard's sketches distribution-by-distribution
    /// (each within the combined rank-error bound of
    /// [`QuantileSketch::merge`]).
    pub fn merge(&mut self, other: &LatencySketches) {
        self.ttft.merge(&other.ttft);
        self.e2e.merge(&other.e2e);
        self.queue_delay.merge(&other.queue_delay);
        self.norm_latency.merge(&other.norm_latency);
    }

    /// Total resident tuples across the four sketches.
    pub fn resident_tuples(&self) -> usize {
        self.ttft.resident_tuples()
            + self.e2e.resident_tuples()
            + self.queue_delay.resident_tuples()
            + self.norm_latency.resident_tuples()
    }

    /// Overwrite `stats`'s quantile point-estimates from the sketches
    /// — the step that makes a merged [`RequestStats`] whole again
    /// after [`RequestStats::merge`] reset them.
    pub fn apply_quantiles(&self, stats: &mut RequestStats) {
        let q = |s: &QuantileSketch, p: f64| s.quantile(p).unwrap_or(0.0);
        let ttft = self.ttft.flushed();
        let e2e = self.e2e.flushed();
        let qdel = self.queue_delay.flushed();
        stats.ttft_p50_s = q(&ttft, 0.50);
        stats.ttft_p99_s = q(&ttft, 0.99);
        stats.e2e_p50_s = q(&e2e, 0.50);
        stats.e2e_p99_s = q(&e2e, 0.99);
        stats.queue_delay_p50_s = q(&qdel, 0.50);
    }

    /// Serialize for the shard telemetry sidecar.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("ttft", self.ttft.to_json())
            .set("e2e", self.e2e.to_json())
            .set("queue_delay", self.queue_delay.to_json())
            .set("norm_latency", self.norm_latency.to_json());
        v
    }

    /// Reload sketches serialized by [`LatencySketches::to_json`].
    pub fn from_json(v: &Value) -> Result<LatencySketches> {
        let s = |key: &str| -> Result<QuantileSketch> {
            QuantileSketch::from_json(
                v.get(key)
                    .ok_or_else(|| anyhow::anyhow!("sketches missing '{key}'"))?,
            )
        };
        Ok(LatencySketches {
            ttft: s("ttft")?,
            e2e: s("e2e")?,
            queue_delay: s("queue_delay")?,
            norm_latency: s("norm_latency")?,
        })
    }
}

/// Consumer of the engine's per-request telemetry. Object-safe: the
/// engine cores take `&mut dyn RequestSink`. Requests arrive in
/// completion order, which sinks may rely on.
pub trait RequestSink {
    /// Accept one completed request (its lifecycle timestamps and
    /// progress counters are final).
    fn record(&mut self, r: &Request);

    /// Aggregates for [`crate::sim::SimMetrics`]. Implementations set
    /// `submitted = finished`; the engine overrides it with the true
    /// offered count.
    fn stats(&self) -> RequestStats;
}

/// Shared per-completion fold: the exact counters both sinks must
/// agree on (parity is by construction, not by approximation).
#[derive(Debug, Clone, Copy, Default)]
struct ExactFold {
    finished: u64,
    prefill_tokens_done: u64,
    decode_tokens_done: u64,
    slo_ttft_ok: u64,
    slo_e2e_ok: u64,
    slo_both_ok: u64,
    norm_sum: f64,
    norm_n: u64,
}

impl ExactFold {
    fn add(&mut self, r: &Request, slo_ttft_s: f64, slo_e2e_s: f64) {
        self.finished += 1;
        self.prefill_tokens_done += r.prefill_done;
        self.decode_tokens_done += r.decode_done;
        let ttft_ok = r.ttft().map(|t| t <= slo_ttft_s).unwrap_or(false);
        let e2e_ok = r.e2e_latency().map(|t| t <= slo_e2e_s).unwrap_or(false);
        self.slo_ttft_ok += ttft_ok as u64;
        self.slo_e2e_ok += e2e_ok as u64;
        self.slo_both_ok += (ttft_ok && e2e_ok) as u64;
        if let Some(l) = r.e2e_latency() {
            self.norm_sum += l / r.decode_tokens.max(1) as f64;
            self.norm_n += 1;
        }
    }

    fn norm_mean(&self) -> f64 {
        if self.norm_n == 0 {
            0.0
        } else {
            self.norm_sum / self.norm_n as f64
        }
    }
}

/// Materialized request sink: keeps every completed request and
/// answers with exact percentiles.
#[derive(Debug)]
pub struct RequestLog {
    slo_ttft_s: f64,
    slo_e2e_s: f64,
    fold: ExactFold,
    pub requests: Vec<Request>,
}

impl RequestLog {
    /// Log judging SLOs against the run configuration's targets.
    pub fn new(cfg: &SimConfig) -> Self {
        Self::with_slos(cfg.slo_ttft_s, cfg.slo_e2e_s)
    }

    pub fn with_slos(slo_ttft_s: f64, slo_e2e_s: f64) -> Self {
        RequestLog {
            slo_ttft_s,
            slo_e2e_s,
            fold: ExactFold::default(),
            requests: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// The recorded requests in id (= arrival) order — the vector
    /// `SimOutput.requests` exposes.
    pub fn into_requests(mut self) -> Vec<Request> {
        self.requests.sort_by_key(|r| r.id);
        self.requests
    }
}

impl RequestSink for RequestLog {
    fn record(&mut self, r: &Request) {
        self.fold.add(r, self.slo_ttft_s, self.slo_e2e_s);
        self.requests.push(r.clone());
    }

    fn stats(&self) -> RequestStats {
        let ttft: Vec<f64> = self.requests.iter().filter_map(|r| r.ttft()).collect();
        let e2e: Vec<f64> = self
            .requests
            .iter()
            .filter_map(|r| r.e2e_latency())
            .collect();
        let qdel: Vec<f64> = self
            .requests
            .iter()
            .filter_map(|r| r.scheduled_s.map(|s| s - r.arrival_s))
            .collect();
        let pc = |v: &[f64], p: f64| if v.is_empty() { 0.0 } else { percentile(v, p) };
        RequestStats {
            submitted: self.fold.finished,
            finished: self.fold.finished,
            prefill_tokens_done: self.fold.prefill_tokens_done,
            decode_tokens_done: self.fold.decode_tokens_done,
            ttft_p50_s: pc(&ttft, 50.0),
            ttft_p99_s: pc(&ttft, 99.0),
            e2e_p50_s: pc(&e2e, 50.0),
            e2e_p99_s: pc(&e2e, 99.0),
            queue_delay_p50_s: pc(&qdel, 50.0),
            norm_latency_mean_s_per_tok: self.fold.norm_mean(),
            norm_latency_n: self.fold.norm_n,
            slo_ttft_ok: self.fold.slo_ttft_ok,
            slo_e2e_ok: self.fold.slo_e2e_ok,
            slo_both_ok: self.fold.slo_both_ok,
        }
    }
}

/// O(sketch) streaming request sink: the same exact fold as
/// [`RequestLog`] plus Greenwald–Khanna sketches for the latency
/// distributions — never retaining the requests themselves.
#[derive(Debug)]
pub struct StreamingRequestSink {
    slo_ttft_s: f64,
    slo_e2e_s: f64,
    fold: ExactFold,
    sketches: LatencySketches,
}

impl StreamingRequestSink {
    /// Default rank error: 0.1% of ranks — at 1M requests the p99 is
    /// resolved to within ±1000 ranks while the sketch holds a few
    /// thousand tuples.
    pub const DEFAULT_EPS: f64 = 1e-3;

    pub fn new(cfg: &SimConfig) -> Self {
        Self::with_epsilon(cfg, Self::DEFAULT_EPS)
    }

    pub fn with_epsilon(cfg: &SimConfig, eps: f64) -> Self {
        StreamingRequestSink {
            slo_ttft_s: cfg.slo_ttft_s,
            slo_e2e_s: cfg.slo_e2e_s,
            fold: ExactFold::default(),
            sketches: LatencySketches::new(eps),
        }
    }

    /// The sketches' rank-error parameter ε.
    pub fn epsilon(&self) -> f64 {
        self.sketches.ttft.epsilon()
    }

    /// Total resident sketch tuples across the four distributions —
    /// the sink's whole per-request memory footprint.
    pub fn resident_tuples(&self) -> usize {
        self.sketches.resident_tuples()
    }

    /// Normalized-latency quantile (s per output token) — beyond the
    /// mean that [`RequestStats`] carries.
    pub fn norm_latency_quantile(&self, q: f64) -> Option<f64> {
        self.sketches.norm_latency.quantile(q)
    }

    /// Queue-delay quantile beyond the p50 in [`RequestStats`].
    pub fn queue_delay_quantile(&self, q: f64) -> Option<f64> {
        self.sketches.queue_delay.quantile(q)
    }

    /// Borrow the latency sketches (e.g. to serialize alongside
    /// `stats()` without consuming the sink).
    pub fn sketches(&self) -> &LatencySketches {
        &self.sketches
    }

    /// Take the latency sketches out of a finished sink — the
    /// per-case telemetry a sharded sweep persists so shards can later
    /// merge into one distribution (DESIGN.md §9).
    pub fn into_sketches(self) -> LatencySketches {
        self.sketches
    }
}

impl RequestSink for StreamingRequestSink {
    fn record(&mut self, r: &Request) {
        self.fold.add(r, self.slo_ttft_s, self.slo_e2e_s);
        if let Some(t) = r.ttft() {
            self.sketches.ttft.add(t);
        }
        if let Some(l) = r.e2e_latency() {
            self.sketches.e2e.add(l);
            self.sketches
                .norm_latency
                .add(l / r.decode_tokens.max(1) as f64);
        }
        if let Some(s) = r.scheduled_s {
            self.sketches.queue_delay.add(s - r.arrival_s);
        }
    }

    fn stats(&self) -> RequestStats {
        let mut st = RequestStats {
            submitted: self.fold.finished,
            finished: self.fold.finished,
            prefill_tokens_done: self.fold.prefill_tokens_done,
            decode_tokens_done: self.fold.decode_tokens_done,
            norm_latency_mean_s_per_tok: self.fold.norm_mean(),
            norm_latency_n: self.fold.norm_n,
            slo_ttft_ok: self.fold.slo_ttft_ok,
            slo_e2e_ok: self.fold.slo_e2e_ok,
            slo_both_ok: self.fold.slo_both_ok,
            ..RequestStats::default()
        };
        // One flush per sketch regardless of how many quantiles are
        // read off it.
        self.sketches.apply_quantiles(&mut st);
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finished_req(id: u64, arrival: f64, ttft: f64, e2e: f64) -> Request {
        let mut r = Request::new(id, arrival, 100, 10);
        r.prefill_done = 100;
        r.decode_done = 10;
        r.scheduled_s = Some(arrival + ttft * 0.5);
        r.first_token_s = Some(arrival + ttft);
        r.finished_s = Some(arrival + e2e);
        r
    }

    /// The exact side of the parity contract: counts, token totals,
    /// SLO counters, and the normalized-latency mean agree across
    /// sinks on the same completion stream.
    #[test]
    fn sinks_agree_on_exact_aggregates() {
        let cfg = SimConfig::default();
        let mut log = RequestLog::new(&cfg);
        let mut stream = StreamingRequestSink::new(&cfg);
        for i in 0..500u64 {
            let r = finished_req(
                i,
                i as f64 * 0.1,
                0.05 + (i % 40) as f64,
                1.0 + (i % 90) as f64,
            );
            log.record(&r);
            stream.record(&r);
        }
        let a = log.stats();
        let b = stream.stats();
        assert_eq!(a.finished, b.finished);
        assert_eq!(a.prefill_tokens_done, b.prefill_tokens_done);
        assert_eq!(a.decode_tokens_done, b.decode_tokens_done);
        assert_eq!(a.slo_ttft_ok, b.slo_ttft_ok);
        assert_eq!(a.slo_e2e_ok, b.slo_e2e_ok);
        assert_eq!(a.slo_both_ok, b.slo_both_ok);
        assert_eq!(
            a.norm_latency_mean_s_per_tok,
            b.norm_latency_mean_s_per_tok
        );
        assert_eq!(a.tokens_done(), 500 * 110);
        // Quantiles: approximate, but within the sketch's rank error
        // (coarse check here; the rank-level assertion lives in
        // tests/request_telemetry.rs).
        assert!((a.ttft_p50_s - b.ttft_p50_s).abs() <= 2.0);
        assert!((a.e2e_p99_s - b.e2e_p99_s).abs() <= 3.0);
    }

    #[test]
    fn into_requests_restores_id_order() {
        let cfg = SimConfig::default();
        let mut log = RequestLog::new(&cfg);
        // Completion order ≠ id order.
        for id in [2u64, 0, 1] {
            log.record(&finished_req(id, id as f64, 0.5, 2.0));
        }
        let reqs = log.into_requests();
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn empty_sinks_report_zeroes() {
        let cfg = SimConfig::default();
        let s = StreamingRequestSink::new(&cfg);
        let st = s.stats();
        assert_eq!(st.finished, 0);
        assert_eq!(st.ttft_p99_s, 0.0);
        assert_eq!(st.norm_latency_mean_s_per_tok, 0.0);
        assert_eq!(s.resident_tuples(), 0);
        assert_eq!(RequestLog::new(&cfg).stats(), st);
    }

    /// Shard-merge contract on the request side: recording a stream
    /// split across two streaming sinks and merging their stats +
    /// sketches reproduces the whole-stream accumulator — counters
    /// exactly, quantiles within the combined rank bound.
    #[test]
    fn request_stats_and_sketches_merge_matches_unsharded() {
        let cfg = SimConfig::default();
        let mut whole = StreamingRequestSink::new(&cfg);
        let mut a = StreamingRequestSink::new(&cfg);
        let mut b = StreamingRequestSink::new(&cfg);
        for i in 0..800u64 {
            let r = finished_req(
                i,
                i as f64 * 0.05,
                0.05 + (i % 37) as f64 * 0.3,
                1.0 + (i % 83) as f64,
            );
            whole.record(&r);
            if i % 2 == 0 {
                a.record(&r);
            } else {
                b.record(&r);
            }
        }
        let want = whole.stats();
        let mut merged = a.stats();
        merged.merge(&b.stats());
        // Counters and the mean are exact.
        assert_eq!(merged.submitted, want.submitted);
        assert_eq!(merged.finished, want.finished);
        assert_eq!(merged.prefill_tokens_done, want.prefill_tokens_done);
        assert_eq!(merged.decode_tokens_done, want.decode_tokens_done);
        assert_eq!(merged.slo_ttft_ok, want.slo_ttft_ok);
        assert_eq!(merged.slo_e2e_ok, want.slo_e2e_ok);
        assert_eq!(merged.slo_both_ok, want.slo_both_ok);
        assert_eq!(merged.norm_latency_n, want.norm_latency_n);
        assert!(
            (merged.norm_latency_mean_s_per_tok - want.norm_latency_mean_s_per_tok).abs()
                < 1e-12
        );
        // Quantiles were reset by merge() and come back from the
        // merged sketches.
        assert_eq!(merged.ttft_p50_s, 0.0);
        let mut sk = a.into_sketches();
        sk.merge(b.sketches());
        sk.apply_quantiles(&mut merged);
        // ε = 1e-3, n = 800 → rank bound ⌈εn⌉ = 1; the TTFT grid step
        // is 0.3 s, e2e step 1 s: one rank is at most one step.
        assert!((merged.ttft_p50_s - want.ttft_p50_s).abs() <= 0.3 + 1e-9);
        assert!((merged.e2e_p99_s - want.e2e_p99_s).abs() <= 1.0 + 1e-9);
        assert!((merged.queue_delay_p50_s - want.queue_delay_p50_s).abs() <= 0.15 + 1e-9);
        // Sidecar round-trip of both halves is lossless.
        let stats_back = RequestStats::from_json(&want.to_json()).unwrap();
        assert_eq!(stats_back, want);
        let sk_back = LatencySketches::from_json(&sk.to_json()).unwrap();
        assert_eq!(sk_back.ttft.quantile(0.5), sk.ttft.quantile(0.5));
        assert_eq!(sk_back.e2e.count(), sk.e2e.count());
    }

    #[test]
    fn unfinished_requests_count_as_slo_misses() {
        let cfg = SimConfig::default();
        let mut stream = StreamingRequestSink::new(&cfg);
        let mut r = Request::new(0, 0.0, 100, 10);
        r.scheduled_s = Some(0.5); // scheduled but never finished
        stream.record(&r);
        let st = stream.stats();
        assert_eq!(st.finished, 1);
        assert_eq!(st.slo_ttft_ok, 0);
        assert_eq!(st.slo_e2e_ok, 0);
        assert_eq!(st.slo_both_ok, 0);
        assert_eq!(st.queue_delay_p50_s, 0.5);
    }
}
