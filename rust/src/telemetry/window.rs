//! Rolling-window telemetry and watch snapshots (DESIGN.md §10).
//!
//! The sweep sinks answer *end-of-run* questions; a live view needs
//! *recent* ones: what is the completion rate right now, the rolling
//! p99 TTFT, the current draw in watts. This module builds those
//! answers on the shared [`TimeWindow`] ring buffer
//! (`util::stats`, the same shape `autoscale::CompletionWindow` runs
//! on):
//!
//! * [`WindowedRequests`] — a [`RequestSink`] keeping the trailing
//!   window of completions (TTFT / e2e / normalized-latency samples +
//!   token counts) alongside cumulative totals;
//! * [`WindowedStages`] — a [`StageSink`] keeping the trailing window
//!   of stage samples (duration, MFU·dt, busy GPU-time, stage joules)
//!   alongside cumulative stage energy;
//! * [`Snapshot`] — the cheap serializable struct a dashboard consumes
//!   (one JSONL line per snapshot, format
//!   [`SNAPSHOT_FORMAT`]);
//! * [`CaseWatch`] — glues one simulation case's two windows together
//!   and emits a [`Snapshot`] every `cadence_s` of **simulation
//!   time**, plus one final `done` snapshot carrying the case totals.
//!
//! Windowed counters are incremental (adjusted on push/evict, never
//! rescanned) and must equal an exact recompute over the retained
//! suffix — a property test below drives random streams and window
//! sizes through both paths. Windowed quantiles are *exact* over the
//! retained samples (the window already holds them; no sketch needed —
//! the ε-sketches remain the right tool for the unbounded cumulative
//! distributions, and stay untouched in the primary sinks).
//!
//! Everything here attaches through [`crate::telemetry::fanout`]; the
//! engine is untouched.

use crate::config::simconfig::SimConfig;
use crate::telemetry::{RequestSink, RequestStats, StageRecord, StageSink, StageStats};
use crate::util::json::Value;
use crate::util::stats::{percentile, percentile_sorted, Summary, TimeWindow};
use crate::workload::Request;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// Format tag written into every snapshot line; bumped on breaking
/// changes.
pub const SNAPSHOT_FORMAT: &str = "vidur-energy/watch-snapshot/v1";

/// One completed request's windowed sample.
#[derive(Debug, Clone)]
struct ReqSample {
    ttft: Option<f64>,
    e2e: Option<f64>,
    norm: Option<f64>,
    tokens: u64,
}

/// Rolling window over recent completions + cumulative request totals.
/// Keyed by finish time (the completion stream is monotone in it).
#[derive(Debug)]
pub struct WindowedRequests {
    window: TimeWindow<ReqSample>,
    /// Incremental Σ tokens over the retained window.
    win_tokens: u64,
    /// Cumulative completions.
    finished: u64,
    /// Cumulative prefill+decode tokens of completions.
    tokens_done: u64,
    /// Latest completion time seen.
    last_t: f64,
}

impl WindowedRequests {
    pub fn new(window_s: f64) -> Self {
        WindowedRequests {
            window: TimeWindow::new(window_s),
            win_tokens: 0,
            finished: 0,
            tokens_done: 0,
            last_t: 0.0,
        }
    }

    /// Fold one completion in and evict entries that fell out of the
    /// trailing window.
    pub fn observe(&mut self, r: &Request) {
        // Completions arrive in finish order; the clamp keeps the
        // window keys monotone even for a hypothetical caller feeding
        // an unfinished request (no `finished_s`), whose arrival-time
        // fallback could otherwise lodge a stale entry behind newer
        // ones and inflate the windowed rates until it drained out.
        let t = r.finished_s.unwrap_or(r.arrival_s).max(self.last_t);
        let tokens = r.prefill_done + r.decode_done;
        self.finished += 1;
        self.tokens_done += tokens;
        self.last_t = self.last_t.max(t);
        self.window.push(
            t,
            ReqSample {
                ttft: r.ttft(),
                e2e: r.e2e_latency(),
                norm: r.e2e_latency().map(|l| l / r.decode_tokens.max(1) as f64),
                tokens,
            },
        );
        self.win_tokens += tokens;
        self.prune(self.last_t);
    }

    /// Completions retained in the window.
    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// Tokens retained in the window (incremental; equals the exact
    /// recompute over the retained suffix).
    pub fn window_tokens(&self) -> u64 {
        self.win_tokens
    }

    /// Cumulative completions.
    pub fn finished(&self) -> u64 {
        self.finished
    }

    /// Cumulative tokens of completions.
    pub fn tokens_done(&self) -> u64 {
        self.tokens_done
    }

    /// Latest completion time seen (0 before the first).
    pub fn last_t(&self) -> f64 {
        self.last_t
    }

    /// Windowed completions per second.
    pub fn qps(&self, now: f64) -> f64 {
        self.window.rate(now)
    }

    fn collect(&self, f: impl Fn(&ReqSample) -> Option<f64>) -> Vec<f64> {
        self.window.iter().filter_map(|(_, s)| f(s)).collect()
    }

    fn windowed_quantile(&self, f: impl Fn(&ReqSample) -> Option<f64>, p: f64) -> Option<f64> {
        let v = self.collect(f);
        if v.is_empty() {
            None
        } else {
            Some(percentile(&v, p))
        }
    }

    /// Exact windowed TTFT percentile (`p` ∈ [0, 100]).
    pub fn ttft_percentile(&self, p: f64) -> Option<f64> {
        self.windowed_quantile(|s| s.ttft, p)
    }

    /// Exact windowed e2e-latency percentile.
    pub fn e2e_percentile(&self, p: f64) -> Option<f64> {
        self.windowed_quantile(|s| s.e2e, p)
    }

    /// Exact windowed normalized-latency percentile (s per output
    /// token).
    pub fn norm_latency_percentile(&self, p: f64) -> Option<f64> {
        self.windowed_quantile(|s| s.norm, p)
    }

    /// Evict without observing (e.g. on a timer tick).
    pub fn prune(&mut self, now: f64) {
        let win_tokens = &mut self.win_tokens;
        self.window.prune_each(now, |_, s| *win_tokens -= s.tokens);
    }

    /// One-pass read-out of the three windowed latency distributions —
    /// each collected and sorted once, however many percentiles a
    /// snapshot then reads off it (the per-percentile accessors above
    /// re-collect per call, which is fine for a single quantile but
    /// 5× the work for a full snapshot).
    pub fn latencies(&self) -> WindowedLatencies {
        let mut l = WindowedLatencies {
            ttft: self.collect(|s| s.ttft),
            e2e: self.collect(|s| s.e2e),
            norm: self.collect(|s| s.norm),
        };
        for v in [&mut l.ttft, &mut l.e2e, &mut l.norm] {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        l
    }
}

/// Sorted windowed latency samples ([`WindowedRequests::latencies`]);
/// percentile reads are O(1) interpolations on the sorted vectors.
pub struct WindowedLatencies {
    ttft: Vec<f64>,
    e2e: Vec<f64>,
    norm: Vec<f64>,
}

impl WindowedLatencies {
    fn pc(v: &[f64], p: f64) -> Option<f64> {
        if v.is_empty() {
            None
        } else {
            Some(percentile_sorted(v, p))
        }
    }

    /// Windowed TTFT percentile (`p` ∈ [0, 100]).
    pub fn ttft(&self, p: f64) -> Option<f64> {
        Self::pc(&self.ttft, p)
    }

    /// Windowed e2e-latency percentile.
    pub fn e2e(&self, p: f64) -> Option<f64> {
        Self::pc(&self.e2e, p)
    }

    /// Windowed normalized-latency percentile (s per output token).
    pub fn norm_latency(&self, p: f64) -> Option<f64> {
        Self::pc(&self.norm, p)
    }
}

impl RequestSink for WindowedRequests {
    fn record(&mut self, r: &Request) {
        self.observe(r);
    }

    /// A **windowed** view of the request aggregates (dashboard tap);
    /// run-level SLO metrics come from the primary sink, never from
    /// here.
    fn stats(&self) -> RequestStats {
        let lat = self.latencies();
        let q = |v: Option<f64>| v.unwrap_or(0.0);
        RequestStats {
            submitted: self.window.len() as u64,
            finished: self.window.len() as u64,
            ttft_p50_s: q(lat.ttft(50.0)),
            ttft_p99_s: q(lat.ttft(99.0)),
            e2e_p50_s: q(lat.e2e(50.0)),
            e2e_p99_s: q(lat.e2e(99.0)),
            ..RequestStats::default()
        }
    }
}

/// One executed stage's windowed sample.
#[derive(Debug, Clone)]
struct StageSample {
    dt_s: f64,
    mfu_dt: f64,
    busy_gpu_s: f64,
    joules: f64,
    batch: f64,
}

/// Rolling window over recent stages + cumulative stage energy. Keyed
/// by stage **end** time; pruned against the running maximum so the
/// bounded skew between pipeline stages of different replicas never
/// runs the window backwards.
#[derive(Debug)]
pub struct WindowedStages {
    window: TimeWindow<StageSample>,
    p_idle: f64,
    win_dt: f64,
    win_mfu_dt: f64,
    win_busy: f64,
    win_joules: f64,
    /// Cumulative stage count.
    stages: u64,
    /// Cumulative stage-covered energy, J (active GPUs at the stage's
    /// Eq. 1 power + replica-idle GPUs at `p_idle`; between-stage idle
    /// gaps are *not* filled — that is the accountant's job, so this is
    /// a live lower bound on the accounted total).
    joules: f64,
    last_t: f64,
}

impl WindowedStages {
    pub fn new(window_s: f64, p_idle: f64) -> Self {
        WindowedStages {
            window: TimeWindow::new(window_s),
            p_idle,
            win_dt: 0.0,
            win_mfu_dt: 0.0,
            win_busy: 0.0,
            win_joules: 0.0,
            stages: 0,
            joules: 0.0,
            last_t: 0.0,
        }
    }

    /// Fold one stage record in and evict what fell out of the window.
    pub fn observe(&mut self, r: &StageRecord) {
        let t = r.end_s();
        let joules = r.replica_power_w(self.p_idle) * r.dt_s;
        self.stages += 1;
        self.joules += joules;
        self.last_t = self.last_t.max(t);
        let s = StageSample {
            dt_s: r.dt_s,
            mfu_dt: r.mfu * r.dt_s,
            busy_gpu_s: r.dt_s * r.active_gpus as f64,
            joules,
            batch: r.batch_size as f64,
        };
        self.win_dt += s.dt_s;
        self.win_mfu_dt += s.mfu_dt;
        self.win_busy += s.busy_gpu_s;
        self.win_joules += s.joules;
        self.window.push(t, s);
        // One eviction path: prune() owns the counter bookkeeping.
        self.prune(self.last_t);
    }

    /// Stages retained in the window.
    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// Cumulative stage count.
    pub fn stages(&self) -> u64 {
        self.stages
    }

    /// Cumulative stage-covered energy, kWh (see `joules` note).
    pub fn energy_kwh(&self) -> f64 {
        self.joules / 3.6e6
    }

    /// Latest stage end time seen.
    pub fn last_t(&self) -> f64 {
        self.last_t
    }

    /// Windowed average power, W: stage joules in the window over the
    /// (elapsed part of the) window.
    pub fn power_w(&self, now: f64) -> f64 {
        self.win_joules / self.window.elapsed(now)
    }

    /// Windowed duration-weighted MFU.
    pub fn mfu(&self) -> f64 {
        if self.win_dt == 0.0 {
            0.0
        } else {
            self.win_mfu_dt / self.win_dt
        }
    }

    /// Windowed busy GPU-seconds.
    pub fn busy_gpu_s(&self) -> f64 {
        self.win_busy
    }

    /// Evict without observing (e.g. before taking a snapshot at a
    /// time past the last stage).
    pub fn prune(&mut self, now: f64) {
        let (dt, mfu, busy, j) = (
            &mut self.win_dt,
            &mut self.win_mfu_dt,
            &mut self.win_busy,
            &mut self.win_joules,
        );
        self.window.prune_each(now, |_, s| {
            *dt -= s.dt_s;
            *mfu -= s.mfu_dt;
            *busy -= s.busy_gpu_s;
            *j -= s.joules;
        });
    }
}

impl StageSink for WindowedStages {
    fn record(&mut self, r: StageRecord) {
        self.observe(&r);
    }

    /// A **windowed** view of the stage aggregates (dashboard tap);
    /// run-level metrics come from the primary sink.
    fn stats(&self) -> StageStats {
        let mut batch = Summary::new();
        let mut span = (f64::INFINITY, f64::NEG_INFINITY);
        for (t, s) in self.window.iter() {
            batch.add(s.batch);
            span = (span.0.min(t - s.dt_s), span.1.max(t));
        }
        let n = self.window.len() as u64;
        StageStats {
            stages: n,
            weighted_mfu: self.mfu(),
            dt_sum: self.win_dt,
            mean_batch: if n == 0 { 0.0 } else { batch.mean() },
            batch_std: batch.std(),
            busy_gpu_s: self.win_busy,
            span: if n == 0 { (0.0, 0.0) } else { span },
        }
    }
}

/// One dashboard/JSONL snapshot of a running (or finished) case.
/// Rolling fields cover the trailing window; `finished`, `stages`,
/// `energy_kwh`, `gco2_g` are cumulative for the case, so summing the
/// `done` snapshots across cases reproduces the sweep totals that land
/// in `meta.json`/`telemetry.json` (the CI watch-smoke checks exactly
/// that).
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Experiment id (`exp1`, `autoscale`, …).
    pub experiment: String,
    /// Shard that produced this (`k/N`), `None` unsharded.
    pub shard: Option<String>,
    /// Global case index within the experiment grid.
    pub case_index: u64,
    /// Process-wide emission sequence number (strictly increasing
    /// across cases; stamped by the live view).
    pub seq: u64,
    /// Case simulation time of the snapshot, seconds (monotone per
    /// case).
    pub t_s: f64,
    /// Final snapshot of a completed case (carries the case totals).
    pub done: bool,
    /// Cases finished so far by this process (stamped by the view;
    /// **shard-local** under `--shard`).
    pub cases_done: u64,
    /// Cases this process owns — `cases_done`'s denominator; equals
    /// `cases_total` unless sharded (stamped by the view).
    pub cases_owned: u64,
    /// Full grid size across all shards (stamped by the view).
    pub cases_total: u64,
    /// Cumulative completions of this case.
    pub finished: u64,
    /// Cumulative stages of this case.
    pub stages: u64,
    /// Windowed completion rate, 1/s.
    pub qps: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    pub norm_latency_p50_s_per_tok: f64,
    /// Windowed average power, W.
    pub power_w: f64,
    /// Windowed duration-weighted MFU.
    pub mfu: f64,
    /// Cumulative stage-covered energy, kWh.
    pub energy_kwh: f64,
    /// Cumulative operational carbon at the accounting CI, g.
    pub gco2_g: f64,
}

impl Snapshot {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("format", SNAPSHOT_FORMAT)
            .set("experiment", self.experiment.as_str())
            .set(
                "shard",
                match &self.shard {
                    Some(s) => Value::Str(s.clone()),
                    None => Value::Null,
                },
            )
            .set("case", self.case_index)
            .set("seq", self.seq)
            .set("t_s", self.t_s)
            .set("done", self.done)
            .set("cases_done", self.cases_done)
            .set("cases_owned", self.cases_owned)
            .set("cases_total", self.cases_total)
            .set("finished", self.finished)
            .set("stages", self.stages)
            .set("qps", self.qps)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("e2e_p50_s", self.e2e_p50_s)
            .set("e2e_p99_s", self.e2e_p99_s)
            .set(
                "norm_latency_p50_s_per_tok",
                self.norm_latency_p50_s_per_tok,
            )
            .set("power_w", self.power_w)
            .set("mfu", self.mfu)
            .set("energy_kwh", self.energy_kwh)
            .set("gco2_g", self.gco2_g);
        v
    }

    pub fn from_json(v: &Value) -> Result<Snapshot> {
        let format = v.req_str("format")?;
        anyhow::ensure!(
            format == SNAPSHOT_FORMAT,
            "unknown watch snapshot format '{format}' (expected '{SNAPSHOT_FORMAT}')"
        );
        let shard = match v.get("shard") {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(Value::Null) | None => None,
            Some(other) => anyhow::bail!("bad 'shard' field: {}", other.to_string()),
        };
        Ok(Snapshot {
            experiment: v.req_str("experiment")?.to_string(),
            shard,
            case_index: v.req_u64("case")?,
            seq: v.req_u64("seq")?,
            t_s: v.req_f64("t_s")?,
            done: v
                .get("done")
                .and_then(|b| b.as_bool())
                .ok_or_else(|| anyhow::anyhow!("missing/non-bool json field 'done'"))?,
            cases_done: v.req_u64("cases_done")?,
            cases_owned: v.req_u64("cases_owned")?,
            cases_total: v.req_u64("cases_total")?,
            finished: v.req_u64("finished")?,
            stages: v.req_u64("stages")?,
            qps: v.req_f64("qps")?,
            ttft_p50_s: v.req_f64("ttft_p50_s")?,
            ttft_p99_s: v.req_f64("ttft_p99_s")?,
            e2e_p50_s: v.req_f64("e2e_p50_s")?,
            e2e_p99_s: v.req_f64("e2e_p99_s")?,
            norm_latency_p50_s_per_tok: v.req_f64("norm_latency_p50_s_per_tok")?,
            power_w: v.req_f64("power_w")?,
            mfu: v.req_f64("mfu")?,
            energy_kwh: v.req_f64("energy_kwh")?,
            gco2_g: v.req_f64("gco2_g")?,
        })
    }
}

/// Receives each emitted snapshot; the live view stamps the
/// process-wide fields (`seq`, `cases_done`, `cases_total`) and
/// renders/appends it. `Send + Sync` because sweep cases emit from
/// worker threads.
pub type SnapshotEmitter = Arc<dyn Fn(&mut Snapshot) + Send + Sync>;

/// Combine emitters into one that calls each in order on the same
/// snapshot. Later emitters see mutations made by earlier ones — the
/// convention (shared with [`crate::report::live::LiveView::emitter`])
/// is that the *first* emitter stamps the process-wide fields and the
/// rest only observe, which is exactly what the serve plane's
/// broadcast tap wants.
pub fn fan_emitters(emitters: Vec<SnapshotEmitter>) -> SnapshotEmitter {
    Arc::new(move |s: &mut Snapshot| {
        for e in &emitters {
            (*e)(s);
        }
    })
}

/// Shared state of one observed case (single-threaded: a sweep case
/// runs wholly on the worker that claimed it, so `Rc<RefCell>` is the
/// right tool — the cross-thread boundary is the emitter).
struct WatchState {
    experiment: String,
    shard: Option<String>,
    case_index: u64,
    cadence_s: f64,
    ci_g_per_kwh: f64,
    req: WindowedRequests,
    stage: WindowedStages,
    next_emit_s: f64,
    last_emit_t: f64,
    emit: SnapshotEmitter,
}

impl WatchState {
    fn now(&self) -> f64 {
        self.req.last_t().max(self.stage.last_t())
    }

    fn snapshot(&self, t: f64, done: bool) -> Snapshot {
        let q = |v: Option<f64>| v.unwrap_or(0.0);
        // One collect + sort per distribution for all five quantiles.
        let lat = self.req.latencies();
        Snapshot {
            experiment: self.experiment.clone(),
            shard: self.shard.clone(),
            case_index: self.case_index,
            seq: 0,        // stamped by the view
            cases_done: 0, // stamped by the view
            cases_owned: 0,
            cases_total: 0,
            t_s: t,
            done,
            finished: self.req.finished(),
            stages: self.stage.stages(),
            qps: self.req.qps(t),
            ttft_p50_s: q(lat.ttft(50.0)),
            ttft_p99_s: q(lat.ttft(99.0)),
            e2e_p50_s: q(lat.e2e(50.0)),
            e2e_p99_s: q(lat.e2e(99.0)),
            norm_latency_p50_s_per_tok: q(lat.norm_latency(50.0)),
            power_w: self.stage.power_w(t),
            mfu: self.stage.mfu(),
            energy_kwh: self.stage.energy_kwh(),
            gco2_g: self.stage.energy_kwh() * self.ci_g_per_kwh,
        }
    }

    fn emit_at(&mut self, t: f64, done: bool) {
        // Monotone-per-case guard: pipeline-stage skew may hand us a
        // timestamp slightly behind the last emission.
        let t = t.max(self.last_emit_t);
        self.last_emit_t = t;
        // Each window was last pruned at its *own* stream's latest
        // time; when one stream lags the other (e.g. no completion for
        // a whole window during a saturated prefill phase) it would
        // otherwise report stale rates at the snapshot time.
        self.req.prune(t);
        self.stage.prune(t);
        let mut s = self.snapshot(t, done);
        // `Arc<dyn Fn>` has no `Fn` impl of its own: call through the
        // deref'd trait object.
        (*self.emit)(&mut s);
    }

    fn maybe_emit(&mut self) {
        let t = self.now();
        if t >= self.next_emit_s {
            self.emit_at(t, false);
            // One snapshot per crossing, however much sim time the
            // triggering event skipped.
            self.next_emit_s = (t / self.cadence_s).floor() * self.cadence_s + self.cadence_s;
        }
    }
}

/// Live-watch attachment for one simulation case: a pair of sink taps
/// (stage + request) over shared rolling windows, emitting a
/// [`Snapshot`] every `cadence_s` of simulation time and once more at
/// [`CaseWatch::finish`]. Attach the taps through the fan-out sinks;
/// the primary accumulators — and therefore every persisted output —
/// are untouched.
pub struct CaseWatch {
    state: Rc<RefCell<WatchState>>,
}

impl CaseWatch {
    /// `window_s` is the rolling-window span, `cadence_s` the sim-time
    /// emission period, `ci_g_per_kwh` the carbon intensity used for
    /// the cumulative gCO₂ line.
    pub fn new(
        cfg: &SimConfig,
        window_s: f64,
        cadence_s: f64,
        ci_g_per_kwh: f64,
        experiment: &str,
        shard: Option<String>,
        case_index: u64,
        emit: SnapshotEmitter,
    ) -> Result<CaseWatch> {
        anyhow::ensure!(cadence_s > 0.0, "watch cadence must be positive");
        let p_idle = cfg.gpu_spec()?.p_idle;
        Ok(CaseWatch {
            state: Rc::new(RefCell::new(WatchState {
                experiment: experiment.to_string(),
                shard,
                case_index,
                cadence_s,
                ci_g_per_kwh,
                req: WindowedRequests::new(window_s),
                stage: WindowedStages::new(window_s, p_idle),
                next_emit_s: cadence_s,
                last_emit_t: 0.0,
                emit,
            })),
        })
    }

    /// The two sink taps to attach behind the fan-outs.
    pub fn taps(&self) -> (WatchStageTap, WatchRequestTap) {
        (
            WatchStageTap {
                state: self.state.clone(),
            },
            WatchRequestTap {
                state: self.state.clone(),
            },
        )
    }

    /// Emit the final `done` snapshot (carries the case totals).
    pub fn finish(&self) {
        let mut st = self.state.borrow_mut();
        let t = st.now();
        st.emit_at(t, true);
    }
}

/// Stage-side tap of a [`CaseWatch`].
pub struct WatchStageTap {
    state: Rc<RefCell<WatchState>>,
}

impl StageSink for WatchStageTap {
    fn record(&mut self, r: StageRecord) {
        let mut st = self.state.borrow_mut();
        st.stage.observe(&r);
        st.maybe_emit();
    }

    fn stats(&self) -> StageStats {
        self.state.borrow().stage.stats()
    }
}

/// Request-side tap of a [`CaseWatch`].
pub struct WatchRequestTap {
    state: Rc<RefCell<WatchState>>,
}

impl RequestSink for WatchRequestTap {
    fn record(&mut self, r: &Request) {
        let mut st = self.state.borrow_mut();
        st.req.observe(r);
        st.maybe_emit();
    }

    fn stats(&self) -> RequestStats {
        self.state.borrow().req.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::replica::StageKind;
    use std::sync::Mutex;

    fn done_req(id: u64, fin: f64, ttft: f64, e2e: f64) -> Request {
        let mut r = Request::new(id, fin - e2e, 40, 10);
        r.prefill_done = 40;
        r.decode_done = 10;
        r.scheduled_s = Some(fin - e2e);
        r.first_token_s = Some(fin - e2e + ttft);
        r.finished_s = Some(fin);
        r
    }

    fn stage(end: f64, dt: f64, mfu: f64, batch: u32) -> StageRecord {
        StageRecord {
            replica: 0,
            pp_stage: 0,
            start_s: end - dt,
            dt_s: dt,
            batch_size: batch,
            new_tokens: batch,
            mfu,
            power_w: 300.0,
            active_gpus: 1,
            idle_gpus: 0,
            flops: 1e12,
            kind: StageKind::Decode,
        }
    }

    /// Property (satellite): windowed counters and quantiles over a
    /// sliding window equal an exact recompute on the retained suffix
    /// for random streams and window sizes.
    #[test]
    fn windowed_requests_match_exact_recompute() {
        use crate::util::proptest::{check, gens};
        check(60, gens::vec_f64(48, 0.05, 9.0), |dts| {
            for window_s in [1.0, 12.0, 200.0] {
                let mut w = WindowedRequests::new(window_s);
                let mut t = 0.0;
                for (i, dt) in dts.iter().enumerate() {
                    t += dt;
                    let ttft = 0.1 + (i % 13) as f64 * 0.21;
                    let e2e = 1.0 + (i % 7) as f64 * 1.7;
                    w.observe(&done_req(i as u64, t, ttft, e2e));
                    // Exact recompute over the retained suffix.
                    let tokens: u64 = w.window.iter().map(|(_, s)| s.tokens).sum();
                    if tokens != w.window_tokens() {
                        return Err(format!(
                            "win tokens {} != recompute {tokens} (step {i}, window {window_s})",
                            w.window_tokens()
                        ));
                    }
                    let ttfts: Vec<f64> =
                        w.window.iter().filter_map(|(_, s)| s.ttft).collect();
                    let want = percentile(&ttfts, 99.0);
                    let got = w.ttft_percentile(99.0).unwrap();
                    if (got - want).abs() > 1e-12 {
                        return Err(format!("windowed p99 {got} != exact {want}"));
                    }
                }
                if w.finished() != dts.len() as u64 {
                    return Err("cumulative count drifted".into());
                }
            }
            Ok(())
        });
    }

    /// Same property on the stage side (all four incremental sums).
    #[test]
    fn windowed_stages_match_exact_recompute() {
        use crate::util::proptest::{check, gens};
        check(60, gens::vec_f64(48, 0.05, 9.0), |dts| {
            for window_s in [1.0, 15.0, 500.0] {
                let mut w = WindowedStages::new(window_s, 100.0);
                let mut t = 0.0;
                for (i, step) in dts.iter().enumerate() {
                    t += step;
                    w.observe(&stage(t, 0.2 + (i % 5) as f64 * 0.1, 0.3, 1 + (i % 8) as u32));
                    let (mut dt, mut mfu, mut busy, mut j) = (0.0, 0.0, 0.0, 0.0);
                    for (_, s) in w.window.iter() {
                        dt += s.dt_s;
                        mfu += s.mfu_dt;
                        busy += s.busy_gpu_s;
                        j += s.joules;
                    }
                    for (name, inc, exact) in [
                        ("dt", w.win_dt, dt),
                        ("mfu_dt", w.win_mfu_dt, mfu),
                        ("busy", w.win_busy, busy),
                        ("joules", w.win_joules, j),
                    ] {
                        if (inc - exact).abs() > 1e-6 * (1.0 + exact.abs()) {
                            return Err(format!(
                                "win {name} {inc} != recompute {exact} \
                                 (step {i}, window {window_s})"
                            ));
                        }
                    }
                }
                if w.stages() != dts.len() as u64 {
                    return Err("cumulative stage count drifted".into());
                }
            }
            Ok(())
        });
    }

    /// Boundary cases the property's random streams may miss: empty
    /// window, single event, and eviction exactly at the cutoff.
    #[test]
    fn window_boundary_cases() {
        let w = WindowedRequests::new(60.0);
        assert_eq!(w.window_len(), 0);
        assert_eq!(w.ttft_percentile(99.0), None);
        assert_eq!(w.qps(30.0), 0.0);

        let mut one = WindowedRequests::new(60.0);
        one.observe(&done_req(0, 10.0, 0.5, 2.0));
        assert_eq!(one.window_len(), 1);
        assert_eq!(one.ttft_percentile(50.0), Some(0.5));
        // Elapsed-aware rate: 1 completion over 10 s, not over 60 s.
        assert!((one.qps(10.0) - 0.1).abs() < 1e-12);

        // Entry exactly at the cutoff is retained (inclusive window).
        let mut edge = WindowedRequests::new(10.0);
        edge.observe(&done_req(0, 5.0, 0.5, 2.0));
        edge.observe(&done_req(1, 15.0, 0.5, 2.0)); // cutoff = 5.0
        assert_eq!(edge.window_len(), 2, "t == cutoff must survive");
        edge.observe(&done_req(2, 15.1, 0.5, 2.0)); // cutoff = 5.1
        assert_eq!(edge.window_len(), 2, "t < cutoff must fall out");
    }

    /// fan_emitters calls every emitter in order on the same snapshot,
    /// so later emitters observe earlier stamps.
    #[test]
    fn fan_emitters_runs_all_in_order() {
        let log: Arc<Mutex<Vec<(u64, &'static str)>>> = Arc::new(Mutex::new(Vec::new()));
        let (l1, l2) = (log.clone(), log.clone());
        let stamp: SnapshotEmitter = Arc::new(move |s: &mut Snapshot| {
            s.seq = 7;
            l1.lock().unwrap().push((s.seq, "stamp"));
        });
        let observe: SnapshotEmitter = Arc::new(move |s: &mut Snapshot| {
            l2.lock().unwrap().push((s.seq, "observe"));
        });
        let fan = fan_emitters(vec![stamp, observe]);
        let cfg = SimConfig::default();
        let watch =
            CaseWatch::new(&cfg, 300.0, 60.0, 400.0, "expX", None, 0, fan).unwrap();
        watch.finish();
        let got = log.lock().unwrap();
        // The observer ran after the stamper and saw its mutation.
        assert_eq!(*got, vec![(7, "stamp"), (7, "observe")]);
    }

    /// CaseWatch emits on the sim-time cadence, stamps monotone
    /// per-case times, and finish() emits the `done` totals snapshot.
    #[test]
    fn case_watch_emits_on_cadence_and_finishes_with_totals() {
        let cfg = SimConfig::default();
        let got: Arc<Mutex<Vec<Snapshot>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = got.clone();
        let emit: SnapshotEmitter = Arc::new(move |s: &mut Snapshot| {
            sink.lock().unwrap().push(s.clone());
        });
        let watch = CaseWatch::new(
            &cfg, 300.0, 60.0, 400.0, "expX", Some("0/2".into()), 3, emit,
        )
        .unwrap();
        {
            let (mut st, mut rq) = watch.taps();
            for i in 0..50u64 {
                let t = i as f64 * 5.0; // 0..245 s: crosses 60/120/180/240
                st.record(stage(t + 0.4, 0.4, 0.25, 4));
                rq.record(&done_req(i, t + 0.5, 0.3, 1.5));
            }
        }
        watch.finish();
        let snaps = got.lock().unwrap();
        // Cadence crossings at 60, 120, 180, 240 plus the final one.
        assert_eq!(snaps.len(), 5, "{snaps:?}");
        assert!(snaps[..4].iter().all(|s| !s.done));
        let last = snaps.last().unwrap();
        assert!(last.done);
        assert_eq!(last.finished, 50);
        assert_eq!(last.stages, 50);
        assert_eq!(last.experiment, "expX");
        assert_eq!(last.shard.as_deref(), Some("0/2"));
        assert_eq!(last.case_index, 3);
        assert!(last.energy_kwh > 0.0);
        assert!((last.gco2_g - last.energy_kwh * 400.0).abs() < 1e-12);
        // Per-case sim time is monotone.
        for w in snaps.windows(2) {
            assert!(w[1].t_s >= w[0].t_s);
        }
        // JSON round-trip is lossless (seq/cases stamped or not).
        let back = Snapshot::from_json(&last.to_json()).unwrap();
        assert_eq!(back, *last);
        let text = last.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(Snapshot::from_json(&parsed).unwrap(), *last);
    }
}
