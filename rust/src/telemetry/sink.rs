//! Streaming stage telemetry (DESIGN.md §7).
//!
//! The engine emits one [`StageRecord`] per executed pipeline stage —
//! millions at production traffic. [`StageSink`] abstracts what happens
//! to them: the materialized [`StageLog`] keeps the full vector (needed
//! for per-stage CSV export and the ablation's re-accounting under
//! alternative power models), while [`StreamingSink`] folds each record
//! online into Eq. 5 bins, summary aggregates, and energy totals — so
//! a long run holds O(bins) state instead of O(stages).
//!
//! Parity is by construction, not by approximation: the streaming sink
//! runs the *same* accumulation code the materialized paths run
//! ([`BinAccumulator`] for Eq. 5, [`StageAggregates`] for Eq. 3/4), fed
//! in the same record order, so both paths produce bit-identical
//! profiles and reports (asserted in `tests/stream_parity.rs`).

use crate::autoscale::FleetTimeline;
use crate::config::simconfig::SimConfig;
use crate::energy::StageAggregates;
use crate::pipeline::{BinAccumulator, BinnedProfile};
use crate::power::PowerModel;
use crate::telemetry::{StageLog, StageRecord};
use crate::util::json::Value;
use crate::util::stats::Summary;
use anyhow::Result;

/// Aggregates the metrics layer consumes, regardless of sink kind.
///
/// Mergeable (DESIGN.md §9): [`StageStats::merge`] combines the
/// accumulators of two disjoint record streams — counts and sums add,
/// the weighted means recombine through their weights (`dt_sum` for
/// MFU, `stages` for batch statistics), spans union. That is what lets
/// per-shard stage telemetry from a cross-machine sweep fold into one
/// experiment-level aggregate without re-running.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageStats {
    /// Stage records produced.
    pub stages: u64,
    /// Duration-weighted mean MFU (Fig. 1's y-axis).
    pub weighted_mfu: f64,
    /// Total stage duration Σ Δt — `weighted_mfu`'s weight, carried so
    /// two `StageStats` can merge their means exactly.
    pub dt_sum: f64,
    /// Mean actual batch size across stages (Fig. 4 panel A).
    pub mean_batch: f64,
    pub batch_std: f64,
    /// Total busy GPU-seconds (active GPUs × stage durations).
    pub busy_gpu_s: f64,
    /// Busy span: earliest start to latest end (0,0 when empty).
    pub span: (f64, f64),
}

impl StageStats {
    /// Fold another (disjoint) record stream's aggregates into this
    /// one. Per-field semantics: `stages`, `dt_sum`, `busy_gpu_s` sum;
    /// `weighted_mfu` recombines weighted by `dt_sum`; the batch
    /// mean/std recombine via Chan's parallel-variance formula weighted
    /// by `stages`; `span` is the union (empty sides are ignored).
    pub fn merge(&mut self, other: &StageStats) {
        if other.stages == 0 {
            return;
        }
        if self.stages == 0 {
            *self = *other;
            return;
        }
        let (n1, n2) = (self.stages as f64, other.stages as f64);
        // Batch summary: reconstruct m2 from the sample std, merge, and
        // re-derive. Exact up to float rounding (counters stay exact).
        let m2_1 = self.batch_std * self.batch_std * (n1 - 1.0).max(0.0);
        let m2_2 = other.batch_std * other.batch_std * (n2 - 1.0).max(0.0);
        let d = other.mean_batch - self.mean_batch;
        let n = n1 + n2;
        let mean = self.mean_batch + d * n2 / n;
        let m2 = m2_1 + m2_2 + d * d * n1 * n2 / n;
        self.mean_batch = mean;
        self.batch_std = if n > 1.0 { (m2 / (n - 1.0)).sqrt() } else { 0.0 };

        let dt = self.dt_sum + other.dt_sum;
        self.weighted_mfu = if dt == 0.0 {
            0.0
        } else {
            (self.weighted_mfu * self.dt_sum + other.weighted_mfu * other.dt_sum) / dt
        };
        self.dt_sum = dt;
        self.busy_gpu_s += other.busy_gpu_s;
        self.stages += other.stages;
        self.span = (self.span.0.min(other.span.0), self.span.1.max(other.span.1));
    }

    /// Serialize for the shard telemetry sidecar.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("stages", self.stages)
            .set("weighted_mfu", self.weighted_mfu)
            .set("dt_sum", self.dt_sum)
            .set("mean_batch", self.mean_batch)
            .set("batch_std", self.batch_std)
            .set("busy_gpu_s", self.busy_gpu_s)
            .set("span_lo", self.span.0)
            .set("span_hi", self.span.1);
        v
    }

    /// Reload stats serialized by [`StageStats::to_json`].
    pub fn from_json(v: &Value) -> Result<StageStats> {
        Ok(StageStats {
            stages: v.req_u64("stages")?,
            weighted_mfu: v.req_f64("weighted_mfu")?,
            dt_sum: v.req_f64("dt_sum")?,
            mean_batch: v.req_f64("mean_batch")?,
            batch_std: v.req_f64("batch_std")?,
            busy_gpu_s: v.req_f64("busy_gpu_s")?,
            span: (v.req_f64("span_lo")?, v.req_f64("span_hi")?),
        })
    }
}

/// Consumer of the engine's per-stage telemetry. Object-safe: the
/// engine hot path takes `&mut dyn StageSink`.
pub trait StageSink {
    /// Accept one executed stage. Records arrive in production order
    /// (the engine's event order), which sinks may rely on.
    fn record(&mut self, r: StageRecord);

    /// Aggregates for [`crate::sim::SimMetrics`].
    fn stats(&self) -> StageStats;
}

impl StageSink for StageLog {
    fn record(&mut self, r: StageRecord) {
        self.push(r);
    }

    fn stats(&self) -> StageStats {
        StageStats {
            stages: self.len() as u64,
            weighted_mfu: self.weighted_mfu(),
            dt_sum: self.records.iter().map(|r| r.dt_s).sum(),
            mean_batch: self.batch_summary.mean(),
            batch_std: self.batch_summary.std(),
            busy_gpu_s: self.busy_gpu_seconds(),
            span: self.span(),
        }
    }
}

/// O(bins) streaming sink: folds stage records online into Eq. 5 bins
/// (via the shared [`BinAccumulator`]), physical energy aggregates
/// (via the shared [`StageAggregates`]), and the summary statistics
/// the metrics layer needs — never retaining the records themselves.
pub struct StreamingSink {
    bins: BinAccumulator,
    agg: StageAggregates,
    power_model: PowerModel,
    /// The accounting-side idle power (`power_model` at MFU 0, idle).
    p_idle_acct: f64,
    stages: u64,
    /// Σ mfu·Δt and Σ Δt for the duration-weighted MFU.
    mfu_dt: f64,
    dt_sum: f64,
    batch_summary: Summary,
    span_lo: f64,
    span_hi: f64,
}

impl StreamingSink {
    /// Sink binning at `interval_s` under the paper-default power
    /// model (Eq. 1 with the GPU's calibrated parameters).
    pub fn new(cfg: &SimConfig, interval_s: f64) -> Result<Self> {
        let model = PowerModel::paper_default(cfg.gpu_spec()?);
        Self::with_model(cfg, interval_s, model)
    }

    /// Sink whose energy aggregates follow an explicit power model —
    /// pass the same model the downstream
    /// [`EnergyAccountant`](crate::energy::EnergyAccountant) uses, or
    /// the report will silently mix power laws.
    pub fn with_model(cfg: &SimConfig, interval_s: f64, model: PowerModel) -> Result<Self> {
        anyhow::ensure!(interval_s > 0.0, "interval must be positive");
        cfg.gpu_spec()?;
        Ok(StreamingSink {
            // Bin under the same idle wattage the model accounts with,
            // so an overridden model yields a coherent Eq. 5 profile
            // (paper default: identical to the GPU spec's p_idle).
            bins: BinAccumulator::new(interval_s, model.power(0.0, false)),
            agg: StageAggregates::default(),
            p_idle_acct: model.power(0.0, false),
            power_model: model,
            stages: 0,
            mfu_dt: 0.0,
            dt_sum: 0.0,
            batch_summary: Summary::new(),
            span_lo: f64::INFINITY,
            span_hi: f64::NEG_INFINITY,
        })
    }

    /// Physical-mode energy aggregates (feed
    /// [`EnergyAccountant::report`](crate::energy::EnergyAccountant::report) /
    /// [`EnergyAccountant::report_fleet`](crate::energy::EnergyAccountant::report_fleet)).
    pub fn aggregates(&self) -> &StageAggregates {
        &self.agg
    }

    /// Peak resident bin count — the sink's whole per-stage memory
    /// footprint, O(makespan / interval) rather than O(stages).
    pub fn peak_resident_bins(&self) -> usize {
        self.bins.len()
    }

    /// Eq. 5 profile against a dynamic-fleet timeline.
    pub fn binned(&self, cfg: &SimConfig, fleet: &FleetTimeline) -> Result<BinnedProfile> {
        self.bins.finish(cfg, fleet)
    }

    /// Eq. 5 profile for a fixed fleet over `makespan_s`.
    pub fn binned_span(&self, cfg: &SimConfig, makespan_s: f64) -> Result<BinnedProfile> {
        self.bins
            .finish(cfg, &FleetTimeline::static_fleet(cfg.replicas, makespan_s))
    }

    /// The power model the aggregates were folded under.
    pub fn power_model(&self) -> &PowerModel {
        &self.power_model
    }

    /// The running batch-size summary (count/mean/std/extrema) — the
    /// same accumulator [`StageLog`] keeps, exposed so parity on the
    /// extrema is testable (they once disagreed through `Summary`'s
    /// derived `Default`).
    pub fn batch_summary(&self) -> &Summary {
        &self.batch_summary
    }
}

impl StageSink for StreamingSink {
    fn record(&mut self, r: StageRecord) {
        self.bins.add(&r);
        self.agg.add(&r, &self.power_model, self.p_idle_acct);
        self.stages += 1;
        self.mfu_dt += r.mfu * r.dt_s;
        self.dt_sum += r.dt_s;
        self.batch_summary.add(r.batch_size as f64);
        self.span_lo = self.span_lo.min(r.start_s);
        self.span_hi = self.span_hi.max(r.end_s());
    }

    fn stats(&self) -> StageStats {
        StageStats {
            stages: self.stages,
            weighted_mfu: if self.dt_sum == 0.0 {
                0.0
            } else {
                self.mfu_dt / self.dt_sum
            },
            dt_sum: self.dt_sum,
            mean_batch: self.batch_summary.mean(),
            batch_std: self.batch_summary.std(),
            // The same sum StageAggregates::add folds (same order).
            busy_gpu_s: self.agg.busy_gpu_s,
            span: if self.stages == 0 {
                (0.0, 0.0)
            } else {
                (self.span_lo, self.span_hi)
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyAccountant;
    use crate::scheduler::replica::StageKind;

    fn rec(start: f64, dt: f64, mfu: f64, batch: u32) -> StageRecord {
        StageRecord {
            replica: 0,
            pp_stage: 0,
            start_s: start,
            dt_s: dt,
            batch_size: batch,
            new_tokens: batch,
            mfu,
            power_w: 250.0,
            active_gpus: 1,
            idle_gpus: 0,
            flops: 1e12,
            kind: StageKind::Decode,
        }
    }

    /// The two sinks agree on every aggregate for the same record
    /// stream (the engine-level parity lives in tests/stream_parity.rs).
    #[test]
    fn sinks_agree_on_stats() {
        let cfg = SimConfig::default();
        let mut log = StageLog::new();
        let mut stream = StreamingSink::new(&cfg, 10.0).unwrap();
        for i in 0..100 {
            let r = rec(i as f64 * 0.7, 0.5, 0.1 + (i % 5) as f64 * 0.05, 1 + i % 8);
            StageSink::record(&mut log, r);
            stream.record(r);
        }
        let a = StageSink::stats(&log);
        let b = stream.stats();
        assert_eq!(a.stages, b.stages);
        assert_eq!(a.weighted_mfu, b.weighted_mfu);
        assert_eq!(a.mean_batch, b.mean_batch);
        assert_eq!(a.batch_std, b.batch_std);
        assert_eq!(a.busy_gpu_s, b.busy_gpu_s);
        assert_eq!(a.span, b.span);
    }

    /// Bins and energy match the materialized pipelines bit-for-bit.
    #[test]
    fn streaming_matches_materialized_binning_and_accounting() {
        let cfg = SimConfig::default();
        let acc = EnergyAccountant::paper_default(&cfg).unwrap();
        let mut log = StageLog::new();
        let mut stream =
            StreamingSink::with_model(&cfg, 10.0, acc.power_model).unwrap();
        for i in 0..200 {
            let r = rec(i as f64 * 0.4, 0.3, (i % 9) as f64 * 0.05, 1 + i % 4);
            log.push(r);
            stream.record(r);
        }
        let makespan = 90.0;
        let mat = crate::pipeline::bin_stages(
            &cfg,
            &log,
            makespan,
            10.0,
            crate::pipeline::BinningBackend::Native,
        )
        .unwrap();
        let str_prof = stream.binned_span(&cfg, makespan).unwrap();
        assert_eq!(mat.power_w, str_prof.power_w);
        assert_eq!(mat.covered_s, str_prof.covered_s);

        let mat_rep = acc.account(&cfg, &log, makespan);
        let str_rep = acc.report(&cfg, stream.aggregates(), makespan);
        assert_eq!(mat_rep.energy_kwh, str_rep.energy_kwh);
        assert_eq!(mat_rep.avg_power_w, str_rep.avg_power_w);
        assert_eq!(mat_rep.peak_power_w, str_rep.peak_power_w);
        assert_eq!(mat_rep.busy_fraction, str_rep.busy_fraction);
    }

    /// Satellite regression: `StageLog::new()` goes through
    /// `Self::default()`, which used to hit `Summary`'s derived
    /// `Default` (`min: 0.0`) — pinning `batch_summary.min()` at 0.0
    /// even though batch sizes are ≥ 1. Both sinks must now agree on
    /// the extrema, and the minimum must be a real batch size.
    #[test]
    fn sinks_agree_on_batch_extrema() {
        let cfg = SimConfig::default();
        let mut log = StageLog::new();
        let mut stream = StreamingSink::new(&cfg, 10.0).unwrap();
        for i in 0..50 {
            let r = rec(i as f64 * 0.5, 0.4, 0.2, 3 + i % 9);
            log.push(r);
            stream.record(r);
        }
        let a = &log.batch_summary;
        let b = stream.batch_summary();
        assert_eq!(a.min(), b.min());
        assert_eq!(a.max(), b.max());
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean(), b.mean());
        assert_eq!(
            a.min(),
            3.0,
            "min must track the smallest batch, not the old 0.0 default"
        );
        assert_eq!(a.max(), 11.0);
    }

    /// Shard-merge contract: splitting one record stream across two
    /// sinks and merging their `StageStats` reproduces the whole-stream
    /// aggregates (counters exactly, weighted means to float
    /// tolerance), and the sidecar JSON round-trip is lossless.
    #[test]
    fn stage_stats_merge_matches_unsharded_and_roundtrips() {
        let cfg = SimConfig::default();
        let mut whole = StreamingSink::new(&cfg, 10.0).unwrap();
        let mut a = StreamingSink::new(&cfg, 10.0).unwrap();
        let mut b = StreamingSink::new(&cfg, 10.0).unwrap();
        for i in 0..300 {
            let dt = 0.2 + (i % 3) as f64 * 0.1;
            let r = rec(i as f64 * 0.3, dt, (i % 7) as f64 * 0.06, 1 + i % 9);
            whole.record(r);
            if i % 2 == 0 {
                a.record(r);
            } else {
                b.record(r);
            }
        }
        let mut merged = a.stats();
        merged.merge(&b.stats());
        let want = whole.stats();
        assert_eq!(merged.stages, want.stages);
        assert_eq!(merged.span, want.span);
        assert!((merged.busy_gpu_s - want.busy_gpu_s).abs() < 1e-9);
        assert!((merged.dt_sum - want.dt_sum).abs() < 1e-9);
        assert!((merged.weighted_mfu - want.weighted_mfu).abs() < 1e-12);
        assert!((merged.mean_batch - want.mean_batch).abs() < 1e-12);
        assert!((merged.batch_std - want.batch_std).abs() < 1e-9);
        // Merge with an empty side is the identity.
        let mut lhs = want;
        lhs.merge(&StageStats::default());
        assert_eq!(lhs.stages, want.stages);
        assert_eq!(lhs.span, want.span);
        let mut rhs = StageStats::default();
        rhs.merge(&want);
        assert_eq!(rhs.stages, want.stages);
        assert_eq!(rhs.weighted_mfu, want.weighted_mfu);
        // JSON round-trip.
        let back = StageStats::from_json(&want.to_json()).unwrap();
        assert_eq!(back.stages, want.stages);
        assert_eq!(back.weighted_mfu, want.weighted_mfu);
        assert_eq!(back.dt_sum, want.dt_sum);
        assert_eq!(back.span, want.span);
    }

    #[test]
    fn empty_sink_stats_are_zero() {
        let cfg = SimConfig::default();
        let s = StreamingSink::new(&cfg, 60.0).unwrap();
        let st = s.stats();
        assert_eq!(st.stages, 0);
        assert_eq!(st.weighted_mfu, 0.0);
        assert_eq!(st.span, (0.0, 0.0));
        assert_eq!(s.peak_resident_bins(), 0);
    }
}
