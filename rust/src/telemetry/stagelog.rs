//! Batch-stage records and the stage log container.

use crate::scheduler::replica::StageKind;
use crate::util::stats::Summary;
use anyhow::{Context, Result};
use std::path::Path;

/// One executed batch stage (one pipeline-parallel stage of one
/// replica iteration) — the paper's logging granularity.
#[derive(Debug, Clone, Copy)]
pub struct StageRecord {
    pub replica: u32,
    /// Pipeline stage index within the replica iteration (0..pp).
    pub pp_stage: u32,
    pub start_s: f64,
    pub dt_s: f64,
    pub batch_size: u32,
    pub new_tokens: u32,
    /// Eq. 2 MFU of the stage's TP group (fraction, not %).
    pub mfu: f64,
    /// Eq. 1 per-GPU power of the stage's active GPUs, W.
    pub power_w: f64,
    /// GPUs actively executing this stage (= TP).
    pub active_gpus: u32,
    /// Replica GPUs idling during this stage (= (PP-1)·TP).
    pub idle_gpus: u32,
    pub flops: f64,
    pub kind: StageKind,
}

impl StageRecord {
    /// Whole-replica average power during this stage, W
    /// (active GPUs at P(MFU), the rest at idle).
    pub fn replica_power_w(&self, p_idle: f64) -> f64 {
        self.power_w * self.active_gpus as f64 + p_idle * self.idle_gpus as f64
    }
    pub fn end_s(&self) -> f64 {
        self.start_s + self.dt_s
    }
}

/// Append-only log of executed stages plus running aggregates.
#[derive(Debug, Default)]
pub struct StageLog {
    pub records: Vec<StageRecord>,
    pub mfu_summary: Summary,
    pub batch_summary: Summary,
}

impl StageLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: StageRecord) {
        self.mfu_summary.add(r.mfu);
        self.batch_summary.add(r.batch_size as f64);
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Busy span: earliest start to latest end.
    pub fn span(&self) -> (f64, f64) {
        if self.records.is_empty() {
            return (0.0, 0.0);
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for r in &self.records {
            lo = lo.min(r.start_s);
            hi = hi.max(r.end_s());
        }
        (lo, hi)
    }

    /// Total busy GPU-seconds (active GPUs × stage durations).
    pub fn busy_gpu_seconds(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.dt_s * r.active_gpus as f64)
            .sum()
    }

    /// Duration-weighted mean MFU (the quantity Fig. 1 plots vs QPS).
    pub fn weighted_mfu(&self) -> f64 {
        let num: f64 = self.records.iter().map(|r| r.mfu * r.dt_s).sum();
        let den: f64 = self.records.iter().map(|r| r.dt_s).sum();
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }

    /// Export as CSV (one row per stage, the paper's per-stage JSON
    /// equivalent). Streams straight through one buffered writer — no
    /// per-field `String` allocations, no in-memory `Table` — since at
    /// production traffic this file has millions of rows.
    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        use std::io::Write as _;
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let write_all = || -> std::io::Result<()> {
            let file = std::fs::File::create(path)?;
            let mut w = std::io::BufWriter::with_capacity(1 << 16, file);
            writeln!(
                w,
                "replica,pp_stage,start_s,dt_s,batch_size,new_tokens,\
                 mfu,power_w,active_gpus,idle_gpus,flops,kind"
            )?;
            for r in &self.records {
                writeln!(
                    w,
                    "{},{},{:.6},{:.6},{},{},{:.6},{:.3},{},{},{:.3e},{}",
                    r.replica,
                    r.pp_stage,
                    r.start_s,
                    r.dt_s,
                    r.batch_size,
                    r.new_tokens,
                    r.mfu,
                    r.power_w,
                    r.active_gpus,
                    r.idle_gpus,
                    r.flops,
                    match r.kind {
                        StageKind::Prefill => "prefill",
                        StageKind::Decode => "decode",
                        StageKind::Mixed => "mixed",
                    },
                )?;
            }
            w.flush()
        };
        write_all().with_context(|| format!("writing {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::Table;

    fn rec(start: f64, dt: f64, mfu: f64, active: u32, idle: u32) -> StageRecord {
        StageRecord {
            replica: 0,
            pp_stage: 0,
            start_s: start,
            dt_s: dt,
            batch_size: 4,
            new_tokens: 4,
            mfu,
            power_w: 200.0,
            active_gpus: active,
            idle_gpus: idle,
            flops: 1e12,
            kind: StageKind::Decode,
        }
    }

    #[test]
    fn aggregates() {
        let mut log = StageLog::new();
        log.push(rec(0.0, 1.0, 0.1, 1, 0));
        log.push(rec(1.0, 3.0, 0.3, 1, 0));
        assert_eq!(log.len(), 2);
        assert_eq!(log.span(), (0.0, 4.0));
        // Weighted MFU = (0.1*1 + 0.3*3)/4 = 0.25
        assert!((log.weighted_mfu() - 0.25).abs() < 1e-12);
        assert_eq!(log.busy_gpu_seconds(), 4.0);
    }

    #[test]
    fn replica_power_includes_idle_gpus() {
        let r = rec(0.0, 1.0, 0.2, 2, 2);
        // 2 active at 200 W + 2 idle at 100 W.
        assert_eq!(r.replica_power_w(100.0), 600.0);
    }

    #[test]
    fn csv_export_roundtrips_row_count() {
        let mut log = StageLog::new();
        for i in 0..10 {
            log.push(rec(i as f64, 0.5, 0.1, 1, 0));
        }
        let dir = std::env::temp_dir().join("vidur_energy_stagelog");
        let p = dir.join("stages.csv");
        log.save_csv(&p).unwrap();
        let t = Table::load(&p).unwrap();
        assert_eq!(t.rows.len(), 10);
        std::fs::remove_dir_all(dir).ok();
    }
}
