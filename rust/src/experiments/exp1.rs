//! Experiment 1 (Fig. 2) — request volume vs power and energy across
//! model sizes 2.7B…72B. Paper findings: average GPU power is stable
//! in request count (135–155 W for ≤34B at TP1/PP1; 125–127.5 W for
//! 70B+ at TP2/PP2) while total energy grows linearly, reaching
//! ~16 kWh (CodeLlama-34B) and >80 kWh (70B+) at 2^16 requests.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::SimConfig;
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub const MODELS: &[(&str, u32, u32)] = &[
    // (model, tp, pp) — 70B+ use TP2/PP2 per the paper.
    ("phi-2", 1, 1),
    ("llama2-7b", 1, 1),
    ("llama3-8b", 1, 1),
    ("codellama-34b", 1, 1),
    ("llama3-70b", 2, 2),
    ("qwen-72b", 2, 2),
];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    // 2^8 .. 2^16; the fast path caps at 2^11 and skips the 70B+ giants'
    // largest points (full sweep reserved for `repro experiment exp1`).
    let exps: Vec<u32> = if fast {
        vec![8, 9, 10, 11]
    } else {
        vec![8, 9, 10, 11, 12, 13, 14, 15, 16]
    };
    let mut cases = Vec::new();
    let mut cfgs = Vec::new();
    for &(model, tp, pp) in MODELS {
        for &e in &exps {
            let mut cfg = SimConfig::default();
            cfg.model = model.into();
            cfg.tp = tp;
            cfg.pp = pp;
            cfg.num_requests = 1u64 << e;
            cfg.seed = case_seed(0xE1, cfgs.len() as u64);
            cases.push((model, tp, pp, cfg.num_requests));
            cfgs.push(cfg);
        }
    }
    let grid = run_grid("exp1", cfgs)?;

    let mut table = Table::new(&[
        "model", "tp", "pp", "requests", "avg_power_w", "energy_kwh", "makespan_s",
        "weighted_mfu",
    ]);
    for (i, r) in grid.iter() {
        let (model, tp, pp, n) = cases[i];
        table.push_row(vec![
            model.to_string(),
            tp.to_string(),
            pp.to_string(),
            n.to_string(),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.3}", r.energy_kwh()),
            format!("{:.1}", r.out.metrics.makespan_s),
            format!("{:.4}", r.mfu()),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("figure", "fig2")
        .set(
            "paper_claim",
            "power stable in request count; energy linear; ~16 kWh @34B/2^16, >80 kWh @70B+",
        )
        .set("sweep", grid.sweep_meta());
    save_grid(out_dir, "exp1", &table, meta, &grid)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use crate::config::simconfig::{CostModelKind, SimConfig};
    use crate::experiments::common::run_case;
    use crate::util::stats::linreg;

    /// Fig. 2's two claims at test scale: energy linear in request
    /// count, power roughly flat.
    #[test]
    fn energy_linear_power_flat() {
        let mut energies = Vec::new();
        let mut powers = Vec::new();
        // Large enough that the warm-up/drain transient is amortized
        // (the paper sweeps 2^8..2^16 where this effect vanishes).
        let counts = [1024u64, 2048, 4096];
        for &n in &counts {
            let mut cfg = SimConfig::default();
            cfg.cost_model = CostModelKind::Native;
            cfg.num_requests = n;
            cfg.seed = 7;
            let r = run_case(&cfg).unwrap();
            energies.push(r.energy_kwh());
            powers.push(r.avg_power_w());
        }
        let xs: Vec<f64> = counts.iter().map(|&n| n as f64).collect();
        let (_, slope, r2) = linreg(&xs, &energies);
        assert!(slope > 0.0, "energy must grow with requests");
        assert!(r2 > 0.98, "energy not linear: r2 {r2} energies {energies:?}");
        // Power flat within 10% once transients amortize.
        let pmin = powers.iter().cloned().fold(f64::INFINITY, f64::min);
        let pmax = powers.iter().cloned().fold(0.0, f64::max);
        assert!(
            (pmax - pmin) / pmax < 0.10,
            "power not stable: {powers:?}"
        );
    }
}
