//! Shared experiment plumbing: run simulation cases — in parallel
//! across worker threads, with O(bins) streaming telemetry, optionally
//! sharded across machines (DESIGN.md §9) — and collect the (power,
//! energy, MFU, latency) quantities the paper's figures plot.

use crate::config::simconfig::SimConfig;
use crate::energy::{EnergyAccountant, EnergyReport};
use crate::exec::OracleStats;
use crate::report::live;
use crate::sim::{self, SimRun};
use crate::sweep::{ShardSpec, SweepExecutor};
use crate::telemetry::{LatencySketches, ShardTelemetry, StreamingRequestSink, StreamingSink};
use crate::util::csv::Table;
use crate::util::json::Value;
use anyhow::Result;
use std::path::Path;

/// Bin width of the per-case streaming sink. Experiments only consume
/// scalar aggregates, so the width only bounds the sink's O(bins)
/// memory; one minute matches the cosim interchange resolution.
pub const CASE_BIN_INTERVAL_S: f64 = 60.0;

/// One simulated configuration's headline numbers. Produced through
/// the streaming telemetry path: no per-stage vector is ever
/// materialized, so peak stage state is `peak_resident_bins` (O(bins))
/// rather than `out.metrics.stage_count` (O(stages)).
pub struct CaseResult {
    pub out: SimRun,
    pub energy: EnergyReport,
    /// The streaming sink's peak resident bin count for this case.
    pub peak_resident_bins: usize,
    /// The case's latency sketches (TTFT / e2e / queue-delay /
    /// normalized latency) — persisted in the shard telemetry sidecar
    /// so sharded sweeps can merge distributions without re-running.
    pub sketches: LatencySketches,
}

impl CaseResult {
    /// The engine's peak live-request count for this case
    /// (O(outstanding); requests stream through the request sink).
    pub fn peak_live_requests(&self) -> usize {
        self.out.peak_live_requests
    }

    pub fn avg_power_w(&self) -> f64 {
        self.energy.avg_power_w
    }
    pub fn energy_kwh(&self) -> f64 {
        self.energy.energy_kwh
    }
    pub fn mfu(&self) -> f64 {
        self.out.metrics.weighted_mfu
    }
    pub fn batch_mean(&self) -> f64 {
        self.out.stage_stats.mean_batch
    }
    pub fn batch_std(&self) -> f64 {
        self.out.stage_stats.batch_std
    }
}

/// Run one case with the paper's default accounting, streaming stage
/// telemetry through an O(bins) sink and request telemetry through
/// latency sketches (no per-request vector is ever materialized).
pub fn run_case(cfg: &SimConfig) -> Result<CaseResult> {
    run_case_watched(cfg, None)
}

/// [`run_case`] with an optional live-watch tap (DESIGN.md §10). When
/// watching, [`live::run_observed`] fans the primary sinks out to the
/// case's rolling windows — the primaries still answer `stats()` and
/// still feed the accounting, so every persisted output is
/// **byte-identical** to an unobserved run (asserted in
/// `tests/watch_observer.rs`).
pub fn run_case_watched(cfg: &SimConfig, watch: Option<live::CaseTap>) -> Result<CaseResult> {
    let acc = EnergyAccountant::paper_default(cfg)?;
    let mut sink = StreamingSink::with_model(cfg, CASE_BIN_INTERVAL_S, acc.power_model)?;
    let mut reqs = StreamingRequestSink::new(cfg);
    let out = live::run_observed(watch, cfg, acc.grid_ci, &mut sink, &mut reqs, |s, r| {
        sim::run_streaming_with(cfg, s, r)
    })?;
    let energy = acc.report(cfg, sink.aggregates(), out.metrics.makespan_s);
    Ok(CaseResult {
        peak_resident_bins: sink.peak_resident_bins(),
        sketches: reqs.into_sketches(),
        out,
        energy,
    })
}

/// Run a case grid on an explicit executor, ignoring the process-wide
/// shard (tests pin worker counts and compare raw result vectors).
/// Experiments use [`run_grid`], which is shard-aware and keeps the
/// global case indices.
pub fn run_cases_on(
    executor: &SweepExecutor,
    cfgs: Vec<SimConfig>,
) -> Result<Vec<CaseResult>> {
    executor.run(cfgs, |_, cfg| run_case(cfg))
}

/// A (possibly shard-filtered) grid run: the cases this process
/// actually executed, tagged with their **global** case indices so CSV
/// rows keep their position in the full grid, plus the shard identity
/// for the telemetry sidecar.
pub struct GridRun {
    /// Experiment id (`exp1`, `fig1`, …) — names the telemetry sidecar
    /// and the watch snapshot stream.
    pub experiment: String,
    /// Size of the full case grid, across all shards.
    pub total_cases: usize,
    /// The shard this process ran, `None` for an unsharded run.
    pub shard: Option<ShardSpec>,
    /// `(global case index, result)`, ascending by index.
    pub results: Vec<(usize, CaseResult)>,
    /// Lazily-built telemetry aggregate — [`GridRun::sweep_meta`] and
    /// the `save_grid` sidecar both read it, and folding every case's
    /// GK sketches is O(cases × sketch), so build it once.
    telemetry: std::cell::OnceCell<ShardTelemetry>,
}

impl GridRun {
    /// Iterate the executed cases as `(global index, result)`.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &CaseResult)> {
        self.results.iter().map(|(i, r)| (*i, r))
    }

    /// The `sweep` object for this run's `meta.json` (oracle cache,
    /// telemetry footprint, shard identity) — read off the same
    /// [`ShardTelemetry`] accumulator that backs the sidecar, so
    /// `meta.json` and `telemetry.json` can never drift apart.
    pub fn sweep_meta(&self) -> Value {
        let tel = self.telemetry();
        sweep_meta_parts(
            self.results.len() as u64,
            tel.oracle,
            tel.stages.stages,
            Some(tel.peak_resident_bins),
            Some(tel.peak_live_requests),
        )
    }

    /// The mergeable telemetry sidecar for this run (DESIGN.md §9):
    /// per-case request/stage accumulators and latency sketches folded
    /// into one shard-level aggregate, keyed by global case index.
    /// Built once, cached for subsequent calls.
    pub fn telemetry(&self) -> &ShardTelemetry {
        self.telemetry.get_or_init(|| {
            let mut tel =
                ShardTelemetry::new(&self.experiment, self.shard, self.total_cases as u64);
            for (i, r) in &self.results {
                tel.add_case(
                    *i as u64,
                    &r.out.request_stats,
                    &r.out.stage_stats,
                    &r.out.oracle,
                    &r.sketches,
                    r.peak_resident_bins as u64,
                    r.out.peak_live_requests as u64,
                );
            }
            tel
        })
    }
}

/// Run the grid honouring the process-wide shard (`--shard k/N`, set
/// via [`crate::sweep::set_shard`]): this process executes only the
/// cases its shard owns (`index % N == k`). Case seeds were derived
/// from **global** indices by the experiment, so shard assignment
/// never changes a case's results — merged shard CSVs are
/// byte-identical to an unsharded run's (`tests/shard_merge.rs`).
///
/// Also honours the process-wide watch (`--watch`, DESIGN.md §10):
/// when set, every case streams rolling-window snapshots to the live
/// view through a telemetry fan-out — without perturbing any output.
pub fn run_grid(experiment: &str, cfgs: Vec<SimConfig>) -> Result<GridRun> {
    run_grid_on(&SweepExecutor::with_default_jobs(), experiment, cfgs)
}

/// [`run_grid`] on an explicit executor (tests pin worker counts).
pub fn run_grid_on(
    executor: &SweepExecutor,
    experiment: &str,
    cfgs: Vec<SimConfig>,
) -> Result<GridRun> {
    let total_cases = cfgs.len();
    let (shard, owned) = crate::sweep::shard::shard_owned(cfgs);
    let view = live::open_view(experiment, total_cases as u64, owned.len() as u64, shard)?;
    let indices: Vec<usize> = owned.iter().map(|(i, _)| *i).collect();
    let results = executor.run(owned, |_, (gi, cfg)| {
        run_case_watched(
            cfg,
            view.as_ref().map(|v| live::CaseTap {
                view: v.clone(),
                case_index: *gi as u64,
            }),
        )
    })?;
    Ok(GridRun {
        experiment: experiment.to_string(),
        total_cases,
        shard,
        // The executor returns results in case order, so they pair
        // back with the global indices they were filtered from.
        results: indices.into_iter().zip(results).collect(),
        telemetry: std::cell::OnceCell::new(),
    })
}

/// The `sweep` meta object from pre-aggregated parts — for experiments
/// that don't go through [`run_grid`] (the autoscale policy sweep, the
/// single-case case study, the materialized ablation); grid
/// experiments get it via [`GridRun::sweep_meta`]. Every
/// experiment's `meta.json` carries this object under `sweep`.
/// `peak_resident_bins: None` marks a materialized run (the resident
/// stage state was the full record vector, reported as
/// `total_stages`); `peak_live_requests: None` likewise marks the
/// request side as materialized. A process-wide shard (`--shard k/N`)
/// is recorded under `shard`; `repro merge` recombines these objects
/// with per-field sum/max semantics
/// ([`crate::sweep::merge::merge_sweep_values`]).
pub fn sweep_meta_parts(
    cases: u64,
    oracle: OracleStats,
    total_stages: u64,
    peak_resident_bins: Option<u64>,
    peak_live_requests: Option<u64>,
) -> Value {
    let mut v = Value::obj();
    v.set("cases", cases)
        .set("jobs", crate::sweep::default_jobs() as u64)
        .set("oracle_cache", oracle.to_json())
        .set("total_stages", total_stages);
    if let Some(s) = crate::sweep::active_shard() {
        v.set("shard", s.label());
    }
    if let Some(r) = peak_live_requests {
        v.set("peak_live_requests", r);
    }
    match peak_resident_bins {
        Some(b) => {
            v.set("peak_resident_bins", b);
        }
        None => {
            v.set("materialized", true);
        }
    }
    v
}

/// Persist an experiment's table + metadata.
pub fn save(
    out_dir: &Path,
    id: &str,
    table: &Table,
    meta: Value,
) -> Result<()> {
    let dir = out_dir.join(id);
    std::fs::create_dir_all(&dir)?;
    table.save(dir.join(format!("{id}.csv")))?;
    std::fs::write(dir.join("meta.json"), meta.pretty())?;
    // Also print the markdown form so terminal runs double as reports.
    println!("\n### {id}\n\n{}", table.to_markdown());
    Ok(())
}

/// [`save`] plus the telemetry sidecar — the persistence path for
/// shardable grid experiments. Sharded and unsharded runs write the
/// same layout (`<id>.csv`, `meta.json`, `telemetry.json`); `repro
/// merge` recombines any number of such directories.
pub fn save_grid(
    out_dir: &Path,
    id: &str,
    table: &Table,
    meta: Value,
    grid: &GridRun,
) -> Result<()> {
    save(out_dir, id, table, meta)?;
    grid.telemetry().save(&out_dir.join(id))
}
