//! Shared experiment plumbing: run simulation cases — in parallel
//! across worker threads, with O(bins) streaming telemetry — and
//! collect the (power, energy, MFU, latency) quantities the paper's
//! figures plot.

use crate::config::simconfig::SimConfig;
use crate::energy::{EnergyAccountant, EnergyReport};
use crate::exec::OracleStats;
use crate::sim::{self, SimRun};
use crate::sweep::SweepExecutor;
use crate::telemetry::StreamingSink;
use crate::util::csv::Table;
use crate::util::json::Value;
use anyhow::Result;
use std::path::Path;

/// Bin width of the per-case streaming sink. Experiments only consume
/// scalar aggregates, so the width only bounds the sink's O(bins)
/// memory; one minute matches the cosim interchange resolution.
pub const CASE_BIN_INTERVAL_S: f64 = 60.0;

/// One simulated configuration's headline numbers. Produced through
/// the streaming telemetry path: no per-stage vector is ever
/// materialized, so peak stage state is `peak_resident_bins` (O(bins))
/// rather than `out.metrics.stage_count` (O(stages)).
pub struct CaseResult {
    pub out: SimRun,
    pub energy: EnergyReport,
    /// The streaming sink's peak resident bin count for this case.
    pub peak_resident_bins: usize,
}

impl CaseResult {
    /// The engine's peak live-request count for this case
    /// (O(outstanding); requests stream through the request sink).
    pub fn peak_live_requests(&self) -> usize {
        self.out.peak_live_requests
    }

    pub fn avg_power_w(&self) -> f64 {
        self.energy.avg_power_w
    }
    pub fn energy_kwh(&self) -> f64 {
        self.energy.energy_kwh
    }
    pub fn mfu(&self) -> f64 {
        self.out.metrics.weighted_mfu
    }
    pub fn batch_mean(&self) -> f64 {
        self.out.stage_stats.mean_batch
    }
    pub fn batch_std(&self) -> f64 {
        self.out.stage_stats.batch_std
    }
}

/// Run one case with the paper's default accounting, streaming stage
/// telemetry through an O(bins) sink and request telemetry through
/// latency sketches (no per-request vector is ever materialized).
pub fn run_case(cfg: &SimConfig) -> Result<CaseResult> {
    let acc = EnergyAccountant::paper_default(cfg)?;
    let mut sink = StreamingSink::with_model(cfg, CASE_BIN_INTERVAL_S, acc.power_model)?;
    let out = sim::run_streaming(cfg, &mut sink)?;
    let energy = acc.report(cfg, sink.aggregates(), out.metrics.makespan_s);
    Ok(CaseResult {
        peak_resident_bins: sink.peak_resident_bins(),
        out,
        energy,
    })
}

/// Run a case grid across the process-default worker count
/// (`--jobs N`, else `available_parallelism`), returning results in
/// case order regardless of completion order. Each worker thread
/// builds its own cost oracle — the PJRT stack is thread-affine — and
/// each case's workload seed lives in its `SimConfig`, so the output
/// is byte-identical for any worker count.
pub fn run_cases(cfgs: Vec<SimConfig>) -> Result<Vec<CaseResult>> {
    run_cases_on(&SweepExecutor::with_default_jobs(), cfgs)
}

/// [`run_cases`] on an explicit executor (tests pin worker counts).
pub fn run_cases_on(
    executor: &SweepExecutor,
    cfgs: Vec<SimConfig>,
) -> Result<Vec<CaseResult>> {
    executor.run(cfgs, |_, cfg| run_case(cfg))
}

/// Sweep-level metadata for an experiment's `meta.json`: aggregate
/// oracle memo-cache statistics (so sweep perf regressions are
/// observable run-over-run) and the telemetry footprint.
pub fn sweep_meta(results: &[CaseResult]) -> Value {
    let mut oracle = OracleStats::default();
    let mut peak_bins = 0usize;
    let mut peak_live = 0usize;
    let mut stages = 0u64;
    for r in results {
        oracle.merge(&r.out.oracle);
        peak_bins = peak_bins.max(r.peak_resident_bins);
        peak_live = peak_live.max(r.out.peak_live_requests);
        stages += r.out.metrics.stage_count;
    }
    sweep_meta_parts(
        results.len() as u64,
        oracle,
        stages,
        Some(peak_bins as u64),
        Some(peak_live as u64),
    )
}

/// [`sweep_meta`] from pre-aggregated parts — for experiments that
/// don't go through [`run_cases`] (the autoscale policy sweep, the
/// single-case case study, the materialized ablation). Every
/// experiment's `meta.json` carries this object under `sweep`.
/// `peak_resident_bins: None` marks a materialized run (the resident
/// stage state was the full record vector, reported as
/// `total_stages`); `peak_live_requests: None` likewise marks the
/// request side as materialized.
pub fn sweep_meta_parts(
    cases: u64,
    oracle: OracleStats,
    total_stages: u64,
    peak_resident_bins: Option<u64>,
    peak_live_requests: Option<u64>,
) -> Value {
    let mut v = Value::obj();
    v.set("cases", cases)
        .set("jobs", crate::sweep::default_jobs() as u64)
        .set("oracle_cache", oracle.to_json())
        .set("total_stages", total_stages);
    if let Some(r) = peak_live_requests {
        v.set("peak_live_requests", r);
    }
    match peak_resident_bins {
        Some(b) => {
            v.set("peak_resident_bins", b);
        }
        None => {
            v.set("materialized", true);
        }
    }
    v
}

/// Persist an experiment's table + metadata.
pub fn save(
    out_dir: &Path,
    id: &str,
    table: &Table,
    meta: Value,
) -> Result<()> {
    let dir = out_dir.join(id);
    std::fs::create_dir_all(&dir)?;
    table.save(dir.join(format!("{id}.csv")))?;
    std::fs::write(dir.join("meta.json"), meta.pretty())?;
    // Also print the markdown form so terminal runs double as reports.
    println!("\n### {id}\n\n{}", table.to_markdown());
    Ok(())
}
