//! Shared experiment plumbing: run one simulation case and collect the
//! (power, energy, MFU, latency) quantities the paper's figures plot.

use crate::config::simconfig::SimConfig;
use crate::energy::{EnergyAccountant, EnergyReport};
use crate::sim::{self, SimOutput};
use crate::util::csv::Table;
use crate::util::json::Value;
use anyhow::Result;
use std::path::Path;

/// One simulated configuration's headline numbers.
pub struct CaseResult {
    pub out: SimOutput,
    pub energy: EnergyReport,
}

impl CaseResult {
    pub fn avg_power_w(&self) -> f64 {
        self.energy.avg_power_w
    }
    pub fn energy_kwh(&self) -> f64 {
        self.energy.energy_kwh
    }
    pub fn mfu(&self) -> f64 {
        self.out.metrics.weighted_mfu
    }
}

/// Run one case with the paper's default accounting.
pub fn run_case(cfg: &SimConfig) -> Result<CaseResult> {
    let out = sim::run(cfg)?;
    let acc = EnergyAccountant::paper_default(cfg)?;
    let energy = acc.account(cfg, &out.stagelog, out.metrics.makespan_s);
    Ok(CaseResult { out, energy })
}

/// Persist an experiment's table + metadata.
pub fn save(
    out_dir: &Path,
    id: &str,
    table: &Table,
    meta: Value,
) -> Result<()> {
    let dir = out_dir.join(id);
    std::fs::create_dir_all(&dir)?;
    table.save(dir.join(format!("{id}.csv")))?;
    std::fs::write(dir.join("meta.json"), meta.pretty())?;
    // Also print the markdown form so terminal runs double as reports.
    println!("\n### {id}\n\n{}", table.to_markdown());
    Ok(())
}
