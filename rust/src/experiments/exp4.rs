//! Experiment 4 (Fig. 5) — query throughput (QPS) vs power and energy
//! at a fixed workload of 2^14 requests. Paper findings: average power
//! rises with QPS and saturates near 360 W beyond QPS ≈ 5; total
//! energy falls with QPS and converges toward ~0.5 kWh beyond QPS ≈ 8.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::{Arrival, SimConfig};
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub const QPS_GRID: &[f64] = &[0.1, 0.2, 0.5, 1.0, 2.0, 3.2, 5.0, 7.9, 12.6];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let n_requests: u64 = if fast { 512 } else { 1 << 14 };
    let grid: &[f64] = if fast {
        &[0.5, 2.0, 5.0, 12.6]
    } else {
        QPS_GRID
    };
    let cfgs: Vec<SimConfig> = grid
        .iter()
        .enumerate()
        .map(|(i, &qps)| {
            let mut cfg = SimConfig::default();
            cfg.arrival = Arrival::Poisson { qps };
            cfg.num_requests = n_requests;
            cfg.seed = case_seed(0xE4, i as u64);
            cfg
        })
        .collect();
    let run = run_grid("exp4", cfgs)?;

    let mut table = Table::new(&[
        "qps", "avg_power_w", "energy_kwh", "makespan_s", "weighted_mfu",
    ]);
    for (i, r) in run.iter() {
        let qps = grid[i];
        table.push_row(vec![
            format!("{qps}"),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.1}", r.out.metrics.makespan_s),
            format!("{:.4}", r.mfu()),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("figure", "fig5")
        .set(
            "paper_claim",
            "power saturates ~360 W past QPS 5; energy converges ~0.5 kWh past QPS 8 (2^14 requests)",
        )
        .set("sweep", run.sweep_meta());
    save_grid(out_dir, "exp4", &table, meta, &run)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use crate::config::simconfig::{Arrival, CostModelKind, SimConfig};
    use crate::experiments::common::run_case;

    fn case(qps: f64) -> (f64, f64) {
        let mut cfg = SimConfig::default();
        cfg.cost_model = CostModelKind::Native;
        cfg.arrival = Arrival::Poisson { qps };
        cfg.num_requests = 256;
        cfg.seed = 4;
        let r = run_case(&cfg).unwrap();
        (r.avg_power_w(), r.energy_kwh())
    }

    #[test]
    fn power_rises_energy_falls_with_qps() {
        let (p_lo, e_lo) = case(0.3);
        let (p_hi, e_hi) = case(10.0);
        assert!(p_hi > p_lo + 30.0, "power lo {p_lo} hi {p_hi}");
        assert!(e_hi < 0.7 * e_lo, "energy lo {e_lo} hi {e_hi}");
    }
}
