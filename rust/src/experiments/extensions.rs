//! Extension experiments beyond the paper's evaluation:
//!
//! * **sched** — scheduler policy comparison (vLLM vs Sarathi vs Orca)
//!   on the default workload: the paper fixes the vLLM scheduler; this
//!   quantifies how much the batching policy itself moves energy and
//!   latency.
//! * **gpu** — cross-GPU sweep: the paper calibrates power models for
//!   H100 and A40 (§3.1) but evaluates only the A100; this runs the
//!   default workload across all three SKUs, showing how the
//!   idle/peak envelope and compute/bandwidth balance shift energy
//!   per request.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::{SchedulerKind, SimConfig};
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub fn run_sched(out_dir: &Path, fast: bool) -> Result<Table> {
    let kinds = [
        ("vllm", SchedulerKind::Vllm),
        ("sarathi", SchedulerKind::Sarathi),
        ("orca", SchedulerKind::Orca),
    ];
    let cfgs: Vec<SimConfig> = kinds
        .iter()
        .enumerate()
        .map(|(i, &(_, kind))| {
            let mut cfg = SimConfig::default();
            cfg.scheduler = kind;
            cfg.num_requests = if fast { 256 } else { 2048 };
            cfg.seed = case_seed(0x5C4ED, i as u64);
            cfg
        })
        .collect();
    let grid = run_grid("sched", cfgs)?;

    let mut table = Table::new(&[
        "scheduler", "avg_power_w", "energy_kwh", "makespan_s", "ttft_p50_s",
        "e2e_p99_s", "mean_batch", "weighted_mfu",
    ]);
    for (i, r) in grid.iter() {
        let (name, _) = kinds[i];
        table.push_row(vec![
            name.to_string(),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.1}", r.out.metrics.makespan_s),
            format!("{:.3}", r.out.metrics.ttft_p50_s),
            format!("{:.2}", r.out.metrics.e2e_p99_s),
            format!("{:.1}", r.out.metrics.mean_batch_size),
            format!("{:.4}", r.mfu()),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("experiment", "sched")
        .set(
            "description",
            "scheduler policy ablation: energy/latency across vLLM, Sarathi, Orca",
        )
        .set("sweep", grid.sweep_meta());
    save_grid(out_dir, "sched", &table, meta, &grid)?;
    Ok(table)
}

pub fn run_gpu(out_dir: &Path, fast: bool) -> Result<Table> {
    let gpus = ["a100-80g", "h100", "a40"];
    let n_requests: u64 = if fast { 256 } else { 2048 };
    let cfgs: Vec<SimConfig> = gpus
        .iter()
        .enumerate()
        .map(|(i, &gpu)| {
            let mut cfg = SimConfig::default();
            cfg.gpu = gpu.into();
            cfg.num_requests = n_requests;
            cfg.seed = case_seed(0x69B0, i as u64);
            cfg
        })
        .collect();
    let grid = run_grid("gpu", cfgs)?;

    let mut table = Table::new(&[
        "gpu", "avg_power_w", "energy_kwh", "wh_per_request", "makespan_s",
        "weighted_mfu",
    ]);
    for (i, r) in grid.iter() {
        table.push_row(vec![
            gpus[i].to_string(),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.4}", r.energy_kwh() * 1000.0 / n_requests as f64),
            format!("{:.1}", r.out.metrics.makespan_s),
            format!("{:.4}", r.mfu()),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("experiment", "gpu")
        .set(
            "description",
            "cross-GPU sweep over the paper's three calibrated SKUs (A100/H100/A40)",
        )
        .set("sweep", grid.sweep_meta());
    save_grid(out_dir, "gpu", &table, meta, &grid)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use crate::config::simconfig::{CostModelKind, SchedulerKind, SimConfig};
    use crate::experiments::common::run_case;

    fn energy_with(gpu: &str) -> f64 {
        let mut cfg = SimConfig::default();
        cfg.cost_model = CostModelKind::Native;
        cfg.gpu = gpu.into();
        cfg.num_requests = 128;
        cfg.seed = 77;
        run_case(&cfg).unwrap().energy_kwh()
    }

    #[test]
    fn h100_finishes_faster_and_cheaper_than_a40() {
        // 6.6x the FLOPs and 4.8x the bandwidth at 2.3x the peak power:
        // H100 must beat the A40 on energy per completed workload.
        let h100 = energy_with("h100");
        let a40 = energy_with("a40");
        assert!(h100 < a40, "h100 {h100} !< a40 {a40}");
    }

    #[test]
    fn schedulers_trade_ttft_for_batching() {
        let run = |kind| {
            let mut cfg = SimConfig::default();
            cfg.cost_model = CostModelKind::Native;
            cfg.scheduler = kind;
            cfg.num_requests = 256;
            cfg.seed = 78;
            run_case(&cfg).unwrap()
        };
        let vllm = run(SchedulerKind::Vllm);
        let sarathi = run(SchedulerKind::Sarathi);
        // Sarathi chunks prefills: its stages are smaller, so it takes
        // more of them; both must complete all work (requests stream
        // through the sink, so completion shows up in the counters).
        assert_eq!(vllm.out.request_stats.finished, 256);
        assert_eq!(sarathi.out.request_stats.finished, 256);
        assert!(sarathi.out.metrics.stage_count > vllm.out.metrics.stage_count);
    }
}
