//! Autoscaling experiment (DESIGN.md §6) — sweep the fleet-scaling
//! policies against a day of CAISO-style grid conditions and a diurnal
//! request load, comparing energy, net emissions, SLO attainment, and
//! fleet size.
//!
//! Scenario: a Llama-3-8B service provisioned statically at 3 replicas
//! for its (midday) peak. The diurnal load leaves that fleet mostly
//! idle off-peak, so the static baseline burns idle power all night at
//! exactly the hours the grid is dirtiest (the CAISO duck-curve
//! evening ramp). The carbon-aware policy sheds replicas during
//! high-CI hours unless the SLO guard vetoes it; solar-following rides
//! the solar peak; reactive tracks queue depth alone.

use super::common::{save, sweep_meta_parts};
use crate::autoscale::GridEnv;
use crate::config::simconfig::{
    Arrival, AutoscaleConfig, CosimConfig, CostModelKind, LengthDist, ScalingPolicyKind,
    SimConfig,
};
use crate::cosim::{default_signal_traces, default_signals, Environment};
use crate::energy::EnergyAccountant;
use crate::pipeline::LoadProfile;
use crate::report::live;
use crate::runtime::ArtifactStore;
use crate::sim::{self, AutoscaleRun};
use crate::sweep::SweepExecutor;
use crate::telemetry::{LatencySketches, ShardTelemetry, StreamingRequestSink, StreamingSink};
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workload::{Request, Trace, WorkloadGenerator};
use anyhow::Result;
use std::path::Path;

/// The four swept policies, static first (the comparison baseline).
pub const POLICIES: &[ScalingPolicyKind] = &[
    ScalingPolicyKind::Static,
    ScalingPolicyKind::Reactive,
    ScalingPolicyKind::CarbonAware,
    ScalingPolicyKind::SolarFollowing,
];

/// Diurnal demand shape in (0, 1]: business-hours peak around 14:00,
/// nighttime trough ~30% of peak.
fn load_shape(hour_of_day: f64) -> f64 {
    let h = hour_of_day.rem_euclid(24.0);
    0.3 + 0.7 * (-((h - 14.0) * (h - 14.0)) / (2.0 * 4.5 * 4.5)).exp()
}

/// Non-homogeneous Poisson arrivals via thinning: candidates at
/// `qps_peak`, accepted with probability `load_shape(t)`. Lengths come
/// from the configured distribution.
pub fn diurnal_trace(
    cfg: &SimConfig,
    start_hour: f64,
    horizon_s: f64,
    qps_peak: f64,
    seed: u64,
) -> Trace {
    let mut rng = Rng::new(seed);
    let mut lengths = WorkloadGenerator::new(
        Arrival::Batch,
        cfg.lengths.clone(),
        cfg.prefill_decode_ratio,
        cfg.max_tokens,
        seed ^ 0xD1A1,
    );
    let mut t = 0.0f64;
    let mut reqs = Vec::new();
    loop {
        t += rng.exponential(qps_peak);
        if t >= horizon_s {
            break;
        }
        if rng.f64() < load_shape(start_hour + t / 3600.0) {
            let template = lengths.next_request();
            reqs.push(Request::new(
                reqs.len() as u64,
                t,
                template.prefill_tokens,
                template.decode_tokens,
            ));
        }
    }
    Trace::new(reqs)
}

/// The default sweep scenario. `fast` compresses a full day into the
/// dirty evening window (17:00 + 2 h) with a lighter load.
pub fn scenario(fast: bool) -> (SimConfig, AutoscaleConfig, CosimConfig, f64, f64) {
    let mut cfg = SimConfig::default();
    cfg.replicas = 3; // statically provisioned for peak
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 256,
        max: 2048,
    };
    cfg.prefill_decode_ratio = Some(8.0);
    cfg.seed = 0xA5CA1E;
    if ArtifactStore::discover().is_err() {
        cfg.cost_model = CostModelKind::Native;
    }

    let mut scale = AutoscaleConfig::default();
    scale.min_replicas = 1;
    scale.max_replicas = 4;

    let mut cosim = CosimConfig::default();
    let (horizon_s, qps_peak) = if fast {
        cosim.start_hour = 17.0; // the duck-curve evening ramp
        scale.decision_interval_s = 120.0;
        scale.cold_start_s = 30.0;
        (7_200.0, 1.5)
    } else {
        scale.decision_interval_s = 300.0;
        scale.cold_start_s = 120.0;
        (86_400.0, 3.0)
    };
    (cfg, scale, cosim, horizon_s, qps_peak)
}

/// One policy's headline numbers after sim + accounting + cosim.
pub struct PolicyResult {
    pub policy: &'static str,
    pub out: AutoscaleRun,
    pub energy_kwh: f64,
    pub net_footprint_g: f64,
    pub carbon_offset_frac: f64,
    pub renewable_share: f64,
    /// The streaming sink's peak resident bin count for this policy.
    pub peak_resident_bins: usize,
    /// The policy run's latency sketches (for the shard telemetry
    /// sidecar, DESIGN.md §9).
    pub sketches: LatencySketches,
}

/// Run one policy of the sweep over a fixed trace, streaming the
/// day-long stage telemetry through an O(bins) sink.
pub fn run_policy(
    cfg: &SimConfig,
    scale_template: &AutoscaleConfig,
    cosim: &CosimConfig,
    policy: ScalingPolicyKind,
    horizon_s: f64,
    trace: Trace,
) -> Result<PolicyResult> {
    run_policy_watched(cfg, scale_template, cosim, policy, horizon_s, trace, None)
}

/// [`run_policy`] with an optional live-watch tap (DESIGN.md §10):
/// under `--watch` the day-long run streams rolling-window snapshots
/// through a telemetry fan-out — the primary sinks, and therefore the
/// policy table and sidecar, are untouched.
pub fn run_policy_watched(
    cfg: &SimConfig,
    scale_template: &AutoscaleConfig,
    cosim: &CosimConfig,
    policy: ScalingPolicyKind,
    horizon_s: f64,
    trace: Trace,
    watch: Option<live::CaseTap>,
) -> Result<PolicyResult> {
    let mut scale = scale_template.clone();
    scale.policy = policy;

    // Grid signals spanning comfortably past the horizon (the drain
    // tail can outlast the last arrival).
    let n_signal = ((horizon_s / 60.0) as usize) * 2 + 120;
    let (solar_sig, ci_sig) = default_signal_traces(cosim, n_signal);
    let grid = GridEnv::from_signals(cosim, ci_sig, solar_sig);

    // Fleet-aware accounting + Eq. 5 binning, folded online.
    let acc = EnergyAccountant::paper_default(cfg)?;
    let mut sink = StreamingSink::with_model(cfg, cosim.interval_s, acc.power_model)?;
    let mut reqs = StreamingRequestSink::new(cfg);
    let out = live::run_observed(watch, cfg, acc.grid_ci, &mut sink, &mut reqs, |s, r| {
        sim::run_autoscaled_streaming_with(cfg, &scale, &grid, trace, s, r)
    })?;
    let energy = acc.report_fleet(cfg, sink.aggregates(), &out.timeline);
    let binned = sink.binned(cfg, &out.timeline)?;
    let profile = LoadProfile::from_binned(&binned);

    // Co-simulate the time-varying demand against the same signals.
    let (solar_w, ci) = default_signals(cosim, profile.len());
    let mut env = Environment::new(cosim.clone());
    let res = env.run_native(&profile.power_w, &solar_w, &ci)?;

    Ok(PolicyResult {
        policy: out.policy,
        energy_kwh: energy.energy_kwh,
        net_footprint_g: res.net_footprint_g,
        carbon_offset_frac: res.carbon_offset_frac,
        renewable_share: res.renewable_share,
        peak_resident_bins: sink.peak_resident_bins(),
        sketches: reqs.into_sketches(),
        out,
    })
}

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let (cfg, scale, cosim, horizon_s, qps_peak) = scenario(fast);
    // Default load is the synthetic diurnal curve; a `--workload`
    // override (trace replay or a scenario generator) swaps the whole
    // demand shape under the same policies.
    let trace = match crate::workload::effective_workload(&cfg) {
        crate::config::WorkloadKind::Synthetic => {
            diurnal_trace(&cfg, cosim.start_hour, horizon_s, qps_peak, cfg.seed)
        }
        _ => crate::workload::trace_from_config(&cfg)?,
    };
    eprintln!(
        "autoscale sweep: {} requests over {:.1} h ({} policies)",
        trace.len(),
        horizon_s / 3600.0,
        POLICIES.len()
    );

    let mut table = Table::new(&[
        "policy",
        "energy_kwh",
        "net_footprint_g",
        "carbon_offset_pct",
        "renewable_pct",
        "slo_pct",
        "slo_ttft_pct",
        "slo_e2e_pct",
        "mean_fleet",
        "max_fleet",
        "scale_ups",
        "scale_downs",
        "ttft_p99_s",
        "makespan_s",
    ]);
    let mut meta = Value::obj();
    let dir = out_dir.join("autoscale");
    // The four policies are independent runs over the same trace:
    // fan them out across the sweep workers — and, under
    // `--shard k/N`, across machines (case index = policy index;
    // the trace is seed-deterministic, so every shard regenerates the
    // identical workload).
    let (shard, owned) = crate::sweep::shard::shard_owned(POLICIES.to_vec());
    let view = live::open_view("autoscale", POLICIES.len() as u64, owned.len() as u64, shard)?;
    let indices: Vec<usize> = owned.iter().map(|(i, _)| *i).collect();
    let results = SweepExecutor::with_default_jobs().run(owned, |_, &(gi, policy)| {
        run_policy_watched(
            &cfg,
            &scale,
            &cosim,
            policy,
            horizon_s,
            trace.clone(),
            view.as_ref().map(|v| live::CaseTap {
                view: v.clone(),
                case_index: gi as u64,
            }),
        )
    })?;
    for r in &results {
        let m = &r.out.sim.metrics;
        let (ups, downs) = r.out.timeline.scale_event_counts();
        table.push_row(vec![
            r.policy.to_string(),
            format!("{:.4}", r.energy_kwh),
            format!("{:.1}", r.net_footprint_g),
            format!("{:.1}", r.carbon_offset_frac * 100.0),
            format!("{:.1}", r.renewable_share * 100.0),
            format!("{:.2}", m.slo_attained * 100.0),
            format!("{:.2}", m.slo_ttft_attained * 100.0),
            format!("{:.2}", m.slo_e2e_attained * 100.0),
            format!("{:.3}", r.out.timeline.mean_fleet()),
            r.out.timeline.max_fleet().to_string(),
            ups.to_string(),
            downs.to_string(),
            format!("{:.3}", m.ttft_p99_s),
            format!("{:.1}", m.makespan_s),
        ]);
        // Per-policy fleet timeline (minute resolution) for figures.
        let mut ft = Table::new(&["t_s", "live_replicas"]);
        let minutes = (r.out.timeline.horizon_s / 60.0).ceil() as usize;
        for i in 0..minutes {
            let t = i as f64 * 60.0;
            ft.push_row(vec![
                format!("{t:.0}"),
                r.out.timeline.live_count_at(t).to_string(),
            ]);
        }
        ft.save(dir.join(format!("fleet_{}.csv", r.policy)))?;
        meta.set(&format!("decisions_{}", r.policy), r.out.decisions.len() as u64);
    }

    // One accumulator for both outputs: the `sweep` meta object is
    // read back off the sidecar aggregate, so the two can never drift.
    let mut telemetry = ShardTelemetry::new("autoscale", shard, POLICIES.len() as u64);
    for (i, r) in indices.iter().zip(&results) {
        telemetry.add_case(
            *i as u64,
            &r.out.sim.request_stats,
            &r.out.sim.stage_stats,
            &r.out.sim.oracle,
            &r.sketches,
            r.peak_resident_bins as u64,
            r.out.sim.peak_live_requests as u64,
        );
    }
    meta.set("experiment", "autoscale")
        .set(
            "paper_claim",
            "carbon-aware autoscaling cuts net emissions vs the static fleet at \
             equal-or-better SLO attainment (extends the paper's §5 carbon-aware \
             direction to fleet capacity)",
        )
        .set(
            "sweep",
            sweep_meta_parts(
                results.len() as u64,
                telemetry.oracle,
                telemetry.stages.stages,
                Some(telemetry.peak_resident_bins),
                Some(telemetry.peak_live_requests),
            ),
        )
        .set("requests", trace.len() as u64)
        .set("horizon_s", horizon_s)
        .set("qps_peak", qps_peak)
        .set("scale_config", {
            let mut s = scale.clone();
            s.policy = ScalingPolicyKind::Static;
            s.to_json()
        })
        .set("sim_config", cfg.to_json())
        .set("cosim_config", cosim.to_json());
    save(out_dir, "autoscale", &table, meta)?;
    telemetry.save(&dir)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::FleetTimeline;
    use crate::pipeline::{bin_stages_fleet, BinningBackend};

    /// Tiny dirty→clean comparison: the carbon-aware fleet must emit
    /// less than the static fleet at equal-or-better SLO attainment —
    /// the experiment's acceptance property in miniature.
    #[test]
    fn carbon_aware_beats_static_on_emissions_at_equal_slo() {
        let mut cfg = SimConfig::default();
        cfg.cost_model = CostModelKind::Native;
        cfg.replicas = 3;
        cfg.num_requests = 900;
        cfg.arrival = Arrival::Poisson { qps: 2.0 };
        cfg.lengths = LengthDist::Zipf {
            theta: 0.6,
            min: 128,
            max: 512,
        };
        cfg.seed = 0xCAFE;
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));
        let span = trace.arrival_span_s();

        let mut scale = AutoscaleConfig::default();
        scale.decision_interval_s = 60.0;
        scale.cold_start_s = 30.0;

        // Dirty grid for the first 60% of the arrivals, clean after.
        let switch = span * 0.6;
        let ci_at = move |t: f64| if t < switch { 500.0 } else { 60.0 };

        let run_one = |policy: ScalingPolicyKind| {
            let mut s = scale.clone();
            s.policy = policy;
            let grid = GridEnv::from_fns(100.0, 200.0, 600.0, 0.0, ci_at, |_| 0.0);
            let out = sim::run_autoscaled(&cfg, &s, &grid, trace.clone()).unwrap();
            assert!(out.sim.requests.iter().all(|r| r.is_finished()));
            let binned = bin_stages_fleet(
                &cfg,
                &out.sim.stagelog,
                &out.timeline,
                60.0,
                BinningBackend::Native,
            )
            .unwrap();
            let profile = LoadProfile::from_binned(&binned);
            let n = profile.len();
            let ci: Vec<f64> = (0..n).map(|i| ci_at(i as f64 * 60.0)).collect();
            let solar = vec![0.0; n];
            let mut env = Environment::new(CosimConfig::default());
            let res = env.run_native(&profile.power_w, &solar, &ci).unwrap();
            (res.net_footprint_g, out.sim.metrics.slo_attained, out)
        };

        let (static_g, static_slo, static_out) = run_one(ScalingPolicyKind::Static);
        let (carbon_g, carbon_slo, carbon_out) =
            run_one(ScalingPolicyKind::CarbonAware);

        assert!((static_out.timeline.mean_fleet() - 3.0).abs() < 1e-9);
        assert!(
            carbon_out.timeline.mean_fleet() < 2.9,
            "carbon policy never shed: mean fleet {}",
            carbon_out.timeline.mean_fleet()
        );
        assert!(
            carbon_g < 0.95 * static_g,
            "carbon-aware {carbon_g} g !< static {static_g} g"
        );
        assert!(
            carbon_slo >= static_slo - 0.05,
            "SLO regressed: carbon {carbon_slo} vs static {static_slo}"
        );
    }

    #[test]
    fn diurnal_trace_has_daytime_peak() {
        let cfg = SimConfig::default();
        let tr = diurnal_trace(&cfg, 0.0, 86_400.0, 2.0, 7);
        assert!(tr.len() > 1000);
        // Arrivals sorted; rate near 14:00 clearly above rate near 02:00.
        let count_in = |lo_h: f64, hi_h: f64| {
            tr.requests
                .iter()
                .filter(|r| r.arrival_s >= lo_h * 3600.0 && r.arrival_s < hi_h * 3600.0)
                .count() as f64
        };
        assert!(count_in(12.0, 16.0) > 1.5 * count_in(0.0, 4.0));
        for w in tr.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    #[test]
    fn fleet_csv_matches_timeline() {
        // live_count sampling used by the CSV writer is consistent
        // with mean_fleet integration on a simple timeline.
        let mut t = FleetTimeline::new();
        t.provision(0, 0.0);
        t.online(0, 0.0);
        t.provision(1, 120.0);
        t.online(1, 150.0);
        t.offline(1, 300.0);
        t.close(600.0);
        let samples: Vec<u32> = (0..10).map(|i| t.live_count_at(i as f64 * 60.0)).collect();
        assert_eq!(samples, vec![1, 1, 2, 2, 2, 1, 1, 1, 1, 1]);
    }
}
