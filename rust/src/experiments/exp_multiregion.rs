//! Multi-region routing experiment (DESIGN.md §13) — sweep the
//! request-granularity route policies over region-count × battery-size
//! axes, with every region running a real simulated fleet (per-region
//! reactive autoscaler, microgrid, phase-shifted CI trace) under one
//! shared clock.
//!
//! This replaces the markdown-only `multiregion` report of earlier
//! revisions: it is a proper grid experiment emitting CSV +
//! `telemetry.json` sidecars, so it shards (`--shard k/N`), merges
//! (`repro merge`), watches (`--watch`), and serves like the rest.

use super::common::{save, sweep_meta_parts};
use crate::config::simconfig::{
    Arrival, AutoscaleConfig, CosimConfig, CostModelKind, LengthDist, ScalingPolicyKind,
    SimConfig,
};
use crate::coordinator::fleet::{
    run_global, FleetRegion, GlobalFleetSpec, GlobalRunResult, RoutePolicyKind,
};
use crate::coordinator::multiregion::default_regions;
use crate::report::live;
use crate::runtime::ArtifactStore;
use crate::sweep::SweepExecutor;
use crate::telemetry::ShardTelemetry;
use crate::util::csv::Table;
use crate::util::json::Value;
use anyhow::Result;
use std::path::Path;

/// One sweep case: (route policy, region count, battery capacity Wh).
type Case = (RoutePolicyKind, usize, f64);

/// Sweep axes + fleet knobs; `defaults(fast)` mirrors the other
/// experiments' fast/full split.
pub struct MultiRegionOpts {
    pub policies: Vec<RoutePolicyKind>,
    pub region_counts: Vec<usize>,
    pub battery_whs: Vec<f64>,
    /// One-way RTT to every remote region, seconds.
    pub rtt_s: f64,
    /// Override `CosimConfig::transfer_overhead` (None = default).
    pub transfer_overhead: Option<f64>,
}

impl MultiRegionOpts {
    pub fn defaults(fast: bool) -> Self {
        MultiRegionOpts {
            policies: RoutePolicyKind::all().to_vec(),
            region_counts: if fast { vec![3] } else { vec![1, 3] },
            battery_whs: if fast {
                vec![100.0]
            } else {
                vec![100.0, 1_000.0]
            },
            rtt_s: 0.05,
            transfer_overhead: None,
        }
    }
}

/// The shared workload/simulator configuration of every case.
fn scenario(fast: bool) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.replicas = 1;
    cfg.seed = 0x9E010;
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 64,
        max: 768,
    };
    cfg.arrival = Arrival::Poisson {
        qps: if fast { 6.0 } else { 8.0 },
    };
    cfg.num_requests = if fast { 400 } else { 2_000 };
    if ArtifactStore::discover().is_err() {
        cfg.cost_model = CostModelKind::Native;
    }
    cfg
}

/// Per-region reactive autoscaler: region-local queue signals decide
/// region-local capacity.
fn region_scale() -> AutoscaleConfig {
    let mut s = AutoscaleConfig::default();
    s.policy = ScalingPolicyKind::Reactive;
    s.min_replicas = 1;
    s.max_replicas = 2;
    s.decision_interval_s = 120.0;
    s.cold_start_s = 30.0;
    s
}

/// Build the global fleet for one case from the default region set
/// (truncated to `n_regions`; index 0 = home).
pub fn fleet_spec(
    policy: RoutePolicyKind,
    n_regions: usize,
    battery_wh: f64,
    rtt_s: f64,
    transfer_overhead: Option<f64>,
    scale: Option<AutoscaleConfig>,
    replicas: u32,
) -> GlobalFleetSpec {
    let regions = default_regions()
        .into_iter()
        .take(n_regions.max(1))
        .map(|r| {
            let mut cosim = CosimConfig::default();
            cosim.battery_wh = battery_wh;
            cosim.solar_capacity_w = r.solar_w;
            if let Some(t) = transfer_overhead {
                cosim.transfer_overhead = t;
            }
            FleetRegion {
                region: r,
                replicas,
                scale: scale.clone(),
                rtt_s,
                cosim,
            }
        })
        .collect();
    GlobalFleetSpec {
        regions,
        policy,
        power_model: None,
    }
}

fn run_case(
    cfg: &SimConfig,
    case: Case,
    opts: &MultiRegionOpts,
    tap: Option<live::CaseTap>,
) -> Result<GlobalRunResult> {
    let (policy, n_regions, battery_wh) = case;
    let spec = fleet_spec(
        policy,
        n_regions,
        battery_wh,
        opts.rtt_s,
        opts.transfer_overhead,
        Some(region_scale()),
        1,
    );
    let mut source = crate::workload::source_from_config(cfg)?;
    run_global(cfg, &spec, &mut *source, tap)
}

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    run_with(out_dir, fast, &MultiRegionOpts::defaults(fast))
}

pub fn run_with(out_dir: &Path, fast: bool, opts: &MultiRegionOpts) -> Result<Table> {
    let cfg = scenario(fast);
    let mut cases: Vec<Case> = Vec::new();
    for &p in &opts.policies {
        for &n in &opts.region_counts {
            for &b in &opts.battery_whs {
                cases.push((p, n, b));
            }
        }
    }
    let total = cases.len();
    eprintln!(
        "multiregion sweep: {} requests x {} cases ({} policies x {} region counts x {} \
         battery sizes)",
        cfg.num_requests,
        total,
        opts.policies.len(),
        opts.region_counts.len(),
        opts.battery_whs.len()
    );

    let mut table = Table::new(&[
        "route_policy",
        "regions",
        "battery_wh",
        "fleet_gpu_kwh",
        "net_footprint_g",
        "moved_requests",
        "region_energy_kwh",
        "region_routed",
        "slo_pct",
        "ttft_p99_s",
        "makespan_s",
    ]);
    let dir = out_dir.join("multiregion");

    let (shard, owned) = crate::sweep::shard::shard_owned(cases);
    let view = live::open_view("multiregion", total as u64, owned.len() as u64, shard)?;
    let indices: Vec<usize> = owned.iter().map(|(i, _)| *i).collect();
    let results = SweepExecutor::with_default_jobs().run(owned, |_, &(gi, case)| {
        run_case(
            &cfg,
            case,
            opts,
            view.as_ref().map(|v| live::CaseTap {
                view: v.clone(),
                case_index: gi as u64,
            }),
        )
    })?;

    for (&gi, res) in indices.iter().zip(&results) {
        // Recover the case from its global index (row ordering must be
        // identical on every shard for `repro merge`).
        let nb = opts.battery_whs.len();
        let nr = opts.region_counts.len();
        let policy = opts.policies[gi / (nr * nb)];
        let n_regions = opts.region_counts[(gi / nb) % nr];
        let battery_wh = opts.battery_whs[gi % nb];
        let m = &res.run.metrics;
        let region_kwh: Vec<String> = res
            .regions
            .iter()
            .map(|r| format!("{:.6}", r.gpu_energy_kwh))
            .collect();
        let region_routed: Vec<String> =
            res.regions.iter().map(|r| r.routed.to_string()).collect();
        table.push_row(vec![
            policy.as_str().to_string(),
            n_regions.to_string(),
            format!("{battery_wh:.0}"),
            format!("{:.6}", res.fleet_gpu_energy_kwh),
            format!("{:.2}", res.fleet_emissions_g),
            res.moved_requests.to_string(),
            region_kwh.join(";"),
            region_routed.join(";"),
            format!("{:.2}", m.slo_attained * 100.0),
            format!("{:.3}", m.ttft_p99_s),
            format!("{:.1}", m.makespan_s),
        ]);
    }

    // One accumulator for both outputs (table meta + sidecar), so the
    // merged sweep aggregates can never drift from the CSV.
    let mut telemetry = ShardTelemetry::new("multiregion", shard, total as u64);
    for (&gi, res) in indices.iter().zip(&results) {
        telemetry.add_case(
            gi as u64,
            &res.run.request_stats,
            &res.run.stage_stats,
            &res.run.oracle,
            &res.run.sketches,
            res.peak_resident_bins as u64,
            res.run.peak_live_requests as u64,
        );
    }
    let mut meta = Value::obj();
    meta.set("experiment", "multiregion")
        .set(
            "paper_claim",
            "request-granularity carbon-aware routing across regions cuts net emissions \
             vs static home placement (extends the paper's §5 multi-region direction \
             from load-profile arithmetic to a simulated global fleet)",
        )
        .set(
            "sweep",
            sweep_meta_parts(
                results.len() as u64,
                telemetry.oracle,
                telemetry.stages.stages,
                Some(telemetry.peak_resident_bins),
                Some(telemetry.peak_live_requests),
            ),
        )
        .set("requests", cfg.num_requests)
        .set(
            "route_policies",
            opts.policies
                .iter()
                .map(|p| p.as_str())
                .collect::<Vec<_>>()
                .join(","),
        )
        .set("rtt_s", opts.rtt_s)
        .set(
            "transfer_overhead",
            opts.transfer_overhead
                .unwrap_or(CosimConfig::default().transfer_overhead),
        )
        .set("scale_config", region_scale().to_json())
        .set("sim_config", cfg.to_json());
    save(out_dir, "multiregion", &table, meta)?;
    telemetry.save(&dir)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sweep's acceptance property in miniature: greedy-ci routes
    /// the bulk of traffic off the dirty home grid and lands at or
    /// below static-home emissions, and every region's accounted
    /// energy sums to the fleet total.
    #[test]
    fn greedy_ci_beats_static_home_and_energy_reconciles() {
        let mut cfg = scenario(true);
        cfg.num_requests = 120;
        let stat = run_case(&cfg, (RoutePolicyKind::StaticHome, 3, 100.0), &defaults(), None)
            .unwrap();
        let greedy =
            run_case(&cfg, (RoutePolicyKind::GreedyCi, 3, 100.0), &defaults(), None).unwrap();
        assert!(
            greedy.fleet_emissions_g <= stat.fleet_emissions_g * 1.02,
            "greedy {} !<= static {}",
            greedy.fleet_emissions_g,
            stat.fleet_emissions_g
        );
        assert!(greedy.moved_requests > 0, "greedy never moved a request");
        for res in [&stat, &greedy] {
            let sum: f64 = res.regions.iter().map(|r| r.gpu_energy_kwh).sum();
            assert!(
                (sum - res.fleet_gpu_energy_kwh).abs() < 1e-9,
                "region energies {} != fleet {}",
                sum,
                res.fleet_gpu_energy_kwh
            );
        }
    }

    fn defaults() -> MultiRegionOpts {
        MultiRegionOpts::defaults(true)
    }
}
