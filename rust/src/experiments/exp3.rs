//! Experiment 3 (Fig. 4) — batch-size cap vs power and energy. Paper
//! findings: actual batch size grows sublinearly with the cap (high
//! variance past 32); average power rises and plateaus above cap 64;
//! total energy falls with diminishing returns past cap 16.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::SimConfig;
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub const CAPS: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let caps: &[usize] = if fast { &[1, 8, 64, 128] } else { CAPS };
    let cfgs: Vec<SimConfig> = caps
        .iter()
        .enumerate()
        .map(|(i, &cap)| {
            let mut cfg = SimConfig::default();
            cfg.batch_cap = cap;
            cfg.num_requests = if fast { 192 } else { 1024 };
            cfg.seed = case_seed(0xE3, i as u64);
            cfg
        })
        .collect();
    let grid = run_grid("exp3", cfgs)?;

    let mut table = Table::new(&[
        "batch_cap", "actual_batch_mean", "actual_batch_std", "avg_power_w",
        "energy_kwh", "makespan_s",
    ]);
    for (i, r) in grid.iter() {
        table.push_row(vec![
            caps[i].to_string(),
            format!("{:.2}", r.batch_mean()),
            format!("{:.2}", r.batch_std()),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.1}", r.out.metrics.makespan_s),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("figure", "fig4")
        .set(
            "paper_claim",
            "actual batch sublinear in cap; power plateaus above 64; energy falls, diminishing past 16",
        )
        .set("sweep", grid.sweep_meta());
    save_grid(out_dir, "exp3", &table, meta, &grid)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use crate::config::simconfig::{CostModelKind, SimConfig};
    use crate::experiments::common::run_case;

    fn case(cap: usize) -> (f64, f64, f64) {
        let mut cfg = SimConfig::default();
        cfg.cost_model = CostModelKind::Native;
        cfg.batch_cap = cap;
        cfg.num_requests = 256;
        cfg.seed = 9;
        let r = run_case(&cfg).unwrap();
        (r.batch_mean(), r.avg_power_w(), r.energy_kwh())
    }

    #[test]
    fn larger_cap_bigger_batches_less_energy() {
        let (b1, _, e1) = case(1);
        let (b32, _, e32) = case(32);
        assert!(b32 > b1, "batch {b1} -> {b32}");
        assert!(
            e32 < e1,
            "batching must save energy: cap1 {e1} kWh, cap32 {e32} kWh"
        );
    }

    #[test]
    fn actual_batch_sublinear_in_cap() {
        let (b16, _, _) = case(16);
        let (b128, _, _) = case(128);
        // 8x the cap must yield far less than 8x the actual batch.
        assert!(b128 < 6.0 * b16, "b16 {b16} b128 {b128}");
    }
}
