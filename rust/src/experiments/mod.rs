//! Regenerators for every table and figure in the paper's evaluation
//! (§4). Each experiment returns a [`crate::util::csv::Table`] with the
//! same rows/series the paper reports and saves CSV + JSON under a
//! results directory. See DESIGN.md §4 for the experiment index.
//!
//! Execution model (DESIGN.md §7): every experiment builds its case
//! grid up front and hands it to [`common::run_grid`], which fans the
//! cases across the sweep worker threads (`--jobs N`, default all
//! cores) and streams each case's stage telemetry through an O(bins)
//! sink. Case seeds derive from the case index
//! ([`crate::util::rng::case_seed`]), so any worker count produces
//! byte-identical CSVs.
//!
//! Cross-machine scale (DESIGN.md §9): under `--shard k/N` the same
//! grid is partitioned by global case index across hosts; each shard
//! writes its rows plus a mergeable telemetry sidecar, and `repro
//! merge` recombines the shard directories into outputs byte-identical
//! to an unsharded run. Single-case experiments (`casestudy`,
//! `ablation`) belong to the shard that owns case 0 and are skipped —
//! not failed — on every other shard, so `repro experiment all
//! --shard k/N` shards the whole paper evaluation wholesale.

pub mod common;
pub mod fig1;
pub mod exp1;
pub mod exp2;
pub mod exp3;
pub mod exp4;
pub mod exp5;
pub mod casestudy;
pub mod ablation;
pub mod extensions;
pub mod exp_autoscale;
pub mod exp_multiregion;
pub mod exp_scenarios;

pub use common::{run_case, CaseResult};

use anyhow::Result;
use std::path::Path;

/// Does the active shard (if any) own this single-case experiment?
/// One-case grids live on the shard owning case 0; other shards skip
/// them so `experiment all --shard k/N` needs no per-id exceptions.
fn shard_owns_single_case(id: &str) -> bool {
    match crate::sweep::active_shard() {
        Some(s) if !s.owns(0) => {
            eprintln!("shard {s}: skipping single-case experiment '{id}' (owned by shard 0)");
            false
        }
        _ => true,
    }
}

/// Single-case experiments don't run through the watched sweep paths:
/// with `--watch` active, say so instead of silently emitting nothing
/// (DESIGN.md §10 — their value is the final summary table, not a
/// case-progress stream).
fn note_unwatched_single_case(id: &str) {
    if crate::report::live::active_watch().is_some() {
        eprintln!(
            "watch: single-case experiment '{id}' emits no live snapshots \
             (DESIGN.md §10)"
        );
    }
}

/// Run an experiment by id ("fig1", "exp1".."exp5", "casestudy",
/// "ablation", or "all").
pub fn run_by_id(id: &str, out_dir: &Path, fast: bool) -> Result<()> {
    match id {
        "fig1" => fig1::run(out_dir, fast).map(|_| ()),
        "exp1" => exp1::run(out_dir, fast).map(|_| ()),
        "exp2" => exp2::run(out_dir, fast).map(|_| ()),
        "exp3" => exp3::run(out_dir, fast).map(|_| ()),
        "exp4" => exp4::run(out_dir, fast).map(|_| ()),
        "exp5" => exp5::run(out_dir, fast).map(|_| ()),
        "casestudy" if !shard_owns_single_case(id) => Ok(()),
        "casestudy" => {
            note_unwatched_single_case(id);
            casestudy::run(out_dir, fast).map(|_| ())
        }
        "ablation" if !shard_owns_single_case(id) => Ok(()),
        "ablation" => {
            note_unwatched_single_case(id);
            ablation::run(out_dir, fast).map(|_| ())
        }
        "sched" => extensions::run_sched(out_dir, fast).map(|_| ()),
        "gpu" => extensions::run_gpu(out_dir, fast).map(|_| ()),
        "autoscale" => exp_autoscale::run(out_dir, fast).map(|_| ()),
        "multiregion" => exp_multiregion::run(out_dir, fast).map(|_| ()),
        "scenarios" => exp_scenarios::run(out_dir, fast).map(|_| ()),
        "all" => {
            for id in [
                "fig1", "exp1", "exp2", "exp3", "exp4", "exp5", "casestudy",
                "ablation", "sched", "gpu", "autoscale", "multiregion", "scenarios",
            ] {
                eprintln!("=== experiment {id} ===");
                run_by_id(id, out_dir, fast)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}'; known: fig1, exp1..exp5, casestudy, ablation, sched, gpu, autoscale, multiregion, scenarios, all"
        ),
    }
}
