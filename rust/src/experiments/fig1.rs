//! Fig. 1 — QPS saturation: simulated MFU vs offered QPS for
//! Meta-Llama-3-8B. The paper shows MFU rising with QPS and plateauing
//! near mfu_sat = 0.45 for QPS ≈ 5–7.9.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::{Arrival, SimConfig};
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub const QPS_GRID: &[f64] = &[0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 6.45, 7.9, 10.0, 12.6];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let cfgs: Vec<SimConfig> = QPS_GRID
        .iter()
        .enumerate()
        .map(|(i, &qps)| {
            let mut cfg = SimConfig::default();
            cfg.arrival = Arrival::Poisson { qps };
            cfg.num_requests = if fast { 192 } else { 1024 };
            cfg.seed = case_seed(42, i as u64);
            cfg
        })
        .collect();
    let grid = run_grid("fig1", cfgs)?;

    let mut table = Table::new(&["qps", "weighted_mfu", "avg_power_w", "achieved_qps"]);
    for (i, r) in grid.iter() {
        let qps = QPS_GRID[i];
        table.push_row(vec![
            format!("{qps}"),
            format!("{:.4}", r.mfu()),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.2}", r.out.metrics.achieved_qps),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("figure", "fig1")
        .set("description", "MFU vs QPS saturation, Meta-Llama-3-8B on A100")
        .set("paper_claim", "MFU plateaus near 0.45 at QPS 5-7.9")
        .set("sweep", grid.sweep_meta());
    save_grid(out_dir, "fig1", &table, meta, &grid)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::CostModelKind;
    use crate::experiments::common::run_case;
    use crate::config::simconfig::Arrival;

    /// The core Fig. 1 claim at reduced scale: MFU grows with QPS and
    /// approaches the saturation region.
    #[test]
    fn mfu_increases_with_qps() {
        let run_at = |qps: f64| {
            let mut cfg = SimConfig::default();
            cfg.cost_model = CostModelKind::Native;
            cfg.arrival = Arrival::Poisson { qps };
            cfg.num_requests = 96;
            cfg.seed = 1;
            run_case(&cfg).unwrap().mfu()
        };
        let lo = run_at(0.5);
        let hi = run_at(8.0);
        assert!(hi > lo * 1.5, "mfu lo {lo} hi {hi}");
    }
}
