//! Scenario-library sweep (DESIGN.md §14) — run every production-shaped
//! workload generator (multi-turn chat, RAG, agentic tool loops,
//! heavy-tailed multi-tenant mix) across a QPS grid and report the
//! energy/latency profile of each shape side by side. The paper's
//! evaluation drives everything from one synthetic length distribution;
//! this grid quantifies how far real request shapes pull power, MFU,
//! and energy-per-request away from that baseline.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::{Arrival, CostModelKind, SimConfig, WorkloadKind};
use crate::runtime::ArtifactStore;
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

/// The scenario axis, in row order.
pub const SCENARIOS: &[&str] = &["chat", "rag", "agentic", "tenants"];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    // A process-wide `--workload` override would force every case onto
    // one kind and silently collapse the scenario axis to duplicates.
    anyhow::ensure!(
        crate::workload::workload_override().is_none(),
        "`repro scenarios` sweeps the workload axis itself; drop the --workload override"
    );
    let n_requests: u64 = if fast { 400 } else { 2_000 };
    let qps_grid: &[f64] = if fast { &[2.0, 6.0] } else { &[1.0, 4.0, 10.0] };

    let mut cfgs: Vec<SimConfig> = Vec::new();
    for scenario in SCENARIOS {
        for &qps in qps_grid {
            let mut cfg = SimConfig::default();
            cfg.workload = WorkloadKind::parse(scenario)?;
            cfg.arrival = Arrival::Poisson { qps };
            cfg.num_requests = n_requests;
            cfg.seed = case_seed(0xA9, cfgs.len() as u64);
            if ArtifactStore::discover().is_err() {
                cfg.cost_model = CostModelKind::Native;
            }
            cfgs.push(cfg);
        }
    }
    let sim_config = cfgs[0].to_json();
    let run = run_grid("scenarios", cfgs)?;

    let mut table = Table::new(&[
        "scenario",
        "qps",
        "avg_power_w",
        "energy_kwh",
        "makespan_s",
        "weighted_mfu",
        "mean_prefill_tok",
        "mean_decode_tok",
        "slo_pct",
        "ttft_p99_s",
    ]);
    for (i, r) in run.iter() {
        let scenario = SCENARIOS[i / qps_grid.len()];
        let qps = qps_grid[i % qps_grid.len()];
        let s = &r.out.request_stats;
        let n = s.finished.max(1) as f64;
        table.push_row(vec![
            scenario.to_string(),
            format!("{qps}"),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.1}", r.out.metrics.makespan_s),
            format!("{:.4}", r.mfu()),
            format!("{:.1}", s.prefill_tokens_done as f64 / n),
            format!("{:.1}", s.decode_tokens_done as f64 / n),
            format!("{:.2}", r.out.metrics.slo_attained * 100.0),
            format!("{:.3}", r.out.metrics.ttft_p99_s),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("experiment", "scenarios")
        .set(
            "paper_claim",
            "request shape, not just rate, moves the energy profile: long-prefill RAG \
             saturates power at lower QPS than chat, while agentic bursts and \
             heavy-tailed tenant mixes widen the tail latencies the paper's single \
             synthetic distribution cannot express (extends §4's QPS sweep)",
        )
        .set("scenarios", SCENARIOS.join(","))
        .set("sweep", run.sweep_meta())
        .set("sim_config", sim_config);
    save_grid(out_dir, "scenarios", &table, meta, &run)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::run_case;

    fn case(kind: WorkloadKind, qps: f64) -> crate::experiments::CaseResult {
        let mut cfg = SimConfig::default();
        cfg.cost_model = CostModelKind::Native;
        cfg.workload = kind;
        cfg.arrival = Arrival::Poisson { qps };
        cfg.num_requests = 200;
        cfg.seed = 0xA9;
        run_case(&cfg).unwrap()
    }

    /// The sweep's headline contrast in miniature: RAG's long-prefill /
    /// short-decode shape gives it a far higher prefill:decode token
    /// ratio than chat at the same rate, and both runs complete the
    /// full request budget.
    #[test]
    fn rag_is_prefill_heavier_than_chat() {
        let chat = case(WorkloadKind::Chat, 4.0);
        let rag = case(WorkloadKind::Rag, 4.0);
        for r in [&chat, &rag] {
            assert_eq!(r.out.request_stats.finished, 200);
        }
        let ratio = |r: &crate::experiments::CaseResult| {
            r.out.request_stats.prefill_tokens_done as f64
                / r.out.request_stats.decode_tokens_done.max(1) as f64
        };
        assert!(
            ratio(&rag) > 2.0 * ratio(&chat),
            "rag ratio {} !> 2x chat ratio {}",
            ratio(&rag),
            ratio(&chat)
        );
    }
}
