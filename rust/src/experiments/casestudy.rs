//! §4.3 / Table 2 / Fig. 6 / Fig. 7 — the Vidur→Vessim integration
//! case study: Llama-2-7B serving a 400k-request Zipf workload
//! (QPS 20, P:D 20, NVLink pairwise) whose binned power profile is
//! co-simulated against CAISO-North-style solar + carbon-intensity
//! signals with a 600 W array and a 100 Wh battery.
//!
//! Paper headlines: 5.90 kWh total demand, 70.3% renewable share,
//! 2.47 kgCO₂ gross, 69.2% offset by solar, battery ~0.8 full cycles /
//! 47.2% average SoC / 64.8% idle, average CI 418.2 g/kWh.

use super::common::{save, sweep_meta_parts};
use crate::config::simconfig::{Arrival, CosimConfig, LengthDist, SimConfig};
use crate::cosim::{CarbonAwareController, Environment};
use crate::energy::EnergyAccountant;
use crate::pipeline::LoadProfile;
use crate::sim;
use crate::telemetry::StreamingSink;
use crate::util::csv::Table;
use crate::util::json::Value;
use anyhow::Result;
use std::path::Path;

/// The paper's integration workload (Table 1b), scaled by `fast`.
///
/// Deviation from Table 1b (documented in EXPERIMENTS.md): the paper
/// runs 400k requests; our roofline execution model is ~2× slower per
/// request than Vidur's learned predictor, which would stretch the
/// workload past the single daylight window the paper's solar numbers
/// imply (4.15 kWh generated ≈ one clear day of a 600 W array). We
/// scale to 190k requests on a single-GPU replica so the workload
/// spans the same ~14 h daylight window — preserving the quantities
/// Table 2 reports (renewable share, offset, battery dynamics).
pub fn workload_config(fast: bool) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.model = "llama2-7b".into();
    cfg.tp = 1;
    cfg.pp = 1;
    cfg.num_requests = if fast { 2_000 } else { 190_000 };
    cfg.arrival = Arrival::Poisson { qps: 20.0 };
    cfg.lengths = LengthDist::Zipf {
        theta: 0.6,
        min: 1024,
        max: 4096,
    };
    cfg.prefill_decode_ratio = Some(20.0);
    cfg.seed = 0xCA5E;
    cfg
}

pub struct CaseStudyOutput {
    pub profile: LoadProfile,
    pub summary: Table,
    pub baseline_json: Value,
    pub aware_json: Value,
}

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    Ok(run_full(out_dir, fast)?.summary)
}

pub fn run_full(out_dir: &Path, fast: bool) -> Result<CaseStudyOutput> {
    // 1+2. Vidur side + Eq. 5 pipeline in one streaming pass: the
    // 190k-request stage stream folds directly into the Vessim
    // 1-minute bins and the energy aggregates as it is produced —
    // O(bins) resident state instead of one record per stage.
    let cfg = workload_config(fast);
    let cosim_cfg = CosimConfig::default();
    let acc = EnergyAccountant::paper_default(&cfg)?;
    let mut sink =
        StreamingSink::with_model(&cfg, cosim_cfg.interval_s, acc.power_model)?;
    let out = sim::run_streaming(&cfg, &mut sink)?;
    let makespan = out.metrics.makespan_s;
    let energy = acc.report(&cfg, sink.aggregates(), makespan);
    let binned = sink.binned_span(&cfg, makespan)?;
    let profile = LoadProfile::from_binned(&binned);

    // 3. Environment signals over the workload window, offset so the
    //    run starts at the configured morning hour.
    let n = profile.len();
    let (solar_w, ci) = crate::cosim::default_signals(&cosim_cfg, n);

    // 4. Co-simulate: monitored baseline + carbon-aware variant.
    let mut env = Environment::new(cosim_cfg.clone());
    let base = env.run_native(&profile.power_w, &solar_w, &ci)?;
    let mut aware_env = Environment::new(cosim_cfg.clone()).with_controller(
        CarbonAwareController::new(cosim_cfg.ci_low, cosim_cfg.ci_high, 0.5),
    );
    let aware = aware_env.run_native(&profile.power_w, &solar_w, &ci)?;

    // 5. Table-2-shaped summary.
    let mut t = Table::new(&["metric", "baseline", "carbon_aware", "paper"]);
    let row = |m: &str, b: String, a: String, p: &str| vec![m.to_string(), b, a, p.to_string()];
    t.push_row(row(
        "total_energy_kwh",
        format!("{:.2}", base.total_energy_kwh),
        format!("{:.2}", aware.total_energy_kwh),
        "5.90",
    ));
    t.push_row(row(
        "solar_generation_kwh",
        format!("{:.2}", base.solar_generation_kwh),
        format!("{:.2}", aware.solar_generation_kwh),
        "4.15",
    ));
    t.push_row(row(
        "grid_consumption_kwh",
        format!("{:.2}", base.grid_consumption_kwh),
        format!("{:.2}", aware.grid_consumption_kwh),
        "1.81",
    ));
    t.push_row(row(
        "renewable_share_pct",
        format!("{:.1}", base.renewable_share * 100.0),
        format!("{:.1}", aware.renewable_share * 100.0),
        "70.3",
    ));
    t.push_row(row(
        "grid_dependency_pct",
        format!("{:.1}", base.grid_dependency * 100.0),
        format!("{:.1}", aware.grid_dependency * 100.0),
        "30.7",
    ));
    t.push_row(row(
        "total_emissions_kg",
        format!("{:.2}", base.total_emissions_kg),
        format!("{:.2}", aware.total_emissions_kg),
        "2.47",
    ));
    t.push_row(row(
        "offset_by_solar_kg",
        format!("{:.2}", base.offset_by_solar_kg),
        format!("{:.2}", aware.offset_by_solar_kg),
        "1.71",
    ));
    t.push_row(row(
        "net_footprint_g",
        format!("{:.0}", base.net_footprint_g),
        format!("{:.0}", aware.net_footprint_g),
        "759.2",
    ));
    t.push_row(row(
        "carbon_offset_pct",
        format!("{:.1}", base.carbon_offset_frac * 100.0),
        format!("{:.1}", aware.carbon_offset_frac * 100.0),
        "69.2",
    ));
    t.push_row(row(
        "avg_ci_g_per_kwh",
        format!("{:.1}", base.avg_ci),
        format!("{:.1}", aware.avg_ci),
        "418.2",
    ));
    t.push_row(row(
        "hours_high_ci",
        format!("{:.1}", base.hours_high_ci),
        format!("{:.1}", aware.hours_high_ci),
        "24.8",
    ));
    t.push_row(row(
        "avg_soc_pct",
        format!("{:.1}", base.avg_soc * 100.0),
        format!("{:.1}", aware.avg_soc * 100.0),
        "47.2",
    ));
    t.push_row(row(
        "hours_below_50_soc",
        format!("{:.1}", base.hours_below_50_soc),
        format!("{:.1}", aware.hours_below_50_soc),
        "15.7",
    ));
    t.push_row(row(
        "hours_above_80_soc",
        format!("{:.1}", base.hours_above_80_soc),
        format!("{:.1}", aware.hours_above_80_soc),
        "6.7",
    ));
    t.push_row(row(
        "charging_pct",
        format!("{:.1}", base.charging_frac * 100.0),
        format!("{:.1}", aware.charging_frac * 100.0),
        "21.2",
    ));
    t.push_row(row(
        "discharging_pct",
        format!("{:.1}", base.discharging_frac * 100.0),
        format!("{:.1}", aware.discharging_frac * 100.0),
        "14.0",
    ));
    t.push_row(row(
        "idle_pct",
        format!("{:.1}", base.idle_frac * 100.0),
        format!("{:.1}", aware.idle_frac * 100.0),
        "64.8",
    ));
    t.push_row(row(
        "battery_full_cycles",
        format!("{:.2}", base.battery_full_cycles),
        format!("{:.2}", aware.battery_full_cycles),
        "0.8",
    ));

    let mut meta = Value::obj();
    meta.set("table", "table2")
        .set("figures", "fig6, fig7")
        .set("workload_makespan_s", makespan)
        .set("profile_minutes", n as u64)
        .set("sim_metrics", out.metrics.to_json())
        .set("energy_report", energy.to_json())
        .set(
            "sweep",
            // The 190k requests streamed through the request sink:
            // peak_live_requests records the engine's actual
            // per-request footprint.
            sweep_meta_parts(
                1,
                out.oracle,
                out.metrics.stage_count,
                Some(sink.peak_resident_bins() as u64),
                Some(out.peak_live_requests as u64),
            ),
        )
        .set("requests_finished", out.request_stats.finished);
    save(out_dir, "casestudy", &t, meta)?;

    // Fig. 6 data: time-resolved power flows.
    let dir = out_dir.join("casestudy");
    let mut fig6 = Table::new(&["t_s", "load_w", "solar_w", "grid_w", "battery_w"]);
    for rec in &base.records {
        fig6.push_row(vec![
            format!("{:.0}", rec.t_s),
            format!("{:.2}", rec.load_w),
            format!("{:.2}", rec.solar_w),
            format!("{:.2}", rec.grid_w),
            format!("{:.2}", rec.battery_w),
        ]);
    }
    fig6.save(dir.join("fig6_power_flows.csv"))?;
    // Fig. 7 data: SoC + cumulative emissions + CI trace.
    let mut fig7 = Table::new(&["t_s", "soc", "ci", "cum_net_g", "cum_offset_g"]);
    let mut cum_net = 0.0;
    let mut cum_gross = 0.0;
    let dt_h = cosim_cfg.interval_s / 3600.0;
    for rec in &base.records {
        cum_net += rec.emissions_g;
        cum_gross += rec.load_w * dt_h / 1000.0 * rec.ci;
        fig7.push_row(vec![
            format!("{:.0}", rec.t_s),
            format!("{:.4}", rec.soc),
            format!("{:.1}", rec.ci),
            format!("{:.2}", cum_net),
            format!("{:.2}", cum_gross - cum_net),
        ]);
    }
    fig7.save(dir.join("fig7_battery_emissions.csv"))?;
    profile.save(dir.join("load_profile.csv"))?;

    Ok(CaseStudyOutput {
        profile,
        summary: t,
        baseline_json: base.to_json(),
        aware_json: aware.to_json(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::CostModelKind;

    #[test]
    fn small_case_study_end_to_end() {
        let mut cfg = workload_config(true);
        cfg.num_requests = 300;
        cfg.cost_model = CostModelKind::Native;
        let acc = EnergyAccountant::paper_default(&cfg).unwrap();
        let mut sink = StreamingSink::with_model(&cfg, 60.0, acc.power_model).unwrap();
        let out = sim::run_streaming(&cfg, &mut sink).unwrap();
        let energy = acc.report(&cfg, sink.aggregates(), out.metrics.makespan_s);
        let binned = sink.binned_span(&cfg, out.metrics.makespan_s).unwrap();
        let profile = LoadProfile::from_binned(&binned);
        assert!(!profile.is_empty());
        // The sink held bins, not stages.
        assert!(out.metrics.stage_count > sink.peak_resident_bins() as u64);
        // Binned energy equals accounted energy (before PUE) within 1%.
        let direct = energy.gpu_energy_kwh;
        let binned_kwh = profile.total_energy_kwh();
        assert!(
            (binned_kwh - direct).abs() / direct < 0.01,
            "binned {binned_kwh} vs direct {direct}"
        );
    }
}
