//! Experiment 5 (§4.2 text) — TP×PP parallelism grid for CodeLlama-34B
//! on 4×A100 with NVLink. Paper findings: average GPU power 213–355 W,
//! peaking at TP2/PP1 and dropping with higher parallelism; energy
//! 0.16–0.56 kWh with the most efficient settings TP2/PP1 and TP1/PP2
//! — runtime reduction matters more than power reduction.

use super::common::{run_grid, save_grid};
use crate::config::simconfig::SimConfig;
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub const GRID: &[(u32, u32)] = &[
    (1, 1),
    (1, 2),
    (1, 4),
    (2, 1),
    (2, 2),
    (2, 4),
    (4, 1),
    (4, 2),
    (4, 4),
];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let grid: &[(u32, u32)] = if fast {
        &[(1, 1), (2, 1), (1, 2), (2, 2)]
    } else {
        GRID
    };
    let cfgs: Vec<SimConfig> = grid
        .iter()
        .enumerate()
        .map(|(i, &(tp, pp))| {
            let mut cfg = SimConfig::default();
            cfg.model = "codellama-34b".into();
            cfg.tp = tp;
            cfg.pp = pp;
            cfg.num_requests = if fast { 128 } else { 1024 };
            cfg.seed = case_seed(0xE5, i as u64);
            cfg
        })
        .collect();
    let run = run_grid("exp5", cfgs)?;

    let mut table = Table::new(&[
        "tp", "pp", "gpus", "avg_power_w", "energy_kwh", "makespan_s", "weighted_mfu",
    ]);
    for (i, r) in run.iter() {
        let (tp, pp) = grid[i];
        table.push_row(vec![
            tp.to_string(),
            pp.to_string(),
            (tp * pp).to_string(),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.1}", r.out.metrics.makespan_s),
            format!("{:.4}", r.mfu()),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("experiment", "exp5")
        .set(
            "paper_claim",
            "power peaks at TP2/PP1, drops with higher parallelism; best energy at TP2/PP1 & TP1/PP2",
        )
        .set("sweep", run.sweep_meta());
    save_grid(out_dir, "exp5", &table, meta, &run)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use crate::config::simconfig::{CostModelKind, SimConfig};
    use crate::experiments::common::run_case;

    fn case(tp: u32, pp: u32) -> (f64, f64, f64) {
        let mut cfg = SimConfig::default();
        cfg.model = "codellama-34b".into();
        cfg.cost_model = CostModelKind::Native;
        cfg.tp = tp;
        cfg.pp = pp;
        cfg.num_requests = 96;
        cfg.seed = 5;
        let r = run_case(&cfg).unwrap();
        (
            r.avg_power_w(),
            r.energy_kwh(),
            r.out.metrics.makespan_s,
        )
    }

    #[test]
    fn tp2_faster_than_tp1() {
        let (_, _, t1) = case(1, 1);
        let (_, _, t2) = case(2, 1);
        assert!(t2 < t1, "tp2 {t2} !< tp1 {t1}");
    }

    #[test]
    fn more_gpus_does_not_mean_less_energy() {
        // The paper's headline: TP4/PP4-style maximal parallelism is
        // not the energy optimum.
        let (_, e_small, _) = case(2, 1);
        let (_, e_big, _) = case(2, 2);
        assert!(
            e_big > 0.8 * e_small,
            "4 GPUs should not dominate 2: {e_big} vs {e_small}"
        );
    }

    #[test]
    fn per_gpu_power_drops_with_parallelism() {
        let (p1, _, _) = case(2, 1);
        let (p2, _, _) = case(2, 2);
        assert!(p2 < p1, "per-GPU power {p2} !< {p1}");
    }
}
