//! Design ablations (DESIGN.md §4 "ABL"): sensitivity of the headline
//! quantities to the power-model parameters the paper fixes
//! heuristically (γ = 0.7, mfu_sat = 0.45, PUE = 1.2), the accounting
//! mode (physical vs the literal Eq. 3), and the power-model baselines
//! (§2's NVML-utilization proxy and a static-TDP estimator).

use super::common::save;
use crate::config::simconfig::SimConfig;
use crate::energy::{AccountingMode, EnergyAccountant};
use crate::power::{PowerModel, PowerParams};
use crate::sim;
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let mut cfg = SimConfig::default();
    cfg.num_requests = if fast { 192 } else { 1024 };
    cfg.seed = case_seed(0xAB1, 0);
    // One materialized run, re-accounted under every power-model
    // variant — the single experiment that genuinely needs the full
    // stage log rather than the streaming sink.
    let out = sim::run(&cfg)?;
    let gpu = cfg.gpu_spec()?;
    let makespan = out.metrics.makespan_s;

    let mut table = Table::new(&["variant", "avg_power_w", "energy_kwh", "delta_vs_default_pct"]);
    let base_params = PowerParams::from_gpu(gpu);

    let account = |model: PowerModel, mode: AccountingMode| {
        EnergyAccountant {
            mode,
            power_model: model,
            grid_ci: 418.2,
        }
        .account(&cfg, &out.stagelog, makespan)
    };

    let default_rep = account(
        PowerModel::MfuPowerLaw(base_params),
        AccountingMode::Physical,
    );
    let base_kwh = default_rep.energy_kwh;
    let mut push = |name: &str, rep: &crate::energy::EnergyReport| {
        table.push_row(vec![
            name.to_string(),
            format!("{:.1}", rep.avg_power_w),
            format!("{:.4}", rep.energy_kwh),
            format!("{:+.1}", (rep.energy_kwh / base_kwh - 1.0) * 100.0),
        ]);
    };
    push("default (gamma=0.7, sat=0.45, physical)", &default_rep);

    // γ sweep.
    for gamma in [0.5, 0.9, 1.0] {
        let mut p = base_params;
        p.gamma = gamma;
        push(
            &format!("gamma={gamma}"),
            &account(PowerModel::MfuPowerLaw(p), AccountingMode::Physical),
        );
    }
    // mfu_sat sweep.
    for sat in [0.35, 0.55] {
        let mut p = base_params;
        p.mfu_sat = sat;
        push(
            &format!("mfu_sat={sat}"),
            &account(PowerModel::MfuPowerLaw(p), AccountingMode::Physical),
        );
    }
    // Accounting mode.
    push(
        "paper_eq3_accounting",
        &account(PowerModel::MfuPowerLaw(base_params), AccountingMode::PaperEq3),
    );
    // Baseline estimators (§2 motivation).
    push(
        "nvml_utilization_proxy",
        &account(
            PowerModel::NvmlProxy {
                p_idle: gpu.p_idle,
                p_max: gpu.p_max_inst,
                busy_util: 0.95,
            },
            AccountingMode::Physical,
        ),
    );
    push(
        "static_tdp_60pct (LLMCarbon-style)",
        &account(
            PowerModel::StaticTdp {
                p_max: gpu.p_max_inst,
                fraction: 0.6,
            },
            AccountingMode::Physical,
        ),
    );

    let mut meta = Value::obj();
    meta.set("experiment", "ablation")
        .set(
            "description",
            "power-model parameter sensitivity + estimator baselines over one default run",
        )
        .set(
            "sweep",
            super::common::sweep_meta_parts(
                1,
                out.oracle,
                out.metrics.stage_count,
                None,
                None,
            ),
        );
    save(out_dir, "ablation", &table, meta)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_runs_and_orders_estimators() {
        let dir = std::env::temp_dir().join("vidur_energy_abl_test");
        let mut cfg_dir = dir.clone();
        cfg_dir.push("x"); // ensure nested create works
        let t = run(&dir, true).unwrap();
        // NVML proxy must report more energy than the MFU law (the
        // paper's core §2 claim).
        let find = |name: &str| {
            t.rows
                .iter()
                .find(|r| r[0].contains(name))
                .map(|r| r[2].parse::<f64>().unwrap())
                .unwrap()
        };
        assert!(find("nvml") > find("default"));
        std::fs::remove_dir_all(dir).ok();
    }
}
