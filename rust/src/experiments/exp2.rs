//! Experiment 2 (Fig. 3) — prefill:decode ratio vs power and energy
//! across fixed request lengths. Paper findings: at fixed P:D, power
//! and energy grow with request length; at fixed length, decode-heavy
//! mixes (lower P:D) raise power and energy for long requests while
//! short requests barely move.
//!
//! Note on axes: the paper's text says "increasing the P:D ratio
//! (i.e., more decode-heavy)" — treating larger ratio values as more
//! decode; we sweep the ratio r = prefill/decode from 50:1 to 1:50 and
//! report both conventions in the CSV (`pd_ratio` = prefill/decode).

use super::common::{run_grid, save_grid};
use crate::config::simconfig::{LengthDist, SimConfig};
use crate::util::csv::Table;
use crate::util::json::Value;
use crate::util::rng::case_seed;
use anyhow::Result;
use std::path::Path;

pub const RATIOS: &[f64] = &[50.0, 10.0, 2.0, 1.0, 0.5, 0.1, 0.02];
pub const LENGTHS: &[u64] = &[128, 512, 1024, 2048, 4096];

pub fn run(out_dir: &Path, fast: bool) -> Result<Table> {
    let ratios: &[f64] = if fast { &[50.0, 1.0, 0.02] } else { RATIOS };
    let lengths: &[u64] = if fast { &[128, 2048] } else { LENGTHS };
    let mut cases = Vec::new();
    let mut cfgs = Vec::new();
    for &ratio in ratios {
        for &len in lengths {
            let mut cfg = SimConfig::default();
            cfg.lengths = LengthDist::Fixed { total: len };
            cfg.prefill_decode_ratio = Some(ratio);
            cfg.num_requests = if fast { 192 } else { 1024 };
            cfg.seed = case_seed(0xE2, cfgs.len() as u64);
            cases.push((ratio, len));
            cfgs.push(cfg);
        }
    }
    let grid = run_grid("exp2", cfgs)?;

    let mut table = Table::new(&[
        "pd_ratio", "request_len", "avg_power_w", "energy_kwh", "weighted_mfu",
        "makespan_s",
    ]);
    for (i, r) in grid.iter() {
        let (ratio, len) = cases[i];
        table.push_row(vec![
            format!("{ratio}"),
            len.to_string(),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.4}", r.energy_kwh()),
            format!("{:.4}", r.mfu()),
            format!("{:.1}", r.out.metrics.makespan_s),
        ]);
    }
    let mut meta = Value::obj();
    meta.set("figure", "fig3")
        .set(
            "paper_claim",
            "power/energy rise with request length; decode-heavy mixes cost more on long requests",
        )
        .set("sweep", grid.sweep_meta());
    save_grid(out_dir, "exp2", &table, meta, &grid)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use crate::config::simconfig::{CostModelKind, LengthDist, SimConfig};
    use crate::experiments::common::run_case;

    fn case(len: u64, ratio: f64) -> (f64, f64) {
        let mut cfg = SimConfig::default();
        cfg.cost_model = CostModelKind::Native;
        cfg.lengths = LengthDist::Fixed { total: len };
        cfg.prefill_decode_ratio = Some(ratio);
        cfg.num_requests = 128;
        cfg.seed = 3;
        let r = run_case(&cfg).unwrap();
        (r.avg_power_w(), r.energy_kwh())
    }

    #[test]
    fn longer_requests_cost_more_energy() {
        let (_, e_short) = case(128, 4.0);
        let (_, e_long) = case(2048, 4.0);
        assert!(e_long > 3.0 * e_short, "short {e_short} long {e_long}");
    }

    #[test]
    fn decode_heavy_long_requests_use_more_energy() {
        // At fixed length, decode-heavy (1:10) costs more total energy
        // than prefill-heavy (10:1): decode iterates per token.
        let (_, e_prefill_heavy) = case(2048, 10.0);
        let (_, e_decode_heavy) = case(2048, 0.1);
        assert!(
            e_decode_heavy > 1.2 * e_prefill_heavy,
            "decode {e_decode_heavy} prefill {e_prefill_heavy}"
        );
    }
}
