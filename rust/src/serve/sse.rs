//! Server-Sent Events framing and the snapshot broadcast hub
//! (DESIGN.md §11).
//!
//! SSE is the transport of choice here because it is *plain HTTP*: a
//! `text/event-stream` response body that never ends, one event per
//! blank-line-terminated frame, resumable via `Last-Event-ID`. No
//! upgrade handshake, no masking, no frames to parse on the write
//! side — exactly what a zero-dependency server can afford, and
//! `curl -N` / `EventSource` consume it natively.
//!
//! The [`SnapshotHub`] is the fan-out point between the watch pipeline
//! (one publisher thread per sweep worker, via the process-wide
//! snapshot tap) and any number of SSE subscriber connections. It is a
//! bounded ring: publishers never block (a slow subscriber costs
//! *itself* a [`Next::Lagged`] gap, never the sweep), and subscribers
//! wait on a condvar with a timeout so they can interleave keep-alive
//! comments and shutdown checks with delivery.
//!
//! Cursors are **arrival numbers** (0-based count of snapshots ever
//! published), not snapshot `seq`: several views of an `experiment
//! all` run publish interleaved, and arrival order is the only total
//! order the hub itself can guarantee. `Last-Event-ID` resume maps the
//! client's last seen `seq` back onto the earliest retained arrival
//! after it.

use crate::telemetry::window::Snapshot;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Default ring capacity: at one snapshot per simulated minute per
/// case, 4096 spans hours of history for a 9-case grid — enough that
/// a resuming dashboard rarely sees a gap, small enough to be noise
/// in memory.
pub const DEFAULT_HUB_CAPACITY: usize = 4096;

/// What a subscriber gets from [`SnapshotHub::next`].
#[derive(Debug, Clone, PartialEq)]
pub enum Next {
    /// The snapshot at the cursor; the returned cursor is the arrival
    /// number to pass back for the one after it.
    Event(u64, Snapshot),
    /// The cursor fell off the ring (slow subscriber); delivery resumes
    /// at the returned oldest-retained arrival. The count of skipped
    /// snapshots is `returned - requested`.
    Lagged(u64),
    /// Nothing new within the timeout — send a keep-alive and retry.
    Timeout,
    /// The hub shut down; the stream is over.
    Closed,
}

struct HubInner {
    /// Snapshots ever published (the next arrival number).
    arrivals: u64,
    /// Retained suffix: (arrival number, snapshot), oldest first.
    ring: VecDeque<(u64, Snapshot)>,
    cap: usize,
    closed: bool,
}

/// Bounded broadcast ring for [`Snapshot`]s: non-blocking publish,
/// condvar-timeout subscribe, explicit lag signalling.
pub struct SnapshotHub {
    inner: Mutex<HubInner>,
    cond: Condvar,
}

impl SnapshotHub {
    pub fn new(cap: usize) -> SnapshotHub {
        SnapshotHub {
            inner: Mutex::new(HubInner {
                arrivals: 0,
                ring: VecDeque::new(),
                cap: cap.max(1),
                closed: false,
            }),
            cond: Condvar::new(),
        }
    }

    /// Publish one snapshot. Never blocks beyond the mutex: when the
    /// ring is full the oldest entry is dropped (slow subscribers see
    /// [`Next::Lagged`]).
    pub fn publish(&self, s: Snapshot) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.closed {
            return;
        }
        let n = g.arrivals;
        g.arrivals += 1;
        g.ring.push_back((n, s));
        while g.ring.len() > g.cap {
            g.ring.pop_front();
        }
        drop(g);
        self.cond.notify_all();
    }

    /// Close the hub: publishes stop, every waiting subscriber wakes
    /// with [`Next::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).closed = true;
        self.cond.notify_all();
    }

    /// Cursor for "everything retained" — the oldest arrival still in
    /// the ring (a fresh subscriber replays the available history; for
    /// a live fleet that is exactly the state it needs to catch up).
    pub fn cursor_oldest(&self) -> u64 {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.ring.front().map(|(n, _)| *n).unwrap_or(g.arrivals)
    }

    /// Cursor for "only what happens next" (no replay).
    pub fn cursor_now(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).arrivals
    }

    /// Cursor resuming *after* the snapshot with sequence `last_seq`:
    /// the first retained arrival whose snapshot has `seq > last_seq`,
    /// or the live end when everything retained was already seen. A
    /// `last_seq` older than the ring simply replays from the oldest —
    /// the client asked for history the ring no longer holds.
    pub fn cursor_after_seq(&self, last_seq: u64) -> u64 {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.ring
            .iter()
            .find(|(_, s)| s.seq > last_seq)
            .map(|(n, _)| *n)
            .unwrap_or(g.arrivals)
    }

    /// Block (up to `timeout`) for the snapshot at arrival `cursor`.
    pub fn next(&self, cursor: u64, timeout: Duration) -> Next {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(front) = g.ring.front().map(|(n, _)| *n) {
                if cursor < front {
                    return Next::Lagged(front);
                }
                if cursor < g.arrivals {
                    let idx = (cursor - front) as usize;
                    let (n, s) = &g.ring[idx];
                    debug_assert_eq!(*n, cursor);
                    return Next::Event(*n, s.clone());
                }
            }
            if g.closed {
                return Next::Closed;
            }
            let (guard, res) = self
                .cond
                .wait_timeout(g, timeout)
                .unwrap_or_else(|e| e.into_inner());
            g = guard;
            if res.timed_out() {
                // Re-check once after the timeout: a publish may have
                // raced the wakeup.
                if g.ring.back().map(|(n, _)| *n >= cursor).unwrap_or(false) || g.closed {
                    continue;
                }
                return Next::Timeout;
            }
        }
    }
}

/// Frame one SSE event. Multi-line data is split across `data:` lines
/// per the spec; the blank line terminates the frame.
pub fn sse_frame(event: Option<&str>, id: Option<u64>, data: &str) -> String {
    let mut out = String::new();
    if let Some(e) = event {
        out.push_str("event: ");
        out.push_str(e);
        out.push('\n');
    }
    if let Some(i) = id {
        out.push_str(&format!("id: {i}\n"));
    }
    for line in data.split('\n') {
        out.push_str("data: ");
        out.push_str(line);
        out.push('\n');
    }
    out.push('\n');
    out
}

/// An SSE comment line (keep-alive; clients ignore it).
pub fn sse_comment(text: &str) -> String {
    format!(": {text}\n\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn snap(seq: u64) -> Snapshot {
        Snapshot {
            experiment: "expX".into(),
            shard: None,
            case_index: seq % 4,
            seq,
            t_s: seq as f64,
            done: false,
            cases_done: 0,
            cases_owned: 4,
            cases_total: 4,
            finished: 0,
            stages: 0,
            qps: 0.0,
            ttft_p50_s: 0.0,
            ttft_p99_s: 0.0,
            e2e_p50_s: 0.0,
            e2e_p99_s: 0.0,
            norm_latency_p50_s_per_tok: 0.0,
            power_w: 0.0,
            mfu: 0.0,
            energy_kwh: 0.0,
            gco2_g: 0.0,
        }
    }

    #[test]
    fn sse_frames_follow_the_spec_shape() {
        let f = sse_frame(Some("snapshot"), Some(7), "{\"a\":1}");
        assert_eq!(f, "event: snapshot\nid: 7\ndata: {\"a\":1}\n\n");
        // Multi-line data splits into one data: line per line.
        let f = sse_frame(None, None, "line1\nline2");
        assert_eq!(f, "data: line1\ndata: line2\n\n");
        assert_eq!(sse_comment("keep-alive"), ": keep-alive\n\n");
    }

    #[test]
    fn hub_delivers_in_order_and_signals_lag() {
        let hub = SnapshotHub::new(4);
        assert_eq!(hub.cursor_now(), 0);
        assert_eq!(hub.cursor_oldest(), 0);
        for i in 1..=3 {
            hub.publish(snap(i));
        }
        let mut cur = hub.cursor_oldest();
        let mut seqs = Vec::new();
        while let Next::Event(n, s) = hub.next(cur, Duration::from_millis(1)) {
            cur = n + 1;
            seqs.push(s.seq);
        }
        assert_eq!(seqs, vec![1, 2, 3]);
        assert_eq!(hub.next(cur, Duration::from_millis(1)), Next::Timeout);

        // Overflow: cap 4, publish 6 more — the oldest fall off and a
        // stale cursor is told where delivery resumes.
        for i in 4..=9 {
            hub.publish(snap(i));
        }
        match hub.next(0, Duration::from_millis(1)) {
            Next::Lagged(resume) => {
                assert_eq!(resume, 5, "ring holds arrivals 5..=8 (snaps 6..=9)");
                match hub.next(resume, Duration::from_millis(1)) {
                    Next::Event(_, s) => assert_eq!(s.seq, 6),
                    other => panic!("expected event after lag, got {other:?}"),
                }
            }
            other => panic!("expected Lagged, got {other:?}"),
        }
    }

    #[test]
    fn cursor_after_seq_resumes_past_the_given_sequence() {
        let hub = SnapshotHub::new(16);
        for i in [10, 20, 30] {
            hub.publish(snap(i));
        }
        // Resume after seq 20 → arrival of seq 30 (arrival 2).
        let cur = hub.cursor_after_seq(20);
        match hub.next(cur, Duration::from_millis(1)) {
            Next::Event(_, s) => assert_eq!(s.seq, 30),
            other => panic!("{other:?}"),
        }
        // Everything seen already → live end (timeout until new data).
        let cur = hub.cursor_after_seq(30);
        assert_eq!(hub.next(cur, Duration::from_millis(1)), Next::Timeout);
        // Ancient seq → oldest retained.
        assert_eq!(hub.cursor_after_seq(0), 0);
    }

    /// A subscriber blocked in next() wakes on publish from another
    /// thread, and close() ends every stream.
    #[test]
    fn blocking_subscriber_wakes_on_publish_and_close() {
        let hub = Arc::new(SnapshotHub::new(16));
        let h2 = hub.clone();
        let t = std::thread::spawn(move || {
            let first = h2.next(0, Duration::from_secs(10));
            let second = h2.next(1, Duration::from_secs(10));
            (first, second)
        });
        std::thread::sleep(Duration::from_millis(30));
        hub.publish(snap(1));
        std::thread::sleep(Duration::from_millis(30));
        hub.close();
        let (first, second) = t.join().unwrap();
        match first {
            Next::Event(0, s) => assert_eq!(s.seq, 1),
            other => panic!("{other:?}"),
        }
        assert_eq!(second, Next::Closed);
        // Publishing after close is a no-op.
        hub.publish(snap(2));
        assert_eq!(hub.cursor_now(), 1);
    }

    /// Stress the lag-resume invariant under real concurrency: with a
    /// tiny ring and publishers racing subscribers, every arrival is
    /// either delivered exactly once or counted in exactly one
    /// `Lagged` gap — never skipped past silently, never delivered
    /// twice. The dangerous window is a subscriber acting on a
    /// `Lagged(resume)` cursor while concurrent publishes push the
    /// ring past `resume` again; the accounting below fails loudly on
    /// any off-by-one in either direction.
    #[test]
    fn hub_lag_resume_neither_skips_nor_double_delivers_under_races() {
        const TOTAL: u64 = 2000;
        const SUBSCRIBERS: usize = 3;
        let hub = Arc::new(SnapshotHub::new(8));
        let mut subs = Vec::new();
        for _ in 0..SUBSCRIBERS {
            let h = hub.clone();
            subs.push(std::thread::spawn(move || {
                let mut cursor = 0u64;
                let mut covered = 0u64;
                let mut skipped = 0u64;
                loop {
                    match h.next(cursor, Duration::from_millis(5)) {
                        Next::Event(n, s) => {
                            // Delivery at exactly the requested cursor:
                            // n < cursor would be a double-delivery,
                            // n > cursor a silent skip.
                            assert_eq!(n, cursor, "event at wrong arrival");
                            // Arrival n carries the snapshot published
                            // n-th (seq = n + 1 by construction), so a
                            // ring-indexing bug shows up as a mismatch.
                            assert_eq!(s.seq, n + 1, "wrong snapshot at arrival {n}");
                            covered += 1;
                            cursor = n + 1;
                        }
                        Next::Lagged(resume) => {
                            // A lag must move forward and account for
                            // every arrival it jumps over.
                            assert!(resume > cursor, "Lagged must advance the cursor");
                            skipped += resume - cursor;
                            cursor = resume;
                        }
                        Next::Timeout => continue,
                        Next::Closed => break,
                    }
                }
                (cursor, covered, skipped)
            }));
        }
        // Publish from two racing threads through one ordering lock, so
        // arrival numbers stay the only total order while the condvar
        // wakeups and ring evictions interleave with the subscribers.
        let seq_lock = Arc::new(std::sync::Mutex::new(0u64));
        let mut pubs = Vec::new();
        for _ in 0..2 {
            let h = hub.clone();
            let lock = seq_lock.clone();
            pubs.push(std::thread::spawn(move || loop {
                let mut g = lock.lock().unwrap();
                if *g == TOTAL {
                    return;
                }
                *g += 1;
                let seq = *g;
                h.publish(snap(seq));
                drop(g);
                if seq % 64 == 0 {
                    // Let subscribers catch up sometimes so the test
                    // exercises both the lagged and the live path.
                    std::thread::sleep(Duration::from_millis(1));
                } else {
                    std::thread::yield_now();
                }
            }));
        }
        for p in pubs {
            p.join().unwrap();
        }
        hub.close();
        for s in subs {
            let (cursor, covered, skipped) = s.join().unwrap();
            // close() wakes subscribers only after the ring is drained
            // (next() prefers delivery over Closed), so each must have
            // accounted for every single arrival.
            assert_eq!(cursor, TOTAL, "subscriber stopped short of the live end");
            assert_eq!(
                covered + skipped,
                TOTAL,
                "arrivals lost or double-counted (covered {covered}, skipped {skipped})"
            );
            assert!(covered > 0, "subscriber never saw a delivery");
        }
    }
}
