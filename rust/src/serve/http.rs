//! Minimal HTTP/1.1 head parsing and response framing for the serve
//! plane (DESIGN.md §11). std-only, pure functions — every byte-level
//! decision lives here so the unit tests can drive torn reads,
//! pipelined requests and hostile input without a socket in sight.
//!
//! Scope is deliberately narrow: the serve plane speaks exactly the
//! slice of HTTP/1.1 its own endpoints need (GET/POST, fixed
//! `Content-Length` bodies, a handful of headers). Everything outside
//! that slice is *rejected loudly* with the right status code rather
//! than half-implemented: chunked transfer encoding → 501, unknown
//! versions → 505, header obs-folding → 400. A malformed request must
//! never panic the server — the connection handler turns every
//! [`HttpError`] into a well-formed error response.

use std::collections::BTreeMap;

/// Cap on the request head (request line + headers). Our biggest
/// legitimate head is a `Last-Event-ID` resume — tiny; 16 KiB leaves
/// room for chatty proxies while bounding a hostile slowloris feed.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Cap on a request body. `POST /v1/sweeps` bodies are < 1 KiB; 1 MiB
/// is generous headroom, beyond it we answer 413 instead of buffering.
pub const MAX_BODY_BYTES: u64 = 1024 * 1024;

/// Cap on the header count (each costs a map entry; 64 is far above
/// anything a real client sends).
pub const MAX_HEADERS: usize = 64;

/// A request-level failure mapped to an HTTP status. The connection
/// handler renders it as a JSON error body; it never propagates as a
/// panic or a process error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    pub fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError {
            status,
            msg: msg.into(),
        }
    }
}

/// The parsed request head.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Head {
    /// Request method, verbatim (methods are case-sensitive).
    pub method: String,
    /// Percent-decoded path, without the query string.
    pub path: String,
    /// Percent-decoded `k=v` query pairs, in order of appearance.
    pub query: Vec<(String, String)>,
    /// Headers with lowercased names (values trimmed, verbatim case).
    pub headers: BTreeMap<String, String>,
}

impl Head {
    /// Header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// First query parameter with this name.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// The declared body length: `Content-Length` when present and
    /// well-formed, 0 when absent. Chunked bodies are refused at parse
    /// time, so absence really does mean "no body".
    pub fn content_length(&self) -> Result<u64, HttpError> {
        match self.header("content-length") {
            None => Ok(0),
            Some(v) => v
                .trim()
                .parse::<u64>()
                .map_err(|_| HttpError::new(400, format!("bad content-length '{v}'"))),
        }
    }
}

/// Outcome of a head-parse attempt over the bytes buffered so far.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseOutcome {
    /// No complete head yet — read more bytes and try again.
    Incomplete,
    /// A complete head; `consumed` bytes of the buffer belong to it
    /// (the rest is body and/or the next pipelined request).
    Ready { head: Head, consumed: usize },
}

/// Find the end of the head: the first blank line. Accepts `\r\n\r\n`
/// and bare `\n\n` (curl and friends always send CRLF; being liberal
/// here costs nothing and keeps hand-rolled test clients simple).
/// Returns (head bytes, total consumed through the terminator).
fn find_head_end(buf: &[u8]) -> Option<(usize, usize)> {
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n");
    let lf = buf.windows(2).position(|w| w == b"\n\n");
    match (crlf, lf) {
        (Some(c), Some(l)) if l < c => Some((l, l + 2)),
        (Some(c), _) => Some((c, c + 4)),
        (None, Some(l)) => Some((l, l + 2)),
        (None, None) => None,
    }
}

/// Percent-decode a path/query component; stray or truncated escapes
/// pass through verbatim (we never serve filesystem paths, so lenient
/// decoding cannot escape anything).
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'%' && i + 3 <= bytes.len() {
            // Byte-wise, not `&s[..]`: a str slice could land mid-char
            // next to a multi-byte sequence and panic.
            let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).ok();
            if let Some(v) = hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                out.push(v);
                i += 3;
                continue;
            }
        }
        if b == b'+' {
            out.push(b' ');
        } else {
            out.push(b);
        }
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Split a raw target into (decoded path, decoded query pairs).
fn parse_target(target: &str) -> (String, Vec<(String, String)>) {
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    let query = raw_query
        .map(|q| {
            q.split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (percent_decode(k), percent_decode(v)),
                    None => (percent_decode(kv), String::new()),
                })
                .collect()
        })
        .unwrap_or_default();
    (percent_decode(raw_path), query)
}

/// Try to parse one request head from the front of `buf`.
///
/// * Not enough bytes yet → `Ok(Incomplete)` — unless the buffer
///   already exceeds [`MAX_HEAD_BYTES`] without a terminator, which is
///   a 431.
/// * A complete but malformed head → `Err` with the right status.
/// * A complete well-formed head → `Ready` with the consumed length,
///   so the connection loop can drain it and immediately re-parse the
///   remainder (pipelining).
pub fn parse_head(buf: &[u8]) -> Result<ParseOutcome, HttpError> {
    let Some((head_len, consumed)) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError::new(431, "request head too large"));
        }
        return Ok(ParseOutcome::Incomplete);
    };
    if head_len > MAX_HEAD_BYTES {
        return Err(HttpError::new(431, "request head too large"));
    }
    let head = std::str::from_utf8(&buf[..head_len])
        .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => {
            return Err(HttpError::new(
                400,
                format!("malformed request line '{request_line}'"),
            ))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(
            505,
            format!("unsupported protocol version '{version}'"),
        ));
    }
    if !target.starts_with('/') {
        // Absolute-form / CONNECT targets — not this server's job.
        return Err(HttpError::new(400, format!("unsupported target '{target}'")));
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            // RFC 7230 deprecated obs-folding; refusing is the
            // conforming behaviour and dodges request-smuggling games.
            return Err(HttpError::new(400, "folded header lines are not supported"));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::new(400, format!("malformed header line '{line}'")));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::new(400, format!("malformed header name '{name}'")));
        }
        let lname = name.to_ascii_lowercase();
        let prev = headers.insert(lname.clone(), value.trim().to_string());
        if prev.is_some() && lname == "content-length" {
            // Duplicate Content-Length — even two *agreeing* copies —
            // is the classic request-smuggling shape (first-wins vs
            // last-wins disagreement between parsers). Reject outright
            // rather than pick a winner.
            return Err(HttpError::new(
                400,
                "duplicate content-length header",
            ));
        }
        if headers.len() > MAX_HEADERS {
            return Err(HttpError::new(431, "too many headers"));
        }
    }
    if let Some(te) = headers.get("transfer-encoding") {
        return Err(HttpError::new(
            501,
            format!("transfer-encoding '{te}' is not supported"),
        ));
    }
    let (path, query) = parse_target(target);
    Ok(ParseOutcome::Ready {
        head: Head {
            method: method.to_string(),
            path,
            query,
            headers,
        },
        consumed,
    })
}

/// Reason phrase for the status codes this server emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Frame a complete HTTP/1.1 response. `extra_headers` are verbatim
/// `Name: value` lines (e.g. `Allow: GET` on a 405).
pub fn response(
    status: u16,
    content_type: &str,
    body: &[u8],
    extra_headers: &[&str],
) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        status_text(status),
        body.len()
    );
    for h in extra_headers {
        out.push_str(h);
        out.push_str("\r\n");
    }
    out.push_str("\r\n");
    let mut bytes = out.into_bytes();
    bytes.extend_from_slice(body);
    bytes
}

/// Frame a JSON error body for an [`HttpError`].
pub fn error_response(e: &HttpError) -> Vec<u8> {
    let mut v = crate::util::json::Value::obj();
    v.set("error", e.msg.as_str());
    response(e.status, "application/json", v.to_string().as_bytes(), &[])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ready(buf: &[u8]) -> (Head, usize) {
        match parse_head(buf).unwrap() {
            ParseOutcome::Ready { head, consumed } => (head, consumed),
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn parses_a_plain_get() {
        let raw = b"GET /v1/fleet HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n";
        let (h, consumed) = ready(raw);
        assert_eq!(consumed, raw.len());
        assert_eq!(h.method, "GET");
        assert_eq!(h.path, "/v1/fleet");
        assert!(h.query.is_empty());
        assert_eq!(h.header("host"), Some("x"));
        assert_eq!(h.header("HOST"), Some("x"), "lookup is case-insensitive");
        assert_eq!(h.content_length().unwrap(), 0);
    }

    #[test]
    fn parses_query_and_percent_escapes() {
        let raw = b"GET /v1/snapshots?last_event_id=42&x=a%20b&flag HTTP/1.1\r\n\r\n";
        let (h, _) = ready(raw);
        assert_eq!(h.path, "/v1/snapshots");
        assert_eq!(h.query_param("last_event_id"), Some("42"));
        assert_eq!(h.query_param("x"), Some("a b"));
        assert_eq!(h.query_param("flag"), Some(""));
        assert_eq!(h.query_param("missing"), None);
    }

    /// Torn reads: every prefix of a valid request must parse as
    /// Incomplete (never an error, never a panic) until the blank line
    /// lands.
    #[test]
    fn torn_reads_stay_incomplete_until_terminator() {
        let raw = b"POST /v1/sweeps HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}";
        let end = raw.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        for cut in 0..end {
            assert_eq!(
                parse_head(&raw[..cut]).unwrap(),
                ParseOutcome::Incomplete,
                "prefix of {cut} bytes"
            );
        }
        let (h, consumed) = ready(raw);
        assert_eq!(consumed, end, "body bytes are not consumed by the head");
        assert_eq!(h.content_length().unwrap(), 2);
    }

    /// Pipelining: two requests back-to-back parse one at a time via
    /// the consumed offset.
    #[test]
    fn pipelined_requests_parse_in_sequence() {
        let raw: &[u8] = b"GET /healthz HTTP/1.1\r\n\r\nGET /v1/fleet HTTP/1.1\r\n\r\n";
        let (h1, c1) = ready(raw);
        assert_eq!(h1.path, "/healthz");
        let (h2, c2) = ready(&raw[c1..]);
        assert_eq!(h2.path, "/v1/fleet");
        assert_eq!(c1 + c2, raw.len());
    }

    #[test]
    fn bare_lf_terminator_is_accepted() {
        let (h, consumed) = ready(b"GET / HTTP/1.1\nHost: x\n\n");
        assert_eq!(h.path, "/");
        assert_eq!(h.header("host"), Some("x"));
        assert_eq!(consumed, b"GET / HTTP/1.1\nHost: x\n\n".len());
    }

    #[test]
    fn hostile_input_errors_cleanly() {
        // Garbage request line.
        let e = parse_head(b"NOT A REQUEST LINE AT ALL\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        // Unsupported version.
        let e = parse_head(b"GET / HTTP/2.0\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 505);
        // Bad header line.
        let e = parse_head(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        // Folded header.
        let e = parse_head(b"GET / HTTP/1.1\r\nA: b\r\n c\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        // Chunked body.
        let e = parse_head(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 501);
        // Non-UTF-8 head.
        let e = parse_head(b"GET /\xff\xfe HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        // Absolute-form target.
        let e = parse_head(b"GET http://x/ HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        // Bad content-length surfaces on the accessor.
        let (h, _) = ready(b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n");
        assert_eq!(h.content_length().unwrap_err().status, 400);
    }

    /// Duplicate `Content-Length` headers — conflicting, agreeing, or
    /// mixed-case — are the request-smuggling shape and must be a 400
    /// at parse time, never a silent first/last-wins pick.
    #[test]
    fn duplicate_content_length_is_rejected() {
        // Conflicting values.
        let e = parse_head(
            b"POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 10\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.msg.contains("content-length"), "{}", e.msg);
        // Even agreeing duplicates are rejected (two parsers may
        // disagree on which copy to honour).
        let e = parse_head(
            b"POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 5\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(e.status, 400);
        // Case-insensitive: the duplicate hides behind different casing.
        let e = parse_head(
            b"POST / HTTP/1.1\r\ncontent-length: 5\r\nCONTENT-LENGTH: 10\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(e.status, 400);
        // A comma-joined value smuggled into one line fails on the
        // accessor instead (not a valid u64).
        let (h, _) = ready(b"POST / HTTP/1.1\r\nContent-Length: 5, 5\r\n\r\n");
        assert_eq!(h.content_length().unwrap_err().status, 400);
        // Duplicates of *other* headers keep last-wins behaviour — only
        // body framing is smuggling-sensitive.
        let (h, _) = ready(b"GET / HTTP/1.1\r\nX-A: one\r\nX-A: two\r\n\r\n");
        assert_eq!(h.header("x-a"), Some("two"));
    }

    /// An oversized head without a terminator is a 431, not unbounded
    /// buffering; with a terminator past the cap likewise.
    #[test]
    fn oversized_heads_are_bounded() {
        let mut big = b"GET /".to_vec();
        big.resize(big.len() + MAX_HEAD_BYTES + 10, b'a');
        assert_eq!(parse_head(&big).unwrap_err().status, 431);
        let mut terminated = b"GET / HTTP/1.1\r\n".to_vec();
        while terminated.len() <= MAX_HEAD_BYTES {
            terminated.extend_from_slice(b"X-Filler: yyyyyyyyyyyyyyyyyyyyyyyyyyyyyyyy\r\n");
        }
        terminated.extend_from_slice(b"\r\n");
        assert_eq!(parse_head(&terminated).unwrap_err().status, 431);
        // Too many individually-small headers likewise.
        let mut many = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 2) {
            many.extend_from_slice(format!("H{i}: v\r\n").as_bytes());
        }
        many.extend_from_slice(b"\r\n");
        assert_eq!(parse_head(&many).unwrap_err().status, 431);
    }

    /// Random byte soup must never panic the parser — every outcome is
    /// Incomplete, Ready or a clean HttpError.
    #[test]
    fn fuzzed_bytes_never_panic() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xBADC0DE);
        for _ in 0..500 {
            let len = (rng.next_u64() % 200) as usize;
            let buf: Vec<u8> = (0..len).map(|_| (rng.next_u64() % 256) as u8).collect();
            let _ = parse_head(&buf);
        }
        // And byte soup appended to a valid prefix.
        for _ in 0..200 {
            let mut buf = b"GET /x HTTP/1.1\r\n".to_vec();
            let len = (rng.next_u64() % 100) as usize;
            buf.extend((0..len).map(|_| (rng.next_u64() % 256) as u8));
            let _ = parse_head(&buf);
        }
        // Random repeated-header soup: a handful of names (including
        // content-length) repeated in random order and casing must
        // parse cleanly or error cleanly — never panic, and never
        // accept two content-length copies.
        let names = ["Content-Length", "content-length", "X-A", "Host"];
        for _ in 0..200 {
            let mut buf = b"POST / HTTP/1.1\r\n".to_vec();
            let n = 1 + (rng.next_u64() % 5) as usize;
            let mut cl_count = 0usize;
            for _ in 0..n {
                let name = names[(rng.next_u64() % names.len() as u64) as usize];
                if name.eq_ignore_ascii_case("content-length") {
                    cl_count += 1;
                }
                buf.extend_from_slice(
                    format!("{name}: {}\r\n", rng.next_u64() % 100).as_bytes(),
                );
            }
            buf.extend_from_slice(b"\r\n");
            match parse_head(&buf) {
                Ok(ParseOutcome::Ready { .. }) => {
                    assert!(cl_count <= 1, "duplicate content-length accepted");
                }
                Ok(ParseOutcome::Incomplete) => panic!("terminated head read as Incomplete"),
                Err(e) => assert_eq!(e.status, 400),
            }
        }
    }

    #[test]
    fn response_frames_status_headers_and_body() {
        let bytes = response(405, "application/json", b"{}", &["Allow: GET"]);
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Allow: GET\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
        let err = error_response(&HttpError::new(400, "nope"));
        let err = String::from_utf8(err).unwrap();
        assert!(err.contains(r#"{"error": "nope"}"#) || err.contains(r#"{"error":"nope"}"#), "{err}");
    }
}
