//! Shared state behind the serve endpoints (DESIGN.md §11): the
//! latest-per-case fleet map, the broadcast hub, and the background
//! sweep registry.
//!
//! Everything here is observation bookkeeping plus a thin job queue —
//! none of it touches the simulation itself. Hosted sweeps run through
//! the exact same `experiments::run_by_id` path the CLI uses, with the
//! watch configured to a JSONL file inside the job's own output
//! directory, so a served sweep's artifacts are byte-identical to an
//! unserved run's (`tests/serve_http.rs` asserts this).

use crate::report::live::{self, snapshot_supersedes};
use crate::serve::sse::{SnapshotHub, DEFAULT_HUB_CAPACITY};
use crate::sweep::ShardSpec;
use crate::telemetry::window::Snapshot;
use crate::util::json::Value;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Format tag on every JSON body the serve plane emits; bumped on
/// breaking contract changes (the endpoint contract is part of the
/// crate's public surface — see DESIGN.md §11).
pub const SERVE_FORMAT: &str = "vidur-energy/serve/v1";

/// One sweep-submission request (`POST /v1/sweeps` body).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRequest {
    /// Experiment id (`exp1`, `autoscale`, `all`, …).
    pub experiment: String,
    /// Worker threads for the sweep (the CLI's `--jobs`).
    pub jobs: usize,
    /// Shard label (`k/N`) or `None` for the whole grid.
    pub shard: Option<String>,
    /// Reduced-size run (the CLI's `--fast`).
    pub fast: bool,
    /// Output directory, assigned by the registry (`<out>/sweep-<id>`).
    pub out: PathBuf,
}

impl SweepRequest {
    /// Parse and validate a submission body. Unknown experiments and
    /// malformed shards are rejected here — before a job is enqueued —
    /// so the client gets a 400, not a job that fails later.
    pub fn from_json(v: &Value) -> Result<SweepRequest> {
        let experiment = v.req_str("experiment")?.to_string();
        let known = crate::report::EXPERIMENT_IDS.contains(&experiment.as_str())
            || experiment == "all";
        anyhow::ensure!(
            known,
            "unknown experiment '{experiment}' (expected one of {}, or 'all')",
            crate::report::EXPERIMENT_IDS.join(", ")
        );
        let jobs = match v.get("jobs") {
            None => crate::sweep::default_jobs(),
            Some(j) => {
                let j = j
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("'jobs' must be a positive integer"))?;
                anyhow::ensure!(j >= 1, "'jobs' must be >= 1");
                j as usize
            }
        };
        let shard = match v.get("shard") {
            None | Some(Value::Null) => None,
            Some(s) => {
                let s = s
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("'shard' must be a string like '0/2'"))?;
                ShardSpec::parse(s)?; // validate now, run later
                Some(s.to_string())
            }
        };
        let fast = match v.get("fast") {
            None => false,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("'fast' must be a boolean"))?,
        };
        Ok(SweepRequest {
            experiment,
            jobs,
            shard,
            fast,
            out: PathBuf::new(), // assigned on submit
        })
    }
}

/// Lifecycle of a submitted sweep job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SweepStatus {
    Queued,
    Running,
    Done,
    Failed(String),
}

impl SweepStatus {
    fn as_str(&self) -> &str {
        match self {
            SweepStatus::Queued => "queued",
            SweepStatus::Running => "running",
            SweepStatus::Done => "done",
            SweepStatus::Failed(_) => "failed",
        }
    }
}

/// Executes one sweep request (injectable: tests swap the real
/// experiment runner for a tiny deterministic grid).
pub type SweepRunner = Arc<dyn Fn(&SweepRequest) -> Result<()> + Send + Sync>;

struct SweepJob {
    id: u64,
    req: SweepRequest,
    status: SweepStatus,
}

/// The submitted-sweeps registry: a queue drained by one worker
/// thread. Sequential on purpose — sweep concurrency lives *inside* a
/// sweep (`--jobs`), and the watch/jobs/shard configuration is
/// process-global, so two hosted sweeps running at once would fight
/// over it.
pub struct SweepRegistry {
    jobs: Mutex<Vec<SweepJob>>,
    cond: Condvar,
    out_root: PathBuf,
}

impl SweepRegistry {
    pub fn new(out_root: PathBuf) -> SweepRegistry {
        SweepRegistry {
            jobs: Mutex::new(Vec::new()),
            cond: Condvar::new(),
            out_root,
        }
    }

    /// Enqueue a validated request; returns the job id (1-based) after
    /// assigning the job its own output directory.
    pub fn submit(&self, mut req: SweepRequest) -> u64 {
        let mut g = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        let id = g.len() as u64 + 1;
        req.out = self.out_root.join(format!("sweep-{id}"));
        g.push(SweepJob {
            id,
            req,
            status: SweepStatus::Queued,
        });
        drop(g);
        self.cond.notify_all();
        id
    }

    /// Status of one job as the `/v1/sweeps/<id>` JSON body.
    pub fn job_json(&self, id: u64) -> Option<Value> {
        let g = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        g.iter().find(|j| j.id == id).map(job_to_json)
    }

    /// All jobs, newest last (`/v1/sweeps` GET body).
    pub fn jobs_json(&self) -> Value {
        let g = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        let mut v = Value::obj();
        v.set("format", SERVE_FORMAT)
            .set("sweeps", Value::Arr(g.iter().map(job_to_json).collect()));
        v
    }

    /// Worker loop: claim the oldest queued job, run it, record the
    /// outcome; park on the condvar (with a timeout, to observe
    /// `shutdown`) when the queue is empty. Runs until `shutdown` *and*
    /// the queue is idle — an accepted job is never abandoned.
    pub fn run_worker(&self, runner: SweepRunner, shutdown: &AtomicBool) {
        loop {
            let claimed = {
                let mut g = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if let Some(j) = g.iter_mut().find(|j| j.status == SweepStatus::Queued) {
                        j.status = SweepStatus::Running;
                        break Some((j.id, j.req.clone()));
                    }
                    if shutdown.load(Ordering::SeqCst) {
                        break None;
                    }
                    let (guard, _) = self
                        .cond
                        .wait_timeout(g, Duration::from_millis(100))
                        .unwrap_or_else(|e| e.into_inner());
                    g = guard;
                }
            };
            let Some((id, req)) = claimed else { return };
            // A panicking runner must not leave the job stuck in
            // `running` (wedging the sequential queue forever) — catch
            // the unwind and record it as a failure so fleet retry
            // logic can observe it and the queue advances.
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                (*runner)(&req)
            }));
            let mut g = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(j) = g.iter_mut().find(|j| j.id == id) {
                j.status = match outcome {
                    Ok(Ok(())) => SweepStatus::Done,
                    Ok(Err(e)) => SweepStatus::Failed(format!("{e:#}")),
                    Err(payload) => SweepStatus::Failed(format!(
                        "panicked: {}",
                        panic_message(payload.as_ref())
                    )),
                };
            }
        }
    }
}

/// Best-effort text of a panic payload (`panic!("...")` yields a
/// `&str` or a `String`; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

fn job_to_json(j: &SweepJob) -> Value {
    let mut v = Value::obj();
    v.set("id", j.id)
        .set("experiment", j.req.experiment.as_str())
        .set("jobs", j.req.jobs as u64)
        .set(
            "shard",
            match &j.req.shard {
                Some(s) => Value::Str(s.clone()),
                None => Value::Null,
            },
        )
        .set("fast", j.req.fast)
        .set("out", j.req.out.display().to_string())
        .set("status", j.status.as_str());
    if let SweepStatus::Failed(msg) = &j.status {
        v.set("error", msg.as_str());
    }
    v
}

/// The sweep runner the CLI uses: configure the process-global
/// jobs/shard/watch the way the `repro experiment` command line would,
/// run the experiment, restore the globals. The watch target is a
/// JSONL file inside the job's output directory — the server's own
/// snapshot tap picks the stream up in process, and `repro watch
/// <out>` keeps working on the same file after the server exits.
pub fn default_runner() -> SweepRunner {
    Arc::new(|req: &SweepRequest| {
        let shard = match &req.shard {
            Some(s) => Some(ShardSpec::parse(s)?),
            None => None,
        };
        std::fs::create_dir_all(&req.out)?;
        let prev_jobs = crate::sweep::default_jobs();
        crate::sweep::set_default_jobs(req.jobs);
        crate::sweep::set_shard(shard);
        let mut watch = live::WatchConfig::stderr();
        watch.target = live::WatchTarget::Json(req.out.join(live::WATCH_FILENAME));
        live::set_watch(Some(watch));
        let result = crate::experiments::run_by_id(&req.experiment, &req.out, req.fast);
        live::set_watch(None);
        crate::sweep::set_shard(None);
        crate::sweep::set_default_jobs(prev_jobs);
        result
    })
}

/// The state every connection handler shares: the broadcast hub, the
/// latest-per-(experiment, shard, case) fleet map, and the sweep
/// registry.
pub struct ServeState {
    pub hub: SnapshotHub,
    fleet: Mutex<BTreeMap<(String, String, u64), Snapshot>>,
    pub sweeps: SweepRegistry,
}

impl ServeState {
    pub fn new(out_root: PathBuf) -> ServeState {
        ServeState {
            hub: SnapshotHub::new(DEFAULT_HUB_CAPACITY),
            fleet: Mutex::new(BTreeMap::new()),
            sweeps: SweepRegistry::new(out_root),
        }
    }

    /// Fold one snapshot in: update the fleet map (same supersedes
    /// rule as `repro watch`'s aggregation) and broadcast it. Both the
    /// in-process tap and the file followers call this, so a snapshot
    /// that arrives twice (tap + follower on the same file) lands in
    /// the same slot instead of double counting.
    pub fn ingest(&self, s: &Snapshot) {
        let key = (
            s.experiment.clone(),
            s.shard.clone().unwrap_or_default(),
            s.case_index,
        );
        {
            let mut fleet = self.fleet.lock().unwrap_or_else(|e| e.into_inner());
            match fleet.get_mut(&key) {
                Some(slot) => {
                    // Stale (older by the supersedes order) or an
                    // exact replay (a follower reset re-reading a file
                    // whose snapshots the tap already delivered):
                    // neither re-broadcasts.
                    if *slot == *s || !snapshot_supersedes(s, slot) {
                        return;
                    }
                    *slot = s.clone();
                }
                None => {
                    fleet.insert(key, s.clone());
                }
            }
        }
        self.hub.publish(s.clone());
    }

    /// The `/v1/fleet` body: `repro watch`'s aggregation over the
    /// latest-per-case snapshots, as JSON.
    pub fn fleet_json(&self) -> Value {
        let fleet = self.fleet.lock().unwrap_or_else(|e| e.into_inner());
        let aggs = live::aggregate(fleet.values());
        let mut v = Value::obj();
        v.set("format", SERVE_FORMAT)
            .set("snapshots_seen", self.hub.cursor_now())
            .set(
                "experiments",
                Value::Arr(aggs.iter().map(|a| a.to_json()).collect()),
            );
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(exp: &str, case: u64, seq: u64, t: f64, done: bool) -> Snapshot {
        Snapshot {
            experiment: exp.to_string(),
            shard: None,
            case_index: case,
            seq,
            t_s: t,
            done,
            cases_done: 0,
            cases_owned: 2,
            cases_total: 2,
            finished: 10 + case,
            stages: 5,
            qps: 1.0,
            ttft_p50_s: 0.1,
            ttft_p99_s: 0.2,
            e2e_p50_s: 0.5,
            e2e_p99_s: 1.0,
            norm_latency_p50_s_per_tok: 0.01,
            power_w: 400.0,
            mfu: 0.4,
            energy_kwh: 0.2,
            gco2_g: 80.0,
        }
    }

    #[test]
    fn sweep_request_validation_rejects_bad_bodies() {
        let parse = |text: &str| {
            SweepRequest::from_json(&crate::util::json::parse(text).unwrap())
        };
        let ok = parse(r#"{"experiment": "exp1", "jobs": 2, "shard": "0/2", "fast": true}"#)
            .unwrap();
        assert_eq!(ok.experiment, "exp1");
        assert_eq!(ok.jobs, 2);
        assert_eq!(ok.shard.as_deref(), Some("0/2"));
        assert!(ok.fast);
        // Defaults: jobs from the process default, no shard, not fast.
        let d = parse(r#"{"experiment": "autoscale"}"#).unwrap();
        assert_eq!(d.jobs, crate::sweep::default_jobs());
        assert_eq!(d.shard, None);
        assert!(!d.fast);
        assert!(parse(r#"{"experiment": "all"}"#).is_ok());
        // Rejections, each naming its problem.
        assert!(parse(r#"{"experiment": "nope"}"#).is_err());
        assert!(parse(r#"{"jobs": 2}"#).is_err());
        assert!(parse(r#"{"experiment": "exp1", "jobs": 0}"#).is_err());
        assert!(parse(r#"{"experiment": "exp1", "jobs": "two"}"#).is_err());
        assert!(parse(r#"{"experiment": "exp1", "shard": "9/2"}"#).is_err());
        assert!(parse(r#"{"experiment": "exp1", "shard": 2}"#).is_err());
        assert!(parse(r#"{"experiment": "exp1", "fast": "yes"}"#).is_err());
    }

    #[test]
    fn ingest_keeps_latest_per_case_and_broadcasts_fresh_only() {
        let st = ServeState::new(PathBuf::from("unused"));
        st.ingest(&snap("expX", 0, 1, 60.0, false));
        st.ingest(&snap("expX", 1, 2, 60.0, false));
        // A stale replay (older by every key) must not rebroadcast.
        st.ingest(&snap("expX", 0, 1, 30.0, false));
        assert_eq!(st.hub.cursor_now(), 2, "stale snapshot rebroadcast");
        // A superseding snapshot updates the slot and broadcasts.
        st.ingest(&snap("expX", 0, 3, 120.0, true));
        assert_eq!(st.hub.cursor_now(), 3);
        // An exact replay (follower re-reading a file the tap already
        // delivered) is dropped too.
        st.ingest(&snap("expX", 0, 3, 120.0, true));
        assert_eq!(st.hub.cursor_now(), 3, "exact replay rebroadcast");
        let v = st.fleet_json();
        assert_eq!(v.req_str("format").unwrap(), SERVE_FORMAT);
        let exps = v.get("experiments").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(exps.len(), 1);
        assert_eq!(exps[0].req_u64("cases_done").unwrap(), 1);
        assert_eq!(exps[0].req_u64("finished").unwrap(), 10 + 11);
    }

    #[test]
    fn registry_runs_jobs_in_submission_order() {
        let reg = Arc::new(SweepRegistry::new(PathBuf::from("serve-out")));
        let ran: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = ran.clone();
        let runner: SweepRunner = Arc::new(move |req: &SweepRequest| {
            sink.lock().unwrap().push(req.experiment.clone());
            if req.experiment == "exp2" {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        let id1 = reg.submit(SweepRequest {
            experiment: "exp1".into(),
            jobs: 1,
            shard: None,
            fast: true,
            out: PathBuf::new(),
        });
        let id2 = reg.submit(SweepRequest {
            experiment: "exp2".into(),
            jobs: 1,
            shard: None,
            fast: true,
            out: PathBuf::new(),
        });
        assert_eq!((id1, id2), (1, 2));
        // Output dirs are assigned per job under the registry root.
        let j1 = reg.job_json(id1).unwrap();
        assert!(j1.req_str("out").unwrap().ends_with("sweep-1"));
        assert_eq!(j1.req_str("status").unwrap(), "queued");

        let shutdown = AtomicBool::new(true); // drain the queue, then stop
        reg.run_worker(runner, &shutdown);
        assert_eq!(*ran.lock().unwrap(), vec!["exp1", "exp2"]);
        assert_eq!(reg.job_json(id1).unwrap().req_str("status").unwrap(), "done");
        let j2 = reg.job_json(id2).unwrap();
        assert_eq!(j2.req_str("status").unwrap(), "failed");
        assert!(j2.req_str("error").unwrap().contains("boom"));
        assert_eq!(reg.job_json(99), None);
        let all = reg.jobs_json();
        assert_eq!(all.get("sweeps").and_then(|s| s.as_arr()).unwrap().len(), 2);
    }

    /// Regression: a panicking runner used to leave its job `running`
    /// forever and wedge the queue — the worker thread died with no
    /// status transition. The unwind must be caught, the job marked
    /// `failed` with the panic payload, and the next queued job run.
    #[test]
    fn panicking_runner_marks_job_failed_and_queue_advances() {
        let reg = Arc::new(SweepRegistry::new(PathBuf::from("serve-out")));
        let ran: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = ran.clone();
        let runner: SweepRunner = Arc::new(move |req: &SweepRequest| {
            sink.lock().unwrap().push(req.experiment.clone());
            if req.experiment == "exp1" {
                panic!("runner exploded mid-sweep");
            }
            Ok(())
        });
        let id1 = reg.submit(SweepRequest {
            experiment: "exp1".into(),
            jobs: 1,
            shard: None,
            fast: true,
            out: PathBuf::new(),
        });
        let id2 = reg.submit(SweepRequest {
            experiment: "exp2".into(),
            jobs: 1,
            shard: None,
            fast: true,
            out: PathBuf::new(),
        });
        // Silence the default panic hook's backtrace spam for the
        // intentional panic, then restore it.
        let prev_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let shutdown = AtomicBool::new(true); // drain the queue, then stop
        reg.run_worker(runner, &shutdown);
        std::panic::set_hook(prev_hook);

        // Both jobs ran: the panic did not wedge the queue.
        assert_eq!(*ran.lock().unwrap(), vec!["exp1", "exp2"]);
        let j1 = reg.job_json(id1).unwrap();
        assert_eq!(j1.req_str("status").unwrap(), "failed");
        assert!(
            j1.req_str("error").unwrap().contains("runner exploded mid-sweep"),
            "panic payload missing from error: {}",
            j1.to_string()
        );
        assert_eq!(reg.job_json(id2).unwrap().req_str("status").unwrap(), "done");
    }
}
