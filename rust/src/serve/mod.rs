//! `repro serve` — a zero-dependency HTTP/SSE surface over the live
//! telemetry plane (DESIGN.md §11).
//!
//! The watch pipeline (DESIGN.md §10) already streams [`Snapshot`]s
//! two ways: in process through [`crate::report::live::LiveView`], and
//! across processes/machines through watch JSONL files. This module
//! puts an HTTP server in front of both so dashboards, `curl`, and
//! fleet tooling can consume them without a shared filesystem:
//!
//! | Endpoint              | Method | Body                                    |
//! |-----------------------|--------|-----------------------------------------|
//! | `/healthz`            | GET    | build identity + liveness               |
//! | `/v1/fleet`           | GET    | `repro watch` aggregation as JSON       |
//! | `/v1/snapshots`       | GET    | SSE stream of snapshots (resumable)     |
//! | `/v1/sweeps`          | POST   | submit a sweep to run in this process   |
//! | `/v1/sweeps`          | GET    | all submitted sweeps                    |
//! | `/v1/sweeps/<id>`     | GET    | one submitted sweep's status            |
//!
//! Implementation choices, deliberately boring: std-only HTTP/1.1
//! (the crate's no-dependency rule is a feature, not a handicap —
//! the protocol slice we need is small, see [`http`]), blocking
//! thread-per-connection I/O (subscriber counts are single-digit
//! operators, not the open internet), and observation-only semantics:
//! serving a sweep changes none of its artifacts — `tests/serve_http.rs`
//! asserts byte-identical outputs with and without the server.

pub mod http;
pub mod sse;
pub mod state;

use crate::report::live::{self, TailState};
use crate::telemetry::window::Snapshot;
use crate::util::version;
use anyhow::{Context, Result};
use http::{parse_head, Head, HttpError, ParseOutcome};
use sse::Next;
use state::{ServeState, SweepRequest, SweepRunner, SERVE_FORMAT};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration (the `repro serve` flags).
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (`:0` picks a free port —
    /// the tests rely on it).
    pub addr: String,
    /// Watch JSONL files or sweep output directories to follow, as
    /// `repro watch` would.
    pub follow: Vec<PathBuf>,
    /// Root directory for hosted sweep outputs (`<out>/sweep-<id>`).
    pub out: PathBuf,
    /// Executes submitted sweeps (tests inject a stub).
    pub runner: SweepRunner,
    /// Poll interval for the file followers.
    pub poll_interval: Duration,
    /// SSE keep-alive comment interval on quiet streams.
    pub keepalive: Duration,
}

impl ServeConfig {
    pub fn new(addr: &str) -> ServeConfig {
        ServeConfig {
            addr: addr.to_string(),
            follow: Vec::new(),
            out: PathBuf::from("serve-results"),
            runner: state::default_runner(),
            poll_interval: Duration::from_millis(250),
            keepalive: Duration::from_secs(15),
        }
    }
}

/// A running server: bound listener plus its accept / follower /
/// sweep-worker threads. Dropping it without [`Server::shutdown`]
/// leaves the threads running (the CLI's foreground mode just parks).
pub struct Server {
    addr: SocketAddr,
    state: Arc<ServeState>,
    shutdown: Arc<AtomicBool>,
    tap_id: u64,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving in background threads. The process-wide
    /// snapshot tap is registered here, so any watched sweep this
    /// process runs — hosted via `POST /v1/sweeps` or started by other
    /// code — is broadcast live.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding serve address {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let state = Arc::new(ServeState::new(cfg.out.clone()));
        let shutdown = Arc::new(AtomicBool::new(false));

        let tap_state = state.clone();
        let tap_id = live::add_snapshot_tap(Arc::new(move |s: &Snapshot| {
            tap_state.ingest(s);
        }));

        let mut threads = Vec::new();

        // Accept loop: nonblocking accept + sleep, one handler thread
        // per connection. Handler threads are detached — they exit on
        // their own when the peer hangs up or the hub closes.
        {
            let (state, shutdown) = (state.clone(), shutdown.clone());
            let keepalive = cfg.keepalive;
            threads.push(std::thread::spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let (state, shutdown) = (state.clone(), shutdown.clone());
                            std::thread::spawn(move || {
                                handle_connection(stream, &state, &shutdown, keepalive);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(100)),
                    }
                }
            }));
        }

        // File followers: one thread polling every followed path with
        // the watch pipeline's incremental tail reader.
        if !cfg.follow.is_empty() {
            let (state, shutdown) = (state.clone(), shutdown.clone());
            let (follow, poll) = (cfg.follow.clone(), cfg.poll_interval);
            threads.push(std::thread::spawn(move || {
                follow_files(&follow, &state, &shutdown, poll);
            }));
        }

        // Sweep worker: drains the submission queue sequentially (the
        // jobs/shard/watch configuration is process-global — see
        // `state::SweepRegistry`).
        {
            let (state, shutdown) = (state.clone(), shutdown.clone());
            let runner = cfg.runner.clone();
            threads.push(std::thread::spawn(move || {
                state.sweeps.run_worker(runner, &shutdown);
            }));
        }

        Ok(Server {
            addr,
            state,
            shutdown,
            tap_id,
            threads,
        })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared state handle (tests inspect the fleet directly).
    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Stop accepting, close every SSE stream, finish queued sweeps,
    /// and join the server threads.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        live::remove_snapshot_tap(self.tap_id);
        self.state.hub.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Foreground mode for the CLI: parks until the process is killed
    /// (the accept thread owns the listener and never exits on its
    /// own).
    pub fn run(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Follow watch files/directories, folding fresh snapshots into the
/// serve state. Tolerant by design, mirroring `repro watch --follow`:
/// paths may not exist yet (a sweep that has not started), files may
/// be truncated and rewritten (fresh runs), a parse error resets that
/// file's state and retries next tick.
fn follow_files(
    follow: &[PathBuf],
    state: &Arc<ServeState>,
    shutdown: &AtomicBool,
    poll: Duration,
) {
    // Per-file tail state plus how many of its snapshots we ingested
    // and the reset generation that count belongs to.
    let mut tails: BTreeMap<PathBuf, (TailState, usize, u64)> = BTreeMap::new();
    while !shutdown.load(Ordering::SeqCst) {
        let existing: Vec<PathBuf> = follow.iter().filter(|p| p.exists()).cloned().collect();
        let files = live::discover_watch_files(&existing).unwrap_or_default();
        for f in files {
            let (tail, ingested, gen) = tails.entry(f.clone()).or_default();
            match live::tail_snapshots(&f, tail) {
                Ok(_) => {
                    if tail.resets != *gen {
                        // The file was truncated or rotated (fresh
                        // run): replay from the start — ingest dedups
                        // exact replays. Keyed on the reset counter,
                        // not a snapshot-count heuristic: a rewrite
                        // that already regrew to as many lines as we
                        // had ingested would pass a length check while
                        // holding different snapshots.
                        *ingested = 0;
                        *gen = tail.resets;
                    }
                    for s in &tail.snapshots[*ingested..] {
                        state.ingest(s);
                    }
                    *ingested = tail.snapshots.len();
                }
                Err(_) => {
                    // tail_snapshots reset its state; re-ingest from 0
                    // next tick once the file parses again.
                    *ingested = 0;
                    *gen = tail.resets;
                }
            }
        }
        std::thread::sleep(poll);
    }
}

/// Per-connection loop: buffered incremental reads, head parsing,
/// routing, pipelining. Every malformed input becomes a well-formed
/// error response — never a panic, never a dead server.
fn handle_connection(
    stream: TcpStream,
    state: &Arc<ServeState>,
    shutdown: &AtomicBool,
    keepalive: Duration,
) {
    let mut stream = stream;
    // Short read timeouts keep the loop responsive to shutdown without
    // busy-waiting on idle keep-alive connections.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 8192];
    loop {
        match parse_head(&buf) {
            Err(e) => {
                let _ = stream.write_all(&http::error_response(&e));
                return; // framing is lost; drop the connection
            }
            Ok(ParseOutcome::Ready { head, consumed }) => {
                buf.drain(..consumed);
                let body = match read_body(&mut stream, &mut buf, &head, shutdown) {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = stream.write_all(&http::error_response(&e));
                        return;
                    }
                };
                if head.method == "GET" && head.path == "/v1/snapshots" {
                    // The SSE stream takes the connection over and
                    // never returns to pipelining.
                    stream_snapshots(&mut stream, &head, state, shutdown, keepalive);
                    return;
                }
                let resp = match route(state, &head, &body) {
                    Ok(bytes) => bytes,
                    Err(e) => http::error_response(&e),
                };
                if stream.write_all(&resp).is_err() {
                    return;
                }
                // Loop on: `buf` may already hold the next pipelined
                // request.
            }
            Ok(ParseOutcome::Incomplete) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match stream.read(&mut chunk) {
                    Ok(0) => return, // peer closed
                    Ok(n) => buf.extend_from_slice(&chunk[..n]),
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        continue;
                    }
                    Err(_) => return,
                }
            }
        }
    }
}

/// Read the declared request body (some of it may already sit in
/// `buf` behind the head).
fn read_body(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    head: &Head,
    shutdown: &AtomicBool,
) -> Result<Vec<u8>, HttpError> {
    let len = head.content_length()?;
    if len > http::MAX_BODY_BYTES {
        return Err(HttpError::new(413, "request body too large"));
    }
    let len = len as usize;
    let mut chunk = [0u8; 8192];
    while buf.len() < len {
        if shutdown.load(Ordering::SeqCst) {
            return Err(HttpError::new(500, "server shutting down"));
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Err(HttpError::new(400, "connection closed mid-body")),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(HttpError::new(400, format!("read error: {e}"))),
        }
    }
    Ok(buf.drain(..len).collect())
}

/// Route one parsed request to its JSON response.
fn route(state: &ServeState, head: &Head, body: &[u8]) -> Result<Vec<u8>, HttpError> {
    let json = |v: crate::util::json::Value, status: u16| {
        http::response(status, "application/json", v.to_string().as_bytes(), &[])
    };
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/") | ("GET", "/index.json") => {
            let mut v = crate::util::json::Value::obj();
            v.set("format", SERVE_FORMAT).set(
                "endpoints",
                crate::util::json::Value::Arr(
                    [
                        "GET /healthz",
                        "GET /v1/fleet",
                        "GET /v1/snapshots (SSE)",
                        "GET /v1/sweeps",
                        "GET /v1/sweeps/<id>",
                        "POST /v1/sweeps",
                    ]
                    .iter()
                    .map(|s| crate::util::json::Value::Str((*s).to_string()))
                    .collect(),
                ),
            );
            Ok(json(v, 200))
        }
        ("GET", "/healthz") => {
            let mut v = crate::util::json::Value::obj();
            v.set("format", SERVE_FORMAT)
                .set("status", "ok")
                .set("version", version::CRATE_VERSION)
                .set(
                    "git",
                    match version::git_describe() {
                        Some(g) => crate::util::json::Value::Str(g.to_string()),
                        None => crate::util::json::Value::Null,
                    },
                )
                .set("version_string", version::version_string());
            Ok(json(v, 200))
        }
        ("GET", "/v1/fleet") => Ok(json(state.fleet_json(), 200)),
        ("GET", "/v1/sweeps") => Ok(json(state.sweeps.jobs_json(), 200)),
        ("GET", p) if p.starts_with("/v1/sweeps/") => {
            let id = p["/v1/sweeps/".len()..]
                .parse::<u64>()
                .map_err(|_| HttpError::new(400, format!("bad sweep id in '{p}'")))?;
            match state.sweeps.job_json(id) {
                Some(v) => Ok(json(v, 200)),
                None => Err(HttpError::new(404, format!("no sweep with id {id}"))),
            }
        }
        ("POST", "/v1/sweeps") => {
            let text = std::str::from_utf8(body)
                .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))?;
            let parsed = crate::util::json::parse(text)
                .map_err(|e| HttpError::new(400, format!("bad json body: {e}")))?;
            let req = SweepRequest::from_json(&parsed)
                .map_err(|e| HttpError::new(400, format!("{e:#}")))?;
            let id = state.sweeps.submit(req);
            let v = state
                .sweeps
                .job_json(id)
                .expect("job visible immediately after submit");
            Ok(json(v, 202))
        }
        // Known paths with the wrong method answer 405 + Allow, per
        // the RFC, so clients learn the contract instead of guessing.
        (_, "/v1/sweeps") => Ok(method_not_allowed("GET, POST")),
        (_, "/" | "/index.json" | "/healthz" | "/v1/fleet" | "/v1/snapshots") => {
            Ok(method_not_allowed("GET"))
        }
        (_, p) if p.starts_with("/v1/sweeps/") => Ok(method_not_allowed("GET")),
        (_, p) => Err(HttpError::new(404, format!("no such endpoint '{p}'"))),
    }
}

/// A 405 with the `Allow` header naming the methods the path accepts.
fn method_not_allowed(allow: &str) -> Vec<u8> {
    let mut v = crate::util::json::Value::obj();
    v.set("error", format!("method not allowed (allow: {allow})"));
    let allow_header = format!("Allow: {allow}");
    http::response(
        405,
        "application/json",
        v.to_string().as_bytes(),
        &[allow_header.as_str()],
    )
}

/// The `/v1/snapshots` SSE stream. Resume: `Last-Event-ID` (header or
/// `last_event_id` query parameter) carries the last snapshot `seq`
/// the client saw; delivery restarts just after it. Without one, the
/// retained history replays from the oldest so a fresh dashboard
/// catches up to the fleet's current state.
fn stream_snapshots(
    stream: &mut TcpStream,
    head: &Head,
    state: &ServeState,
    shutdown: &AtomicBool,
    keepalive: Duration,
) {
    let resume = head
        .header("last-event-id")
        .or_else(|| head.query_param("last_event_id"))
        .and_then(|v| v.trim().parse::<u64>().ok());
    let mut cursor = match resume {
        Some(seq) => state.hub.cursor_after_seq(seq),
        None => state.hub.cursor_oldest(),
    };
    let header = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                  Cache-Control: no-cache\r\nConnection: close\r\n\r\n";
    if stream.write_all(header.as_bytes()).is_err() {
        return;
    }
    loop {
        if shutdown.load(Ordering::SeqCst) {
            let _ = stream.write_all(sse::sse_comment("server shutting down").as_bytes());
            return;
        }
        let frame = match state.hub.next(cursor, keepalive) {
            Next::Event(n, s) => {
                cursor = n + 1;
                sse::sse_frame(Some("snapshot"), Some(s.seq), &s.to_json().to_string())
            }
            Next::Lagged(resume_at) => {
                let skipped = resume_at.saturating_sub(cursor);
                cursor = resume_at;
                sse::sse_comment(&format!("lagged: {skipped} snapshot(s) skipped"))
            }
            Next::Timeout => sse::sse_comment("keep-alive"),
            Next::Closed => {
                let _ = stream.write_all(sse::sse_comment("stream closed").as_bytes());
                return;
            }
        };
        if stream.write_all(frame.as_bytes()).is_err() {
            return; // subscriber went away
        }
    }
}
