//! Simulation summary metrics: latency distributions, throughput,
//! batching behaviour, MFU — the quantities the paper's figures are
//! built from.

use crate::config::simconfig::SimConfig;
use crate::telemetry::StageStats;
use crate::util::json::Value;
use crate::util::stats::percentile;
use crate::workload::Request;

#[derive(Debug, Clone)]
pub struct SimMetrics {
    /// Wall-clock from t=0 to the last event.
    pub makespan_s: f64,
    /// Achieved request throughput over the makespan.
    pub achieved_qps: f64,
    /// Total tokens processed (prefill + decode) per second.
    pub token_throughput: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    /// Mean normalized latency (s per output token) — vLLM's metric.
    pub norm_latency_s_per_tok: f64,
    /// Duration-weighted mean MFU (Fig. 1's y-axis).
    pub weighted_mfu: f64,
    /// Mean actual batch size across stages (Fig. 4 panel A).
    pub mean_batch_size: f64,
    pub stage_count: u64,
    pub preemptions: u64,
    /// Mean queueing delay (arrival -> first scheduled).
    pub queue_delay_p50_s: f64,
    /// Fraction of requests whose TTFT met `cfg.slo_ttft_s`
    /// (unfinished requests count as misses).
    pub slo_ttft_attained: f64,
    /// Fraction of requests whose e2e latency met `cfg.slo_e2e_s`.
    pub slo_e2e_attained: f64,
    /// Fraction meeting both SLOs — the autoscaler's guard metric and
    /// the sweep's service-quality axis.
    pub slo_attained: f64,
}

impl SimMetrics {
    pub fn compute(
        cfg: &SimConfig,
        requests: &[Request],
        stages: &StageStats,
        makespan_s: f64,
        preemptions: u64,
    ) -> SimMetrics {
        let ttft: Vec<f64> = requests.iter().filter_map(|r| r.ttft()).collect();
        let e2e: Vec<f64> = requests.iter().filter_map(|r| r.e2e_latency()).collect();
        let qdel: Vec<f64> = requests
            .iter()
            .filter_map(|r| r.scheduled_s.map(|s| s - r.arrival_s))
            .collect();
        let norm: Vec<f64> = requests
            .iter()
            .filter_map(|r| {
                r.e2e_latency().map(|l| l / r.decode_tokens.max(1) as f64)
            })
            .collect();
        let total_tokens: u64 = requests.iter().map(|r| r.total_tokens()).sum();
        let n_req = requests.len().max(1) as f64;
        let ttft_ok = requests
            .iter()
            .filter(|r| r.ttft().map(|t| t <= cfg.slo_ttft_s).unwrap_or(false))
            .count() as f64;
        let e2e_ok = requests
            .iter()
            .filter(|r| {
                r.e2e_latency().map(|t| t <= cfg.slo_e2e_s).unwrap_or(false)
            })
            .count() as f64;
        let both_ok = requests
            .iter()
            .filter(|r| {
                r.ttft().map(|t| t <= cfg.slo_ttft_s).unwrap_or(false)
                    && r.e2e_latency().map(|t| t <= cfg.slo_e2e_s).unwrap_or(false)
            })
            .count() as f64;
        let pc = |v: &[f64], p: f64| if v.is_empty() { 0.0 } else { percentile(v, p) };
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        SimMetrics {
            makespan_s,
            achieved_qps: requests.len() as f64 / makespan_s.max(1e-9),
            token_throughput: total_tokens as f64 / makespan_s.max(1e-9),
            ttft_p50_s: pc(&ttft, 50.0),
            ttft_p99_s: pc(&ttft, 99.0),
            e2e_p50_s: pc(&e2e, 50.0),
            e2e_p99_s: pc(&e2e, 99.0),
            norm_latency_s_per_tok: mean(&norm),
            weighted_mfu: stages.weighted_mfu,
            mean_batch_size: stages.mean_batch,
            stage_count: stages.stages,
            preemptions,
            queue_delay_p50_s: pc(&qdel, 50.0),
            slo_ttft_attained: ttft_ok / n_req,
            slo_e2e_attained: e2e_ok / n_req,
            slo_attained: both_ok / n_req,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("makespan_s", self.makespan_s)
            .set("achieved_qps", self.achieved_qps)
            .set("token_throughput", self.token_throughput)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("e2e_p50_s", self.e2e_p50_s)
            .set("e2e_p99_s", self.e2e_p99_s)
            .set("norm_latency_s_per_tok", self.norm_latency_s_per_tok)
            .set("weighted_mfu", self.weighted_mfu)
            .set("mean_batch_size", self.mean_batch_size)
            .set("stage_count", self.stage_count)
            .set("preemptions", self.preemptions)
            .set("queue_delay_p50_s", self.queue_delay_p50_s)
            .set("slo_ttft_attained", self.slo_ttft_attained)
            .set("slo_e2e_attained", self.slo_e2e_attained)
            .set("slo_attained", self.slo_attained);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::SimConfig;

    #[test]
    fn metrics_from_synthetic_requests() {
        let mut reqs = vec![
            Request::new(0, 0.0, 10, 5),
            Request::new(1, 1.0, 10, 5),
        ];
        reqs[0].scheduled_s = Some(0.0);
        reqs[0].first_token_s = Some(0.5);
        reqs[0].finished_s = Some(1.0);
        reqs[1].scheduled_s = Some(1.2);
        reqs[1].first_token_s = Some(2.0);
        reqs[1].finished_s = Some(3.0);
        let m =
            SimMetrics::compute(&SimConfig::default(), &reqs, &StageStats::default(), 3.0, 0);
        assert!((m.achieved_qps - 2.0 / 3.0).abs() < 1e-9);
        assert!((m.ttft_p50_s - 0.75).abs() < 1e-9); // median of 0.5 and 1.0
        assert!((m.e2e_p50_s - 1.5).abs() < 1e-9); // median of 1.0 and 2.0
        assert_eq!(m.token_throughput, 30.0 / 3.0);
        let j = m.to_json();
        assert!(j.get("makespan_s").is_some());
        assert!(j.get("slo_attained").is_some());
    }

    #[test]
    fn slo_attainment_fractions() {
        let mut cfg = SimConfig::default();
        cfg.slo_ttft_s = 0.8;
        cfg.slo_e2e_s = 2.0;
        let mut reqs = vec![
            Request::new(0, 0.0, 10, 5), // ttft 0.5 ok, e2e 1.0 ok
            Request::new(1, 1.0, 10, 5), // ttft 1.0 miss, e2e 2.0 ok
            Request::new(2, 2.0, 10, 5), // unfinished: misses both
        ];
        reqs[0].first_token_s = Some(0.5);
        reqs[0].finished_s = Some(1.0);
        reqs[1].first_token_s = Some(2.0);
        reqs[1].finished_s = Some(3.0);
        let m = SimMetrics::compute(&cfg, &reqs, &StageStats::default(), 3.0, 0);
        assert!((m.slo_ttft_attained - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.slo_e2e_attained - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.slo_attained - 1.0 / 3.0).abs() < 1e-12);
    }
}
