//! Simulation summary metrics: latency distributions, throughput,
//! batching behaviour, MFU — the quantities the paper's figures are
//! built from.
//!
//! Computed from the telemetry accumulators ([`RequestStats`] +
//! [`StageStats`]) rather than request/stage vectors, so the same code
//! serves the materialized and the streaming (O(outstanding + bins))
//! paths — see DESIGN.md §8.

use crate::telemetry::{RequestStats, StageStats};
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct SimMetrics {
    /// Wall-clock from t=0 to the last event.
    pub makespan_s: f64,
    /// Achieved request throughput over the makespan — *completed*
    /// requests only (in-flight work is not throughput).
    pub achieved_qps: f64,
    /// Tokens actually processed (prefill + decode progress of
    /// completed requests) per second — not the offered token budget.
    pub token_throughput: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    /// Mean normalized latency (s per output token) — vLLM's metric.
    pub norm_latency_s_per_tok: f64,
    /// Duration-weighted mean MFU (Fig. 1's y-axis).
    pub weighted_mfu: f64,
    /// Mean actual batch size across stages (Fig. 4 panel A).
    pub mean_batch_size: f64,
    pub stage_count: u64,
    pub preemptions: u64,
    /// Median queueing delay (arrival -> first scheduled).
    pub queue_delay_p50_s: f64,
    /// Fraction of requests whose TTFT met `cfg.slo_ttft_s`
    /// (unfinished requests count as misses).
    pub slo_ttft_attained: f64,
    /// Fraction of requests whose e2e latency met `cfg.slo_e2e_s`.
    pub slo_e2e_attained: f64,
    /// Fraction meeting both SLOs — the autoscaler's guard metric and
    /// the sweep's service-quality axis.
    pub slo_attained: f64,
}

impl SimMetrics {
    /// Fold the two telemetry accumulators into the headline metrics.
    /// `requests.submitted` must already be stamped by the engine (the
    /// SLO denominators count offered requests, so anything still in
    /// flight is a miss).
    pub fn compute(
        requests: &RequestStats,
        stages: &StageStats,
        makespan_s: f64,
        preemptions: u64,
    ) -> SimMetrics {
        let n_req = requests.submitted.max(1) as f64;
        SimMetrics {
            makespan_s,
            achieved_qps: requests.finished as f64 / makespan_s.max(1e-9),
            token_throughput: requests.tokens_done() as f64 / makespan_s.max(1e-9),
            ttft_p50_s: requests.ttft_p50_s,
            ttft_p99_s: requests.ttft_p99_s,
            e2e_p50_s: requests.e2e_p50_s,
            e2e_p99_s: requests.e2e_p99_s,
            norm_latency_s_per_tok: requests.norm_latency_mean_s_per_tok,
            weighted_mfu: stages.weighted_mfu,
            mean_batch_size: stages.mean_batch,
            stage_count: stages.stages,
            preemptions,
            queue_delay_p50_s: requests.queue_delay_p50_s,
            slo_ttft_attained: requests.slo_ttft_ok as f64 / n_req,
            slo_e2e_attained: requests.slo_e2e_ok as f64 / n_req,
            slo_attained: requests.slo_both_ok as f64 / n_req,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("makespan_s", self.makespan_s)
            .set("achieved_qps", self.achieved_qps)
            .set("token_throughput", self.token_throughput)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("e2e_p50_s", self.e2e_p50_s)
            .set("e2e_p99_s", self.e2e_p99_s)
            .set("norm_latency_s_per_tok", self.norm_latency_s_per_tok)
            .set("weighted_mfu", self.weighted_mfu)
            .set("mean_batch_size", self.mean_batch_size)
            .set("stage_count", self.stage_count)
            .set("preemptions", self.preemptions)
            .set("queue_delay_p50_s", self.queue_delay_p50_s)
            .set("slo_ttft_attained", self.slo_ttft_attained)
            .set("slo_e2e_attained", self.slo_e2e_attained)
            .set("slo_attained", self.slo_attained);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::SimConfig;
    use crate::telemetry::{RequestLog, RequestSink};
    use crate::workload::Request;

    fn finished(id: u64, arrival: f64, sched: f64, first: f64, fin: f64) -> Request {
        let mut r = Request::new(id, arrival, 10, 5);
        r.prefill_done = 10;
        r.decode_done = 5;
        r.scheduled_s = Some(sched);
        r.first_token_s = Some(first);
        r.finished_s = Some(fin);
        r
    }

    #[test]
    fn metrics_from_synthetic_requests() {
        let mut log = RequestLog::new(&SimConfig::default());
        log.record(&finished(0, 0.0, 0.0, 0.5, 1.0));
        log.record(&finished(1, 1.0, 1.2, 2.0, 3.0));
        let stats = log.stats(); // both finished: submitted == finished
        let m = SimMetrics::compute(&stats, &StageStats::default(), 3.0, 0);
        assert!((m.achieved_qps - 2.0 / 3.0).abs() < 1e-9);
        assert!((m.ttft_p50_s - 0.75).abs() < 1e-9); // median of 0.5 and 1.0
        assert!((m.e2e_p50_s - 1.5).abs() < 1e-9); // median of 1.0 and 2.0
        assert_eq!(m.token_throughput, 30.0 / 3.0);
        let j = m.to_json();
        assert!(j.get("makespan_s").is_some());
        assert!(j.get("slo_attained").is_some());
    }

    #[test]
    fn slo_attainment_counts_unfinished_as_misses() {
        let mut cfg = SimConfig::default();
        cfg.slo_ttft_s = 0.8;
        cfg.slo_e2e_s = 2.0;
        let mut log = RequestLog::new(&cfg);
        // ttft 0.5 ok, e2e 1.0 ok.
        log.record(&finished(0, 0.0, 0.0, 0.5, 1.0));
        // ttft 1.0 miss, e2e 2.0 ok.
        log.record(&finished(1, 1.0, 1.2, 2.0, 3.0));
        // A third request never finished: the engine stamps it into
        // the denominator without recording it.
        let mut stats = log.stats();
        stats.submitted = 3;
        let m = SimMetrics::compute(&stats, &StageStats::default(), 3.0, 0);
        assert!((m.slo_ttft_attained - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.slo_e2e_attained - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.slo_attained - 1.0 / 3.0).abs() < 1e-12);
    }

    /// Satellite fixes: unfinished requests are not throughput, and
    /// tokens are charged by progress, not budget.
    #[test]
    fn throughput_counts_finished_work_only() {
        let mut log = RequestLog::new(&SimConfig::default());
        log.record(&finished(0, 0.0, 0.0, 0.5, 1.0)); // 15 tokens done
        let mut stats = log.stats();
        stats.submitted = 4; // three more still in flight
        let m = SimMetrics::compute(&stats, &StageStats::default(), 10.0, 0);
        assert!((m.achieved_qps - 0.1).abs() < 1e-12, "qps {}", m.achieved_qps);
        assert!((m.token_throughput - 1.5).abs() < 1e-12);
    }
}
