//! Event queues for the discrete-event cores: the classic binary heap
//! and a calendar queue (Brown 1988), behind one [`EventQueue`] trait
//! so the engines are generic over the scheduler and the heap stays
//! available for differential testing.
//!
//! The calendar queue buckets events by time slot (`slot = ⌊at/width⌋`,
//! bucket = `slot mod nbuckets`): push appends to a bucket, pop scans
//! forward from the current slot — O(1) amortized for the
//! near-uniform event streams a simulation produces, vs the heap's
//! O(log n). Events landing a full calendar lap or more ahead of the
//! current slot (autoscale ticks, cold-start completions) go to a
//! sorted *overflow* list and migrate into buckets as the clock
//! reaches them; when the bucket population outgrows the calendar it
//! rebuilds with twice the buckets and a width re-estimated from the
//! populated span (≈3 slots per resident event).
//!
//! Ordering contract (pinned by the in-module differential tests and
//! `rust/tests/calq_parity.rs`): both implementations pop in exactly
//! the order the engine's original `BinaryHeap<Event>` did — ascending
//! event time, ties broken by push order via an internal sequence
//! counter that increments on every push. Equal times always share a
//! slot, hence a bucket, so the tie-break never crosses structures.
//!
//! Discipline: like any discrete-event schedule, events must not be
//! pushed *before* the most recently popped event time (the engine
//! only schedules at `now` or later). The calendar relies on this to
//! advance its clock monotonically and debug-asserts it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event: firing time, push-order sequence, payload.
struct Event<K> {
    at: f64,
    seq: u64,
    kind: K,
}

impl<K> PartialEq for Event<K> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<K> Eq for Event<K> {}
impl<K> PartialOrd for Event<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<K> Ord for Event<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed comparison; ties broken by insertion order.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// `a` pops strictly before `b`.
#[inline]
fn earlier<K>(a: &Event<K>, b: &Event<K>) -> bool {
    match a.at.partial_cmp(&b.at) {
        Some(Ordering::Less) => true,
        Some(Ordering::Greater) => false,
        _ => a.seq < b.seq,
    }
}

/// The event-scheduler interface of the simulation cores. Pops return
/// `(time, payload)` in ascending time order with push-order
/// tie-breaking; the sequence counter lives inside the queue.
pub trait EventQueue<K> {
    fn push(&mut self, at: f64, kind: K);
    fn pop(&mut self) -> Option<(f64, K)>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The original `BinaryHeap` scheduler — O(log n), kept as the
/// differential-testing reference ([`crate::sim::run_with_sinks_heap`]).
pub struct HeapQueue<K> {
    heap: BinaryHeap<Event<K>>,
    seq: u64,
}

impl<K> HeapQueue<K> {
    pub fn new() -> Self {
        Self::with_capacity(0)
    }
    pub fn with_capacity(n: usize) -> Self {
        HeapQueue {
            heap: BinaryHeap::with_capacity(n),
            seq: 0,
        }
    }
}

impl<K> Default for HeapQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K> EventQueue<K> for HeapQueue<K> {
    fn push(&mut self, at: f64, kind: K) {
        self.seq += 1;
        self.heap.push(Event {
            at,
            seq: self.seq,
            kind,
        });
    }
    fn pop(&mut self) -> Option<(f64, K)> {
        self.heap.pop().map(|e| (e.at, e.kind))
    }
    fn len(&self) -> usize {
        self.heap.len()
    }
}

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 16;
const MIN_WIDTH: f64 = 1e-9;

/// Calendar-queue scheduler — O(1) amortized push/pop.
pub struct CalendarQueue<K> {
    /// `nbuckets` (a power of two) vectors; every resident event's
    /// slot lies in `[cur_slot, cur_slot + nbuckets)`, so each bucket
    /// holds events of exactly one slot value.
    buckets: Vec<Vec<Event<K>>>,
    /// Seconds per slot.
    width: f64,
    /// Slot of the most recently popped event (the scan start).
    cur_slot: u64,
    /// Events resident in `buckets` (excludes `overflow`).
    in_buckets: usize,
    /// Far-future events, sorted descending by (at, seq): the back is
    /// the earliest and migrates into buckets as the clock advances.
    overflow: Vec<Event<K>>,
    seq: u64,
}

impl<K> CalendarQueue<K> {
    /// Default geometry: 64 buckets of 50 ms — tuned to the engine's
    /// stage times; the adaptive rebuild corrects any mismatch.
    pub fn new() -> Self {
        Self::with_params(64, 0.05)
    }

    /// Explicit geometry (tests). `nbuckets` is rounded up to a power
    /// of two and clamped to `[16, 65536]`.
    pub fn with_params(nbuckets: usize, width: f64) -> Self {
        let nb = nbuckets.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        CalendarQueue {
            buckets: (0..nb).map(|_| Vec::new()).collect(),
            width: width.max(MIN_WIDTH),
            cur_slot: 0,
            in_buckets: 0,
            overflow: Vec::new(),
            seq: 0,
        }
    }

    #[inline]
    fn slot(&self, at: f64) -> u64 {
        (at / self.width) as u64
    }

    /// First slot beyond the calendar's reach from `cur_slot`.
    #[inline]
    fn horizon(&self) -> u64 {
        self.cur_slot.saturating_add(self.buckets.len() as u64)
    }

    #[inline]
    fn bucket_of(&self, slot: u64) -> usize {
        (slot & (self.buckets.len() as u64 - 1)) as usize
    }

    fn insert(&mut self, e: Event<K>) {
        let s = self.slot(e.at);
        debug_assert!(
            s >= self.cur_slot,
            "event at {} pushed before the queue's current slot",
            e.at
        );
        if s < self.horizon() {
            let b = self.bucket_of(s);
            self.buckets[b].push(e);
            self.in_buckets += 1;
        } else {
            let pos = self.overflow.partition_point(|o| earlier(&e, o));
            self.overflow.insert(pos, e);
        }
    }

    /// Pull every overflow event now within the calendar horizon into
    /// its bucket (called after `cur_slot` advances via an overflow pop).
    fn migrate(&mut self) {
        let h = self.horizon();
        while let Some(o) = self.overflow.last() {
            if self.slot(o.at) >= h {
                break;
            }
            let e = self.overflow.pop().expect("checked non-empty");
            let b = self.bucket_of(self.slot(e.at));
            self.buckets[b].push(e);
            self.in_buckets += 1;
        }
    }

    /// Re-bucket everything into `nb` buckets with a width re-estimated
    /// from the populated span (targets ≈3 slots per event, keeping
    /// buckets short and scans shorter).
    fn rebuild(&mut self, nb: usize) {
        let nb = nb.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        let mut all: Vec<Event<K>> = Vec::with_capacity(self.len());
        for b in &mut self.buckets {
            all.append(b);
        }
        all.append(&mut self.overflow);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for e in &all {
            lo = lo.min(e.at);
            hi = hi.max(e.at);
        }
        if all.len() > 1 && hi > lo {
            self.width = ((hi - lo) * 3.0 / all.len() as f64).max(MIN_WIDTH);
        }
        if self.buckets.len() != nb {
            self.buckets.resize_with(nb, Vec::new);
        }
        self.in_buckets = 0;
        self.cur_slot = if lo.is_finite() { self.slot(lo) } else { 0 };
        for e in all {
            self.insert(e);
        }
    }
}

impl<K> Default for CalendarQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K> EventQueue<K> for CalendarQueue<K> {
    fn push(&mut self, at: f64, kind: K) {
        self.seq += 1;
        self.insert(Event {
            at,
            seq: self.seq,
            kind,
        });
        if self.in_buckets > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            let nb = self.buckets.len() * 2;
            self.rebuild(nb);
        }
    }

    fn pop(&mut self) -> Option<(f64, K)> {
        if self.in_buckets == 0 {
            // Everything (if anything) is in overflow: jump the clock.
            let e = self.overflow.pop()?;
            self.cur_slot = self.slot(e.at);
            self.migrate();
            return Some((e.at, e.kind));
        }
        let mut s = self.cur_slot;
        loop {
            // An overflow event at an already-passed (empty) slot is
            // the minimum: no bucket event can precede it.
            if let Some(o) = self.overflow.last() {
                if self.slot(o.at) < s {
                    let e = self.overflow.pop().expect("checked non-empty");
                    self.cur_slot = self.slot(e.at);
                    self.migrate();
                    return Some((e.at, e.kind));
                }
            }
            let b = self.bucket_of(s);
            if !self.buckets[b].is_empty() {
                // Every event in this bucket shares slot `s`.
                let mut mi = 0;
                for i in 1..self.buckets[b].len() {
                    if earlier(&self.buckets[b][i], &self.buckets[b][mi]) {
                        mi = i;
                    }
                }
                if let Some(o) = self.overflow.last() {
                    if self.slot(o.at) == s && earlier(o, &self.buckets[b][mi]) {
                        let e = self.overflow.pop().expect("checked non-empty");
                        self.cur_slot = s;
                        self.migrate();
                        return Some((e.at, e.kind));
                    }
                }
                let e = self.buckets[b].swap_remove(mi);
                self.in_buckets -= 1;
                self.cur_slot = s;
                return Some((e.at, e.kind));
            }
            // in_buckets > 0 bounds this scan: some bucket within
            // [cur_slot, horizon) is non-empty.
            s += 1;
        }
    }

    fn len(&self) -> usize {
        self.in_buckets + self.overflow.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gens};
    use crate::util::rng::Rng;

    #[test]
    fn empty_pops_none() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mks: [fn() -> Box<dyn EventQueue<u64>>; 2] = [
            || Box::new(CalendarQueue::<u64>::new()),
            || Box::new(HeapQueue::<u64>::new()),
        ];
        for mk in mks {
            let mut q = mk();
            for k in 0..20u64 {
                q.push(1.25, k);
            }
            q.push(0.5, 100);
            for want in std::iter::once(100).chain(0..20u64) {
                assert_eq!(q.pop().map(|(_, k)| k), Some(want));
            }
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn resize_and_overflow_drain_sorted() {
        // Degenerate geometry forces both the overflow path (huge
        // times vs tiny width) and several rebuilds (500 events into
        // 16 buckets).
        let mut q: CalendarQueue<u64> = CalendarQueue::with_params(16, 1e-3);
        let mut rng = Rng::new(7);
        for k in 0..500u64 {
            let at = if k % 7 == 0 {
                1e6 + rng.f64() * 1e3
            } else {
                rng.f64() * 50.0
            };
            q.push(at, k);
        }
        assert_eq!(q.len(), 500);
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while let Some((at, _)) = q.pop() {
            assert!(at >= last, "out of order: {at} after {last}");
            last = at;
            n += 1;
        }
        assert_eq!(n, 500);
    }

    /// The satellite differential test: random event streams obeying
    /// the DES discipline (pushes never precede the last pop) drive
    /// the calendar and the heap through identical (time, payload)
    /// pop sequences — including exact ties and far-future events.
    #[test]
    fn random_streams_match_heap() {
        check(60, gens::u64_in(0, u64::MAX / 2), |&seed| {
            let mut rng = Rng::new(seed);
            let nb = *rng.choose(&[16usize, 32, 64]);
            let width = *rng.choose(&[1e-3, 0.05, 1.0, 60.0]);
            let mut cal: CalendarQueue<u64> = CalendarQueue::with_params(nb, width);
            let mut heap: HeapQueue<u64> = HeapQueue::new();
            let mut now = 0.0f64;
            let mut key = 0u64;
            for _ in 0..400 {
                if rng.f64() < 0.6 || (cal.is_empty() && heap.is_empty()) {
                    // Push 1–4 events at/after `now`; offsets mix
                    // exact ties, bucket-local, lap-distant, and
                    // overflow-distant times.
                    for _ in 0..rng.int_range(1, 4) {
                        let off = match rng.int_range(0, 5) {
                            0 => 0.0,
                            1 => rng.f64() * 0.01,
                            2 => rng.f64() * 1.0,
                            3 => rng.f64() * 1e3,
                            _ => 1e5 + rng.f64() * 1e5,
                        };
                        cal.push(now + off, key);
                        heap.push(now + off, key);
                        key += 1;
                    }
                } else {
                    let a = cal.pop();
                    let b = heap.pop();
                    if a != b {
                        return Err(format!("divergence: cal {a:?} vs heap {b:?}"));
                    }
                    if let Some((at, _)) = a {
                        now = at;
                    }
                }
                if cal.len() != heap.len() {
                    return Err(format!("len drift: {} vs {}", cal.len(), heap.len()));
                }
            }
            // Drain to the end: full order parity.
            loop {
                let a = cal.pop();
                let b = heap.pop();
                if a != b {
                    return Err(format!("drain divergence: cal {a:?} vs heap {b:?}"));
                }
                if a.is_none() {
                    break;
                }
            }
            Ok(())
        });
    }
}
