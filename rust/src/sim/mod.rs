//! The Vidur-like discrete-event inference simulator: event queue,
//! replica iteration loop, and summary metrics.

pub mod engine;
pub mod metrics;

pub use engine::{run, run_with_trace, SimOutput};
pub use metrics::SimMetrics;
