//! The Vidur-like discrete-event inference simulator: event
//! schedulers (calendar queue + reference heap, [`calq`]), reusable
//! hot-path scratch ([`arena`]), the replica iteration loop, and
//! summary metrics.

pub mod arena;
pub mod calq;
pub mod engine;
pub mod metrics;

pub use engine::{
    run, run_autoscaled, run_autoscaled_streaming, run_autoscaled_streaming_with,
    run_autoscaled_with_model, run_autoscaled_with_sink, run_autoscaled_with_sinks,
    run_autoscaled_with_sinks_heap, run_multifleet, run_streaming, run_streaming_with,
    run_with_model, run_with_sink, run_with_sinks, run_with_sinks_heap, run_with_trace,
    AutoscaleOutput, AutoscaleRun, MultiFleetRun, RegionRun, RegionSim, SimOutput, SimRun,
};
pub use metrics::SimMetrics;
