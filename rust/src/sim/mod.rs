//! The Vidur-like discrete-event inference simulator: event queue,
//! replica iteration loop, and summary metrics.

pub mod engine;
pub mod metrics;

pub use engine::{
    run, run_autoscaled, run_autoscaled_with_model, run_with_trace, AutoscaleOutput,
    SimOutput,
};
pub use metrics::SimMetrics;
