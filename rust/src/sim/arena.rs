//! Reusable scratch buffers for the event-loop hot path.
//!
//! Steady-state simulation used to allocate on every event: a fresh
//! `entries` vector per planned stage (inside
//! `ReplicaScheduler::next_stage`), a `finished` vector per completed
//! stage, and per-arrival `outstanding`/`eligible` snapshots for the
//! router. [`StageScratch`] pools all of them: stage-entry vectors
//! cycle through [`StageScratch::take_entries`] /
//! [`StageScratch::recycle_entries`] (a plan's vector is reclaimed
//! when its completion event fires), and the flat buffers are cleared
//! and refilled in place. After warm-up the per-event allocation
//! count drops to zero; capacity only grows when a new high-water
//! mark is hit.
//!
//! Rare control-plane paths (autoscale rebalancing, drain rerouting,
//! scale ticks) still allocate — they fire per decision interval, not
//! per stage, and keeping them allocation-free would complicate
//! borrow lifetimes for no measurable gain.

/// Per-engine-run scratch space. Create one per simulation run; the
/// engine threads it through planning and completion.
#[derive(Default)]
pub struct StageScratch {
    /// Recycled stage-entry vectors (each cleared before pooling).
    entry_pool: Vec<Vec<(u64, u32)>>,
    /// Finished-request ids of the stage being completed.
    pub finished: Vec<u64>,
    /// Per-replica outstanding counts snapshot for the router.
    pub outstanding: Vec<u64>,
    /// Routing-eligible replica indices (autoscaled engine).
    pub eligible: Vec<usize>,
}

impl StageScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty entries vector, reusing pooled capacity when available.
    #[inline]
    pub fn take_entries(&mut self) -> Vec<(u64, u32)> {
        self.entry_pool.pop().unwrap_or_default()
    }

    /// Return a stage's entries vector to the pool once its completion
    /// has been applied.
    #[inline]
    pub fn recycle_entries(&mut self, mut v: Vec<(u64, u32)>) {
        v.clear();
        self.entry_pool.push(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entries_recycle_preserves_capacity() {
        let mut s = StageScratch::new();
        let mut v = s.take_entries();
        assert!(v.is_empty());
        for i in 0..100 {
            v.push((i, 1));
        }
        let cap = v.capacity();
        s.recycle_entries(v);
        let v2 = s.take_entries();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap, "pooled capacity lost");
    }

    #[test]
    fn pool_grows_only_to_high_water_mark() {
        let mut s = StageScratch::new();
        let a = s.take_entries();
        let b = s.take_entries();
        s.recycle_entries(a);
        s.recycle_entries(b);
        assert_eq!(s.entry_pool.len(), 2);
        let _ = s.take_entries();
        assert_eq!(s.entry_pool.len(), 1);
    }
}
