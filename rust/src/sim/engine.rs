//! Discrete-event simulation engine.
//!
//! Events: request arrivals and replica iteration completions, ordered
//! by simulation time in a binary heap. Each replica executes one
//! iteration (= `pp` sequential pipeline stages of one batch) at a
//! time; the cost of a stage comes from the configured oracle (AOT
//! HLO by default, native roofline otherwise), and every pipeline
//! stage is logged as a [`StageRecord`] — the paper's granularity.
//!
//! Pipeline-parallel note: stages of one iteration run back-to-back
//! (no cross-iteration microbatch overlap), matching the conservative
//! reading of Vidur's replica-stage traces; while one PP stage
//! computes, the other (pp-1)·tp GPUs of the replica idle at
//! `p_idle` and are charged as such by the energy accounting.

use crate::cluster::topology::ClusterTopology;
use crate::config::simconfig::SimConfig;
use crate::exec::batch::BatchDesc;
use crate::exec::{build_cost_model, StageCostModel};
use crate::scheduler::replica::{ReplicaScheduler, StagePlan};
use crate::scheduler::router::Router;
use crate::sim::metrics::SimMetrics;
use crate::telemetry::{StageLog, StageRecord};
use crate::workload::{Request, Trace, WorkloadGenerator};
use anyhow::Result;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled simulation event.
#[derive(Debug)]
enum EventKind {
    Arrival { request: u64 },
    IterDone { replica: u32, plan: StagePlan },
}

struct Event {
    at: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed comparison; ties broken by insertion order.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Everything a simulation run produces.
pub struct SimOutput {
    pub config: SimConfig,
    pub requests: Vec<Request>,
    pub stagelog: StageLog,
    pub metrics: SimMetrics,
    /// Cost-oracle call statistics (calls, cache hits) when the HLO
    /// backend is used.
    pub oracle_calls: u64,
    pub oracle_hits: u64,
}

/// Run the simulator with a freshly generated workload.
pub fn run(cfg: &SimConfig) -> Result<SimOutput> {
    cfg.validate()?;
    let mut gen = WorkloadGenerator::from_config(cfg);
    let trace = Trace::new(gen.generate(cfg.num_requests));
    run_with_trace(cfg, trace)
}

/// Run the simulator over an explicit trace (held fixed across sweeps).
pub fn run_with_trace(cfg: &SimConfig, trace: Trace) -> Result<SimOutput> {
    let cost = build_cost_model(cfg)?;
    run_with_model(cfg, trace, cost)
}

/// Run with an explicit cost model (tests inject mocks here).
pub fn run_with_model(
    cfg: &SimConfig,
    trace: Trace,
    mut cost: Box<dyn StageCostModel>,
) -> Result<SimOutput> {
    let topo = ClusterTopology::from_config(cfg)?;
    let mut requests = trace.requests;
    requests.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    // Request ids must index into the vec.
    for (i, r) in requests.iter_mut().enumerate() {
        r.id = i as u64;
    }

    let mut replicas: Vec<ReplicaScheduler> = (0..cfg.replicas)
        .map(|i| ReplicaScheduler::new(i, cfg))
        .collect::<Result<_>>()?;
    let mut router = Router::new(cfg.router, cfg.replicas as usize);
    let mut busy: Vec<bool> = vec![false; cfg.replicas as usize];

    let mut heap = BinaryHeap::with_capacity(requests.len() * 2);
    let mut seq = 0u64;
    for r in &requests {
        heap.push(Event {
            at: r.arrival_s,
            seq,
            kind: EventKind::Arrival { request: r.id },
        });
        seq += 1;
    }

    let mut stagelog = StageLog::new();
    let mut batch = BatchDesc::new(topo.model, topo.gpu, cfg.tp, cfg.pp, cfg.exec.clone());
    let mut finished_count = 0u64;
    let total = requests.len() as u64;
    let idle_gpus_per_stage = (cfg.pp - 1) * cfg.tp;

    // Start an iteration on a replica if it is free and has work.
    // Returns the scheduled completion event, if any.
    let start_iteration = |replica_idx: usize,
                               now: f64,
                               replicas: &mut [ReplicaScheduler],
                               requests: &mut [Request],
                               cost: &mut dyn StageCostModel,
                               stagelog: &mut StageLog,
                               batch: &mut BatchDesc,
                               seq: &mut u64|
     -> Option<Event> {
        let plan = replicas[replica_idx].next_stage(requests, now)?;
        // Price one pipeline stage.
        batch.clear();
        for &(id, nt) in &plan.entries {
            batch.push(nt, requests[id as usize].context_len() as u32);
        }
        let c = cost.stage_cost(batch);
        // pp sequential stages, each logged separately.
        for s in 0..cfg.pp {
            stagelog.push(StageRecord {
                replica: replica_idx as u32,
                pp_stage: s,
                start_s: now + s as f64 * c.t_stage_s,
                dt_s: c.t_stage_s,
                batch_size: plan.batch_size() as u32,
                new_tokens: plan.total_new_tokens() as u32,
                mfu: c.mfu,
                power_w: c.power_w,
                active_gpus: cfg.tp,
                idle_gpus: idle_gpus_per_stage,
                flops: c.flops,
                kind: plan.kind,
            });
        }
        let iter_time = c.t_stage_s * cfg.pp as f64;
        *seq += 1;
        Some(Event {
            at: now + iter_time,
            seq: *seq,
            kind: EventKind::IterDone {
                replica: replica_idx as u32,
                plan,
            },
        })
    };

    let mut last_time = 0.0f64;
    while let Some(ev) = heap.pop() {
        let now = ev.at;
        last_time = last_time.max(now);
        match ev.kind {
            EventKind::Arrival { request } => {
                let outstanding: Vec<u64> =
                    replicas.iter().map(|r| r.outstanding).collect();
                let target = router.route(&outstanding);
                replicas[target].enqueue(request);
                if !busy[target] {
                    if let Some(e) = start_iteration(
                        target,
                        now,
                        &mut replicas,
                        &mut requests,
                        cost.as_mut(),
                        &mut stagelog,
                        &mut batch,
                        &mut seq,
                    ) {
                        busy[target] = true;
                        heap.push(e);
                    }
                }
            }
            EventKind::IterDone { replica, plan } => {
                let idx = replica as usize;
                let fin = replicas[idx].complete_stage(&mut requests, &plan, now);
                finished_count += fin.len() as u64;
                busy[idx] = false;
                if let Some(e) = start_iteration(
                    idx,
                    now,
                    &mut replicas,
                    &mut requests,
                    cost.as_mut(),
                    &mut stagelog,
                    &mut batch,
                    &mut seq,
                ) {
                    busy[idx] = true;
                    heap.push(e);
                }
            }
        }
    }

    anyhow::ensure!(
        finished_count == total,
        "simulation ended with {finished_count}/{total} requests finished (deadlock?)"
    );

    let preemptions = replicas.iter().map(|r| r.preemptions).sum();
    let metrics = SimMetrics::compute(cfg, &requests, &stagelog, last_time, preemptions);
    let (oracle_calls, oracle_hits) = cost.stats();
    Ok(SimOutput {
        config: cfg.clone(),
        requests,
        stagelog,
        metrics,
        oracle_calls,
        oracle_hits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::{Arrival, CostModelKind, LengthDist};
    use crate::exec::batch::StageCost;

    /// Constant-time mock oracle: every stage takes 10 ms.
    struct MockCost;
    impl StageCostModel for MockCost {
        fn stage_cost(&mut self, b: &BatchDesc) -> StageCost {
            StageCost {
                t_stage_s: 0.01,
                flops: b.total_new_tokens() as f64 * 1e9,
                mfu: 0.2,
                power_w: 250.0,
            }
        }
        fn name(&self) -> &'static str {
            "mock"
        }
    }

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.num_requests = 40;
        cfg.cost_model = CostModelKind::Native;
        cfg.lengths = LengthDist::Zipf {
            theta: 0.6,
            min: 64,
            max: 512,
        };
        cfg.arrival = Arrival::Poisson { qps: 10.0 };
        cfg
    }

    #[test]
    fn all_requests_finish_native() {
        let out = run(&small_cfg()).unwrap();
        assert_eq!(out.requests.len(), 40);
        assert!(out.requests.iter().all(|r| r.is_finished()));
        assert!(out.metrics.makespan_s > 0.0);
        assert!(!out.stagelog.is_empty());
    }

    #[test]
    fn mock_oracle_timing_is_deterministic() {
        let cfg = small_cfg();
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));
        let a = run_with_model(&cfg, trace.clone(), Box::new(MockCost)).unwrap();
        let b = run_with_model(&cfg, trace, Box::new(MockCost)).unwrap();
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.stagelog.len(), b.stagelog.len());
    }

    #[test]
    fn stage_times_are_contiguous_per_replica() {
        let out = run(&small_cfg()).unwrap();
        // Stages of one replica never overlap.
        let mut recs: Vec<_> = out.stagelog.records.iter().collect();
        recs.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
        let mut last_end = 0.0;
        for r in recs {
            assert!(
                r.start_s >= last_end - 1e-9,
                "overlap: starts {} before {}",
                r.start_s,
                last_end
            );
            last_end = r.end_s();
        }
    }

    #[test]
    fn timestamps_monotone_and_lifecycle_consistent() {
        let out = run(&small_cfg()).unwrap();
        for r in &out.requests {
            let sched = r.scheduled_s.unwrap();
            let first = r.first_token_s.unwrap();
            let fin = r.finished_s.unwrap();
            assert!(sched >= r.arrival_s);
            assert!(first >= sched);
            assert!(fin >= first);
        }
    }

    #[test]
    fn multi_replica_distributes_load() {
        let mut cfg = small_cfg();
        cfg.replicas = 2;
        cfg.num_requests = 60;
        let out = run(&cfg).unwrap();
        assert!(out.requests.iter().all(|r| r.is_finished()));
        let replicas_used: std::collections::HashSet<u32> =
            out.stagelog.records.iter().map(|r| r.replica).collect();
        assert_eq!(replicas_used.len(), 2, "both replicas must execute work");
    }

    #[test]
    fn pp_stages_logged_per_iteration() {
        let mut cfg = small_cfg();
        cfg.pp = 2;
        cfg.tp = 2;
        cfg.num_requests = 10;
        let out = run(&cfg).unwrap();
        // Every iteration logs exactly pp stage records.
        assert_eq!(out.stagelog.len() % 2, 0);
        let r = &out.stagelog.records[0];
        assert_eq!(r.active_gpus, 2);
        assert_eq!(r.idle_gpus, 2); // (pp-1)*tp
    }

    #[test]
    fn higher_qps_shrinks_makespan() {
        // Same workload executed faster when offered load arrives faster
        // (the Exp. 4 energy-vs-QPS mechanism).
        let mut lo = small_cfg();
        lo.arrival = Arrival::Poisson { qps: 1.0 };
        lo.num_requests = 50;
        let mut hi = lo.clone();
        hi.arrival = Arrival::Poisson { qps: 20.0 };
        let out_lo = run(&lo).unwrap();
        let out_hi = run(&hi).unwrap();
        assert!(
            out_hi.metrics.makespan_s < out_lo.metrics.makespan_s,
            "hi {} !< lo {}",
            out_hi.metrics.makespan_s,
            out_lo.metrics.makespan_s
        );
    }
}
