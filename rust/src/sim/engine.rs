//! Discrete-event simulation engine.
//!
//! Events: request arrivals and replica iteration completions, ordered
//! by simulation time in a calendar queue ([`crate::sim::calq`] —
//! O(1) amortized vs the heap's O(log n); the original binary heap
//! remains available through [`run_with_sinks_heap`] /
//! [`run_autoscaled_with_sinks_heap`] for differential testing, and
//! `tests/calq_parity.rs` proves both produce byte-identical
//! telemetry). Each replica executes one iteration (= `pp` sequential
//! pipeline stages of one batch) at a time; the cost of a stage comes
//! from the configured oracle (AOT HLO by default, native roofline or
//! interpolated surface otherwise), and every pipeline stage is
//! logged as a [`StageRecord`] — the paper's granularity.
//!
//! Allocation model: the hot path is allocation-free at steady state.
//! Stage-entry vectors cycle through a [`StageScratch`] pool
//! (planned into by `ReplicaScheduler::next_stage_into`, reclaimed
//! when the iteration's completion event fires), and the
//! finished/outstanding/eligible buffers are reused per event.
//!
//! Pipeline-parallel note: stages of one iteration run back-to-back
//! (no cross-iteration microbatch overlap), matching the conservative
//! reading of Vidur's replica-stage traces; while one PP stage
//! computes, the other (pp-1)·tp GPUs of the replica idle at
//! `p_idle` and are charged as such by the energy accounting.
//!
//! Memory model (DESIGN.md §8): the cores are streaming end to end.
//! Arrivals are pulled one at a time from a [`RequestSource`] (exactly
//! one pending-arrival event lives in the event queue), outstanding requests
//! live in a compact [`LiveRequests`] map that drops each entry the
//! moment it completes and is handed to the [`RequestSink`], and stage
//! records flow into the [`StageSink`]. A run is O(outstanding + bins)
//! resident, independent of the request count.
//!
//! Two entry families, each generic over the telemetry sinks (pass
//! materialized logs to keep every record, streaming sinks to fold
//! them online):
//! * [`run`] / [`run_with_trace`] / [`run_with_model`] /
//!   [`run_with_sink`] / [`run_with_sinks`] / [`run_streaming`] — the
//!   fixed-fleet engine;
//! * [`run_autoscaled`] / [`run_autoscaled_with_model`] /
//!   [`run_autoscaled_with_sink`] / [`run_autoscaled_with_sinks`] /
//!   [`run_autoscaled_streaming`] — the dynamic fleet engine
//!   (DESIGN.md §6): replicas are provisioned with a cold-start delay
//!   (drawing idle power while booting), gracefully drained (admission
//!   closes, running requests finish, queued ones re-route through the
//!   [`Router`]), and taken offline, under a
//!   [`crate::autoscale::ScalingPolicy`] evaluated on a fixed decision
//!   interval against load telemetry ([`CompletionWindow`], itself a
//!   request-sink client) and grid signals.

use crate::autoscale::{
    build_policy, CompletionWindow, FleetController, FleetTimeline, GridEnv, LoadSignals,
    ScaleDecision,
};
use crate::cluster::topology::ClusterTopology;
use crate::config::simconfig::{AutoscaleConfig, SimConfig};
use crate::coordinator::fleet::{RegionSignals, RoutePolicy};
use crate::cosim::Microgrid;
use crate::exec::batch::BatchDesc;
use crate::exec::{build_cost_model, OracleStats, StageCostModel};
use crate::scheduler::replica::{ReplicaScheduler, StagePlan};
use crate::scheduler::router::Router;
use crate::sim::arena::StageScratch;
use crate::sim::calq::{CalendarQueue, EventQueue, HeapQueue};
use crate::sim::metrics::SimMetrics;
use crate::telemetry::{
    LatencySketches, RequestLog, RequestSink, RequestStats, StageLog, StageRecord, StageSink,
    StageStats, StreamingRequestSink,
};
use crate::workload::{
    LiveRequests, Request, RequestSource, RequestStore, Trace, WorkloadGenerator,
};
use anyhow::Result;

/// A scheduled fixed-fleet simulation event.
#[derive(Debug)]
enum EventKind {
    Arrival { request: u64 },
    IterDone { replica: u32, plan: StagePlan },
}

/// Events of the autoscaled engine: the base events plus replica
/// lifecycle transitions and periodic scaling decisions.
#[derive(Debug)]
enum AsEventKind {
    Arrival { request: u64 },
    IterDone { replica: u32, plan: StagePlan },
    /// Cold start finished; the replica starts serving traffic.
    ReplicaOnline { replica: u32 },
    /// Periodic autoscaling decision.
    ScaleTick,
}

/// Lifecycle state of one replica slot in the dynamic fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RState {
    /// Cold-starting (exists, draws idle power, serves nothing).
    Provisioning,
    /// Serving traffic.
    Active,
    /// Admission closed; finishing running requests.
    Draining,
    /// Gone.
    Offline,
}

/// What a simulation run produces regardless of sink kind: summary
/// metrics plus the stage/request accumulators and oracle cache
/// statistics. The caller's sinks hold the per-record telemetry (all
/// records for the materialized logs, online folds for the streaming
/// sinks); nothing here is O(requests) or O(stages).
pub struct SimRun {
    pub config: SimConfig,
    pub metrics: SimMetrics,
    /// Sink-side stage aggregates (also folded into `metrics`).
    pub stage_stats: StageStats,
    /// Sink-side request aggregates (also folded into `metrics`).
    pub request_stats: RequestStats,
    /// High-water mark of concurrently live requests inside the
    /// engine — the per-request memory footprint (O(outstanding)).
    pub peak_live_requests: usize,
    /// Cost-oracle memo-cache statistics (zero for cache-less backends).
    pub oracle: OracleStats,
}

/// Everything a materialized simulation run produces: the run plus the
/// full request vector and per-stage log.
pub struct SimOutput {
    pub config: SimConfig,
    pub requests: Vec<Request>,
    pub stagelog: StageLog,
    pub metrics: SimMetrics,
    /// Cost-oracle memo-cache statistics (zero for cache-less backends).
    pub oracle: OracleStats,
}

/// A dynamic-fleet run against caller-owned sinks: the simulation
/// run plus the replica lifecycle the energy layers need.
pub struct AutoscaleRun {
    pub sim: SimRun,
    /// Per-replica existence intervals + lifecycle event log.
    pub timeline: FleetTimeline,
    /// Every scaling decision the controller took.
    pub decisions: Vec<ScaleDecision>,
    /// Name of the policy that drove the run.
    pub policy: &'static str,
}

/// A materialized dynamic-fleet run: the simulation output plus the
/// replica lifecycle the energy layers need.
pub struct AutoscaleOutput {
    pub sim: SimOutput,
    /// Per-replica existence intervals + lifecycle event log.
    pub timeline: FleetTimeline,
    /// Every scaling decision the controller took.
    pub decisions: Vec<ScaleDecision>,
    /// Name of the policy that drove the run.
    pub policy: &'static str,
}

/// Pull the next arrival (if any) out of the source: insert it into
/// the live map and schedule its arrival event. The cores call this
/// once at startup and once per arrival pop, so the event queue never
/// holds more than one pending arrival. Returns false when the source
/// is exhausted.
fn pull_arrival<K, Q: EventQueue<K>>(
    source: &mut dyn RequestSource,
    live: &mut LiveRequests,
    queue: &mut Q,
    submitted: &mut u64,
    mk: impl FnOnce(u64) -> K,
) -> bool {
    match source.next_request() {
        Some(r) => {
            *submitted += 1;
            queue.push(r.arrival_s, mk(r.id));
            live.insert(r);
            true
        }
        None => false,
    }
}

/// Plan and price one iteration on `replica_idx`: asks the replica
/// scheduler for the next stage plan, prices it through the oracle,
/// emits `pp` stage records into the sink, and returns the iteration
/// completion time with the plan — or None when the replica has
/// nothing runnable.
fn plan_iteration(
    replica_idx: usize,
    now: f64,
    cfg: &SimConfig,
    idle_gpus_per_stage: u32,
    replicas: &mut [ReplicaScheduler],
    live: &mut LiveRequests,
    cost: &mut dyn StageCostModel,
    sink: &mut dyn StageSink,
    batch: &mut BatchDesc,
    scratch: &mut StageScratch,
) -> Option<(f64, StagePlan)> {
    // Plan into a pooled entries vector (recycled when this
    // iteration's completion event fires): no per-stage allocation.
    let mut entries = scratch.take_entries();
    let Some(kind) = replicas[replica_idx].next_stage_into(&mut *live, now, &mut entries)
    else {
        scratch.recycle_entries(entries);
        return None;
    };
    let plan = StagePlan { entries, kind };
    // Price one pipeline stage.
    batch.clear();
    for &(id, nt) in &plan.entries {
        batch.push(nt, live.req(id).context_len() as u32);
    }
    let c = cost.stage_cost(batch);
    // pp sequential stages, each logged separately.
    for s in 0..cfg.pp {
        sink.record(StageRecord {
            replica: replica_idx as u32,
            pp_stage: s,
            start_s: now + s as f64 * c.t_stage_s,
            dt_s: c.t_stage_s,
            batch_size: plan.batch_size() as u32,
            new_tokens: plan.total_new_tokens() as u32,
            mfu: c.mfu,
            power_w: c.power_w,
            active_gpus: cfg.tp,
            idle_gpus: idle_gpus_per_stage,
            flops: c.flops,
            kind: plan.kind,
        });
    }
    Some((now + c.t_stage_s * cfg.pp as f64, plan))
}

/// Retire the finished requests of one completed stage: drop them
/// from the live map and hand them to the request sink(s) in finish
/// order. Returns how many finished.
fn retire_finished(
    fin: &[u64],
    live: &mut LiveRequests,
    sinks: &mut [&mut dyn RequestSink],
) -> u64 {
    for &id in fin {
        let done = live.remove(id);
        for s in sinks.iter_mut() {
            s.record(&done);
        }
    }
    fin.len() as u64
}

/// Run the simulator with the workload `cfg` describes — the
/// synthetic generator, a replayed trace, or a scenario (DESIGN.md
/// §14).
pub fn run(cfg: &SimConfig) -> Result<SimOutput> {
    let trace = crate::workload::trace_from_config(cfg)?;
    run_with_trace(cfg, trace)
}

/// Run the simulator over an explicit trace (held fixed across sweeps).
pub fn run_with_trace(cfg: &SimConfig, trace: Trace) -> Result<SimOutput> {
    let cost = build_cost_model(cfg)?;
    run_with_model(cfg, trace, cost)
}

/// Run with an explicit cost model, materializing the full stage log
/// and request vector.
pub fn run_with_model(
    cfg: &SimConfig,
    trace: Trace,
    cost: Box<dyn StageCostModel>,
) -> Result<SimOutput> {
    let mut stagelog = StageLog::new();
    let mut reqlog = RequestLog::new(cfg);
    let mut source = trace.into_source();
    let run = run_with_sinks(cfg, &mut source, cost, &mut stagelog, &mut reqlog)?;
    Ok(SimOutput {
        config: run.config,
        requests: reqlog.into_requests(),
        stagelog,
        metrics: run.metrics,
        oracle: run.oracle,
    })
}

/// Run with a lazily generated workload against a caller-owned stage
/// sink; request telemetry streams through sketches. With a
/// [`crate::telemetry::StreamingSink`] this is the fully streaming
/// path: O(outstanding + bins) resident state end to end.
pub fn run_streaming(cfg: &SimConfig, sink: &mut dyn StageSink) -> Result<SimRun> {
    let mut reqs = StreamingRequestSink::new(cfg);
    run_streaming_with(cfg, sink, &mut reqs)
}

/// [`run_streaming`] with a caller-owned request sink — for callers
/// that need the sink's latency sketches afterwards (the sharded sweep
/// path persists them in the telemetry sidecar, DESIGN.md §9).
pub fn run_streaming_with(
    cfg: &SimConfig,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
) -> Result<SimRun> {
    let mut source = crate::workload::source_from_config(cfg)?;
    let cost = build_cost_model(cfg)?;
    run_with_sinks(cfg, &mut *source, cost, sink, requests)
}

/// Fixed-fleet run over an explicit trace and stage sink; request
/// telemetry streams through sketches.
pub fn run_with_sink(
    cfg: &SimConfig,
    trace: Trace,
    cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
) -> Result<SimRun> {
    let mut source = trace.into_source();
    let mut reqs = StreamingRequestSink::new(cfg);
    run_with_sinks(cfg, &mut source, cost, sink, &mut reqs)
}

/// The fixed-fleet engine core: explicit arrival source, cost model,
/// and stage/request telemetry sinks (tests inject mocks here). Runs
/// on the calendar-queue scheduler.
pub fn run_with_sinks(
    cfg: &SimConfig,
    source: &mut dyn RequestSource,
    cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
) -> Result<SimRun> {
    run_with_sinks_on(cfg, source, cost, sink, requests, CalendarQueue::new())
}

/// [`run_with_sinks`] on the reference binary-heap scheduler — the
/// differential-testing hook (`tests/calq_parity.rs` proves both
/// produce byte-identical telemetry).
pub fn run_with_sinks_heap(
    cfg: &SimConfig,
    source: &mut dyn RequestSource,
    cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
) -> Result<SimRun> {
    let queue = HeapQueue::with_capacity(cfg.replicas as usize * 2 + 4);
    run_with_sinks_on(cfg, source, cost, sink, requests, queue)
}

fn run_with_sinks_on<Q: EventQueue<EventKind>>(
    cfg: &SimConfig,
    source: &mut dyn RequestSource,
    mut cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
    mut queue: Q,
) -> Result<SimRun> {
    cfg.validate()?;
    let topo = ClusterTopology::from_config(cfg)?;
    let mut replicas: Vec<ReplicaScheduler> = (0..cfg.replicas)
        .map(|i| ReplicaScheduler::new(i, cfg))
        .collect::<Result<_>>()?;
    let mut router = Router::new(cfg.router, cfg.replicas as usize);
    let mut busy: Vec<bool> = vec![false; cfg.replicas as usize];

    // O(outstanding) event state: one pending arrival + one in-flight
    // iteration per replica.
    let mut live = LiveRequests::new();
    let mut scratch = StageScratch::new();
    let mut submitted = 0u64;
    pull_arrival(source, &mut live, &mut queue, &mut submitted, |id| {
        EventKind::Arrival { request: id }
    });

    let mut batch = BatchDesc::new(topo.model, topo.gpu, cfg.tp, cfg.pp, cfg.exec.clone());
    let mut finished_count = 0u64;
    let idle_gpus_per_stage = (cfg.pp - 1) * cfg.tp;

    let mut last_time = 0.0f64;
    while let Some((now, ev)) = queue.pop() {
        last_time = last_time.max(now);
        match ev {
            EventKind::Arrival { request } => {
                // Keep exactly one pending arrival: pull the successor
                // before routing this one, so same-instant arrivals
                // stay ordered ahead of the iteration completions
                // pushed below.
                pull_arrival(source, &mut live, &mut queue, &mut submitted, |id| {
                    EventKind::Arrival { request: id }
                });
                scratch.outstanding.clear();
                scratch
                    .outstanding
                    .extend(replicas.iter().map(|r| r.outstanding));
                let target = router.route(&scratch.outstanding);
                replicas[target].enqueue(request);
                if !busy[target] {
                    if let Some((at, plan)) = plan_iteration(
                        target,
                        now,
                        cfg,
                        idle_gpus_per_stage,
                        &mut replicas,
                        &mut live,
                        cost.as_mut(),
                        sink,
                        &mut batch,
                        &mut scratch,
                    ) {
                        busy[target] = true;
                        queue.push(
                            at,
                            EventKind::IterDone {
                                replica: target as u32,
                                plan,
                            },
                        );
                    }
                }
            }
            EventKind::IterDone { replica, plan } => {
                let idx = replica as usize;
                scratch.finished.clear();
                replicas[idx].complete_stage_into(
                    &mut live,
                    &plan.entries,
                    now,
                    &mut scratch.finished,
                );
                finished_count +=
                    retire_finished(&scratch.finished, &mut live, &mut [&mut *requests]);
                scratch.recycle_entries(plan.entries);
                busy[idx] = false;
                if let Some((at, plan)) = plan_iteration(
                    idx,
                    now,
                    cfg,
                    idle_gpus_per_stage,
                    &mut replicas,
                    &mut live,
                    cost.as_mut(),
                    sink,
                    &mut batch,
                    &mut scratch,
                ) {
                    busy[idx] = true;
                    queue.push(at, EventKind::IterDone { replica, plan });
                }
            }
        }
    }

    anyhow::ensure!(
        finished_count == submitted,
        "simulation ended with {finished_count}/{submitted} requests finished (deadlock?)"
    );

    let preemptions = replicas.iter().map(|r| r.preemptions).sum();
    let stage_stats = sink.stats();
    let mut request_stats = requests.stats();
    request_stats.submitted = submitted;
    let metrics = SimMetrics::compute(&request_stats, &stage_stats, last_time, preemptions);
    Ok(SimRun {
        config: cfg.clone(),
        metrics,
        stage_stats,
        request_stats,
        peak_live_requests: live.peak_resident(),
        oracle: cost.stats(),
    })
}

/// Start an iteration on `idx` if it is free and has runnable work;
/// pushes the completion event.
fn try_start<Q: EventQueue<AsEventKind>>(
    idx: usize,
    now: f64,
    cfg: &SimConfig,
    idle_gpus_per_stage: u32,
    replicas: &mut [ReplicaScheduler],
    live: &mut LiveRequests,
    cost: &mut dyn StageCostModel,
    sink: &mut dyn StageSink,
    batch: &mut BatchDesc,
    scratch: &mut StageScratch,
    busy: &mut [bool],
    queue: &mut Q,
) {
    if busy[idx] {
        return;
    }
    if let Some((at, plan)) = plan_iteration(
        idx,
        now,
        cfg,
        idle_gpus_per_stage,
        replicas,
        live,
        cost,
        sink,
        batch,
        scratch,
    ) {
        busy[idx] = true;
        queue.push(
            at,
            AsEventKind::IterDone {
                replica: idx as u32,
                plan,
            },
        );
    }
}

/// Move every queued request of `victim` to active replicas via the
/// router. Returns the set of replicas that received work (the caller
/// kicks them). The controller never drains the last active replica,
/// so an eligible target always exists when there is work to move.
fn reroute_queue(
    victim: usize,
    state: &[RState],
    replicas: &mut [ReplicaScheduler],
    router: &mut Router,
) -> Vec<usize> {
    let ids = replicas[victim].drain_queue();
    if ids.is_empty() {
        return Vec::new();
    }
    let eligible: Vec<usize> = state
        .iter()
        .enumerate()
        .filter(|(i, s)| **s == RState::Active && *i != victim)
        .map(|(i, _)| i)
        .collect();
    assert!(
        !eligible.is_empty(),
        "drain left no active replica to requeue onto"
    );
    let mut touched = Vec::new();
    for id in ids {
        let outstanding: Vec<u64> = replicas.iter().map(|r| r.outstanding).collect();
        let target = router.route_among(&eligible, &outstanding);
        replicas[target].enqueue(id);
        if !touched.contains(&target) {
            touched.push(target);
        }
    }
    touched
}

/// Move a fair share of the standing queue backlog onto the
/// newly-online replica `idx`. The newcomer takes a *ceiling* share —
/// `total_queued / n` would floor small backlogs to 0, leaving a
/// freshly cold-started replica idle until the next arrival despite
/// queued work — while donors keep at least the floor share.
fn rebalance_onto(idx: usize, actives: &[usize], replicas: &mut [ReplicaScheduler]) {
    let total_queued: usize = actives.iter().map(|&i| replicas[i].queue_len()).sum();
    if total_queued == 0 {
        return;
    }
    let n = actives.len().max(1);
    let keep = total_queued / n;
    let mut want = total_queued
        .div_ceil(n)
        .saturating_sub(replicas[idx].queue_len());
    for &j in actives {
        if want == 0 {
            break;
        }
        if j == idx {
            continue;
        }
        let excess = replicas[j].queue_len().saturating_sub(keep);
        let take = excess.min(want);
        if take > 0 {
            for id in replicas[j].steal_queued(take) {
                replicas[idx].enqueue(id);
            }
            want -= take;
        }
    }
}

/// Run the dynamic-fleet simulator with the configured cost oracle.
pub fn run_autoscaled(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    trace: Trace,
) -> Result<AutoscaleOutput> {
    let cost = build_cost_model(cfg)?;
    run_autoscaled_with_model(cfg, scale, grid, trace, cost)
}

/// Dynamic-fleet run with an explicit cost model, materializing the
/// full stage log and request vector.
pub fn run_autoscaled_with_model(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    trace: Trace,
    cost: Box<dyn StageCostModel>,
) -> Result<AutoscaleOutput> {
    let mut stagelog = StageLog::new();
    let mut reqlog = RequestLog::new(cfg);
    let mut source = trace.into_source();
    let run = run_autoscaled_with_sinks(
        cfg,
        scale,
        grid,
        &mut source,
        cost,
        &mut stagelog,
        &mut reqlog,
    )?;
    Ok(AutoscaleOutput {
        sim: SimOutput {
            config: run.sim.config,
            requests: reqlog.into_requests(),
            stagelog,
            metrics: run.sim.metrics,
            oracle: run.sim.oracle,
        },
        timeline: run.timeline,
        decisions: run.decisions,
        policy: run.policy,
    })
}

/// Dynamic-fleet run with the configured cost oracle against a
/// caller-owned stage sink; request telemetry streams through
/// sketches (O(outstanding + bins) with a streaming stage sink).
pub fn run_autoscaled_streaming(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    trace: Trace,
    sink: &mut dyn StageSink,
) -> Result<AutoscaleRun> {
    let cost = build_cost_model(cfg)?;
    run_autoscaled_with_sink(cfg, scale, grid, trace, cost, sink)
}

/// [`run_autoscaled_streaming`] with a caller-owned request sink —
/// the dynamic-fleet twin of [`run_streaming_with`] (the sharded
/// autoscale sweep persists the sink's sketches, DESIGN.md §9).
pub fn run_autoscaled_streaming_with(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    trace: Trace,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
) -> Result<AutoscaleRun> {
    let cost = build_cost_model(cfg)?;
    let mut source = trace.into_source();
    run_autoscaled_with_sinks(cfg, scale, grid, &mut source, cost, sink, requests)
}

/// Dynamic-fleet run over an explicit trace, cost model, and stage
/// sink; request telemetry streams through sketches.
pub fn run_autoscaled_with_sink(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    trace: Trace,
    cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
) -> Result<AutoscaleRun> {
    let mut source = trace.into_source();
    let mut reqs = StreamingRequestSink::new(cfg);
    run_autoscaled_with_sinks(cfg, scale, grid, &mut source, cost, sink, &mut reqs)
}

/// Dynamic-fleet engine core: like [`run_with_sinks`] but the replica
/// fleet grows and shrinks under the configured scaling policy.
///
/// Replica lifecycle: Provision (cold start, idle power, `cold_start_s`
/// long) → Active → Draining (admission closed, queue re-routed,
/// running requests finish) → Offline. The initial fleet is
/// `cfg.replicas` clamped into the autoscaler bounds and is online at
/// t = 0 with no cold start.
pub fn run_autoscaled_with_sinks(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    source: &mut dyn RequestSource,
    cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
) -> Result<AutoscaleRun> {
    run_autoscaled_with_sinks_on(
        cfg,
        scale,
        grid,
        source,
        cost,
        sink,
        requests,
        CalendarQueue::new(),
    )
}

/// [`run_autoscaled_with_sinks`] on the reference binary-heap
/// scheduler — the differential-testing hook for the dynamic fleet.
pub fn run_autoscaled_with_sinks_heap(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    source: &mut dyn RequestSource,
    cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
) -> Result<AutoscaleRun> {
    let queue = HeapQueue::with_capacity(cfg.replicas as usize * 2 + 64);
    run_autoscaled_with_sinks_on(cfg, scale, grid, source, cost, sink, requests, queue)
}

fn run_autoscaled_with_sinks_on<Q: EventQueue<AsEventKind>>(
    cfg: &SimConfig,
    scale: &AutoscaleConfig,
    grid: &GridEnv,
    source: &mut dyn RequestSource,
    mut cost: Box<dyn StageCostModel>,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
    mut queue: Q,
) -> Result<AutoscaleRun> {
    cfg.validate()?;
    scale.validate()?;
    let topo = ClusterTopology::from_config(cfg)?;

    let init = cfg.replicas.clamp(scale.min_replicas, scale.max_replicas);
    let mut replicas: Vec<ReplicaScheduler> = (0..init)
        .map(|i| ReplicaScheduler::new(i, cfg))
        .collect::<Result<_>>()?;
    let mut state: Vec<RState> = vec![RState::Active; init as usize];
    let mut busy: Vec<bool> = vec![false; init as usize];
    let mut router = Router::new(cfg.router, init as usize);
    let mut timeline = FleetTimeline::new();
    for i in 0..init {
        timeline.provision(i, 0.0);
        timeline.online(i, 0.0);
    }
    let mut controller = FleetController::new(scale.clone(), build_policy(scale, init));

    let mut live = LiveRequests::new();
    let mut scratch = StageScratch::new();
    let mut submitted = 0u64;
    let mut source_done = !pull_arrival(source, &mut live, &mut queue, &mut submitted, |id| {
        AsEventKind::Arrival { request: id }
    });
    queue.push(scale.decision_interval_s, AsEventKind::ScaleTick);

    let mut batch = BatchDesc::new(topo.model, topo.gpu, cfg.tp, cfg.pp, cfg.exec.clone());
    let mut finished_count = 0u64;
    let idle_gpus_per_stage = (cfg.pp - 1) * cfg.tp;

    // Recent-completion window feeding the SLO/throughput telemetry —
    // a request-sink client fed the same completion stream as the
    // caller's sink.
    let window_s = (scale.decision_interval_s * 5.0).max(300.0);
    let mut window = CompletionWindow::new(window_s);

    let mut last_time = 0.0f64;
    while let Some((now, ev)) = queue.pop() {
        // Only workload progress defines the makespan: control-plane
        // events (ticks, cold-start completions) trailing the last
        // request must not inflate it — or the timeline horizon, which
        // would charge phantom whole-fleet idle energy.
        if matches!(
            ev,
            AsEventKind::Arrival { .. } | AsEventKind::IterDone { .. }
        ) {
            last_time = last_time.max(now);
        }
        match ev {
            AsEventKind::Arrival { request } => {
                if !source_done {
                    source_done =
                        !pull_arrival(source, &mut live, &mut queue, &mut submitted, |id| {
                            AsEventKind::Arrival { request: id }
                        });
                }
                scratch.eligible.clear();
                scratch.eligible.extend(
                    state
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| **s == RState::Active)
                        .map(|(i, _)| i),
                );
                scratch.outstanding.clear();
                scratch
                    .outstanding
                    .extend(replicas.iter().map(|r| r.outstanding));
                let target = router.route_among(&scratch.eligible, &scratch.outstanding);
                replicas[target].enqueue(request);
                try_start(
                    target,
                    now,
                    cfg,
                    idle_gpus_per_stage,
                    &mut replicas,
                    &mut live,
                    cost.as_mut(),
                    sink,
                    &mut batch,
                    &mut scratch,
                    &mut busy,
                    &mut queue,
                );
            }
            AsEventKind::IterDone { replica, plan } => {
                let idx = replica as usize;
                scratch.finished.clear();
                replicas[idx].complete_stage_into(
                    &mut live,
                    &plan.entries,
                    now,
                    &mut scratch.finished,
                );
                finished_count += retire_finished(
                    &scratch.finished,
                    &mut live,
                    &mut [&mut window as &mut dyn RequestSink, &mut *requests],
                );
                scratch.recycle_entries(plan.entries);
                busy[idx] = false;
                try_start(
                    idx,
                    now,
                    cfg,
                    idle_gpus_per_stage,
                    &mut replicas,
                    &mut live,
                    cost.as_mut(),
                    sink,
                    &mut batch,
                    &mut scratch,
                    &mut busy,
                    &mut queue,
                );
                if state[idx] == RState::Draining {
                    // Preemption during the drain may have pushed
                    // requests back onto this replica's queue; they
                    // must move to an active replica or they would
                    // never be re-admitted.
                    if replicas[idx].queue_len() > 0 {
                        for t in reroute_queue(idx, &state, &mut replicas, &mut router) {
                            try_start(
                                t,
                                now,
                                cfg,
                                idle_gpus_per_stage,
                                &mut replicas,
                                &mut live,
                                cost.as_mut(),
                                sink,
                                &mut batch,
                                &mut scratch,
                                &mut busy,
                                &mut queue,
                            );
                        }
                    }
                    if !busy[idx] && !replicas[idx].has_work() {
                        state[idx] = RState::Offline;
                        timeline.offline(replica, now);
                    }
                }
            }
            AsEventKind::ReplicaOnline { replica } => {
                if source_done && finished_count >= submitted {
                    continue; // run is over; don't pollute the timeline
                }
                let idx = replica as usize;
                // A cancelled provision may already be Offline.
                if state[idx] == RState::Provisioning {
                    state[idx] = RState::Active;
                    timeline.online(replica, now);
                    // Rebalance: a scale-up was triggered by backlog, so
                    // the new replica takes its fair (ceiling) share of
                    // standing queues instead of waiting for future
                    // arrivals.
                    let actives: Vec<usize> = state
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| **s == RState::Active)
                        .map(|(i, _)| i)
                        .collect();
                    rebalance_onto(idx, &actives, &mut replicas);
                    try_start(
                        idx,
                        now,
                        cfg,
                        idle_gpus_per_stage,
                        &mut replicas,
                        &mut live,
                        cost.as_mut(),
                        sink,
                        &mut batch,
                        &mut scratch,
                        &mut busy,
                        &mut queue,
                    );
                }
            }
            AsEventKind::ScaleTick => {
                if source_done && finished_count >= submitted {
                    continue; // run is over; stop the tick chain
                }
                window.prune(now);
                let active =
                    state.iter().filter(|&&s| s == RState::Active).count() as u32;
                let pending =
                    state.iter().filter(|&&s| s == RState::Provisioning).count() as u32;
                let queued: u64 =
                    replicas.iter().map(|r| r.queue_len() as u64).sum();
                let running: u64 =
                    replicas.iter().map(|r| r.running_len() as u64).sum();
                let load = LoadSignals {
                    t_s: now,
                    queued,
                    running,
                    active_replicas: active,
                    pending_replicas: pending,
                    recent_qps: window.qps(now),
                    recent_ttft_p99_s: window.ttft_p99(),
                    recent_e2e_p99_s: window.e2e_p99(),
                    slo_ttft_s: cfg.slo_ttft_s,
                    slo_e2e_s: cfg.slo_e2e_s,
                };
                let desired = controller.desired(&load, &grid.at(now));
                let fleet = active + pending;
                if desired > fleet {
                    for _ in 0..(desired - fleet) {
                        let id = replicas.len() as u32;
                        replicas.push(ReplicaScheduler::new(id, cfg)?);
                        state.push(RState::Provisioning);
                        busy.push(false);
                        timeline.provision(id, now);
                        queue.push(
                            now + scale.cold_start_s,
                            AsEventKind::ReplicaOnline { replica: id },
                        );
                    }
                } else if desired < fleet {
                    let mut shed = fleet - desired;
                    // 1. Cancel cold starts (newest first): free.
                    for idx in (0..replicas.len()).rev() {
                        if shed == 0 {
                            break;
                        }
                        if state[idx] == RState::Provisioning {
                            state[idx] = RState::Offline;
                            timeline.offline(idx as u32, now);
                            shed -= 1;
                        }
                    }
                    // 2. Gracefully drain the least-loaded active
                    //    replicas, always keeping at least one active.
                    while shed > 0 {
                        let actives: Vec<usize> = state
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| **s == RState::Active)
                            .map(|(i, _)| i)
                            .collect();
                        if actives.len() <= 1 {
                            break;
                        }
                        let victim = *actives
                            .iter()
                            .min_by_key(|&&i| replicas[i].outstanding)
                            .unwrap();
                        state[victim] = RState::Draining;
                        // Close scheduler-side admission too: without
                        // this, preemption refugees would be silently
                        // re-admitted onto the draining replica.
                        replicas[victim].begin_drain();
                        timeline.drain_start(victim as u32, now);
                        for t in
                            reroute_queue(victim, &state, &mut replicas, &mut router)
                        {
                            try_start(
                                t,
                                now,
                                cfg,
                                idle_gpus_per_stage,
                                &mut replicas,
                                &mut live,
                                cost.as_mut(),
                                sink,
                                &mut batch,
                                &mut scratch,
                                &mut busy,
                                &mut queue,
                            );
                        }
                        if !busy[victim] && !replicas[victim].has_work() {
                            state[victim] = RState::Offline;
                            timeline.offline(victim as u32, now);
                        }
                        shed -= 1;
                    }
                }
                // Re-arm the tick only while progress is possible: at
                // this point the popped tick was the only one pending,
                // so a non-empty queue means arrivals/iterations/onlines
                // are still in flight. An empty queue with unfinished
                // requests is a deadlock — stop ticking so the loop
                // exits and the ensure! below reports it.
                if !queue.is_empty() {
                    queue.push(now + scale.decision_interval_s, AsEventKind::ScaleTick);
                }
            }
        }
    }

    anyhow::ensure!(
        finished_count == submitted,
        "autoscaled simulation ended with {finished_count}/{submitted} requests finished (deadlock?)"
    );

    timeline.close(last_time);
    let preemptions = replicas.iter().map(|r| r.preemptions).sum();
    let stage_stats = sink.stats();
    let mut request_stats = requests.stats();
    request_stats.submitted = submitted;
    let metrics = SimMetrics::compute(&request_stats, &stage_stats, last_time, preemptions);
    let policy = controller.policy_name();
    Ok(AutoscaleRun {
        sim: SimRun {
            config: cfg.clone(),
            metrics,
            stage_stats,
            request_stats,
            peak_live_requests: live.peak_resident(),
            oracle: cost.stats(),
        },
        timeline,
        decisions: controller.decisions,
        policy,
    })
}

// ---------------------------------------------------------------------------
// Multi-fleet (regional) engine — DESIGN.md §13.
// ---------------------------------------------------------------------------

/// Events of the multi-fleet engine: the autoscaled events tagged with
/// their region, plus the routed-arrival hop.
#[derive(Debug)]
enum MrEventKind {
    /// A request arriving at the global router (home region's door).
    Arrival { request: u64 },
    /// A routed request landing in a remote region after the RTT.
    RemoteArrival { region: u32, request: u64 },
    IterDone { region: u32, replica: u32, plan: StagePlan },
    ReplicaOnline { region: u32, replica: u32 },
    ScaleTick { region: u32 },
}

/// One region's slice of a multi-fleet run: the simulated cluster,
/// its grid environment, the advisory microgrid, and the caller-owned
/// telemetry sinks. Replica ids are region-local (dense from 0).
pub struct RegionSim<'a> {
    /// Initial (and, without `scale`, fixed) replica count.
    pub replicas: u32,
    /// Per-region autoscaler; `None` keeps the fleet fixed.
    pub scale: Option<AutoscaleConfig>,
    /// Live CI/solar signals for this region's router + controller.
    pub grid: GridEnv,
    /// One-way RTT from the router to this region, seconds (0 = home).
    pub rtt_s: f64,
    /// Advisory per-replica demand estimate, W (drives the microgrid
    /// stepping the router's battery-SoC signal comes from; the
    /// authoritative energy accounting bins the stage records instead).
    pub power_est_w: f64,
    /// Battery + solar microgrid, stepped on `interval_s` inside the
    /// run so routing sees a live state of charge.
    pub microgrid: Microgrid,
    /// Microgrid stepping interval, seconds.
    pub interval_s: f64,
    /// Fractional energy overhead of serving a moved request here
    /// (0 at home) — surfaced to the route policy.
    pub transfer_overhead: f64,
    pub sink: &'a mut dyn StageSink,
    pub requests: &'a mut dyn RequestSink,
}

/// Per-region outcome of a multi-fleet run.
pub struct RegionRun {
    /// Replica lifecycle (region-local ids, shared clock horizon).
    pub timeline: FleetTimeline,
    /// Requests the route policy sent here.
    pub routed: u64,
    /// This region's stage aggregates (its sink's view).
    pub stage_stats: StageStats,
    /// This region's request aggregates (`submitted` = `routed`).
    pub request_stats: RequestStats,
    /// Scaling decisions of the region's controller (empty if fixed).
    pub decisions: Vec<ScaleDecision>,
    /// Scaling policy name, or `"fixed"` without a controller.
    pub scaling_policy: &'static str,
    /// Battery SoC after the advisory microgrid stepping.
    pub final_soc: f64,
}

/// What a multi-fleet run produces: fleet-wide metrics (merged across
/// regions) plus the per-region breakdown.
pub struct MultiFleetRun {
    pub config: SimConfig,
    pub metrics: SimMetrics,
    /// Stage aggregates merged across every region.
    pub stage_stats: StageStats,
    /// Fleet-wide request aggregates (an internal sink fed every
    /// completion; per-region sinks keep their own).
    pub request_stats: RequestStats,
    /// Fleet-wide latency sketches (for telemetry sidecars).
    pub sketches: LatencySketches,
    pub per_region: Vec<RegionRun>,
    pub peak_live_requests: usize,
    pub oracle: OracleStats,
    /// Name of the route policy that drove the run.
    pub route_policy: &'static str,
}

/// Internal per-region state of the multi-fleet core.
struct MrRegion<'a> {
    spec: RegionSim<'a>,
    replicas: Vec<ReplicaScheduler>,
    rstate: Vec<RState>,
    busy: Vec<bool>,
    router: Router,
    timeline: FleetTimeline,
    controller: Option<FleetController>,
    window: CompletionWindow,
    routed: u64,
    /// Microgrid stepping frontier (advisory accounting clock).
    grid_t: f64,
}

impl MrRegion<'_> {
    fn active_count(&self) -> u32 {
        self.rstate.iter().filter(|&&s| s == RState::Active).count() as u32
    }

    /// Step the advisory microgrid up to `now` in `interval_s` chunks:
    /// active replicas draw the estimated wattage against the region's
    /// live solar/CI, moving the battery SoC the router reads.
    fn advance_microgrid(&mut self, now: f64) {
        let dt = self.spec.interval_s;
        if dt <= 0.0 {
            return;
        }
        while self.grid_t + dt <= now {
            let g = self.spec.grid.at(self.grid_t);
            let demand = self.active_count() as f64 * self.spec.power_est_w;
            self.spec
                .microgrid
                .step(self.grid_t, demand, g.solar_w, g.ci, dt);
            self.grid_t += dt;
        }
    }

    /// Snapshot the live routing signals at `now`.
    fn signals(&self, now: f64) -> RegionSignals {
        let g = self.spec.grid.at(now);
        let active = self.active_count();
        let b = &self.spec.microgrid.battery;
        RegionSignals {
            ci_g_per_kwh: g.ci,
            solar_w: g.solar_w,
            est_demand_w: active as f64 * self.spec.power_est_w,
            battery_soc: b.soc,
            soc_min: b.soc_min,
            soc_max: b.soc_max,
            queue_depth: self.replicas.iter().map(|r| r.outstanding).sum(),
            active_replicas: active,
            rtt_s: self.spec.rtt_s,
            transfer_overhead: self.spec.transfer_overhead,
        }
    }
}

/// Start an iteration on region `region`, replica `idx`, if it is free
/// and has runnable work; pushes the completion event and counts it as
/// in-flight work.
#[allow(clippy::too_many_arguments)]
fn mr_try_start(
    region: u32,
    idx: usize,
    now: f64,
    cfg: &SimConfig,
    idle_gpus_per_stage: u32,
    rg: &mut MrRegion<'_>,
    live: &mut LiveRequests,
    cost: &mut dyn StageCostModel,
    batch: &mut BatchDesc,
    scratch: &mut StageScratch,
    queue: &mut CalendarQueue<MrEventKind>,
    inflight: &mut u64,
) {
    if rg.busy[idx] {
        return;
    }
    if let Some((at, plan)) = plan_iteration(
        idx,
        now,
        cfg,
        idle_gpus_per_stage,
        &mut rg.replicas,
        live,
        cost,
        &mut *rg.spec.sink,
        batch,
        scratch,
    ) {
        rg.busy[idx] = true;
        *inflight += 1;
        queue.push(
            at,
            MrEventKind::IterDone {
                region,
                replica: idx as u32,
                plan,
            },
        );
    }
}

/// Admit one request into region `region` (home arrivals and remote
/// landings share this): route it across the region's replicas and
/// kick the target. A fixed-fleet region uses the plain `route` call
/// the fixed core uses — the single-region byte-neutrality hinges on
/// that — while an autoscaled region routes among Active replicas.
#[allow(clippy::too_many_arguments)]
fn mr_admit(
    region: u32,
    request: u64,
    now: f64,
    cfg: &SimConfig,
    idle_gpus_per_stage: u32,
    rg: &mut MrRegion<'_>,
    live: &mut LiveRequests,
    cost: &mut dyn StageCostModel,
    batch: &mut BatchDesc,
    scratch: &mut StageScratch,
    queue: &mut CalendarQueue<MrEventKind>,
    inflight: &mut u64,
) {
    scratch.outstanding.clear();
    scratch
        .outstanding
        .extend(rg.replicas.iter().map(|r| r.outstanding));
    let target = if rg.controller.is_some() {
        scratch.eligible.clear();
        scratch.eligible.extend(
            rg.rstate
                .iter()
                .enumerate()
                .filter(|(_, s)| **s == RState::Active)
                .map(|(i, _)| i),
        );
        rg.router.route_among(&scratch.eligible, &scratch.outstanding)
    } else {
        rg.router.route(&scratch.outstanding)
    };
    rg.replicas[target].enqueue(request);
    mr_try_start(
        region,
        target,
        now,
        cfg,
        idle_gpus_per_stage,
        rg,
        live,
        cost,
        batch,
        scratch,
        queue,
        inflight,
    );
}

/// Multi-fleet engine core (DESIGN.md §13): every region's fleet,
/// controller, and microgrid advance on one shared clock; `policy`
/// assigns each arriving request to a region from live signals, and a
/// remote assignment pays the region's RTT before admission.
///
/// With one region configured the event sequence — and therefore the
/// per-region sink telemetry — is byte-identical to
/// [`run_with_sinks`]: same pull/route/plan order, no control-plane
/// events (ticks exist only for autoscaled regions), no signal
/// snapshots (the single-region fast path skips the router entirely).
///
/// Termination: `inflight` counts queued workload events (arrivals,
/// remote hops, iterations, cold starts). Scale ticks re-arm only
/// while such work exists, so idle regions' mutual tick chains cannot
/// keep the loop alive — and a deadlocked run drains to zero and is
/// reported by the final ensure, exactly like the single-fleet cores.
pub fn run_multifleet(
    cfg: &SimConfig,
    source: &mut dyn RequestSource,
    mut cost: Box<dyn StageCostModel>,
    policy: &mut dyn RoutePolicy,
    regions: Vec<RegionSim<'_>>,
) -> Result<MultiFleetRun> {
    cfg.validate()?;
    anyhow::ensure!(!regions.is_empty(), "multi-fleet run needs at least one region");
    let topo = ClusterTopology::from_config(cfg)?;
    let mut queue: CalendarQueue<MrEventKind> = CalendarQueue::new();

    let mut fleet: Vec<MrRegion<'_>> = Vec::with_capacity(regions.len());
    for (ri, spec) in regions.into_iter().enumerate() {
        if let Some(s) = &spec.scale {
            s.validate()?;
        }
        let init = match &spec.scale {
            Some(s) => spec.replicas.clamp(s.min_replicas, s.max_replicas),
            None => spec.replicas,
        };
        anyhow::ensure!(init >= 1, "region {ri} has no replicas");
        let replicas: Vec<ReplicaScheduler> = (0..init)
            .map(|i| ReplicaScheduler::new(i, cfg))
            .collect::<Result<_>>()?;
        let mut timeline = FleetTimeline::new();
        for i in 0..init {
            timeline.provision(i, 0.0);
            timeline.online(i, 0.0);
        }
        let controller = spec
            .scale
            .as_ref()
            .map(|s| FleetController::new(s.clone(), build_policy(s, init)));
        let window_s = spec
            .scale
            .as_ref()
            .map(|s| (s.decision_interval_s * 5.0).max(300.0))
            .unwrap_or(300.0);
        if let Some(s) = &spec.scale {
            queue.push(
                s.decision_interval_s,
                MrEventKind::ScaleTick { region: ri as u32 },
            );
        }
        fleet.push(MrRegion {
            replicas,
            rstate: vec![RState::Active; init as usize],
            busy: vec![false; init as usize],
            router: Router::new(cfg.router, init as usize),
            timeline,
            controller,
            window: CompletionWindow::new(window_s),
            routed: 0,
            grid_t: 0.0,
            spec,
        });
    }
    let n_regions = fleet.len();

    let mut live = LiveRequests::new();
    let mut scratch = StageScratch::new();
    let mut fleet_reqs = StreamingRequestSink::new(cfg);
    let mut submitted = 0u64;
    let mut source_done = !pull_arrival(source, &mut live, &mut queue, &mut submitted, |id| {
        MrEventKind::Arrival { request: id }
    });
    // Queued workload events (everything but scale ticks): the tick
    // chains' liveness condition.
    let mut inflight: u64 = if source_done { 0 } else { 1 };

    let mut batch = BatchDesc::new(topo.model, topo.gpu, cfg.tp, cfg.pp, cfg.exec.clone());
    let mut finished_count = 0u64;
    let idle_gpus_per_stage = (cfg.pp - 1) * cfg.tp;
    let mut signals: Vec<RegionSignals> = Vec::with_capacity(n_regions);

    let mut last_time = 0.0f64;
    while let Some((now, ev)) = queue.pop() {
        if !matches!(ev, MrEventKind::ScaleTick { .. }) {
            inflight -= 1;
        }
        // Only workload progress defines the makespan (same rule as
        // the autoscaled core): trailing control-plane events must not
        // inflate it or the timeline horizons.
        if matches!(
            ev,
            MrEventKind::Arrival { .. }
                | MrEventKind::RemoteArrival { .. }
                | MrEventKind::IterDone { .. }
        ) {
            last_time = last_time.max(now);
        }
        match ev {
            MrEventKind::Arrival { request } => {
                if !source_done {
                    source_done =
                        !pull_arrival(source, &mut live, &mut queue, &mut submitted, |id| {
                            MrEventKind::Arrival { request: id }
                        });
                    if !source_done {
                        inflight += 1;
                    }
                }
                let target = if n_regions == 1 {
                    // Single-region fast path: no snapshots, no policy
                    // call — keeps the event stream byte-identical to
                    // the fixed core.
                    0
                } else {
                    signals.clear();
                    for rg in fleet.iter_mut() {
                        rg.advance_microgrid(now);
                        signals.push(rg.signals(now));
                    }
                    policy.route(now, &signals).min(n_regions - 1)
                };
                fleet[target].routed += 1;
                if target == 0 {
                    mr_admit(
                        0,
                        request,
                        now,
                        cfg,
                        idle_gpus_per_stage,
                        &mut fleet[0],
                        &mut live,
                        cost.as_mut(),
                        &mut batch,
                        &mut scratch,
                        &mut queue,
                        &mut inflight,
                    );
                } else {
                    let rtt = fleet[target].spec.rtt_s.max(0.0);
                    queue.push(
                        now + rtt,
                        MrEventKind::RemoteArrival {
                            region: target as u32,
                            request,
                        },
                    );
                    inflight += 1;
                }
            }
            MrEventKind::RemoteArrival { region, request } => {
                mr_admit(
                    region,
                    request,
                    now,
                    cfg,
                    idle_gpus_per_stage,
                    &mut fleet[region as usize],
                    &mut live,
                    cost.as_mut(),
                    &mut batch,
                    &mut scratch,
                    &mut queue,
                    &mut inflight,
                );
            }
            MrEventKind::IterDone { region, replica, plan } => {
                let idx = replica as usize;
                let rg = &mut fleet[region as usize];
                scratch.finished.clear();
                rg.replicas[idx].complete_stage_into(
                    &mut live,
                    &plan.entries,
                    now,
                    &mut scratch.finished,
                );
                finished_count += retire_finished(
                    &scratch.finished,
                    &mut live,
                    &mut [
                        &mut rg.window as &mut dyn RequestSink,
                        &mut *rg.spec.requests,
                        &mut fleet_reqs,
                    ],
                );
                scratch.recycle_entries(plan.entries);
                rg.busy[idx] = false;
                mr_try_start(
                    region,
                    idx,
                    now,
                    cfg,
                    idle_gpus_per_stage,
                    rg,
                    &mut live,
                    cost.as_mut(),
                    &mut batch,
                    &mut scratch,
                    &mut queue,
                    &mut inflight,
                );
                if rg.rstate[idx] == RState::Draining {
                    if rg.replicas[idx].queue_len() > 0 {
                        for t in reroute_queue(idx, &rg.rstate, &mut rg.replicas, &mut rg.router)
                        {
                            mr_try_start(
                                region,
                                t,
                                now,
                                cfg,
                                idle_gpus_per_stage,
                                rg,
                                &mut live,
                                cost.as_mut(),
                                &mut batch,
                                &mut scratch,
                                &mut queue,
                                &mut inflight,
                            );
                        }
                    }
                    if !rg.busy[idx] && !rg.replicas[idx].has_work() {
                        rg.rstate[idx] = RState::Offline;
                        rg.timeline.offline(replica, now);
                    }
                }
            }
            MrEventKind::ReplicaOnline { region, replica } => {
                if source_done && finished_count >= submitted {
                    continue; // run is over; don't pollute the timeline
                }
                let idx = replica as usize;
                let rg = &mut fleet[region as usize];
                if rg.rstate[idx] == RState::Provisioning {
                    rg.rstate[idx] = RState::Active;
                    rg.timeline.online(replica, now);
                    let actives: Vec<usize> = rg
                        .rstate
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| **s == RState::Active)
                        .map(|(i, _)| i)
                        .collect();
                    rebalance_onto(idx, &actives, &mut rg.replicas);
                    mr_try_start(
                        region,
                        idx,
                        now,
                        cfg,
                        idle_gpus_per_stage,
                        rg,
                        &mut live,
                        cost.as_mut(),
                        &mut batch,
                        &mut scratch,
                        &mut queue,
                        &mut inflight,
                    );
                }
            }
            MrEventKind::ScaleTick { region } => {
                if source_done && finished_count >= submitted {
                    continue; // run is over; stop this region's chain
                }
                let rg = &mut fleet[region as usize];
                let (decision_interval_s, cold_start_s) = match &rg.spec.scale {
                    Some(s) => (s.decision_interval_s, s.cold_start_s),
                    None => continue,
                };
                rg.window.prune(now);
                let active = rg.active_count();
                let pending = rg
                    .rstate
                    .iter()
                    .filter(|&&s| s == RState::Provisioning)
                    .count() as u32;
                let queued: u64 = rg.replicas.iter().map(|r| r.queue_len() as u64).sum();
                let running: u64 = rg.replicas.iter().map(|r| r.running_len() as u64).sum();
                let load = LoadSignals {
                    t_s: now,
                    queued,
                    running,
                    active_replicas: active,
                    pending_replicas: pending,
                    recent_qps: rg.window.qps(now),
                    recent_ttft_p99_s: rg.window.ttft_p99(),
                    recent_e2e_p99_s: rg.window.e2e_p99(),
                    slo_ttft_s: cfg.slo_ttft_s,
                    slo_e2e_s: cfg.slo_e2e_s,
                };
                let desired = rg
                    .controller
                    .as_mut()
                    .expect("scale tick implies a controller")
                    .desired(&load, &rg.spec.grid.at(now));
                let have = active + pending;
                if desired > have {
                    for _ in 0..(desired - have) {
                        let id = rg.replicas.len() as u32;
                        rg.replicas.push(ReplicaScheduler::new(id, cfg)?);
                        rg.rstate.push(RState::Provisioning);
                        rg.busy.push(false);
                        rg.timeline.provision(id, now);
                        queue.push(
                            now + cold_start_s,
                            MrEventKind::ReplicaOnline {
                                region,
                                replica: id,
                            },
                        );
                        inflight += 1;
                    }
                } else if desired < have {
                    let mut shed = have - desired;
                    // 1. Cancel cold starts (newest first): free.
                    for idx in (0..rg.replicas.len()).rev() {
                        if shed == 0 {
                            break;
                        }
                        if rg.rstate[idx] == RState::Provisioning {
                            rg.rstate[idx] = RState::Offline;
                            rg.timeline.offline(idx as u32, now);
                            shed -= 1;
                        }
                    }
                    // 2. Gracefully drain the least-loaded active
                    //    replicas, always keeping at least one active.
                    while shed > 0 {
                        let actives: Vec<usize> = rg
                            .rstate
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| **s == RState::Active)
                            .map(|(i, _)| i)
                            .collect();
                        if actives.len() <= 1 {
                            break;
                        }
                        let victim = *actives
                            .iter()
                            .min_by_key(|&&i| rg.replicas[i].outstanding)
                            .unwrap();
                        rg.rstate[victim] = RState::Draining;
                        rg.replicas[victim].begin_drain();
                        rg.timeline.drain_start(victim as u32, now);
                        for t in
                            reroute_queue(victim, &rg.rstate, &mut rg.replicas, &mut rg.router)
                        {
                            mr_try_start(
                                region,
                                t,
                                now,
                                cfg,
                                idle_gpus_per_stage,
                                rg,
                                &mut live,
                                cost.as_mut(),
                                &mut batch,
                                &mut scratch,
                                &mut queue,
                                &mut inflight,
                            );
                        }
                        if !rg.busy[victim] && !rg.replicas[victim].has_work() {
                            rg.rstate[victim] = RState::Offline;
                            rg.timeline.offline(victim as u32, now);
                        }
                        shed -= 1;
                    }
                }
                // Re-arm only while workload events are in flight: an
                // empty workload queue with unfinished requests is a
                // deadlock — let every tick chain die so the loop
                // exits and the ensure below reports it. (The plain
                // `!queue.is_empty()` test of the single-fleet core
                // would livelock here: two idle regions' ticks keep
                // each other alive forever.)
                if inflight > 0 {
                    queue.push(
                        now + decision_interval_s,
                        MrEventKind::ScaleTick { region },
                    );
                }
            }
        }
    }

    anyhow::ensure!(
        finished_count == submitted,
        "multi-fleet simulation ended with {finished_count}/{submitted} requests finished (deadlock?)"
    );

    let mut preemptions = 0u64;
    let mut merged: Option<StageStats> = None;
    let mut per_region = Vec::with_capacity(fleet.len());
    for mut rg in fleet {
        rg.timeline.close(last_time);
        preemptions += rg.replicas.iter().map(|r| r.preemptions).sum::<u64>();
        let stage_stats = rg.spec.sink.stats();
        match merged.as_mut() {
            None => merged = Some(stage_stats),
            Some(m) => m.merge(&stage_stats),
        }
        let mut request_stats = rg.spec.requests.stats();
        request_stats.submitted = rg.routed;
        let scaling_policy = rg
            .controller
            .as_ref()
            .map(|c| c.policy_name())
            .unwrap_or("fixed");
        per_region.push(RegionRun {
            timeline: rg.timeline,
            routed: rg.routed,
            stage_stats,
            request_stats,
            decisions: rg.controller.map(|c| c.decisions).unwrap_or_default(),
            scaling_policy,
            final_soc: rg.spec.microgrid.battery.soc,
        });
    }
    let stage_stats = merged.expect("at least one region");
    let mut request_stats = fleet_reqs.stats();
    request_stats.submitted = submitted;
    let metrics = SimMetrics::compute(&request_stats, &stage_stats, last_time, preemptions);
    Ok(MultiFleetRun {
        config: cfg.clone(),
        metrics,
        stage_stats,
        request_stats,
        sketches: fleet_reqs.into_sketches(),
        per_region,
        peak_live_requests: live.peak_resident(),
        oracle: cost.stats(),
        route_policy: policy.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::kvcache::KvCache;
    use crate::config::simconfig::{
        Arrival, CostModelKind, LengthDist, SchedulerKind, ScalingPolicyKind,
    };
    use crate::exec::batch::StageCost;

    /// Constant-time mock oracle: every stage takes 10 ms.
    struct MockCost;
    impl StageCostModel for MockCost {
        fn stage_cost(&mut self, b: &BatchDesc) -> StageCost {
            StageCost {
                t_stage_s: 0.01,
                flops: b.total_new_tokens() as f64 * 1e9,
                mfu: 0.2,
                power_w: 250.0,
            }
        }
        fn name(&self) -> &'static str {
            "mock"
        }
    }

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.num_requests = 40;
        cfg.cost_model = CostModelKind::Native;
        cfg.lengths = LengthDist::Zipf {
            theta: 0.6,
            min: 64,
            max: 512,
        };
        cfg.arrival = Arrival::Poisson { qps: 10.0 };
        cfg
    }

    #[test]
    fn all_requests_finish_native() {
        let out = run(&small_cfg()).unwrap();
        assert_eq!(out.requests.len(), 40);
        assert!(out.requests.iter().all(|r| r.is_finished()));
        assert!(out.metrics.makespan_s > 0.0);
        assert!(!out.stagelog.is_empty());
    }

    /// The lazy-arrival path and the materialized path are the same
    /// simulation: identical schedule, identical exact aggregates.
    #[test]
    fn streaming_run_matches_materialized_run() {
        let cfg = small_cfg();
        let mat = run(&cfg).unwrap();
        let mut stage_sink = StageLog::new();
        let stream = run_streaming(&cfg, &mut stage_sink).unwrap();
        assert_eq!(mat.metrics.makespan_s, stream.metrics.makespan_s);
        assert_eq!(mat.metrics.stage_count, stream.metrics.stage_count);
        assert_eq!(mat.metrics.achieved_qps, stream.metrics.achieved_qps);
        assert_eq!(mat.metrics.token_throughput, stream.metrics.token_throughput);
        assert_eq!(mat.metrics.slo_attained, stream.metrics.slo_attained);
        assert_eq!(stream.request_stats.finished, 40);
        assert_eq!(stream.request_stats.submitted, 40);
        // The live map never held the whole workload resident.
        assert!(stream.peak_live_requests <= 40);
    }

    #[test]
    fn mock_oracle_timing_is_deterministic() {
        let cfg = small_cfg();
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));
        let a = run_with_model(&cfg, trace.clone(), Box::new(MockCost)).unwrap();
        let b = run_with_model(&cfg, trace, Box::new(MockCost)).unwrap();
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.stagelog.len(), b.stagelog.len());
    }

    #[test]
    fn stage_times_are_contiguous_per_replica() {
        let out = run(&small_cfg()).unwrap();
        // Stages of one replica never overlap.
        let mut recs: Vec<_> = out.stagelog.records.iter().collect();
        recs.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
        let mut last_end = 0.0;
        for r in recs {
            assert!(
                r.start_s >= last_end - 1e-9,
                "overlap: starts {} before {}",
                r.start_s,
                last_end
            );
            last_end = r.end_s();
        }
    }

    #[test]
    fn timestamps_monotone_and_lifecycle_consistent() {
        let out = run(&small_cfg()).unwrap();
        for r in &out.requests {
            let sched = r.scheduled_s.unwrap();
            let first = r.first_token_s.unwrap();
            let fin = r.finished_s.unwrap();
            assert!(sched >= r.arrival_s);
            assert!(first >= sched);
            assert!(fin >= first);
        }
    }

    #[test]
    fn multi_replica_distributes_load() {
        let mut cfg = small_cfg();
        cfg.replicas = 2;
        cfg.num_requests = 60;
        let out = run(&cfg).unwrap();
        assert!(out.requests.iter().all(|r| r.is_finished()));
        let replicas_used: std::collections::HashSet<u32> =
            out.stagelog.records.iter().map(|r| r.replica).collect();
        assert_eq!(replicas_used.len(), 2, "both replicas must execute work");
    }

    #[test]
    fn pp_stages_logged_per_iteration() {
        let mut cfg = small_cfg();
        cfg.pp = 2;
        cfg.tp = 2;
        cfg.num_requests = 10;
        let out = run(&cfg).unwrap();
        // Every iteration logs exactly pp stage records.
        assert_eq!(out.stagelog.len() % 2, 0);
        let r = &out.stagelog.records[0];
        assert_eq!(r.active_gpus, 2);
        assert_eq!(r.idle_gpus, 2); // (pp-1)*tp
    }

    #[test]
    fn higher_qps_shrinks_makespan() {
        // Same workload executed faster when offered load arrives faster
        // (the Exp. 4 energy-vs-QPS mechanism).
        let mut lo = small_cfg();
        lo.arrival = Arrival::Poisson { qps: 1.0 };
        lo.num_requests = 50;
        let mut hi = lo.clone();
        hi.arrival = Arrival::Poisson { qps: 20.0 };
        let out_lo = run(&lo).unwrap();
        let out_hi = run(&hi).unwrap();
        assert!(
            out_hi.metrics.makespan_s < out_lo.metrics.makespan_s,
            "hi {} !< lo {}",
            out_hi.metrics.makespan_s,
            out_lo.metrics.makespan_s
        );
    }

    // --- rebalance (ReplicaOnline) ---

    fn bare_replica(id: u32) -> ReplicaScheduler {
        ReplicaScheduler::with_kv(
            id,
            SchedulerKind::Vllm,
            128,
            512,
            KvCache::with_blocks(16, 1000),
        )
    }

    /// Satellite regression: with a 1-request backlog across 2 actives
    /// the floor share was 0 and the cold-started replica idled; the
    /// ceiling share hands it the queued request.
    #[test]
    fn rebalance_moves_small_backlog_to_new_replica() {
        let mut reps = vec![bare_replica(0), bare_replica(1)];
        reps[0].enqueue(7);
        rebalance_onto(1, &[0, 1], &mut reps);
        assert_eq!(reps[1].queue_len(), 1, "newcomer must take the backlog");
        assert_eq!(reps[0].queue_len(), 0);
    }

    #[test]
    fn rebalance_takes_ceiling_share_and_leaves_floor() {
        let mut reps = vec![bare_replica(0), bare_replica(1)];
        for id in 0..5 {
            reps[0].enqueue(id);
        }
        rebalance_onto(1, &[0, 1], &mut reps);
        // ceil(5/2) = 3 to the newcomer, floor(5/2) = 2 stay.
        assert_eq!(reps[1].queue_len(), 3);
        assert_eq!(reps[0].queue_len(), 2);
    }

    #[test]
    fn rebalance_noop_without_backlog() {
        let mut reps = vec![bare_replica(0), bare_replica(1)];
        rebalance_onto(1, &[0, 1], &mut reps);
        assert_eq!(reps[0].queue_len(), 0);
        assert_eq!(reps[1].queue_len(), 0);
    }

    // --- dynamic fleet ---

    fn scale_cfg(policy: ScalingPolicyKind) -> AutoscaleConfig {
        let mut s = AutoscaleConfig::default();
        s.policy = policy;
        s.decision_interval_s = 2.0;
        s.cold_start_s = 1.0;
        s
    }

    #[test]
    fn static_policy_matches_fixed_fleet_engine() {
        let mut cfg = small_cfg();
        cfg.replicas = 2;
        cfg.num_requests = 80;
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));

        let base = run_with_trace(&cfg, trace.clone()).unwrap();
        let mut s = scale_cfg(ScalingPolicyKind::Static);
        s.min_replicas = 2;
        s.max_replicas = 2;
        let auto =
            run_autoscaled(&cfg, &s, &GridEnv::constant(150.0, 0.0), trace).unwrap();

        assert!(auto.sim.requests.iter().all(|r| r.is_finished()));
        assert_eq!(auto.timeline.max_fleet(), 2);
        assert_eq!(auto.timeline.mean_fleet(), 2.0);
        // Same trace, same fleet, same oracle: identical schedule.
        let rel = (auto.sim.metrics.makespan_s - base.metrics.makespan_s).abs()
            / base.metrics.makespan_s;
        assert!(rel < 1e-2, "makespans diverge: {rel}");
        assert_eq!(auto.sim.stagelog.len(), base.stagelog.len());
    }

    #[test]
    fn reactive_scales_up_under_burst() {
        let mut cfg = small_cfg();
        cfg.replicas = 1;
        cfg.num_requests = 300;
        cfg.arrival = Arrival::Poisson { qps: 60.0 };
        cfg.batch_cap = 8; // small batches force a backlog
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));

        let mut s = scale_cfg(ScalingPolicyKind::Reactive);
        s.queue_high = 4.0;
        let out =
            run_autoscaled(&cfg, &s, &GridEnv::constant(150.0, 0.0), trace).unwrap();
        assert!(out.sim.requests.iter().all(|r| r.is_finished()));
        assert!(
            out.timeline.max_fleet() > 1,
            "burst never scaled up: decisions {:?}",
            out.decisions
        );
        // Replicas beyond the first went through a real cold start.
        assert!(out
            .timeline
            .spans
            .iter()
            .skip(1)
            .all(|sp| sp.online_s.map(|t| t >= sp.up_s + 1.0).unwrap_or(true)));
    }

    #[test]
    fn carbon_policy_drains_on_dirty_grid_and_work_survives() {
        let mut cfg = small_cfg();
        cfg.replicas = 3;
        cfg.num_requests = 200;
        cfg.arrival = Arrival::Poisson { qps: 8.0 };
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));

        let s = scale_cfg(ScalingPolicyKind::CarbonAware);
        // Permanently dirty grid: fleet must shed towards min_replicas.
        let out =
            run_autoscaled(&cfg, &s, &GridEnv::constant(500.0, 0.0), trace).unwrap();
        assert!(out.sim.requests.iter().all(|r| r.is_finished()));
        let (_, downs) = out.timeline.scale_event_counts();
        assert!(downs >= 2, "dirty grid should drain replicas");
        // Drained replicas saw a graceful lifecycle.
        for sp in &out.timeline.spans {
            if let (Some(d), Some(down)) = (sp.drain_s, sp.down_s) {
                assert!(down >= d, "offline before drain on {sp:?}");
            }
        }
        assert!(out.timeline.mean_fleet() < 3.0);
    }

    #[test]
    fn autoscaled_run_is_deterministic() {
        let mut cfg = small_cfg();
        cfg.num_requests = 120;
        cfg.arrival = Arrival::Poisson { qps: 30.0 };
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));
        let s = scale_cfg(ScalingPolicyKind::Reactive);
        let a = run_autoscaled_with_model(
            &cfg,
            &s,
            &GridEnv::constant(150.0, 0.0),
            trace.clone(),
            Box::new(MockCost),
        )
        .unwrap();
        let b = run_autoscaled_with_model(
            &cfg,
            &s,
            &GridEnv::constant(150.0, 0.0),
            trace,
            Box::new(MockCost),
        )
        .unwrap();
        assert_eq!(a.sim.metrics.makespan_s, b.sim.metrics.makespan_s);
        assert_eq!(a.sim.stagelog.len(), b.sim.stagelog.len());
        assert_eq!(a.timeline.events.len(), b.timeline.events.len());
    }

    // --- calendar queue vs binary heap: exact event-order parity ---

    /// The calendar-queue engine is the same simulation as the heap
    /// engine, bit for bit: identical stage records and exact metric
    /// equality (tests/calq_parity.rs extends this to byte-identical
    /// CSV exports).
    #[test]
    fn calendar_and_heap_engines_are_identical() {
        let mut cfg = small_cfg();
        cfg.replicas = 2;
        cfg.num_requests = 120;
        cfg.arrival = Arrival::Poisson { qps: 30.0 };
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));

        let mut cal_stages = StageLog::new();
        let mut cal_reqs = RequestLog::new(&cfg);
        let mut src = trace.clone().into_source();
        let cal = run_with_sinks(
            &cfg,
            &mut src,
            Box::new(MockCost),
            &mut cal_stages,
            &mut cal_reqs,
        )
        .unwrap();

        let mut heap_stages = StageLog::new();
        let mut heap_reqs = RequestLog::new(&cfg);
        let mut src = trace.into_source();
        let heap = run_with_sinks_heap(
            &cfg,
            &mut src,
            Box::new(MockCost),
            &mut heap_stages,
            &mut heap_reqs,
        )
        .unwrap();

        assert_eq!(cal.metrics.makespan_s, heap.metrics.makespan_s);
        assert_eq!(cal.metrics.stage_count, heap.metrics.stage_count);
        assert_eq!(cal_stages.len(), heap_stages.len());
        for (a, b) in cal_stages.records.iter().zip(&heap_stages.records) {
            assert_eq!(a.replica, b.replica);
            assert_eq!(a.start_s, b.start_s);
            assert_eq!(a.batch_size, b.batch_size);
            assert_eq!(a.new_tokens, b.new_tokens);
        }
    }

    #[test]
    fn autoscaled_calendar_and_heap_engines_are_identical() {
        let mut cfg = small_cfg();
        cfg.num_requests = 150;
        cfg.arrival = Arrival::Poisson { qps: 40.0 };
        cfg.batch_cap = 8;
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));
        let s = scale_cfg(ScalingPolicyKind::Reactive);
        let grid = GridEnv::constant(150.0, 0.0);

        let mut cal_stages = StageLog::new();
        let mut cal_reqs = RequestLog::new(&cfg);
        let mut src = trace.clone().into_source();
        let cal = run_autoscaled_with_sinks(
            &cfg,
            &s,
            &grid,
            &mut src,
            Box::new(MockCost),
            &mut cal_stages,
            &mut cal_reqs,
        )
        .unwrap();

        let mut heap_stages = StageLog::new();
        let mut heap_reqs = RequestLog::new(&cfg);
        let mut src = trace.into_source();
        let heap = run_autoscaled_with_sinks_heap(
            &cfg,
            &s,
            &grid,
            &mut src,
            Box::new(MockCost),
            &mut heap_stages,
            &mut heap_reqs,
        )
        .unwrap();

        assert_eq!(cal.sim.metrics.makespan_s, heap.sim.metrics.makespan_s);
        assert_eq!(cal_stages.len(), heap_stages.len());
        assert_eq!(cal.timeline.events.len(), heap.timeline.events.len());
        assert_eq!(cal.decisions.len(), heap.decisions.len());
        for (a, b) in cal_stages.records.iter().zip(&heap_stages.records) {
            assert_eq!((a.replica, a.start_s), (b.replica, b.start_s));
        }
    }

    fn mr_region<'a>(
        replicas: u32,
        scale: Option<AutoscaleConfig>,
        ci: f64,
        rtt_s: f64,
        sink: &'a mut dyn StageSink,
        requests: &'a mut dyn RequestSink,
    ) -> RegionSim<'a> {
        use crate::battery::Battery;
        use crate::config::simconfig::CosimConfig;
        RegionSim {
            replicas,
            scale,
            grid: GridEnv::constant(ci, 0.0),
            rtt_s,
            power_est_w: 300.0,
            microgrid: Microgrid::new(Battery::from_config(&CosimConfig::default())),
            interval_s: 60.0,
            transfer_overhead: if rtt_s > 0.0 { 0.05 } else { 0.0 },
            sink,
            requests,
        }
    }

    /// One fixed-fleet region under the multi-fleet core is the same
    /// simulation as the fixed core: identical event order, identical
    /// telemetry (the byte-neutrality the integration test pins at the
    /// CSV level).
    #[test]
    fn single_region_multifleet_matches_fixed_fleet_engine() {
        use crate::coordinator::fleet::RoutePolicyKind;

        let mut cfg = small_cfg();
        cfg.replicas = 2;
        cfg.num_requests = 60;
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));

        let mut base_stages = StageLog::new();
        let mut base_reqs = RequestLog::new(&cfg);
        let mut src = trace.clone().into_source();
        let base = run_with_sinks(
            &cfg,
            &mut src,
            Box::new(MockCost),
            &mut base_stages,
            &mut base_reqs,
        )
        .unwrap();

        let mut stages = StageLog::new();
        let mut reqs = RequestLog::new(&cfg);
        let mut src = trace.into_source();
        let mut policy = RoutePolicyKind::StaticHome.build(cfg.slo_ttft_s);
        let region = mr_region(cfg.replicas, None, 418.2, 0.0, &mut stages, &mut reqs);
        let run = run_multifleet(
            &cfg,
            &mut src,
            Box::new(MockCost),
            policy.as_mut(),
            vec![region],
        )
        .unwrap();

        assert_eq!(base.metrics.makespan_s, run.metrics.makespan_s);
        assert_eq!(base.metrics.stage_count, run.metrics.stage_count);
        assert_eq!(base_stages.len(), stages.len());
        for (a, b) in base_stages.records.iter().zip(&stages.records) {
            assert_eq!((a.replica, a.start_s, a.dt_s), (b.replica, b.start_s, b.dt_s));
        }
        assert_eq!(run.per_region.len(), 1);
        assert_eq!(run.per_region[0].routed, cfg.num_requests);
        assert_eq!(run.per_region[0].scaling_policy, "fixed");
    }

    /// Three regions (home autoscaled, remotes fixed) under greedy-ci:
    /// every request finishes exactly once, the per-region routing
    /// counts partition the workload, and the cheapest region wins the
    /// bulk of the traffic despite its RTT.
    #[test]
    fn multifleet_routes_across_regions_and_conserves_requests() {
        use crate::coordinator::fleet::RoutePolicyKind;

        let mut cfg = small_cfg();
        cfg.num_requests = 60;
        let mut gen = WorkloadGenerator::from_config(&cfg);
        let trace = Trace::new(gen.generate(cfg.num_requests));
        let mut src = trace.into_source();

        let mut s0 = StageLog::new();
        let mut s1 = StageLog::new();
        let mut s2 = StageLog::new();
        let mut r0 = RequestLog::new(&cfg);
        let mut r1 = RequestLog::new(&cfg);
        let mut r2 = RequestLog::new(&cfg);
        let scale = scale_cfg(ScalingPolicyKind::Reactive);
        let mut policy = RoutePolicyKind::GreedyCi.build(cfg.slo_ttft_s);
        let regions = vec![
            mr_region(1, Some(scale), 418.2, 0.0, &mut s0, &mut r0),
            mr_region(1, None, 650.0, 0.05, &mut s1, &mut r1),
            mr_region(1, None, 120.0, 0.05, &mut s2, &mut r2),
        ];
        let run = run_multifleet(&cfg, &mut src, Box::new(MockCost), policy.as_mut(), regions)
            .unwrap();

        assert_eq!(run.request_stats.finished, 60);
        let routed: u64 = run.per_region.iter().map(|r| r.routed).sum();
        assert_eq!(routed, 60);
        let finished: u64 = run.per_region.iter().map(|r| r.request_stats.finished).sum();
        assert_eq!(finished, 60);
        // Constant CIs: greedy-ci always picks the 120 g/kWh region.
        assert_eq!(run.per_region[2].routed, 60);
        assert_eq!(run.route_policy, "greedy-ci");
        // The remote hop delays admission, never loses a request.
        assert!(run.metrics.makespan_s > 0.0);
    }
}
