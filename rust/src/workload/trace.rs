//! Request-trace export/replay: persist a generated workload as CSV so
//! runs are exactly repeatable across configurations (the paper holds
//! the workload fixed while sweeping batch size, QPS, parallelism).

use crate::util::csv::Table;
use crate::workload::request::Request;
use crate::workload::store::RequestSource;
use anyhow::{bail, Result};
use std::path::Path;

/// A materialized request stream.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn new(requests: Vec<Request>) -> Self {
        Trace { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Duration from first to last arrival.
    pub fn arrival_span_s(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        self.requests.last().unwrap().arrival_s - self.requests[0].arrival_s
    }

    pub fn total_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.total_tokens()).sum()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut t = Table::new(&["id", "arrival_s", "prefill_tokens", "decode_tokens"]);
        for r in &self.requests {
            t.push_row(vec![
                r.id.to_string(),
                // Shortest-roundtrip formatting: load() recovers the
                // exact f64, so save -> load -> re-save is
                // byte-identical and a replayed trace reproduces the
                // generator's arrivals bit-for-bit.
                format!("{}", r.arrival_s),
                r.prefill_tokens.to_string(),
                r.decode_tokens.to_string(),
            ]);
        }
        t.save(path)
    }

    /// Consume the trace into a pull-based [`RequestSource`]: requests
    /// sorted by arrival with ids reassigned to 0..n (the engine's
    /// historical indexing contract), yielded one at a time.
    pub fn into_source(mut self) -> TraceSource {
        // total_cmp, not partial_cmp().unwrap(): a NaN arrival that
        // slipped past validation must not panic the sort. (load()
        // rejects NaN rows up front; this guards hand-built traces.)
        self.requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        TraceSource {
            iter: self.requests.into_iter(),
            next_id: 0,
        }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Trace> {
        let path = path.as_ref();
        let t = Table::load(path)?;
        let ids = t.f64_col("id")?;
        let at = t.f64_col("arrival_s")?;
        let pf = t.f64_col("prefill_tokens")?;
        let dc = t.f64_col("decode_tokens")?;
        let mut requests = Vec::with_capacity(ids.len());
        for (i, (((id, a), p), d)) in ids.iter().zip(&at).zip(&pf).zip(&dc).enumerate() {
            // Validate before Request::new (which would panic) and
            // before the sort (which would mis-order on NaN). Line
            // numbers are 1-based with the header on line 1.
            let line = i + 2;
            if !a.is_finite() {
                bail!("{}:{line}: non-finite arrival time {a}", path.display());
            }
            if *a < 0.0 {
                bail!("{}:{line}: negative arrival time {a}", path.display());
            }
            for (v, what) in [(p, "prefill_tokens"), (d, "decode_tokens")] {
                if !v.is_finite() || *v < 1.0 {
                    bail!(
                        "{}:{line}: {what} must be a finite count >= 1, got {v}",
                        path.display()
                    );
                }
            }
            requests.push(Request::new(*id as u64, *a, *p as u64, *d as u64));
        }
        requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        Ok(Trace { requests })
    }
}

/// Arrival-ordered pull source over a materialized [`Trace`] (see
/// [`Trace::into_source`]).
pub struct TraceSource {
    iter: std::vec::IntoIter<Request>,
    next_id: u64,
}

impl RequestSource for TraceSource {
    fn next_request(&mut self) -> Option<Request> {
        let mut r = self.iter.next()?;
        r.id = self.next_id;
        self.next_id += 1;
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::{Arrival, LengthDist};
    use crate::workload::generator::WorkloadGenerator;

    #[test]
    fn roundtrip_through_csv() {
        let mut g = WorkloadGenerator::new(
            Arrival::Poisson { qps: 6.45 },
            LengthDist::Zipf { theta: 0.6, min: 128, max: 2048 },
            None,
            4096,
            5,
        );
        let tr = Trace::new(g.generate(50));
        let dir = std::env::temp_dir().join("vidur_energy_trace_test");
        let path = dir.join("trace.csv");
        tr.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.len(), tr.len());
        for (a, b) in tr.requests.iter().zip(&back.requests) {
            assert_eq!(a.id, b.id);
            // Shortest-roundtrip save formatting: arrivals come back
            // bit-exact, not merely close.
            assert!(a.arrival_s == b.arrival_s, "{} != {}", a.arrival_s, b.arrival_s);
            assert_eq!(a.prefill_tokens, b.prefill_tokens);
            assert_eq!(a.decode_tokens, b.decode_tokens);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_rejects_malformed_rows_with_line_numbers() {
        let dir = std::env::temp_dir().join("vidur_energy_trace_malformed");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, body: &str| {
            let p = dir.join(name);
            std::fs::write(&p, body).unwrap();
            p
        };
        let header = "id,arrival_s,prefill_tokens,decode_tokens\n";

        let nan = write("nan.csv", &format!("{header}0,0.5,10,5\n1,NaN,10,5\n"));
        let err = Trace::load(&nan).unwrap_err().to_string();
        assert!(err.contains(":3:") && err.contains("non-finite"), "{err}");

        let neg = write("neg.csv", &format!("{header}0,-1.0,10,5\n"));
        let err = Trace::load(&neg).unwrap_err().to_string();
        assert!(err.contains(":2:") && err.contains("negative"), "{err}");

        let zero = write("zero.csv", &format!("{header}0,0.5,0,5\n"));
        let err = Trace::load(&zero).unwrap_err().to_string();
        assert!(err.contains(":2:") && err.contains("prefill_tokens"), "{err}");

        let inf = write("inf.csv", &format!("{header}0,0.5,10,inf\n"));
        let err = Trace::load(&inf).unwrap_err().to_string();
        assert!(err.contains("decode_tokens"), "{err}");
    }

    #[test]
    fn into_source_survives_nan_arrival() {
        // Hand-built traces bypass load() validation; the sort must
        // not panic (regression: partial_cmp().unwrap()).
        let tr = Trace::new(vec![
            Request::new(0, f64::NAN, 10, 5),
            Request::new(1, 1.0, 10, 5),
        ]);
        let mut src = tr.into_source();
        let mut n = 0;
        while src.next_request().is_some() {
            n += 1;
        }
        assert_eq!(n, 2);
    }

    #[test]
    fn into_source_sorts_and_reassigns_ids() {
        let tr = Trace::new(vec![
            Request::new(7, 4.0, 20, 5),
            Request::new(3, 1.0, 10, 5),
            Request::new(9, 2.5, 15, 5),
        ]);
        let mut src = tr.into_source();
        let mut got = Vec::new();
        while let Some(r) = src.next_request() {
            got.push((r.id, r.arrival_s));
        }
        assert_eq!(got, vec![(0, 1.0), (1, 2.5), (2, 4.0)]);
    }

    #[test]
    fn span_and_tokens() {
        let tr = Trace::new(vec![
            Request::new(0, 1.0, 10, 5),
            Request::new(1, 4.0, 20, 5),
        ]);
        assert_eq!(tr.arrival_span_s(), 3.0);
        assert_eq!(tr.total_tokens(), 40);
    }
}
