//! Request storage the scheduler and engine operate over.
//!
//! The schedulers address requests by id. Historically that storage
//! was a `Vec<Request>` holding the *entire* workload (O(requests)
//! resident for the whole run). [`RequestStore`] abstracts the id →
//! request lookup so the engine can instead keep a [`LiveRequests`]
//! map of only the outstanding requests — entries are inserted when
//! the arrival event fires and dropped the moment the request
//! completes and has been handed to the request sink
//! ([`crate::telemetry::RequestSink`]). A multi-million-request run
//! then holds O(outstanding) request state, not O(requests)
//! (DESIGN.md §8).

use crate::workload::request::Request;
use std::collections::HashMap;

/// Mutable id-addressed request storage.
///
/// Implemented by `[Request]` / `Vec<Request>` (tests and materialized
/// traces, where `id` indexes the vector) and by [`LiveRequests`] (the
/// engine's compact map of outstanding requests). Lookups panic on an
/// unknown id: the schedulers only hold ids they were handed, so a
/// miss is always an engine-side lifecycle bug.
pub trait RequestStore {
    fn req(&self, id: u64) -> &Request;
    fn req_mut(&mut self, id: u64) -> &mut Request;
}

impl RequestStore for [Request] {
    fn req(&self, id: u64) -> &Request {
        &self[id as usize]
    }
    fn req_mut(&mut self, id: u64) -> &mut Request {
        &mut self[id as usize]
    }
}

impl RequestStore for Vec<Request> {
    fn req(&self, id: u64) -> &Request {
        &self[id as usize]
    }
    fn req_mut(&mut self, id: u64) -> &mut Request {
        &mut self[id as usize]
    }
}

/// Multiplicative hasher for the dense sequential request ids — the
/// live map sits on the scheduler's per-stage lookup path, where the
/// default SipHash would cost tens of millions of needless hash
/// rounds per multi-million-request run. One Fibonacci multiply
/// spreads sequential keys across buckets.
#[derive(Clone, Copy, Default)]
pub struct IdHasher(u64);

impl std::hash::Hasher for IdHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        // Only u64 request ids are ever hashed; this path is for
        // completeness.
        for &b in bytes {
            self.0 = self.0.rotate_left(8) ^ u64::from(b);
        }
        self.0 = self.0.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }
    fn write_u64(&mut self, v: u64) {
        self.0 = v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }
}

type IdMap = HashMap<u64, Request, std::hash::BuildHasherDefault<IdHasher>>;

/// The outstanding-request map: holds each request from its arrival
/// event until completion, then drops it. Tracks the peak resident
/// count — the engine's whole per-request memory footprint, asserted
/// O(outstanding) in `tests/request_telemetry.rs`.
#[derive(Debug, Default)]
pub struct LiveRequests {
    map: IdMap,
    peak: usize,
}

impl LiveRequests {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit an arriving request. Ids must be unique while live.
    pub fn insert(&mut self, r: Request) {
        let prev = self.map.insert(r.id, r);
        debug_assert!(prev.is_none(), "duplicate live request id");
        self.peak = self.peak.max(self.map.len());
    }

    /// Retire a completed request, returning it for the sink.
    pub fn remove(&mut self, id: u64) -> Request {
        self.map
            .remove(&id)
            .unwrap_or_else(|| panic!("request {id} not live"))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// High-water mark of concurrently live requests.
    pub fn peak_resident(&self) -> usize {
        self.peak
    }
}

impl RequestStore for LiveRequests {
    fn req(&self, id: u64) -> &Request {
        self.map
            .get(&id)
            .unwrap_or_else(|| panic!("request {id} not live"))
    }
    fn req_mut(&mut self, id: u64) -> &mut Request {
        self.map
            .get_mut(&id)
            .unwrap_or_else(|| panic!("request {id} not live"))
    }
}

/// Pull-based arrival stream: yields requests one at a time in
/// nondecreasing `arrival_s` order, so the engine keeps exactly one
/// pending-arrival event in its heap instead of pre-pushing the whole
/// workload. Implemented by [`crate::workload::trace::TraceSource`]
/// (materialized traces), [`crate::workload::generator::LazyWorkload`]
/// (on-the-fly generation, the O(1)-memory front of the pipeline),
/// [`crate::workload::replay::ReplaySource`] (streaming trace replay
/// off disk), and the [`crate::workload::scenario`] generators
/// (chat/rag/agentic/tenants plus their weighted
/// [`crate::workload::scenario::MixSource`]). The conformance suite in
/// `tests/workload_sources.rs` pins this contract for every
/// implementation.
pub trait RequestSource {
    /// The next request, or `None` when the workload is exhausted.
    /// Arrival times must be nondecreasing and ids unique.
    fn next_request(&mut self) -> Option<Request>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_map_tracks_peak_and_drops_finished() {
        let mut live = LiveRequests::new();
        for i in 0..4u64 {
            live.insert(Request::new(i, i as f64, 10, 5));
        }
        assert_eq!(live.len(), 4);
        live.remove(1);
        live.remove(3);
        assert_eq!(live.len(), 2);
        assert_eq!(live.peak_resident(), 4);
        live.insert(Request::new(9, 9.0, 10, 5));
        assert_eq!(live.peak_resident(), 4);
        assert_eq!(live.req(9).id, 9);
        live.req_mut(0).prefill_done = 3;
        assert_eq!(live.req(0).prefill_done, 3);
    }

    #[test]
    #[should_panic(expected = "not live")]
    fn removing_unknown_id_panics() {
        LiveRequests::new().remove(7);
    }

    #[test]
    fn slice_store_indexes_by_id() {
        let mut v = vec![Request::new(0, 0.0, 5, 5), Request::new(1, 1.0, 5, 5)];
        assert_eq!(v.req(1).id, 1);
        v.req_mut(0).decode_done = 2;
        assert_eq!(v[0].decode_done, 2);
    }
}
