//! Region-splitting [`RequestSource`] adapter: partition one arrival
//! stream across N regions for independently-driven per-region fleets
//! (the pre-partitioned counterpart of the admission-time router in
//! [`crate::coordinator::fleet`] — useful for baselines where the
//! assignment is fixed up front rather than decided per request).
//!
//! Each partition preserves arrival order and re-ids its requests
//! densely from 0, so a partition is a self-contained workload any
//! engine entry point accepts.

use crate::workload::{Request, RequestSource, Trace};

/// One region's share of a split workload. Implements
/// [`RequestSource`], yielding its requests in arrival order.
pub struct SplitSource {
    requests: std::vec::IntoIter<Request>,
}

impl SplitSource {
    pub fn len_hint(&self) -> usize {
        self.requests.len()
    }
}

impl RequestSource for SplitSource {
    fn next_request(&mut self) -> Option<Request> {
        self.requests.next()
    }
}

/// Split `trace` into `n_regions` partitions with an explicit
/// assignment function (request → region index, clamped into range).
/// Requests keep their arrival times; ids are re-issued densely per
/// partition.
pub fn split_trace(
    trace: &Trace,
    n_regions: usize,
    mut assign: impl FnMut(&Request) -> usize,
) -> Vec<SplitSource> {
    assert!(n_regions > 0, "cannot split into zero regions");
    let mut parts: Vec<Vec<Request>> = (0..n_regions).map(|_| Vec::new()).collect();
    for r in &trace.requests {
        let region = assign(r).min(n_regions - 1);
        let id = parts[region].len() as u64;
        parts[region].push(Request::new(
            id,
            r.arrival_s,
            r.prefill_tokens,
            r.decode_tokens,
        ));
    }
    parts
        .into_iter()
        .map(|requests| SplitSource {
            requests: requests.into_iter(),
        })
        .collect()
}

/// Round-robin split: request k goes to region k mod n.
pub fn split_round_robin(trace: &Trace, n_regions: usize) -> Vec<SplitSource> {
    let mut k = 0usize;
    split_trace(trace, n_regions, move |_| {
        let r = k % n_regions.max(1);
        k += 1;
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::SimConfig;
    use crate::workload::WorkloadGenerator;

    fn trace(n: u64) -> Trace {
        let mut cfg = SimConfig::default();
        cfg.num_requests = n;
        let mut gen = WorkloadGenerator::from_config(&cfg);
        Trace::new(gen.generate(n))
    }

    #[test]
    fn partitions_are_exhaustive_and_order_preserving() {
        let t = trace(50);
        let parts = split_round_robin(&t, 3);
        assert_eq!(parts.len(), 3);
        let mut total = 0usize;
        for mut p in parts {
            let mut last = f64::NEG_INFINITY;
            let mut next_id = 0u64;
            while let Some(r) = p.next_request() {
                assert!(r.arrival_s >= last, "arrival order broken");
                assert_eq!(r.id, next_id, "ids not dense");
                last = r.arrival_s;
                next_id += 1;
                total += 1;
            }
        }
        assert_eq!(total, 50, "split lost or duplicated requests");
    }

    #[test]
    fn assignment_function_is_respected_and_clamped() {
        let t = trace(10);
        // Everything to region 7 of 2 → clamped to the last region.
        let parts = split_trace(&t, 2, |_| 7);
        assert_eq!(parts[0].len_hint(), 0);
        assert_eq!(parts[1].len_hint(), 10);
    }
}
