//! The unit of work: one inference request with prefill/decode token
//! budgets and the lifecycle timestamps the metrics layer needs.

pub type RequestId = u64;

/// Lifecycle state of a request inside a replica scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting in the replica queue (not yet admitted to a batch).
    Queued,
    /// Prefill in progress (`prefill_done < prefill_tokens`).
    Prefill,
    /// Autoregressive decode (one token per iteration).
    Decode,
    /// All decode tokens produced.
    Finished,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time, seconds of simulation clock.
    pub arrival_s: f64,
    /// Prompt tokens to prefill.
    pub prefill_tokens: u64,
    /// Tokens to generate.
    pub decode_tokens: u64,

    // --- progress (mutated by the scheduler) ---
    pub prefill_done: u64,
    pub decode_done: u64,

    // --- lifecycle timestamps (set by the simulator) ---
    /// First admitted into a running batch.
    pub scheduled_s: Option<f64>,
    /// First output token produced (end of first decode iteration).
    pub first_token_s: Option<f64>,
    /// Completed.
    pub finished_s: Option<f64>,
}

impl Request {
    pub fn new(id: RequestId, arrival_s: f64, prefill_tokens: u64, decode_tokens: u64) -> Self {
        assert!(prefill_tokens > 0, "request must have a prompt");
        assert!(decode_tokens > 0, "request must generate >= 1 token");
        Request {
            id,
            arrival_s,
            prefill_tokens,
            decode_tokens,
            prefill_done: 0,
            decode_done: 0,
            scheduled_s: None,
            first_token_s: None,
            finished_s: None,
        }
    }

    pub fn total_tokens(&self) -> u64 {
        self.prefill_tokens + self.decode_tokens
    }

    /// Tokens currently resident in the KV cache.
    pub fn context_len(&self) -> u64 {
        self.prefill_done + self.decode_done
    }

    pub fn phase(&self) -> Phase {
        if self.decode_done >= self.decode_tokens {
            Phase::Finished
        } else if self.prefill_done >= self.prefill_tokens {
            Phase::Decode
        } else if self.prefill_done > 0 || self.scheduled_s.is_some() {
            Phase::Prefill
        } else {
            Phase::Queued
        }
    }

    pub fn is_finished(&self) -> bool {
        self.phase() == Phase::Finished
    }

    /// Remaining prefill tokens.
    pub fn prefill_remaining(&self) -> u64 {
        self.prefill_tokens - self.prefill_done
    }

    /// End-to-end latency (None until finished).
    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished_s.map(|f| f - self.arrival_s)
    }

    /// Time to first token (None until the first token exists).
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|f| f - self.arrival_s)
    }

    /// Split a total length into (prefill, decode) by a P:D ratio
    /// (Exp. 2: ratios from 50:1 to 1:50), guaranteeing both >= 1.
    pub fn split_by_ratio(total: u64, ratio: f64) -> (u64, u64) {
        assert!(total >= 2, "need at least 2 tokens to split");
        assert!(ratio > 0.0);
        let prefill = ((total as f64) * ratio / (1.0 + ratio)).round() as u64;
        let prefill = prefill.clamp(1, total - 1);
        (prefill, total - prefill)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_phases() {
        let mut r = Request::new(1, 0.0, 100, 10);
        assert_eq!(r.phase(), Phase::Queued);
        r.scheduled_s = Some(0.1);
        assert_eq!(r.phase(), Phase::Prefill);
        r.prefill_done = 100;
        assert_eq!(r.phase(), Phase::Decode);
        r.decode_done = 10;
        assert_eq!(r.phase(), Phase::Finished);
        assert!(r.is_finished());
    }

    #[test]
    fn context_grows_with_progress() {
        let mut r = Request::new(1, 0.0, 50, 5);
        assert_eq!(r.context_len(), 0);
        r.prefill_done = 50;
        r.decode_done = 3;
        assert_eq!(r.context_len(), 53);
    }

    #[test]
    fn latency_metrics() {
        let mut r = Request::new(1, 2.0, 10, 2);
        assert_eq!(r.e2e_latency(), None);
        r.first_token_s = Some(3.0);
        r.finished_s = Some(5.0);
        assert_eq!(r.ttft(), Some(1.0));
        assert_eq!(r.e2e_latency(), Some(3.0));
    }

    #[test]
    fn split_by_ratio_extremes() {
        // 50:1 prefill-heavy.
        let (p, d) = Request::split_by_ratio(1020, 50.0);
        assert_eq!(p + d, 1020);
        assert!(p as f64 / d as f64 > 40.0);
        // 1:50 decode-heavy.
        let (p, d) = Request::split_by_ratio(1020, 1.0 / 50.0);
        assert!(d as f64 / p as f64 > 40.0);
        // Both always >= 1.
        let (p, d) = Request::split_by_ratio(2, 1000.0);
        assert!(p >= 1 && d >= 1);
        let (p, d) = Request::split_by_ratio(2, 0.0001);
        assert!(p >= 1 && d >= 1);
    }

    #[test]
    #[should_panic(expected = "prompt")]
    fn zero_prefill_rejected() {
        Request::new(1, 0.0, 0, 5);
    }
}
